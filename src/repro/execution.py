"""Execution plans and fleet-coupling specs — the one config surface
shared by `repro.tune.optimize`, `repro.fleet.backtest` and
`repro.dispatch.dispatch`.

Two orthogonal questions used to be answered by one accreting pile of
`TuneConfig` fields (``chunk_rows`` / ``shard`` / ``dispatch`` /
``dispatch_soft`` / ``dispatch_blend`` / ... with mutually-exclusive
semantics enforced by scattered runtime raises):

  * **What couples the fleet?** — `Coupling`: which fleet-level terms
    bind the objective (total-power cap, aggregate-compute floor, the
    dispatch-aware water-fill term) plus the hard-dispatch re-scoring
    config. A default `Coupling()` binds nothing.
  * **How does the batch execute?** — `ExecutionPlan`: one program, row
    chunks of a fixed size, or `shard_map` over devices, and which
    reproducibility contract the caller expects (bitwise for chunking,
    ULP for sharding).

Both are frozen (hashable) dataclasses, so they ride inside jit-static
configs exactly like the NamedTuples they replace. The legality rule
that used to live in `tune.optimizer._run_loop` — a *chunked* program
cannot evaluate a coupled objective, because coupled terms see every
row at once — is a constructor invariant here
(`validate_plan_coupling`), raised when the pair is first assembled
instead of deep inside the hot-loop dispatcher. Sharding a coupled
objective is legal since the psum-reduction rework: the sharded
objective reduces its fleet aggregates over `parallel.row_mesh` with
`jax.lax.psum`, so every shard sees the whole fleet's totals.

This module intentionally imports nothing from the engine layers (the
dispatch config it carries is duck-typed), so `tune`, `fleet`,
`dispatch` and `live` can all depend on it without cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

_MODES = ("auto", "single", "chunked", "sharded")
_CONTRACTS = ("auto", "bitwise", "ulp")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """How a [B]-row batch executes (hashable; jit-static).

    ``mode``:
      * ``"auto"`` — chunk if ``chunk_rows`` is set and the batch
        exceeds it, else shard over available devices when profitable,
        else one program (the pre-redesign default behaviour);
      * ``"single"`` — always one program (the old ``shard=False``);
      * ``"chunked"`` — fixed row slices of ``chunk_rows`` (the old
        ``chunk_rows=``), bit-identical per row to the single program;
      * ``"sharded"`` — `shard_map` over a 1-D row mesh, padding the
        batch to equal shard widths when needed; ULP-equal per row.

    ``devices`` caps the shard count (0: all available). ``contract``
    documents (and validates) the reproducibility expectation: chunked
    execution is bitwise, sharding is ULP-level — asking for
    ``"bitwise"`` together with ``mode="sharded"`` is a contradiction
    and raises here rather than surprising a downstream assert.
    """

    mode: str = "auto"
    chunk_rows: int = 0
    devices: int = 0
    contract: str = "auto"

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"ExecutionPlan.mode must be one of "
                             f"{_MODES}, got {self.mode!r}")
        if self.contract not in _CONTRACTS:
            raise ValueError(f"ExecutionPlan.contract must be one of "
                             f"{_CONTRACTS}, got {self.contract!r}")
        if self.chunk_rows == 1:
            raise ValueError(
                "ExecutionPlan.chunk_rows must be >= 2: width-1 "
                "programs scalarize on XLA:CPU and drift off the "
                "bit-identical contract (same reason shards keep >= 2 "
                "rows)")
        if self.chunk_rows < 0:
            raise ValueError("ExecutionPlan.chunk_rows must be >= 0")
        if self.devices < 0:
            raise ValueError("ExecutionPlan.devices must be >= 0")
        if self.mode == "chunked" and not self.chunk_rows:
            raise ValueError("ExecutionPlan(mode='chunked') needs "
                             "chunk_rows >= 2")
        if self.mode == "sharded" and self.chunk_rows:
            raise ValueError("ExecutionPlan(mode='sharded') does not "
                             "chunk — drop chunk_rows or use "
                             "mode='chunked'")
        if self.mode == "sharded" and self.contract == "bitwise":
            raise ValueError(
                "ExecutionPlan: sharded execution is ULP-equal, not "
                "bitwise (XLA codegen depends on the shard width) — "
                "use mode='chunked' for the bitwise contract")


@dataclasses.dataclass(frozen=True)
class Coupling:
    """What fleet-level terms bind a tuning objective (hashable).

    ``dispatch`` is the *soft*, dispatch-aware coupling (the old
    ``TuneConfig.dispatch_soft``): differentiate through the relaxed
    water-fill so sites learn their fleet role. ``reeval`` is the
    hard-dispatch re-scoring config only (the old
    ``TuneConfig.dispatch``): it scores the final policy sets under
    feasible `repro.dispatch.dispatch` but adds nothing to the
    gradient, so it does **not** couple rows. Both are duck-typed
    `repro.dispatch.DispatchConfig` instances (kept loose so this
    module stays import-cycle-free). ``relief`` (a duck-typed
    `repro.dispatch.Relief`) prices infeasible dispatch hours as shed
    instead of raising, in *both* the soft water-fill term and the hard
    re-scoring; None defers to whatever the dispatch configs carry.
    """

    power_cap_mw: Optional[float] = None
    min_up_hours: Optional[float] = None
    penalty_weight: float = 10.0
    dispatch: Optional[Any] = None       # soft / dispatch-aware
    dispatch_blend: float = 0.5
    dispatch_mw_scale: float = 0.05
    reeval: Optional[Any] = None         # hard re-scoring only
    relief: Optional[Any] = None         # shed pricing for infeasibility

    @property
    def binds(self) -> bool:
        """True when any term couples rows through a fleet aggregate
        (``reeval`` alone does not — it is post-hoc scoring)."""
        return (self.power_cap_mw is not None
                or self.min_up_hours is not None
                or self.dispatch is not None)

    @property
    def reeval_config(self):
        """The hard-dispatch config the final re-scoring runs under:
        ``reeval`` when given, else the soft ``dispatch`` config."""
        return self.reeval if self.reeval is not None else self.dispatch

    @property
    def relief_config(self):
        """The shed-pricing spec in force: ``relief`` when given, else
        whatever the soft dispatch config itself carries (duck-typed
        `repro.dispatch.Relief`; None means infeasibility stays hard)."""
        if self.relief is not None:
            return self.relief
        d = self.dispatch
        return getattr(d, "relief", None) if d is not None else None


def validate_plan_coupling(plan: ExecutionPlan,
                           coupling: Optional[Coupling], *,
                           context: str = "ExecutionPlan") -> None:
    """The one legality rule the pair carries: a chunked program cannot
    evaluate a coupled objective. Coupled terms (power_cap_mw /
    min_up_hours / the dispatch_soft water-fill) see every row at once,
    so a row chunk would optimize against a fleet that does not exist —
    and quietly dropping the chunking instead would drop the memory
    bound the user asked for. Sharding is the supported scale-out for
    coupled objectives (psum-reduced aggregates)."""
    if coupling is None or not coupling.binds:
        return
    if plan.chunk_rows:
        raise ValueError(
            f"{context}: chunk_rows cannot be combined with fleet "
            "coupling (power_cap_mw / min_up_hours / dispatch_soft): "
            "coupled terms see every row at once, so a row chunk would "
            "optimize against a fleet that does not exist — use "
            "ExecutionPlan(mode='sharded') (coupled aggregates are "
            "psum-reduced across shards), tune unchunked, or drop the "
            "coupling")


def take_rows(record, order, *, shared=(), n_rows: Optional[int] = None):
    """Shape-driven row slice of a record of [B]-leading arrays.

    The one implementation behind `ScenarioGrid.take_rows`,
    `tune.optimizer`'s problem slicing and `LiveGrid.take_rows` — every
    chunked/sharded path slices rows the same way. ``record`` is a
    frozen dataclass or NamedTuple; fields named in ``shared`` are
    carried through untouched, fields that themselves expose a
    ``take_rows`` method recurse (a `LiveGrid` carries its row-expanded
    `ScenarioGrid`), and everything else must be a [B]-leading array —
    a field that is neither raises instead of being silently dropped,
    so a future field cannot fall through the permutation.
    """
    order = np.asarray(order)
    if dataclasses.is_dataclass(record):
        names = [f.name for f in dataclasses.fields(record)]

        def rebuild(rep):
            return dataclasses.replace(record, **rep)
    elif hasattr(record, "_fields") and hasattr(record, "_replace"):
        names = list(record._fields)
        rebuild = lambda rep: record._replace(**rep)  # noqa: E731
    else:
        raise TypeError(f"take_rows needs a dataclass or NamedTuple, "
                        f"got {type(record).__name__}")
    if n_rows is None:
        n_rows = next(
            (int(v.shape[0]) for v in (getattr(record, n) for n in names
                                       if n not in shared)
             if hasattr(v, "shape") and getattr(v, "ndim", 0) >= 1),
            0)
    rep = {}
    for name in names:
        if name in shared:
            continue
        v = getattr(record, name)
        if callable(getattr(v, "take_rows", None)):
            rep[name] = v.take_rows(order)
            continue
        if not hasattr(v, "shape") or v.ndim < 1 or v.shape[0] != n_rows:
            raise TypeError(
                f"{type(record).__name__}.take_rows: field {name!r} is "
                "neither a shared field nor a [B]-leading per-row array "
                "— add it to SHARED_FIELDS or make it per-row")
        rep[name] = v[order]
    return rebuild(rep)
