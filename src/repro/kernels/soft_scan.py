"""Differentiable (temperature-relaxed) fleet scan as one fused pass.

`repro.kernels.fleet_scan` answers "what does this policy cost?" but its
thresholds enter through comparisons, so policy parameters cannot be
*optimized* by gradient descent through it. This module relaxes the
two-threshold hysteresis state machine with sigmoid event gates at
temperature ``tau``:

    a_t = sigmoid((p_on  - p_t) / tau)        turn-on strength
    b_t = sigmoid((p_t - p_off) / tau)        turn-off strength
    s_t = a_t + (1 - a_t)(1 - b_t) s_{t-1}    soft on-state in [0, 1]

As tau -> 0 the gates harden and s_t converges to `fleet_scan_ref`'s
state at every sample not exactly on a threshold (on-wins precedence in
a degenerate p_on == p_off band, matching the Pallas kernel's event
encoding). The recurrence is *affine* in s_{t-1}, so instead of a
sequential scan it is evaluated with one `jax.lax.associative_scan` over
the composition monoid of affine maps

    (alpha, beta) o (alpha', beta') = (alpha alpha', beta alpha' + beta')

giving a single fused jitted pass over [B, T] with O(log T) depth — the
whole tuning objective (soft scan + cost assembly + penalties) is one
XLA computation, and JAX's native autodiff through the associative scan
provides exact gradients of the relaxed objective. That native backward
is also the expensive way to get them: it re-materialises the [B, T]
affine intermediates at every level of the scan tree. ``fused=True``
(what `repro.tune` runs) swaps in the checkpointed custom VJP of
`repro.kernels.soft_scan_vjp` — same values and gradients to tight
tolerance, O(B·T/block) residuals and a fraction of the backward cost;
the native form stays the ground truth the fused path is tested
against.

Computation runs in the price dtype, so float64 inputs (under x64) give
float64 gradients — the finite-difference checks in `tests/test_tune.py`
rely on this.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ref import FleetScanOut, soft_gates


def _affine_compose(earlier, later):
    """Composition of affine maps s -> alpha*s + beta, earlier first."""
    a1, b1 = earlier
    a2, b2 = later
    return a1 * a2, b1 * a2 + b2


def soft_state(prices: jax.Array, p_on: jax.Array, p_off: jax.Array, *,
               tau, fused: bool = False, block_t: int = 256,
               use_pallas: Optional[bool] = None) -> jax.Array:
    """Soft on-state trajectory s in [0, 1]^{B x T} via associative scan.

    prices: [B, T]; p_on/p_off: [B] (broadcastable). Initial state is 1
    (running), matching `fleet_scan_ref`. ``fused=True`` routes through
    `repro.kernels.soft_scan_vjp.soft_state_fused` — same values, but a
    hand-written checkpointed VJP instead of native autodiff through the
    associative scan (the tuner's fast path; `repro.tune` defaults to
    it). The default here stays the native form: it is the
    autodiff-ground-truth the fused path is tested against.
    """
    if fused:
        from repro.kernels.soft_scan_vjp import soft_state_fused
        return soft_state_fused(prices, p_on, p_off, tau=tau,
                                block_t=block_t, use_pallas=use_pallas)
    p = jnp.asarray(prices)
    dtype = p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32
    p = p.astype(dtype)
    b = p.shape[0]
    p_on = jnp.broadcast_to(jnp.asarray(p_on, dtype), (b,))
    p_off = jnp.broadcast_to(jnp.asarray(p_off, dtype), (b,))
    inv_tau = 1.0 / jnp.asarray(tau, dtype)

    _, _, alpha, beta = soft_gates(p, p_on[:, None], p_off[:, None],
                                   inv_tau)                 # [B, T]
    cum_a, cum_b = jax.lax.associative_scan(
        _affine_compose, (alpha, beta), axis=1)
    return cum_a * 1.0 + cum_b                              # s0 = 1


def soft_scan_parts(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                    off_level: jax.Array, idle_frac: jax.Array, *,
                    tau, fused: bool = False, block_t: int = 256,
                    use_pallas: Optional[bool] = None
                    ) -> tuple[FleetScanOut, jax.Array, jax.Array]:
    """(FleetScanOut, per-sample draw [B, T], capacity [B, T]) of the
    relaxed scan.

    The draw trajectory is what fleet-coupling penalties (total-power
    cap) integrate over; the capacity trajectory is what the soft
    dispatch coupling offers as availability (the relaxed analogue of
    `repro.dispatch.capacity_series`); `soft_fleet_scan` discards both.
    ``fused`` selects the checkpointed custom-VJP state evaluation (see
    `soft_state`); everything downstream of the state is plain autodiff
    either way.
    """
    p = jnp.asarray(prices)
    dtype = p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32
    p = p.astype(dtype)
    b = p.shape[0]
    off_level = jnp.broadcast_to(jnp.asarray(off_level, dtype), (b,))
    idle_frac = jnp.broadcast_to(jnp.asarray(idle_frac, dtype), (b,))

    s = soft_state(p, p_on, p_off, tau=tau, fused=fused,
                   block_t=block_t, use_pallas=use_pallas)  # [B, T]
    s_prev = jnp.concatenate([jnp.ones((b, 1), dtype), s[:, :-1]], axis=1)
    starts = s * (1.0 - s_prev)           # smooth 0->1 transition mass
    cap = off_level[:, None] + (1.0 - off_level[:, None]) * s
    draw = cap + idle_frac[:, None] * (1.0 - cap)
    return FleetScanOut(
        draw_price_sum=jnp.sum(draw * p, axis=1),
        up_units=jnp.sum(cap, axis=1),
        n_starts=jnp.sum(starts, axis=1),
        restart_price_sum=jnp.sum(starts * p, axis=1)), draw, cap


def soft_fleet_scan(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                    off_level: jax.Array, idle_frac: jax.Array, *,
                    tau, fused: bool = False, block_t: int = 256,
                    use_pallas: Optional[bool] = None) -> FleetScanOut:
    """Differentiable counterpart of `repro.kernels.fleet_scan.fleet_scan`.

    Same contract ([B, T] prices, [B] broadcastable params, p_on <= p_off)
    and the same `FleetScanOut` sufficient statistics, but every output is
    a smooth function of (prices, p_on, p_off, off_level, idle_frac) at
    temperature ``tau`` and converges to the hard scan as tau -> 0.
    Verified against `repro.kernels.ref.soft_scan_ref` (sequential
    oracle) and against `fleet_scan_ref` in the tau -> 0 limit.
    """
    return soft_scan_parts(prices, p_on, p_off, off_level, idle_frac,
                           tau=tau, fused=fused, block_t=block_t,
                           use_pallas=use_pallas)[0]
