"""Hour-by-hour feasible fleet dispatch as a Pallas TPU kernel.

The cross-site dispatcher (`repro.dispatch`) allocates a fleet-wide
compute demand across S sites every hour, greedily filling price-sorted
capacity segments (locked / retain-with-migration-premium / fresh — see
`repro.kernels.ref.dispatch_alloc_hour`). The allocation is a true
recurrence over time — the previous hour's placement prices retention
and the dwell counters gate migration — so unlike `fleet_scan` there is
no cummax trick that removes the serial dependence. What *can* be
removed is everything expensive inside an hour:

  * the price sort: segment sort keys depend only on prices and the
    (static) migration premium, never on the running state, so the
    ascending sort permutation of all 3S segments and its inverse are
    precomputed on the host ([T, 3S] int32 each) and streamed through
    the grid like any other input;
  * the per-hour greedy fill: with the permutation in hand, "capacity
    mass at strictly cheaper segments" is gather -> exclusive cumsum ->
    gather-back, and the fill is a clip — O(S) work per hour.

Layout: grid = (n_time_blocks,) with time innermost and [block_t, S]
time-major blocks; the carry (previous allocation + dwell counters, both
[S]) lives in VMEM scratch across time blocks — zero HBM round-trips for
state, the `fleet_scan.py` / `ssd_scan.py` pattern. Hours inside a block
run under `fori_loop`. Per-hour math is imported from
`repro.kernels.ref.dispatch_alloc_hour`, shared verbatim with the
sequential `dispatch_ref` oracle, so kernel and reference are
bit-identical (asserted in `tests/test_dispatch.py`).

T-padding needs no masking: padded hours carry zero demand and zero
availability, so they allocate nothing, and they sit after every real
hour so their dwell decrements touch no real decision.

Validated in interpret mode against `repro.kernels.ref.dispatch_ref`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import dispatch_alloc_hour


def _dispatch_kernel(a_ref, order_ref, rank_ref, d_ref,   # time-major
                     out_ref,                             # [block_t, S]
                     prev_scr, dwell_scr,                 # [S] VMEM carry
                     *, block_t: int, min_dwell: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        prev_scr[...] = jnp.zeros_like(prev_scr)    # start empty
        dwell_scr[...] = jnp.zeros_like(dwell_scr)

    def hour(h, carry):
        alloc, dwell = dispatch_alloc_hour(
            prev_scr[...], dwell_scr[...], a_ref[h, :], order_ref[h, :],
            rank_ref[h, :], d_ref[h], min_dwell=min_dwell)
        out_ref[h, :] = alloc
        prev_scr[...] = alloc
        dwell_scr[...] = dwell
        return carry

    jax.lax.fori_loop(0, block_t, hour, 0)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "min_dwell", "interpret"))
def _dispatch_scan_padded(a_tm: jax.Array, order: jax.Array,
                          rank: jax.Array, demand: jax.Array, *,
                          block_t: int, min_dwell: int,
                          interpret: bool) -> jax.Array:
    """Core pallas_call over padded, time-major inputs.

    a_tm: [T*, S]; order/rank: [T*, 3S]; demand: [T*] (T* a block_t
    multiple). Returns the allocation [T*, S].
    """
    t_pad, s = a_tm.shape
    nt = t_pad // block_t

    kernel = functools.partial(_dispatch_kernel, block_t=block_t,
                               min_dwell=min_dwell)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t,), lambda ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, s), jnp.float32),
        scratch_shapes=[pltpu.VMEM((s,), jnp.float32),
                        pltpu.VMEM((s,), jnp.float32)],
        interpret=interpret,
    )(a_tm, order, rank, demand)


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def dispatch_scan(avail: jax.Array, order: jax.Array, rank: jax.Array,
                  demand: jax.Array, *, min_dwell: int = 0,
                  block_t: int = 512,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Feasible dispatch allocation. avail: [S, T] MW; order/rank:
    [T, 3S] precomputed segment sort data
    (`repro.dispatch.segment_rank`); demand: [T] MW. Returns the
    allocation [S, T].

    Same contract as `repro.kernels.ref.dispatch_ref`; this is the hot
    inner loop of `repro.dispatch.dispatch`.
    """
    a = jnp.asarray(avail, jnp.float32)
    s, t = a.shape
    block_t = max(min(block_t, t), 1)
    pad_t = (-t) % block_t

    a_tm = jnp.pad(a.T, ((0, pad_t), (0, 0)))        # [T*, S] time-major
    order_p = jnp.pad(jnp.asarray(order, jnp.int32), ((0, pad_t), (0, 0)))
    rank_p = jnp.pad(jnp.asarray(rank, jnp.int32), ((0, pad_t), (0, 0)))
    d_p = jnp.pad(jnp.asarray(demand, jnp.float32), (0, pad_t))
    out = _dispatch_scan_padded(a_tm, order_p, rank_p, d_p,
                                block_t=block_t, min_dwell=int(min_dwell),
                                interpret=_auto_interpret(interpret))
    return out[:t].T
