"""Public jit'd entry points for the Pallas kernels.

These wrappers own everything the kernels should not: layout transposes
into the kernel-native [B, heads, seq, feature] form, padding to block
multiples (padded KV is masked via ``kv_len`` / validity, padded Q rows are
sliced off), block-size selection (hardware-aligned 128-multiples when the
shape allows), and interpret-mode auto-detection (interpret=True off-TPU so
the same code path is testable on CPU).

The model swaps these in for its XLA blockwise implementations when
``cfg.attn_impl == "pallas"``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_bgrd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan_grouped


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _block(n: int, cap: int) -> int:
    """Hardware-friendly block: the largest 128-multiple <= min(cap, n)
    (or n itself when n < 128 — small smoke shapes)."""
    cap = max(min(cap, n), 1)
    if cap >= 128:
        return (cap // 128) * 128
    return cap


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0, bq: int = 512, bkv: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """FlashAttention forward. q: [B,Sq,H,Dh]; k,v: [B,Skv,G,Dh];
    returns [B,Sq,H,Dh]. Causal/window masks are positional with
    ``q_offset`` added to query positions (chunked prefill)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    bq = _block(sq, bq)
    bkv = _block(skv, bkv)

    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, bq)       # [B,H,Sq*,Dh]
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, bkv)      # [B,G,Skv*,Dh]
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, bkv)

    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               q_offset=q_offset, bq=bq, bkv=bkv,
                               kv_len=skv,
                               interpret=_auto_interpret(interpret))
    return out[:, :, :sq].transpose(0, 2, 1, 3)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *, bkv: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    """One-token attention against a cache. q: [B,1,H,Dh];
    k,v: [B,W,G,Dh]; valid: [B,W] bool. Returns [B,1,H,Dh]."""
    b, _, h, dh = q.shape
    w, g = k.shape[1], k.shape[2]
    r = h // g
    bkv = _block(w, bkv)

    qg = q.reshape(b, g, r, dh)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, bkv)      # [B,G,W*,Dh]
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, bkv)
    vm = _pad_to(valid.astype(jnp.int8), 1, bkv)       # [B,W*]

    out = decode_attention_bgrd(qg, kt, vt, vm, bkv=bkv,
                                interpret=_auto_interpret(interpret))
    return out.reshape(b, 1, h, dh)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array,
             b: jax.Array, c: jax.Array, chunk: int,
             h0: Optional[jax.Array] = None, *,
             interpret: Optional[bool] = None
             ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Mamba2). Same contract as
    `repro.models.ssm.ssd_chunked`:

    x: [B,S,H,P]; dt: [B,S,H] post-softplus; a: [H] negative;
    b,c: [B,S,G,N]; h0: [B,H,P,N] or None.
    Returns y: [B,S,H,P] (f32), h_last: [B,H,P,N] (f32).
    """
    bsz, s, nh, p = x.shape
    g, n = b.shape[2], b.shape[3]
    pad = (-s) % chunk
    if pad:
        # zero padding is exact: dt=0 -> decay 1, zero state contribution
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)]   # noqa: E731
                               + [(0, 0)] * (t.ndim - 2))
        y, h_t = ssd_scan(zp(x), zp(dt), a, zp(b), zp(c), chunk, h0,
                          interpret=interpret)
        return y[:, :s], h_t
    nc = s // chunk

    xk = x.transpose(0, 2, 1, 3).reshape(bsz, nh, nc, chunk, p)
    dtk = dt.transpose(0, 2, 1).reshape(bsz, nh, nc, chunk)
    bk = b.transpose(0, 2, 1, 3).reshape(bsz, g, nc, chunk, n)
    ck = c.transpose(0, 2, 1, 3).reshape(bsz, g, nc, chunk, n)
    h0k = jnp.zeros((bsz, nh, n, p), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32).transpose(0, 1, 3, 2)

    y, h_t = ssd_scan_grouped(xk, dtk, a.astype(jnp.float32), bk, ck, h0k,
                              l_chunk=chunk, n_groups=g,
                              interpret=_auto_interpret(interpret))
    y = y.reshape(bsz, nh, s, p).transpose(0, 2, 1, 3)
    return y, h_t.transpose(0, 1, 3, 2)
