"""FlashAttention forward as a Pallas TPU kernel.

TPU adaptation of the (GPU-origin) FlashAttention algorithm: there are no
warps or shared-memory banks here — the TPU analogue is a *sequential grid*
whose innermost dimension streams KV blocks through VMEM while an f32
(m, l, acc) carry lives in VMEM scratch. Block shapes are chosen so

  * the last two dims of every matmul are multiples of the 128x128 MXU
    (q_block x d_head and q_block x kv_block), and
  * the per-step working set (q + k + v blocks + [bq, bkv] scores + scratch)
    stays well under the ~16 MiB VMEM budget:
    bq=512, bkv=512, dh=128 (bf16)  ->  ~1.6 MiB.

Grouped-query attention never materialises K/V at H heads: the grid walks
query heads and the BlockSpec index map fetches the *group's* KV block
(h -> h // rep), which is exactly the Megatron GQA layout used by the
sharding rules (q-head shards align with kv-group shards, so under tensor
parallelism the kernel sees only local heads).

Causal / sliding-window masking is positional (iota-based), so the same
kernel serves training (q_offset=0) and chunked prefill (q_offset>0).
Out-of-range KV blocks are skipped with `pl.when` — on real TPU the skip
eliminates ~half the MXU work for causal attention; in interpret mode it is
just as correct.

Validated on CPU via interpret=True against `repro.kernels.ref.attention_ref`
(tests/test_kernels.py sweeps shapes and dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30  # matches the model's masked-score constant


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,          # I/O refs
                  m_scr, l_scr, acc_scr,                # VMEM scratch
                  *, scale: float, causal: bool, window: int,
                  q_offset: int, kv_len: int, bq: int, bkv: int,
                  n_kv: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # --- block relevance: skip fully-masked KV blocks ----------------------
    q_start = q_offset + qi * bq          # first absolute q position
    q_end = q_start + bq - 1
    k_start = kj * bkv
    relevant = k_start <= jnp.minimum(q_end, kv_len - 1) if causal \
        else k_start <= kv_len - 1
    if window:
        # block ends before the window of even the *first* query row
        # (the least restrictive row in the block)
        relevant &= (k_start + bkv - 1) > (q_start - window)

    @pl.when(relevant)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)            # [bkv, dh]
        v = v_ref[0, 0]                                # [bkv, dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bkv]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = kpos < kv_len                           # kv padding
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot(p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bkv",
                     "kv_len", "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool, window: int, q_offset: int,
                         bq: int, bkv: int, kv_len: int,
                         interpret: bool) -> jax.Array:
    """Core pallas_call. q: [B,H,Sq,Dh]; k,v: [B,G,Skv,Dh] (padded to
    block multiples); returns [B,H,Sq,Dh]. ``kv_len`` = true KV length."""
    b, h, sq, dh = q.shape
    g, skv = k.shape[1], k.shape[2]
    rep = h // g
    n_q, n_kv = sq // bq, skv // bkv

    kernel = functools.partial(
        _flash_kernel, scale=dh ** -0.5, causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, bq=bq, bkv=bkv, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
