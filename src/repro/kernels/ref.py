"""Pure-jnp oracles for every Pallas kernel (the `ref.py` of kernels/).

These are *direct* (non-blocked) implementations — O(S^2) score tensors,
materialised state sequences — used only by tests and never by the model
(the model's own XLA path is the separately-implemented blockwise form in
`repro.models.attention` / `repro.models.ssm`, giving three independent
implementations that must agree).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


class FleetScanOut(NamedTuple):
    """Per-row sufficient statistics of a batched policy backtest.

    All cost quantities downstream (CPC, TCO, reduction) are affine in
    these four sums, so the scan never materialises the [B, T] mask.
    """

    draw_price_sum: jax.Array   # sum_t draw_t * p_t            [B]
    up_units: jax.Array         # sum_t capacity_t               [B]
    n_starts: jax.Array         # number of off->on transitions  [B]
    restart_price_sum: jax.Array  # sum_t start_t * p_t          [B]


def fleet_scan_ref(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                   off_level: jax.Array, idle_frac: jax.Array
                   ) -> FleetScanOut:
    """Sequential oracle for the batched hysteresis/threshold scan.

    prices: [B, T]; p_on/p_off/off_level/idle_frac: [B] per-row policy
    parameters (p_on <= p_off; p_on == p_off is a plain threshold).

    State machine per row (initial state: on, matching
    `repro.core.policy.hysteresis_policy`'s initial carry):

        on_t = 0 if p_t > p_off, 1 if p_t <= p_on, else on_{t-1}

    With p_on == p_off the hold-band is empty and this is *exactly*
    `repro.core.policy.threshold_policy` (run while p <= threshold); note
    `hysteresis_policy` resumes on strict p < p_on instead, so the two
    differ only at samples exactly equal to p_on.
    Capacity while "off" is ``off_level`` (partial shutdown, paper §V-C);
    residual draw while off is ``idle_frac`` of the *shut-down* capacity.
    """
    p = jnp.asarray(prices, jnp.float32)
    b = p.shape[0]
    p_on, p_off, off_level, idle_frac = (
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,))
        for v in (p_on, p_off, off_level, idle_frac))

    def step(carry, p_t):
        on_prev, acc = carry
        on = jnp.where(p_t > p_off, 0.0,
                       jnp.where(p_t <= p_on, 1.0, on_prev))
        start = jnp.maximum(on - on_prev, 0.0)
        cap = off_level + (1.0 - off_level) * on
        draw = cap + idle_frac * (1.0 - cap)
        acc = (acc[0] + draw * p_t, acc[1] + cap,
               acc[2] + start, acc[3] + start * p_t)
        return (on, acc), None

    zeros = jnp.zeros((b,), jnp.float32)
    init = (jnp.ones((b,), jnp.float32), (zeros, zeros, zeros, zeros))
    (_, acc), _ = jax.lax.scan(step, init, p.T)
    return FleetScanOut(*acc)


def soft_scan_ref(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                  off_level: jax.Array, idle_frac: jax.Array, *,
                  tau: float) -> FleetScanOut:
    """Sequential oracle for the temperature-``tau`` relaxation of
    `fleet_scan_ref` (see `repro.kernels.soft_scan` for the fused form).

    The hard two-threshold state machine

        on_t = 0 if p_t > p_off, 1 if p_t <= p_on, else on_{t-1}

    is relaxed with sigmoid event gates a_t = sigmoid((p_on - p_t)/tau)
    ("turn on") and b_t = sigmoid((p_t - p_off)/tau) ("turn off"):

        s_t = a_t + (1 - a_t)(1 - b_t) s_{t-1},   s_{-1} = 1

    which is affine in s_{t-1} and recovers the hard recurrence (with the
    kernel's on-wins precedence) as tau -> 0 at every sample not exactly
    on a threshold. Restarts are counted softly as s_t (1 - s_{t-1}) —
    smooth everywhere, and equal to the hard 0->1 indicator on binary
    states. Everything is differentiable in (p_on, p_off, off_level,
    idle_frac, prices); computation runs in the price dtype (float64
    under x64 — finite-difference gradient checks rely on this).
    """
    p = jnp.asarray(prices)
    dtype = p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32
    p = p.astype(dtype)
    b = p.shape[0]
    p_on, p_off, off_level, idle_frac = (
        jnp.broadcast_to(jnp.asarray(v, dtype), (b,))
        for v in (p_on, p_off, off_level, idle_frac))
    inv_tau = 1.0 / jnp.asarray(tau, dtype)

    def step(carry, p_t):
        s_prev, acc = carry
        a = jax.nn.sigmoid((p_on - p_t) * inv_tau)
        off = jax.nn.sigmoid((p_t - p_off) * inv_tau)
        s = a + (1.0 - a) * (1.0 - off) * s_prev
        start = s * (1.0 - s_prev)
        cap = off_level + (1.0 - off_level) * s
        draw = cap + idle_frac * (1.0 - cap)
        acc = (acc[0] + draw * p_t, acc[1] + cap,
               acc[2] + start, acc[3] + start * p_t)
        return (s, acc), None

    zeros = jnp.zeros((b,), dtype)
    init = (jnp.ones((b,), dtype), (zeros, zeros, zeros, zeros))
    (_, acc), _ = jax.lax.scan(step, init, p.T)
    return FleetScanOut(*acc)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  q_offset: int = 0) -> jax.Array:
    """q: [B,Sq,H,Dh]; k,v: [B,Skv,G,Dh]; GQA by head repetition."""
    b, sq, h, dh = q.shape
    skv, g = k.shape[1], k.shape[2]
    rep = h // g
    kh = jnp.repeat(k, rep, axis=2)
    vh = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * dh ** -0.5
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid: jax.Array) -> jax.Array:
    """q: [B,1,H,Dh]; k,v: [B,W,G,Dh]; valid: [B,W] bool."""
    b, _, h, dh = q.shape
    g = k.shape[2]
    rep = h // g
    kh = jnp.repeat(k, rep, axis=2)
    vh = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * dh ** -0.5
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
            b: jax.Array, c: jax.Array,
            h0: Optional[jax.Array] = None
            ) -> tuple[jax.Array, jax.Array]:
    """Sequential (unchunked) SSD recurrence — the ground-truth oracle.

    x: [B,S,H,P]; dt: [B,S,H] (post-softplus); a: [H] (negative);
    b,c: [B,S,G,N]; h0: [B,H,P,N] or None.
    Returns y: [B,S,H,P] (f32), h_last: [B,H,P,N] (f32).

        h_t = h_{t-1} * exp(dt_t a) + dt_t x_t b_t^T ;  y_t = h_t c_t
    """
    bsz, s, nh, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = nh // g
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)   # [B,S,H,N]
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp          # [B,H,P], [B,H], [B,H,N], [B,H,N]
        decay = jnp.exp(dt_t * a[None, :])                 # [B,H]
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t, b_t)
        h = h * decay[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y_t

    h_init = jnp.zeros((bsz, nh, p, n), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    h_last, ys = jax.lax.scan(
        step, h_init,
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
         bh.swapaxes(0, 1), ch.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h_last
