"""Pure-jnp oracles for every Pallas kernel (the `ref.py` of kernels/).

These are *direct* (non-blocked) implementations — O(S^2) score tensors,
materialised state sequences — used only by tests and never by the model
(the model's own XLA path is the separately-implemented blockwise form in
`repro.models.attention` / `repro.models.ssm`, giving three independent
implementations that must agree).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


class FleetScanOut(NamedTuple):
    """Per-row sufficient statistics of a batched policy backtest.

    All cost quantities downstream (CPC, TCO, reduction) are affine in
    these four sums, so the scan never materialises the [B, T] mask.
    """

    draw_price_sum: jax.Array   # sum_t draw_t * p_t            [B]
    up_units: jax.Array         # sum_t capacity_t               [B]
    n_starts: jax.Array         # number of off->on transitions  [B]
    restart_price_sum: jax.Array  # sum_t start_t * p_t          [B]


def hard_hour_step(on_prev, p_t, p_on, p_off, off_level, idle_frac):
    """One hour of the hard shutdown state machine — the single source
    of the per-hour update, shared (elementwise, broadcasting) by
    `fleet_scan_ref` and the telemetry companion `fleet_hourly_ref` so
    the per-hour records aggregate exactly the trajectory the backtest
    scores. Returns ``(on, start, cap, draw)``."""
    on = jnp.where(p_t > p_off, 0.0,
                   jnp.where(p_t <= p_on, 1.0, on_prev))
    start = jnp.maximum(on - on_prev, 0.0)
    cap = off_level + (1.0 - off_level) * on
    draw = cap + idle_frac * (1.0 - cap)
    return on, start, cap, draw


class FleetHourly(NamedTuple):
    """Per-hour fleet aggregates ([T] each) of a batched backtest — the
    payload of the ``fleet.hourly`` telemetry drain. Reductions run
    on-device inside the scan, so only 4T floats ever cross to the
    host."""

    on_mw: jax.Array       # sum_b weight_b * cap_bt (weighted capacity)
    draw_price: jax.Array  # sum_b weight_b * draw_bt * p_bt (EUR-rate)
    starts: jax.Array      # off->on transitions across rows
    stops: jax.Array       # on->off transitions across rows


def fleet_hourly_ref(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                     off_level: jax.Array, idle_frac: jax.Array,
                     weight: jax.Array) -> FleetHourly:
    """Hour-indexed companion of `fleet_scan_ref`: same state machine
    (via `hard_hour_step`), but emitting [T]-shaped fleet aggregates
    instead of per-row sums. ``weight`` ([B], e.g. each row's MW rating)
    scales capacity and draw into fleet-level MW; transition counts are
    unweighted."""
    p = jnp.asarray(prices, jnp.float32)
    b = p.shape[0]
    p_on, p_off, off_level, idle_frac, weight = (
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,))
        for v in (p_on, p_off, off_level, idle_frac, weight))

    def step(on_prev, p_t):
        on, start, cap, draw = hard_hour_step(on_prev, p_t, p_on, p_off,
                                              off_level, idle_frac)
        stop = jnp.maximum(on_prev - on, 0.0)
        ys = (jnp.sum(weight * cap), jnp.sum(weight * draw * p_t),
              jnp.sum(start), jnp.sum(stop))
        return on, ys

    _, ys = jax.lax.scan(step, jnp.ones((b,), jnp.float32), p.T)
    return FleetHourly(*ys)


def fleet_scan_ref(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                   off_level: jax.Array, idle_frac: jax.Array
                   ) -> FleetScanOut:
    """Sequential oracle for the batched hysteresis/threshold scan.

    prices: [B, T]; p_on/p_off/off_level/idle_frac: [B] per-row policy
    parameters (p_on <= p_off; p_on == p_off is a plain threshold).

    State machine per row (initial state: on, matching
    `repro.core.policy.hysteresis_policy`'s initial carry):

        on_t = 0 if p_t > p_off, 1 if p_t <= p_on, else on_{t-1}

    With p_on == p_off the hold-band is empty and this is *exactly*
    `repro.core.policy.threshold_policy` (run while p <= threshold); note
    `hysteresis_policy` resumes on strict p < p_on instead, so the two
    differ only at samples exactly equal to p_on.
    Capacity while "off" is ``off_level`` (partial shutdown, paper §V-C);
    residual draw while off is ``idle_frac`` of the *shut-down* capacity.
    """
    p = jnp.asarray(prices, jnp.float32)
    b = p.shape[0]
    p_on, p_off, off_level, idle_frac = (
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,))
        for v in (p_on, p_off, off_level, idle_frac))

    def step(carry, p_t):
        on_prev, acc = carry
        on, start, cap, draw = hard_hour_step(on_prev, p_t, p_on, p_off,
                                              off_level, idle_frac)
        acc = (acc[0] + draw * p_t, acc[1] + cap,
               acc[2] + start, acc[3] + start * p_t)
        return (on, acc), None

    zeros = jnp.zeros((b,), jnp.float32)
    init = (jnp.ones((b,), jnp.float32), (zeros, zeros, zeros, zeros))
    (_, acc), _ = jax.lax.scan(step, init, p.T)
    return FleetScanOut(*acc)


def queue_scan_ref(arrivals: jax.Array, cap: jax.Array, *,
                   deadline: int, bound) -> tuple[jax.Array, jax.Array,
                                                  jax.Array, jax.Array]:
    """Sequential oracle for the hard work-ledger scan
    (`repro.kernels.queue_scan.queue_scan`).

    arrivals/cap: [R, T] MWh per hour. Deliberately a different
    formulation from the kernel's parallel-cumsum fill: the age buckets
    are walked *sequentially* (python-unrolled — ``deadline`` is
    static), serving oldest-first from a running remaining-capacity
    variable and re-queueing under ``bound`` with a running kept-mass —
    the greedy prose the cumsum idiom must reproduce. Returns per-hour
    ``(served [R, T], dropped [R, T], backlog [R, T], q_final [R, D])``.
    """
    a = jnp.asarray(arrivals)
    dtype = a.dtype if jnp.issubdtype(a.dtype, jnp.floating) else jnp.float32
    a = a.astype(dtype)
    c = jnp.broadcast_to(jnp.asarray(cap, dtype), a.shape)
    r = a.shape[0]
    d = int(deadline)

    def hour(q, inp):
        a_t, c_t = inp
        # q[:, i] has waited i+1 hours; serve oldest first
        work = [q[:, d - 1 - i] for i in range(d)] + [a_t]
        rem = c_t
        served = jnp.zeros_like(c_t)
        unserved = []
        for w in work:
            s_i = jnp.minimum(rem, w)
            rem = rem - s_i
            served = served + s_i
            unserved.append(w - s_i)
        dropped = unserved[0]             # waited past the deadline
        kept = jnp.zeros_like(c_t)
        new_q = []
        for w in unserved[1:]:            # oldest survivor first
            keep = jnp.minimum(w, jnp.maximum(bound - kept, 0.0))
            kept = kept + keep
            dropped = dropped + (w - keep)
            new_q.append(keep)
        q = jnp.stack(new_q[::-1], axis=1) if d \
            else jnp.zeros((r, 0), dtype)
        return q, (served, dropped, kept)

    q0 = jnp.zeros((r, d), dtype)
    q_final, (served, dropped, backlog) = jax.lax.scan(
        hour, q0, (a.T, c.T))
    return served.T, dropped.T, backlog.T, q_final


def soft_gates(p_t, p_on, p_off, inv_tau):
    """Per-hour sigmoid event gates of the relaxed hysteresis recurrence.

    Returns ``(a, f, alpha, beta)`` with a = sigmoid((p_on - p_t)/tau)
    ("turn on"), f = sigmoid((p_t - p_off)/tau) ("turn off"), and the
    affine-map coefficients of s_t = alpha_t s_{t-1} + beta_t. Shared
    verbatim — elementwise, broadcasting — by `soft_scan_ref`,
    `repro.kernels.soft_scan.soft_state`, and both paths of the fused
    VJP (`repro.kernels.soft_scan_vjp`), so every implementation relaxes
    the state machine with the *same* per-hour math.
    """
    a = jax.nn.sigmoid((p_on - p_t) * inv_tau)
    f = jax.nn.sigmoid((p_t - p_off) * inv_tau)
    return a, f, (1.0 - a) * (1.0 - f), a


def soft_gate_grad(p_t, s_prev, u_t, p_on, p_off, inv_tau, gates=None):
    """Per-hour chain rule of the relaxed recurrence.

    Given the adjoint u_t = dL/ds_t (fully accumulated through later
    hours) and the entering state s_{t-1}, backpropagates through
    s_t = alpha_t s_{t-1} + beta_t and the gates to the hour's inputs.
    Returns per-hour contributions ``(d_p, d_p_on, d_p_off, d_inv_tau)``
    — callers sum the last three over t (and convert d_inv_tau to d_tau
    via dtau = -inv_tau^2 d_invtau). Shared verbatim by the sequential
    oracle `soft_scan_grad_ref`, the blocked XLA backward, and the
    Pallas backward kernel, exactly like `dispatch_alloc_hour`.
    ``gates`` lets a caller that already evaluated `soft_gates` (the
    blocked backwards need alpha for the adjoint recurrence anyway)
    pass ``(a, f)`` instead of paying the sigmoids twice.
    """
    a, f = gates if gates is not None else \
        soft_gates(p_t, p_on, p_off, inv_tau)[:2]
    d_alpha = u_t * s_prev                  # d beta = u_t
    d_a = u_t - d_alpha * (1.0 - f)         # alpha = (1-a)(1-f), beta = a
    d_f = -d_alpha * (1.0 - a)
    d_zon = d_a * a * (1.0 - a)             # z_on  = (p_on - p) inv_tau
    d_zoff = d_f * f * (1.0 - f)            # z_off = (p - p_off) inv_tau
    d_p = (d_zoff - d_zon) * inv_tau
    d_p_on = d_zon * inv_tau
    d_p_off = -d_zoff * inv_tau
    d_inv_tau = d_zon * (p_on - p_t) + d_zoff * (p_t - p_off)
    return d_p, d_p_on, d_p_off, d_inv_tau


def soft_scan_grad_ref(prices: jax.Array, p_on: jax.Array,
                       p_off: jax.Array, g: jax.Array, *, tau
                       ) -> tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array]:
    """Sequential oracle for the VJP of the soft-state trajectory.

    Given the cotangent ``g`` [B, T] of `soft_scan.soft_state`'s output,
    runs the recurrence forward (materialising the state sequence — this
    is an oracle, not a fast path), then walks the time grid in reverse
    accumulating the adjoint u_t = g_t + alpha_{t+1} u_{t+1} and the
    per-hour input gradients via `soft_gate_grad`. Returns
    ``(d_prices [B, T], d_p_on [B], d_p_off [B], d_tau [])``.
    """
    p = jnp.asarray(prices)
    dtype = p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32
    p = p.astype(dtype)
    b = p.shape[0]
    p_on = jnp.broadcast_to(jnp.asarray(p_on, dtype), (b,))
    p_off = jnp.broadcast_to(jnp.asarray(p_off, dtype), (b,))
    g = jnp.asarray(g, dtype)
    inv_tau = 1.0 / jnp.asarray(tau, dtype)

    def fwd(s_prev, p_t):
        _, _, alpha, beta = soft_gates(p_t, p_on, p_off, inv_tau)
        return alpha * s_prev + beta, (s_prev, alpha)

    _, (s_prev_t, alpha_t) = jax.lax.scan(fwd, jnp.ones((b,), dtype), p.T)

    def bwd(carry, inp):
        u_next, alpha_next = carry          # u_{t+1}, alpha_{t+1}
        p_t, g_t, s_prev, alpha = inp
        u = g_t + alpha_next * u_next
        d_p, d_on, d_off, d_it = soft_gate_grad(p_t, s_prev, u, p_on,
                                                p_off, inv_tau)
        return (u, alpha), (d_p, d_on, d_off, d_it)

    zeros = jnp.zeros((b,), dtype)
    _, (d_p, d_on, d_off, d_it) = jax.lax.scan(
        bwd, (zeros, zeros), (p.T, g.T, s_prev_t, alpha_t), reverse=True)
    d_tau = -inv_tau ** 2 * jnp.sum(d_it)
    return d_p.T, jnp.sum(d_on, axis=0), jnp.sum(d_off, axis=0), d_tau


def soft_scan_ref(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                  off_level: jax.Array, idle_frac: jax.Array, *,
                  tau: float) -> FleetScanOut:
    """Sequential oracle for the temperature-``tau`` relaxation of
    `fleet_scan_ref` (see `repro.kernels.soft_scan` for the fused form).

    The hard two-threshold state machine

        on_t = 0 if p_t > p_off, 1 if p_t <= p_on, else on_{t-1}

    is relaxed with sigmoid event gates a_t = sigmoid((p_on - p_t)/tau)
    ("turn on") and b_t = sigmoid((p_t - p_off)/tau) ("turn off"):

        s_t = a_t + (1 - a_t)(1 - b_t) s_{t-1},   s_{-1} = 1

    which is affine in s_{t-1} and recovers the hard recurrence (with the
    kernel's on-wins precedence) as tau -> 0 at every sample not exactly
    on a threshold. Restarts are counted softly as s_t (1 - s_{t-1}) —
    smooth everywhere, and equal to the hard 0->1 indicator on binary
    states. Everything is differentiable in (p_on, p_off, off_level,
    idle_frac, prices); computation runs in the price dtype (float64
    under x64 — finite-difference gradient checks rely on this).
    """
    p = jnp.asarray(prices)
    dtype = p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32
    p = p.astype(dtype)
    b = p.shape[0]
    p_on, p_off, off_level, idle_frac = (
        jnp.broadcast_to(jnp.asarray(v, dtype), (b,))
        for v in (p_on, p_off, off_level, idle_frac))
    inv_tau = 1.0 / jnp.asarray(tau, dtype)

    def step(carry, p_t):
        s_prev, acc = carry
        _, _, alpha, beta = soft_gates(p_t, p_on, p_off, inv_tau)
        s = alpha * s_prev + beta
        start = s * (1.0 - s_prev)
        cap = off_level + (1.0 - off_level) * s
        draw = cap + idle_frac * (1.0 - cap)
        acc = (acc[0] + draw * p_t, acc[1] + cap,
               acc[2] + start, acc[3] + start * p_t)
        return (s, acc), None

    zeros = jnp.zeros((b,), dtype)
    init = (jnp.ones((b,), dtype), (zeros, zeros, zeros, zeros))
    (_, acc), _ = jax.lax.scan(step, init, p.T)
    return FleetScanOut(*acc)


def dispatch_alloc_hour(prev: jax.Array, dwell: jax.Array,
                        avail: jax.Array, order: jax.Array,
                        rank: jax.Array, demand,
                        *, min_dwell: int) -> tuple[jax.Array, jax.Array]:
    """One hour of feasible cross-site dispatch (greedy water-fill).

    Shared *verbatim* by `dispatch_ref` and the Pallas kernel
    (`repro.kernels.dispatch_scan`), so the two paths produce
    bit-identical allocations — only the orchestration around this
    function (lax.scan vs time-blocked grid with VMEM carry) differs.

    Each site contributes three price-sorted segments of capacity:

      locked  — load held < ``min_dwell`` hours; ranked below every
                other segment (price-ordered among themselves) so it is
                retained unless demand itself shrinks below the locks
      retain  — the rest of the previous allocation, priced at
                p - migrate_cost (leaving must pay the migration fee)
      fresh   — unused capacity at the plain market price

    ``order``/``rank`` are the ascending sort permutation of the 3S
    segment keys and its inverse, precomputed on the host
    (`repro.dispatch.segment_rank`): keys depend only on prices and the
    migration premium, never on the running state. The greedy fill is
    then sort-free — gather the widths into price order, one exclusive
    cumsum, gather each segment's cheaper-mass back, and take
    ``clip(demand - cheaper_mass, 0, width)`` — O(S) work per hour.

    prev/dwell/avail: [S]; order/rank: [3S] int32; demand: scalar MW.
    Returns ``(alloc [S], dwell' [S])``. Capacity loss breaks a dwell
    lock (physics beats contract): locked width is capped at ``avail``.
    """
    s = prev.shape[0]
    held = jnp.minimum(prev, avail)
    if min_dwell > 0:
        locked = jnp.where(dwell > 0.0, held, 0.0)
    else:
        locked = jnp.zeros_like(held)
    widths = jnp.concatenate([locked, held - locked, avail - held])
    sorted_w = jnp.take(widths, order)
    excl = jnp.cumsum(sorted_w) - sorted_w
    before = jnp.take(excl, rank)        # MW at strictly cheaper segments
    fill = jnp.clip(demand - before, 0.0, widths)
    alloc = fill[:s] + fill[s:2 * s] + fill[2 * s:]
    if min_dwell > 0:
        dwell = jnp.where(alloc > prev + DWELL_EVENT_MW, float(min_dwell),
                          jnp.maximum(dwell - 1.0, 0.0))
    return alloc, dwell


def dispatch_ref(avail: jax.Array, order: jax.Array, rank: jax.Array,
                 demand: jax.Array, *, min_dwell: int = 0) -> jax.Array:
    """Sequential oracle for the hour-by-hour fleet dispatch scan.

    avail: [S, T] available MW per site (policy on/off state x site
    rating); order/rank: [T, 3S] precomputed segment sort data;
    demand: [T] MW. Returns the allocation [S, T]. Initial state is
    empty (hour 0 *places* the fleet's load, which is not counted as
    migration by the accounting in `repro.dispatch`).
    """
    a = jnp.asarray(avail, jnp.float32)
    s = a.shape[0]

    def step(carry, inp):
        prev, dwell = carry
        a_t, o_t, r_t, d_t = inp
        alloc, dwell = dispatch_alloc_hour(prev, dwell, a_t, o_t, r_t,
                                           d_t, min_dwell=min_dwell)
        return (alloc, dwell), alloc

    zeros = jnp.zeros((s,), jnp.float32)
    _, alloc_t = jax.lax.scan(
        step, (zeros, zeros),
        (a.T, jnp.asarray(order, jnp.int32), jnp.asarray(rank, jnp.int32),
         jnp.asarray(demand, jnp.float32)))
    return alloc_t.T


DWELL_EVENT_MW = 1e-3  # allocation increase (MW) that counts as a
                       # fresh placement and rearms the dwell lock.
                       # Shared by the hard fill and its soft
                       # relaxation: 1 kW is far above both paths' f32
                       # rounding (so a site whose load merely *rounds*
                       # differently never rearms) and far below any
                       # real cross-site move, which is what lets the
                       # soft dwell dynamics converge to the hard ones
                       # as tau -> 0 instead of flipping locks on noise.

_WL_TINY = 1e-30      # absolute floor for water-level denominators
_WL_SIGMA_SPAN = 40.0  # sigmoid(±40) saturates in f32 *and* f64: the
                       # soft water level lives within ±40 tau of the
                       # hard one (see `soft_water_level`)
_DWELL_CNT_SCALE = 0.05  # dwell-count temperature per price-unit tau:
                         # the hard countdown parks the counter exactly
                         # on the min(d, 1) / relu(d - 1) kinks, so the
                         # soft path smooths both at tau_cnt =
                         # tau * this (sigmoid lock gate, softplus
                         # decrement) — co-annealed, FD-checkable
                         # gradients at every tau, hard counters in the
                         # limit


def soft_water_level(keys: jax.Array, widths: jax.Array, demand,
                     lam0, inv_tau, *, n_bisect: int = 30) -> jax.Array:
    """Level ``lam`` of the entropic water-fill: the root of

        f(lam) = sum_j widths_j sigmoid((lam - keys_j) / tau) = demand

    f is monotone in lam, so the root is unique whenever it exists;
    ``lam0`` must be the *hard* water level (the marginal segment's key
    from the precomputed sort), which brackets the soft root within
    ``±40 tau`` (sigmoid(40) == 1 in f32: every segment cheaper than the
    hard level is full at lam0 + 40 tau, so f covers the demand there,
    and only the below-marginal mass — at most the demand — survives at
    lam0 - 40 tau). Fixed-count bisection under ``stop_gradient`` finds
    the root; one *differentiable* Newton step from the stop-gradded
    solution then supplies the exact first-order implicit gradient
    (d lam = (d demand - sum_j sigma_j d w_j - ...) / f'(lam)) without
    backpropagating through the solver iterations. The correction is
    clipped to the bracket radius so an infeasible hour (demand above
    total width: f' -> 0 at the saturated bracket edge) degrades to
    "everything full" instead of emitting huge levels; callers
    renormalise the fill mass, so the clip never distorts feasible
    hours, where the correction is O(bracket / 2^n_bisect).
    """
    lam_hat = _bisect_level(keys, widths, demand, lam0, inv_tau,
                            n_bisect=n_bisect)
    return soft_water_level_fixed(keys, widths, demand, lam_hat, inv_tau)


def _bisect_level(keys: jax.Array, widths: jax.Array, demand, lam0,
                  inv_tau, *, n_bisect: int = 30) -> jax.Array:
    """The non-differentiable half of `soft_water_level`: fixed-count
    bisection from the hard-level bracket, returned under
    ``stop_gradient``. Saved as a per-hour residual by the fused
    dispatch VJP (`repro.kernels.soft_dispatch`) so the backward pass
    never re-runs the solver."""
    span = _WL_SIGMA_SPAN / inv_tau

    def f(lam):
        return jnp.sum(widths * jax.nn.sigmoid((lam - keys) * inv_tau))

    def bisect(_, lo_hi):
        lo, hi = lo_hi
        mid = 0.5 * (lo + hi)
        below = f(mid) < demand
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(
        0, n_bisect, bisect, (lam0 - span, lam0 + span))
    return jax.lax.stop_gradient(0.5 * (lo + hi))


def soft_water_level_fixed(keys: jax.Array, widths: jax.Array, demand,
                           lam_hat, inv_tau) -> jax.Array:
    """The differentiable half of `soft_water_level`: one Newton
    correction from an already-solved (stop-gradded) ``lam_hat``. All
    gradient flow of the water level lives here — given the same
    ``lam_hat`` the composition is bitwise-identical to the original
    fused form, which is what lets the custom VJP replay this half from
    a saved residual."""
    lam_hat = jax.lax.stop_gradient(lam_hat)
    span = _WL_SIGMA_SPAN / inv_tau
    sig = jax.nn.sigmoid((lam_hat - keys) * inv_tau)
    denom = jnp.maximum(
        jax.lax.stop_gradient(jnp.sum(widths * sig * (1.0 - sig))
                              * inv_tau), _WL_TINY)
    step = (demand - jnp.sum(widths * sig)) / denom
    return lam_hat + jnp.clip(step, -span, span)


def soft_dispatch_hour(prev: jax.Array, dwell: jax.Array,
                       avail: jax.Array, keys: jax.Array,
                       order: jax.Array, demand, *, inv_tau, inv_tau_mw,
                       min_dwell: int,
                       n_bisect: int = 30) -> tuple[jax.Array, jax.Array]:
    """One hour of the temperature-``tau`` softmin water-fill — the
    relaxation of `dispatch_alloc_hour`.

    Shared *verbatim* by `soft_dispatch_ref` and the Pallas kernel
    (`repro.kernels.soft_dispatch`), exactly like `dispatch_alloc_hour`,
    so the two soft paths are bit-identical. Same segment model (locked
    / retain / fresh), but every hard choice is smoothed:

      * the greedy fill becomes the entropic water-fill
        ``x_j = w_j sigmoid((lam - key_j) / tau)`` with ``lam`` from
        `soft_water_level` — a softmin over the (price − migrate
        premium) keys that spreads marginal mass across nearby segments
        and converges to the exact clip-fill as tau -> 0;
      * the dwell lock becomes a smooth discount: lock strength
        ``sigmoid((dwell - 1/2) / tau_cnt)`` of the held mass (the hard
        ``dwell > 0`` gate on the integer-valued limit), the countdown
        ``relu(dwell - 1)`` becomes its softplus at the same count
        temperature (the hard chain parks the counter exactly on both
        kinks — smoothing them is what makes the gradients
        finite-difference-checkable), and the fresh-placement reset
        becomes a sigmoid of the allocation *increase* at MW
        temperature ``tau_mw = 1 / inv_tau_mw``.

    ``keys`` are the host-precomputed [3S] segment keys of
    `repro.dispatch.segment_keys` and ``order`` their ascending sort —
    reused to seed the water-level bracket with the hard level (count
    the sorted widths' cumulative mass past the demand). The fill is
    renormalised to sum exactly to the demand (scale -> 1 as tau -> 0),
    which also zeroes allocation on zero-demand padded hours.
    prev/dwell/avail: [S]; keys: [3S]; order: [3S] int32.
    Returns ``(alloc [S], dwell' [S])``.
    """
    alloc, dwell, _ = soft_dispatch_hour_parts(
        prev, dwell, avail, keys, order, demand, inv_tau=inv_tau,
        inv_tau_mw=inv_tau_mw, min_dwell=min_dwell, n_bisect=n_bisect)
    return alloc, dwell


def _hour_widths(prev: jax.Array, dwell: jax.Array, avail: jax.Array, *,
                 inv_tau, min_dwell: int) -> jax.Array:
    """[3S] locked / retain / fresh segment widths of one hour."""
    held = jnp.minimum(prev, avail)
    if min_dwell > 0:
        inv_tau_cnt = inv_tau / _DWELL_CNT_SCALE
        locked = jax.nn.sigmoid((dwell - 0.5) * inv_tau_cnt) * held
    else:
        locked = jnp.zeros_like(held)
    return jnp.concatenate([locked, held - locked, avail - held])


def soft_dispatch_hour_fixed(prev: jax.Array, dwell: jax.Array,
                             avail: jax.Array, keys: jax.Array, demand,
                             lam_hat, inv_tau, inv_tau_mw, *,
                             min_dwell: int
                             ) -> tuple[jax.Array, jax.Array]:
    """One soft-dispatch hour given an already-bisected water level.

    The differentiable core of `soft_dispatch_hour`: every op that
    carries gradient (widths, Newton correction, fill, renormalisation,
    dwell dynamics) — only the stop-gradded solver state (``lam_hat``
    from `_bisect_level`, which also subsumes the sorted hard-level
    seed) is taken as an input. Needs no ``order``, no sort walk and no
    bisection, which is exactly what the fused custom VJP exploits: the
    forward saves ``lam_hat`` per hour, and the backward is the
    `jax.vjp` transpose of *this* function — the same linear map native
    autodiff would build, just replayed from slim residuals
    (`soft_dispatch_hour_grad`).
    """
    s = prev.shape[0]
    widths = _hour_widths(prev, dwell, avail, inv_tau=inv_tau,
                          min_dwell=min_dwell)
    lam = soft_water_level_fixed(keys, widths, demand, lam_hat, inv_tau)

    fill = widths * jax.nn.sigmoid((lam - keys) * inv_tau)
    fill = fill * (demand / jnp.maximum(jnp.sum(fill),
                                        1e-9 * demand + _WL_TINY))
    alloc = fill[:s] + fill[s:2 * s] + fill[2 * s:]
    if min_dwell > 0:
        inv_tau_cnt = inv_tau / _DWELL_CNT_SCALE
        moved_in = jax.nn.sigmoid((alloc - prev - DWELL_EVENT_MW)
                                  * inv_tau_mw)
        count_down = jax.nn.softplus((dwell - 1.0) * inv_tau_cnt) \
            / inv_tau_cnt
        dwell = moved_in * min_dwell + (1.0 - moved_in) * count_down
    return alloc, dwell


def soft_dispatch_hour_parts(prev: jax.Array, dwell: jax.Array,
                             avail: jax.Array, keys: jax.Array,
                             order: jax.Array, demand, *, inv_tau,
                             inv_tau_mw, min_dwell: int,
                             n_bisect: int = 30
                             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """`soft_dispatch_hour` that also returns the bisected level
    ``lam_hat`` — the one extra per-hour residual the fused VJP saves.
    ``(alloc, dwell')`` are bitwise those of `soft_dispatch_hour`."""
    s = prev.shape[0]
    widths = _hour_widths(prev, dwell, avail, inv_tau=inv_tau,
                          min_dwell=min_dwell)
    sorted_w = jnp.take(widths, order)
    cums = jnp.cumsum(sorted_w)
    marginal = jnp.minimum(jnp.sum((cums < demand).astype(jnp.int32)),
                           3 * s - 1)
    lam0 = jax.lax.stop_gradient(
        jnp.take(jnp.take(keys, order), marginal))
    lam_hat = _bisect_level(keys, widths, demand, lam0, inv_tau,
                            n_bisect=n_bisect)
    alloc, dwell = soft_dispatch_hour_fixed(
        prev, dwell, avail, keys, demand, lam_hat, inv_tau, inv_tau_mw,
        min_dwell=min_dwell)
    return alloc, dwell, lam_hat


def soft_dispatch_hour_grad(prev: jax.Array, dwell: jax.Array,
                            avail: jax.Array, keys: jax.Array, demand,
                            lam_hat, inv_tau, inv_tau_mw,
                            u_alloc: jax.Array, u_dwell: jax.Array, *,
                            min_dwell: int):
    """Adjoint of one fixed-level hour: the exact `jax.vjp` transpose of
    `soft_dispatch_hour_fixed` under output cotangents ``(u_alloc,
    u_dwell)``. Shared verbatim by the XLA and Pallas fused backwards
    and by the sequential `soft_dispatch_grad_ref` oracle — the same
    role `soft_gate_grad` plays for the isolated scan. Returns
    ``(d_prev, d_dwell, d_avail, d_keys, d_demand, d_inv_tau,
    d_inv_tau_mw)``; linear in the cotangents, so zero-padded hours
    contribute exact zeros and padding needs no masking.
    """
    def fwd(p, dw, av, ke, de, it, itm):
        return soft_dispatch_hour_fixed(p, dw, av, ke, de, lam_hat,
                                        it, itm, min_dwell=min_dwell)

    _, pull = jax.vjp(fwd, prev, dwell, avail, keys, demand,
                      inv_tau, inv_tau_mw)
    return pull((u_alloc, u_dwell))


def soft_dispatch_grad_ref(avail: jax.Array, keys: jax.Array,
                           order: jax.Array, demand: jax.Array,
                           g: jax.Array, *, tau, min_dwell: int = 0,
                           mw_scale: float = 0.05, n_bisect: int = 30):
    """Sequential oracle for the fused soft-dispatch backward.

    Pulls the output cotangent ``g`` ([S, T], against the allocation of
    `soft_dispatch_ref`) back through the hour recurrence: a forward
    scan records each hour's entering state and bisected level, a
    reverse scan chains `soft_dispatch_hour_grad` carrying the adjoints
    of the (prev alloc, dwell) state. Returns ``(d_avail [S, T],
    d_keys [T, 3S], d_demand [T], d_tau [])`` — the same quantities
    native autodiff produces, to float round-off, and the contract the
    blocked XLA/Pallas backwards in `repro.kernels.soft_dispatch` are
    tested against (exactly as `soft_scan_grad_ref` anchors the
    isolated scan's VJP).
    """
    a = jnp.asarray(avail)
    dtype = a.dtype if jnp.issubdtype(a.dtype, jnp.floating) \
        else jnp.float32
    a = a.astype(dtype)
    s = a.shape[0]
    keys = jnp.asarray(keys, dtype)
    demand = jnp.asarray(demand, dtype)
    g = jnp.asarray(g, dtype)
    tau = jnp.asarray(tau, dtype)
    inv_tau = 1.0 / tau
    inv_tau_mw = inv_tau / jnp.asarray(mw_scale, dtype)

    def fstep(carry, inp):
        prev, dwell = carry
        a_t, k_t, o_t, d_t = inp
        alloc, dwell2, lam_hat = soft_dispatch_hour_parts(
            prev, dwell, a_t, k_t, o_t, d_t, inv_tau=inv_tau,
            inv_tau_mw=inv_tau_mw, min_dwell=min_dwell,
            n_bisect=n_bisect)
        return (alloc, dwell2), (prev, dwell, lam_hat)

    zeros = jnp.zeros((s,), dtype)
    _, (prevs, dwells_in, lam_hats) = jax.lax.scan(
        fstep, (zeros, zeros),
        (a.T, keys, jnp.asarray(order, jnp.int32), demand))

    def bstep(carry, inp):
        u_prev, u_dwell, acc_it, acc_itm = carry
        p_t, dw_t, lam_t, a_t, k_t, d_t, g_t = inp
        d_p, d_dw, d_av, d_ke, d_de, d_it, d_itm = \
            soft_dispatch_hour_grad(p_t, dw_t, a_t, k_t, d_t, lam_t,
                                    inv_tau, inv_tau_mw, g_t + u_prev,
                                    u_dwell, min_dwell=min_dwell)
        return (d_p, d_dw, acc_it + d_it, acc_itm + d_itm), \
            (d_av, d_ke, d_de)

    init = (zeros, zeros, jnp.zeros((), dtype), jnp.zeros((), dtype))
    (_, _, acc_it, acc_itm), (d_av, d_ke, d_de) = jax.lax.scan(
        bstep, init, (prevs, dwells_in, lam_hats, a.T, keys, demand, g.T),
        reverse=True)
    # tau -> (inv_tau, inv_tau_mw) chain: d itau/d tau = -itau^2,
    # d itaumw/d tau = -itau * itaumw
    d_tau = -(inv_tau ** 2) * acc_it - inv_tau * inv_tau_mw * acc_itm
    return d_av.T, d_ke, d_de, d_tau


def soft_dispatch_ref(avail: jax.Array, keys: jax.Array, order: jax.Array,
                      demand: jax.Array, *, tau, min_dwell: int = 0,
                      mw_scale: float = 0.05,
                      n_bisect: int = 30) -> jax.Array:
    """Sequential oracle for the soft (differentiable) dispatch scan.

    avail: [S, T] available MW; keys/order: [T, 3S] precomputed segment
    keys (`repro.dispatch.segment_keys`) and their ascending sort
    permutation; demand: [T] MW. Returns the relaxed allocation [S, T],
    differentiable in ``avail``, ``demand``, ``keys`` and ``tau``, and
    converging to `dispatch_ref`'s hard allocation as tau -> 0 (at
    problems whose segment keys are distinct). ``mw_scale`` sets the MW
    temperature of the dwell reset gate as ``tau * mw_scale`` — it
    co-anneals with ``tau``. Computation runs in the availability dtype
    (float64 under x64 — the FD gradient checks rely on this), exactly
    like `soft_scan_ref`.
    """
    a = jnp.asarray(avail)
    dtype = a.dtype if jnp.issubdtype(a.dtype, jnp.floating) else jnp.float32
    a = a.astype(dtype)
    s = a.shape[0]
    keys = jnp.asarray(keys, dtype)
    demand = jnp.asarray(demand, dtype)
    inv_tau = 1.0 / jnp.asarray(tau, dtype)
    inv_tau_mw = inv_tau / jnp.asarray(mw_scale, dtype)

    def step(carry, inp):
        prev, dwell = carry
        a_t, k_t, o_t, d_t = inp
        alloc, dwell = soft_dispatch_hour(
            prev, dwell, a_t, k_t, o_t, d_t, inv_tau=inv_tau,
            inv_tau_mw=inv_tau_mw, min_dwell=min_dwell,
            n_bisect=n_bisect)
        return (alloc, dwell), alloc

    zeros = jnp.zeros((s,), dtype)
    _, alloc_t = jax.lax.scan(
        step, (zeros, zeros),
        (a.T, keys, jnp.asarray(order, jnp.int32), demand))
    return alloc_t.T


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  q_offset: int = 0) -> jax.Array:
    """q: [B,Sq,H,Dh]; k,v: [B,Skv,G,Dh]; GQA by head repetition."""
    b, sq, h, dh = q.shape
    skv, g = k.shape[1], k.shape[2]
    rep = h // g
    kh = jnp.repeat(k, rep, axis=2)
    vh = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * dh ** -0.5
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid: jax.Array) -> jax.Array:
    """q: [B,1,H,Dh]; k,v: [B,W,G,Dh]; valid: [B,W] bool."""
    b, _, h, dh = q.shape
    g = k.shape[2]
    rep = h // g
    kh = jnp.repeat(k, rep, axis=2)
    vh = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * dh ** -0.5
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array,
            b: jax.Array, c: jax.Array,
            h0: Optional[jax.Array] = None
            ) -> tuple[jax.Array, jax.Array]:
    """Sequential (unchunked) SSD recurrence — the ground-truth oracle.

    x: [B,S,H,P]; dt: [B,S,H] (post-softplus); a: [H] (negative);
    b,c: [B,S,G,N]; h0: [B,H,P,N] or None.
    Returns y: [B,S,H,P] (f32), h_last: [B,H,P,N] (f32).

        h_t = h_{t-1} * exp(dt_t a) + dt_t x_t b_t^T ;  y_t = h_t c_t
    """
    bsz, s, nh, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = nh // g
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)   # [B,S,H,N]
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp          # [B,H,P], [B,H], [B,H,N], [B,H,N]
        decay = jnp.exp(dt_t * a[None, :])                 # [B,H]
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t, b_t)
        h = h * decay[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y_t

    h_init = jnp.zeros((bsz, nh, p, n), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    h_last, ys = jax.lax.scan(
        step, h_init,
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
         bh.swapaxes(0, 1), ch.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h_last
