"""Mamba2 SSD (state-space duality) chunk scan as a Pallas TPU kernel.

The SSD algorithm splits the sequence into chunks of length L. Within a
chunk the recurrence collapses to a masked quadratic ("attention") form —
three MXU matmuls — and between chunks only an [N, P] state is carried.

TPU adaptation: the original Triton kernels split intra/inter-chunk work
into separate launches with the state scan on the host side. On TPU the
grid is *sequential*, so the inter-chunk recurrence becomes a VMEM scratch
carry along the innermost grid dimension — one kernel does the whole scan
with zero HBM round-trips for the state. Grid = (B, H, n_chunks):

    state_scr [N, P] f32   carried across the chunk dimension
    per step:  lmat   = exp(segsum(dt*a))        [L, L]   (VPU)
               scores = (C B^T) * lmat           [L, L]   (MXU)
               y      = scores (x*dt)            [L, P]   (MXU)
               y     += (C state) * exp(cum)     [L, P]   (MXU)
               state  = state*exp(cum[-1]) + B^T (x*dt*decay)   (MXU)

VMEM per step at L=256, P=64, N=128 (f32): x/y 64 KiB, B/C 2x128 KiB,
scores/lmat 2x256 KiB, state 32 KiB -> < 1 MiB, comfortably inside VMEM;
L is the kernel's block knob (cfg.ssm_chunk).

B/C are shared across heads within a group (Mamba2-1.3b: one group), so the
BlockSpec index map (h -> h // heads_per_group) fetches the group block —
no per-head materialisation in HBM.

Validated in interpret mode against `repro.kernels.ref.ssd_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,   # inputs
                y_ref, hT_ref,                                # outputs
                state_scr,                                    # [N, P] f32
                *, l_chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = h0_ref[0, 0].astype(jnp.float32)     # [N, P]

    x = x_ref[0, 0, 0].astype(jnp.float32)                    # [L, P]
    dt = dt_ref[0, 0].astype(jnp.float32)                     # [1, L]
    a = a_ref[0]                                              # scalar
    bmat = b_ref[0, 0, 0].astype(jnp.float32)                 # [L, N]
    cmat = c_ref[0, 0, 0].astype(jnp.float32)                 # [L, N]

    da = dt[0] * a                                            # [L] (<= 0)
    cum = jnp.cumsum(da)                                      # [L]
    # segment-sum decay matrix: lmat[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, None] - cum[None, :]
    tril = (jax.lax.broadcasted_iota(jnp.int32, (l_chunk, l_chunk), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (l_chunk, l_chunk), 1))
    lmat = jnp.where(tril, jnp.exp(diff), 0.0)                # [L, L]

    xdt = x * dt[0][:, None]                                  # [L, P]
    scores = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * lmat            # [L, L]
    y = jax.lax.dot(scores, xdt,
                    preferred_element_type=jnp.float32)       # [L, P]

    # contribution of the carried-in state
    state = state_scr[...]                                    # [N, P]
    y_off = jax.lax.dot(cmat, state,
                        preferred_element_type=jnp.float32)   # [L, P]
    y += y_off * jnp.exp(cum)[:, None]

    # state update: h <- h * exp(cum[-1]) + B^T (xdt * decay)
    decay = jnp.exp(cum[-1] - cum)                            # [L]
    contrib = jax.lax.dot_general(
        bmat, xdt * decay[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                   # [N, P]
    state_scr[...] = state * jnp.exp(cum[-1]) + contrib

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hT_ref[0, 0] = state_scr[...].astype(hT_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("l_chunk", "n_groups", "interpret"))
def ssd_scan_grouped(x: jax.Array, dt: jax.Array, a: jax.Array,
                     b: jax.Array, c: jax.Array, h0: jax.Array, *,
                     l_chunk: int, n_groups: int,
                     interpret: bool) -> tuple[jax.Array, jax.Array]:
    """Core pallas_call.

    x:  [B, H, NC, L, P]      (conv-activated inputs, head-split)
    dt: [B, H, NC, L]         (post-softplus step sizes)
    a:  [H]                   (negative decay coefficients)
    b/c:[B, G, NC, L, N]      (G groups; heads share group blocks)
    h0: [B, H, N, P]          (initial state, zeros for training)
    Returns y: [B, H, NC, L, P] and final state [B, H, N, P] (f32).
    """
    bsz, nh, nc, l, p = x.shape
    n = b.shape[-1]
    rep = nh // n_groups

    kernel = functools.partial(_ssd_kernel, l_chunk=l, n_chunks=nc)

    return pl.pallas_call(
        kernel,
        grid=(bsz, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, l, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, l), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, 1, 1, l, n),
                         lambda b_, h_, c_: (b_, h_ // rep, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, l, n),
                         lambda b_, h_, c_: (b_, h_ // rep, c_, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, l, p),
                         lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nh, nc, l, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, h0)
