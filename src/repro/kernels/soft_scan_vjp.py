"""Fused, checkpointed custom VJP for the soft-state trajectory.

`repro.kernels.soft_scan.soft_state` evaluates the relaxed hysteresis
recurrence s_t = alpha_t s_{t-1} + beta_t with one
`jax.lax.associative_scan`, and PR 2's tuner differentiated it with
native autodiff. That works, but the autodiff rule for an associative
scan transposes every combine of the O(log T)-depth tree: the backward
pass re-materialises the full [B, T] affine-map intermediates (several
buffers of them) in HBM on every Adam step, and its arithmetic is 3-4x
the forward's. This module replaces it with a hand-written
`jax.custom_vjp` built on rematerialisation over time blocks:

  forward   evaluate s blockwise (within-block prefix scan + an exact
            [n_blocks]-length carry propagation) and save as residuals
            only the inputs plus the per-block *entering* states —
            O(B * T / block_t) extra memory instead of O(B * T).

  backward  walk the time grid in reverse, one block at a time:
            recompute the gates and the within-block states from the
            saved carry (checkpointed recompute, block-local), run the
            adjoint recurrence u_t = g_t + alpha_{t+1} u_{t+1} — itself
            a first-order linear recurrence, evaluated with the same
            blocked machinery in reverse — and apply the per-hour chain
            rule `repro.kernels.ref.soft_gate_grad`, which is shared
            verbatim with the sequential oracle
            `repro.kernels.ref.soft_scan_grad_ref`.

Two implementations sit behind the same custom_vjp, mirroring
`fleet_scan`: a blocked pure-XLA form (the fast path off-TPU —
sequential in time, vectorized over rows, and dtype-following so the
float64 parity tests are exact), and a Pallas TPU kernel pair
(time-innermost grid, carries in VMEM scratch, log-depth doubling
scans in-block, the backward visiting time blocks in reverse via its
index map; validated in interpret mode, like the other kernels in this
package). Gradients
agree with native autodiff through `soft_state` to tight tolerance —
the reassociation of the time reduction is the only difference — and
cotangents are produced for all four primals (prices, p_on, p_off,
tau), so the annealed tuner's traced tau needs no special casing.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import soft_gate_grad, soft_gates

DEFAULT_BLOCK_T = 256


# ---------------------------------------------------------------------------
# blocked XLA path (fast path off-TPU; dtype-following)
# ---------------------------------------------------------------------------
#
# XLA:CPU runs a tight `lax.scan` over T (one [B]-wide fused vector op
# per hour) several times faster than the log-depth associative scan the
# native path uses — the scan's strided odd/even slicing is hostile to
# caches, and its autodiff rule is worse still. So off-TPU the fused
# path is sequential in time and vectorized over rows, exactly like the
# `ref.py` oracles, with the backward walking block by block so its
# transients stay O(B * block_t) instead of O(B * T).

def _xla_fwd(p, p_on, p_off, inv_tau, block_t):
    """Forward state trajectory + the per-block entering states.

    Time-major sequential scan; the checkpoint carries are a gather of
    states already computed (s at block boundaries), so saving them
    costs nothing beyond the O(B * T / block_t) residual itself.
    """
    b, t = p.shape
    _, _, alpha, beta = soft_gates(p.T, p_on[None, :], p_off[None, :],
                                   inv_tau)                   # [T, B]

    def step(s, ab):
        a_t, b_t = ab
        s = a_t * s + b_t
        return s, s

    _, s_tm = jax.lax.scan(step, jnp.ones((b,), p.dtype), (alpha, beta))
    s = s_tm.T                                                # [B, T]
    nb = -(-t // block_t)
    ones = jnp.ones((b, 1), p.dtype)
    if nb == 1:
        return s, ones
    idx = jnp.arange(1, nb) * block_t - 1    # state entering blocks 1..
    return s, jnp.concatenate([ones, s[:, idx]], axis=1)


def _xla_bwd(p, p_on, p_off, inv_tau, carries, g, block_t):
    """Checkpointed backward: walk the time grid in reverse, one block
    at a time — recompute gates and states block-locally from the saved
    entering carry, run the adjoint recurrence u_t = g_t + alpha_{t+1}
    u_{t+1} across the block (seeded over the boundary by the later
    block's first hour), then apply the shared per-hour chain rule
    `soft_gate_grad` and accumulate the parameter sums. Transients are
    O(B * block_t) per block plus the d_prices output (dead code the
    compiler can drop when prices carry no cotangent — the tuner's
    case)."""
    b, t = p.shape
    pad = (-t) % block_t
    nb = (t + pad) // block_t
    p_blk = jnp.pad(p.T, ((0, pad), (0, 0))).reshape(nb, block_t, b)
    g_blk = jnp.pad(g.T, ((0, pad), (0, 0))).reshape(nb, block_t, b)
    valid = (jnp.arange(nb * block_t) < t).astype(p.dtype) \
        .reshape(nb, block_t, 1)

    def block_step(carry, xs):
        u_next, a_next, acc = carry          # adjoint seed from block j+1
        p_b, g_b, c_in, v_b = xs             # [bt, B], [bt, B], [B], [bt, 1]
        a, f, alpha, beta = soft_gates(p_b, p_on[None, :], p_off[None, :],
                                       inv_tau)
        alpha = alpha * v_b + (1.0 - v_b)    # identity maps past T
        g_b = g_b * v_b

        def fstep(s, ab):
            a_t, b_t = ab
            return a_t * s + b_t, s          # emit the *entering* state

        _, s_prev = jax.lax.scan(fstep, c_in, (alpha, beta * v_b))

        def bstep(c, ab):
            u_n, a_n = c
            g_t, a_t = ab
            u_t = g_t + a_n * u_n
            return (u_t, a_t), u_t

        (u_first, a_first), u = jax.lax.scan(
            bstep, (u_next, a_next), (g_b, alpha), reverse=True)

        d_p, d_on, d_off, d_it = soft_gate_grad(
            p_b, s_prev, u, p_on[None, :], p_off[None, :], inv_tau,
            gates=(a, f))
        acc = (acc[0] + jnp.sum(d_on * v_b, axis=0),
               acc[1] + jnp.sum(d_off * v_b, axis=0),
               acc[2] + jnp.sum(d_it * v_b, axis=0))
        return (u_first, a_first, acc), d_p * v_b

    zeros = jnp.zeros((b,), p.dtype)
    (_, _, acc), d_p_blk = jax.lax.scan(
        block_step, (zeros, zeros, (zeros, zeros, zeros)),
        (p_blk, g_blk, carries.T, valid), reverse=True)
    d_p = d_p_blk.reshape(nb * block_t, b)[:t].T
    return d_p, acc[0], acc[1], jnp.sum(acc[2])


# ---------------------------------------------------------------------------
# Pallas TPU kernels (time-innermost grid, carries in VMEM scratch)
# ---------------------------------------------------------------------------

def _prefix_linear(coeff: jax.Array, acc: jax.Array) -> jax.Array:
    """In-kernel prefix of s_i = coeff_i s_{i-1} + acc_i (s_{-1} folded
    into acc_0) along axis 0 by log-depth doubling: shifted-in zeros
    terminate both the value and the running product past the edge."""
    n = coeff.shape[0]
    s, prod = acc, coeff
    d = 1
    while d < n:
        zeros = jnp.zeros((d,) + s.shape[1:], s.dtype)
        s = s + prod * jnp.concatenate([zeros, s[:-d]], axis=0)
        prod = prod * jnp.concatenate([zeros, prod[:-d]], axis=0)
        d *= 2
    return s


def _suffix_linear(coeff: jax.Array, acc: jax.Array) -> jax.Array:
    """Mirror of `_prefix_linear` for u_i = acc_i + coeff_i u_{i+1}
    (the seed from beyond the block folded into acc_{-1})."""
    n = coeff.shape[0]
    u, prod = acc, coeff
    d = 1
    while d < n:
        zeros = jnp.zeros((d,) + u.shape[1:], u.dtype)
        u = u + prod * jnp.concatenate([u[d:], zeros], axis=0)
        prod = prod * jnp.concatenate([prod[d:], zeros], axis=0)
        d *= 2
    return u


def _fwd_kernel(p_ref, pon_ref, poff_ref, itau_ref,
                s_ref, carr_ref,
                state_scr, *, t_total: int, block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state_scr[...] = jnp.ones_like(state_scr)      # s_{-1} = 1

    p = p_ref[...].astype(jnp.float32)                 # [bt, bb] time-major
    pon = pon_ref[...]
    poff = poff_ref[...]
    inv_tau = itau_ref[0]
    tloc = jax.lax.broadcasted_iota(jnp.int32, p.shape, 0)
    valid = (ti * block_t + tloc) < t_total

    _, _, alpha, beta = soft_gates(p, pon[None, :], poff[None, :], inv_tau)
    alpha = jnp.where(valid, alpha, 1.0)               # identity padding
    beta = jnp.where(valid, beta, 0.0)

    carry = state_scr[...]                             # [bb]
    carr_ref[...] = carry[None, :]                     # entering state
    # fold the entering state into acc_0 (static-slice concat, not a
    # scatter — lowers cleanly on the VPU)
    beta = jnp.concatenate([beta[:1] + alpha[:1] * carry[None, :],
                            beta[1:]], axis=0)
    s = _prefix_linear(alpha, beta)
    s_ref[...] = s
    state_scr[...] = s[-1]


def _bwd_kernel(p_ref, g_ref, pon_ref, poff_ref, itau_ref, carr_ref,
                dp_ref, sums_ref,
                u_scr, afirst_scr, acc_scr,
                *, t_total: int, block_t: int, n_t_blocks: int):
    ti = pl.program_id(1)                # visits time blocks in reverse
                                         # via the index maps

    @pl.when(ti == 0)
    def _init():
        u_scr[...] = jnp.zeros_like(u_scr)        # no hours after T-1
        afirst_scr[...] = jnp.zeros_like(afirst_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    pon = pon_ref[...]
    poff = poff_ref[...]
    inv_tau = itau_ref[0]
    bi = n_t_blocks - 1 - ti                       # actual time-block index
    tloc = jax.lax.broadcasted_iota(jnp.int32, p.shape, 0)
    valid = (bi * block_t + tloc) < t_total

    a_gate, f_gate, alpha, beta = soft_gates(p, pon[None, :],
                                             poff[None, :], inv_tau)
    alpha = jnp.where(valid, alpha, 1.0)
    beta = jnp.where(valid, beta, 0.0)
    g = jnp.where(valid, g, 0.0)

    # recompute the block's states from the saved entering carry
    carry = carr_ref[0, :]                         # [bb]
    beta_f = jnp.concatenate([beta[:1] + alpha[:1] * carry[None, :],
                              beta[1:]], axis=0)
    s = _prefix_linear(alpha, beta_f)
    s_prev = jnp.concatenate([carry[None, :], s[:-1]], axis=0)

    # adjoint within the block, seeded across the boundary by the later
    # block's first-hour adjoint: u_t = g_t + alpha_{t+1} u_{t+1}
    coeff = jnp.concatenate([alpha[1:],
                             jnp.zeros((1,) + alpha.shape[1:],
                                       alpha.dtype)], axis=0)
    seed = (afirst_scr[...] * u_scr[...])[None, :]
    g = jnp.concatenate([g[:-1], g[-1:] + seed], axis=0)
    u = _suffix_linear(coeff, g)
    u_scr[...] = u[0]
    afirst_scr[...] = alpha[0]

    d_p, d_on, d_off, d_it = soft_gate_grad(p, s_prev, u, pon[None, :],
                                            poff[None, :], inv_tau,
                                            gates=(a_gate, f_gate))
    vf = valid.astype(jnp.float32)
    dp_ref[...] = d_p * vf
    acc_scr[0, :] += jnp.sum(d_on * vf, axis=0)
    acc_scr[1, :] += jnp.sum(d_off * vf, axis=0)
    acc_scr[2, :] += jnp.sum(d_it * vf, axis=0)

    @pl.when(ti == n_t_blocks - 1)
    def _finish():
        sums_ref[...] = acc_scr[...]


def _pick_block(n: int, cap: int) -> int:
    """Largest 128-multiple <= min(cap, n), or n itself for small n."""
    cap = max(min(cap, n), 1)
    return (cap // 128) * 128 if cap >= 128 else cap


def _pallas_pad(p, p_on, p_off, block_b, block_t):
    b, t = p.shape
    pad_b = (-b) % block_b
    pad_t = (-t) % block_t
    p_tm = jnp.pad(p.astype(jnp.float32).T, ((0, pad_t), (0, pad_b)))
    pon = jnp.pad(p_on.astype(jnp.float32), (0, pad_b))
    poff = jnp.pad(p_off.astype(jnp.float32), (0, pad_b))
    return p_tm, pon, poff


@functools.partial(jax.jit, static_argnames=("block_b", "block_t",
                                             "t_total", "interpret"))
def _pallas_fwd(p_tm, pon, poff, itau, *, block_b, block_t, t_total,
                interpret):
    t_pad, b_pad = p_tm.shape
    nb, nt = b_pad // block_b, t_pad // block_t
    kernel = functools.partial(_fwd_kernel, t_total=t_total,
                               block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((block_t, block_b), lambda bi, ti: (ti, bi)),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
            pl.BlockSpec((1,), lambda bi, ti: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, block_b), lambda bi, ti: (ti, bi)),
            pl.BlockSpec((1, block_b), lambda bi, ti: (ti, bi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_pad, b_pad), jnp.float32),
            jax.ShapeDtypeStruct((nt, b_pad), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_b,), jnp.float32)],
        interpret=interpret,
    )(p_tm, pon, poff, itau)


@functools.partial(jax.jit, static_argnames=("block_b", "block_t",
                                             "t_total", "interpret"))
def _pallas_bwd(p_tm, g_tm, pon, poff, itau, carr, *, block_b, block_t,
                t_total, interpret):
    t_pad, b_pad = p_tm.shape
    nb, nt = b_pad // block_b, t_pad // block_t
    kernel = functools.partial(_bwd_kernel, t_total=t_total,
                               block_t=block_t, n_t_blocks=nt)
    rev = lambda bi, ti: (nt - 1 - ti, bi)         # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((block_t, block_b), rev),
            pl.BlockSpec((block_t, block_b), rev),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
            pl.BlockSpec((1,), lambda bi, ti: (0,)),
            pl.BlockSpec((1, block_b), rev),
        ],
        out_specs=[
            pl.BlockSpec((block_t, block_b), rev),
            pl.BlockSpec((3, block_b), lambda bi, ti: (0, bi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_pad, b_pad), jnp.float32),
            jax.ShapeDtypeStruct((3, b_pad), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_b,), jnp.float32),
                        pltpu.VMEM((block_b,), jnp.float32),
                        pltpu.VMEM((3, block_b), jnp.float32)],
        interpret=interpret,
    )(p_tm, g_tm, pon, poff, itau, carr)


# ---------------------------------------------------------------------------
# the custom_vjp
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _soft_state(p, p_on, p_off, tau, block_t, use_pallas, interpret):
    s, _ = _soft_state_fwd(p, p_on, p_off, tau, block_t, use_pallas,
                           interpret)
    return s


def _soft_state_fwd(p, p_on, p_off, tau, block_t, use_pallas, interpret):
    inv_tau = 1.0 / tau
    if use_pallas:
        b, t = p.shape
        block_b = _pick_block(b, 128)
        bt = _pick_block(t, block_t)
        p_tm, pon, poff = _pallas_pad(p, p_on, p_off, block_b, bt)
        itau = jnp.asarray(inv_tau, jnp.float32).reshape(1)
        s_tm, carr = _pallas_fwd(p_tm, pon, poff, itau, block_b=block_b,
                                 block_t=bt, t_total=t,
                                 interpret=interpret)
        s = s_tm[:t, :b].T.astype(p.dtype)
        carries = carr[:, :b].T.astype(p.dtype)
    else:
        s, carries = _xla_fwd(p, p_on, p_off, inv_tau, block_t)
    # residuals: inputs + per-block entering states — O(B * T / block_t)
    # beyond buffers that already exist, never the [B, T] intermediates
    return s, (p, p_on, p_off, tau, carries)


def _soft_state_bwd(block_t, use_pallas, interpret, res, g):
    p, p_on, p_off, tau, carries = res
    inv_tau = 1.0 / tau
    if use_pallas:
        b, t = p.shape
        block_b = _pick_block(b, 128)
        bt = _pick_block(t, block_t)
        p_tm, pon, poff = _pallas_pad(p, p_on, p_off, block_b, bt)
        g_tm = jnp.pad(g.astype(jnp.float32).T,
                       ((0, (-t) % bt), (0, (-b) % block_b)))
        itau = jnp.asarray(inv_tau, jnp.float32).reshape(1)
        carr = jnp.pad(carries.astype(jnp.float32).T,
                       ((0, 0), (0, (-b) % block_b)))
        dp_tm, sums = _pallas_bwd(p_tm, g_tm, pon, poff, itau, carr,
                                  block_b=block_b, block_t=bt, t_total=t,
                                  interpret=interpret)
        d_p = dp_tm[:t, :b].T.astype(p.dtype)
        d_on = sums[0, :b].astype(p.dtype)
        d_off = sums[1, :b].astype(p.dtype)
        d_it = jnp.sum(sums[2, :b]).astype(p.dtype)
    else:
        d_p, d_on, d_off, d_it = _xla_bwd(p, p_on, p_off, inv_tau,
                                          carries, g, block_t)
    d_tau = (-inv_tau ** 2 * d_it).astype(jnp.result_type(tau))
    return d_p, d_on, d_off, d_tau


_soft_state.defvjp(_soft_state_fwd, _soft_state_bwd)


def _auto_pallas(use_pallas: Optional[bool]) -> bool:
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return use_pallas


def soft_state_fused(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                     *, tau, block_t: int = DEFAULT_BLOCK_T,
                     use_pallas: Optional[bool] = None,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Drop-in replacement for `soft_scan.soft_state` with a fused,
    checkpointed VJP.

    Same contract (prices [B, T]; p_on/p_off [B] broadcastable; initial
    state 1) and the same forward values up to summation order; the
    backward saves only per-block carries and rematerialises gates
    block-locally, so an Adam step's residual footprint drops from
    O(B*T) affine intermediates to O(B*T/block_t). ``use_pallas=None``
    auto-selects the TPU kernel pair on TPU and the blocked XLA form
    elsewhere (the Pallas interpreter is a debugging tool, not a fast
    path). Differentiable in all of (prices, p_on, p_off, tau).
    """
    p = jnp.asarray(prices)
    dtype = p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32
    p = p.astype(dtype)
    b = p.shape[0]
    p_on = jnp.broadcast_to(jnp.asarray(p_on, dtype), (b,))
    p_off = jnp.broadcast_to(jnp.asarray(p_off, dtype), (b,))
    tau = jnp.asarray(tau, dtype)
    use_pallas = _auto_pallas(use_pallas)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _soft_state(p, p_on, p_off, tau, int(block_t), use_pallas,
                       bool(interpret))
