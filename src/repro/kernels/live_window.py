"""Horizon-windowed variants of the fleet/dispatch inner steps.

The offline engines scan a *known* [T]-hour trace once. The live
operator (`repro.live`) instead re-plans every simulated hour over an
H-hour *forecast* window, then commits only the first hour — so it
needs the same per-hour math (`hard_hour_step`, `dispatch_alloc_hour`,
both shared verbatim with the offline kernels) orchestrated as short
in-jit window scans that start from a carried state and run entirely on
forecast data.

These are pure-JAX (no new Pallas kernels): the windows are tens of
hours, the outer live loop is already one jitted `lax.scan`, and the
hot-path property the repo benchmarks is the jitted batched outer loop
vs a per-hour Python re-plan (`benchmarks/bench_live.py`) — not an
inner-window kernel. The segment sort moves in-jit here
(`segment_keys_jnp`/`segment_rank_jnp`) because forecast prices only
exist inside the scan; ordering is invariant to the span constant as
long as it exceeds the price span plus the fee, so any host-side
``span`` upper bound over the full trace keeps the in-jit order
identical to the host `repro.dispatch.segment_rank` order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ref import dispatch_alloc_hour, hard_hour_step


def segment_keys_jnp(p_t, migrate_cost, span):
    """In-jit mirror of `repro.dispatch.segment_keys` for one hour:
    ``p_t [..., S] -> keys [..., 3S]`` (locked below everything by
    ``span``, retained at ``p - migrate_cost``, fresh at ``p``).
    ``span`` must exceed the *global* price span plus ``|migrate_cost|``
    (host-computed once over the full trace); the key ordering — the
    only thing the fill consumes — is then independent of its value."""
    return jnp.concatenate([p_t - span, p_t - migrate_cost, p_t], axis=-1)


def segment_rank_jnp(keys):
    """Ascending sort permutation and its inverse of one hour's segment
    keys (in-jit counterpart of `repro.dispatch.segment_rank`). JAX's
    argsort is stable, so ties resolve by segment position exactly like
    the host path."""
    order = jnp.argsort(keys, axis=-1).astype(jnp.int32)
    return order, jnp.argsort(order, axis=-1).astype(jnp.int32)


def plan_on_window(on0, prices_w, p_on, p_off, off_level, idle_frac):
    """Roll the hard shutdown state machine over an H-hour (forecast)
    window from the carried state ``on0`` — the windowed variant of the
    `fleet_scan_ref` inner step, elementwise over any leading batch.

    prices_w: [..., H]; on0 and the policy fields broadcast against its
    leading shape. Returns ``(on_last, cap_w, draw_w)`` with cap/draw
    shaped like ``prices_w`` — the planned capacity trajectory a
    dispatch plan prices against.
    """
    def step(on, p_t):
        on, _, cap, draw = hard_hour_step(on, p_t, p_on, p_off,
                                          off_level, idle_frac)
        return on, (cap, draw)

    on_last, (cap_w, draw_w) = jax.lax.scan(
        step, on0, jnp.moveaxis(prices_w, -1, 0))
    return (on_last, jnp.moveaxis(cap_w, 0, -1),
            jnp.moveaxis(draw_w, 0, -1))


def dispatch_window(prev, dwell, avail_w, keys_w, demand_w, *,
                    min_dwell: int):
    """Greedy water-fill over an H-hour window from a carried dispatch
    state — the windowed variant of the `dispatch_ref` scan, built on
    the same `dispatch_alloc_hour` (so an H=1 window with a fresh carry
    is exactly one offline fill hour; pinned in tests/test_live.py).

    prev/dwell: [S] carried allocation and dwell locks entering the
    window; avail_w: [S, H]; keys_w: [H, 3S] (from `segment_keys_jnp`
    on forecast prices); demand_w: [H]. Returns ``(alloc_w [S, H],
    prev', dwell')`` — the planned allocation and the state the *next*
    window would start from if the whole plan were executed.
    """
    def step(carry, inp):
        prev, dwell = carry
        a_t, k_t, d_t = inp
        order, rank = segment_rank_jnp(k_t)
        alloc, dwell = dispatch_alloc_hour(prev, dwell, a_t, order, rank,
                                           d_t, min_dwell=min_dwell)
        return (alloc, dwell), alloc

    (prev, dwell), alloc_h = jax.lax.scan(
        step, (prev, dwell), (avail_w.T, keys_w, demand_w))
    return alloc_h.T, prev, dwell
