"""Batched stateful threshold/hysteresis scan as a Pallas TPU kernel.

The fleet backtesting engine needs, for B = N x M x K scenario rows and a
[B, T] price block, four per-row sums (see `repro.kernels.ref.FleetScanOut`)
driven by a per-row two-threshold state machine. A naive formulation is a
sequential scan over T — hostile to the VPU. The kernel instead removes the
time recurrence *inside* each block with a last-decisive-event trick:

    on_t  = 0 if p_t > p_off, 1 if p_t <= p_on, else on_{t-1}

is "state of the most recent decisive sample". Encoding each decisive
sample as ev_t = 2 t + on_t (on/off are mutually exclusive since
p_on <= p_off) and taking a running max over time yields, per element, the
index *and* decision of the latest event in one `cummax` — no serial loop;
samples before the first event inherit the carry from the previous block.

Layout: time-major [T, B] blocks (rows ride the 128-lane axis, the running
max runs along sublanes). Grid = (n_row_blocks, n_time_blocks) with time
innermost, so the on/off carry and the four accumulators live in VMEM
scratch across time blocks — zero HBM round-trips for state, exactly the
pattern of `ssd_scan.py`. Padding in T is masked in-kernel against the true
length; padding in B is sliced off by the wrapper.

Validated in interpret mode against `repro.kernels.ref.fleet_scan_ref`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import FleetScanOut


def _fleet_kernel(p_ref, pon_ref, poff_ref, lvl_ref, idle_ref,   # inputs
                  out_ref,                                       # [4, bb]
                  state_scr, acc_scr,                            # scratch
                  *, block_t: int, n_t_blocks: int, t_total: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state_scr[...] = jnp.ones_like(state_scr)   # start running
        acc_scr[...] = jnp.zeros_like(acc_scr)

    p = p_ref[...].astype(jnp.float32)              # [bt, bb] time-major
    pon = pon_ref[...]                              # [bb]
    poff = poff_ref[...]
    lvl = lvl_ref[...]
    idle = idle_ref[...]

    tloc = jax.lax.broadcasted_iota(jnp.int32, p.shape, 0)
    valid = (ti * block_t + tloc) < t_total         # [bt, bb] T-padding mask

    on_ev = (p <= pon[None, :]) & valid
    off_ev = (p > poff[None, :]) & valid
    # ev = 2t for an off event, 2t+1 for an on event, -1 otherwise; the
    # running max is then the latest decisive event and its low bit the
    # state it imposed.
    ev = jnp.where(on_ev | off_ev,
                   2 * tloc + on_ev.astype(jnp.int32), -1)
    last = jax.lax.cummax(ev, axis=0)               # [bt, bb]

    carry = state_scr[...]                          # [bb] in {0, 1}
    on = jnp.where(last >= 0, (last & 1).astype(jnp.float32),
                   carry[None, :])                  # [bt, bb]
    on_prev = jnp.concatenate([carry[None, :], on[:-1]], axis=0)
    starts = jnp.maximum(on - on_prev, 0.0)         # only at valid samples

    vf = valid.astype(jnp.float32)
    cap = lvl[None, :] + (1.0 - lvl[None, :]) * on
    draw = cap + idle[None, :] * (1.0 - cap)
    acc_scr[0, :] += jnp.sum(draw * p * vf, axis=0)
    acc_scr[1, :] += jnp.sum(cap * vf, axis=0)
    acc_scr[2, :] += jnp.sum(starts, axis=0)
    acc_scr[3, :] += jnp.sum(starts * p, axis=0)
    # events on invalid samples are masked, so on[-1] is the state at the
    # last valid sample even in a partially (or fully) padded block.
    state_scr[...] = on[-1]

    @pl.when(ti == n_t_blocks - 1)
    def _finish():
        out_ref[...] = acc_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_t", "t_total",
                                    "interpret"))
def _fleet_scan_padded(p_tm: jax.Array, pon: jax.Array, poff: jax.Array,
                       lvl: jax.Array, idle: jax.Array, *,
                       block_b: int, block_t: int, t_total: int,
                       interpret: bool) -> jax.Array:
    """Core pallas_call over padded, time-major inputs.

    p_tm: [T*, B*] (block multiples); params: [B*]. Returns [4, B*].
    """
    t_pad, b_pad = p_tm.shape
    nb, nt = b_pad // block_b, t_pad // block_t

    kernel = functools.partial(_fleet_kernel, block_t=block_t,
                               n_t_blocks=nt, t_total=t_total)
    return pl.pallas_call(
        kernel,
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((block_t, block_b), lambda bi, ti: (ti, bi)),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, ti: (bi,)),
        ],
        out_specs=pl.BlockSpec((4, block_b), lambda bi, ti: (0, bi)),
        out_shape=jax.ShapeDtypeStruct((4, b_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b,), jnp.float32),
                        pltpu.VMEM((4, block_b), jnp.float32)],
        interpret=interpret,
    )(p_tm, pon, poff, lvl, idle)


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pick_block(n: int, cap: int) -> int:
    """Largest 128-multiple <= min(cap, n), or n itself for small n."""
    cap = max(min(cap, n), 1)
    return (cap // 128) * 128 if cap >= 128 else cap


def fleet_scan(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
               off_level: jax.Array, idle_frac: jax.Array, *,
               block_b: int = 128, block_t: int = 512,
               interpret: Optional[bool] = None) -> FleetScanOut:
    """Batched hysteresis scan. prices: [B, T]; params: [B] (broadcastable).

    Same contract as `repro.kernels.ref.fleet_scan_ref`, which requires
    ``p_on <= p_off`` (the event encoding gives "on" precedence inside an
    inverted band, the reference gives "off" — `repro.fleet.grid`
    validates this). This is the hot inner loop of
    `repro.fleet.engine.backtest`.
    """
    p = jnp.asarray(prices, jnp.float32)
    b, t = p.shape
    block_b = _pick_block(b, block_b)
    block_t = _pick_block(t, block_t)
    pad_b = (-b) % block_b
    pad_t = (-t) % block_t

    p_tm = jnp.pad(p.T, ((0, pad_t), (0, pad_b)))    # [T*, B*] time-major
    def _param(v):
        return jnp.pad(jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,)),
                       (0, pad_b))
    out = _fleet_scan_padded(
        p_tm, _param(p_on), _param(p_off), _param(off_level),
        _param(idle_frac), block_b=block_b, block_t=block_t, t_total=t,
        interpret=_auto_interpret(interpret))
    return FleetScanOut(out[0, :b], out[1, :b], out[2, :b], out[3, :b])
