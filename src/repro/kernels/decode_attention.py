"""Single-token (decode) attention over a KV cache as a Pallas TPU kernel.

Decode attention is memory-bound: one query token per sequence reads the
whole [W, G, Dh] cache. The kernel streams KV blocks through VMEM with an
online-softmax carry (same sequential-grid pattern as flash_attention), but
the q block is the *GQA group* — all R = H/G query heads that share one KV
head are processed together, turning R separate [1, Dh] @ [Dh, bkv] GEMVs
into one [R, bkv] matmul. With R = 5..8 on the assigned GQA configs this is
the difference between wasting 127/128 MXU rows and wasting (128-R)/128 —
and it amortises each KV byte over R heads, which matters more: the roofline
for decode is HBM bandwidth, and bytes/step ~ cache size / R per head.

Validity is slot-based (``pos_buf`` semantics from the model's AttnCache):
a mask row [W] accompanies the cache, so rolling (sliding-window) caches and
linear caches use the same kernel.

Validated in interpret mode against `repro.kernels.ref.decode_attention_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr,
                   *, scale: float, bkv: int, n_kv: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                # [R, dh]
    k = k_ref[0, 0].astype(jnp.float32)                # [bkv, dh]
    v = v_ref[0, 0]                                    # [bkv, dh]
    valid = valid_ref[0] != 0                          # [bkv] int8 -> bool

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, :], s, NEG_INF)          # [R, bkv]

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot(p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def decode_attention_bgrd(q: jax.Array, k: jax.Array, v: jax.Array,
                          valid: jax.Array, *, bkv: int,
                          interpret: bool) -> jax.Array:
    """Core pallas_call. q: [B,G,R,Dh]; k,v: [B,G,W,Dh] (W a multiple of
    ``bkv``); valid: [B,W] int8. Returns [B,G,R,Dh]."""
    b, g, r, dh = q.shape
    w = k.shape[2]
    n_kv = w // bkv

    kernel = functools.partial(_decode_kernel, scale=dh ** -0.5,
                               bkv=bkv, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(b, g, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, r, dh), lambda b_, g_, j: (b_, g_, 0, 0)),
            pl.BlockSpec((1, 1, bkv, dh), lambda b_, g_, j: (b_, g_, j, 0)),
            pl.BlockSpec((1, 1, bkv, dh), lambda b_, g_, j: (b_, g_, j, 0)),
            pl.BlockSpec((1, bkv), lambda b_, g_, j: (b_, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, r, dh),
                               lambda b_, g_, j: (b_, g_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, r, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((r,), jnp.float32),
            pltpu.VMEM((r,), jnp.float32),
            pltpu.VMEM((r, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
