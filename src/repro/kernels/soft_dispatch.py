"""Differentiable (temperature-relaxed) cross-site dispatch.

`repro.kernels.dispatch_scan` answers "where does the load run under
these schedules?" but its greedy water-fill allocates through argsort
comparisons and clips, so per-site policy parameters can shape the
dispatch only through zero-measure kinks — a site that never receives
load gets no gradient at all. This module relaxes the per-hour greedy
fill with the *entropic* water-fill at temperature ``tau``:

    x_j = w_j sigmoid((lam - key_j) / tau),   sum_j x_j = demand

the unique optimum of  min_x sum key_j x_j + tau * H(x; w)  over the
capacity box — a softmin over the (price − migrate-premium) segment
keys. As tau -> 0 the sigmoids harden into the exact greedy clip-fill
(`repro.kernels.ref.dispatch_alloc_hour`), and for tau > 0 every
segment carries allocation mass proportional to how close its key sits
to the water level, so gradients see *all* sites — the signal that lets
`repro.tune` teach each site its fleet role (the swing-site effect).
Dwell locks are discounted smoothly (lock strength ``min(dwell, 1)``,
sigmoid fresh-placement reset at a co-annealed MW temperature), so the
hour-to-hour recurrence stays differentiable end to end.

The water level ``lam`` has no closed form; it is found by fixed-count
bisection seeded from the *hard* water level — which the
host-precomputed `repro.dispatch.segment_rank` sort yields in O(S) —
under ``stop_gradient``, with one differentiable Newton step providing
the exact first-order implicit gradient (`repro.kernels.ref.
soft_water_level`). Per-hour math is `repro.kernels.ref.
soft_dispatch_hour`, shared *verbatim* with the sequential
`soft_dispatch_ref` oracle, so kernel and reference are bit-identical.

Layout mirrors `dispatch_scan`: off-TPU the public entry point runs the
jitted sequential-in-time `lax.scan` form (dtype-following, so float64
FD gradient checks are exact); on TPU a Pallas kernel with grid =
(n_time_blocks,), time innermost, [block_t, S] time-major blocks and
the (prev alloc, dwell) carry in VMEM scratch — zero HBM round-trips
for state. T-padding needs no masking: padded hours carry zero demand,
and the renormalised fill is exactly zero there. Validated in
interpret mode against `soft_dispatch_ref`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import (soft_dispatch_hour, soft_dispatch_hour_grad,
                               soft_dispatch_hour_parts, soft_dispatch_ref)


def _soft_dispatch_kernel(a_ref, keys_ref, order_ref, d_ref,  # time-major
                          itau_ref, itaumw_ref,               # (1,) scalars
                          out_ref,                            # [block_t, S]
                          prev_scr, dwell_scr,                # [S] VMEM carry
                          *, block_t: int, min_dwell: int, n_bisect: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        prev_scr[...] = jnp.zeros_like(prev_scr)     # start empty
        dwell_scr[...] = jnp.zeros_like(dwell_scr)

    inv_tau = itau_ref[0]
    inv_tau_mw = itaumw_ref[0]

    def hour(h, carry):
        alloc, dwell = soft_dispatch_hour(
            prev_scr[...], dwell_scr[...], a_ref[h, :], keys_ref[h, :],
            order_ref[h, :], d_ref[h], inv_tau=inv_tau,
            inv_tau_mw=inv_tau_mw, min_dwell=min_dwell,
            n_bisect=n_bisect)
        out_ref[h, :] = alloc
        prev_scr[...] = alloc
        dwell_scr[...] = dwell
        return carry

    jax.lax.fori_loop(0, block_t, hour, 0)


@functools.partial(jax.jit, static_argnames=("block_t", "min_dwell",
                                             "n_bisect", "interpret"))
def _soft_dispatch_padded(a_tm: jax.Array, keys: jax.Array,
                          order: jax.Array, demand: jax.Array,
                          itau: jax.Array, itaumw: jax.Array, *,
                          block_t: int, min_dwell: int, n_bisect: int,
                          interpret: bool) -> jax.Array:
    """Core pallas_call over padded, time-major inputs.

    a_tm: [T*, S]; keys/order: [T*, 3S]; demand: [T*]; itau/itaumw:
    (1,) (T* a block_t multiple). Returns the allocation [T*, S].
    """
    t_pad, s = a_tm.shape
    nt = t_pad // block_t

    kernel = functools.partial(_soft_dispatch_kernel, block_t=block_t,
                               min_dwell=min_dwell, n_bisect=n_bisect)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t,), lambda ti: (ti,)),
            pl.BlockSpec((1,), lambda ti: (0,)),
            pl.BlockSpec((1,), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, s), jnp.float32),
        scratch_shapes=[pltpu.VMEM((s,), jnp.float32),
                        pltpu.VMEM((s,), jnp.float32)],
        interpret=interpret,
    )(a_tm, keys, order, demand, itau, itaumw)


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def soft_dispatch_pallas(avail: jax.Array, keys: jax.Array,
                         order: jax.Array, demand: jax.Array, *,
                         tau, min_dwell: int = 0, mw_scale: float = 0.05,
                         n_bisect: int = 30, block_t: int = 512,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Pallas form of the soft dispatch scan (f32; forward only — the
    differentiable path is the XLA scan in `soft_dispatch`). Same
    contract as `repro.kernels.ref.soft_dispatch_ref`; bit-identical to
    it (asserted in `tests/test_soft_dispatch.py`)."""
    a = jnp.asarray(avail, jnp.float32)
    s, t = a.shape
    block_t = max(min(block_t, t), 1)
    pad_t = (-t) % block_t

    a_tm = jnp.pad(a.T, ((0, pad_t), (0, 0)))        # [T*, S] time-major
    keys_p = jnp.pad(jnp.asarray(keys, jnp.float32), ((0, pad_t), (0, 0)))
    order_p = jnp.pad(jnp.asarray(order, jnp.int32), ((0, pad_t), (0, 0)))
    d_p = jnp.pad(jnp.asarray(demand, jnp.float32), (0, pad_t))
    itau = (1.0 / jnp.asarray(tau, jnp.float32)).reshape(1)
    itaumw = itau / jnp.float32(mw_scale)
    out = _soft_dispatch_padded(a_tm, keys_p, order_p, d_p, itau, itaumw,
                                block_t=block_t, min_dwell=int(min_dwell),
                                n_bisect=int(n_bisect),
                                interpret=_auto_interpret(interpret))
    return out[:t].T


# ---------------------------------------------------------------------------
# Fused custom VJP: slim residuals (alloc, entering dwell, bisected level)
# instead of native autodiff's per-hour intermediate stash, and a backward
# that never re-runs the bisection or the sort walk — the per-hour adjoint
# is `repro.kernels.ref.soft_dispatch_hour_grad`, the exact `jax.vjp`
# transpose of the shared fixed-level hour, so fused gradients match
# native autodiff to float round-off (and `soft_dispatch_grad_ref`
# anchors both). Structure mirrors `repro.kernels.soft_scan_vjp`: an XLA
# scan pair off-TPU, a Pallas kernel pair (time-innermost grid, state
# adjoints in VMEM scratch, reversed block index maps in the backward) on
# TPU, selected by the same `use_pallas` / `interpret` knobs.
# ---------------------------------------------------------------------------


def _xla_fused_fwd(a, keys, order, demand, inv_tau, inv_tau_mw, *,
                   min_dwell: int, n_bisect: int):
    """Forward scan that also emits the VJP residuals.

    Returns ``(alloc [S, T], dwell_in [S, T], lam_hat [T])`` where
    ``dwell_in`` is each hour's *entering* dwell state (the prev-alloc
    entering state needs no residual — it is the output shifted by one
    hour) and ``lam_hat`` the stop-gradded bisection solution the
    backward replays the Newton correction from.
    """
    s = a.shape[0]

    def step(carry, inp):
        prev, dwell = carry
        a_t, k_t, o_t, d_t = inp
        alloc, dwell2, lam_hat = soft_dispatch_hour_parts(
            prev, dwell, a_t, k_t, o_t, d_t, inv_tau=inv_tau,
            inv_tau_mw=inv_tau_mw, min_dwell=min_dwell,
            n_bisect=n_bisect)
        return (alloc, dwell2), (alloc, dwell, lam_hat)

    zeros = jnp.zeros((s,), a.dtype)
    _, (alloc_t, dwin_t, lam_t) = jax.lax.scan(
        step, (zeros, zeros), (a.T, keys, order, demand))
    return alloc_t.T, dwin_t.T, lam_t


def _xla_fused_bwd(a, keys, demand, inv_tau, inv_tau_mw, alloc, dwin,
                   lam, g, *, min_dwell: int):
    """Reverse scan carrying the (prev alloc, dwell) state adjoints.

    Linear in ``g``, so the zero cotangents of padded hours contribute
    exact zeros — same no-masking contract as the forward. Returns
    ``(d_avail, d_keys, d_demand, sum d_inv_tau, sum d_inv_tau_mw)``.
    """
    s = a.shape[0]
    prev = jnp.concatenate([jnp.zeros_like(alloc[:, :1]),
                            alloc[:, :-1]], axis=1)

    def step(carry, inp):
        u_prev, u_dwell, acc_it, acc_itm = carry
        p_t, dw_t, lam_t, a_t, k_t, d_t, g_t = inp
        d_p, d_dw, d_av, d_ke, d_de, d_it, d_itm = \
            soft_dispatch_hour_grad(p_t, dw_t, a_t, k_t, d_t, lam_t,
                                    inv_tau, inv_tau_mw, g_t + u_prev,
                                    u_dwell, min_dwell=min_dwell)
        return (d_p, d_dw, acc_it + d_it, acc_itm + d_itm), \
            (d_av, d_ke, d_de)

    zeros = jnp.zeros((s,), a.dtype)
    zero = jnp.zeros((), a.dtype)
    (_, _, acc_it, acc_itm), (d_av, d_ke, d_de) = jax.lax.scan(
        step, (zeros, zeros, zero, zero),
        (prev.T, dwin.T, lam, a.T, keys, demand, g.T), reverse=True)
    return d_av.T, d_ke, d_de, acc_it, acc_itm


def _fused_fwd_kernel(a_ref, keys_ref, order_ref, d_ref,      # time-major
                      itau_ref, itaumw_ref,                   # (1,) scalars
                      out_ref, dwin_ref, lam_ref,             # residuals out
                      prev_scr, dwell_scr,                    # [S] VMEM carry
                      *, block_t: int, min_dwell: int, n_bisect: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        prev_scr[...] = jnp.zeros_like(prev_scr)
        dwell_scr[...] = jnp.zeros_like(dwell_scr)

    inv_tau = itau_ref[0]
    inv_tau_mw = itaumw_ref[0]

    def hour(h, carry):
        dwin_ref[h, :] = dwell_scr[...]              # entering dwell
        alloc, dwell, lam_hat = soft_dispatch_hour_parts(
            prev_scr[...], dwell_scr[...], a_ref[h, :], keys_ref[h, :],
            order_ref[h, :], d_ref[h], inv_tau=inv_tau,
            inv_tau_mw=inv_tau_mw, min_dwell=min_dwell,
            n_bisect=n_bisect)
        out_ref[h, :] = alloc
        lam_ref[h] = lam_hat
        prev_scr[...] = alloc
        dwell_scr[...] = dwell
        return carry

    jax.lax.fori_loop(0, block_t, hour, 0)


@functools.partial(jax.jit, static_argnames=("block_t", "min_dwell",
                                             "n_bisect", "interpret"))
def _pallas_fused_fwd(a_tm, keys, order, demand, itau, itaumw, *,
                      block_t: int, min_dwell: int, n_bisect: int,
                      interpret: bool):
    """pallas_call of the residual-emitting forward over padded,
    time-major inputs (same layout as `_soft_dispatch_padded`)."""
    t_pad, s = a_tm.shape
    nt = t_pad // block_t

    kernel = functools.partial(_fused_fwd_kernel, block_t=block_t,
                               min_dwell=min_dwell, n_bisect=n_bisect)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t,), lambda ti: (ti,)),
            pl.BlockSpec((1,), lambda ti: (0,)),
            pl.BlockSpec((1,), lambda ti: (0,)),
        ],
        out_specs=[pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
                   pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
                   pl.BlockSpec((block_t,), lambda ti: (ti,))],
        out_shape=[jax.ShapeDtypeStruct((t_pad, s), jnp.float32),
                   jax.ShapeDtypeStruct((t_pad, s), jnp.float32),
                   jax.ShapeDtypeStruct((t_pad,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((s,), jnp.float32),
                        pltpu.VMEM((s,), jnp.float32)],
        interpret=interpret,
    )(a_tm, keys, order, demand, itau, itaumw)


def _fused_bwd_kernel(prev_ref, dwin_ref, lam_ref, a_ref, keys_ref,
                      d_ref, g_ref, itau_ref, itaumw_ref,
                      dav_ref, dke_ref, dde_ref, sums_ref,
                      uprev_scr, udwell_scr, acc_scr,
                      *, block_t: int, min_dwell: int, n_t_blocks: int):
    """One reversed time block of the backward: the index maps walk
    blocks last-to-first, hours run block_t-1 .. 0 inside, and the
    (prev, dwell) adjoints cross block boundaries in VMEM scratch. The
    two tau-chain accumulators ride along in scratch and are emitted
    once, from the final (earliest-time) block."""
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        uprev_scr[...] = jnp.zeros_like(uprev_scr)
        udwell_scr[...] = jnp.zeros_like(udwell_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    inv_tau = itau_ref[0]
    inv_tau_mw = itaumw_ref[0]

    def hour(i, carry):
        h = block_t - 1 - i
        d_p, d_dw, d_av, d_ke, d_de, d_it, d_itm = \
            soft_dispatch_hour_grad(
                prev_ref[h, :], dwin_ref[h, :], a_ref[h, :],
                keys_ref[h, :], d_ref[h], lam_ref[h], inv_tau,
                inv_tau_mw, g_ref[h, :] + uprev_scr[...],
                udwell_scr[...], min_dwell=min_dwell)
        dav_ref[h, :] = d_av
        dke_ref[h, :] = d_ke
        dde_ref[h] = d_de
        uprev_scr[...] = d_p
        udwell_scr[...] = d_dw
        acc_scr[0] = acc_scr[0] + d_it
        acc_scr[1] = acc_scr[1] + d_itm
        return carry

    jax.lax.fori_loop(0, block_t, hour, 0)

    @pl.when(ti == n_t_blocks - 1)
    def _emit():
        sums_ref[...] = acc_scr[...]


@functools.partial(jax.jit, static_argnames=("block_t", "min_dwell",
                                             "interpret"))
def _pallas_fused_bwd(prev_tm, dwin_tm, lam, a_tm, keys, demand, g_tm,
                      itau, itaumw, *, block_t: int, min_dwell: int,
                      interpret: bool):
    t_pad, s = a_tm.shape
    nt = t_pad // block_t
    rev2 = lambda ti: (nt - 1 - ti, 0)          # noqa: E731
    rev1 = lambda ti: (nt - 1 - ti,)            # noqa: E731

    kernel = functools.partial(_fused_bwd_kernel, block_t=block_t,
                               min_dwell=min_dwell, n_t_blocks=nt)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_t, s), rev2),
            pl.BlockSpec((block_t, s), rev2),
            pl.BlockSpec((block_t,), rev1),
            pl.BlockSpec((block_t, s), rev2),
            pl.BlockSpec((block_t, 3 * s), rev2),
            pl.BlockSpec((block_t,), rev1),
            pl.BlockSpec((block_t, s), rev2),
            pl.BlockSpec((1,), lambda ti: (0,)),
            pl.BlockSpec((1,), lambda ti: (0,)),
        ],
        out_specs=[pl.BlockSpec((block_t, s), rev2),
                   pl.BlockSpec((block_t, 3 * s), rev2),
                   pl.BlockSpec((block_t,), rev1),
                   pl.BlockSpec((2,), lambda ti: (0,))],
        out_shape=[jax.ShapeDtypeStruct((t_pad, s), jnp.float32),
                   jax.ShapeDtypeStruct((t_pad, 3 * s), jnp.float32),
                   jax.ShapeDtypeStruct((t_pad,), jnp.float32),
                   jax.ShapeDtypeStruct((2,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((s,), jnp.float32),
                        pltpu.VMEM((s,), jnp.float32),
                        pltpu.VMEM((2,), jnp.float32)],
        interpret=interpret,
    )(prev_tm, dwin_tm, lam, a_tm, keys, demand, g_tm, itau, itaumw)


def _pallas_pad(x, pad_t, val=0.0):
    # The backward pads avail/demand with ones, not zeros: an all-zero
    # hour makes the fill renorm divide by the 1e-30 floor, whose
    # square underflows to 0 in f32 and turns the division transpose
    # into 0/0 — NaN even under the padded hours' all-zero cotangents.
    # A well-conditioned dummy hour keeps the padded adjoints exactly
    # zero instead (the VJP is linear in the cotangents).
    return jnp.pad(jnp.asarray(x, jnp.float32),
                   ((0, pad_t),) + ((0, 0),) * (x.ndim - 1),
                   constant_values=val)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def _soft_dispatch_fused(avail, keys, order, demand, tau, min_dwell,
                         mw_scale, n_bisect, block_t, use_pallas,
                         interpret):
    alloc, _, _ = _fused_primal(avail, keys, order, demand, tau,
                                min_dwell, mw_scale, n_bisect, block_t,
                                use_pallas, interpret)
    return alloc


def _fused_primal(avail, keys, order, demand, tau, min_dwell, mw_scale,
                  n_bisect, block_t, use_pallas, interpret):
    s, t = avail.shape
    inv_tau = 1.0 / tau
    inv_tau_mw = inv_tau / jnp.asarray(mw_scale, tau.dtype)
    if not use_pallas:
        return _xla_fused_fwd(avail, keys, jnp.asarray(order, jnp.int32),
                              demand, inv_tau, inv_tau_mw,
                              min_dwell=min_dwell, n_bisect=n_bisect)
    bt = max(min(block_t, t), 1)
    pad_t = (-t) % bt
    alloc_tm, dwin_tm, lam = _pallas_fused_fwd(
        _pallas_pad(avail.T, pad_t), _pallas_pad(keys, pad_t),
        jnp.pad(jnp.asarray(order, jnp.int32), ((0, pad_t), (0, 0))),
        _pallas_pad(demand, pad_t),
        jnp.asarray(inv_tau, jnp.float32).reshape(1),
        jnp.asarray(inv_tau_mw, jnp.float32).reshape(1),
        block_t=bt, min_dwell=min_dwell, n_bisect=n_bisect,
        interpret=_auto_interpret(interpret))
    return alloc_tm[:t].T, dwin_tm[:t].T, lam[:t]


def _fused_fwd(avail, keys, order, demand, tau, min_dwell, mw_scale,
               n_bisect, block_t, use_pallas, interpret):
    alloc, dwin, lam = _fused_primal(avail, keys, order, demand, tau,
                                     min_dwell, mw_scale, n_bisect,
                                     block_t, use_pallas, interpret)
    return alloc, (avail, keys, demand, tau, alloc, dwin, lam,
                   np.shape(order))


def _fused_bwd(min_dwell, mw_scale, n_bisect, block_t, use_pallas,
               interpret, res, g):
    avail, keys, demand, tau, alloc, dwin, lam, order_shape = res
    inv_tau = 1.0 / tau
    inv_tau_mw = inv_tau / jnp.asarray(mw_scale, tau.dtype)
    if not use_pallas:
        d_av, d_ke, d_de, acc_it, acc_itm = _xla_fused_bwd(
            avail, keys, demand, inv_tau, inv_tau_mw, alloc, dwin, lam,
            g, min_dwell=min_dwell)
    else:
        s, t = avail.shape
        bt = max(min(block_t, t), 1)
        pad_t = (-t) % bt
        prev = jnp.concatenate([jnp.zeros_like(alloc[:, :1]),
                                alloc[:, :-1]], axis=1)
        d_av_tm, d_ke, d_de, sums = _pallas_fused_bwd(
            _pallas_pad(prev.T, pad_t), _pallas_pad(dwin.T, pad_t),
            _pallas_pad(lam, pad_t), _pallas_pad(avail.T, pad_t, 1.0),
            _pallas_pad(keys, pad_t), _pallas_pad(demand, pad_t, 1.0),
            _pallas_pad(g.T, pad_t),
            jnp.asarray(inv_tau, jnp.float32).reshape(1),
            jnp.asarray(inv_tau_mw, jnp.float32).reshape(1),
            block_t=bt, min_dwell=min_dwell,
            interpret=_auto_interpret(interpret))
        d_av = d_av_tm[:t].T.astype(avail.dtype)
        d_ke = d_ke[:t].astype(keys.dtype)
        d_de = d_de[:t].astype(demand.dtype)
        acc_it, acc_itm = sums[0], sums[1]
    # tau -> (inv_tau, inv_tau_mw) chain (see soft_dispatch_grad_ref)
    d_tau = (-(inv_tau ** 2) * acc_it
             - inv_tau * inv_tau_mw * acc_itm).astype(tau.dtype)
    d_order = np.zeros(order_shape, jax.dtypes.float0)
    return d_av, d_ke, d_order, d_de, d_tau


_soft_dispatch_fused.defvjp(_fused_fwd, _fused_bwd)


def soft_dispatch_fused(avail: jax.Array, keys: jax.Array,
                        order: jax.Array, demand: jax.Array, *, tau,
                        min_dwell: int = 0, mw_scale: float = 0.05,
                        n_bisect: int = 30, block_t: int = 512,
                        use_pallas: Optional[bool] = None,
                        interpret: Optional[bool] = None) -> jax.Array:
    """`soft_dispatch` under the fused custom VJP: same allocation (the
    forward runs the same per-hour math), same gradients to float
    round-off, but the backward replays the Newton correction from
    saved ``lam_hat`` residuals instead of transposing through the
    stashed intermediates of the native scan — no bisection, no sort
    walk, O(S·T) residual memory. Dtype-following off-TPU (the f64 FD
    checks run through here); the Pallas pair is f32.
    """
    a = jnp.asarray(avail)
    dtype = a.dtype if jnp.issubdtype(a.dtype, jnp.floating) \
        else jnp.float32
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        dtype = jnp.float32
    return _soft_dispatch_fused(
        a.astype(dtype), jnp.asarray(keys, dtype),
        jnp.asarray(order, jnp.int32), jnp.asarray(demand, dtype),
        jnp.asarray(tau, dtype), int(min_dwell), float(mw_scale),
        int(n_bisect), int(block_t), bool(use_pallas), interpret)


_soft_dispatch_ref_jit = jax.jit(
    soft_dispatch_ref, static_argnames=("min_dwell", "n_bisect"))


def soft_dispatch(avail: jax.Array, keys: jax.Array, order: jax.Array,
                  demand: jax.Array, *, tau, min_dwell: int = 0,
                  mw_scale: float = 0.05, n_bisect: int = 30,
                  block_t: int = 512,
                  use_pallas: Optional[bool] = None,
                  fused: bool = False) -> jax.Array:
    """Differentiable fleet dispatch allocation at temperature ``tau``.

    avail: [S, T] MW; keys/order: [T, 3S] precomputed segment keys and
    sort (`repro.dispatch.segment_keys` / `segment_rank`); demand: [T]
    MW. Returns the relaxed allocation [S, T], converging to
    `repro.kernels.ref.dispatch_ref` as tau -> 0.

    ``use_pallas=None`` auto-selects like `repro.dispatch.dispatch`:
    the Pallas kernel on TPU, the jitted sequential scan elsewhere.
    Called *inside* a jit (the tuner's soft objective) it traces the
    scan form directly, which is the path gradients flow through.

    ``fused=True`` routes through `soft_dispatch_fused` — the same
    allocation under the custom VJP, whose backward replays from slim
    residuals instead of transposing the native scan (the fast path
    for dispatch-aware tuning).
    """
    if fused:
        return soft_dispatch_fused(avail, keys, order, demand, tau=tau,
                                   min_dwell=min_dwell,
                                   mw_scale=mw_scale, n_bisect=n_bisect,
                                   block_t=block_t,
                                   use_pallas=use_pallas)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return soft_dispatch_pallas(avail, keys, order, demand, tau=tau,
                                    min_dwell=min_dwell,
                                    mw_scale=mw_scale, n_bisect=n_bisect,
                                    block_t=block_t)
    return _soft_dispatch_ref_jit(avail, keys, order, demand, tau=tau,
                                  min_dwell=min_dwell, mw_scale=mw_scale,
                                  n_bisect=n_bisect)


def soft_shed(avail_total: jax.Array, demand: jax.Array, tau, *,
              mw_scale: float = 0.05) -> jax.Array:
    """Smoothed per-hour shed: how much of ``demand`` [T] exceeds the
    fleet's total availability ``avail_total`` [T], relaxed at the same
    MW-space temperature the water-fill uses (``tau * mw_scale`` — the
    scale `soft_dispatch` applies to every MW sigmoid, so shed and
    allocation co-anneal).

        shed_t = w * softplus((demand_t - avail_total_t) / w),
        w = max(tau * mw_scale, 1e-9)

    converging to ``relu(demand - avail_total)`` — the exact shortfall
    the hard dispatcher sheds under `repro.dispatch.Relief` — as
    tau -> 0. Smooth everywhere, so gradients see the VoLL price of an
    *approaching* infeasibility before the hard boundary is crossed."""
    d = jnp.asarray(demand)
    w = jnp.maximum(jnp.asarray(tau, d.dtype) * d.dtype.type(mw_scale),
                    d.dtype.type(1e-9))
    return w * jax.nn.softplus((d - avail_total) / w)
