"""Differentiable (temperature-relaxed) cross-site dispatch.

`repro.kernels.dispatch_scan` answers "where does the load run under
these schedules?" but its greedy water-fill allocates through argsort
comparisons and clips, so per-site policy parameters can shape the
dispatch only through zero-measure kinks — a site that never receives
load gets no gradient at all. This module relaxes the per-hour greedy
fill with the *entropic* water-fill at temperature ``tau``:

    x_j = w_j sigmoid((lam - key_j) / tau),   sum_j x_j = demand

the unique optimum of  min_x sum key_j x_j + tau * H(x; w)  over the
capacity box — a softmin over the (price − migrate-premium) segment
keys. As tau -> 0 the sigmoids harden into the exact greedy clip-fill
(`repro.kernels.ref.dispatch_alloc_hour`), and for tau > 0 every
segment carries allocation mass proportional to how close its key sits
to the water level, so gradients see *all* sites — the signal that lets
`repro.tune` teach each site its fleet role (the swing-site effect).
Dwell locks are discounted smoothly (lock strength ``min(dwell, 1)``,
sigmoid fresh-placement reset at a co-annealed MW temperature), so the
hour-to-hour recurrence stays differentiable end to end.

The water level ``lam`` has no closed form; it is found by fixed-count
bisection seeded from the *hard* water level — which the
host-precomputed `repro.dispatch.segment_rank` sort yields in O(S) —
under ``stop_gradient``, with one differentiable Newton step providing
the exact first-order implicit gradient (`repro.kernels.ref.
soft_water_level`). Per-hour math is `repro.kernels.ref.
soft_dispatch_hour`, shared *verbatim* with the sequential
`soft_dispatch_ref` oracle, so kernel and reference are bit-identical.

Layout mirrors `dispatch_scan`: off-TPU the public entry point runs the
jitted sequential-in-time `lax.scan` form (dtype-following, so float64
FD gradient checks are exact); on TPU a Pallas kernel with grid =
(n_time_blocks,), time innermost, [block_t, S] time-major blocks and
the (prev alloc, dwell) carry in VMEM scratch — zero HBM round-trips
for state. T-padding needs no masking: padded hours carry zero demand,
and the renormalised fill is exactly zero there. Validated in
interpret mode against `soft_dispatch_ref`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import soft_dispatch_hour, soft_dispatch_ref


def _soft_dispatch_kernel(a_ref, keys_ref, order_ref, d_ref,  # time-major
                          itau_ref, itaumw_ref,               # (1,) scalars
                          out_ref,                            # [block_t, S]
                          prev_scr, dwell_scr,                # [S] VMEM carry
                          *, block_t: int, min_dwell: int, n_bisect: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        prev_scr[...] = jnp.zeros_like(prev_scr)     # start empty
        dwell_scr[...] = jnp.zeros_like(dwell_scr)

    inv_tau = itau_ref[0]
    inv_tau_mw = itaumw_ref[0]

    def hour(h, carry):
        alloc, dwell = soft_dispatch_hour(
            prev_scr[...], dwell_scr[...], a_ref[h, :], keys_ref[h, :],
            order_ref[h, :], d_ref[h], inv_tau=inv_tau,
            inv_tau_mw=inv_tau_mw, min_dwell=min_dwell,
            n_bisect=n_bisect)
        out_ref[h, :] = alloc
        prev_scr[...] = alloc
        dwell_scr[...] = dwell
        return carry

    jax.lax.fori_loop(0, block_t, hour, 0)


@functools.partial(jax.jit, static_argnames=("block_t", "min_dwell",
                                             "n_bisect", "interpret"))
def _soft_dispatch_padded(a_tm: jax.Array, keys: jax.Array,
                          order: jax.Array, demand: jax.Array,
                          itau: jax.Array, itaumw: jax.Array, *,
                          block_t: int, min_dwell: int, n_bisect: int,
                          interpret: bool) -> jax.Array:
    """Core pallas_call over padded, time-major inputs.

    a_tm: [T*, S]; keys/order: [T*, 3S]; demand: [T*]; itau/itaumw:
    (1,) (T* a block_t multiple). Returns the allocation [T*, S].
    """
    t_pad, s = a_tm.shape
    nt = t_pad // block_t

    kernel = functools.partial(_soft_dispatch_kernel, block_t=block_t,
                               min_dwell=min_dwell, n_bisect=n_bisect)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, 3 * s), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t,), lambda ti: (ti,)),
            pl.BlockSpec((1,), lambda ti: (0,)),
            pl.BlockSpec((1,), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, s), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, s), jnp.float32),
        scratch_shapes=[pltpu.VMEM((s,), jnp.float32),
                        pltpu.VMEM((s,), jnp.float32)],
        interpret=interpret,
    )(a_tm, keys, order, demand, itau, itaumw)


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def soft_dispatch_pallas(avail: jax.Array, keys: jax.Array,
                         order: jax.Array, demand: jax.Array, *,
                         tau, min_dwell: int = 0, mw_scale: float = 0.05,
                         n_bisect: int = 30, block_t: int = 512,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Pallas form of the soft dispatch scan (f32; forward only — the
    differentiable path is the XLA scan in `soft_dispatch`). Same
    contract as `repro.kernels.ref.soft_dispatch_ref`; bit-identical to
    it (asserted in `tests/test_soft_dispatch.py`)."""
    a = jnp.asarray(avail, jnp.float32)
    s, t = a.shape
    block_t = max(min(block_t, t), 1)
    pad_t = (-t) % block_t

    a_tm = jnp.pad(a.T, ((0, pad_t), (0, 0)))        # [T*, S] time-major
    keys_p = jnp.pad(jnp.asarray(keys, jnp.float32), ((0, pad_t), (0, 0)))
    order_p = jnp.pad(jnp.asarray(order, jnp.int32), ((0, pad_t), (0, 0)))
    d_p = jnp.pad(jnp.asarray(demand, jnp.float32), (0, pad_t))
    itau = (1.0 / jnp.asarray(tau, jnp.float32)).reshape(1)
    itaumw = itau / jnp.float32(mw_scale)
    out = _soft_dispatch_padded(a_tm, keys_p, order_p, d_p, itau, itaumw,
                                block_t=block_t, min_dwell=int(min_dwell),
                                n_bisect=int(n_bisect),
                                interpret=_auto_interpret(interpret))
    return out[:t].T


_soft_dispatch_ref_jit = jax.jit(
    soft_dispatch_ref, static_argnames=("min_dwell", "n_bisect"))


def soft_dispatch(avail: jax.Array, keys: jax.Array, order: jax.Array,
                  demand: jax.Array, *, tau, min_dwell: int = 0,
                  mw_scale: float = 0.05, n_bisect: int = 30,
                  block_t: int = 512,
                  use_pallas: Optional[bool] = None) -> jax.Array:
    """Differentiable fleet dispatch allocation at temperature ``tau``.

    avail: [S, T] MW; keys/order: [T, 3S] precomputed segment keys and
    sort (`repro.dispatch.segment_keys` / `segment_rank`); demand: [T]
    MW. Returns the relaxed allocation [S, T], converging to
    `repro.kernels.ref.dispatch_ref` as tau -> 0.

    ``use_pallas=None`` auto-selects like `repro.dispatch.dispatch`:
    the Pallas kernel on TPU, the jitted sequential scan elsewhere.
    Called *inside* a jit (the tuner's soft objective) it traces the
    scan form directly, which is the path gradients flow through.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return soft_dispatch_pallas(avail, keys, order, demand, tau=tau,
                                    min_dwell=min_dwell,
                                    mw_scale=mw_scale, n_bisect=n_bisect,
                                    block_t=block_t)
    return _soft_dispatch_ref_jit(avail, keys, order, demand, tau=tau,
                                  min_dwell=min_dwell, mw_scale=mw_scale,
                                  n_bisect=n_bisect)
