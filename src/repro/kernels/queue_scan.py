"""In-scan work ledger: bounded deferral queue with deadline aging.

`queue_hour_step` is one hour of the hard ledger — the single source of
the per-hour update, shared by the standalone `queue_scan`, the fused
`workload_fleet_scan` (fleet state machine + ledger in one carry), and
the soft path of `repro.tune.objective.soft_objective` — exactly the
role `hard_hour_step` plays for the shutdown state machine.

The greedy oldest-first fill uses the same parallel-cumsum idiom as
`dispatch_alloc_hour`: line the waiting work up oldest-first with the
hour's arrivals last, take ``clip(cap - older_mass, 0, width)`` per age
bucket, and the fill equals the sequential greedy serve. Work that has
waited past ``deadline`` hours drops; survivors age one hour and
re-queue under the backlog ``bound`` (oldest kept, youngest dropped on
overflow — upstream is most likely to still retry the newest work).

The soft relaxation replaces both clips with `smoothclip` — a softplus
pair whose derivative is the sigmoid drop gate — at an MWh temperature
co-annealed with the tuner's price temperature (``tau_mwh = tau *
QUEUE_MWH_SCALE``, mirroring `_DWELL_CNT_SCALE`). It is exact at zero
width, strictly inside ``(0, w)`` otherwise, and converges to the hard
clip as tau -> 0, so the soft ledger conserves work the same way the
hard one does and FD-gradient checks pass at every temperature.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.ref import FleetScanOut, hard_hour_step

QUEUE_MWH_SCALE = 0.05   # MWh smoothing width per price-unit of tau:
                         # the soft ledger's clip temperature co-anneals
                         # with the tuner's sigmoid temperature, so the
                         # tau -> 0 limit recovers the hard ledger and
                         # the schedule needs no second knob


def smoothclip(z, w, tau):
    """Soft ``clip(z, 0, w)``: ``tau*(softplus(z/tau) -
    softplus((z-w)/tau))``. Exactly 0 at w == 0, strictly in (0, w) for
    w > 0, monotone in z, derivative a sigmoid pair (the drop gate), and
    -> clip(z, 0, w) as tau -> 0."""
    return tau * (jax.nn.softplus(z / tau)
                  - jax.nn.softplus((z - w) / tau))


def queue_hour_step(q, a_t, cap_t, *, bound, tau=None):
    """One hour of the work ledger (hard, or soft when ``tau`` is set).

    q: [..., D] backlog by age (index 0 youngest = arrived last hour,
    D-1 one hour from deadline expiry); a_t/cap_t: [...] arrivals and
    serving capacity in MWh (broadcastable against q's batch shape).
    Returns ``(q_new, served, dropped)`` — served/dropped [...].
    """
    # oldest-first work vector: [q[D-1], ..., q[0], arrivals]
    w = jnp.concatenate([q[..., ::-1], jnp.broadcast_to(
        a_t[..., None], q.shape[:-1] + (1,))], axis=-1)
    excl = jnp.cumsum(w, axis=-1) - w
    room = cap_t[..., None] - excl
    serve = jnp.clip(room, 0.0, w) if tau is None \
        else smoothclip(room, w, tau)
    served = jnp.sum(serve, axis=-1)
    u = w - serve
    aged = u[..., 1:]                     # survivors, still oldest-first
    excl_a = jnp.cumsum(aged, axis=-1) - aged
    keep = jnp.clip(bound - excl_a, 0.0, aged) if tau is None \
        else smoothclip(bound - excl_a, aged, tau)
    dropped = u[..., 0] + jnp.sum(aged - keep, axis=-1)
    return keep[..., ::-1], served, dropped


class QueueScanOut(NamedTuple):
    """Ledger sufficient statistics over the horizon (batch-shaped)."""

    served: jax.Array       # total MWh served
    dropped: jax.Array      # total MWh dropped (expiry + overflow)
    backlog: jax.Array      # MWh-hours deferred (sum of hourly backlog)
    served_cost: jax.Array  # EUR: sum_t served_t * p_t (0 if no prices)
    q_final: jax.Array      # [..., D] end-of-run queue, youngest first


class QueueHourly(NamedTuple):
    """Per-hour ledger series ([..., T] each)."""

    served: jax.Array
    dropped: jax.Array
    backlog: jax.Array


def queue_scan(arrivals, cap, *, deadline: int, bound, tau=None,
               prices=None, hourly: bool = False):
    """Scan the work ledger over the horizon.

    arrivals/cap: [..., T] MWh per hour, mutually broadcastable;
    ``prices`` (optional, broadcastable) prices each served MWh at the
    hour it is *actually* served — deferral pays the price eventually
    paid, which is the whole point of carrying work into cheaper hours.
    ``tau=None`` is the hard ledger; a scalar (traced is fine) runs the
    `smoothclip` relaxation in the capacity dtype (f64 under x64 — FD
    checks rely on it). With ``hourly=True`` returns
    ``(QueueScanOut, QueueHourly)``.
    """
    a = jnp.asarray(arrivals)
    c = jnp.asarray(cap)
    dtype = jnp.result_type(a.dtype, c.dtype)
    if not jnp.issubdtype(dtype, jnp.floating):
        dtype = jnp.float32
    shape = jnp.broadcast_shapes(a.shape, c.shape)
    a = jnp.broadcast_to(a.astype(dtype), shape)
    c = jnp.broadcast_to(c.astype(dtype), shape)
    p = jnp.zeros(shape, dtype) if prices is None \
        else jnp.broadcast_to(jnp.asarray(prices, dtype), shape)
    batch = shape[:-1]
    d = int(deadline)

    def step(carry, xs):
        q, s_acc, d_acc, b_acc, c_acc = carry
        a_t, cap_t, p_t = xs
        q, served, dropped = queue_hour_step(q, a_t, cap_t, bound=bound,
                                             tau=tau)
        bl = jnp.sum(q, axis=-1)
        carry = (q, s_acc + served, d_acc + dropped, b_acc + bl,
                 c_acc + served * p_t)
        ys = (served, dropped, bl) if hourly else None
        return carry, ys

    zeros = jnp.zeros(batch, dtype)
    init = (jnp.zeros(batch + (d,), dtype), zeros, zeros, zeros, zeros)
    (q, served, dropped, backlog, cost), ys = jax.lax.scan(
        step, init, (jnp.moveaxis(a, -1, 0), jnp.moveaxis(c, -1, 0),
                     jnp.moveaxis(p, -1, 0)))
    out = QueueScanOut(served, dropped, backlog, cost, q)
    if hourly:
        return out, QueueHourly(*(jnp.moveaxis(y, 0, -1) for y in ys))
    return out


class WorkloadFleetOut(NamedTuple):
    """Fused fleet + ledger scan output.

    ``fleet`` carries the exact `FleetScanOut` sums of
    `repro.kernels.ref.fleet_scan_ref` (op-for-op the same per-hour
    update — the ledger rides the carry without feeding back, so the
    fleet half stays bit-identical); the ledger stats are [B, G] over
    the G demand draws every row serves.
    """

    fleet: FleetScanOut     # [B] each
    served: jax.Array       # [B, G] MWh
    dropped: jax.Array      # [B, G] MWh
    backlog: jax.Array      # [B, G] MWh-hours deferred
    served_cost: jax.Array  # [B, G] EUR at the hour each MWh is served


class WorkloadHourly(NamedTuple):
    """Per-hour fleet-mean ledger aggregates ([T] each) — the payload of
    the ``workload.hourly`` telemetry drain (mean over rows x draws, so
    only 4T floats cross to the host)."""

    demand_mwh: jax.Array
    served_mwh: jax.Array
    dropped_mwh: jax.Array
    backlog_mwh: jax.Array


def workload_fleet_scan(prices, p_on, p_off, off_level, idle_frac,
                        cap_mwh, demand_mw, dt, *, deadline: int,
                        bound, hourly: bool = False):
    """Fleet shutdown state machine and work ledger in one lax.scan.

    prices: [B, T]; policy params: [B]; ``cap_mwh`` [B] is the MWh one
    fully-on hour serves (power * dt); ``demand_mw`` [G, T] the demand
    draws (MW, converted per-row to MWh via ``dt`` [B]); the queue carry
    is [B, G, deadline]. The fleet accumulators reproduce
    `fleet_scan_ref` exactly — same `hard_hour_step`, same accumulation
    order, f32 — and the ledger serves each draw with the hour's
    *realised* capacity, so shutdown decisions defer or drop real work.
    With ``hourly=True`` returns ``(WorkloadFleetOut, WorkloadHourly)``.
    """
    p = jnp.asarray(prices, jnp.float32)
    b = p.shape[0]
    p_on, p_off, off_level, idle_frac = (
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,))
        for v in (p_on, p_off, off_level, idle_frac))
    dem = jnp.asarray(demand_mw, jnp.float32)
    g = dem.shape[0]
    cap_mwh = jnp.broadcast_to(jnp.asarray(cap_mwh, jnp.float32), (b,))
    dt = jnp.broadcast_to(jnp.asarray(dt, jnp.float32), (b,))
    d = int(deadline)
    bound = jnp.float32(bound)

    def step(carry, xs):
        on_prev, acc, q, qacc = carry
        p_t, a_t = xs
        on, start, cap, draw = hard_hour_step(on_prev, p_t, p_on, p_off,
                                              off_level, idle_frac)
        acc = (acc[0] + draw * p_t, acc[1] + cap,
               acc[2] + start, acc[3] + start * p_t)
        a_bg = dt[:, None] * a_t[None, :]          # [B, G] MWh arriving
        q, served, dropped = queue_hour_step(
            q, a_bg, (cap_mwh * cap)[:, None], bound=bound)
        bl = jnp.sum(q, axis=-1)
        qacc = (qacc[0] + served, qacc[1] + dropped, qacc[2] + bl,
                qacc[3] + served * p_t[:, None])
        ys = (jnp.mean(a_bg), jnp.mean(served), jnp.mean(dropped),
              jnp.mean(bl)) if hourly else None
        return (on, acc, q, qacc), ys

    zeros_b = jnp.zeros((b,), jnp.float32)
    zeros_bg = jnp.zeros((b, g), jnp.float32)
    init = (jnp.ones((b,), jnp.float32),
            (zeros_b, zeros_b, zeros_b, zeros_b),
            jnp.zeros((b, g, d), jnp.float32),
            (zeros_bg, zeros_bg, zeros_bg, zeros_bg))
    (_, acc, _, qacc), ys = jax.lax.scan(step, init, (p.T, dem.T))
    out = WorkloadFleetOut(FleetScanOut(*acc), *qacc)
    if hourly:
        return out, WorkloadHourly(*ys)
    return out
