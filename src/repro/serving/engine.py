"""Price-aware batched serving engine.

Continuous batching over a fixed pool of decode slots: arriving requests
are prefetched (prefill) into free slots; every engine tick runs one
batched `decode_step` for all active slots. The KV cache pool is allocated
once at ``max_seq`` and slots are recycled — the standard
(vLLM-style, TPU-simplified) slot engine, with the cache living as one
stacked pytree so the decode step is a single jit.

Variable capacity for serving (the paper's technique on the inference
side): the *admission width* follows the energy price. At high prices the
engine stops admitting new requests (optionally shrinking to a
``min_slots`` floor for SLO floors, per the paper's §V-B note that
operators may keep a subset up for availability) and drains; at low prices
it runs the full width. The cost meter attributes energy to served tokens,
yielding EUR/1k-tokens — CPC with "compute" = tokens.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, init_cache, prefill
from repro.runtime.accounting import CostMeter


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [prompt_len] int32
    max_new: int
    arrived_h: float = 0.0
    # filled by the engine
    started_h: Optional[float] = None
    done_h: Optional[float] = None
    output: Optional[list] = None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8                  # decode batch width
    min_slots: int = 0              # SLO floor kept during high prices
    max_seq: int = 256
    hours_per_tick: float = 0.02    # simulated market-time per decode tick
    power_mw: float = 0.5
    fixed_cost_per_hour: float = 80.0


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 scheduler=None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.scheduler = scheduler   # EnergyAwareScheduler or None
        self.meter = CostMeter(power_mw=scfg.power_mw,
                               fixed_cost_per_hour=scfg.fixed_cost_per_hour)
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}       # slot -> request
        self.remaining: dict[int, int] = {}
        self.clock_h = 0.0
        self.tokens_served = 0
        self.completed: list[Request] = []

        b, s = scfg.slots, scfg.max_seq
        self.caches = init_cache(cfg, b, s)
        self.positions = jnp.zeros((b,), jnp.int32)
        self.tokens = jnp.zeros((b, 1), jnp.int32)
        self.live = np.zeros((b,), bool)
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, t, c, pos, cfg))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.arrived_h = self.clock_h
        self.queue.append(req)

    def _admission_width(self) -> int:
        """Price-gated number of usable slots."""
        if self.scheduler is None:
            return self.scfg.slots
        price = self.scheduler.stream.current()
        if price > self.scheduler.p_thresh:
            return self.scfg.min_slots
        return self.scfg.slots

    def _fill_slots(self) -> None:
        width = self._admission_width()
        for slot in range(self.scfg.slots):
            if self.live[slot] or slot >= width or not self.queue:
                continue
            req = self.queue.popleft()
            req.started_h = self.clock_h
            plen = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
            logits, caches1 = prefill(self.params, batch, self.cfg,
                                      max_seq=self.scfg.max_seq)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)  # greedy
            # copy the single-sequence cache into slot `slot`
            self.caches = jax.tree.map(
                lambda pool, one: _slot_set(pool, one, slot),
                self.caches, caches1)
            self.positions = self.positions.at[slot].set(plen)
            self.tokens = self.tokens.at[slot, 0].set(nxt[0])
            self.live[slot] = True
            self.active[slot] = req
            self.remaining[slot] = req.max_new - 1
            req.output = [int(nxt[0])]
            self.tokens_served += 1

    def tick(self) -> None:
        """One engine tick: admissions + one batched decode step."""
        price = (self.scheduler.stream.current()
                 if self.scheduler else 0.0)
        if self.scheduler is not None:
            self.scheduler.step(self.scfg.hours_per_tick)
        self._fill_slots()
        any_live = bool(self.live.any())
        if any_live:
            logits, self.caches = self._decode(
                self.params, self.tokens, self.caches, self.positions)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)       # [B]
            self.tokens = nxt[:, None]
            self.positions = self.positions + self.live.astype(np.int32)
            for slot in list(self.active):
                if not self.live[slot]:
                    continue
                req = self.active[slot]
                req.output.append(int(nxt[slot]))
                self.tokens_served += 1
                self.remaining[slot] -= 1
                full = int(self.positions[slot]) >= self.scfg.max_seq - 1
                if self.remaining[slot] <= 0 or full:
                    req.done_h = self.clock_h
                    self.completed.append(req)
                    del self.active[slot], self.remaining[slot]
                    self.live[slot] = False
        self.meter.tick(self.scfg.hours_per_tick, price, running=any_live,
                        load=float(self.live.mean()) if any_live else 0.0)
        self.clock_h += self.scfg.hours_per_tick

    def run(self, ticks: int) -> dict:
        for _ in range(ticks):
            self.tick()
        done = self.completed
        waits = [r.started_h - r.arrived_h for r in done
                 if r.started_h is not None]
        out = self.meter.summary()
        out.update({
            "tokens_served": self.tokens_served,
            "completed": len(done),
            "queued": len(self.queue),
            "mean_queue_h": float(np.mean(waits)) if waits else 0.0,
            "eur_per_1k_tokens": (self.meter.tco
                                  / max(self.tokens_served, 1) * 1000.0),
        })
        return out


def _slot_set(pool: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Write a batch-1 cache leaf into slot ``slot`` of the pooled leaf.
    Cache leaves have batch as the first non-layer axis: pooled [L, B, ...]
    or [B, ...]; `one` matches with B=1."""
    if pool.ndim == one.ndim and pool.shape[0] != one.shape[0]:
        # [B, ...] leaf
        return jax.lax.dynamic_update_slice_in_dim(
            pool, one.astype(pool.dtype), slot, axis=0)
    return jax.lax.dynamic_update_slice_in_dim(
        pool, one.astype(pool.dtype), slot, axis=1)
