"""The paper's contribution: a TCO model for variable-capacity computing.

Everything here is pure-jnp, jit-compatible and vmap-compatible. The model
is deliberately closed-form (the paper's Eqs. 1-29): the heavy machinery
that *acts* on its decisions lives in `repro.runtime`.
"""

from repro.core.price_model import (
    PriceStats,
    price_stats,
    price_variability,
    threshold_price,
    region_means,
    resample,
)
from repro.core.tco import (
    SystemCosts,
    energy_cost_always_on,
    energy_cost_with_shutdowns,
    cpc_always_on,
    cpc_with_shutdowns,
    cpc_ratio,
    cpc_reduction,
    psi,
    shutdowns_viable,
)
from repro.core.optimizer import (
    ShutdownPlan,
    break_even_fraction,
    optimal_shutdown,
    psi_sweep,
)
from repro.core.scenarios import (
    amplify_volatility,
    scale_fixed_costs,
)
from repro.core.policy import (
    threshold_policy,
    hysteresis_policy,
    policy_energy_cost,
    policy_cpc,
    shutdown_cost_adjusted_viability,
)

__all__ = [
    "PriceStats",
    "price_stats",
    "price_variability",
    "threshold_price",
    "region_means",
    "resample",
    "SystemCosts",
    "energy_cost_always_on",
    "energy_cost_with_shutdowns",
    "cpc_always_on",
    "cpc_with_shutdowns",
    "cpc_ratio",
    "cpc_reduction",
    "psi",
    "shutdowns_viable",
    "ShutdownPlan",
    "break_even_fraction",
    "optimal_shutdown",
    "psi_sweep",
    "amplify_volatility",
    "scale_fixed_costs",
    "threshold_policy",
    "hysteresis_policy",
    "policy_energy_cost",
    "policy_cpc",
    "shutdown_cost_adjusted_viability",
]
