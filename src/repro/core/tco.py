"""TCO / CPC accounting — paper Section III(b), Eqs. (6)-(19).

Two fundamental policies over a period T with full-power draw C:

  Always-On        E_AO = T * C * p_avg                       (Eq. 6)
  With-Shutdowns   E_WS = T * C * p_avg * (1 - k*x)           (Eq. 9)

Cost-per-compute divides TCO by *operational* time:

  CPC_AO = (F + E_AO) / T                                     (Eq. 11)
  CPC_WS = (F + E_WS) / ((1-x) * T)                           (Eq. 13)

and the paper's central result: shutdowns are beneficial iff

  k > Psi + 1,   Psi = F / E_AO                               (Eq. 19)

independent of x. All quantities are jnp scalars/arrays and broadcast.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class SystemCosts(NamedTuple):
    """Static description of a compute system's cost structure (Table I)."""

    fixed: jnp.ndarray       # F  [currency] over the period T
    power: jnp.ndarray       # C  [MW] draw under full operation
    period: jnp.ndarray      # T  [hours]

    @property
    def F(self):  # noqa: N802 - paper notation
        return self.fixed

    @property
    def C(self):  # noqa: N802
        return self.power

    @property
    def T(self):  # noqa: N802
        return self.period


def make_system(fixed: float, power: float, period: float) -> SystemCosts:
    return SystemCosts(jnp.asarray(fixed, jnp.float32),
                       jnp.asarray(power, jnp.float32),
                       jnp.asarray(period, jnp.float32))


def energy_cost_always_on(sys: SystemCosts, p_avg) -> jnp.ndarray:
    """E_AO = T * C * p_avg  (Eq. 6)."""
    return sys.T * sys.C * jnp.asarray(p_avg)


def energy_cost_with_shutdowns(sys: SystemCosts, p_avg, k, x) -> jnp.ndarray:
    """E_WS = T * C * p_avg * (1 - k x)  (Eq. 9)."""
    return sys.T * sys.C * jnp.asarray(p_avg) * (1.0 - jnp.asarray(k) * jnp.asarray(x))


def cpc_always_on(sys: SystemCosts, p_avg) -> jnp.ndarray:
    """CPC_AO = (F + E_AO) / T  (Eq. 11)."""
    return (sys.F + energy_cost_always_on(sys, p_avg)) / sys.T


def cpc_with_shutdowns(sys: SystemCosts, p_avg, k, x) -> jnp.ndarray:
    """CPC_WS = (F + E_WS) / ((1-x) T)  (Eq. 13)."""
    e_ws = energy_cost_with_shutdowns(sys, p_avg, k, x)
    return (sys.F + e_ws) / ((1.0 - jnp.asarray(x)) * sys.T)


def psi(sys: SystemCosts, p_avg) -> jnp.ndarray:
    """Cost-distribution coefficient Psi = F / E_AO  (Eq. 18)."""
    return sys.F / energy_cost_always_on(sys, p_avg)


def cpc_ratio(psi_val, k, x) -> jnp.ndarray:
    """CPC_WS / CPC_AO in the dimensionless form of Eq. (28):

        ratio = (Psi + 1 - k x) / ((Psi + 1) (1 - x))

    Depends on the system only through Psi — used throughout Section IV.
    """
    psi_val, k, x = (jnp.asarray(v) for v in (psi_val, k, x))
    return (psi_val + 1.0 - k * x) / ((psi_val + 1.0) * (1.0 - x))


def cpc_reduction(psi_val, k, x) -> jnp.ndarray:
    """Relative CPC reduction of WS over AO, 1 - CPC_WS/CPC_AO (Eq. 26)."""
    return 1.0 - cpc_ratio(psi_val, k, x)


def shutdowns_viable(psi_val, k) -> jnp.ndarray:
    """The paper's headline criterion: k > Psi + 1  (Eq. 19)."""
    return jnp.asarray(k) > jnp.asarray(psi_val) + 1.0
