"""Regional comparison — paper Section IV-E / Table II.

`PAPER_TABLE2` records the paper's published values (the reproduction
target). `compute_region_row` produces the same row from any price series;
`regional_table` runs the whole study on our calibrated synthetic markets
(or real data when supplied).

The paper fixes the *system* (Lichtenberg's fixed costs and power draw) and
varies only the market: Psi_region = Psi_LB * p_avg_DE / p_avg_region,
because Psi = F / (T * C * p_avg) is inversely proportional to the mean
price. Table II's Psi column follows this rule (e.g. Finland:
2.0 * 77.84 / 46.36 = 3.36), which we replicate.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.optimizer import optimal_shutdown

PSI_LICHTENBERG = 2.0          # paper Section IV-A estimate
P_AVG_GERMANY = 77.84          # EUR/MWh, Germany 2024 (paper Table II)


class RegionRow(NamedTuple):
    region: str
    p_avg: float
    psi: float
    x_be_pct: float            # break-even shutdown fraction [%]
    x_opt_pct: float           # optimal shutdown fraction [%]
    cpc_red_pct: float         # max CPC reduction [%]


# Paper Table II (verbatim); '-' entries (Spain) encoded as None.
PAPER_TABLE2 = {
    "south_australia": RegionRow("south_australia", 59.36, 2.62, 17.55, 1.55, 5.99),
    "finland":         RegionRow("finland",         46.36, 3.36,  8.25, 2.20, 1.76),
    "estonia":         RegionRow("estonia",         87.69, 1.77,  9.24, 2.46, 1.52),
    "germany":         RegionRow("germany",         77.84, 2.00,  3.34, 0.82, 0.57),
    "south_sweden":    RegionRow("south_sweden",    50.05, 3.11,  3.75, 1.22, 0.52),
    "poland":          RegionRow("poland",          96.26, 1.62,  4.04, 1.50, 0.39),
    "netherlands":     RegionRow("netherlands",     77.60, 2.01,  2.54, 0.64, 0.39),
    "great_britain":   RegionRow("great_britain",   85.92, 1.81,  1.12, 0.38, 0.15),
    "france":          RegionRow("france",          58.19, 2.67,  0.53, 0.23, 0.04),
    "spain":           RegionRow("spain",           63.09, 2.47, None, None, None),
}

# Section IV-A headline numbers (Germany 2024, 1 h, Psi = 2).
PAPER_LICHTENBERG = {
    "x_be_pct": 3.32,          # Fig. 3 (Table II lists 3.34 from a
                               # different data source / FX conversion)
    "x_opt_pct": 0.8189,
    "k_opt": 4.9726,
    "cpc_red_pct": 0.5429,
    "p_thresh": 237.84,
}

# Section IV-B (South Australia, AEMO dispatch prices, Psi = 2).
PAPER_SOUTH_AUSTRALIA_IV_B = {
    "x_be_pct": 25.66,
    "x_opt_pct": 3.66,
    "cpc_red_pct": 8.31,
}


def psi_for_region(p_avg_region: float,
                   psi_ref: float = PSI_LICHTENBERG,
                   p_avg_ref: float = P_AVG_GERMANY) -> float:
    """Psi of the Lichtenberg system transplanted into another market."""
    return psi_ref * p_avg_ref / p_avg_region


def compute_region_row(region: str, prices: np.ndarray,
                       psi: float | None = None) -> RegionRow:
    prices = np.asarray(prices)
    p_avg = float(prices.mean())
    psi_val = float(psi) if psi is not None else psi_for_region(p_avg)
    plan = optimal_shutdown(prices, psi_val)
    viable = bool(plan.viable)
    return RegionRow(
        region=region,
        p_avg=p_avg,
        psi=psi_val,
        x_be_pct=float(plan.x_break_even) * 100 if viable else None,
        x_opt_pct=float(plan.x_opt) * 100 if viable else None,
        cpc_red_pct=float(plan.cpc_reduction) * 100 if viable else None,
    )


def regional_table(prices_by_region: dict[str, np.ndarray]) -> list[RegionRow]:
    rows = [compute_region_row(r, p) for r, p in prices_by_region.items()]
    rows.sort(key=lambda r: (r.cpc_red_pct is None,
                             -(r.cpc_red_pct or 0.0)))
    return rows
