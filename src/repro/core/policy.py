"""Executable shutdown policies over concrete price series.

The paper's closed-form model assumes free, instantaneous shutdowns and a
single threshold. This module provides the *operational* counterpart used by
`repro.runtime`: policies map a price series to an uptime mask, and cost
accounting evaluates any mask — which lets us (beyond the paper, closing the
§V-A gap) price in shutdown/restart overheads and hysteresis.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tco import SystemCosts


def threshold_policy(prices: jnp.ndarray, p_thresh) -> jnp.ndarray:
    """Uptime mask: run (1.0) while price <= threshold, shut down otherwise.

    This is the paper's WS policy realised on a concrete series.
    """
    return (jnp.asarray(prices) <= jnp.asarray(p_thresh)).astype(jnp.float32)


def hysteresis_policy(prices: jnp.ndarray, p_on, p_off) -> jnp.ndarray:
    """Two-threshold policy: shut down when price rises above ``p_off``;
    resume only when it falls back below ``p_on`` (p_on <= p_off).

    Reduces shutdown churn (and hence restart overhead) versus the single
    threshold — a beyond-paper operational refinement.
    """
    p = jnp.asarray(prices)

    def step(running, pi):
        running = jnp.where(pi > p_off, 0.0,
                            jnp.where(pi < p_on, 1.0, running))
        return running, running

    _, mask = jax.lax.scan(step, jnp.asarray(1.0), p)
    return mask


def policy_energy_cost(sys: SystemCosts, prices: jnp.ndarray,
                       uptime: jnp.ndarray,
                       idle_power_frac: float = 0.0) -> jnp.ndarray:
    """Energy cost of an arbitrary uptime mask.

    ``idle_power_frac`` models residual draw while "off" (paper §V-A notes
    real shutdowns are not free; suspended nodes still draw power).
    E = sum_i dt * C * (uptime_i + idle * (1-uptime_i)) * p_i.
    """
    p = jnp.asarray(prices)
    n = p.shape[0]
    dt = sys.T / n
    draw = uptime + idle_power_frac * (1.0 - uptime)
    return jnp.sum(dt * sys.C * draw * p)


def policy_cpc(sys: SystemCosts, prices: jnp.ndarray, uptime: jnp.ndarray,
               idle_power_frac: float = 0.0,
               restart_energy_mwh: float = 0.0,
               restart_time_h: float = 0.0,
               initial_uptime: float = 1.0) -> jnp.ndarray:
    """CPC of an arbitrary uptime mask, including restart overheads.

    Each 0->1 transition in the mask costs ``restart_energy_mwh`` (billed at
    the price of the restart interval) and ``restart_time_h`` of lost uptime.
    ``initial_uptime`` is the state *before* the series begins (1.0 — the
    machine was running — matches `hysteresis_policy`'s initial carry); a
    series that begins in the off state (``initial_uptime=0.0``) therefore
    counts its boot at index 0 as a restart instead of silently dropping it.
    With zero overheads and a threshold mask this reduces exactly to Eq. (13).
    """
    p = jnp.asarray(prices)
    n = p.shape[0]
    dt = sys.T / n
    e_run = policy_energy_cost(sys, prices, uptime, idle_power_frac)
    prev = jnp.concatenate(
        [jnp.asarray(initial_uptime, uptime.dtype)[None], uptime[:-1]])
    starts = jnp.maximum(uptime - prev, 0.0)
    e_restart = jnp.sum(starts * restart_energy_mwh * p)
    up_hours = jnp.sum(uptime) * dt - jnp.sum(starts) * restart_time_h
    return (sys.F + e_run + e_restart) / jnp.maximum(up_hours, 1e-9)


def shutdown_cost_adjusted_viability(psi_val, k,
                                     restart_overhead_frac) -> jnp.ndarray:
    """Viability with a restart overhead expressed as a fraction of the
    energy saved per shutdown event. Eq. (19) becomes

        k (1 - overhead) > Psi + 1.

    With overhead = 0 this is exactly the paper's criterion; the paper's
    statement that its estimate is an *upper bound* corresponds to
    overhead > 0 shrinking the viable region.
    """
    return jnp.asarray(k) * (1.0 - jnp.asarray(restart_overhead_frac)) \
        > jnp.asarray(psi_val) + 1.0
