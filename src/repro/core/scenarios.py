"""Scenario transforms — paper Section IV-C/IV-D.

`amplify_volatility` is Eq. (30): scale each non-negative price by a factor
determined by the instantaneous fossil share beta of generation,

    p~_i = p_i                                  if p_i <= 0
           p_i (1-beta_i)/2 + p_i beta_i 2      otherwise,

which compresses renewable-dominated (cheap) hours and stretches
fossil-dominated (expensive) hours — the paper's proxy for carbon taxes plus
ever-cheaper renewables. `scale_fixed_costs` models hardware-price shifts
(Section IV-C/D: Psi 2.0 -> 1.6 is a 20% fixed-cost cut).
"""

from __future__ import annotations

import jax.numpy as jnp


def fossil_share(fossil: jnp.ndarray, renewable: jnp.ndarray) -> jnp.ndarray:
    """beta_i = fossil_i / (fossil_i + renewable_i), safe at zero output."""
    fossil = jnp.asarray(fossil)
    renewable = jnp.asarray(renewable)
    total = fossil + renewable
    return jnp.where(total > 0, fossil / jnp.maximum(total, 1e-9), 0.5)


def amplify_volatility(prices: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    """Eq. (30): fossil-share-driven price stretching."""
    p = jnp.asarray(prices)
    beta = jnp.broadcast_to(jnp.asarray(beta), p.shape)
    stretched = p * (1.0 - beta) / 2.0 + p * beta * 2.0
    return jnp.where(p <= 0.0, p, stretched)


def scale_fixed_costs(psi_val, factor) -> jnp.ndarray:
    """New Psi after scaling fixed costs by `factor` (energy costs fixed).

    Psi = F / E_AO is linear in F, so Psi' = factor * Psi.
    """
    return jnp.asarray(psi_val) * jnp.asarray(factor)
