"""Price model — paper Section III(a), Eqs. (1)-(5) and the PV set Eq. (20).

Given a price series ``p`` sampled at a regular interval over period ``T``
and a shutdown fraction ``x``, the model splits prices at the (1-x)-quantile
into a *high* and a *low* region and characterises volatility by

    k(x) = p_high(x) / p_avg            (Eq. 3)

The *price variability* of a series is the set PV = {(k(x), x)} traced over
all feasible x (Eq. 20). Empirically, with n samples sorted descending,
x = m/n for m = 1..n-1 and p_high(m) is the mean of the top-m samples, so
the entire PV set is one sort + one cumulative sum — O(n log n), fully
vectorised, jit-compatible.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class PriceStats(NamedTuple):
    """The (k, x) description of a price series at one shutdown fraction.

    Fields mirror Table I of the paper.
    """

    x: jnp.ndarray        # shutdown fraction in (0, 1)
    k: jnp.ndarray        # p_high / p_avg                      (Eq. 3)
    p_avg: jnp.ndarray    # mean price over T
    p_high: jnp.ndarray   # mean price inside the high region    (Eq. 4)
    p_low: jnp.ndarray    # mean price inside the low region     (Eq. 5)
    p_thresh: jnp.ndarray # Q_{1-x}(p)                           (Eq. 1)


def _sorted_desc(prices: jnp.ndarray) -> jnp.ndarray:
    return jnp.sort(jnp.asarray(prices, dtype=jnp.float64
                                if jnp.asarray(prices).dtype == jnp.float64
                                else jnp.float32))[::-1]


def price_variability(prices: jnp.ndarray) -> PriceStats:
    """The full empirical PV set (Eq. 20) of a price series.

    Returns a ``PriceStats`` whose fields are arrays of length n-1,
    one entry per feasible shutdown fraction x = m/n, m = 1..n-1.
    """
    p = _sorted_desc(prices)
    n = p.shape[0]
    p_avg = jnp.mean(p)
    m = jnp.arange(1, n)                       # number of "high" samples
    x = m / n
    cum = jnp.cumsum(p)[:-1]                   # sum of top-m samples
    p_high = cum / m                           # mean of high region
    p_low = (jnp.sum(p) - cum) / (n - m)       # mean of low region
    k = p_high / p_avg
    p_thresh = p[m - 1]                        # m-th highest sample = Q_{1-x}
    return PriceStats(x=x, k=k, p_avg=jnp.broadcast_to(p_avg, x.shape),
                      p_high=p_high, p_low=p_low, p_thresh=p_thresh)


def price_stats(prices: jnp.ndarray, x: float | jnp.ndarray) -> PriceStats:
    """Model parameters (Eqs. 1-5) of ``prices`` at shutdown fraction ``x``.

    ``x`` may be a scalar or an array (broadcast over fractions).
    """
    p = _sorted_desc(prices)
    n = p.shape[0]
    x = jnp.asarray(x)
    p_avg = jnp.mean(p)
    m = jnp.clip(jnp.round(x * n).astype(jnp.int32), 1, n - 1)
    cum = jnp.concatenate([jnp.zeros((1,), p.dtype), jnp.cumsum(p)])
    p_high = cum[m] / m
    p_low = (cum[n] - cum[m]) / (n - m)
    x_eff = m / n
    k = p_high / p_avg
    p_thresh = p[m - 1]
    return PriceStats(x=x_eff, k=k,
                      p_avg=jnp.broadcast_to(p_avg, x_eff.shape),
                      p_high=p_high, p_low=p_low, p_thresh=p_thresh)


def threshold_price(prices: jnp.ndarray, x: float) -> jnp.ndarray:
    """p_thresh = Q_{1-x}(p_1..n)  (Eq. 1)."""
    return price_stats(prices, x).p_thresh


def region_means(p_avg, k, x):
    """Closed-form p_high, p_low from (p_avg, k, x)  (Eqs. 4-5)."""
    p_avg, k, x = jnp.asarray(p_avg), jnp.asarray(k), jnp.asarray(x)
    p_high = p_avg * k
    p_low = p_avg * (k * x - 1.0) / (x - 1.0)
    return p_high, p_low


def resample(prices: jnp.ndarray, factor: int) -> jnp.ndarray:
    """Downsample a price series by block-averaging ``factor`` samples.

    Models coarser sampling intervals (Fig. 3: 1 h -> 1 day -> 1 week);
    trailing remainder samples are dropped.
    """
    n = (prices.shape[0] // factor) * factor
    return jnp.mean(prices[:n].reshape(-1, factor), axis=1)
