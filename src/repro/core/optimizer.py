"""Shutdown-plan optimisation — paper Eqs. (21)-(29) and the Psi sweep of
Fig. 5.

Given the empirical PV set of a price series and a system's Psi:

  x_BE   — break-even fraction: largest x with k(x) > Psi + 1 (Fig. 3)
  x_opt  — argmin_x CPC_WS(x) over the PV set          (Eqs. 21-25)
  CPC reduction at x_opt                               (Eqs. 26-29)

All searches run over the *full* empirical PV set (one entry per sample),
exactly as the paper does, so results are data-driven, not parametric.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.price_model import price_variability
from repro.core.tco import cpc_ratio, cpc_reduction


class ShutdownPlan(NamedTuple):
    """The model's full recommendation for (prices, Psi)."""

    viable: jnp.ndarray        # any x with k(x) > Psi+1 ?
    x_break_even: jnp.ndarray  # largest beneficial x (0 if none)
    x_opt: jnp.ndarray         # CPC-minimising shutdown fraction
    k_opt: jnp.ndarray         # k at x_opt
    p_thresh: jnp.ndarray      # threshold price at x_opt
    cpc_reduction: jnp.ndarray # 1 - CPC_WS/CPC_AO at x_opt (>=0)
    p_avg: jnp.ndarray


def break_even_fraction(prices: jnp.ndarray, psi_val) -> jnp.ndarray:
    """Largest x such that k(x) > Psi + 1 (the point where the k-x line
    leaves the viable region in Fig. 3). Returns 0.0 when no x qualifies.

    k(x) is non-increasing in x, so this is the boundary of a prefix set.
    """
    pv = price_variability(prices)
    good = pv.k > jnp.asarray(psi_val) + 1.0
    # k is non-increasing => `good` is a prefix; count of Trues = index of BE.
    m_be = jnp.sum(good.astype(jnp.int32))
    return jnp.where(m_be > 0, pv.x[jnp.maximum(m_be - 1, 0)], 0.0)


def optimal_shutdown(prices: jnp.ndarray, psi_val) -> ShutdownPlan:
    """Full plan: x_BE, x_opt = argmin CPC_WS over the PV set, and the CPC
    reduction at the optimum (clipped at the AO policy: if no x improves
    CPC, the plan is x_opt = 0 with reduction 0)."""
    psi_val = jnp.asarray(psi_val, jnp.float32)
    pv = price_variability(prices)
    ratio = cpc_ratio(psi_val, pv.k, pv.x)      # CPC_WS/CPC_AO per x (Eq.28)
    i_opt = jnp.argmin(ratio)
    best_ratio = ratio[i_opt]
    improves = best_ratio < 1.0
    x_be = break_even_fraction(prices, psi_val)
    return ShutdownPlan(
        viable=improves,
        x_break_even=x_be,
        x_opt=jnp.where(improves, pv.x[i_opt], 0.0),
        k_opt=jnp.where(improves, pv.k[i_opt], jnp.nan),
        p_thresh=jnp.where(improves, pv.p_thresh[i_opt], jnp.inf),
        cpc_reduction=jnp.where(improves, 1.0 - best_ratio, 0.0),
        p_avg=pv.p_avg[0],
    )


def psi_sweep(prices: jnp.ndarray, psi_values: jnp.ndarray) -> jnp.ndarray:
    """Maximum theoretical CPC reduction vs Psi (Fig. 5).

    Returns an array of CPC reductions, one per Psi value.
    """
    pv = price_variability(prices)

    def best_reduction(psi_val):
        red = cpc_reduction(psi_val, pv.k, pv.x)
        return jnp.maximum(jnp.max(red), 0.0)

    return jax.vmap(best_reduction)(jnp.asarray(psi_values))
