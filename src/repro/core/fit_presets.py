"""Fit market-generator presets to the paper's Table II statistics.

Usage:  PYTHONPATH=src python -m repro.core.fit_presets [--regions a,b,...]

For each region the fit targets are the two k-x points pinned down by
Table II (see `repro.core.calibration`): k(x_BE) = Psi+1 and k(x_opt) =
k_opt(Psi, x_opt, red). Germany additionally targets the Section IV-A
threshold ratio p_thresh/p_avg = 237.84/77.84 at x_opt (matched implicitly
through the tail shape). Results are written to
repro/configs/market_presets.json and the residuals reported.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.core.calibration import (KTargets, calibrate_market, interp_k,
                                    k_opt_from_table)
from repro.core.optimizer import optimal_shutdown
from repro.core.regions import PAPER_TABLE2
from repro.energy.markets import MarketParams, generate_market

OUT = Path(__file__).resolve().parent.parent / "configs" / \
    "market_presets.json"

# Starting points: spikier markets get spikier inits.
_SPIKY = dict(spike_enter=0.02, spike_stay=0.5, spike_mu=2.2,
              spike_sigma=1.2)
_CALM = dict(spike_enter=0.002, spike_stay=0.4, spike_mu=0.3,
             spike_sigma=0.5, price_sens=0.8)
_INIT_STYLE = {
    "south_australia": _SPIKY,
    "finland": _SPIKY,
    "estonia": _SPIKY,
    "germany": dict(spike_enter=0.006, spike_stay=0.5, spike_mu=1.0,
                    spike_sigma=0.8),
    "south_sweden": dict(spike_enter=0.006, spike_stay=0.5, spike_mu=1.2,
                         spike_sigma=0.9),
    "poland": _CALM,
    "netherlands": dict(spike_enter=0.004, spike_stay=0.5, spike_mu=0.8,
                        spike_sigma=0.7),
    "great_britain": _CALM,
    "france": _CALM,
    "spain": dict(spike_enter=0.0005, spike_stay=0.3, spike_mu=-0.5,
                  spike_sigma=0.3, price_sens=0.5, wind_sigma=0.02),
}


def targets_for(region: str) -> KTargets:
    row = PAPER_TABLE2[region]
    if row.x_be_pct is None:      # Spain: not viable at Psi+1 = 3.47; keep
        # the whole curve below even at the single-highest sample.
        return KTargets(xs=(0.000115, 0.001, 0.01), ks=(3.0, 2.4, 1.9))
    x_be = row.x_be_pct / 100.0
    x_opt = row.x_opt_pct / 100.0
    red = row.cpc_red_pct / 100.0
    k_be = row.psi + 1.0
    k_opt = k_opt_from_table(row.psi, x_opt, red)
    if region == "germany":
        # Fig. 2 pins the extreme tail too: max 2024 price ~ 900 EUR/MWh
        # => k(1/8760) ~ 900/77.84 ~ 11.6.
        return KTargets(xs=(1.0 / 8760, x_opt, x_be),
                        ks=(11.6, k_opt, k_be), weights=(0.5, 2.0, 2.0))
    return KTargets(xs=(x_opt, x_be), ks=(k_opt, k_be),
                    weights=(2.0, 1.0))


def fit_region(region: str, max_iter: int) -> tuple[dict, dict]:
    row = PAPER_TABLE2[region]
    # seed is part of the preset: calibrate on (and average over) the seeds
    # the preset will actually use, so the fit cannot overfit one draw.
    s0 = sum(ord(c) for c in region) * 7919 % (2 ** 16)
    base = MarketParams(p_avg=row.p_avg, seed=s0,
                        **_INIT_STYLE.get(region, {}))
    tgt = targets_for(region)
    t0 = time.time()
    fitted, loss = calibrate_market(base, tgt, max_iter=max_iter,
                                    seeds=(s0, s0 + 1, s0 + 2))
    prices = np.asarray(generate_market(fitted).prices)
    k_hat = interp_k(prices, tgt.xs)
    plan = optimal_shutdown(prices, row.psi)
    report = {
        "region": region,
        "loss": loss,
        "seconds": round(time.time() - t0, 1),
        "k_targets": list(tgt.ks),
        "k_fitted": [float(v) for v in k_hat],
        "paper": {"x_be_pct": row.x_be_pct, "x_opt_pct": row.x_opt_pct,
                  "cpc_red_pct": row.cpc_red_pct},
        "ours": {
            "viable": bool(plan.viable),
            "x_be_pct": float(plan.x_break_even) * 100,
            "x_opt_pct": float(plan.x_opt) * 100,
            "cpc_red_pct": float(plan.cpc_reduction) * 100,
            "p_avg": float(prices.mean()),
        },
    }
    return dataclasses.asdict(fitted), report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--regions", default=",".join(PAPER_TABLE2.keys()))
    ap.add_argument("--max-iter", type=int, default=120)
    args = ap.parse_args()

    presets = json.loads(OUT.read_text()) if OUT.exists() else {}
    reports = []
    for region in args.regions.split(","):
        region = region.strip()
        params, report = fit_region(region, args.max_iter)
        presets[region] = params
        reports.append(report)
        print(json.dumps(report, indent=2))
        OUT.parent.mkdir(parents=True, exist_ok=True)
        OUT.write_text(json.dumps(presets, indent=2, sort_keys=True))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
