"""Calibrate synthetic market generators against published statistics.

The paper's case studies derive from 2024 price series we cannot access
offline. Each region in Table II, however, pins down points on the k-x
curve:

  * at the break-even fraction  x_BE:  k(x_BE) = Psi + 1      (Eq. 19)
  * at the optimum x_opt, the CPC reduction `red` gives (Eq. 28)
        k_opt = (Psi+1) * (1 - (1-red)(1-x_opt)) / x_opt

We fit the spike/volatility parameters of `repro.energy.markets` so the
synthetic series reproduces those (x, k) targets (p_avg is matched exactly
by scaling — k is scale-invariant). The optimizer is a self-contained
Nelder-Mead (no scipy in this environment); the objective interpolates
log k at the target fractions over the empirical PV set.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.price_model import price_variability
from repro.energy.markets import MarketParams, generate_market


# ---------------------------------------------------------------------------
# targets
# ---------------------------------------------------------------------------

def k_opt_from_table(psi: float, x_opt: float, red: float) -> float:
    """Invert Eq. (28) at the optimum: the k that yields `red` at x_opt."""
    return (psi + 1.0) * (1.0 - (1.0 - red) * (1.0 - x_opt)) / x_opt


@dataclasses.dataclass(frozen=True)
class KTargets:
    """Target points (x_i, k_i) on the empirical k-x curve, with weights."""

    xs: tuple
    ks: tuple
    weights: tuple | None = None


def interp_k(prices: np.ndarray, xs: Sequence[float]) -> np.ndarray:
    """k(x) read off the empirical PV set by log-x interpolation."""
    pv = price_variability(np.asarray(prices))
    x_grid = np.asarray(pv.x)
    k_grid = np.asarray(pv.k)
    return np.exp(np.interp(np.log(np.asarray(xs)),
                            np.log(x_grid), np.log(k_grid)))


def target_loss(prices: np.ndarray, targets: KTargets) -> float:
    k_hat = interp_k(prices, targets.xs)
    w = np.asarray(targets.weights) if targets.weights else \
        np.ones(len(targets.xs))
    err = np.log(k_hat) - np.log(np.asarray(targets.ks))
    return float(np.sum(w * err ** 2))


# ---------------------------------------------------------------------------
# Nelder-Mead (self-contained; no scipy available offline)
# ---------------------------------------------------------------------------

def nelder_mead(f: Callable[[np.ndarray], float], x0: np.ndarray,
                steps: np.ndarray, max_iter: int = 200,
                xtol: float = 1e-3) -> tuple[np.ndarray, float]:
    n = len(x0)
    simplex = [np.asarray(x0, dtype=np.float64)]
    for i in range(n):
        v = np.array(x0, dtype=np.float64)
        v[i] += steps[i]
        simplex.append(v)
    vals = [f(v) for v in simplex]

    for _ in range(max_iter):
        order = np.argsort(vals)
        simplex = [simplex[i] for i in order]
        vals = [vals[i] for i in order]
        if np.max([np.linalg.norm(s - simplex[0]) for s in simplex[1:]]) < xtol:
            break
        centroid = np.mean(simplex[:-1], axis=0)
        worst = simplex[-1]
        refl = centroid + (centroid - worst)
        f_refl = f(refl)
        if f_refl < vals[0]:
            expd = centroid + 2.0 * (centroid - worst)
            f_expd = f(expd)
            if f_expd < f_refl:
                simplex[-1], vals[-1] = expd, f_expd
            else:
                simplex[-1], vals[-1] = refl, f_refl
        elif f_refl < vals[-2]:
            simplex[-1], vals[-1] = refl, f_refl
        else:
            contr = centroid + 0.5 * (worst - centroid)
            f_contr = f(contr)
            if f_contr < vals[-1]:
                simplex[-1], vals[-1] = contr, f_contr
            else:  # shrink
                for i in range(1, n + 1):
                    simplex[i] = simplex[0] + 0.5 * (simplex[i] - simplex[0])
                    vals[i] = f(simplex[i])
    best = int(np.argmin(vals))
    return simplex[best], vals[best]


# ---------------------------------------------------------------------------
# market calibration
# ---------------------------------------------------------------------------

# Parameters exposed to the fit, with (log-space) bounds.
_FIT_FIELDS = ("spike_enter", "spike_stay", "spike_mu", "spike_sigma",
               "price_sens", "wind_sigma")
_LO = np.array([1e-5, 0.05, -1.5, 0.05, 0.2, 0.005])
_HI = np.array([0.20, 0.97, 3.50, 2.50, 6.0, 0.40])


def _theta_to_params(base: MarketParams, theta: np.ndarray) -> MarketParams:
    vals = _LO + (_HI - _LO) / (1.0 + np.exp(-theta))   # sigmoid box
    kw = {k: float(v) for k, v in zip(_FIT_FIELDS, vals)}
    kw["spike_mu"] = float(vals[2])                     # may be negative
    return base.replace(**kw)


def _params_to_theta(params: MarketParams) -> np.ndarray:
    vals = np.array([getattr(params, k) for k in _FIT_FIELDS])
    frac = np.clip((vals - _LO) / (_HI - _LO), 1e-4, 1 - 1e-4)
    return np.log(frac / (1 - frac))


def calibrate_market(base: MarketParams, targets: KTargets,
                     max_iter: int = 120,
                     seeds: Sequence[int] = (0,)) -> tuple[MarketParams, float]:
    """Fit spike/volatility parameters so the generated series hits the
    (x, k) targets. Averages the loss over ``seeds`` for robustness."""

    def objective(theta: np.ndarray) -> float:
        params = _theta_to_params(base, theta)
        tot = 0.0
        for s in seeds:
            prices = np.asarray(generate_market(
                params.replace(seed=int(s))).prices)
            if prices.mean() <= 0:
                return 1e6
            tot += target_loss(prices, targets)
        return tot / len(seeds)

    theta0 = _params_to_theta(base)
    theta, loss = nelder_mead(objective, theta0,
                              steps=0.7 * np.ones(len(theta0)),
                              max_iter=max_iter)
    return _theta_to_params(base, theta), loss
