"""Feasible cross-site dispatch: allocate a fleet-wide compute demand
across sites hour by hour under *hard* constraints.

  schedule — materialised per-site shutdown schedules (the fleet state
             machine, hour-by-hour instead of summed)
  allocate — greedy water-fill over price-sorted capacity segments with
             migration costs and minimum-dwell locks; loud
             `DispatchInfeasible` on unmeetable constraints

The hot loop is `repro.kernels.dispatch_scan` (Pallas, time-innermost
grid with the carry in VMEM), bit-identical to the sequential
`repro.kernels.ref.dispatch_ref` oracle; its temperature-relaxed
counterpart `repro.kernels.soft_dispatch` softmins over the same
`segment_keys` so gradients flow through placement.
`repro.fleet.summarize` exposes the result as `FleetSummary.dispatch`;
`repro.tune.optimize` re-scores tuned policies on feasible dispatch via
`TuneConfig.dispatch` and tunes *through* the relaxed dispatcher via
`TuneConfig.dispatch_soft`.
"""

from repro.dispatch.allocate import (DispatchConfig, DispatchInfeasible,
                                     DispatchProblem, DispatchResult,
                                     Relief, build_problem,
                                     diurnal_demand, dispatch,
                                     resolve_demand, segment_keys,
                                     segment_rank, summarize_alloc)
from repro.dispatch.schedule import capacity_series, on_state_series

__all__ = ["DispatchConfig", "DispatchInfeasible", "DispatchProblem",
           "DispatchResult", "Relief", "build_problem", "diurnal_demand",
           "dispatch", "resolve_demand", "segment_keys", "segment_rank",
           "summarize_alloc", "capacity_series", "on_state_series"]
