"""Materialised per-site shutdown schedules.

The fleet scan (`repro.kernels.fleet_scan`) deliberately never stores the
[B, T] on/off trajectory — every per-site cost is affine in four sums.
The dispatcher, however, needs the hour-by-hour *capacity* each site
offers: which is exactly the same two-threshold hysteresis state machine,
materialised instead of summed. `capacity_series` is that
materialisation; `tests/test_dispatch.py` pins it against
`fleet_scan_ref`'s ``up_units`` so the two state machines cannot drift
apart.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def on_state_series(prices: jax.Array, p_on: jax.Array,
                    p_off: jax.Array) -> jax.Array:
    """[S, T] on/off trajectory of the hysteresis state machine.

    Same recurrence and initial state (running) as
    `repro.kernels.ref.fleet_scan_ref`:

        on_t = 0 if p_t > p_off, 1 if p_t <= p_on, else on_{t-1}

    ``p_off = +inf`` rows (always-on policies) never shut down.
    """
    p = jnp.asarray(prices, jnp.float32)
    s = p.shape[0]
    p_on, p_off = (jnp.broadcast_to(jnp.asarray(v, jnp.float32), (s,))
                   for v in (p_on, p_off))

    def step(on_prev, p_t):
        on = jnp.where(p_t > p_off, 0.0,
                       jnp.where(p_t <= p_on, 1.0, on_prev))
        return on, on

    _, on = jax.lax.scan(step, jnp.ones((s,), jnp.float32), p.T)
    return on.T


@jax.jit
def capacity_series(prices: jax.Array, p_on: jax.Array, p_off: jax.Array,
                    off_level: jax.Array) -> jax.Array:
    """[S, T] capacity fraction each site offers per hour: 1 while on,
    ``off_level`` (partial shutdown, paper §V-C) while off."""
    on = on_state_series(prices, p_on, p_off)
    s = on.shape[0]
    lvl = jnp.broadcast_to(jnp.asarray(off_level, jnp.float32), (s,))
    return lvl[:, None] + (1.0 - lvl[:, None]) * on
