"""Feasible cross-site dispatch: hard constraints, not penalty terms.

The paper prices each site in isolation; the PR-2 tuner couples sites
only through *soft* penalties. An operator with sites in several markets
instead shifts load to wherever power is cheapest, subject to hard
constraints (the TARDIS setting, PAPERS.md): per-site capacity from each
site's shutdown schedule, a total-fleet power cap, and an aggregate
compute floor. This module is that dispatcher.

Model. Every hour, a fleet-wide compute demand ``D_t`` (MW) is placed
across S sites. Site s offers ``avail[s, t]`` MW (its policy's on/off
state times its rating — `repro.dispatch.schedule`). Placement is a
greedy water-fill over price-sorted capacity segments: load already at a
site is priced at ``p - migrate_cost`` (leaving must pay the one-time
migration fee, so moves happen only when the price advantage beats the
fee within the hour), load placed less than ``min_dwell_h`` hours ago is
locked (ranked below everything), and fresh capacity pays the plain
market price. With ``migrate_cost = 0`` and ``min_dwell_h = 0`` this
reduces exactly to filling the cheapest available sites each hour.

Greedy-by-price is *optimal* per hour for this segment model (exchange
argument: any feasible allocation moving a MW from a cheaper to a
costlier segment weakly increases cost); the migration premium and dwell
locks make consecutive hours consistent instead of thrashing.

Infeasibility is loud: demand above the power cap, demand above fleet
availability in any hour, or a total demand below the compute floor
raises `DispatchInfeasible` — hard constraints are never silently
clipped. Feasible results report their slack.

The hot loop is `repro.kernels.dispatch_scan` (Pallas, time-innermost
with the carry in VMEM) with `repro.kernels.ref.dispatch_ref` as the
sequential oracle; both share the per-hour math and are bit-identical.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import jax
import numpy as np

from repro import obs
from repro.dispatch.schedule import capacity_series
from repro.execution import ExecutionPlan
from repro.kernels.dispatch_scan import dispatch_scan
from repro.kernels.ref import dispatch_ref

_MOVE_TOL = 1e-6     # MW below which an hour's net move is not an event
_NEAR_FRAC = 0.05    # capacity slack below this fraction of demand is
                     # "near-infeasible" in the telemetry margin count


class DispatchInfeasible(ValueError):
    """A hard dispatch constraint cannot be met (never silently clipped)."""


class Relief(NamedTuple):
    """Graceful-degradation pricing for infeasible dispatch hours.

    With a ``Relief`` attached (``DispatchConfig.relief`` /
    ``DispatchProblem.relief``), an hour whose demand exceeds fleet
    availability (or the power cap) no longer raises
    `DispatchInfeasible`: every available MW is still placed by the
    same water-fill, and the unmet remainder is *shed* at the
    value-of-lost-load price — a slack segment priced at
    ``voll_eur_mwh`` above every real one, so relief never displaces
    feasible allocation. Hashable, so configs stay jit-static.

    ``voll_eur_mwh`` should sit well above the market price span
    (default 3000 EUR/MWh, the order of magnitude of European market
    price caps): shed is then a last resort the optimizer only takes
    when the fleet physically cannot serve.
    """

    voll_eur_mwh: float = 3000.0


class DispatchConfig(NamedTuple):
    """Operator-side dispatch constraints (hashable — nested in
    `repro.tune.TuneConfig` as a jit-static field).

    ``demand_mw`` is the fleet-wide compute demand: a scalar (same MW
    every hour) or a length-[T] *profile* — pass a tuple (e.g. from
    `diurnal_demand`) so the config stays hashable; any other length
    raises loudly in `build_problem`. When None it defaults to
    ``demand_frac`` of the summed site ratings. ``migrate_cost`` is EUR
    per MW moved between sites (charged on the matched in/out flow, and
    used as the retention premium in the greedy fill). ``min_dwell_h``
    locks newly placed load for that many hours. ``compute_floor_mwh``
    is the aggregate compute the fleet must deliver over the period.
    ``plan`` (`repro.execution.ExecutionPlan`, itself hashable) pins the
    execution layout `dispatch` solves under — the same object
    `TuneConfig` and `fleet.backtest` take; None leaves the backend
    auto-select in force. ``relief`` (a `Relief`) converts infeasible
    hours into priced shed instead of raising; None keeps the hard
    raise, bit-identical to the pre-relief dispatcher. ``workload`` (a
    `repro.workload.Workload`, duck-typed to avoid the import cycle)
    derives the demand profile from the request-arrival model when
    ``demand_mw`` is None — the expected MW of
    `Workload.mean_demand_mw`; with both unset the ``demand_frac``
    default applies bit-identically.
    """

    demand_mw: Optional[Union[float, tuple]] = None
    demand_frac: float = 0.5
    power_cap_mw: float = float("inf")
    migrate_cost: float = 0.0
    min_dwell_h: int = 0
    compute_floor_mwh: float = 0.0
    plan: Optional[ExecutionPlan] = None
    relief: Optional[Relief] = None
    workload: Optional[object] = None


class DispatchProblem(NamedTuple):
    """One concrete dispatch instance (all arrays host-side numpy)."""

    prices: np.ndarray      # [S, T] EUR/MWh
    avail_mw: np.ndarray    # [S, T] available MW (schedule x rating)
    demand_mw: np.ndarray   # [T] fleet demand
    power_cap_mw: float
    migrate_cost: float     # EUR per MW moved
    min_dwell_h: int
    compute_floor_mwh: float
    fixed_cost: float       # summed per-period fixed cost of the sites
    site_names: tuple = ()
    # precomputed segment sort data ([T, 3S] int32 each, from
    # `segment_rank`); None -> computed on first dispatch
    order: Optional[np.ndarray] = None
    rank: Optional[np.ndarray] = None
    relief: Optional[Relief] = None   # None -> infeasibility raises


class DispatchResult(NamedTuple):
    """Feasible dispatch outcome (the `FleetSummary.dispatch` block)."""

    alloc_mw: np.ndarray      # [S, T] hourly allocation
    cpc: float                # (fixed + energy + migration) / delivered
    energy_cost: float        # sum_t sum_s alloc * price
    migration_cost: float     # migrate_cost x MW moved
    migration_mw: float       # total MW moved between sites
    n_migrations: int         # hours with a net cross-site move
    delivered_mwh: float
    site_mwh: np.ndarray      # [S] compute delivered per site
    slack_power_mw: float     # min_t (power cap - demand)
    slack_capacity_mw: float  # min_t (fleet availability - demand)
    slack_floor_mwh: float    # delivered - compute floor
    # relief accounting (all zero when relief is None / nothing shed)
    shed_mwh: float = 0.0     # demand the fleet could not serve
    shed_cost: float = 0.0    # shed_mwh x value of lost load
    n_shed_hours: int = 0     # hours with shed above _MOVE_TOL


def segment_keys(prices: np.ndarray, migrate_cost: float) -> np.ndarray:
    """[T, 3S] float64 sort keys of every site's three capacity
    segments — the single source of the segment price model, shared by
    the hard sort (`segment_rank`) and the soft water-fill
    (`repro.kernels.soft_dispatch`, which softmins over these keys).

    Locked segments sit below everything (offset by more than the price
    span, price-ordered among themselves), retained load is priced at
    ``p - migrate_cost``, fresh capacity at ``p``. Keys depend only on
    prices and the premium — never on the running state — which is what
    lets both kernels run sort-free.
    """
    p = np.asarray(prices, np.float64).T                      # [T, S]
    span = float(np.max(p) - np.min(p)) + abs(migrate_cost) + 1.0
    return np.concatenate([p - span, p - migrate_cost, p], axis=1)


def segment_rank(prices: np.ndarray, migrate_cost: float, *,
                 keys: Optional[np.ndarray] = None
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Ascending sort permutation and rank ([T, 3S] int32 each) of the
    `segment_keys` (float64, so a class offset cannot swallow price
    differences). A caller that already computed the keys (the soft
    dispatch coupling needs them as data too) passes them instead of
    paying `segment_keys` twice.

    Ties (equal keys) resolve by segment position — stable argsort —
    so a site's retained load wins over its own fresh capacity at
    ``migrate_cost = 0``; cross-site ties follow site order.
    """
    if keys is None:
        keys = segment_keys(prices, migrate_cost)
    order = np.argsort(keys, axis=1, kind="stable").astype(np.int32)
    rank = np.empty_like(order)
    np.put_along_axis(rank, order,
                      np.broadcast_to(np.arange(order.shape[1],
                                                dtype=np.int32),
                                      order.shape), axis=1)
    return order, rank


def diurnal_demand(t: int, *, base_mw: float, swing_mw: float,
                   peak_hour: float = 17.0) -> tuple:
    """Length-``t`` diurnal demand profile as a hashable tuple (so it
    can sit in `DispatchConfig.demand_mw`, which `repro.tune` uses as a
    jit-static field): ``base + swing * cos(2 pi (h - peak) / 24)`` —
    load peaks at ``peak_hour`` local time and bottoms out 12 h later.
    """
    if swing_mw < 0 or swing_mw > base_mw:
        raise ValueError("diurnal_demand needs 0 <= swing_mw <= base_mw "
                         "(negative demand is not dispatchable)")
    h = np.arange(t, dtype=np.float64) % 24.0
    prof = base_mw + swing_mw * np.cos((h - peak_hour) * (2.0 * np.pi / 24.0))
    return tuple(float(x) for x in prof)


def resolve_demand(cfg: DispatchConfig, power: np.ndarray,
                   t: int) -> np.ndarray:
    """[T] demand profile of a `DispatchConfig`: a scalar ``demand_mw``
    broadcasts, a sequence must have exactly ``t`` entries (anything
    else raises — a profile built for the wrong horizon is a bug, not a
    broadcast), and None defaults to ``demand_frac`` of the summed site
    ratings. Shared by `build_problem` and the soft dispatch coupling
    (`repro.tune.objective.dispatch_coupling_from_grid`). A
    ``cfg.workload`` spec takes over the None default: the profile is
    the workload's expected demand (`Workload.mean_demand_mw`)."""
    if cfg.demand_mw is None and getattr(cfg, "workload", None) \
            is not None:
        demand = np.asarray(cfg.workload.mean_demand_mw(t), np.float64)
    elif cfg.demand_mw is None:
        demand = np.asarray(cfg.demand_frac
                            * float(np.asarray(power, np.float64).sum()))
    else:
        demand = np.asarray(cfg.demand_mw, np.float64)
    if demand.ndim == 0:
        return np.broadcast_to(demand.astype(np.float32), (t,))
    if demand.shape != (t,):
        raise ValueError(
            f"DispatchConfig.demand_mw profile has {demand.shape[0]} "
            f"entries but the problem spans {t} hours — pass a scalar "
            "or a length-T profile (e.g. repro.dispatch.diurnal_demand)")
    return demand.astype(np.float32)


def build_problem(prices, p_on, p_off, off_level, power,
                  cfg: DispatchConfig, *, fixed=None,
                  site_names: Sequence[str] = ()) -> DispatchProblem:
    """Assemble a `DispatchProblem` from per-site policy variables.

    prices: [S, T]; p_on/p_off/off_level/power (MW rating): [S].
    Availability is each site's materialised shutdown schedule times its
    rating. ``cfg.demand_mw`` may be a scalar or a [T] profile
    (`resolve_demand`). Callers hold the site semantics:
    `repro.fleet.report` feeds the best swept row per (market, system)
    cell, `repro.tune` the gradient-tuned policies.
    """
    prices = np.asarray(prices, np.float32)
    s, t = prices.shape
    power = np.broadcast_to(np.asarray(power, np.float32), (s,))
    cap = np.asarray(capacity_series(prices, p_on, p_off, off_level))
    order, rank = segment_rank(prices, float(cfg.migrate_cost))
    return DispatchProblem(
        prices=prices,
        avail_mw=power[:, None] * cap,
        demand_mw=resolve_demand(cfg, power, t),
        power_cap_mw=float(cfg.power_cap_mw),
        migrate_cost=float(cfg.migrate_cost),
        min_dwell_h=int(cfg.min_dwell_h),
        compute_floor_mwh=float(cfg.compute_floor_mwh),
        fixed_cost=float(np.sum(fixed)) if fixed is not None else 0.0,
        site_names=tuple(site_names),
        order=order, rank=rank, relief=cfg.relief)


def _infeasible(reason: str, **detail) -> DispatchInfeasible:
    obs.trace_event("dispatch.infeasible", {"reason": reason, **detail})
    obs.counter("dispatch.infeasible").inc()
    return DispatchInfeasible(reason)


def _check_feasible(problem: DispatchProblem) -> None:
    d = np.asarray(problem.demand_mw, np.float64)
    cap = problem.power_cap_mw
    if float(d.max()) > cap:
        worst = int(d.argmax())
        raise _infeasible(
            f"fleet power cap {cap:.3f} MW is below the demand "
            f"{d.max():.3f} MW (first binding hour {worst}) — the cap "
            "can never be met by reallocating; raise it or shed demand",
            constraint="power_cap", hour=worst)
    avail = np.asarray(problem.avail_mw, np.float64).sum(axis=0)   # [T]
    short = d - avail
    if float(short.max()) > 1e-6:
        worst = int(short.argmax())
        n_bad = int((short > 1e-6).sum())
        raise _infeasible(
            f"fleet availability covers demand in only {len(d) - n_bad}/"
            f"{len(d)} hours: worst hour {worst} offers {avail[worst]:.3f} "
            f"MW against {d[worst]:.3f} MW demanded — site schedules shut "
            "down too much capacity for this demand",
            constraint="capacity", hour=worst, n_short_hours=n_bad)
    if float(d.sum()) < problem.compute_floor_mwh:
        raise _infeasible(
            f"aggregate compute floor {problem.compute_floor_mwh:.3f} MWh "
            f"exceeds the total demanded {d.sum():.3f} MWh — the floor "
            "cannot be reached even at full delivery",
            constraint="compute_floor")


_dispatch_ref_jit = jax.jit(dispatch_ref, static_argnames=("min_dwell",))


def dispatch(problem: DispatchProblem, *,
             use_pallas: Optional[bool] = None,
             block_t: int = 512,
             plan: Optional[ExecutionPlan] = None) -> DispatchResult:
    """Solve one dispatch instance; raises `DispatchInfeasible` when a
    hard constraint cannot hold.

    ``use_pallas=None`` auto-selects like `repro.fleet.engine.backtest`:
    the Pallas kernel on TPU, the jitted sequential reference elsewhere
    (both are bit-identical; the interpreter is a debugging tool, not a
    fast path).

    ``plan`` (`repro.execution.ExecutionPlan` — the same object
    `repro.tune.TuneConfig` and `fleet.backtest` take) pins the layout:
    ``mode='single'`` forces the one-program reference path
    (``use_pallas=False``); ``mode='auto'`` keeps the backend
    auto-select. Chunked and sharded plans raise — a dispatch instance
    has no row axis to split (its site axis is coupled through the
    shared water level every hour).
    """
    if plan is not None:
        if plan.mode in ("chunked", "sharded"):
            raise ValueError(
                f"dispatch: ExecutionPlan(mode={plan.mode!r}) has no "
                "meaning here — a dispatch instance has no row axis to "
                "chunk or shard (sites are coupled through the shared "
                "water level); use mode='single' or 'auto'")
        if plan.mode == "single":
            use_pallas = False
    if problem.relief is None:
        demand = problem.demand_mw
        _check_feasible(problem)
    else:
        # graceful degradation: cap demand at the power ceiling (a
        # bitwise no-op whenever the cap is slack) and let the
        # width-clipped fill place every available MW; the remainder is
        # priced as shed by `summarize_alloc` against the *original*
        # demand. The kernels are untouched — relief is accounting.
        demand = np.minimum(np.asarray(problem.demand_mw),
                            problem.power_cap_mw
                            ).astype(problem.demand_mw.dtype)
    order, rank = (problem.order, problem.rank) \
        if problem.order is not None and problem.rank is not None \
        else segment_rank(problem.prices, problem.migrate_cost)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        alloc = dispatch_scan(problem.avail_mw, order, rank,
                              demand,
                              min_dwell=problem.min_dwell_h,
                              block_t=block_t)
    else:
        alloc = _dispatch_ref_jit(problem.avail_mw, order, rank,
                                  demand,
                                  min_dwell=problem.min_dwell_h)
    return summarize_alloc(problem, np.asarray(alloc))


def summarize_alloc(problem: DispatchProblem,
                    alloc: np.ndarray) -> DispatchResult:
    """Cost/migration/slack accounting over a [S, T] allocation (shared
    by both scan paths, so identical allocations give identical stats).

    Hour 0 places the fleet's load from empty; migration counts only the
    *matched* in/out flow (load that left one site and arrived at
    another), so demand ramps are not billed as moves.

    All totals are sums of float64 per-hour [T] aggregates — the same
    arrays emitted as the ``dispatch.hourly`` trace event — so
    `repro.obs.report` reproduces ``cpc`` and ``n_migrations`` from the
    trace alone, bit for bit.
    """
    alloc = np.asarray(alloc, np.float64)
    prices = np.asarray(problem.prices, np.float64)
    demand = np.asarray(problem.demand_mw, np.float64)

    energy_t = (alloc * prices).sum(axis=0)               # [T]
    delivered_t = alloc.sum(axis=0)                       # [T]
    prev = np.concatenate([np.zeros_like(alloc[:, :1]), alloc[:, :-1]],
                          axis=1)
    delta = alloc - prev
    inflow = np.clip(delta, 0.0, None).sum(axis=0)        # [T]
    outflow = np.clip(-delta, 0.0, None).sum(axis=0)
    moved = np.minimum(inflow, outflow)
    energy_cost = float(energy_t.sum())
    migration_mw = float(moved.sum())
    migration_cost = problem.migrate_cost * migration_mw
    delivered = float(delivered_t.sum())

    avail_total = np.asarray(problem.avail_mw, np.float64).sum(axis=0)
    slack_cap_t = avail_total - demand                    # [T]
    if problem.relief is not None:
        # unmet demand, priced at the value of lost load. Shed is the
        # *exact* float64 shortfall against availability and the power
        # cap — not against the f32 allocation, whose rounding residue
        # would price phantom micro-shed on feasible hours — with the
        # same 1e-6 MW tolerance `_check_feasible` applies, so relief
        # sheds exactly where the hard path would have raised. The
        # relief branch is structurally separate so relief=None keeps
        # the exact pre-relief arithmetic.
        served_t = np.minimum(demand,
                              np.minimum(problem.power_cap_mw,
                                         avail_total))
        shed_t = np.clip(demand - served_t, 0.0, None)    # [T]
        shed_t = np.where(shed_t > 1e-6, shed_t, 0.0)
        shed_mwh = float(shed_t.sum())
        shed_cost = float(problem.relief.voll_eur_mwh) * shed_mwh
        n_shed_hours = int((shed_t > 0.0).sum())
        cpc = (problem.fixed_cost + energy_cost + migration_cost
               + shed_cost) / max(delivered, 1e-9)
    else:
        shed_mwh = shed_cost = 0.0
        n_shed_hours = 0
        cpc = (problem.fixed_cost + energy_cost + migration_cost) \
            / max(delivered, 1e-9)
    result = DispatchResult(
        alloc_mw=alloc,
        cpc=cpc,
        energy_cost=energy_cost,
        migration_cost=migration_cost,
        migration_mw=migration_mw,
        n_migrations=int((moved > _MOVE_TOL).sum()),
        delivered_mwh=delivered,
        site_mwh=alloc.sum(axis=1),
        slack_power_mw=float(problem.power_cap_mw - demand.max()),
        slack_capacity_mw=float(slack_cap_t.min()),
        slack_floor_mwh=delivered - problem.compute_floor_mwh,
        shed_mwh=shed_mwh,
        shed_cost=shed_cost,
        n_shed_hours=n_shed_hours,
    )
    if obs.enabled():
        near = int((slack_cap_t < _NEAR_FRAC * demand).sum())
        obs.trace_event("dispatch.hourly", {
            "delivered_mwh": delivered_t, "energy_cost": energy_t,
            "moved_mw": moved, "slack_capacity_mw": slack_cap_t,
            "demand_mw": demand, "move_tol": _MOVE_TOL,
            "fixed_cost": problem.fixed_cost,
            "migrate_cost": problem.migrate_cost,
        })
        obs.trace_event("dispatch.result", {
            "cpc": result.cpc, "energy_cost": energy_cost,
            "migration_cost": migration_cost, "migration_mw": migration_mw,
            "n_migrations": result.n_migrations,
            "delivered_mwh": delivered,
            "slack_power_mw": result.slack_power_mw,
            "slack_capacity_mw": result.slack_capacity_mw,
            "slack_floor_mwh": result.slack_floor_mwh,
            "near_infeasible_hours": near, "near_frac": _NEAR_FRAC,
            "n_sites": int(alloc.shape[0]), "hours": int(alloc.shape[1]),
            "site_names": list(problem.site_names),
        })
        if problem.relief is not None:
            obs.trace_event("dispatch.shed", {
                "shed_mwh": shed_mwh, "shed_cost": shed_cost,
                "n_shed_hours": n_shed_hours,
                "voll_eur_mwh": float(problem.relief.voll_eur_mwh),
                "demand_mwh": float(demand.sum()),
                "delivered_mwh": delivered,
            })
            obs.counter("dispatch.shed_mwh").inc(shed_mwh)
            obs.counter("dispatch.shed_hours").inc(n_shed_hours)
        obs.counter("dispatch.calls").inc()
        obs.counter("dispatch.moves").inc(result.n_migrations)
        obs.gauge("dispatch.slack_capacity_mw").set(result.slack_capacity_mw)
        obs.gauge("dispatch.slack_power_mw").set(result.slack_power_mw)
        obs.gauge("dispatch.cpc").set(result.cpc)
    return result
