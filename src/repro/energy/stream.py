"""Price streams: the runtime-facing interface to a market.

A ``PriceStream`` replays a (real or synthetic) hourly series at an
arbitrary simulated clock rate and exposes the trailing window the
``EnergyAwareScheduler`` needs to re-estimate the PV set online. It is
plain Python (host-side control plane) — device code never sees prices.
"""

from __future__ import annotations

import numpy as np


class PriceStream:
    """Replays a price series with a trailing-window view.

    Parameters
    ----------
    prices : array [n]
        hourly price samples (EUR/MWh).
    window : int
        trailing window length used for online PV estimation.
    start : int
        starting index into the series.
    """

    def __init__(self, prices, window: int = 24 * 28, start: int = 0):
        self.prices = np.asarray(prices, dtype=np.float64)
        if self.prices.ndim != 1 or self.prices.shape[0] < 2:
            raise ValueError("prices must be a 1-D series")
        self.window = int(window)
        self._start = int(start)
        self._hours = 0.0            # fractional hours accumulate exactly

    @property
    def pos(self) -> int:
        return self._start + int(self._hours)

    def current(self) -> float:
        return float(self.prices[self.pos % len(self.prices)])

    def trailing(self) -> np.ndarray:
        """The trailing ``window`` samples ending at the current hour."""
        n = len(self.prices)
        idx = (np.arange(self.pos - self.window + 1, self.pos + 1)) % n
        return self.prices[idx]

    def advance(self, hours: float = 1.0) -> None:
        """Advance simulated time; sub-hour ticks accumulate without loss
        (a 0.02 h serving tick still crosses hour boundaries on time)."""
        self._hours += float(hours)

    def peek(self, horizon: int) -> np.ndarray:
        """Day-ahead style lookahead (spot markets publish next-day prices
        at ~13:00; the scheduler may use up to `horizon` future samples)."""
        n = len(self.prices)
        idx = (np.arange(self.pos + 1, self.pos + 1 + horizon)) % n
        return self.prices[idx]
