"""Price streams: the runtime-facing interface to a market.

A ``PriceStream`` replays a (real or synthetic) hourly series at an
arbitrary simulated clock rate and exposes the trailing window the
``EnergyAwareScheduler`` needs to re-estimate the PV set online. It is
plain Python (host-side control plane) — device code never sees prices.

Lookahead follows the day-ahead market contract: the exchange clears
once per day (EPEX SPOT / Nord Pool publish around 13:00) and the
result covers all 24 hours of the *next* delivery day. Before
``publish_hour`` the stream therefore only knows prices through the end
of the current day; after it, through the end of the next day. ``peek``
truncates to that boundary instead of leaking perfect foresight.
"""

from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


def ffill_with_staleness(values, *, fill_value: Optional[float] = None):
    """Carry the last finite sample forward over NaN/inf gaps.

    Returns ``(filled, staleness)`` — ``filled`` is ``values`` with every
    non-finite entry replaced by the most recent finite one, and
    ``staleness[i]`` counts how many samples ago that donor was observed
    (0 where ``values[i]`` itself is finite). A leading gap (no prior
    finite sample) is filled with ``fill_value`` (default: the first
    finite sample in the series) and its staleness counts from the
    series start. Fully vectorized: gap positions index the running
    maximum of observed positions, so a year-long series fills in one
    pass with no Python loop.
    """
    v = np.asarray(values, dtype=np.float64)
    if v.ndim != 1:
        raise ValueError("ffill_with_staleness expects a 1-D series")
    ok = np.isfinite(v)
    if not ok.any():
        raise ValueError("cannot forward-fill an all-gap series")
    pos = np.arange(v.size)
    last = np.maximum.accumulate(np.where(ok, pos, -1))
    staleness = np.where(last >= 0, pos - last, pos + 1).astype(np.int64)
    if fill_value is None:
        fill_value = v[ok][0]
    filled = np.where(last >= 0, v[np.maximum(last, 0)],
                      np.float64(fill_value))
    return filled, staleness


class PriceStream:
    """Replays a price series with a trailing-window view.

    Parameters
    ----------
    prices : array [n]
        hourly price samples (EUR/MWh).
    window : int
        trailing window length used for online PV estimation.
    start : int
        starting index into the series (index 0 is hour 0 of a day).
    publish_hour : int or None
        local hour at which the day-ahead auction result for the next
        delivery day becomes visible (default 13, the EPEX/Nord Pool
        gate-closure convention). ``None`` disables the publication
        gate and restores unlimited lookahead (backtests that *want*
        perfect foresight must now ask for it explicitly).
    fill : str or None
        ``"ffill"`` carries the last finite price forward over NaN gaps
        in the feed (a dropped exchange message, a faulted scrape) and
        keeps a per-hour staleness counter; ``None`` (default) rejects
        non-finite input loudly, preserving the pre-existing contract
        that a stream never silently serves bad data.
    """

    def __init__(self, prices, window: int = 24 * 28, start: int = 0,
                 publish_hour: Optional[int] = 13,
                 fill: Optional[str] = None):
        self.prices = np.asarray(prices, dtype=np.float64)
        if self.prices.ndim != 1 or self.prices.shape[0] < 2:
            raise ValueError("prices must be a 1-D series")
        if publish_hour is not None and not 0 <= int(publish_hour) < 24:
            raise ValueError("publish_hour must be in [0, 24) or None")
        if fill not in (None, "ffill"):
            raise ValueError(f"unknown fill mode {fill!r}")
        if fill == "ffill":
            self.prices, self.staleness = \
                ffill_with_staleness(self.prices)
        else:
            if not np.isfinite(self.prices).all():
                raise ValueError(
                    "prices contain non-finite samples; pass "
                    "fill='ffill' to carry the last good price forward")
            self.staleness = np.zeros(self.prices.shape, dtype=np.int64)
        self.fill = fill
        self.window = int(window)
        self.publish_hour = (None if publish_hour is None
                             else int(publish_hour))
        self._start = int(start)
        self._hours = 0.0            # fractional hours accumulate exactly

    @property
    def pos(self) -> int:
        return self._start + int(self._hours)

    def __len__(self) -> int:
        return len(self.prices)

    def current(self) -> float:
        return float(self.prices[self.pos % len(self.prices)])

    def trailing(self) -> np.ndarray:
        """The trailing ``window`` samples ending at the current hour."""
        n = len(self.prices)
        idx = (np.arange(self.pos - self.window + 1, self.pos + 1)) % n
        return self.prices[idx]

    def advance(self, hours: float = 1.0) -> None:
        """Advance simulated time; sub-hour ticks accumulate without loss
        (a 0.02 h serving tick still crosses hour boundaries on time)."""
        self._hours += float(hours)

    def reset(self) -> None:
        """Rewind to the construction position for deterministic replay."""
        self._hours = 0.0

    def published_through(self) -> int:
        """Last absolute index whose price is published at the current
        hour under the day-ahead contract: the end of today, plus all of
        tomorrow once the auction result is out (``pos`` hour-of-day >=
        ``publish_hour``)."""
        if self.publish_hour is None:
            return self.pos + len(self.prices)   # effectively unlimited
        pos = self.pos
        day_end = (pos // 24) * 24 + 23
        if pos % 24 >= self.publish_hour:
            day_end += 24
        return day_end

    @property
    def available_lookahead(self) -> int:
        """How many future samples ``peek`` can currently return."""
        return max(0, self.published_through() - self.pos)

    def peek(self, horizon: int) -> np.ndarray:
        """Published future prices, up to ``horizon`` samples.

        Returns *at most* ``min(horizon, available_lookahead)`` samples
        — possibly zero-length early in the day. Callers needing a fixed
        length should pad with a forecast (`repro.energy.forecast`).
        """
        n = len(self.prices)
        horizon = min(int(horizon), self.available_lookahead)
        idx = (np.arange(self.pos + 1, self.pos + 1 + horizon)) % n
        return self.prices[idx]

    def __iter__(self) -> Iterator[float]:
        """Yield one hourly sample per step from the current position,
        advancing the stream — one full pass over the series. Does not
        rewind first; call `reset` for replay from the start."""
        for _ in range(len(self.prices)):
            yield self.current()
            self.advance(1.0)
