"""Energy-market substrate: synthetic spot markets, generation mix,
price streams, forecasting, and loaders for real market data (SMARD CSV).

The paper's inputs are hourly day-ahead price series (SMARD / AEMO /
Electricity Maps, 2024). Those are not available offline, so
`repro.energy.markets` provides a structural generator — diurnal/seasonal
demand, solar/wind supply, AR residual, regime-switching spikes, negative
midday prices — whose parameters are *calibrated* per region against the
paper's published statistics (see `repro.core.calibration`).
"""

from repro.energy.ensemble import block_bootstrap
from repro.energy.forecast import (mae, mase, seasonal_naive,
                                   seasonal_naive_batch, similar_day_ar,
                                   similar_day_ar_batch)
from repro.energy.markets import MarketParams, generate_market, MarketData
from repro.energy.stream import PriceStream
from repro.energy.presets import region_params, REGION_PRESETS

__all__ = [
    "MarketParams",
    "MarketData",
    "generate_market",
    "PriceStream",
    "block_bootstrap",
    "region_params",
    "REGION_PRESETS",
    "seasonal_naive",
    "seasonal_naive_batch",
    "similar_day_ar",
    "similar_day_ar_batch",
    "mae",
    "mase",
]
