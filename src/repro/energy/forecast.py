"""Minimal electricity-price forecasting (EPF) baselines.

The paper defers to the EPF literature [17] for real forecasting; the
runtime only needs a *sane* expectation of near-term prices to set its
threshold before day-ahead prices publish. We implement the two standard
EPF baselines (Lago et al., 2021):

  seasonal-naive  p^(t+h) = p(t + h - 168)   (same hour last week)
  similar-day AR  seasonal-naive + AR(1)-damped recent residual
"""

from __future__ import annotations

import numpy as np


def seasonal_naive(history: np.ndarray, horizon: int,
                   season: int = 168) -> np.ndarray:
    """Repeat the same hour from ``season`` samples ago."""
    history = np.asarray(history)
    if history.shape[0] < season:
        season = 24 if history.shape[0] >= 24 else 1
    idx = np.arange(horizon) - season      # negative: wraps from the end
    return history[idx % history.shape[0]] if season < horizon \
        else history[idx]


def similar_day_ar(history: np.ndarray, horizon: int,
                   season: int = 168, damp: float = 0.9) -> np.ndarray:
    """Seasonal-naive plus exponentially damped last residual."""
    history = np.asarray(history, dtype=np.float64)
    base = seasonal_naive(history, horizon, season)
    season_eff = season if history.shape[0] >= 2 * season else \
        (24 if history.shape[0] >= 48 else 1)
    resid = history[-1] - history[-1 - season_eff]
    correction = resid * damp ** np.arange(1, horizon + 1)
    return base + correction


def mae(pred: np.ndarray, truth: np.ndarray) -> float:
    return float(np.mean(np.abs(np.asarray(pred) - np.asarray(truth))))
