"""Minimal electricity-price forecasting (EPF) baselines.

The paper defers to the EPF literature [17] for real forecasting; the
runtime only needs a *sane* expectation of near-term prices to set its
threshold before day-ahead prices publish. We implement the two standard
EPF baselines (Lago et al., 2021):

  seasonal-naive  p^(t+h) = p(t + h - 168)   (same hour last week)
  similar-day AR  seasonal-naive + AR(1)-damped recent residual

Both are **strictly causal**: a forecast for step ``h`` reads only the
last ``season`` samples of history, tiling the most recent season when
the horizon runs past it (the old ``% len(history)`` wrap reached into
samples the forecaster could never have seen in a walk-forward setting).

The ``*_batch`` variants are the jit-safe ``[..., W] -> [..., H]``
JAX path the live operator loop (`repro.live`) vectorizes over thousands
of controller instances — same index arithmetic, same fallbacks, so the
numpy and batched forecasts agree exactly (pinned in tests/test_live.py).

Accuracy metrics: ``mae`` and the scale-free ``mase`` (MAE scaled by the
in-sample seasonal-naive MAE — Hyndman & Koehler 2006), the standard EPF
skill score: mase < 1 beats the seasonal-naive yardstick.
"""

from __future__ import annotations

import numpy as np


def effective_season(n: int, season: int) -> int:
    """The season actually usable with ``n`` history samples: the
    requested one when it fits, else daily (24), else 1 (persistence).
    Single source of the fallback, shared by the numpy and batched
    paths (and by the live loop's window sizing)."""
    if n >= season:
        return season
    return 24 if n >= 24 else 1


def seasonal_naive(history: np.ndarray, horizon: int,
                   season: int = 168) -> np.ndarray:
    """Repeat the same hour from ``season`` samples ago, tiling the
    *last* season of history when ``horizon > season`` (strictly
    causal — never wraps into samples older than one season, and never
    into the unknown future)."""
    history = np.asarray(history)
    n = history.shape[-1] if history.ndim else history.shape[0]
    season = effective_season(int(n), season)
    idx = n - season + (np.arange(horizon) % season)
    return history[..., idx] if history.ndim > 1 else history[idx]


def similar_day_ar(history: np.ndarray, horizon: int,
                   season: int = 168, damp: float = 0.9) -> np.ndarray:
    """Seasonal-naive plus exponentially damped last residual (the
    residual needs one extra sample: season + 1 history)."""
    history = np.asarray(history, dtype=np.float64)
    base = seasonal_naive(history, horizon, season)
    s = effective_season(history.shape[-1] - 1, season)
    resid = np.asarray(history[..., -1] - history[..., -1 - s])
    correction = resid[..., None] * damp ** np.arange(1, horizon + 1)
    return base + correction


def seasonal_naive_batch(history, horizon: int, season: int = 168):
    """Batched jit-safe seasonal-naive: ``history [..., W] -> [..., H]``.

    Same strictly causal tiling as `seasonal_naive` (``W`` and the
    season are static under jit). The live loop calls this on the
    per-market trailing window every simulated hour."""
    import jax.numpy as jnp
    w = int(history.shape[-1])
    season = effective_season(w, season)
    idx = w - season + (jnp.arange(horizon) % season)
    return jnp.asarray(history)[..., idx]


def similar_day_ar_batch(history, horizon: int, season: int = 168,
                         damp: float = 0.9):
    """Batched jit-safe similar-day AR: ``history [..., W] -> [..., H]``
    — `seasonal_naive_batch` plus the damped last-residual correction,
    matching `similar_day_ar` exactly on equal inputs."""
    import jax.numpy as jnp
    history = jnp.asarray(history)
    base = seasonal_naive_batch(history, horizon, season)
    s = effective_season(int(history.shape[-1]) - 1, season)
    resid = history[..., -1] - history[..., -1 - s]
    correction = resid[..., None] * damp ** jnp.arange(1, horizon + 1,
                                                       dtype=base.dtype)
    return base + correction


def mae(pred: np.ndarray, truth: np.ndarray) -> float:
    return float(np.mean(np.abs(np.asarray(pred) - np.asarray(truth))))


def mase(pred: np.ndarray, truth: np.ndarray, history: np.ndarray,
         season: int = 168) -> float:
    """Mean absolute *scaled* error: MAE over the forecast divided by
    the in-sample MAE of the seasonal-naive forecaster on ``history``
    (Hyndman & Koehler 2006). Scale-free across markets with different
    price levels; < 1 means the forecaster beats seasonal-naive."""
    history = np.asarray(history, np.float64)
    s = effective_season(history.shape[0] - 1, season)
    scale = float(np.mean(np.abs(history[s:] - history[:-s])))
    return mae(pred, truth) / max(scale, 1e-12)
