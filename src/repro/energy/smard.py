"""Loader for SMARD-style market CSV exports (semicolon-separated, German
number formatting) and a generic single-column loader.

The paper sources Germany's 2024 day-ahead prices from SMARD [7]. When the
real export is available, drop it next to your config and point
``--prices path.csv`` at it; every model entry point consumes the result
identically to a synthetic series.

Malformed rows are counted, not silently dropped: both loaders warn when
more than ``max_skip_frac`` of the data rows fail to parse and raise when
*nothing* parses — a mis-pointed ``column`` index fails loudly instead of
returning a short (or empty) series that corrupts every downstream
statistic. Per-load totals are available via ``return_stats=True``.
"""

from __future__ import annotations

import csv
import io
import warnings
from pathlib import Path
from typing import NamedTuple

import numpy as np

from repro import obs
from repro.energy.stream import ffill_with_staleness


class LoadStats(NamedTuple):
    """Row accounting of one CSV load."""

    n_rows: int       # data rows seen (header excluded)
    n_parsed: int     # rows that yielded a finite price
    n_skipped: int    # unparseable / too-short rows
    n_nan: int        # parsed but empty ("-"/blank) price fields
    n_filled: int = 0  # empty hours recovered by fill="ffill"

    @property
    def skip_frac(self) -> float:
        bad = self.n_skipped + self.n_nan - self.n_filled
        return bad / self.n_rows if self.n_rows else 0.0

    def __str__(self) -> str:
        filled = f", {self.n_filled} filled" if self.n_filled else ""
        return (f"{self.n_rows} data rows: {self.n_parsed} parsed, "
                f"{self.n_skipped} unparseable, {self.n_nan} empty"
                f"{filled} ({self.skip_frac:.1%} bad)")


def _emit_load_event(stats: LoadStats, path, what: str,
                     action: str) -> None:
    """Structured telemetry mirror of the loader's warn/raise paths —
    one ``loader.skipped_rows`` event whose payload is exactly the
    `LoadStats` fields (pinned in tests/test_obs.py) plus the loader
    name and the action taken."""
    obs.trace_event("loader.skipped_rows", {
        "loader": what, "path": str(path), "n_rows": stats.n_rows,
        "n_parsed": stats.n_parsed, "n_skipped": stats.n_skipped,
        "n_nan": stats.n_nan, "n_filled": stats.n_filled,
        "skip_frac": stats.skip_frac, "action": action})
    obs.counter("loader.skipped_rows").inc(stats.n_skipped + stats.n_nan)


def _finalize(values: list, stats: LoadStats, path, what: str,
              max_skip_frac: float, return_stats: bool,
              fill: str | None = None):
    if fill not in (None, "ffill"):
        raise ValueError(f"{what}: unknown fill mode {fill!r}")
    arr = np.asarray(values, dtype=np.float64)
    if stats.n_rows and stats.n_parsed == 0:
        _emit_load_event(stats, path, what, "raise")
        raise ValueError(
            f"{what}: no {path} row parsed ({stats}) — "
            "wrong column index or not a price CSV?")
    if fill == "ffill" and np.isnan(arr).any():
        arr, stale = ffill_with_staleness(arr)
        stats = stats._replace(n_filled=int((stale > 0).sum()))
    else:
        arr = arr[~np.isnan(arr)]
    if stats.skip_frac > max_skip_frac:
        _emit_load_event(stats, path, what, "warn")
        warnings.warn(
            f"{what}: skipped rows of {path} ({stats}; over the "
            f"{max_skip_frac:.0%} threshold) — "
            "check the column index / file format", stacklevel=3)
    elif stats.n_skipped or stats.n_nan:
        _emit_load_event(stats, path, what, "ok")
    return (arr, stats) if return_stats else arr


def _parse_german_float(s: str) -> float:
    s = s.strip().replace(".", "").replace(",", ".")
    if s in ("", "-"):
        return float("nan")
    return float(s)


def load_smard_csv(path: str | Path, column: int = -1, *,
                   max_skip_frac: float = 0.05,
                   return_stats: bool = False,
                   fill: str | None = None):
    """Load a SMARD 'Marktdaten' CSV export; returns EUR/MWh samples.

    SMARD exports are ';'-separated with a header row; price columns use
    German decimal commas. ``column`` selects the price column (default:
    last). With ``return_stats=True`` returns ``(prices, LoadStats)``.

    Real SMARD year exports carry empty price fields ("-") on DST-switch
    and outage hours. By default those hours are *dropped* (shortening
    the series and shifting hour-of-day alignment); ``fill="ffill"``
    instead carries the last published price forward, keeps the series
    full-length, and reports the repair count in ``LoadStats.n_filled``
    (filled hours no longer count toward the skip-fraction warning).
    """
    text = Path(path).read_text(encoding="utf-8-sig")
    rows = list(csv.reader(io.StringIO(text), delimiter=";"))
    out: list = []
    n_rows = n_skipped = n_nan = 0
    for row in rows[1:]:
        if not row:
            continue                     # blank line, not a data row
        n_rows += 1
        if len(row) <= abs(column) - (1 if column < 0 else 0):
            n_skipped += 1
            continue
        try:
            v = _parse_german_float(row[column])
        except ValueError:
            n_skipped += 1
            continue
        if np.isnan(v):
            n_nan += 1
        out.append(v)
    stats = LoadStats(n_rows=n_rows, n_parsed=n_rows - n_skipped - n_nan,
                      n_skipped=n_skipped, n_nan=n_nan)
    return _finalize(out, stats, path, "load_smard_csv", max_skip_frac,
                     return_stats, fill=fill)


def load_price_csv(path: str | Path, *, max_skip_frac: float = 0.05,
                   return_stats: bool = False):
    """Generic loader: one price per line, or comma-separated single column.

    Leading unparseable lines (one- or multi-line headers, before the
    first value parses) are expected and not counted against the skip
    threshold; unparseable lines *after* data has started are. A file
    with content but no parseable value at all raises.
    """
    text = Path(path).read_text()
    vals: list = []
    n_rows = n_skipped = n_header = 0
    for line in text.splitlines():
        line = line.strip().split(",")[0]
        if not line:
            continue
        try:
            vals.append(float(line))
        except ValueError:
            if not vals:
                n_header += 1            # still inside the header block
            else:
                n_rows += 1
                n_skipped += 1
            continue
        n_rows += 1
    if not vals and (n_rows or n_header):
        _emit_load_event(
            LoadStats(n_rows=n_rows + n_header, n_parsed=0,
                      n_skipped=n_skipped + n_header, n_nan=0),
            path, "load_price_csv", "raise")
        raise ValueError(
            f"load_price_csv: no {path} line parsed "
            f"({n_header} non-numeric lines) — not a price CSV?")
    stats = LoadStats(n_rows=n_rows, n_parsed=n_rows - n_skipped,
                      n_skipped=n_skipped, n_nan=0)
    return _finalize(vals, stats, path, "load_price_csv", max_skip_frac,
                     return_stats)
