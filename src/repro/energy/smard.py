"""Loader for SMARD-style market CSV exports (semicolon-separated, German
number formatting) and a generic single-column loader.

The paper sources Germany's 2024 day-ahead prices from SMARD [7]. When the
real export is available, drop it next to your config and point
``--prices path.csv`` at it; every model entry point consumes the result
identically to a synthetic series.
"""

from __future__ import annotations

import csv
import io
from pathlib import Path

import numpy as np


def _parse_german_float(s: str) -> float:
    s = s.strip().replace(".", "").replace(",", ".")
    if s in ("", "-"):
        return float("nan")
    return float(s)


def load_smard_csv(path: str | Path, column: int = -1) -> np.ndarray:
    """Load a SMARD 'Marktdaten' CSV export; returns EUR/MWh samples.

    SMARD exports are ';'-separated with a header row; price columns use
    German decimal commas. ``column`` selects the price column (default:
    last).
    """
    text = Path(path).read_text(encoding="utf-8-sig")
    rows = list(csv.reader(io.StringIO(text), delimiter=";"))
    out = []
    for row in rows[1:]:
        if not row or len(row) <= abs(column) - (1 if column < 0 else 0):
            continue
        try:
            out.append(_parse_german_float(row[column]))
        except ValueError:
            continue
    arr = np.asarray(out, dtype=np.float64)
    return arr[~np.isnan(arr)]


def load_price_csv(path: str | Path) -> np.ndarray:
    """Generic loader: one price per line, or comma-separated single column."""
    text = Path(path).read_text()
    vals = []
    for line in text.splitlines():
        line = line.strip().split(",")[0]
        if not line:
            continue
        try:
            vals.append(float(line))
        except ValueError:
            continue  # header
    return np.asarray(vals, dtype=np.float64)
