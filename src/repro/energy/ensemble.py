"""Moving-block bootstrap of price traces — empirical market ensembles.

The fleet engine and the policy tuner consume an [N, T] price matrix
(`repro.fleet.grid.build_grid` accepts one directly). For synthetic
markets, `MarketParams` seeds already give a Monte-Carlo ensemble; for a
*historical* trace (e.g. a SMARD CSV year loaded via
`repro.energy.smard`) there is only one realisation. The moving-block
bootstrap resamples it into N pseudo-series that preserve the
short-range dependence structure (diurnal cycles, spike persistence)
within each block while shuffling the block order — the standard tool
for confidence bands on statistics of dependent series (Kunsch 1989).

Primary use: tune policies on one resample set, validate the tuned
thresholds on held-out resamples (`examples/tune_policies.py`), so the
reported CPC improvement is not an artifact of one spike's placement.
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def block_bootstrap(prices: np.ndarray, n_series: int, *,
                    series_hours: Optional[int] = None,
                    block_hours: int = 7 * 24,
                    circular: bool = True,
                    seed: int = 0) -> np.ndarray:
    """Moving-block bootstrap resamples of a price trace.

    Parameters
    ----------
    prices : [T0] source trace (hourly samples).
    n_series : number of resampled series N.
    series_hours : length T of each resample (default: len(prices)).
    block_hours : block length L. Blocks this long are copied verbatim,
        so dependence up to ~L lags survives; a week (default) spans the
        diurnal and weekday structure of day-ahead markets.
    circular : sample block starts from the whole series, wrapping
        around the end (circular block bootstrap — every sample equally
        likely); ``False`` restricts starts to [0, T0 - L] (classic MBB,
        slight under-weighting of the edges).
    seed : RNG seed; resamples are reproducible.

    Returns a float32 [N, T] matrix that `repro.fleet.grid.build_grid`
    accepts directly as its ``markets`` argument.
    """
    p = np.asarray(prices, np.float64).ravel()
    t0 = p.shape[0]
    if t0 < 2:
        raise ValueError("need a source trace with at least 2 samples")
    t = int(series_hours) if series_hours is not None else t0
    block = int(min(block_hours, t0))
    if block < 1:
        raise ValueError("block_hours must be >= 1")
    if n_series < 1:
        raise ValueError("n_series must be >= 1")

    rng = np.random.default_rng(seed)
    n_blocks = -(-t // block)                      # ceil
    if circular:
        starts = rng.integers(0, t0, size=(n_series, n_blocks))
        idx = (starts[..., None] + np.arange(block)) % t0
    else:
        starts = rng.integers(0, t0 - block + 1, size=(n_series, n_blocks))
        idx = starts[..., None] + np.arange(block)
    out = p[idx].reshape(n_series, n_blocks * block)[:, :t]
    return np.ascontiguousarray(out, dtype=np.float32)
