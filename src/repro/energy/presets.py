"""Per-region market presets.

Calibrated parameters live in ``repro/configs/market_presets.json`` (written
by ``python -m repro.core.fit_presets``, which fits the generator to the
paper's Table II statistics). If a region has not been calibrated yet, a
structurally sensible default with the paper's p_avg is returned.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.regions import PAPER_TABLE2
from repro.energy.markets import MarketParams

_PRESET_PATH = Path(__file__).resolve().parent.parent / "configs" / \
    "market_presets.json"


def _defaults(region: str) -> MarketParams:
    row = PAPER_TABLE2.get(region)
    p_avg = row.p_avg if row is not None else 80.0
    return MarketParams(p_avg=p_avg, seed=abs(hash(region)) % (2 ** 31))


def _load_baked() -> dict:
    if _PRESET_PATH.exists():
        return json.loads(_PRESET_PATH.read_text())
    return {}


REGION_PRESETS = sorted(PAPER_TABLE2.keys())


def region_params(region: str, seed: int | None = None) -> MarketParams:
    """Calibrated ``MarketParams`` for a Table II region."""
    baked = _load_baked().get(region)
    if baked is None:
        params = _defaults(region)
    else:
        params = MarketParams(**baked)
    if seed is not None:
        params = params.replace(seed=seed)
    return params
