"""Structural synthetic electricity-market generator.

Price formation follows the merit-order intuition the paper leans on
(Fig. 1): price ~ f(net load) where net load = demand - renewables.
Components:

  demand      diurnal double-peak + seasonal + weekday/weekend profile
  solar       clear-sky diurnal bell * seasonal * cloud AR process
  wind        slow AR(1) process (multi-day autocorrelation)
  residual    fast AR(1) price noise
  spikes      two-state Markov regime ("doldrums": low wind + peak demand)
              with lognormal multiplicative magnitude — the heavy tail that
              makes k(x) large at small x
  negatives   renewable-surplus hours can push prices below zero

The generator returns both the price series and the fossil/renewable
generation volumes, so the Eq. (30) scenario transform has a consistent
beta_i. Everything is jax.random-driven and reproducible by seed.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MarketParams:
    """Parameters of one synthetic regional market (hourly resolution)."""

    n_hours: int = 8760
    p_avg: float = 80.0          # target mean price [EUR/MWh]; series is
                                 # rescaled to hit this exactly
    # demand shape (relative units; mean 1.0)
    diurnal_amp: float = 0.10    # morning/evening double peak
    seasonal_amp: float = 0.08   # winter > summer
    weekend_drop: float = 0.10
    # supply
    solar_share: float = 0.25    # midday solar depth relative to demand
    solar_seasonal: float = 0.5  # summer/winter solar asymmetry
    cloud_sigma: float = 0.25    # day-scale cloud AR innovations
    wind_share: float = 0.30
    wind_rho: float = 0.995      # ~multi-day autocorrelation at 1 h
    wind_sigma: float = 0.06
    # price formation
    price_sens: float = 1.4      # price response to net-load deviation
                                 # (relative price units per net-load unit)
    noise_rho: float = 0.7
    noise_sigma: float = 0.05
    # spike regime (energy doldrums)
    spike_enter: float = 0.004   # P(calm -> spike) per hour
    spike_stay: float = 0.55     # P(spike persists) per hour
    spike_mu: float = 0.9        # lognormal magnitude of multiplier - 1
    spike_sigma: float = 0.7
    spike_cap: float = 40.0      # cap on the spike multiplier (market cap)
    # negative prices
    neg_sens: float = 1.2        # how hard renewable surplus pushes down
    seed: int = 0

    def replace(self, **kw) -> "MarketParams":
        return dataclasses.replace(self, **kw)


class MarketData(NamedTuple):
    prices: jnp.ndarray     # [n_hours] EUR/MWh
    demand: jnp.ndarray     # [n_hours] relative units (mean ~1)
    fossil: jnp.ndarray     # [n_hours] generation volume (relative)
    renewable: jnp.ndarray  # [n_hours] generation volume (relative)


# numeric fields passed into the jitted body as traced scalars, so
# calibration can sweep parameters without re-tracing.
_THETA_FIELDS = ("p_avg", "diurnal_amp", "seasonal_amp", "weekend_drop",
                 "solar_share", "solar_seasonal", "cloud_sigma",
                 "wind_share", "wind_rho", "wind_sigma", "price_sens",
                 "noise_rho", "noise_sigma", "spike_enter", "spike_stay",
                 "spike_mu", "spike_sigma", "spike_cap", "neg_sens")


def _ar1(key, n, rho, sigma):
    innov = sigma * jax.random.normal(key, (n,))

    def step(carry, eps):
        nxt = rho * carry + jnp.sqrt(1 - rho ** 2) * eps
        return nxt, nxt

    _, out = jax.lax.scan(step, jnp.asarray(0.0), innov)
    return out


def generate_market(params: MarketParams) -> MarketData:
    """Generate one year (or ``n_hours``) of hourly market data."""
    theta = {f: jnp.asarray(getattr(params, f), jnp.float32)
             for f in _THETA_FIELDS}
    return _generate_jit(params.n_hours, params.seed, theta)


from functools import partial  # noqa: E402


@partial(jax.jit, static_argnums=(0,))
def _generate_jit(n_hours: int, seed: int, theta: dict) -> MarketData:
    class _P:  # attribute view over theta for readability below
        pass

    p = _P()
    for f, v in theta.items():
        setattr(p, f, v)
    p.n_hours = n_hours

    key = jax.random.PRNGKey(seed)
    k_cloud, k_wind, k_noise, k_sp_e, k_sp_m = jax.random.split(key, 5)

    t = jnp.arange(p.n_hours)
    hour = t % 24
    day = t // 24
    doy = day % 365

    # --- demand ---------------------------------------------------------
    diurnal = (jnp.exp(-0.5 * ((hour - 8.5) / 2.2) ** 2)
               + 1.15 * jnp.exp(-0.5 * ((hour - 19.0) / 2.6) ** 2))
    diurnal = diurnal / jnp.mean(diurnal) - 1.0
    seasonal = jnp.cos(2 * jnp.pi * (doy - 15) / 365.0)  # peak mid-January
    weekday = day % 7
    weekend = ((weekday == 5) | (weekday == 6)).astype(jnp.float32)
    demand = (1.0 + p.diurnal_amp * diurnal
              + p.seasonal_amp * seasonal
              - p.weekend_drop * weekend)

    # --- renewables ------------------------------------------------------
    sun = jnp.maximum(jnp.cos((hour - 13.0) / 24.0 * 2 * jnp.pi), 0.0) ** 1.5
    sun_season = 1.0 - p.solar_seasonal * jnp.cos(2 * jnp.pi * (doy - 172) / 365.0)
    cloud = jnp.clip(1.0 + _ar1(k_cloud, p.n_hours, 0.97, p.cloud_sigma), 0.1, 1.6)
    solar = p.solar_share * 2.8 * sun * sun_season * cloud
    wind_lvl = _ar1(k_wind, p.n_hours, p.wind_rho, 1.0)   # unit variance
    wind = p.wind_share * jnp.clip(1.0 + (1.4 / 0.06) * p.wind_sigma
                                   * wind_lvl, 0.02, 3.0)
    biomass = 0.08 * jnp.ones_like(solar)
    renewable_raw = solar + wind + biomass

    # --- price formation --------------------------------------------------
    net_load = demand - renewable_raw
    net_dev = net_load - jnp.mean(net_load)
    noise = _ar1(k_noise, p.n_hours, p.noise_rho, p.noise_sigma)
    rel = 1.0 + p.price_sens * net_dev + noise

    # negative prices: when renewables exceed demand, push harder down
    surplus = jnp.maximum(renewable_raw - demand, 0.0)
    rel = rel - p.neg_sens * surplus

    # spike regime: two-state Markov chain
    u_enter = jax.random.uniform(k_sp_e, (p.n_hours,))
    mag = jnp.exp(p.spike_mu + p.spike_sigma
                  * jax.random.normal(k_sp_m, (p.n_hours,)))
    mag = jnp.minimum(mag, p.spike_cap)

    def spike_step(state, inp):
        u, m = inp
        stay = jnp.where(state > 0.5, u < p.spike_stay, False)
        enter = jnp.where(state < 0.5, u < p.spike_enter, False)
        nxt = jnp.where(stay | enter, 1.0, 0.0)
        return nxt, nxt * m

    _, spike_mult = jax.lax.scan(spike_step, jnp.asarray(0.0),
                                 (u_enter, mag))
    # spikes multiply only positive prices (scarcity pricing)
    rel = jnp.where(rel > 0, rel * (1.0 + spike_mult), rel)

    # scale to the exact target mean (k(x) is scale-invariant)
    mean_rel = jnp.mean(rel)
    prices = rel * (p.p_avg / jnp.maximum(mean_rel, 1e-6))

    # generation volumes for Eq. (30): fossil fills residual net load
    fossil = jnp.maximum(demand - renewable_raw, 0.03 * demand)
    return MarketData(prices=prices, demand=demand,
                      fossil=fossil, renewable=renewable_raw)


def diurnal_profile(data: MarketData) -> jnp.ndarray:
    """Average price per hour-of-day (Fig. 1)."""
    n = (data.prices.shape[0] // 24) * 24
    return jnp.mean(data.prices[:n].reshape(-1, 24), axis=0)
