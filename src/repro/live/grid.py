"""LiveGrid — materialise a batched controller-design sweep.

A live controller instance is one scenario row of the offline
`ScenarioGrid` *plus* a controller design: which forecaster it trusts,
how far ahead it plans (horizon), how often it re-solves (cadence), and
which re-solve family it runs (quantile re-resolution of the policy's
shutdown fraction, or a short warm-started gradient re-tune). The cross
product — forecaster x horizon x cadence x family x base row — is
flattened into one row-expanded `ScenarioGrid` (via `take_rows`, so
every engine-facing field is already per-live-row) with the controller
design carried as parallel [B] vectors, and the whole sweep runs as one
jitted scan in `repro.live.controller`.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro import execution
from repro.fleet.grid import PolicySpec, ScenarioGrid

# forecaster id -> name; ids are baked into the controller's stacked
# forecast tensor, so the order here is contractual. "persistence"
# repeats the last published price; "perfect" reads the true future
# trace (the zero-forecast-error control arm of the regret sandwich).
FORECASTERS = ("seasonal_naive", "similar_day_ar", "persistence",
               "perfect")

# family id -> name. "quantile" re-resolves the policy's shutdown
# fraction x against the forecast window's own PV set (the live analog
# of `repro.fleet.grid._resolve_threshold`); "tuned" descends the
# relaxed CPC objective on the forecast window with a few warm-started
# Adam steps per cadence tick (the in-scan analog of
# `repro.tune.optimize(warm_start=...)`).
FAMILIES = ("quantile", "tuned")


@dataclasses.dataclass(frozen=True)
class LiveGrid:
    """Row-expanded controller sweep, ordered
    b = (((base*F + f)*H + h)*C + c)*FAM + fam."""

    grid: ScenarioGrid          # one row per controller instance
    base_row: np.ndarray        # [B] int64 row in the source grid
    forecaster_id: jnp.ndarray  # [B] int32 index into FORECASTERS
    horizon: jnp.ndarray        # [B] int32 planning horizon (hours)
    cadence: jnp.ndarray        # [B] int32 re-solve period (hours)
    family_id: jnp.ndarray      # [B] int32 index into FAMILIES
    x: jnp.ndarray              # [B] shutdown fraction; <= 0: the row
                                #     keeps its offline threshold
    hysteresis: jnp.ndarray     # [B] resume back-off (PolicySpec)
    forecaster_names: tuple = FORECASTERS
    family_names: tuple = ()
    horizons: tuple = ()
    cadences: tuple = ()

    @property
    def n_rows(self) -> int:
        return int(self.base_row.shape[0])

    @property
    def h_max(self) -> int:
        return int(np.max(np.asarray(self.horizon)))

    # fields shared across rows, NOT permuted by take_rows (the design
    # axes' name tables); everything else must be [B]-leading or the
    # generic take_rows refuses to guess
    SHARED_FIELDS = ("forecaster_names", "family_names", "horizons",
                     "cadences")

    def take_rows(self, order: np.ndarray) -> "LiveGrid":
        """Row-permuted view over controller instances — the one
        shape-driven `repro.execution.take_rows` shared with
        `ScenarioGrid.take_rows` (the nested row-expanded grid recurses
        through its own ``take_rows``, keeping its price block shared)
        and `tune.optimizer`'s problem slicing."""
        return execution.take_rows(self, order, shared=self.SHARED_FIELDS,
                                   n_rows=self.n_rows)


def build_live_grid(grid: ScenarioGrid, policies: Sequence[PolicySpec],
                    *, forecasters: Sequence[str] = FORECASTERS,
                    horizons: Sequence[int] = (24,),
                    cadences: Sequence[int] = (1,),
                    families: Sequence[str] = ("quantile",)) -> LiveGrid:
    """Cross an offline `ScenarioGrid` with a controller-design sweep.

    ``policies`` must be the same specs the grid was built from (the
    grid itself stores only resolved thresholds; the live quantile
    family needs each row's shutdown *fraction* back). Fixed-threshold
    and always-on specs get ``x = 0`` — those rows never re-solve and
    ride along as offline-policy control arms.
    """
    if len(policies) != grid.n_policies:
        raise ValueError(f"grid has {grid.n_policies} policies but "
                         f"{len(policies)} specs were given")
    for f in forecasters:
        if f not in FORECASTERS:
            raise ValueError(f"unknown forecaster {f!r} "
                             f"(have {FORECASTERS})")
    for fam in families:
        if fam not in FAMILIES:
            raise ValueError(f"unknown family {fam!r} (have {FAMILIES})")
    horizons = tuple(int(h) for h in horizons)
    cadences = tuple(int(c) for c in cadences)
    if any(h < 2 for h in horizons):
        raise ValueError("horizons must be >= 2 (a 1-hour window has no "
                         "interior quantile)")
    if any(c < 1 for c in cadences):
        raise ValueError("cadences must be >= 1")

    b0 = grid.n_rows
    f_ids = np.asarray([FORECASTERS.index(f) for f in forecasters],
                       np.int32)
    fam_ids = np.asarray([FAMILIES.index(f) for f in families], np.int32)
    base, fi, hi, ci, gi = np.meshgrid(
        np.arange(b0), f_ids, np.asarray(horizons, np.int32),
        np.asarray(cadences, np.int32), fam_ids, indexing="ij")
    base = base.reshape(-1)
    pol = np.asarray(grid.policy_idx, np.int64)[base]
    x = np.asarray([0.0 if p.x is None else max(float(p.x), 0.0)
                    for p in policies], np.float32)[pol]
    hyst = np.asarray([float(p.hysteresis) for p in policies],
                      np.float32)[pol]
    return LiveGrid(
        grid=grid.take_rows(base),
        base_row=base,
        forecaster_id=jnp.asarray(fi.reshape(-1)),
        horizon=jnp.asarray(hi.reshape(-1)),
        cadence=jnp.asarray(ci.reshape(-1)),
        family_id=jnp.asarray(gi.reshape(-1)),
        x=jnp.asarray(x), hysteresis=jnp.asarray(hyst),
        forecaster_names=tuple(forecasters),
        family_names=tuple(families),
        horizons=horizons, cadences=cadences)
