"""Live fleet operator: batched rolling-horizon control under forecast
uncertainty.

Every offline result in this repo tunes and dispatches against a fully
known price year — perfect-foresight numbers the paper never qualifies.
This subsystem is the missing control plane: a receding-horizon
controller that each simulated hour (1) forecasts the next H hours from
the trailing published window (`repro.energy.forecast`, batched),
(2) re-solves shutdown thresholds — and, via `repro.live.fleet`,
cross-site dispatch — against the forecast at a configurable cadence,
and (3) realizes costs on the *true* trace, carrying on/off state,
dwell locks and restart overheads across the horizon boundary. The
whole outer loop is one jitted `lax.scan` over hours, vectorized over
thousands of controller instances (forecaster x horizon x cadence x
policy-family x market grid rows), so a full controller-design sweep is
a single program (`benchmarks/bench_live.py` gates its throughput edge
over a per-hour Python re-plan loop).

Two re-solve paths exist on purpose:

  * the in-scan **families** (`LiveGrid.family_id`): quantile
    re-resolution and a short warm-started Adam descent whose moments
    live in the scan carry — fully batched, one program;
  * the host-level path `repro.tune.optimize(warm_start=...)`, the full
    annealed tuner re-entered from the previous tick's solution —
    demonstrated by ``examples/live_operator.py --retune``, for when
    one fleet's re-tune is worth a host round-trip per cadence tick.

Scoring (`summarize_live`) reports realized CPC, regret vs the
clairvoyant hindsight oracle and vs the offline-tuned policy, forecast
MAE/MASE attribution, and decision churn; every hourly decision lands
in the `repro.obs` trace as ``live.step`` / ``live.result`` events.

  quickstart:  PYTHONPATH=src python examples/live_operator.py --smoke
"""

from repro.live.controller import (LiveConfig, LiveResult, live_backtest)
from repro.live.fleet import LiveFleetResult, live_fleet_dispatch
from repro.live.grid import (FAMILIES, FORECASTERS, LiveGrid,
                             build_live_grid)
from repro.live.report import (LiveSummary, hindsight_cpc, offline_cpc,
                               summarize_live)

__all__ = ["FAMILIES", "FORECASTERS", "LiveConfig", "LiveFleetResult",
           "LiveGrid", "LiveResult", "LiveSummary", "build_live_grid",
           "hindsight_cpc", "live_backtest", "live_fleet_dispatch",
           "offline_cpc", "summarize_live"]
