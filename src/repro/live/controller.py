"""The receding-horizon live controller: one jitted scan over hours,
vectorized over every controller instance of a `LiveGrid`.

Each simulated hour ``t`` (decision first, realization second — the
day-ahead market has published prices through the current hour, so the
trailing window ends *at* ``t`` and the forecast covers ``t+1..t+H``):

  1. **Forecast.** The [N, season+1] trailing window of every market is
     gathered (mod-``T``, circular trace semantics) and all four
     forecasters run batched (`repro.energy.forecast` ``*_batch``
     paths); each row then selects its own forecaster's view of its own
     market with one advanced-indexing gather.
  2. **Re-solve.** On the row's cadence tick, the committed thresholds
     are re-solved against the forecast window: the *quantile* family
     re-resolves the policy's shutdown fraction on the window's PV set
     (a masked descending sort, exactly mirroring
     `repro.fleet.grid._resolve_threshold` at n = horizon), the *tuned*
     family runs ``inner_steps`` warm-started Adam steps on the relaxed
     per-window CPC (the in-scan analog of
     `repro.tune.optimize(warm_start=...)` — Adam moments and step
     counts live in the scan carry, so every cadence tick continues the
     previous descent instead of cold-starting). Rows with ``x <= 0``
     never commit (offline control arms).
  3. **Realize.** The committed thresholds drive one `hard_hour_step`
     at the *true* price; on/off state, restart events and the four
     `FleetScanOut` sums carry across the horizon boundary in the scan
     state, so costs are realized exactly like the offline backtest.

Cost assembly reuses `repro.fleet.engine.fleet_costs` with every
period-extensive quantity scaled by ``hours / T`` — a live window that
covers the full trace with a perfect forecaster therefore reproduces
the offline `backtest` numbers bit for bit (pinned in
tests/test_live.py).

Telemetry follows the `repro.obs` contract: per-hour fleet aggregates
are computed *only* when the static ``telemetry`` flag is set (off
means off — the scan carries no extra outputs), drained as one
``live.step`` io_callback after the scan, and feed nothing back, so
results are bit-identical on vs off.

Faults (`repro.faults`) degrade the controller gracefully instead of
crashing it. Under the static ``faulted`` flag the same scan gains
three in-scan channels (the flag is Python-static, so the zero-fault
program is op-identical to the healthy one):

  * price-feed gaps — decisions read the forward-filled *observed*
    price series (vectorized cummax ffill, staleness tracked per
    market) while costs settle at the true price, mirroring
    `repro.faults.inject._faulted_scan`;
  * forecast blackouts — a fallback ladder replaces the fresh
    forecast: (0) fresh, (1) the last-published window age-shifted
    with persistence tail-padding while it still covers the horizon,
    (2) seasonal-naive recomputed from the observed history once the
    published window has fully aged out, (3) raw persistence when the
    price feed itself is older than a season. Rung occupancy is
    accumulated in-scan and emitted as one ``live.fallback`` event;
  * site outages — a zero capacity multiplier forces the row off
    (state carry included, so recovery re-enters through the normal
    start path and bills the restart overhead); partial multipliers
    derate capacity and draw. Demand surges have no live analog (the
    controller rows are uncoupled) and are ignored here.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.energy.forecast import (seasonal_naive_batch,
                                   similar_day_ar_batch)
from repro.fleet.engine import fleet_costs
from repro.kernels.ref import FleetScanOut, hard_hour_step
from repro.live.grid import LiveGrid


class LiveConfig(NamedTuple):
    """Static controller configuration (hashable — a jit-static arg,
    like `repro.tune.TuneConfig`).

    ``start``/``hours`` select the live window of the trace;
    ``season`` the forecasters' seasonal period (168 = weekly);
    ``inner_*`` the tuned family's per-cadence-tick Adam budget;
    ``churn_tol`` the threshold change (EUR/MWh) that counts as a
    decision churn event."""

    start: int = 0
    hours: int = 336
    season: int = 168
    inner_steps: int = 4
    inner_lr: float = 2.0
    inner_tau: float = 5.0
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    churn_tol: float = 1e-3


class LiveResult(NamedTuple):
    """Per-controller-row outcome of a live run (all [B])."""

    cpc: jax.Array            # realized cost-per-compute on the window
    cpc_ao: jax.Array         # always-on baseline on the same window
    tco: jax.Array
    energy_cost: jax.Array
    restart_cost: jax.Array
    up_hours: jax.Array
    n_starts: jax.Array
    n_stops: jax.Array
    x_realized: jax.Array     # realized shutdown fraction
    p_off_final: jax.Array    # last committed threshold
    threshold_updates: jax.Array  # commits that moved p_off > churn_tol
    mae1: jax.Array           # one-step-ahead forecast MAE
    mae_h: jax.Array          # mean MAE over the full horizon
    mase1: jax.Array          # mae1 / seasonal-naive one-step MAE


def _window_cpc_grad(p_off, fc, hmask, off_level, idle_frac, power,
                     fixed_h, dt, inv_tau):
    """Per-row gradient of the relaxed CPC on the forecast window.

    The window objective is per-hour independent (no hysteresis memory
    — a deliberate simplification of the offline soft scan that keeps
    the in-scan re-tune one sigmoid deep), so grad-of-sum gives every
    row its own gradient in one backward pass."""
    def total(po):
        s = jax.nn.sigmoid((po[:, None] - fc) * inv_tau)
        cap = off_level[:, None] + (1.0 - off_level[:, None]) * s
        draw = cap + idle_frac[:, None] * (1.0 - cap)
        num = fixed_h + dt * power * jnp.sum(
            jnp.where(hmask, draw * fc, 0.0), axis=1)
        den = jnp.maximum(dt * jnp.sum(jnp.where(hmask, cap, 0.0),
                                       axis=1), 1e-9)
        return jnp.sum(num / den)

    return jax.grad(total)(p_off)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "h_max", "telemetry", "faulted"))
def _live_scan(prices, market_idx, fixed, power, period, p_on0, p_off0,
               off_level, idle_frac, forecaster_id, horizon, cadence,
               family_id, x, hysteresis, *, cfg: LiveConfig, h_max: int,
               telemetry: bool = False, faulted: bool = False,
               cap_mult=None, price_ok=None, forecast_ok=None):
    t_total = prices.shape[1]
    b = market_idx.shape[0]
    w = cfg.season + 1                      # window: one season + "now"
    h = h_max
    dt = period / t_total                   # hours per sample, per row
    fixed_h = fixed * (horizon.astype(jnp.float32) / t_total)
    inv_tau = 1.0 / jnp.float32(cfg.inner_tau)
    p_max_rows = jnp.max(prices, axis=1)[market_idx]
    resolvable = x > 0.0
    tuned_row = resolvable & (family_id == 1)
    hmask = (jnp.arange(h, dtype=jnp.int32)[None, :]
             < horizon[:, None])            # [B, H]
    # quantile index, mirroring _resolve_threshold at n = horizon
    hf = horizon.astype(jnp.float32)
    m_q = jnp.clip(jnp.round(x * hf), 1.0, hf - 1.0).astype(jnp.int32)

    if faulted:
        # Observed price series: vectorized causal ffill over feed gaps
        # (cummax of the last-arrival index), staleness in hours. A
        # leading gap falls back to the market's first true price.
        tt = jnp.arange(t_total, dtype=jnp.int32)[None, :]
        last = jax.lax.associative_scan(
            jnp.maximum, jnp.where(price_ok, tt, -1), axis=1)
        p_obs_full = jnp.take_along_axis(prices, jnp.maximum(last, 0),
                                         axis=1)
        p_obs_full = jnp.where(last >= 0, p_obs_full, prices[:, :1])
        stale_full = tt - last                           # [N, T]
        obs_src = p_obs_full
    else:
        obs_src = prices

    def step(carry, t):
        if faulted:
            (on, p_on_c, p_off_c, po_t, m_t, v_t, tc, acc,
             (fc_prev, fc_age, racc, foacc, gapacc)) = carry
        else:
            (on, p_on_c, p_off_c, po_t, m_t, v_t, tc, acc) = carry

        # --- 1. forecast: every forecaster, every market, batched -----
        hist = obs_src[:, (t - w + 1 + jnp.arange(w)) % t_total]  # [N, W]
        truth = prices[:, (t + 1 + jnp.arange(h)) % t_total]     # [N, H]
        f_sn = seasonal_naive_batch(hist, h, cfg.season)
        f_ar = similar_day_ar_batch(hist, h, cfg.season)
        f_p = jnp.broadcast_to(hist[:, -1:], f_sn.shape)
        f_all = jnp.stack([f_sn, f_ar, f_p, truth])      # [4, N, H]
        fc = f_all[forecaster_id, market_idx]            # [B, H]
        truth_rows = truth[market_idx]                   # [B, H]

        if faulted:
            # Degradation ladder: fresh -> age-shifted last-published
            # (persistence-padded tail) -> seasonal-naive on observed
            # history -> raw persistence once the feed itself is stale.
            f_ok_t = forecast_ok[:, t % t_total][market_idx]   # [B]
            stale_t = stale_full[:, t % t_total][market_idx]   # [B]
            age = jnp.where(f_ok_t, 0, fc_age + 1)             # [B]
            shift = jnp.clip(jnp.arange(h, dtype=jnp.int32)[None, :]
                             + age[:, None], 0, h - 1)
            fc_shift = jnp.take_along_axis(fc_prev, shift, axis=1)
            r1 = (~f_ok_t) & (age < h)
            r23 = (~f_ok_t) & (age >= h)
            r3 = r23 & (stale_t > cfg.season)
            r2 = r23 & ~r3
            fc = jnp.where(f_ok_t[:, None], fc,
                 jnp.where(r1[:, None], fc_shift,
                 jnp.where(r2[:, None], f_sn[market_idx],
                           f_p[market_idx])))
            fc_prev = jnp.where(f_ok_t[:, None], fc, fc_prev)
            fc_age = age

        # --- 2. re-solve on the cadence tick --------------------------
        do_commit = (((t - cfg.start) % cadence) == 0) & resolvable

        # quantile family: descending masked sort; -inf padding sinks
        # beyond-horizon samples to the tail, so index m-1 < horizon
        # always hits a real forecast sample
        desc = -jnp.sort(-jnp.where(hmask, fc, -jnp.inf), axis=1)
        q_thr = jnp.take_along_axis(desc, (m_q - 1)[:, None],
                                    axis=1)[:, 0]

        # tuned family: inner_steps warm-started Adam steps on the
        # relaxed window CPC (moments/counters in the carry)
        def inner(k, st):
            po, m, v = st
            g = _window_cpc_grad(po, fc, hmask, off_level, idle_frac,
                                 power, fixed_h, dt, inv_tau)
            g = jnp.where(tuned_row, g, 0.0)
            m = cfg.adam_b1 * m + (1.0 - cfg.adam_b1) * g
            v = cfg.adam_b2 * v + (1.0 - cfg.adam_b2) * g * g
            tck = tc + (k + 1.0)
            mhat = m / (1.0 - cfg.adam_b1 ** tck)
            vhat = v / (1.0 - cfg.adam_b2 ** tck)
            return (po - cfg.inner_lr * mhat
                    / (jnp.sqrt(vhat) + cfg.adam_eps), m, v)

        po_new, m_new, v_new = jax.lax.fori_loop(
            0, cfg.inner_steps, inner, (po_t, m_t, v_t))
        apply_t = do_commit & tuned_row
        po_t = jnp.where(apply_t, po_new, po_t)
        m_t = jnp.where(apply_t, m_new, m_t)
        v_t = jnp.where(apply_t, v_new, v_t)
        tc = jnp.where(apply_t, tc + cfg.inner_steps, tc)

        cand = jnp.where(family_id == 1, po_t, q_thr)
        p_off_new = jnp.where(do_commit, cand, p_off_c)
        p_on_new = jnp.where(
            do_commit,
            p_off_new - (1.0 - hysteresis) * jnp.abs(p_off_new),
            p_on_c)
        churn = (do_commit
                 & (jnp.abs(p_off_new - p_off_c) > cfg.churn_tol))

        # --- 3. realize on the true trace -----------------------------
        p_t = prices[:, t % t_total][market_idx]
        if faulted:
            # decide on the observed (gap-filled) price, settle at the
            # true price; a zero capacity multiplier forces the row off
            # and recovery re-enters through the normal start account
            p_dec = p_obs_full[:, t % t_total][market_idx]
            m_row = cap_mult[:, t % t_total]                   # [B]
            on_new, _, _, _ = hard_hour_step(
                on, p_dec, p_on_new, p_off_new, off_level, idle_frac)
            on_new = jnp.where(m_row > 0.0, on_new, 0.0)
            st_ = jnp.maximum(on_new - on, 0.0)
            cap = off_level + (1.0 - off_level) * on_new
            draw = cap + idle_frac * (1.0 - cap)
            cap = cap * m_row                                  # derate
            draw = draw * m_row
            ok_t = price_ok[:, t % t_total][market_idx]
            racc = racc + jnp.stack(
                [jnp.sum(f_ok_t.astype(jnp.float32)),
                 jnp.sum(r1.astype(jnp.float32)),
                 jnp.sum(r2.astype(jnp.float32)),
                 jnp.sum(r3.astype(jnp.float32))])
            foacc = foacc + jnp.sum((m_row <= 0.0).astype(jnp.float32))
            gapacc = gapacc + jnp.sum((~ok_t).astype(jnp.float32))
        else:
            on_new, st_, cap, draw = hard_hour_step(
                on, p_t, p_on_new, p_off_new, off_level, idle_frac)
        stop = jnp.maximum(on - on_new, 0.0)

        err1 = jnp.abs(fc[:, 0] - truth_rows[:, 0])
        err_h = (jnp.sum(jnp.where(hmask, jnp.abs(fc - truth_rows), 0.0),
                         axis=1) / hf)
        naive1 = jnp.abs(f_sn[:, 0] - truth[:, 0])[market_idx]

        acc = (acc[0] + draw * p_t, acc[1] + cap, acc[2] + st_,
               acc[3] + st_ * p_t, acc[4] + stop,
               acc[5] + churn.astype(jnp.float32),
               acc[6] + err1, acc[7] + err_h, acc[8] + naive1)
        if faulted:
            carry = (on_new, p_on_new, p_off_new, po_t, m_t, v_t, tc,
                     acc, (fc_prev, fc_age, racc, foacc, gapacc))
        else:
            carry = (on_new, p_on_new, p_off_new, po_t, m_t, v_t, tc,
                     acc)
        if telemetry:
            ys = (jnp.sum(power * cap), jnp.sum(power * draw * p_t),
                  jnp.sum(st_) + jnp.sum(stop), jnp.mean(err1),
                  jnp.sum(do_commit.astype(jnp.float32)))
        else:
            ys = None
        return carry, ys

    zeros = jnp.zeros((b,), jnp.float32)
    po0 = jnp.where(jnp.isfinite(p_off0), p_off0, p_max_rows)
    init = (jnp.ones((b,), jnp.float32), p_on0, p_off0, po0,
            zeros, zeros, zeros, tuple(zeros for _ in range(9)))
    if faulted:
        # last-published window starts fully aged so a blackout at the
        # first hour already lands on the seasonal-naive rung
        init = init + ((jnp.zeros((b, h), jnp.float32),
                        jnp.full((b,), h, jnp.int32),
                        jnp.zeros((4,), jnp.float32),
                        jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)),)
    ts = cfg.start + jnp.arange(cfg.hours, dtype=jnp.int32)
    carry_f, ys = jax.lax.scan(step, init, ts)
    fstats = carry_f[8][2:] if faulted else None
    acc = carry_f[7]
    p_off_f = carry_f[2]
    if telemetry:
        obs.drain("live.step", on_mw=ys[0], cost_rate=ys[1],
                  transitions=ys[2], abs_err1=ys[3], commits=ys[4])
    scan_out = FleetScanOut(draw_price_sum=acc[0], up_units=acc[1],
                            n_starts=acc[2], restart_price_sum=acc[3])
    return scan_out, acc[4:], p_off_f, fstats


def live_backtest(lgrid: LiveGrid, cfg: LiveConfig = LiveConfig(), *,
                  faults=None) -> LiveResult:
    """Run every controller instance of ``lgrid`` over the live window
    in one jitted scan and assemble realized costs.

    Window accounting: every period-extensive quantity (fixed cost, the
    accounting period itself) is scaled by ``hours / T``, so per-sample
    hours ``dt = period / T`` match the offline backtest and a window
    covering the whole trace reproduces `repro.fleet.engine.backtest`
    exactly. Indices wrap mod ``T`` (circular trace): the trailing
    window before hour ``season`` reads the end of the trace, which is
    the periodic-boundary convention of the synthetic markets.

    ``faults`` is an optional `repro.faults.FaultTrace` (compiled here
    onto B rows x N markets x T trace hours — outage targets index
    controller *rows*, fault hours are absolute trace hours) or
    pre-compiled `repro.faults.FaultMasks`. None or a trivial schedule
    takes the healthy scan, bit-identical to omitting the argument;
    otherwise the degradation ladder engages (module docstring) and a
    ``live.fallback`` event reports rung occupancy.
    """
    grid = lgrid.grid
    if cfg.hours < 1:
        raise ValueError("LiveConfig.hours must be >= 1")
    telemetry = obs.enabled()
    masks = None
    if faults is not None and getattr(faults, "events", True):
        from repro.faults.inject import emit_fault_events, resolve_masks
        b = grid.n_rows
        t_total = grid.n_hours
        masks = resolve_masks(faults, b, int(grid.prices.shape[0]),
                              t_total)
        if masks.is_trivial:
            masks = None
        else:
            emit_fault_events(faults, masks, scope="live")
    faulted = masks is not None
    fault_kw = {}
    if faulted:
        fault_kw = dict(
            cap_mult=jnp.asarray(masks.cap_mult, jnp.float32),
            price_ok=jnp.asarray(masks.price_ok),
            forecast_ok=jnp.asarray(masks.forecast_ok))
    scan_out, extras, p_off_f, fstats = _live_scan(
        grid.prices, grid.market_idx, grid.fixed, grid.power, grid.period,
        grid.p_on, grid.p_off, grid.off_level, grid.idle_frac,
        lgrid.forecaster_id, lgrid.horizon, lgrid.cadence,
        lgrid.family_id, lgrid.x, lgrid.hysteresis,
        cfg=cfg, h_max=lgrid.h_max, telemetry=telemetry,
        faulted=faulted, **fault_kw)
    n_stops, churn, err1, err_h, naive1 = extras
    if faulted and telemetry:
        import numpy as np
        rungs = np.asarray(fstats[0])
        obs.trace_event("live.fallback", {
            "fresh": int(rungs[0]), "stale_shift": int(rungs[1]),
            "seasonal_naive": int(rungs[2]),
            "persistence": int(rungs[3]),
            "forced_off_row_hours": int(fstats[1]),
            "stale_price_row_hours": int(fstats[2]),
            "rows": grid.n_rows, "hours": cfg.hours})
        obs.counter("live.fallback_hours").inc(
            int(rungs[1] + rungs[2] + rungs[3]))

    t_total = grid.n_hours
    frac = cfg.hours / t_total
    window = (cfg.start + jnp.arange(cfg.hours)) % t_total
    price_sum = jnp.sum(grid.prices[:, window], axis=1)[grid.market_idx]
    costs = fleet_costs(
        scan_out, price_sum=price_sum, fixed=grid.fixed * frac,
        power=grid.power, period=grid.period * frac,
        restart_energy_mwh=grid.restart_energy_mwh,
        restart_time_h=grid.restart_time_h, n_samples=cfg.hours)
    mae1 = err1 / cfg.hours
    return LiveResult(
        cpc=costs.cpc, cpc_ao=costs.cpc_ao, tco=costs.tco,
        energy_cost=costs.energy_cost, restart_cost=costs.restart_cost,
        up_hours=costs.up_hours, n_starts=scan_out.n_starts,
        n_stops=n_stops,
        x_realized=1.0 - scan_out.up_units / cfg.hours,
        p_off_final=p_off_f, threshold_updates=churn,
        mae1=mae1, mae_h=err_h / cfg.hours,
        mase1=mae1 / jnp.maximum(naive1 / cfg.hours, 1e-9))
