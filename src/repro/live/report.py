"""Live-run scoring: regret vs the hindsight oracle and vs the
offline-tuned policy, grouped over the controller-design sweep.

The *hindsight oracle* is the clairvoyant two-level relaxation: with
the whole live window known, an operator free to pick any set of
full-capacity hours (no hysteresis, no dwell, no restart overheads)
runs at capacity in exactly the k cheapest hours for some k — so the
optimum is an exact 1-D scan over k on each market's sorted window.
This lower-bounds every realizable threshold policy *when restart
costs are non-negative* (a restart priced at a negative-price hour
could otherwise earn money the oracle ignores); the acceptance grid
therefore uses restart-free policies, and the bound is asserted row by
row in tests/test_live.py.

The *offline-tuned* comparison simply re-runs the offline backtest on
the live window with the grid's own (full-trace-resolved) thresholds —
what the operator would have realized by never reacting to the stream.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.fleet.engine import backtest
from repro.live.controller import LiveConfig, LiveResult
from repro.live.grid import FAMILIES, FORECASTERS, LiveGrid


def _window_index(cfg: LiveConfig, t_total: int) -> np.ndarray:
    return (cfg.start + np.arange(cfg.hours)) % t_total


def hindsight_cpc(lgrid: LiveGrid, cfg: LiveConfig,
                  chunk_rows: int = 4096) -> np.ndarray:
    """[B] clairvoyant lower-bound CPC per controller row (see module
    docstring; requires non-negative restart costs to be a bound)."""
    grid = lgrid.grid
    t = cfg.hours
    idx = _window_index(cfg, grid.n_hours)
    prices_w = np.asarray(grid.prices, np.float64)[:, idx]   # [N, T]
    cheap = np.concatenate(
        [np.zeros((prices_w.shape[0], 1)),
         np.cumsum(np.sort(prices_w, axis=1), axis=1)], axis=1)  # [N,T+1]
    total = prices_w.sum(axis=1)                             # [N]

    frac = t / grid.n_hours
    mi = np.asarray(grid.market_idx, np.int64)
    fixed = np.asarray(grid.fixed, np.float64) * frac
    power = np.asarray(grid.power, np.float64)
    dt = np.asarray(grid.period, np.float64) / grid.n_hours
    lvl = np.asarray(grid.off_level, np.float64)
    idle = np.asarray(grid.idle_frac, np.float64)

    out = np.empty(lgrid.n_rows, np.float64)
    k = np.arange(t + 1, dtype=np.float64)                   # [T+1]
    for lo in range(0, lgrid.n_rows, chunk_rows):
        sl = slice(lo, lo + chunk_rows)
        draw_off = (lvl[sl] + idle[sl] * (1.0 - lvl[sl]))[:, None]
        # energy when ON occupies the k cheapest hours, OFF the rest
        energy = draw_off * total[mi[sl], None] \
            + (1.0 - draw_off) * cheap[mi[sl]]               # [b, T+1]
        up = lvl[sl][:, None] * t + (1.0 - lvl[sl])[:, None] * k[None]
        cpc_k = (fixed[sl][:, None]
                 + dt[sl][:, None] * power[sl][:, None] * energy) \
            / np.maximum(dt[sl][:, None] * up, 1e-9)
        out[sl] = cpc_k.min(axis=1)
    return out


def offline_cpc(lgrid: LiveGrid, cfg: LiveConfig) -> np.ndarray:
    """[B] CPC the grid's offline (full-trace) thresholds realize on the
    live window — the never-react baseline, via the offline engine on a
    window-sliced grid with the same ``hours / T`` cost scaling as
    `repro.live.controller.live_backtest`."""
    grid = lgrid.grid
    idx = _window_index(cfg, grid.n_hours)
    frac = cfg.hours / grid.n_hours
    grid_w = dataclasses.replace(
        grid, prices=jnp.asarray(np.asarray(grid.prices)[:, idx]),
        fixed=grid.fixed * frac, period=grid.period * frac)
    return np.asarray(backtest(grid_w, use_pallas=False).cpc, np.float64)


@dataclasses.dataclass(frozen=True)
class LiveSummary:
    """Scored live run: per-row arrays plus the grouped design table."""

    cpc_live: np.ndarray       # [B]
    cpc_oracle: np.ndarray     # [B] hindsight lower bound
    cpc_offline: np.ndarray    # [B] never-react baseline
    regret_oracle: np.ndarray  # [B] cpc_live / cpc_oracle - 1
    regret_offline: np.ndarray  # [B] cpc_live / cpc_offline - 1
    table: tuple               # grouped by (forecaster, horizon,
                               # cadence, family), mean stats per group

    def render_table(self) -> str:
        head = (f"{'forecaster':>16} {'H':>4} {'cad':>4} {'family':>9} "
                f"{'cpc':>9} {'vs oracle':>10} {'vs offline':>11} "
                f"{'mae1':>8} {'churn':>7}")
        lines = [head, "-" * len(head)]
        for r in self.table:
            lines.append(
                f"{r['forecaster']:>16} {r['horizon']:>4d} "
                f"{r['cadence']:>4d} {r['family']:>9} "
                f"{r['cpc']:>9.3f} {r['regret_oracle']:>9.1%} "
                f"{r['regret_offline']:>10.1%} {r['mae1']:>8.2f} "
                f"{r['churn']:>7.1f}")
        return "\n".join(lines)


def summarize_live(lgrid: LiveGrid, result: LiveResult,
                   cfg: LiveConfig) -> LiveSummary:
    """Score a `LiveResult` against both reference points and group the
    sweep by controller design. Emits the ``live.result`` trace event."""
    cpc_live = np.asarray(result.cpc, np.float64)
    cpc_o = hindsight_cpc(lgrid, cfg)
    cpc_f = offline_cpc(lgrid, cfg)
    reg_o = cpc_live / np.maximum(cpc_o, 1e-12) - 1.0
    reg_f = cpc_live / np.maximum(cpc_f, 1e-12) - 1.0

    fid = np.asarray(lgrid.forecaster_id)
    hor = np.asarray(lgrid.horizon)
    cad = np.asarray(lgrid.cadence)
    fam = np.asarray(lgrid.family_id)
    mae1 = np.asarray(result.mae1, np.float64)
    churn = np.asarray(result.threshold_updates, np.float64)
    rows = []
    for f, h, c, g in sorted({(int(a), int(b), int(d), int(e))
                              for a, b, d, e in zip(fid, hor, cad, fam)}):
        sel = (fid == f) & (hor == h) & (cad == c) & (fam == g)
        rows.append({
            "forecaster": FORECASTERS[f],
            "horizon": h, "cadence": c,
            "family": FAMILIES[g],
            "cpc": float(cpc_live[sel].mean()),
            "regret_oracle": float(reg_o[sel].mean()),
            "regret_offline": float(reg_f[sel].mean()),
            "mae1": float(mae1[sel].mean()),
            "churn": float(churn[sel].mean()),
            "rows": int(sel.sum())})
    rows.sort(key=lambda r: r["cpc"])

    summary = LiveSummary(cpc_live=cpc_live, cpc_oracle=cpc_o,
                          cpc_offline=cpc_f, regret_oracle=reg_o,
                          regret_offline=reg_f, table=tuple(rows))
    if obs.enabled():
        obs.trace_event("live.result", {
            "rows": int(lgrid.n_rows), "hours": int(cfg.hours),
            "cpc_mean": float(cpc_live.mean()),
            "regret_oracle_mean": float(reg_o.mean()),
            "regret_offline_mean": float(reg_f.mean()),
            "mae1_mean": float(mae1.mean()),
            "churn_total": float(churn.sum()),
            "best": rows[0] if rows else None})
        obs.gauge("live.regret_oracle_mean").set(float(reg_o.mean()))
        obs.counter("live.runs").inc()
    return summary
