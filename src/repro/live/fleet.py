"""Live cross-site dispatch: commit the first hour, plan the rest.

The offline dispatcher (`repro.dispatch`) water-fills a *known* [S, T]
price block. The live loop instead, each hour: re-solves the per-site
shutdown thresholds against the forecast window (quantile family, at
the configured cadence), realizes each site's on/off state at the TRUE
current price (day-ahead — the current hour is always published),
commits one `dispatch_alloc_hour` fill on the true prices, then rolls a
full forecast-horizon plan (`plan_on_window` + `dispatch_window`) from
the committed state to measure *re-plan churn*: how much the committed
allocation deviates from what the previous hour's plan promised for
this hour. Dwell locks and the committed allocation carry across the
horizon boundary in the scan state, exactly like the offline scan
carry.

Two deliberate divergences from the offline path, both forced by
running inside jit:

  * infeasibility cannot raise mid-scan — demand above fleet
    availability is *shed* (the fill already caps at total width) and
    reported as ``shed_mwh`` instead of `DispatchInfeasible`;
  * the segment sort runs in-jit on the traced prices
    (`segment_keys_jnp`); ordering matches the host sort whenever
    prices are distinct at f32 (tests pin allocation agreement with
    `dispatch_ref` on the never-re-solve path).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.energy.forecast import seasonal_naive_batch
from repro.kernels.live_window import (dispatch_window, plan_on_window,
                                       segment_keys_jnp, segment_rank_jnp)
from repro.kernels.ref import dispatch_alloc_hour, hard_hour_step


class LiveFleetResult(NamedTuple):
    """Outcome of a live dispatch run over one fleet of S sites."""

    alloc_mw: jax.Array       # [S, hours] committed allocation
    cpc: jax.Array            # (fixed + energy + migration) / delivered
    energy_cost: jax.Array
    migration_cost: jax.Array
    migration_mw: jax.Array   # matched in/out flow, like summarize_alloc
    delivered_mwh: jax.Array
    shed_mwh: jax.Array       # demand the fleet could not place
    replan_mw: jax.Array      # sum_t |commit_t - plan_{t-1}(t)|
    p_off_final: jax.Array    # [S] last committed thresholds
    # work-ledger economics over the sampled demand draws (see
    # `live_fleet_dispatch`'s ``workload``): {"served_mwh",
    # "dropped_mwh", "deferred_mwh_h", "cost" (all [n_draws]),
    # "cpc_p10"/"cpc_p50"/"cpc_p90" (floats)} — None without a Workload
    workload: Optional[dict] = None


@functools.partial(jax.jit, static_argnames=(
    "start", "hours", "horizon", "cadence", "season", "min_dwell"))
def _live_fleet_scan(prices, power, p_on0, p_off0, off_level, idle_frac,
                     x, demand, migrate_cost, span, *, start: int,
                     hours: int, horizon: int, cadence: int, season: int,
                     min_dwell: int):
    s, t_total = prices.shape
    w = season + 1
    xq = jnp.asarray(x, jnp.float32)
    resolvable = xq > 0.0
    hq = float(horizon)
    m_idx = jnp.clip(jnp.round(xq * hq), 1.0, hq - 1.0).astype(jnp.int32)

    def step(carry, i):
        on, p_on_c, p_off_c, prev, dwell, plan_next = carry
        t = start + i
        hist = prices[:, (t - w + 1 + jnp.arange(w)) % t_total]  # [S, W]
        fc = seasonal_naive_batch(hist, horizon, season)         # [S, H]

        do_commit = (i % cadence) == 0
        desc = -jnp.sort(-fc, axis=1)
        q_thr = jnp.take_along_axis(desc, (m_idx - 1)[:, None],
                                    axis=1)[:, 0]
        commit_thr = do_commit & resolvable
        p_off_new = jnp.where(commit_thr, q_thr, p_off_c)
        p_on_new = jnp.where(commit_thr, q_thr, p_on_c)

        # realize site availability at the true (published) price
        p_t = prices[:, t % t_total]
        on_new, _, cap, _ = hard_hour_step(on, p_t, p_on_new, p_off_new,
                                           off_level, idle_frac)
        avail = power * cap
        d_t = demand[i]

        # commit this hour on true prices
        order, rank = segment_rank_jnp(
            segment_keys_jnp(p_t, migrate_cost, span))
        alloc, dwell = dispatch_alloc_hour(prev, dwell, avail, order,
                                           rank, d_t,
                                           min_dwell=min_dwell)

        # plan the forecast horizon from the committed state: planned
        # availability rolls the same state machine over the forecast,
        # planned demand repeats the profile (wrapping the live window)
        _, cap_w, _ = plan_on_window(on_new, fc, p_on_new, p_off_new,
                                     off_level, idle_frac)
        avail_w = power[:, None] * cap_w
        keys_w = segment_keys_jnp(fc.T, migrate_cost, span)      # [H, 3S]
        d_w = demand[(i + 1 + jnp.arange(horizon)) % hours]
        plan_w, _, _ = dispatch_window(alloc, dwell, avail_w, keys_w,
                                       d_w, min_dwell=min_dwell)

        replan = jnp.where(i == 0, 0.0,
                           jnp.sum(jnp.abs(alloc - plan_next)))
        ys = (alloc, jnp.sum(alloc * p_t), jnp.maximum(
            d_t - jnp.sum(alloc), 0.0), replan)
        return ((on_new, p_on_new, p_off_new, alloc, dwell,
                 plan_w[:, 0]), ys)

    zeros = jnp.zeros((s,), jnp.float32)
    init = (jnp.ones((s,), jnp.float32), p_on0, p_off0, zeros, zeros,
            zeros)
    carry, (alloc_t, energy_t, shed_t, replan_t) = jax.lax.scan(
        step, init, jnp.arange(hours, dtype=jnp.int32))
    return (alloc_t.T, energy_t, shed_t, replan_t, carry[2])


def live_fleet_dispatch(prices, power, p_on, p_off, off_level, idle_frac,
                        x, demand=None, *, start: int = 0,
                        hours: int = 168,
                        horizon: int = 24, cadence: int = 1,
                        season: int = 168, migrate_cost: float = 0.0,
                        min_dwell: int = 0, fixed: float = 0.0,
                        workload=None, faults=None) -> LiveFleetResult:
    """Run the live dispatch loop over one fleet.

    prices: [S, T] per-site market prices; power/p_on/p_off/off_level/
    idle_frac/x: [S] per-site policy state (``x <= 0``: the site keeps
    its offline thresholds — pass the full offline thresholds and
    ``x = 0`` everywhere with ``cadence >= hours`` to reproduce the
    offline `dispatch_ref` path); demand: scalar MW or [hours] profile.
    Cost accounting mirrors `repro.dispatch.summarize_alloc` (matched
    in/out migration flow; hour 0 placement is not a move).

    ``workload`` (a `repro.workload.Workload`) makes ``demand``
    optional: the loop then plans against the workload's *mean* demand
    profile over the live window, and afterwards replays every sampled
    demand draw through the hard work ledger
    (`repro.workload.replay_ledger`) against the hour-by-hour
    *delivered* fleet allocation — `LiveFleetResult.workload` reports
    served/deferred/dropped totals per draw plus CPC p10/p50/p90 over
    the draws. ``faults`` (a demand-surge schedule, see
    `repro.faults`) perturbs the arrival intensity of the live window,
    so live rows feel surges in the request process itself.
    """
    prices = jnp.asarray(prices, jnp.float32)
    s, t_total = prices.shape
    if horizon < 2:
        raise ValueError("horizon must be >= 2")
    mult = None
    if workload is not None and faults is not None:
        from repro.faults.inject import emit_fault_events, resolve_masks
        masks = resolve_masks(faults, s, s, int(start) + int(hours))
        emit_fault_events(faults, masks, scope="live.workload")
        m = np.asarray(masks.demand_mult, np.float64)
        mult = None if np.all(m == 1.0) else m
    if demand is None:
        if workload is None:
            raise ValueError("live_fleet_dispatch: pass demand= or a "
                             "workload= to derive it from")
        demand = workload.mean_demand_mw(int(start) + int(hours),
                                         mult)[start:start + hours]
    demand = np.asarray(demand, np.float32)
    if demand.ndim == 0:
        demand_h = np.broadcast_to(demand, (hours,))
    elif demand.shape == (hours,):
        demand_h = demand
    else:
        raise ValueError(f"demand must be a scalar or a length-{hours} "
                         f"profile, got shape {demand.shape}")
    span = float(jnp.max(prices) - jnp.min(prices)) \
        + abs(float(migrate_cost)) + 1.0
    bcast = lambda v: jnp.broadcast_to(  # noqa: E731
        jnp.asarray(v, jnp.float32), (s,))
    alloc, energy_t, shed_t, replan_t, p_off_f = _live_fleet_scan(
        prices, bcast(power), bcast(p_on), bcast(p_off),
        bcast(off_level), bcast(idle_frac), bcast(x),
        jnp.asarray(demand_h), jnp.float32(migrate_cost),
        jnp.float32(span), start=int(start), hours=int(hours),
        horizon=int(horizon), cadence=int(cadence), season=int(season),
        min_dwell=int(min_dwell))

    a = alloc
    prev = jnp.concatenate([jnp.zeros_like(a[:, :1]), a[:, :-1]], axis=1)
    delta = a - prev
    moved = jnp.minimum(jnp.sum(jnp.clip(delta, 0.0, None), axis=0),
                        jnp.sum(jnp.clip(-delta, 0.0, None), axis=0))
    migration_mw = jnp.sum(moved)
    energy = jnp.sum(energy_t)
    delivered = jnp.sum(a)
    migration_cost = migrate_cost * migration_mw
    wl_stats = None
    if workload is not None:
        wl_stats = _replay_workload(
            workload, np.asarray(alloc), mult, start=int(start),
            hours=int(hours),
            fleet_cost=float(fixed + energy + migration_cost))
    return LiveFleetResult(
        alloc_mw=alloc,
        cpc=(fixed + energy + migration_cost)
        / jnp.maximum(delivered, 1e-9),
        energy_cost=energy, migration_cost=migration_cost,
        migration_mw=migration_mw, delivered_mwh=delivered,
        shed_mwh=jnp.sum(shed_t), replan_mw=jnp.sum(replan_t),
        p_off_final=p_off_f, workload=wl_stats)


def _replay_workload(workload, alloc: np.ndarray,
                     mult: Optional[np.ndarray], *, start: int,
                     hours: int, fleet_cost: float) -> dict:
    """Hard-ledger replay of every sampled demand draw against the
    committed hour-by-hour fleet allocation (post-hoc, host-side — the
    live scan itself is untouched). Costing mirrors
    `repro.workload.WorkloadResult`: fleet bill + SLO-priced backlog +
    VoLL-priced drops, per served MWh."""
    from repro.workload import replay_ledger
    draws = workload.sample_demand_mw(start + hours, mult)[:,
                                                           start:
                                                           start + hours]
    cap = np.sum(alloc, axis=0).astype(np.float64)      # MWh per hour
    served = np.empty(draws.shape[0])
    dropped = np.empty(draws.shape[0])
    backlog = np.empty(draws.shape[0])
    for g in range(draws.shape[0]):
        rep = replay_ledger(draws[g], cap,
                            deadline=int(workload.deadline_h),
                            bound=float(workload.queue_bound_mwh))
        served[g] = np.sum(rep.served)
        dropped[g] = np.sum(rep.dropped)
        backlog[g] = np.sum(rep.backlog)
    cost = (fleet_cost
            + float(workload.slo_penalty_eur_mwh) * backlog
            + float(workload.relief.voll_eur_mwh) * dropped)
    cpc = cost / np.maximum(served, 1e-9)
    p10, p50, p90 = np.quantile(cpc, [0.1, 0.5, 0.9])
    return {"served_mwh": served, "dropped_mwh": dropped,
            "deferred_mwh_h": backlog, "cost": cost,
            "cpc_p10": float(p10), "cpc_p50": float(p50),
            "cpc_p90": float(p90)}
