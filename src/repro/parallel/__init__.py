from repro.parallel.axes import (
    LogicalRules,
    TRAIN_RULES,
    SSM_PREFILL_RULES,
    DECODE_RULES,
    SINGLE_DEVICE_RULES,
    axis_size,
    constrain,
    current_mesh,
    current_rules,
    logical_to_spec,
    row_mesh,
    use_sharding,
)

__all__ = [
    "LogicalRules",
    "TRAIN_RULES",
    "SSM_PREFILL_RULES",
    "DECODE_RULES",
    "SINGLE_DEVICE_RULES",
    "axis_size",
    "constrain",
    "current_mesh",
    "current_rules",
    "logical_to_spec",
    "row_mesh",
    "use_sharding",
]
