"""Logical-axis sharding system for the (pod, data, model) production mesh.

Model code never names mesh axes directly: tensors are annotated with
*logical* axis names (``constrain(x, "batch", "seq", None)``) and a rule set
maps logical names to mesh axes. Rule sets differ per execution phase:

  TRAIN_RULES         batch over (pod, data); Megatron-style sequence
                      parallelism between blocks (seq over model); heads /
                      ffn / vocab over model; fsdp (param embed dim) over data
  SSM_PREFILL_RULES   like TRAIN but seq unsharded (SSD chunk scan carries
                      sequential state along seq; sharding it would force
                      GSPMD to serialise)
  DECODE_RULES        batch over (pod, data); no SP (seq axis = cache
                      positions, sharded over model only for attention KV)
  SINGLE_DEVICE_RULES everything replicated (smoke tests, CPU)

Without an active mesh (``use_sharding`` context) every annotation is an
identity, so the same model code runs on one CPU device in tests.
"""

from __future__ import annotations

import contextlib
import functools
import threading
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.6: public, check_vma kw
    shard_map = jax.shard_map
    SHARD_MAP_NOCHECK = {"check_vma": False}
except AttributeError:                  # older jax: experimental, check_rep
    from jax.experimental.shard_map import shard_map
    SHARD_MAP_NOCHECK = {"check_rep": False}

LogicalRules = Mapping[str, Optional[Sequence[str] | str]]

# fsdp: weights' embed dim sharded over data (ZeRO-3 style gather at use)
TRAIN_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": "model",            # sequence parallelism between blocks
    "seq_noshard": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": None,             # activation embed dim
    "embed_p": "data",         # parameter embed dim (fsdp)
    "ffn": "model",
    "vocab": "model",
    "experts": None,
    "cap": None,
    "state": None,
    "layers": None,
    "cache_seq": "model",
    "apps": None,
}

SSM_PREFILL_RULES: LogicalRules = dict(TRAIN_RULES, seq=None)

DECODE_RULES: LogicalRules = dict(TRAIN_RULES, seq=None)

SINGLE_DEVICE_RULES: LogicalRules = {k: None for k in TRAIN_RULES}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: LogicalRules = SINGLE_DEVICE_RULES


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: LogicalRules):
    """Activate a mesh + logical rule set for model code built inside."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> LogicalRules:
    return _CTX.rules


def axis_size(name: str) -> int:
    """Size of a mesh axis under the active mesh (1 if none)."""
    mesh = _CTX.mesh
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    rules: Optional[LogicalRules] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """Translate logical axis names to a PartitionSpec under ``rules``.

    Mesh axes absent from ``mesh`` (or the active mesh) are dropped — the
    same rule set serves the 2x16x16 multi-pod mesh (with its "pod" axis)
    and the 16x16 single-pod mesh.
    """
    rules = rules if rules is not None else _CTX.rules
    mesh = mesh if mesh is not None else _CTX.mesh
    spec = []
    used: set[str] = set()
    for name in logical_axes:
        if name is None:
            spec.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            spec.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a not in used
                     and (mesh is None or a in mesh.shape))
        used.update(axes)
        spec.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*spec)


def sanitized_spec(shape: Sequence[int],
                   logical_axes: Sequence[Optional[str]],
                   rules: Optional[LogicalRules] = None,
                   mesh: Optional[Mesh] = None) -> P:
    """`logical_to_spec` with divisibility enforcement: mesh axes that do
    not evenly divide the corresponding dim are dropped (required for jit
    argument shardings and shard_map in_specs)."""
    mesh = mesh if mesh is not None else _CTX.mesh
    spec = logical_to_spec(logical_axes, rules, mesh)
    if mesh is None:
        return spec
    out = []
    for i, axes in enumerate(spec):
        if axes is None:
            out.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        keep = []
        size = shape[i] if i < len(shape) else 1
        for a in axes_t:
            if a not in mesh.shape:
                continue
            n = mesh.shape[a]
            if size % n == 0:
                keep.append(a)
                size //= n
        out.append(tuple(keep) if len(keep) > 1
                   else (keep[0] if keep else None))
    return P(*out)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """`with_sharding_constraint` by logical names; identity with no mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def named_sharding(*logical_axes: Optional[str],
                   mesh: Optional[Mesh] = None,
                   rules: Optional[LogicalRules] = None) -> NamedSharding:
    mesh = mesh if mesh is not None else _CTX.mesh
    if mesh is None:
        raise ValueError("no active mesh")
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def psum_id(x, axis_name: str):
    """`jax.lax.psum` whose backward pass is the identity.

    Inside a `shard_map` body (``check_rep=False``), differentiating a
    raw ``psum`` applies ``psum`` to the cotangent too, multiplying the
    gradient by the shard count — the cotangent of a fleet aggregate is
    already replicated (every shard forms the same downstream loss from
    it), so summing it across shards over-counts by exactly ``n_sh``.
    With the identity backward, the per-shard gradient of a loss built
    on `psum_id`-reduced aggregates equals the single program's per-row
    gradient exactly.

    Contract: only valid when every shard consumes the reduced value
    through the same expression (replicated cotangent) — true for the
    coupled fleet aggregates in `repro.tune.objective`, not for
    arbitrary per-shard weightings of the reduced value.
    """
    return jax.lax.psum(x, axis_name)


def _psum_id_fwd(x, axis_name):
    return jax.lax.psum(x, axis_name), None


def _psum_id_bwd(axis_name, _, ct):
    return (ct,)


psum_id.defvjp(_psum_id_fwd, _psum_id_bwd)


def row_mesh(n: int, axis: str = "rows") -> Mesh:
    """1-D mesh over the first ``n`` local devices.

    The batch-sharding mesh of data-parallel scenario work — the
    tuner's `shard_map`-over-B hot loop (`repro.tune.optimizer`) splits
    independent grid rows across it. Orthogonal to the logical-axis
    model meshes above: rows are embarrassingly parallel, so no rule
    set is involved.
    """
    import numpy as np
    devices = jax.devices()
    if n > len(devices):
        raise ValueError(f"row_mesh({n}) but only {len(devices)} devices")
    return Mesh(np.asarray(devices[:n]), (axis,))
