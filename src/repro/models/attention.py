"""Attention: GQA with RoPE, optional QKV bias, sliding windows, caches.

Full-sequence attention (train / prefill) uses a *blockwise online-softmax*
formulation — the XLA expression of FlashAttention: query chunks via
`lax.map`, kv chunks via `lax.scan` carrying (max, denom, acc) in f32. Peak
memory is O(q_chunk * kv_chunk) per head instead of O(S^2); the Pallas
kernel in `repro.kernels.flash_attention` implements the same tiling for
TPU VMEM and is numerically interchangeable (cfg.attn_impl = 'pallas').

GQA layout note (TPU/GSPMD): query heads are ordered grouped
(h = g * rep + j), so a model-axis shard of q heads maps to a single kv
group whenever model_parallelism >= n_kv_heads — the Megatron GQA layout
that keeps attention collective-free under tensor parallelism.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, apply_rope, normal_init
from repro.parallel.axes import constrain

NEG_INF = -2.0 ** 30  # large-negative instead of -inf: avoids NaNs from
                      # (-inf) - (-inf) in fully-masked online-softmax rows


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (chunk sizes must tile
    the sequence exactly; e.g. whisper's enc_seq=1500 with cap 512 -> 500)."""
    cap = min(cap, n)
    for c in range(cap, 0, -1):
        if n % c == 0:
            return c
    return n


class AttnCache(NamedTuple):
    """Decode-time KV cache. ``k``/``v``: [B, W, G, Dh] (W = window or
    max_seq); ``pos_buf``: [B, W] absolute position per slot (-1 = empty),
    which makes rolling (SWA) and linear caches uniform.

    With ``cfg.kv_cache_dtype == "int8"`` (beyond-paper serving
    optimisation), k/v hold per-(token, head) absmax-scaled int8 and
    ``k_scale``/``v_scale`` [B, W, G] f32 carry the scales. Attention
    never materialises a dequantised cache: the k-scale multiplies the
    *scores* and the v-scale folds into the softmax weights."""

    k: jax.Array
    v: jax.Array
    pos_buf: jax.Array
    k_scale: Any = None
    v_scale: Any = None


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absmax int8 over the trailing (head_dim) axis.
    x: [..., Dh] -> (int8 [..., Dh], f32 scale [...])."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / safe[..., None]), -127, 127) \
        .astype(jnp.int8)
    return q, scale


def init_attention(key, cfg: ModelConfig, *, cross: bool = False,
                   n_layers_scale: Optional[int] = None) -> dict:
    d = cfg.d_model
    h, g, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pdt = _dtype(cfg.param_dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    depth = n_layers_scale or cfg.n_layers
    p = {
        "wq": normal_init(kq, (d, h, dh), 0.02, pdt),
        "wk": normal_init(kk, (d, g, dh), 0.02, pdt),
        "wv": normal_init(kv, (d, g, dh), 0.02, pdt),
        "wo": normal_init(ko, (h, dh, d), 0.02 / (2 * depth) ** 0.5, pdt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), pdt)
        p["bk"] = jnp.zeros((g, dh), pdt)
        p["bv"] = jnp.zeros((g, dh), pdt)
    return p


def _project_qkv(x, x_kv, p, cfg: ModelConfig):
    cdt = _dtype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dgk->bsgk", x_kv, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dgk->bsgk", x_kv, p["wv"].astype(cdt))
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    return q, k, v


# ---------------------------------------------------------------------------
# blockwise online-softmax attention (XLA flash)
# ---------------------------------------------------------------------------

def _tp_align_heads(q, k, v):
    """Align head counts to the tensor-parallel width (Megatron GQA layout).

    GSPMD shards the head dim of q ([B,S,H,Dh]) and kv ([B,S,G,Dh]) over
    ``model``. When G < TP or TP does not divide H, the partitioner falls
    back to "involuntary full rematerialization" (replicating whole
    tensors inside the attention loops — observed as per-kv-step GiB-scale
    all-gathers on grok-1). Alignment rules, all mathematically exact:

      * H, G both divisible by TP: untouched.
      * H divisible, TP divisible by G: replicate kv heads to TP
        (adjacent duplication keeps the grouped q->kv mapping).
      * otherwise: MHA-ize (replicate kv to H) and zero-pad both to the
        next multiple of TP; the caller slices padded q heads off, so
        dead heads never reach the output projection.

    Returns (q, k, v, h_orig) — caller slices [..., :h_orig, :].
    """
    from repro.parallel.axes import axis_size
    tp = axis_size("model")
    h, g = q.shape[-2], k.shape[-2]
    if tp <= 1 or (h % tp == 0 and g % tp == 0):
        return q, k, v, h
    if h % tp == 0 and tp % g == 0 and g < tp:
        rep = tp // g
        return q, jnp.repeat(k, rep, axis=-2), \
            jnp.repeat(v, rep, axis=-2), h
    rep = h // g
    if rep > 1:
        k = jnp.repeat(k, rep, axis=-2)
        v = jnp.repeat(v, rep, axis=-2)
    h_pad = -(-h // tp) * tp
    if h_pad != h:
        pad = [(0, 0)] * (q.ndim - 2) + [(0, h_pad - h), (0, 0)]
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    return q, k, v, h


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        cfg: ModelConfig, *, causal: bool,
                        window: int = 0,
                        q_offset: int = 0) -> jax.Array:
    """q: [B, Sq, H, Dh]; k, v: [B, Skv, G, Dh]; returns [B, Sq, H, Dh].

    Grouped-query: q is viewed as [B, Sq, G, R, Dh] (R = H // G) so kv is
    never materialised at H heads. kv chunks stream through a scan with an
    f32 (m, l, acc) carry; q chunks via lax.map bound peak memory.
    """
    h_orig = q.shape[2]
    q, k, v, _ = _tp_align_heads(q, k, v)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            bq=cfg.attn_q_chunk, bkv=cfg.attn_kv_chunk)
        return out[:, :, :h_orig]

    b, sq, h, dh = q.shape
    skv, g = k.shape[1], k.shape[2]
    r = h // g
    qc = _largest_divisor(sq, cfg.attn_q_chunk)
    kc = _largest_divisor(skv, cfg.attn_kv_chunk)
    n_qc, n_kc = sq // qc, skv // kc

    scale = dh ** -0.5
    qg = q.reshape(b, sq, g, r, dh)
    kv_pos = jnp.arange(skv)

    def q_block(idx):
        qi = jax.lax.dynamic_slice_in_dim(qg, idx * qc, qc, axis=1)
        q_pos = q_offset + idx * qc + jnp.arange(qc)

        def kv_step(carry, inputs):
            m_prev, l_prev, acc = carry
            kj, vj, pj = inputs                     # [b,kc,g,dh], pos [kc]
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= pj[None, :] <= q_pos[:, None]
            if window:
                mask &= pj[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p_, axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p_.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        ks = k.reshape(b, n_kc, kc, g, dh).swapaxes(0, 1)
        vs = v.reshape(b, n_kc, kc, g, dh).swapaxes(0, 1)
        ps = kv_pos.reshape(n_kc, kc)
        init = (jnp.full((b, g, r, qc), NEG_INF, jnp.float32),
                jnp.zeros((b, g, r, qc), jnp.float32),
                jnp.zeros((b, g, r, qc, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (ks, vs, ps))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return out                                   # [b,g,r,qc,dh]

    if n_qc == 1:
        out = q_block(jnp.asarray(0))                   # [b,g,r,sq,dh]
    else:
        outs = jax.lax.map(q_block, jnp.arange(n_qc))   # [n_qc,b,g,r,qc,dh]
        out = jnp.moveaxis(outs, 0, 3).reshape(b, g, r, sq, dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)
    return out.astype(q.dtype)[:, :, :h_orig]


# ---------------------------------------------------------------------------
# full attention layer (train / prefill / decode)
# ---------------------------------------------------------------------------

def attention_layer(x: jax.Array, p: dict, cfg: ModelConfig, *,
                    causal: bool = True,
                    use_rope: bool = True,
                    x_kv: Optional[jax.Array] = None,
                    cache: Optional[AttnCache] = None,
                    positions: Optional[jax.Array] = None,
                    cross_kv: Optional[tuple] = None,
                    window: Optional[int] = None,
                    return_kv: bool = False,
                    ) -> tuple[jax.Array, Optional[AttnCache]]:
    """One attention layer.

    Modes:
      * full-sequence (cache=None): train / prefill; x: [B,S,D].
      * decode (cache given): x: [B,1,D], positions: [B] absolute position
        of the new token; returns the updated cache.
      * cross attention: pass ``cross_kv=(k,v)`` precomputed from the
        encoder (no cache update, no rope).
    """
    cdt = _dtype(cfg.dtype)
    window = cfg.swa_window if window is None else window
    b = x.shape[0]
    h, g, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    if cross_kv is not None:
        k, v = cross_kv
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
        q = constrain(q, "batch", None, "heads", None)
        if "bq" in p:
            q = q + p["bq"].astype(cdt)
        if x.shape[1] == 1:   # decode: q len 1, full enc kv, no mask
            out = _decode_attention(q, k.astype(cdt), v.astype(cdt),
                                    None, cfg)
        else:
            out = blockwise_attention(q, k, v, cfg, causal=False)
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
        return o, None

    x_kv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(x, x_kv, p, cfg)
    # only pin head shardings that divide evenly; uneven head counts are
    # aligned inside blockwise_attention (_tp_align_heads)
    from repro.parallel.axes import axis_size
    tp = axis_size("model")
    if h % max(tp, 1) == 0:
        q = constrain(q, "batch", None, "heads", None)
    if g % max(tp, 1) == 0:
        k = constrain(k, "batch", None, "kv_heads", None)
        v = constrain(v, "batch", None, "kv_heads", None)

    if cache is None:
        s = x.shape[1]
        pos = jnp.arange(s) if positions is None else positions
        if use_rope:
            q = apply_rope(q, jnp.broadcast_to(pos, (b, s)), cfg.rope_theta)
            k = apply_rope(k, jnp.broadcast_to(pos, (b, s)), cfg.rope_theta)
        out = blockwise_attention(q, k, v, cfg, causal=causal, window=window)
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
        if return_kv:
            # collected K/V feed the decode cache: shard them like the
            # cache (positions over `model`) — at 32k prefill the stacked
            # [L,B,S,G,Dh] collection is otherwise the largest live tensor
            k = constrain(k, "batch", "cache_seq", None, None)
            v = constrain(v, "batch", "cache_seq", None, None)
            return o, (k, v)
        return o, None

    # ---- decode path -----------------------------------------------------
    assert x.shape[1] == 1
    pos = positions                                     # [B] int32
    if use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    if cache.k.shape[2] != k.shape[2]:   # aligned cache: replicate kv heads
        rep_c = cache.k.shape[2] // k.shape[2]
        k = jnp.repeat(k, rep_c, axis=2)
        v = jnp.repeat(v, rep_c, axis=2)
    w = cache.k.shape[1]
    slot = (pos % w).astype(jnp.int32)                  # rolling for SWA;
    # for linear caches w == max_seq so slot == pos.

    def upd(buf, new, sl):
        return jax.lax.dynamic_update_slice_in_dim(buf, new, sl, axis=0)

    quant = cache.k_scale is not None
    if quant:
        kq, ks = quantize_kv(k)                          # [B,1,G,Dh],[B,1,G]
        vq, vs = quantize_kv(v)
        new_k = jax.vmap(upd)(cache.k, kq, slot)
        new_v = jax.vmap(upd)(cache.v, vq, slot)
        new_ks = jax.vmap(upd)(cache.k_scale, ks, slot)
        new_vs = jax.vmap(upd)(cache.v_scale, vs, slot)
    else:
        new_k = jax.vmap(upd)(cache.k, k.astype(cache.k.dtype), slot)
        new_v = jax.vmap(upd)(cache.v, v.astype(cache.v.dtype), slot)
        new_ks = new_vs = None
    new_pb = jax.vmap(
        lambda pb, sl, pp: jax.lax.dynamic_update_slice_in_dim(
            pb, pp[None], sl, axis=0))(cache.pos_buf, slot, pos)
    new_cache = AttnCache(new_k, new_v, new_pb, k_scale=new_ks,
                          v_scale=new_vs)

    valid = (new_pb <= pos[:, None])
    if window:
        valid &= new_pb > (pos[:, None] - window)
    valid &= new_pb >= 0
    if quant:
        out = _decode_attention(q, new_k, new_v, valid, cfg,
                                k_scale=new_ks, v_scale=new_vs)
    else:
        out = _decode_attention(q, new_k.astype(cdt), new_v.astype(cdt),
                                valid, cfg)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    return o, new_cache


def _decode_attention(q, k, v, valid, cfg: Optional[ModelConfig],
                      k_scale=None, v_scale=None):
    """q: [B,1,H,Dh]; k,v: [B,W,G,Dh]; valid: [B,W] bool or None.

    With ``k_scale``/``v_scale`` ([B,W,G] f32), k/v are absmax int8: the
    k-scale multiplies the scores and the v-scale folds into the softmax
    weights — the dequantised cache is never materialised."""
    if cfg is not None and cfg.attn_impl == "pallas" and k_scale is None:
        from repro.kernels import ops as kops
        if valid is None:
            valid = jnp.ones(k.shape[:2], bool)
        return kops.decode_attention(q, k, v, valid,
                                     bkv=cfg.attn_kv_chunk)
    b, _, h, dh = q.shape
    g = k.shape[2]
    r = h // g
    qg = q.reshape(b, g, r, dh)
    kk = k.astype(jnp.float32) if k_scale is not None else k
    s = jnp.einsum("bgrd,bkgd->bgrk", qg.astype(kk.dtype), kk,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    if k_scale is not None:
        s = s * k_scale.transpose(0, 2, 1)[:, :, None, :]   # [B,G,1,W]
    if valid is not None:
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p_ = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p_ = p_ * v_scale.transpose(0, 2, 1)[:, :, None, :]
        out = jnp.einsum("bgrk,bkgd->bgrd", p_, v.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bgrk,bkgd->bgrd", p_.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def init_attn_cache(cfg: ModelConfig, batch: int, max_seq: int,
                    *, window: Optional[int] = None,
                    abstract: bool = False) -> AttnCache:
    w = window if window is not None else \
        (cfg.swa_window if cfg.swa_window else max_seq)
    w = min(w, max_seq)
    g, dh = cfg.cache_heads, cfg.resolved_head_dim
    kv_dt = _dtype(cfg.kv_cache_dtype)
    quant = cfg.kv_cache_dtype == "int8"
    shp = (batch, w, g, dh)
    sshp = (batch, w, g)
    if abstract:
        sds = jax.ShapeDtypeStruct
        return AttnCache(
            sds(shp, kv_dt), sds(shp, kv_dt), sds((batch, w), jnp.int32),
            k_scale=sds(sshp, jnp.float32) if quant else None,
            v_scale=sds(sshp, jnp.float32) if quant else None)
    return AttnCache(
        jnp.zeros(shp, kv_dt), jnp.zeros(shp, kv_dt),
        jnp.full((batch, w), -1, jnp.int32),
        k_scale=jnp.zeros(sshp, jnp.float32) if quant else None,
        v_scale=jnp.zeros(sshp, jnp.float32) if quant else None)
