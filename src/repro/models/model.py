"""Public model API: one set of entry points for all six families.

  init_params   parameter pytree for any assigned architecture
  loss_fn       training loss (next-token CE + MoE aux), remat/scan inside
  prefill       full-sequence forward that returns (last-pos logits, caches)
  decode_step   single-token step with caches (the ``serve_step`` the
                decode_* / long_* dry-run shapes lower)
  init_cache    per-family cache pytree (``abstract=True`` gives
                ShapeDtypeStructs for the dry-run — no allocation)
  cache_specs   logical sharding axes for every cache leaf
  param_specs   logical sharding axes for every parameter leaf

Parameters and caches carry a leading stacked layer axis consumed by
``lax.scan`` (see transformer.py). Sharding is expressed purely through
logical axis names; `repro.parallel.axes` maps them onto the active mesh.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import AttnCache, init_attn_cache
from repro.models.layers import _dtype, embed, rms_norm, unembed
from repro.models.ssm import SSMCache, init_ssm_cache
from repro.models.transformer import (decoder_forward, encdec_decoder_forward,
                                      encoder_forward, init_model_params)
from repro.parallel.axes import constrain

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> dict:
    return init_model_params(key, cfg)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _token_nll(h: jax.Array, labels: jax.Array, params: dict,
               cfg: ModelConfig) -> jax.Array:
    """Per-token negative log-likelihood. h: [B,S,D] (final-normed).

    With ``cfg.loss_chunk`` set, computes CE one sequence chunk at a time so
    the [B,S,V] logits tensor is never materialised (peak activation memory
    drops by ~B*S*V*4 bytes; a §Perf memory-term optimisation).
    """

    def ce(hc, lc):
        logits = unembed(hc, params["embed"], cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, lc[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return lse - gold                                   # [B, s]

    b, s, d = h.shape
    chunk = cfg.loss_chunk
    if chunk and s > chunk and s % chunk == 0:
        nc = s // chunk
        hs = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
        ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
        nll = jax.lax.map(lambda t: ce(*t), (hs, ls))       # [nc, B, chunk]
        return jnp.moveaxis(nll, 0, 1).reshape(b, s)
    return ce(h, labels)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, *,
            seq_sharded: bool = True) -> tuple[jax.Array, dict]:
    """Next-token cross-entropy (+ MoE load-balance aux).

    batch: tokens [B,S] int32, labels [B,S] int32, optional loss_mask
    [B,S] f32, plus 'frames' (audio) / 'patches' (vlm) frontend stand-ins.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("loss_mask")
    h = embed(tokens, params["embed"], cfg)

    if cfg.family == "audio":
        enc = encoder_forward(params, batch["frames"].astype(h.dtype), cfg)
        h = constrain(h, "batch", "seq", None)
        h, aux, _ = encdec_decoder_forward(params, h, cfg, enc_out=enc,
                                           seq_sharded=seq_sharded)
    else:
        if cfg.frontend == "vision":
            vt = cfg.vis_tokens
            patches = batch["patches"].astype(h.dtype)
            h = jnp.concatenate([patches, h[:, vt:]], axis=1)
            pmask = (jnp.arange(h.shape[1]) >= vt).astype(jnp.float32)[None]
            mask = pmask if mask is None else mask * pmask
        h = constrain(h, "batch", "seq", None)
        h, aux, _ = decoder_forward(params, h, cfg, seq_sharded=seq_sharded)

    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    nll = _token_nll(h, labels, params, cfg)
    if mask is None:
        ce_loss = jnp.mean(nll)
        denom = jnp.asarray(nll.size, jnp.float32)
    else:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce_loss = jnp.sum(nll * mask) / denom
    loss = ce_loss + cfg.moe_aux_coef * aux
    return loss, {"loss": loss, "ce": ce_loss, "moe_aux": aux,
                  "tokens": denom}


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _cache_from_kv(k: jax.Array, v: jax.Array, w: int,
                   cfg: ModelConfig) -> AttnCache:
    """Build the decode cache from collected K/V [..., S, G, Dh].

    Linear caches (w >= s) are a pad — never a scatter, which would
    materialise an unsharded zero buffer the size of the whole cache
    (17 GiB/device for a 64-layer 32k prefill). Rolling (sliding-window,
    w < s) caches scatter only the last ``w`` positions.
    """
    s = k.shape[-3]
    kvdt = _dtype(cfg.kv_cache_dtype)
    quant = cfg.kv_cache_dtype == "int8"
    if cfg.cache_heads != k.shape[-2]:   # aligned cache (Megatron layout)
        rep = cfg.cache_heads // k.shape[-2]
        k = jnp.repeat(k, rep, axis=-2)
        v = jnp.repeat(v, rep, axis=-2)
    if quant:
        from repro.models.attention import quantize_kv
        k, ks = quantize_kv(k)
        v, vs = quantize_kv(v)
    lead = k.shape[:-3]
    nlead = len(lead)

    def spec(*tail):
        return ("layers", "batch")[2 - nlead:] + tail

    if w >= s:
        widths = [(0, 0)] * nlead + [(0, w - s), (0, 0), (0, 0)]
        kc = jnp.pad(k.astype(kvdt), widths)
        vc = jnp.pad(v.astype(kvdt), widths)
        pos = jnp.pad(jnp.arange(s), (0, w - s), constant_values=-1)
        pos_buf = jnp.broadcast_to(pos, lead + (w,))
        kc = constrain(kc, *spec("cache_seq", "kv_heads", None))
        vc = constrain(vc, *spec("cache_seq", "kv_heads", None))
        if quant:
            sw = widths[:-1]
            return AttnCache(kc, vc, pos_buf,
                             k_scale=jnp.pad(ks, sw),
                             v_scale=jnp.pad(vs, sw))
        return AttnCache(kc, vc, pos_buf)

    srcpos = jnp.arange(s - w, s)
    slots = srcpos % w
    kc = jnp.zeros(lead + (w,) + k.shape[-2:], kvdt)
    vc = jnp.zeros(lead + (w,) + v.shape[-2:], kvdt)
    kc = kc.at[..., slots, :, :].set(k[..., s - w:, :, :].astype(kvdt))
    vc = vc.at[..., slots, :, :].set(v[..., s - w:, :, :].astype(kvdt))
    pos_buf = jnp.broadcast_to(
        jnp.zeros((w,), jnp.int32).at[slots].set(srcpos), lead + (w,))
    if quant:
        ksb = jnp.zeros(lead + (w,) + k.shape[-2:-1], jnp.float32)
        vsb = jnp.zeros(lead + (w,) + v.shape[-2:-1], jnp.float32)
        ksb = ksb.at[..., slots, :].set(ks[..., s - w:, :])
        vsb = vsb.at[..., slots, :].set(vs[..., s - w:, :])
        return AttnCache(kc, vc, pos_buf, k_scale=ksb, v_scale=vsb)
    return AttnCache(kc, vc, pos_buf)


def prefill(params: dict, batch: dict, cfg: ModelConfig, *,
            max_seq: Optional[int] = None,
            seq_sharded: bool = False) -> tuple[jax.Array, dict]:
    """Full-sequence forward returning (last-position logits [B,V], caches).

    ``max_seq`` sets the decode cache capacity (default: prompt length —
    pass prompt + generation budget for serving).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = max_seq or s
    h = embed(tokens, params["embed"], cfg)
    fam = cfg.family

    if fam == "audio":
        enc = encoder_forward(params, batch["frames"].astype(h.dtype), cfg)
        h = constrain(h, "batch", "seq", None)
        h, _, col = encdec_decoder_forward(params, h, cfg, enc_out=enc,
                                           seq_sharded=seq_sharded,
                                           collect=True)
        self_c = _cache_from_kv(col["self"][0], col["self"][1],
                                max_seq, cfg)
        caches = {"self": self_c,
                  "cross_k": col["cross_k"].astype(_dtype(cfg.dtype)),
                  "cross_v": col["cross_v"].astype(_dtype(cfg.dtype))}
    else:
        if cfg.frontend == "vision":
            vt = cfg.vis_tokens
            h = jnp.concatenate(
                [batch["patches"].astype(h.dtype), h[:, vt:]], axis=1)
        h = constrain(h, "batch", "seq", None)
        h, _, col = decoder_forward(params, h, cfg, seq_sharded=seq_sharded,
                                    collect=True)
        if fam in ("dense", "vlm", "moe"):
            w = min(cfg.swa_window or max_seq, max_seq)
            k, v = col["attn"]
            caches = {"attn": _cache_from_kv(k, v, w, cfg)}
        elif fam == "ssm":
            caches = {"ssm": col["ssm"]}
        elif fam == "hybrid":
            k, v = col["attn"]
            caches = {"ssm": col["ssm"],
                      "attn": _cache_from_kv(k, v, max_seq, cfg)}
        else:
            raise ValueError(fam)

    h_last = rms_norm(h[:, -1:], params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(h_last, params["embed"], cfg)[:, 0]
    return logits, caches


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(params: dict, tokens: jax.Array, caches: dict,
                positions: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, dict]:
    """One decoding step. tokens: [B,1]; positions: [B] absolute position
    of the new token. Returns (logits [B,V], updated caches)."""
    h = embed(tokens, params["embed"], cfg)
    if cfg.family == "audio":
        h, _, new_caches = encdec_decoder_forward(
            params, h, cfg, caches=caches, positions=positions,
            seq_sharded=False)
    else:
        h, _, new_caches = decoder_forward(
            params, h, cfg, caches=caches, positions=positions,
            seq_sharded=False)
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(h, params["embed"], cfg)[:, 0]
    return logits, new_caches


# ---------------------------------------------------------------------------
# cache construction + sharding specs
# ---------------------------------------------------------------------------

def _stack(tree, n: int, abstract: bool):
    def f(leaf):
        if abstract or isinstance(leaf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((n,) + leaf.shape, leaf.dtype)
        return jnp.broadcast_to(leaf[None], (n,) + leaf.shape)
    return jax.tree.map(f, tree)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
               abstract: bool = False) -> dict:
    """Decode-cache pytree (leading stacked layer axis, scan-ready)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        layer = init_attn_cache(cfg, batch, max_seq, abstract=abstract)
        return {"attn": _stack(layer, cfg.n_layers, abstract)}
    if fam == "ssm":
        layer = init_ssm_cache(cfg, batch, abstract=abstract)
        return {"ssm": _stack(layer, cfg.n_layers, abstract)}
    if fam == "hybrid":
        n_apps = cfg.n_layers // cfg.hybrid_attn_every
        ssm_layer = init_ssm_cache(cfg, batch, abstract=abstract)
        attn_layer = init_attn_cache(cfg, batch, max_seq, window=max_seq,
                                     abstract=abstract)
        return {"ssm": _stack(ssm_layer, cfg.n_layers, abstract),
                "attn": _stack(attn_layer, n_apps, abstract)}
    if fam == "audio":
        self_layer = init_attn_cache(cfg, batch, max_seq, abstract=abstract)
        g, dh = cfg.n_kv_heads, cfg.resolved_head_dim
        cdt = _dtype(cfg.dtype)
        cross_shape = (cfg.n_layers, batch, cfg.enc_seq, g, dh)
        if abstract:
            cross = jax.ShapeDtypeStruct(cross_shape, cdt)
            return {"self": _stack(self_layer, cfg.n_layers, abstract),
                    "cross_k": cross, "cross_v": cross}
        return {"self": _stack(self_layer, cfg.n_layers, abstract),
                "cross_k": jnp.zeros(cross_shape, cdt),
                "cross_v": jnp.zeros(cross_shape, cdt)}
    raise ValueError(fam)


_ATTN_CACHE_AXES = AttnCache(
    k=("layers", "batch", "cache_seq", "kv_heads", None),
    v=("layers", "batch", "cache_seq", "kv_heads", None),
    pos_buf=("layers", "batch", "cache_seq"),
)
_ATTN_CACHE_AXES_Q = _ATTN_CACHE_AXES._replace(
    k_scale=("layers", "batch", "cache_seq", "kv_heads"),
    v_scale=("layers", "batch", "cache_seq", "kv_heads"),
)
_SSM_CACHE_AXES = SSMCache(
    conv=("layers", "batch", None, "ffn"),
    state=("layers", "batch", "heads", None, None),
)


def cache_specs(cfg: ModelConfig) -> dict:
    """Logical sharding axes for every leaf of ``init_cache``'s pytree."""
    fam = cfg.family
    attn_axes = (_ATTN_CACHE_AXES_Q if cfg.kv_cache_dtype == "int8"
                 else _ATTN_CACHE_AXES)
    if fam in ("dense", "vlm", "moe"):
        return {"attn": attn_axes}
    if fam == "ssm":
        return {"ssm": _SSM_CACHE_AXES}
    if fam == "hybrid":
        return {"ssm": _SSM_CACHE_AXES, "attn": attn_axes}
    if fam == "audio":
        cross = ("layers", "batch", None, "kv_heads", None)
        return {"self": attn_axes, "cross_k": cross, "cross_v": cross}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# parameter sharding specs
# ---------------------------------------------------------------------------

# Base logical axes per parameter name (without the stacked layer axis).
_PARAM_AXES = {
    "tok": ("vocab", "embed_p"),
    "unembed": ("embed_p", "vocab"),
    "wq": ("embed_p", "heads", None),
    "wk": ("embed_p", "kv_heads", None),
    "wv": ("embed_p", "kv_heads", None),
    "wo": ("heads", None, "embed_p"),
    "bq": ("heads", None),
    "bk": ("kv_heads", None),
    "bv": ("kv_heads", None),
    "w1": ("embed_p", "ffn"),
    "w3": ("embed_p", "ffn"),
    "w2": ("ffn", "embed_p"),
    "router": (None, None),
    "in_proj": ("embed_p", "ffn"),
    "conv_w": (None, "ffn"),
    "conv_b": ("ffn",),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "gated_norm": ("ffn",),
    "out_proj": ("ffn", "embed_p"),
    "scale": (None,),
}


def _leaf_axes(name: str, ndim: int) -> tuple:
    base = _PARAM_AXES[name]
    if ndim == len(base):
        return base
    if ndim == len(base) + 1:                  # stacked over layers
        return ("layers",) + base
    if ndim == len(base) + 2 and name in ("w1", "w2", "w3"):
        return ("layers", "experts") + base    # stacked MoE experts
    raise ValueError(f"param {name!r} with ndim {ndim}")


def param_specs(params: Any) -> Any:
    """Logical sharding axes for every parameter leaf (path-name driven)."""
    def f(path, leaf):
        name = path[-1].key
        return _leaf_axes(name, leaf.ndim)
    return jax.tree_util.tree_map_with_path(f, params)
