"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of length ``ssm_chunk``; within a chunk the recurrence is the masked
quadratic (attention-like) form — an MXU-friendly matmul — and across chunks
a `lax.scan` carries the [H, P, N] state. Decode is the plain linear
recurrence on a [B, H, P, N] state plus a [B, K-1, conv_dim] conv state.

`repro.kernels.ssd_scan` provides the Pallas TPU kernel for the intra-chunk
stage; this module is the pure-jnp reference path (cfg.attn_impl drives the
swap at the block level).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, normal_init, rms_norm
from repro.parallel.axes import constrain

N_GROUPS = 1  # B/C projection groups (Mamba2-1.3b uses 1)


class SSMCache(NamedTuple):
    conv: jax.Array   # [B, K-1, conv_dim] last conv inputs
    state: jax.Array  # [B, H, P, N] recurrent state (f32)


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    nh = cfg.ssm_heads
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_dim = d_in + 2 * N_GROUPS * n
    return d_in, nh, p, n, conv_dim


def init_ssm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, nh, _, n, conv_dim = _dims(cfg)
    pdt = _dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj order: [z (d_in), x (d_in), B (g*n), C (g*n), dt (nh)]
    d_proj = 2 * d_in + 2 * N_GROUPS * n + nh
    return {
        "in_proj": normal_init(k1, (d, d_proj), 0.02, pdt),
        "conv_w": normal_init(k2, (cfg.ssm_conv, conv_dim), 0.2, pdt),
        "conv_b": jnp.zeros((conv_dim,), pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": (jnp.log(jnp.exp(
            jnp.exp(jax.random.uniform(k3, (nh,), jnp.float32)
                    * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3)))
            - 1.0 + 1e-9)).astype(jnp.float32),  # inverse-softplus init
        "gated_norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": normal_init(
            k4, (d_in, d), 0.02 / (2 * cfg.n_layers) ** 0.5, pdt),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_in, nh, _, n, _ = _dims(cfg)
    gn = N_GROUPS * n
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in:2 * d_in]
    b = zxbcdt[..., 2 * d_in:2 * d_in + gn]
    c = zxbcdt[..., 2 * d_in + gn:2 * d_in + 2 * gn]
    dt = zxbcdt[..., 2 * d_in + 2 * gn:]
    return z, x, b, c, dt


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k].

    Lower-triangular log-decay matrix for the intra-chunk quadratic form.
    """
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_coef, b, c, chunk: int,
                h0: Optional[jax.Array] = None, *, impl: str = "xla"):
    """Chunked SSD scan (pure jnp; ``impl='pallas'`` dispatches to the
    `repro.kernels.ssd_scan` TPU kernel with identical semantics).

    x: [B,S,H,P] (pre-multiplied by nothing; dt applied inside)
    dt: [B,S,H] (post-softplus), a_coef: [H] (negative)
    b, c: [B,S,G,N] (G groups broadcast over heads)
    Returns y: [B,S,H,P], final_state: [B,H,P,N] (f32).
    """
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.ssd_scan(x, dt, a_coef, b, c, chunk, h0)
    bsz, s, nh, p = x.shape
    g, n = b.shape[2], b.shape[3]
    pad = (-s) % chunk
    if pad:
        # zero-pad to a chunk multiple: dt=0 gives decay exp(0)=1 and a
        # zero state contribution, so padded positions are exact no-ops
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)]   # noqa: E731
                               + [(0, 0)] * (t.ndim - 2))
        y, h_last = ssd_chunked(zp(x), zp(dt), a_coef, zp(b), zp(c),
                                chunk, h0, impl=impl)
        return y[:, :s], h_last
    nc = s // chunk
    rep = nh // g

    # pin shardings: x/dt over heads; B/C *replicated* — without this,
    # GSPMD propagates a model-axis sharding onto the state dim N, turning
    # every einsum that contracts N into per-chunk partial-sum collectives
    # (§Perf H3.3)
    x = constrain(x, "batch", None, "heads", None)
    dt = constrain(dt, "batch", None, "heads")
    b = constrain(b, "batch", None, None, None)
    c = constrain(c, "batch", None, None, None)

    # fold dt into x and into the decay exponents
    xdt = (x.astype(jnp.float32) * dt[..., None])     # [B,S,H,P]
    da = dt * a_coef[None, None, :]                   # [B,S,H] (negative)

    def r(t, shape):  # chunk reshape [B,S,...] -> [B,nc,chunk,...]
        return t.reshape((bsz, nc, chunk) + shape)

    xc = r(xdt, (nh, p))
    dac = r(da, (nh,)).transpose(0, 1, 3, 2)          # [B,nc,H,chunk]
    bc = r(b.astype(jnp.float32), (g, n))
    cc = r(c.astype(jnp.float32), (g, n))
    bc_h = jnp.repeat(bc, rep, axis=3) if g != nh else bc
    cc_h = jnp.repeat(cc, rep, axis=3) if g != nh else cc

    da_cum = jnp.cumsum(dac, axis=-1)                 # [B,nc,H,chunk]
    # 1. intra-chunk (quadratic / "attention" form)
    lmat = jnp.exp(_segsum(dac))                      # [B,nc,H,chunk,chunk]
    scores = jnp.einsum("bclhn,bcshn->bchls", cc_h, bc_h) * lmat
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, xc)

    # 2. per-chunk output states
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)  # [B,nc,H,chunk]
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn", bc_h, decay_states, xc)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[..., -1])             # [B,nc,H]

    def scan_fn(h_prev, inp):
        st, dec = inp                                  # [B,H,P,N], [B,H]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h_init = jnp.zeros((bsz, nh, p, n), jnp.float32) if h0 is None else h0
    h_last, h_prevs = jax.lax.scan(
        scan_fn, h_init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                   # [B,nc,H,P,N]

    # 4. contribution of the carried-in state to each position
    state_decay = jnp.exp(da_cum)                      # [B,nc,H,chunk]
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", cc_h, h_prevs, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, nh, p)
    return y, h_last


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv1d. xbc: [B,S,C]; w: [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + bias[None, None, :]


def ssm_block(x: jax.Array, p: dict, cfg: ModelConfig, *,
              cache: Optional[SSMCache] = None,
              ) -> tuple[jax.Array, Optional[SSMCache]]:
    """Full Mamba2 block: in_proj -> conv -> SSD -> gated norm -> out_proj.

    Full-sequence when cache is None; single-token decode otherwise.
    """
    cdt = _dtype(cfg.dtype)
    d_in, nh, hp, n, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cdt))
    zxbcdt = constrain(zxbcdt, "batch", None, None)
    z, xin, b, c, dt_raw = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([xin, b, c], axis=-1)        # conv over x|B|C
    a_coef = -jnp.exp(p["A_log"])                      # [H] negative
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])

    if cache is None:
        # depthwise conv splits exactly: run the (model-shardable) x part
        # and the small B/C part separately, so the [B,S,d_inner]
        # intermediates are TP-sharded instead of replicated (16x less
        # live memory per device; §Perf H3)
        conv_w = p["conv_w"].astype(cdt)
        conv_b = p["conv_b"].astype(cdt)
        xin = constrain(xin, "batch", None, "ffn")
        xs = jax.nn.silu(_causal_conv(xin, conv_w[:, :d_in],
                                      conv_b[:d_in]))
        xs = constrain(xs, "batch", None, "ffn")
        bc = jnp.concatenate([b, c], axis=-1)
        bc_out = jax.nn.silu(_causal_conv(bc, conv_w[:, d_in:],
                                          conv_b[d_in:]))
        bs = bc_out[..., :N_GROUPS * n]
        cs = bc_out[..., N_GROUPS * n:]
        bsz, s = x.shape[0], x.shape[1]
        xh = xs.reshape(bsz, s, nh, hp)
        xh = constrain(xh, "batch", None, "heads", None)
        bg = bs.reshape(bsz, s, N_GROUPS, n)
        cg = cs.reshape(bsz, s, N_GROUPS, n)
        y, h_last = ssd_chunked(xh, dt, a_coef, bg, cg, cfg.ssm_chunk,
                                impl=('pallas' if cfg.attn_impl == 'pallas'
                                      else 'xla'))
        y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
        y = y.reshape(bsz, s, d_in).astype(cdt)
        y = rms_norm(y * jax.nn.silu(z), p["gated_norm"], cfg.norm_eps)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cdt))
        # (serving prefill that also needs the decode cache uses
        # `ssm_prefill_with_cache` below)
        return out, None

    # ---- decode ----------------------------------------------------------
    new_conv = jnp.concatenate([cache.conv, xbc.astype(cache.conv.dtype)],
                               axis=1)[:, 1:]          # [B,K-1,C]
    k = cfg.ssm_conv
    full = jnp.concatenate([cache.conv.astype(cdt), xbc], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", full, p["conv_w"].astype(cdt)) \
        + p["conv_b"].astype(cdt)
    conv_out = jax.nn.silu(conv_out)[:, None, :]       # [B,1,C]
    xs = conv_out[..., :d_in]
    bs = conv_out[..., d_in:d_in + N_GROUPS * n]
    cs = conv_out[..., d_in + N_GROUPS * n:]
    bsz = x.shape[0]
    xh = xs.reshape(bsz, nh, hp).astype(jnp.float32)
    bg = jnp.repeat(bs.reshape(bsz, N_GROUPS, n), nh // N_GROUPS, axis=1)
    cg = jnp.repeat(cs.reshape(bsz, N_GROUPS, n), nh // N_GROUPS, axis=1)
    dt1 = dt[:, 0]                                     # [B,H]
    decay = jnp.exp(dt1 * a_coef[None, :])             # [B,H]
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt1, xh, bg.astype(jnp.float32))
    h_new = cache.state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new, cg.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), p["gated_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cdt))
    return out, SSMCache(new_conv, h_new)


def _tail_conv_state(xbc, cfg):
    return xbc[:, -(cfg.ssm_conv - 1):, :]


def ssm_prefill_with_cache(x, p, cfg: ModelConfig):
    """Full-sequence forward that also returns the decode cache (used by
    serving prefill). Mirrors ssm_block's full-sequence path."""
    cdt = _dtype(cfg.dtype)
    d_in, nh, hp, n, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cdt))
    z, xin, b, c, dt_raw = _split_proj(zxbcdt, cfg)
    # decode conv state: only the last K-1 positions of x|B|C
    tail = cfg.ssm_conv - 1
    xbc_tail = jnp.concatenate([xin[:, -tail:], b[:, -tail:],
                                c[:, -tail:]], axis=-1)
    a_coef = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    conv_w = p["conv_w"].astype(cdt)
    conv_b = p["conv_b"].astype(cdt)
    xin = constrain(xin, "batch", None, "ffn")
    xs = jax.nn.silu(_causal_conv(xin, conv_w[:, :d_in], conv_b[:d_in]))
    xs = constrain(xs, "batch", None, "ffn")
    bc_out = jax.nn.silu(_causal_conv(jnp.concatenate([b, c], axis=-1),
                                      conv_w[:, d_in:], conv_b[d_in:]))
    bs = bc_out[..., :N_GROUPS * n]
    cs = bc_out[..., N_GROUPS * n:]
    bsz, s = x.shape[0], x.shape[1]
    xh = xs.reshape(bsz, s, nh, hp)
    bg = bs.reshape(bsz, s, N_GROUPS, n)
    cg = cs.reshape(bsz, s, N_GROUPS, n)
    y, h_last = ssd_chunked(xh, dt, a_coef, bg, cg, cfg.ssm_chunk,
                                impl=('pallas' if cfg.attn_impl == 'pallas'
                                      else 'xla'))
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, d_in).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), p["gated_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cdt))
    cache = SSMCache(xbc_tail.astype(cdt), h_last)
    return out, cache


def init_ssm_cache(cfg: ModelConfig, batch: int,
                   *, abstract: bool = False) -> SSMCache:
    _, nh, hp, n, conv_dim = _dims(cfg)
    cdt = _dtype(cfg.dtype)
    conv_shape = (batch, cfg.ssm_conv - 1, conv_dim)
    state_shape = (batch, nh, hp, n)
    if abstract:
        sds = jax.ShapeDtypeStruct
        return SSMCache(sds(conv_shape, cdt), sds(state_shape, jnp.float32))
    return SSMCache(jnp.zeros(conv_shape, cdt),
                    jnp.zeros(state_shape, jnp.float32))
