from repro.models.model import (
    init_params,
    loss_fn,
    prefill,
    decode_step,
    init_cache,
    cache_specs,
    param_specs,
)

__all__ = [
    "init_params",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_cache",
    "cache_specs",
    "param_specs",
]
