"""Shared layer primitives: norms, MLPs, rotary embeddings, initializers.

All parameters are plain dict pytrees; all functions are pure. Norm math
runs in float32 regardless of activation dtype (standard LM practice).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.axes import constrain


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16, "int8": jnp.int8}[name]


def normal_init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def init_rms_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq].

    Split-half convention (Llama / Qwen / NeoX). Math in f32.
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)          # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [...,seq,half]
    cos = jnp.cos(angles)[..., None, :]                # [..., seq, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, gated: bool = True) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pdt = _dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 0.02
    scale_out = 0.02 / (2 * cfg.n_layers) ** 0.5
    p = {"w1": normal_init(k1, (d, f), scale_in, pdt),
         "w2": normal_init(k2, (f, d), scale_out, pdt)}
    if gated:
        p["w3"] = normal_init(k3, (d, f), scale_in, pdt)
    return p


def mlp(x: jax.Array, p: dict, cfg: ModelConfig,
        act: str = "silu") -> jax.Array:
    """SwiGLU when `w3` present, else plain act MLP (whisper: gelu)."""
    cdt = _dtype(cfg.dtype)
    h = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(cdt))
    h = constrain(h, "batch", None, "ffn")
    a = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    if "w3" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w3"].astype(cdt))
        a = a * g
    out = jnp.einsum("bsf,fd->bsd", a, p["w2"].astype(cdt))
    return out


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embeddings(key, cfg: ModelConfig) -> dict:
    pdt = _dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"tok": normal_init(k1, (cfg.vocab, cfg.d_model), 0.02, pdt)}
    if not cfg.tie_embeddings:
        p["unembed"] = normal_init(k2, (cfg.d_model, cfg.vocab), 0.02, pdt)
    return p


def embed(tokens: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    cdt = _dtype(cfg.dtype)
    return jnp.take(p["tok"].astype(cdt), tokens, axis=0)


def unembed(h: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    cdt = _dtype(cfg.dtype)
    w = p["tok"].astype(cdt).T if cfg.tie_embeddings \
        else p["unembed"].astype(cdt)
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    logits = constrain(logits, "batch", None, "vocab")
    return logits.astype(_dtype(cfg.logit_dtype))
