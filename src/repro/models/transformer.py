"""Model assembly: blocks composed via scan-over-layers for all families.

Families:
  dense   pre-norm GQA attention + SwiGLU MLP (qwen*, stablelm, internvl2
          backbone)
  moe     GQA attention + top-k expert FFN (mixtral, grok-1); optional SWA
  ssm     Mamba2/SSD blocks (mamba2-1.3b)
  hybrid  Mamba2 blocks with a weight-shared attention block applied every
          `hybrid_attn_every` layers (zamba2, simplified: the shared block
          is a standard pre-norm attn+MLP pair; Zamba2's LoRA adapters and
          embedding concat are omitted — noted in DESIGN.md)
  audio   whisper enc-dec: bidirectional encoder over precomputed frame
          embeddings (conv frontend stub), causal decoder w/ cross-attn
  vlm     dense backbone; first `vis_tokens` positions take precomputed
          patch embeddings (InternViT frontend stub)

Layer parameters are stacked on a leading axis and consumed by
``jax.lax.scan`` — one compiled block body regardless of depth (compile
time and HLO size stay flat across the 24..80-layer configs). ``cfg.remat``
wraps the block body in ``jax.checkpoint``.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (AttnCache, attention_layer,
                                    init_attention, init_attn_cache)
from repro.models.layers import (_dtype, init_embeddings, init_mlp,
                                 init_rms_norm, embed, mlp, rms_norm,
                                 unembed)
from repro.parallel.axes import (SHARD_MAP_NOCHECK, constrain,
                                 current_mesh, shard_map)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_dense_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms_norm(cfg.d_model),
            "attn": init_attention(k1, cfg),
            "ln2": init_rms_norm(cfg.d_model),
            "ffn": init_mlp(k2, cfg)}


def _init_moe_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms_norm(cfg.d_model),
            "attn": init_attention(k1, cfg),
            "ln2": init_rms_norm(cfg.d_model),
            "ffn": moe_lib.init_moe(k2, cfg)}


def _init_ssm_block(key, cfg: ModelConfig) -> dict:
    return {"ln": init_rms_norm(cfg.d_model),
            "ssm": ssm_lib.init_ssm(key, cfg)}


def _init_encdec_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_rms_norm(cfg.d_model),
            "self_attn": init_attention(k1, cfg),
            "ln2": init_rms_norm(cfg.d_model),
            "cross_attn": init_attention(k2, cfg, cross=True),
            "ln3": init_rms_norm(cfg.d_model),
            "ffn": init_mlp(k3, cfg, gated=False)}


def _init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms_norm(cfg.d_model),
            "attn": init_attention(k1, cfg, n_layers_scale=cfg.enc_layers),
            "ln2": init_rms_norm(cfg.d_model),
            "ffn": init_mlp(k2, cfg, gated=False)}


_BLOCK_INIT = {"dense": _init_dense_block, "vlm": _init_dense_block,
               "moe": _init_moe_block, "ssm": _init_ssm_block,
               "hybrid": _init_ssm_block, "audio": _init_encdec_dec_block}


def init_model_params(key, cfg: ModelConfig) -> dict:
    ke, kb, ks, kenc = jax.random.split(key, 4)
    block_init = _BLOCK_INIT[cfg.family]
    layer_keys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
    params = {"embed": init_embeddings(ke, cfg),
              "blocks": blocks,
              "final_norm": init_rms_norm(cfg.d_model)}
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(ks)
        params["shared"] = {"ln1": init_rms_norm(cfg.d_model),
                            "attn": init_attention(k1, cfg),
                            "ln2": init_rms_norm(cfg.d_model),
                            "ffn": init_mlp(k2, cfg)}
    if cfg.is_encdec:
        enc_keys = jax.random.split(kenc, cfg.enc_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_enc_block(k, cfg))(enc_keys)
        params["enc_norm"] = init_rms_norm(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# MoE ffn wrapper: token-local dispatch under shard_map on the mesh
# ---------------------------------------------------------------------------

def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig,
            seq_sharded: bool) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux scalar).

    **Expert parallelism over the `model` axis** (all-to-all dispatch):

      * tokens stay on their (batch x seq) shard for routing — routing is
        per-token, so the dispatch buffers scale with the *local* token
        count (B_loc x S_loc), never the gathered sequence;
      * the model axis owns experts: with TP >= E each expert lives on
        dup = TP/E devices, each holding an F-slice of that expert
        ([E*dup, D, F/dup] EP layout, a free contiguous reshape of the
        stored [E, D(fsdp), F(tp)] weights); with E > TP each device owns
        E/TP whole experts;
      * one all-to-all sends each expert's token buffer to its owners
        (duplicated across F-slices), dense per-expert SwiGLU GEMMs run at
        full MXU tile sizes, and the return all-to-all brings partial
        outputs home where the dup F-slices are summed — completing the F
        contraction with *no* psum over model;
      * expert weights' fsdp (D-axis over `data`) shard is all-gathered at
        use, ZeRO-3 style.

    Wire per layer: 2 all-to-alls of dup * T_loc * k * capacity_factor * D
    — versus a gather-based TP dispatch this is ~T_loc*D*(2*dup*k*cf) vs
    T_full*D on wire, and 16x less live dispatch memory at TP=16.
    """
    b, s, d = x.shape
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape \
            or mesh.shape["model"] == 1:
        out, aux = moe_lib.moe_ffn_local(x.reshape(-1, d), p, cfg)
        return out.reshape(b, s, d), aux

    tp = mesh.shape["model"]
    e, f = cfg.n_experts, cfg.d_ff
    if tp % e == 0:
        dup, e_loc = tp // e, 1
    elif e % tp == 0:
        dup, e_loc = 1, e // tp
    else:
        raise ValueError(f"EP needs tp % E == 0 or E % tp == 0; "
                         f"got E={e}, tp={tp}")
    f_loc = f // dup

    # token sharding from the *active* rule set, divisibility-sanitised
    # (long-context decode has batch=1: batch stays unsharded there)
    from repro.parallel.axes import sanitized_spec
    x_spec = sanitized_spec(x.shape,
                            ("batch", "seq" if seq_sharded else None,
                             None))
    token_axes = tuple(a for part in x_spec if part
                       for a in ((part,) if isinstance(part, str)
                                 else part))
    all_axes = token_axes if token_axes else None

    # EP layout: [E, D, F] -> [E*dup, D, F/dup] (contiguous F split)
    def ep_in(w):                     # w1/w3: [E, D, F]
        return w.reshape(e, d, dup, f_loc).transpose(0, 2, 1, 3) \
                .reshape(e * dup, d, f_loc)

    def ep_out(w):                    # w2: [E, F, D]
        return w.reshape(e, dup, f_loc, d).reshape(e * dup, f_loc, d)

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)

    if s == 1:
        # ---- decode: weights-stationary TP-MoE (§Perf H1.2) ------------
        # At one token per sequence the ZeRO-3 weight gathers dwarf the
        # activations (~300 MB of expert weights vs ~100 KB of tokens per
        # layer, measured). Invert the movement: weights are used in their
        # *storage* sharding [E, D/data, F/model] — zero weight bytes on
        # the wire — while the tokens are all-gathered over the dp axes
        # (every device then holds the same tiny global batch). Each
        # device contracts its D-shard (psum over data) and F-shard
        # (psum over model); one final all-gather re-assembles D. Per
        # layer this moves a few MB instead of hundreds.
        d_data = mesh.shape.get("data", 1)
        d_shard = d // d_data
        # batch may be too small to shard (long_500k: batch 1)
        from repro.parallel.axes import sanitized_spec
        xd_spec = sanitized_spec(x.shape, ("batch", None, None))
        part0 = xd_spec[0]
        gather_axes = (() if part0 is None
                       else ((part0,) if isinstance(part0, str)
                             else tuple(part0)))

        def local_dec(x_loc, router, w1, w3, w2):
            # x_loc [B_loc, 1, D]; w1/w3 [E, D/data, F/model];
            # w2 [E, F/model, D/data]
            bl = x_loc.shape[0]
            x_all = x_loc.reshape(bl, d)
            for ax in gather_axes:
                x_all = jax.lax.all_gather(x_all, ax, axis=0, tiled=True)
            buf, meta, aux = moe_lib.route_and_dispatch(x_all, router, cfg)
            # D contraction over the data axis
            if "data" in mesh.shape:
                lo = jax.lax.axis_index("data") * d_shard
                buf_d = jax.lax.dynamic_slice_in_dim(buf, lo, d_shard,
                                                     axis=2)
            else:
                buf_d = buf
            cdt2 = w1.dtype
            h1 = jnp.einsum("ecd,edf->ecf", buf_d.astype(cdt2), w1)
            h3 = jnp.einsum("ecd,edf->ecf", buf_d.astype(cdt2), w3)
            if "data" in mesh.shape:
                h1 = jax.lax.psum(h1, "data")
                h3 = jax.lax.psum(h3, "data")
            hh = jax.nn.silu(h1) * h3                  # [E, cap, F/model]
            out_p = jnp.einsum("ecf,efd->ecd", hh, w2)  # [E,cap,D/data]
            out_p = jax.lax.psum(out_p, "model")        # finish F
            if "data" in mesh.shape:
                out_buf = jax.lax.all_gather(out_p, "data", axis=2,
                                             tiled=True)
            else:
                out_buf = out_p
            y_all = moe_lib.combine(out_buf.astype(buf.dtype), meta, d,
                                    cfg)                # [T_all, D]
            off = jnp.zeros((), jnp.int32)
            for ax in gather_axes:
                off = off * mesh.shape[ax] + jax.lax.axis_index(ax)
            y = jax.lax.dynamic_slice_in_dim(y_all, off * bl, bl, axis=0)
            return y.reshape(bl, 1, d), aux

        out, aux = shard_map(
            local_dec, mesh=mesh,
            in_specs=(xd_spec, P(None, None),
                      P(None, "data", "model"), P(None, "data", "model"),
                      P(None, "model", "data")),
            out_specs=(xd_spec, P()),
            **SHARD_MAP_NOCHECK,
        )(x, p["router"], p["w1"], p["w3"], p["w2"])
        return out, aux

    def local(x_loc, router, w1, w3, w2):
        # fsdp gather of this device's expert(-slice) weights (ZeRO-3)
        w1f = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
        w3f = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
        w2f = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
        bl, sl, _ = x_loc.shape
        buf, meta, aux = moe_lib.route_and_dispatch(
            x_loc.reshape(-1, d), router, cfg)          # [E, cap, D]
        cap = buf.shape[1]

        # pack destinations: expert e -> devices [e*dup, (e+1)*dup)
        if dup > 1:
            send = jnp.broadcast_to(buf[:, None], (e, dup, cap, d)) \
                      .reshape(tp, cap, d)
        else:
            send = buf.reshape(tp, e_loc * cap, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: [tp(sources), cap', d] — this device's expert(-slice)
        if e_loc > 1:
            # sources sent [e_loc, cap, d] chunks; regroup per expert
            xin = recv.reshape(tp, e_loc, cap, d).transpose(1, 0, 2, 3) \
                      .reshape(e_loc, tp * cap, d)
        else:
            xin = recv.reshape(1, tp * cap, d)
        out_e = moe_lib.expert_gemms(xin, w1f, w3f, w2f, cfg)
        if e_loc > 1:
            back = out_e.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3) \
                        .reshape(tp, e_loc * cap, d)
        else:
            back = out_e.reshape(tp, cap, d)
        ret = jax.lax.all_to_all(back, "model", split_axis=0,
                                 concat_axis=0, tiled=False)
        if dup > 1:                    # sum the F-slice partials
            out_buf = jnp.sum(ret.reshape(e, dup, cap, d)
                              .astype(jnp.float32), axis=1) \
                         .astype(ret.dtype)
        else:
            out_buf = ret.reshape(e, cap, d)
        y = moe_lib.combine(out_buf, meta, d, cfg)
        if all_axes:
            aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(bl, sl, d), aux

    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", None, "data")),
        out_specs=(x_spec, P()),
        **SHARD_MAP_NOCHECK,
    )(x, p["router"], ep_in(p["w1"]), ep_in(p["w3"]), ep_out(p["w2"]))
    return out, aux


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------

class BlockIO(NamedTuple):
    h: jax.Array
    aux: jax.Array                       # accumulated moe aux loss
    shared_cache: Any                    # hybrid: stacked shared-attn caches
    app_idx: jax.Array                   # hybrid: next shared-attn slot


def _attn_ffn_block(h, bp, cfg: ModelConfig, *, cache, positions,
                    seq_sharded, return_kv=False):
    resid = h
    x = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
    attn_out, new_cache = attention_layer(x, bp["attn"], cfg, causal=True,
                                          cache=cache, positions=positions,
                                          return_kv=return_kv)
    h = resid + attn_out
    h = constrain(h, "batch", "seq" if seq_sharded else None, None)
    resid = h
    x = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
    if cfg.n_experts:
        ffn_out, aux = moe_ffn(x, bp["ffn"], cfg, seq_sharded)
    else:
        ffn_out, aux = mlp(x, bp["ffn"], cfg), jnp.zeros((), jnp.float32)
    h = resid + ffn_out
    h = constrain(h, "batch", "seq" if seq_sharded else None, None)
    return h, aux, new_cache


def _ssm_block(h, bp, cfg: ModelConfig, *, cache, seq_sharded):
    resid = h
    x = rms_norm(h, bp["ln"]["scale"], cfg.norm_eps)
    out, new_cache = ssm_lib.ssm_block(x, bp["ssm"], cfg, cache=cache)
    h = resid + out
    h = constrain(h, "batch", "seq" if seq_sharded else None, None)
    return h, new_cache


# ---------------------------------------------------------------------------
# decoder-only forward (dense / vlm / moe / ssm / hybrid)
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def decoder_forward(params: dict, h: jax.Array, cfg: ModelConfig, *,
                    caches: Optional[dict] = None,
                    positions: Optional[jax.Array] = None,
                    seq_sharded: bool = True,
                    collect: bool = False):
    """Run the stacked decoder blocks. h: [B, S, D] embedded input.

    caches: per-family pytree with leaves stacked on a leading layer axis
    (see `model.init_cache`). Returns (h, aux, new_caches).

    ``collect=True`` (prefill): run full-sequence and additionally return
    the per-layer cache material (projected K/V; SSM conv/recurrent state).
    """
    decode = caches is not None
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        def body(carry, xs):
            h, aux = carry
            bp, cache = xs
            h, a, new_cache = _attn_ffn_block(
                h, bp, cfg, cache=cache if decode else None,
                positions=positions, seq_sharded=seq_sharded,
                return_kv=collect)
            return (h, aux + a), new_cache

        xs = (params["blocks"],
              caches["attn"] if decode else _dummy_layer_xs(cfg))
        (h, aux), new_attn = jax.lax.scan(_remat(body, cfg), (h, 0.0), xs)
        new_caches = ({"attn": new_attn} if (decode or collect) else None)
        return h, aux, new_caches

    if fam == "ssm":
        def body(carry, xs):
            h = carry
            bp, cache = xs
            if collect:
                resid = h
                x = rms_norm(h, bp["ln"]["scale"], cfg.norm_eps)
                out, new_cache = ssm_lib.ssm_prefill_with_cache(
                    x, bp["ssm"], cfg)
                h = resid + out
                h = constrain(h, "batch",
                              "seq" if seq_sharded else None, None)
            else:
                h, new_cache = _ssm_block(h, bp, cfg,
                                          cache=cache if decode else None,
                                          seq_sharded=seq_sharded)
            return h, new_cache

        xs = (params["blocks"],
              caches["ssm"] if decode else _dummy_layer_xs(cfg))
        h, new_ssm = jax.lax.scan(_remat(body, cfg), h, xs)
        new_caches = ({"ssm": new_ssm} if (decode or collect) else None)
        return h, jnp.zeros((), jnp.float32), new_caches

    if fam == "hybrid":
        # static grouping: every `every` SSM layers, one weight-shared
        # attention block (own KV cache per application). Python loop over
        # groups keeps cache plumbing static; inner scans keep HLO small.
        every = cfg.hybrid_attn_every
        n_apps = cfg.n_layers // every
        shared = params["shared"]
        aux = jnp.zeros((), jnp.float32)

        def ssm_body(carry, xs):
            h = carry
            bp, cache = xs
            if collect:
                resid = h
                x = rms_norm(h, bp["ln"]["scale"], cfg.norm_eps)
                out, new_cache = ssm_lib.ssm_prefill_with_cache(
                    x, bp["ssm"], cfg)
                h = resid + out
                h = constrain(h, "batch",
                              "seq" if seq_sharded else None, None)
            else:
                h, new_cache = _ssm_block(h, bp, cfg,
                                          cache=cache if decode else None,
                                          seq_sharded=seq_sharded)
            return h, new_cache

        def shared_attn_block(h, cache_a):
            resid = h
            x = rms_norm(h, shared["ln1"]["scale"], cfg.norm_eps)
            a_out, new_attn_c = attention_layer(
                x, shared["attn"], cfg, causal=True, cache=cache_a,
                positions=positions, return_kv=collect)
            h = resid + a_out
            resid = h
            x = rms_norm(h, shared["ln2"]["scale"], cfg.norm_eps)
            h = resid + mlp(x, shared["ffn"], cfg)
            h = constrain(h, "batch",
                          "seq" if seq_sharded else None, None)
            return h, new_attn_c

        if not decode:
            # remat each shared-attn application (19 un-rematted
            # full-sequence attention blocks dominate zamba2's train
            # memory otherwise)
            shared_attn_block = _remat(shared_attn_block, cfg)

        def run_group(h, lo, hi, app_idx):
            bp_g = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
            cache_g = (jax.tree.map(lambda a: a[lo:hi], caches["ssm"])
                       if decode else jnp.zeros((hi - lo,), jnp.float32))
            h, new_ssm = jax.lax.scan(_remat(ssm_body, cfg), h,
                                      (bp_g, cache_g))
            new_attn_c = None
            if app_idx is not None:
                cache_a = (jax.tree.map(lambda a: a[app_idx],
                                        caches["attn"]) if decode else None)
                h, new_attn_c = shared_attn_block(h, cache_a)
            return h, new_ssm, new_attn_c

        new_ssm_parts, new_attn_parts = [], []
        for g in range(n_apps):
            h, ssm_c, attn_c = run_group(h, g * every, (g + 1) * every, g)
            new_ssm_parts.append(ssm_c)
            new_attn_parts.append(attn_c)
        if n_apps * every < cfg.n_layers:         # trailing layers
            h, ssm_c, _ = run_group(h, n_apps * every, cfg.n_layers, None)
            new_ssm_parts.append(ssm_c)

        new_caches = None
        if decode or collect:
            new_ssm = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm_parts)
            new_attn = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *new_attn_parts)
            new_caches = {"ssm": new_ssm, "attn": new_attn}
        return h, aux, new_caches

    raise ValueError(f"decoder_forward: unsupported family {fam}")


def _dummy_layer_xs(cfg: ModelConfig):
    """Per-layer scan placeholder when no caches flow through."""
    return jnp.zeros((cfg.n_layers,), jnp.float32)


# ---------------------------------------------------------------------------
# encoder (whisper) and enc-dec forward
# ---------------------------------------------------------------------------

def encoder_forward(params: dict, h: jax.Array, cfg: ModelConfig, *,
                    seq_sharded: bool = False) -> jax.Array:
    def body(carry, bp):
        h = carry
        resid = h
        x = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
        a_out, _ = attention_layer(x, bp["attn"], cfg, causal=False,
                                   use_rope=False)
        h = resid + a_out
        resid = h
        x = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
        h = resid + mlp(x, bp["ffn"], cfg, act="gelu")
        h = constrain(h, "batch", None, None)
        return h, None

    h, _ = jax.lax.scan(_remat(body, cfg), h, params["enc_blocks"])
    return rms_norm(h, params["enc_norm"]["scale"], cfg.norm_eps)


def encdec_decoder_forward(params: dict, h: jax.Array, cfg: ModelConfig, *,
                           enc_out: Optional[jax.Array] = None,
                           caches: Optional[dict] = None,
                           positions: Optional[jax.Array] = None,
                           seq_sharded: bool = True,
                           collect: bool = False):
    """Whisper decoder. Training: cross-attn K/V computed per block from
    ``enc_out``. Decode: cross K/V come precomputed from the cache.
    ``collect=True`` (prefill): also return per-layer self K/V + cross K/V."""
    decode = caches is not None
    cdt = _dtype(cfg.dtype)

    def body(carry, xs):
        h, aux = carry
        bp, self_cache, cross_k, cross_v = xs
        resid = h
        x = rms_norm(h, bp["ln1"]["scale"], cfg.norm_eps)
        a_out, new_self = attention_layer(
            x, bp["self_attn"], cfg, causal=True,
            cache=self_cache if decode else None, positions=positions,
            return_kv=collect)
        h = resid + a_out
        resid = h
        x = rms_norm(h, bp["ln2"]["scale"], cfg.norm_eps)
        if decode:
            ck, cv = cross_k, cross_v
        else:
            ck = jnp.einsum("bsd,dgk->bsgk", enc_out,
                            bp["cross_attn"]["wk"].astype(cdt))
            cv = jnp.einsum("bsd,dgk->bsgk", enc_out,
                            bp["cross_attn"]["wv"].astype(cdt))
        c_out, _ = attention_layer(x, bp["cross_attn"], cfg,
                                   cross_kv=(ck, cv))
        h = resid + c_out
        resid = h
        x = rms_norm(h, bp["ln3"]["scale"], cfg.norm_eps)
        h = resid + mlp(x, bp["ffn"], cfg, act="gelu")
        h = constrain(h, "batch", "seq" if (seq_sharded and not decode)
                      else None, None)
        out = (new_self, ck, cv) if collect else new_self
        return (h, aux), out

    if decode:
        xs = (params["blocks"], caches["self"],
              caches["cross_k"], caches["cross_v"])
    else:
        n_l = cfg.n_layers
        xs = (params["blocks"], _dummy(n_l), _dummy(n_l), _dummy(n_l))
    (h, aux), scanned = jax.lax.scan(_remat(body, cfg), (h, 0.0), xs)
    new_caches = None
    if decode:
        new_caches = {"self": scanned, "cross_k": caches["cross_k"],
                      "cross_v": caches["cross_v"]}
    elif collect:
        new_self, cross_k, cross_v = scanned
        new_caches = {"self": new_self, "cross_k": cross_k,
                      "cross_v": cross_v}
    return h, aux, new_caches


def _dummy(n):
    return jnp.zeros((n,), jnp.float32)
