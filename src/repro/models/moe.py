"""Mixture-of-Experts FFN (Mixtral / Grok-1 style: top-2 of 8, SwiGLU).

Dispatch is the *sort-based capacity* scheme: assignments are sorted by
expert, ranked within expert, and scattered into an [E, cap, D] buffer that
feeds dense per-expert GEMMs — MXU-friendly and dropless up to the capacity
factor (overflow tokens fall back to the residual stream, GShard-style).

Distribution: the dispatch is *token-local*. Under the production mesh the
surrounding `shard_map` hands every device its own tokens (batch over
(pod, data); sequence gathered from the SP shards over `model`), the full
router, and the expert shards [E, D_shard(fsdp), F_shard(tp)]; the fsdp
shard is all-gathered at use and the F contraction reduce-scattered back to
sequence shards — the Megatron SP<->TP transition. No all-to-all is needed
because experts are weight-sharded, not token-sharded (EP over `model` is
the recorded hillclimb alternative; see EXPERIMENTS.md §Perf).

Gradients flow through the combine weights (standard top-k STE-free
routing); a Switch-style load-balancing auxiliary loss is returned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, normal_init


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    pdt = _dtype(cfg.param_dtype)
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": normal_init(kr, (d, e), 0.02, jnp.float32),
        "w1": normal_init(k1, (e, d, f), 0.02, pdt),
        "w3": normal_init(k3, (e, d, f), 0.02, pdt),
        "w2": normal_init(k2, (e, f, d),
                          0.02 / (2 * cfg.n_layers) ** 0.5, pdt),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


class DispatchMeta:
    """Sorted-assignment metadata linking tokens <-> expert buffer slots."""

    def __init__(self, eid_s, tok_s, wgt_s, rank_c, keep, t, cap):
        self.eid_s, self.tok_s, self.wgt_s = eid_s, tok_s, wgt_s
        self.rank_c, self.keep, self.t, self.cap = rank_c, keep, t, cap


def route_and_dispatch(x2d: jax.Array, router: jax.Array, cfg: ModelConfig
                       ) -> tuple[jax.Array, DispatchMeta, jax.Array]:
    """Route tokens and scatter them into [E, cap, D] expert buffers.

    Returns (buf, meta, aux_loss). Dropped (over-capacity) assignments
    scatter out of bounds and contribute zero on combine (GShard-style
    residual fallback).
    """
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    cdt = _dtype(cfg.dtype)
    cap = _capacity(t, cfg)

    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)              # [T, E]
    top_p, top_i = jax.lax.top_k(probs, k)               # [T, k]
    weights = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Switch-style load-balance auxiliary: E * sum_e f_e * P_e
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0))

    eid = top_i.reshape(-1)                              # [T*k]
    tok = jnp.repeat(jnp.arange(t), k)                   # [T*k]
    wgt = weights.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, wgt_s = eid[order], tok[order], wgt[order]
    counts = jnp.bincount(eid, length=e)                 # [E]
    starts = jnp.cumsum(counts) - counts                 # exclusive
    rank = jnp.arange(t * k) - starts[eid_s]             # pos within expert
    keep = rank < cap

    # scatter tokens into [E, cap, D]; dropped rows scatter out of bounds
    rank_c = jnp.where(keep, rank, cap)                  # drop via OOB
    buf = jnp.zeros((e, cap, d), cdt)
    buf = buf.at[eid_s, rank_c].set(x2d[tok_s].astype(cdt), mode="drop")
    return buf, DispatchMeta(eid_s, tok_s, wgt_s, rank_c, keep, t, cap), aux


def expert_gemms(buf: jax.Array, w1, w3, w2, cfg: ModelConfig) -> jax.Array:
    """Dense per-expert SwiGLU. buf: [E', cap', D]; w*: [E', D, F'] /
    [E', F', D] (E'/F' may be EP-transformed). Returns [E', cap', D]."""
    cdt = _dtype(cfg.dtype)
    h1 = jnp.einsum("ecd,edf->ecf", buf, w1.astype(cdt))
    h3 = jnp.einsum("ecd,edf->ecf", buf, w3.astype(cdt))
    h = jax.nn.silu(h1) * h3
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(cdt))


def combine(out_buf: jax.Array, meta: DispatchMeta, d: int,
            cfg: ModelConfig) -> jax.Array:
    """Gather expert outputs back to token order, weighted. -> [T, D]."""
    cdt = _dtype(cfg.dtype)
    contrib = out_buf[meta.eid_s, jnp.minimum(meta.rank_c, meta.cap - 1)]
    contrib = contrib * (meta.wgt_s * meta.keep).astype(cdt)[:, None]
    return jnp.zeros((meta.t, d), cdt).at[meta.tok_s].add(contrib)


def moe_ffn_local(x2d: jax.Array, p: dict, cfg: ModelConfig
                  ) -> tuple[jax.Array, jax.Array]:
    """Token-local MoE. x2d: [T, D] -> ([T, D], aux_loss scalar)."""
    t, d = x2d.shape
    buf, meta, aux = route_and_dispatch(x2d, p["router"], cfg)
    out_buf = expert_gemms(buf, p["w1"], p["w3"], p["w2"], cfg)
    return combine(out_buf, meta, d, cfg), aux
