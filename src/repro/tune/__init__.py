"""Differentiable policy tuning: gradient-optimize shutdown policies
over the whole fleet grid.

The fleet engine (`repro.fleet`) made *sweeping* policies cheap; this
subsystem makes them *searchable*. The two-threshold hysteresis state
machine is relaxed with temperature-``tau`` sigmoid gates
(`repro.kernels.soft_scan` — one fused associative scan over [B, T],
differentiable end to end), per-row policy variables are
reparameterized onto the feasible set (`objective` — p_on <= p_off and
off_level in [0, 1) by construction), and a vmapped Adam loop
(`optimizer`, reusing `repro.optim.adamw`) descends the per-row
CPC/CPC_AO ratio for all B rows simultaneously while annealing tau
toward the hard scan. The result is re-evaluated hard and guaranteed
no worse than the row's own swept `PolicySpec` — and no worse than the
*best* swept policy of the row's (market, system) cell whenever the
hardware parameters (idle draw, restart costs) are uniform within the
cell, since the cell-best fallback is re-priced under each row's own
hardware.

Dispatch-aware tuning (`TuneConfig.dispatch_soft`) goes one level up:
the relaxed schedules feed the temperature-relaxed water-fill
dispatcher (`repro.kernels.soft_dispatch`), so gradients flow through
*placement* and per-site thresholds learn their fleet role — the
designated swing site emerges instead of being hand-assigned. The
final set is still re-scored on feasible `repro.dispatch.dispatch`.

  quickstart:  PYTHONPATH=src python examples/tune_policies.py
"""

from repro.execution import Coupling, ExecutionPlan
from repro.tune.objective import (DispatchCoupling, PhysicalPolicy,
                                  PolicyParams, TuneProblem, cell_index,
                                  dispatch_coupling_from_grid,
                                  init_from_grid, inverse_transform,
                                  problem_from_grid, soft_costs,
                                  soft_dispatch_ratio, soft_objective,
                                  transform)
from repro.tune.optimizer import (TuneConfig, TuneResult, cell_best_rows,
                                  hard_cpc, optimize,
                                  sharded_soft_objective, tune_loop,
                                  tune_loop_checkpointed)

__all__ = ["Coupling", "DispatchCoupling", "ExecutionPlan",
           "PhysicalPolicy", "PolicyParams",
           "TuneProblem", "TuneConfig", "TuneResult", "cell_best_rows",
           "cell_index", "dispatch_coupling_from_grid", "hard_cpc",
           "init_from_grid", "inverse_transform", "problem_from_grid",
           "soft_costs", "soft_dispatch_ratio", "soft_objective",
           "sharded_soft_objective", "transform", "optimize",
           "tune_loop", "tune_loop_checkpointed"]
