"""Batched gradient tuning of shutdown policies over a whole fleet grid.

`optimize` turns every row of a `ScenarioGrid` into an independent
optimization problem: the row's three policy variables (threshold,
hysteresis gap, off-capacity level — reparameterized unconstrained, see
`repro.tune.objective`) descend the temperature-relaxed CPC objective
simultaneously, one jitted `lax.scan` over optimization steps with the
whole [B]-row gradient computed in a single backward pass through the
soft scan — by default the fused, checkpointed custom VJP of
`repro.kernels.soft_scan_vjp` (``TuneConfig.fused``), which replaces
native autodiff through the associative scan with a block-local
recompute and cuts both the backward's arithmetic and its residual
memory.

The update rule *is* `repro.optim.adamw.adamw_update` — the same code
path that trains the models — vmapped over rows so each row carries its
own Adam moments and (optionally) its own per-row gradient clip.

The hot loop (`tune_loop`) is one compiled program: the τ-annealing
schedule, every Adam step, and the final hard (τ → 0) re-evaluation all
run inside a single jit with the raw-parameter carry donated, so a
tuning run is one dispatch and the optimizer state never round-trips.
Because the per-row gradients are batch-independent (sum-reduction, see
`soft_objective`), the loop also scales out without changing results:

  * row chunking — ``TuneConfig.chunk_rows`` tunes the grid in fixed
    row slices (padded to one compile shape), bounding peak memory so
    B ~ 10^5 grids tune on one host — *bit-identical* to the one-shot
    program (every chunk compiles to the same shape);
  * ``shard_map`` over B — with more than one device (including CPU
    virtual devices via ``--xla_force_host_platform_device_count``),
    rows are split across a 1-D `repro.parallel.row_mesh` and tuned in
    parallel. Same math, but XLA codegen depends on the shard width, so
    agreement with the single-device program is ULP-level rather than
    bitwise (shards narrower than 2 rows are never created).

Temperature annealing: the sigmoid temperature follows a geometric
schedule from ``tau_start`` (smooth, wide basins — gradients see far
across the price distribution) down to ``tau_end`` (nearly hard — the
soft objective tracks the real discrete-switching CPC). After the last
step the result is re-evaluated under the *hard* scan (tau -> 0
exactly), and each row keeps the best of {tuned params, its own swept
policy, the best swept policy of its (market, system) cell} — so the
reported CPC can never be worse than the swept grid it started from.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.dispatch import (DispatchConfig, DispatchInfeasible,
                            build_problem)
from repro.dispatch import dispatch as dispatch_solve
from repro.fleet.engine import backtest, fleet_costs
from repro.fleet.grid import concat_rows, row_chunks
from repro.kernels.ref import fleet_scan_ref
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update
from repro.parallel.axes import SHARD_MAP_NOCHECK, row_mesh, shard_map
from repro.tune.objective import (DispatchCoupling, PhysicalPolicy,
                                  PolicyParams, TuneProblem, cell_index,
                                  dispatch_coupling_from_grid,
                                  init_from_grid, inverse_transform,
                                  problem_from_grid, soft_objective,
                                  transform)

from jax.sharding import PartitionSpec as P


class TuneConfig(NamedTuple):
    """Hyperparameters of a fleet tuning run (hashable — used as a jit
    static argument)."""

    steps: int = 300
    lr: float = 0.5              # raw-space Adam step (price units for
                                 # raw_off; Adam normalizes per-coordinate)
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    clip_norm: float = 0.0       # per-row grad clip; 0 disables. The
                                 # clipped quantity is the row's
                                 # gradient of its *own* CPC ratio
                                 # (sum-reduction, B-independent);
                                 # values calibrated against the PR-2/3
                                 # mean-reduction loop scale by 1/B
    tau_start: float = 30.0      # EUR/MWh-scale smoothing at the start
    tau_end: float = 0.3         # nearly hard by the end
    # hot-loop implementation knobs
    fused: bool = True           # checkpointed custom-VJP soft scan
                                 # (False: native autodiff — the PR-3
                                 # baseline, kept for A/B benchmarks)
    block_t: int = 256           # checkpoint block length (hours)
    chunk_rows: int = 0          # tune the grid in row slices of this
                                 # size (0 disables; >= 2) — bounds
                                 # peak memory, bit-identical per row
    shard: bool = True           # shard_map rows over available devices
                                 # (auto: engages when >1 device and no
                                 # coupling penalty; bit-identical)
    eval_stages: int = 4         # hard (tau -> 0) re-evaluations spread
                                 # over the anneal: the scan splits into
                                 # this many segments (same per-step
                                 # ops; trajectories agree to float
                                 # round-off across stage counts) with
                                 # a hard CPC re-eval at each boundary
                                 # -> TuneResult.stage_cpc; clamped to
                                 # [1, steps]
    # fleet-coupling penalties (None disables)
    power_cap_mw: Optional[float] = None
    min_up_hours: Optional[float] = None
    penalty_weight: float = 10.0
    # feasible cross-site dispatch re-evaluation (None disables): after
    # hard re-evaluation, score the tuned and the best-swept policy sets
    # under `repro.dispatch` — hard constraints, not the soft penalties
    # above — and report both (TuneResult.dispatch)
    dispatch: Optional[DispatchConfig] = None
    # dispatch-AWARE tuning (None disables): differentiate through the
    # temperature-relaxed water-fill dispatcher
    # (`repro.kernels.soft_dispatch`, co-annealed with the scan tau) so
    # per-site thresholds learn their fleet role; the final hard
    # re-evaluation is still scored on feasible `dispatch()` (under
    # ``dispatch`` if also set, else under this config). Couples every
    # row through the shared water level: the chunked path refuses it
    # loudly and sharding is disabled.
    dispatch_soft: Optional[DispatchConfig] = None
    dispatch_blend: float = 0.5      # fleet-dispatch share of the loss
    dispatch_mw_scale: float = 0.05  # MW temperature of the dwell reset
                                     # gate per unit tau


class TuneResult(NamedTuple):
    """Output of `optimize` (per-row arrays of shape [B])."""

    params: PhysicalPolicy       # selected per-row policy (hard-eval best)
    raw: PolicyParams            # final raw params of the gradient run
    cpc: np.ndarray              # hard CPC of the selected policy
    cpc_tuned: np.ndarray        # hard CPC of the gradient-tuned params
    cpc_swept: np.ndarray        # engine CPC of the row's own swept policy
    cpc_swept_best: np.ndarray   # best engine CPC in the row's cell
    improvement_vs_best: np.ndarray   # 1 - cpc / cpc_swept_best
    improvement_vs_own: np.ndarray    # 1 - cpc / cpc_swept
    source: np.ndarray           # 0 = tuned, 1 = own swept, 2 = cell best
    history: dict                # per-step arrays: loss, tau, penalty
    # mean hard CPC at each anneal-stage boundary ([cfg.eval_stages],
    # last entry == mean(cpc_tuned)) — the convergence curve the soft
    # loss cannot show (chunked runs report the mean over row chunks)
    stage_cpc: Optional[np.ndarray] = None
    # feasible-dispatch re-evaluation (None unless cfg.dispatch or
    # cfg.dispatch_soft given): {"cpc_tuned", "cpc_swept", "chosen",
    # "tuned", "swept", "rows", "site_names", "infeasible_*"} where
    # "tuned"/"swept" are repro.dispatch.DispatchResult and "rows" the
    # grid rows operated as sites
    dispatch: Optional[dict] = None


def _tau_schedule(cfg: TuneConfig) -> jnp.ndarray:
    """Geometric anneal tau_start -> tau_end over ``cfg.steps``."""
    if cfg.steps == 1:
        return jnp.asarray([cfg.tau_start], jnp.float32)
    i = jnp.arange(cfg.steps, dtype=jnp.float32) / (cfg.steps - 1)
    return cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** i


def _hard_cpc_rows(p_on, p_off, off_level, problem: TuneProblem
                   ) -> jnp.ndarray:
    """Hard (tau -> 0) CPC of arbitrary per-row policy variables under
    each row's own hardware parameters — the engine's exact scan + cost
    path. Traced into `tune_loop` (and into the jitted `hard_cpc`)."""
    p_rows = problem.row_prices()
    scan = fleet_scan_ref(p_rows, p_on, p_off, off_level,
                          problem.idle_frac)
    return fleet_costs(
        scan, price_sum=problem.price_sum, fixed=problem.fixed,
        power=problem.power, period=problem.period,
        restart_energy_mwh=problem.restart_energy_mwh,
        restart_time_h=problem.restart_time_h,
        n_samples=p_rows.shape[1]).cpc


hard_cpc = jax.jit(_hard_cpc_rows)


def _stage_bounds(cfg: TuneConfig) -> list:
    """Step indices of the anneal-stage boundaries: ``eval_stages``
    near-equal segments of [0, steps] (strictly increasing — clamped to
    at most one stage per step)."""
    stages = max(1, min(int(cfg.eval_stages), cfg.steps))
    return [(i * cfg.steps) // stages for i in range(stages + 1)]


def _loop_body(raw0: PolicyParams, problem: TuneProblem, cfg: TuneConfig,
               coupling: Optional[DispatchCoupling] = None,
               telemetry: bool = False):
    """The tuner hot loop: annealed Adam scan + hard re-evaluations.

    Traced under plain jit (single program), under `shard_map` (one
    shard of rows), and per chunk — identical per-row math in all
    three, which is what makes the scaled-out paths bit-consistent
    (``coupling`` is only ever non-None in the single program).

    The step scan runs as ``cfg.eval_stages`` back-to-back `lax.scan`
    segments over the one tau schedule — the per-step ops are the same,
    so trajectories agree across stage counts to float round-off
    (segment boundaries change XLA fusion, hence ULP-level rather than
    bitwise) — with the *hard* (tau -> 0)
    CPC re-evaluated at each boundary (``history["stage_cpc"]``,
    [stages]; its last entry is the final hard re-eval, so the stage
    curve is free). ``telemetry`` adds per-step grad-norm / clip-
    fraction side-outputs to the history — observers of values the
    update already computes, never inputs to it, keeping the tuned
    parameters bit-identical (asserted in tests/test_obs.py).
    Returns ``(raw_f, history, cpc_tuned)``.
    """
    b = raw0.raw_off.shape[0]
    opt = AdamWConfig(lr=cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                      weight_decay=0.0, clip_norm=cfg.clip_norm)

    def row_update(g, st, p):
        new_p, new_st, _ = adamw_update(g, st, p, opt)
        return new_p, new_st

    state_axes = AdamWState(step=None, mu=0, nu=0)
    vupdate = jax.vmap(row_update, in_axes=(0, state_axes, 0),
                       out_axes=(0, state_axes))

    grad_fn = jax.value_and_grad(soft_objective, has_aux=True)
    state0 = AdamWState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(jnp.zeros_like, raw0),
                        nu=jax.tree.map(jnp.zeros_like, raw0))
    min_dwell = cfg.dispatch_soft.min_dwell_h \
        if cfg.dispatch_soft is not None else 0

    def step(carry, tau):
        raw, st = carry
        (loss, aux), grads = grad_fn(
            raw, problem, tau, power_cap_mw=cfg.power_cap_mw,
            min_up_hours=cfg.min_up_hours,
            penalty_weight=cfg.penalty_weight,
            dispatch=coupling, dispatch_blend=cfg.dispatch_blend,
            dispatch_min_dwell=min_dwell,
            dispatch_mw_scale=cfg.dispatch_mw_scale,
            fused=cfg.fused, block_t=cfg.block_t, reduction="sum")
        out = {"loss": loss / b, "tau": tau,
               "penalty": aux["penalty"],
               "dispatch_ratio": aux["dispatch_ratio"]}
        if telemetry:
            # observers only: read the gradients the update consumes,
            # feed nothing back
            norm = jnp.sqrt(grads.raw_off ** 2 + grads.raw_gap ** 2
                            + grads.raw_lvl ** 2)            # [B]
            out["grad_norm"] = jnp.mean(norm)
            out["clip_frac"] = (
                jnp.mean((norm > cfg.clip_norm).astype(norm.dtype))
                if cfg.clip_norm else jnp.zeros((), norm.dtype))
        raw, st = vupdate(grads, st, raw)
        return (raw, st), out

    taus = _tau_schedule(cfg)
    bounds = _stage_bounds(cfg)
    carry = (raw0, state0)
    hists, stage_cpc = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        carry, h = jax.lax.scan(step, carry, taus[lo:hi])
        hists.append(h)
        ph = transform(carry[0])
        cpc_rows = _hard_cpc_rows(ph.p_on, ph.p_off, ph.off_level,
                                  problem)
        stage_cpc.append(jnp.mean(cpc_rows))
    raw_f = carry[0]
    hist = hists[0] if len(hists) == 1 else \
        jax.tree.map(lambda *xs: jnp.concatenate(xs), *hists)
    hist["stage_cpc"] = jnp.stack(stage_cpc)
    # cpc_rows from the last stage IS the final hard re-evaluation
    return raw_f, hist, cpc_rows


@functools.partial(jax.jit, static_argnames=("cfg", "telemetry"),
                   donate_argnums=(0,))
def tune_loop(raw0: PolicyParams, problem: TuneProblem,
              coupling: Optional[DispatchCoupling] = None, *,
              cfg: TuneConfig, telemetry: bool = False):
    """One compiled tuning program: τ-annealed Adam over all rows plus
    the staged hard re-evaluations, with the raw-parameter carry donated
    (the Adam scan reuses its buffers instead of allocating fresh ones
    each call). ``coupling`` (from `dispatch_coupling_from_grid`)
    switches on the dispatch-aware fleet term. ``telemetry`` is static:
    False (the default, and whenever `repro.obs` is disabled) compiles
    the exact pre-telemetry program with no extra side-outputs. This is
    the object `benchmarks/bench_tune.py` times."""
    return _loop_body(raw0, problem, cfg, coupling, telemetry)


_PROBLEM_ROW_FIELDS = tuple(f for f in TuneProblem._fields
                            if f != "prices")


def _take_problem(problem: TuneProblem, idx: np.ndarray) -> TuneProblem:
    """Row-slice every [B] field of a `TuneProblem` (prices stay shared,
    exactly like `ScenarioGrid.take_rows`)."""
    return problem._replace(**{
        f: jnp.asarray(getattr(problem, f))[idx]
        for f in _PROBLEM_ROW_FIELDS})


@functools.cache
def _sharded_loop(n_dev: int, cfg: TuneConfig, telemetry: bool = False):
    """jit(shard_map(loop)) over a 1-D row mesh, cached per
    (n_dev, cfg, telemetry).

    Per-shard histories come back stacked [n_dev, steps]; the caller
    averages them (equal shard sizes)."""
    mesh = row_mesh(n_dev)
    rows = P("rows")

    def body(raw0, problem):
        raw_f, hist, cpc = _loop_body(raw0, problem, cfg,
                                      telemetry=telemetry)
        return raw_f, {k: v[None] for k, v in hist.items()}, cpc

    in_specs = (rows, TuneProblem(
        prices=P(), **{f: rows for f in _PROBLEM_ROW_FIELDS}))
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(rows, rows, rows), **SHARD_MAP_NOCHECK)
    return jax.jit(fn, donate_argnums=(0,))


def _run_loop(raw0: PolicyParams, problem: TuneProblem, cfg: TuneConfig,
              n_rows: int,
              coupling: Optional[DispatchCoupling] = None,
              telemetry: bool = False):
    """Dispatch the hot loop over the single / sharded / chunked path.

    Per-row math is identical in all three (sum-reduction makes each
    row's gradient independent of its batch); chunking is bitwise, the
    sharded path is ULP-equivalent (see the module docstring). Returns
    ``(raw_f, history, cpc_tuned)`` with history arrays [steps].
    """
    coupled = (cfg.power_cap_mw is not None
               or cfg.min_up_hours is not None
               or coupling is not None)

    if cfg.chunk_rows == 1:
        raise ValueError(
            "TuneConfig.chunk_rows must be >= 2: width-1 programs "
            "scalarize on XLA:CPU and drift off the bit-identical "
            "contract (same reason shards keep >= 2 rows)")
    if cfg.chunk_rows and coupled:
        # loud, not silent: a chunked water level / penalty over a
        # partial fleet is a different objective, and quietly dropping
        # the chunking instead would drop the memory bound the user
        # asked for
        raise ValueError(
            "TuneConfig.chunk_rows cannot be combined with fleet "
            "coupling (power_cap_mw / min_up_hours / dispatch_soft): "
            "coupled terms see every row at once, so a row chunk would "
            "optimize against a fleet that does not exist — tune "
            "unchunked (one program) or drop the coupling")

    # an explicit chunk_rows is a memory bound the user asked for — it
    # wins over auto-sharding (the two do not compose yet; a sharded
    # host that also needs chunking should chunk)
    if cfg.chunk_rows and n_rows > cfg.chunk_rows:
        # pad to one compile shape by repeating row 0: padded rows are
        # tuned like any other and dropped afterwards — per-row math is
        # batch-independent, so the real rows are unaffected (the loss
        # *history*, a diagnostic, does average over the padding)
        raws, cpcs, hists = [], [], []
        for sl in row_chunks(n_rows, cfg.chunk_rows):
            raw_j = jax.tree.map(lambda x: jnp.asarray(x)[sl], raw0)
            r, h, cp = tune_loop(raw_j, _take_problem(problem, sl),
                                 cfg=cfg, telemetry=telemetry)
            raws.append(r)
            hists.append(h)
            cpcs.append(cp)
        hist = {k: np.mean([np.asarray(h[k]) for h in hists], axis=0)
                for k in hists[0]}
        return (concat_rows(raws, n_rows), hist,
                concat_rows(cpcs, n_rows))

    # an explicit chunk_rows wins over auto-sharding even when the grid
    # is small enough to skip the chunked branch above: the user opted
    # into the bitwise chunk contract, and the shard path is only
    # ULP-equivalent
    if cfg.shard and not coupled and not cfg.chunk_rows:
        n_avail = len(jax.devices())
        # largest divisor of B that keeps >= 2 rows per shard: width-1
        # shards scalarize on XLA:CPU and round a few ops differently
        # (observed 1-ulp drift), breaking the bit-consistency contract
        # — and a 1-row shard is degenerate parallelism anyway
        n_dev = next((d for d in range(min(n_avail, n_rows // 2), 0, -1)
                      if n_rows % d == 0), 1)
        if n_dev > 1:
            raw_f, hist, cpc = _sharded_loop(n_dev, cfg,
                                             telemetry)(raw0, problem)
            return raw_f, {k: np.asarray(v).mean(axis=0)
                           for k, v in hist.items()}, cpc

    raw_f, hist, cpc = tune_loop(raw0, problem, coupling, cfg=cfg,
                                 telemetry=telemetry)
    return raw_f, {k: np.asarray(v) for k, v in hist.items()}, cpc


def _hard_cpc_batched(p_on, p_off, off_level, problem: TuneProblem,
                      chunk_rows: int) -> np.ndarray:
    """`hard_cpc`, optionally evaluated in row chunks so the in-jit
    [B, T] price gather never exceeds the chunk footprint."""
    b = np.shape(p_on)[0]
    if not chunk_rows or b <= chunk_rows:
        return np.asarray(hard_cpc(p_on, p_off, off_level, problem),
                          np.float64)
    parts = [hard_cpc(jnp.asarray(p_on)[sl], jnp.asarray(p_off)[sl],
                      jnp.asarray(off_level)[sl],
                      _take_problem(problem, sl))
             for sl in row_chunks(b, chunk_rows)]
    return np.asarray(concat_rows(parts, b), np.float64)


def cell_best_rows(grid, cpc: np.ndarray) -> np.ndarray:
    """Index of the lowest-CPC row within each row's (market, system)
    cell, mapped back onto rows (robust to row permutations)."""
    key = cell_index(grid)
    best: dict[int, int] = {}
    for b in range(len(key)):
        c = int(key[b])
        if c not in best or cpc[b] < cpc[best[c]]:
            best[c] = b
    return np.asarray([best[int(c)] for c in key], np.int64)


def _dispatch_reeval(grid, params: PhysicalPolicy, cpc: np.ndarray,
                     best_row: np.ndarray, dcfg: DispatchConfig) -> dict:
    """Score the selected (tuned) and the best-swept policy sets under
    the *feasible* cross-site dispatcher — one site per (market, system)
    cell, hard constraints instead of the soft tuning penalties. A
    policy set that cannot meet the configured demand is not clipped to
    fit: it scores ``cpc = inf`` with the `DispatchInfeasible` reason
    recorded, and the feasible set (if any) is chosen."""
    key = cell_index(grid)
    sel: dict[int, int] = {}
    for b in range(len(key)):
        c = int(key[b])
        if c not in sel or cpc[b] < cpc[sel[c]]:
            sel[c] = b
    rows = np.asarray([sel[c] for c in sorted(sel)], np.int64)
    markets = np.asarray(grid.market_idx)[rows]
    prices = np.asarray(grid.prices)[markets]
    power = np.asarray(grid.power)[rows]
    fixed = np.asarray(grid.fixed)[rows]

    def run(p_on, p_off, lvl, take):
        try:
            return dispatch_solve(build_problem(
                prices, np.asarray(p_on)[take], np.asarray(p_off)[take],
                np.asarray(lvl)[take], power, dcfg, fixed=fixed)), None
        except DispatchInfeasible as e:
            return None, str(e)

    tuned, why_t = run(params.p_on, params.p_off, params.off_level, rows)
    sw = best_row[rows]
    swept, why_s = run(grid.p_on, grid.p_off, grid.off_level, sw)
    cpc_t = tuned.cpc if tuned is not None else float("inf")
    cpc_s = swept.cpc if swept is not None else float("inf")
    chosen = None if tuned is None and swept is None else \
        ("tuned" if cpc_t <= cpc_s else "swept")
    names = tuple(f"{grid.market_names[n]}/{grid.system_names[m]}"
                  for n, m in zip(np.asarray(grid.market_idx)[rows],
                                  np.asarray(grid.system_idx)[rows])) \
        if grid.market_names and grid.system_names else ()
    return {"cpc_tuned": cpc_t, "cpc_swept": cpc_s, "chosen": chosen,
            "tuned": tuned, "swept": swept, "rows": rows,
            "site_names": names,
            "infeasible_tuned": why_t, "infeasible_swept": why_s}


def optimize(grid, cfg: TuneConfig = TuneConfig(), *,
             warm_start=None) -> TuneResult:
    """Gradient-tune every scenario row of ``grid``; hard-re-evaluate.

    Each row is seeded at its own swept `PolicySpec` (so the grid's K
    policies double as K random restarts per (market, system) cell) and
    tuned for ``cfg.steps`` Adam steps under the annealed soft
    objective. ``warm_start`` overrides the seed: a `PolicyParams` (raw),
    a `PhysicalPolicy` (mapped through `inverse_transform`), or a prior
    `TuneResult` (its ``.raw``) — the entry point a receding-horizon
    caller (`repro.live`, `examples/live_operator.py`) uses to re-tune
    at each cadence tick from the previous tick's solution with a short
    ``cfg.steps`` budget instead of a cold anneal. The final selection keeps, per row, the best hard-CPC
    policy among the tuned parameters and the swept baselines — when
    hardware parameters (idle draw, restart costs) are uniform within a
    cell, the reported ``cpc`` therefore matches or beats the best swept
    policy on every row. With fleet-coupling penalties configured the
    swept fallback is disabled (swept policies ignore the constraints),
    so ``cpc`` reports the tuned params unconditionally — sharding is
    disabled too, and an explicit ``chunk_rows`` raises, since coupled
    terms see every row at once.

    With ``cfg.dispatch_soft`` the annealed objective additionally
    differentiates through the relaxed water-fill dispatcher
    (`repro.tune.objective.soft_dispatch_ratio`), the per-row swept
    fallback is disabled for the same reason as above, and the final
    policy set is re-scored on *feasible* `repro.dispatch.dispatch`
    (under ``cfg.dispatch`` if also given, else under the same config)
    against the best-swept set — so the reported fleet CPC under hard
    dispatch is never worse than the swept baseline's.
    """
    telemetry = obs.enabled()
    problem = problem_from_grid(grid)
    if warm_start is None:
        raw0 = init_from_grid(grid)
    elif isinstance(warm_start, TuneResult):
        raw0 = warm_start.raw
    elif isinstance(warm_start, PhysicalPolicy):
        raw0 = inverse_transform(warm_start)
    elif isinstance(warm_start, PolicyParams):
        raw0 = warm_start
    else:
        raise TypeError("warm_start must be PolicyParams, PhysicalPolicy "
                        f"or TuneResult, got {type(warm_start).__name__}")
    if np.asarray(raw0.raw_off).shape != (grid.n_rows,):
        raise ValueError(
            f"warm_start has {np.asarray(raw0.raw_off).shape} raw_off for "
            f"a {grid.n_rows}-row grid")
    if warm_start is not None:
        # the tuning loop donates its parameter carry; copy so the
        # caller's warm-start source (e.g. the previous tick's
        # TuneResult in a receding-horizon loop) stays alive
        raw0 = PolicyParams(*(jnp.array(a) for a in raw0))
    coupling = dispatch_coupling_from_grid(grid, cfg.dispatch_soft) \
        if cfg.dispatch_soft is not None else None
    raw_f, hist, cpc_tuned_dev = _run_loop(raw0, problem, cfg,
                                           grid.n_rows, coupling,
                                           telemetry)
    stage_cpc = np.asarray(hist.pop("stage_cpc"), np.float64)
    cpc_tuned = np.asarray(cpc_tuned_dev, np.float64)

    # hard re-evaluation of the swept baselines at tau -> 0
    swept = backtest(grid, use_pallas=False, chunk_rows=cfg.chunk_rows)
    cpc_swept = np.asarray(swept.cpc, np.float64)
    best_row = cell_best_rows(grid, cpc_swept)
    cpc_swept_best = cpc_swept[best_row]

    tuned = transform(raw_f)
    # cell-best swept params evaluated under *this* row's hardware
    cb = PhysicalPolicy(p_on=grid.p_on[best_row], p_off=grid.p_off[best_row],
                        off_level=grid.off_level[best_row])
    cpc_cb = _hard_cpc_batched(cb.p_on, cb.p_off, cb.off_level, problem,
                               cfg.chunk_rows)

    cand = np.stack([cpc_tuned, cpc_swept, cpc_cb])        # [3, B]
    if (cfg.power_cap_mw is not None or cfg.min_up_hours is not None
            or cfg.dispatch_soft is not None):
        # fleet-coupling constraints: the swept baselines ignore them, so
        # falling back to a lower-CPC swept policy would silently violate
        # the constraint the user asked for — keep the tuned params.
        # (Dispatch-aware runs likewise: a per-row swept fallback judged
        # on *isolated* CPC would undo the fleet-role specialisation the
        # dispatch term just taught; the swept set still competes, as a
        # whole fleet, in the hard dispatch re-scoring below.)
        source = np.zeros(cand.shape[1], np.int64)
    else:
        source = np.argmin(cand, axis=0)
    cpc = cand[source, np.arange(cand.shape[1])]

    def pick(tuned_v, own_v, cb_v):
        stacked = jnp.stack([jnp.asarray(tuned_v), jnp.asarray(own_v),
                             jnp.asarray(cb_v)])
        return stacked[source, jnp.arange(stacked.shape[1])]

    params = PhysicalPolicy(
        p_on=pick(tuned.p_on, grid.p_on, cb.p_on),
        p_off=pick(tuned.p_off, grid.p_off, cb.p_off),
        off_level=pick(tuned.off_level, grid.off_level, cb.off_level))

    dispatch_out = None
    reeval_cfg = cfg.dispatch if cfg.dispatch is not None \
        else cfg.dispatch_soft
    if reeval_cfg is not None:
        dispatch_out = _dispatch_reeval(grid, params, cpc, best_row,
                                        reeval_cfg)

    result = TuneResult(
        params=params, raw=raw_f, cpc=cpc, cpc_tuned=cpc_tuned,
        cpc_swept=cpc_swept, cpc_swept_best=cpc_swept_best,
        improvement_vs_best=1.0 - cpc / cpc_swept_best,
        improvement_vs_own=1.0 - cpc / cpc_swept,
        source=source, history=hist, stage_cpc=stage_cpc,
        dispatch=dispatch_out)
    if telemetry:
        _emit_tune_events(cfg, result)
    return result


def _emit_tune_events(cfg: TuneConfig, res: TuneResult) -> None:
    """Stream the finished run's history into the trace: one
    ``tune.step`` per optimization step (loss / tau / penalty, plus
    grad-norm and clip-fraction — present because the loop ran with its
    telemetry side-outputs), one ``tune.stage`` per hard re-eval
    boundary, one ``tune.result``."""
    hist = res.history
    step_keys = [k for k in ("loss", "tau", "penalty", "dispatch_ratio",
                             "grad_norm", "clip_frac") if k in hist]
    for i in range(len(hist["loss"])):
        obs.trace_event("tune.step",
                        {"step": i,
                         **{k: float(hist[k][i]) for k in step_keys}})
        if "grad_norm" in hist:
            obs.histogram("tune.grad_norm").observe(hist["grad_norm"][i])
    bounds = _stage_bounds(cfg)
    for k, v in enumerate(res.stage_cpc):
        obs.trace_event("tune.stage", {"stage": k,
                                       "through_step": bounds[k + 1],
                                       "cpc_hard_mean": float(v)})
    src_names = ("tuned", "own_swept", "cell_best")
    obs.trace_event("tune.result", {
        "rows": int(res.cpc.shape[0]), "steps": cfg.steps,
        "cpc_mean": float(np.mean(res.cpc)),
        "cpc_tuned_mean": float(np.mean(res.cpc_tuned)),
        "cpc_swept_best_mean": float(np.mean(res.cpc_swept_best)),
        "improvement_vs_best_mean": float(np.mean(res.improvement_vs_best)),
        "source_counts": {src_names[s]: int(n) for s, n in
                          zip(*np.unique(res.source, return_counts=True))}})
    obs.gauge("tune.cpc_mean").set(float(np.mean(res.cpc)))
    obs.counter("tune.runs").inc()
