"""Batched gradient tuning of shutdown policies over a whole fleet grid.

`optimize` turns every row of a `ScenarioGrid` into an independent
optimization problem: the row's three policy variables (threshold,
hysteresis gap, off-capacity level — reparameterized unconstrained, see
`repro.tune.objective`) descend the temperature-relaxed CPC objective
simultaneously, one jitted `lax.scan` over optimization steps with the
whole [B]-row gradient computed in a single backward pass through the
associative soft scan.

The update rule *is* `repro.optim.adamw.adamw_update` — the same code
path that trains the models — vmapped over rows so each row carries its
own Adam moments and (optionally) its own per-row gradient clip.

Temperature annealing: the sigmoid temperature follows a geometric
schedule from ``tau_start`` (smooth, wide basins — gradients see far
across the price distribution) down to ``tau_end`` (nearly hard — the
soft objective tracks the real discrete-switching CPC). After the last
step the result is re-evaluated under the *hard* scan (tau -> 0
exactly), and each row keeps the best of {tuned params, its own swept
policy, the best swept policy of its (market, system) cell} — so the
reported CPC can never be worse than the swept grid it started from.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dispatch import (DispatchConfig, DispatchInfeasible,
                            build_problem)
from repro.dispatch import dispatch as dispatch_solve
from repro.fleet.engine import backtest, fleet_costs
from repro.kernels.ref import fleet_scan_ref
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update
from repro.tune.objective import (PhysicalPolicy, PolicyParams,
                                  cell_index, init_from_grid,
                                  problem_from_grid, soft_objective,
                                  transform)


class TuneConfig(NamedTuple):
    """Hyperparameters of a fleet tuning run (hashable — used as a jit
    static argument)."""

    steps: int = 300
    lr: float = 0.5              # raw-space Adam step (price units for
                                 # raw_off; Adam normalizes per-coordinate)
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    clip_norm: float = 0.0       # per-row grad clip; 0 disables
    tau_start: float = 30.0      # EUR/MWh-scale smoothing at the start
    tau_end: float = 0.3         # nearly hard by the end
    # fleet-coupling penalties (None disables)
    power_cap_mw: Optional[float] = None
    min_up_hours: Optional[float] = None
    penalty_weight: float = 10.0
    # feasible cross-site dispatch re-evaluation (None disables): after
    # hard re-evaluation, score the tuned and the best-swept policy sets
    # under `repro.dispatch` — hard constraints, not the soft penalties
    # above — and report both (TuneResult.dispatch)
    dispatch: Optional[DispatchConfig] = None


class TuneResult(NamedTuple):
    """Output of `optimize` (per-row arrays of shape [B])."""

    params: PhysicalPolicy       # selected per-row policy (hard-eval best)
    raw: PolicyParams            # final raw params of the gradient run
    cpc: np.ndarray              # hard CPC of the selected policy
    cpc_tuned: np.ndarray        # hard CPC of the gradient-tuned params
    cpc_swept: np.ndarray        # engine CPC of the row's own swept policy
    cpc_swept_best: np.ndarray   # best engine CPC in the row's cell
    improvement_vs_best: np.ndarray   # 1 - cpc / cpc_swept_best
    improvement_vs_own: np.ndarray    # 1 - cpc / cpc_swept
    source: np.ndarray           # 0 = tuned, 1 = own swept, 2 = cell best
    history: dict                # per-step arrays: loss, tau, penalty
    # feasible-dispatch re-evaluation (None unless cfg.dispatch given):
    # {"cpc_tuned", "cpc_swept", "chosen", "tuned", "swept"} where the
    # last two are repro.dispatch.DispatchResult
    dispatch: Optional[dict] = None


def _tau_schedule(cfg: TuneConfig) -> jnp.ndarray:
    """Geometric anneal tau_start -> tau_end over ``cfg.steps``."""
    if cfg.steps == 1:
        return jnp.asarray([cfg.tau_start], jnp.float32)
    i = jnp.arange(cfg.steps, dtype=jnp.float32) / (cfg.steps - 1)
    return cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** i


@functools.partial(jax.jit, static_argnames=("cfg",))
def _tune_loop(raw0: PolicyParams, problem, *, cfg: TuneConfig):
    opt = AdamWConfig(lr=cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                      weight_decay=0.0, clip_norm=cfg.clip_norm)

    def row_update(g, st, p):
        new_p, new_st, _ = adamw_update(g, st, p, opt)
        return new_p, new_st

    state_axes = AdamWState(step=None, mu=0, nu=0)
    vupdate = jax.vmap(row_update, in_axes=(0, state_axes, 0),
                       out_axes=(0, state_axes))

    grad_fn = jax.value_and_grad(soft_objective, has_aux=True)
    state0 = AdamWState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(jnp.zeros_like, raw0),
                        nu=jax.tree.map(jnp.zeros_like, raw0))

    def step(carry, tau):
        raw, st = carry
        (loss, aux), grads = grad_fn(
            raw, problem, tau, power_cap_mw=cfg.power_cap_mw,
            min_up_hours=cfg.min_up_hours,
            penalty_weight=cfg.penalty_weight)
        raw, st = vupdate(grads, st, raw)
        return (raw, st), {"loss": loss, "tau": tau,
                           "penalty": aux["penalty"]}

    (raw_f, _), hist = jax.lax.scan(step, (raw0, state0),
                                    _tau_schedule(cfg))
    return raw_f, hist


@jax.jit
def hard_cpc(p_on, p_off, off_level, problem) -> jnp.ndarray:
    """Hard (tau -> 0) CPC of arbitrary per-row policy variables under
    each row's own hardware parameters — the engine's exact scan + cost
    path."""
    p_rows = problem.row_prices()
    scan = fleet_scan_ref(p_rows, p_on, p_off, off_level,
                          problem.idle_frac)
    return fleet_costs(
        scan, price_sum=problem.price_sum, fixed=problem.fixed,
        power=problem.power, period=problem.period,
        restart_energy_mwh=problem.restart_energy_mwh,
        restart_time_h=problem.restart_time_h,
        n_samples=p_rows.shape[1]).cpc


def cell_best_rows(grid, cpc: np.ndarray) -> np.ndarray:
    """Index of the lowest-CPC row within each row's (market, system)
    cell, mapped back onto rows (robust to row permutations)."""
    key = cell_index(grid)
    best: dict[int, int] = {}
    for b in range(len(key)):
        c = int(key[b])
        if c not in best or cpc[b] < cpc[best[c]]:
            best[c] = b
    return np.asarray([best[int(c)] for c in key], np.int64)


def _dispatch_reeval(grid, params: PhysicalPolicy, cpc: np.ndarray,
                     best_row: np.ndarray, dcfg: DispatchConfig) -> dict:
    """Score the selected (tuned) and the best-swept policy sets under
    the *feasible* cross-site dispatcher — one site per (market, system)
    cell, hard constraints instead of the soft tuning penalties. A
    policy set that cannot meet the configured demand is not clipped to
    fit: it scores ``cpc = inf`` with the `DispatchInfeasible` reason
    recorded, and the feasible set (if any) is chosen."""
    key = cell_index(grid)
    sel: dict[int, int] = {}
    for b in range(len(key)):
        c = int(key[b])
        if c not in sel or cpc[b] < cpc[sel[c]]:
            sel[c] = b
    rows = np.asarray([sel[c] for c in sorted(sel)], np.int64)
    markets = np.asarray(grid.market_idx)[rows]
    prices = np.asarray(grid.prices)[markets]
    power = np.asarray(grid.power)[rows]
    fixed = np.asarray(grid.fixed)[rows]

    def run(p_on, p_off, lvl, take):
        try:
            return dispatch_solve(build_problem(
                prices, np.asarray(p_on)[take], np.asarray(p_off)[take],
                np.asarray(lvl)[take], power, dcfg, fixed=fixed)), None
        except DispatchInfeasible as e:
            return None, str(e)

    tuned, why_t = run(params.p_on, params.p_off, params.off_level, rows)
    sw = best_row[rows]
    swept, why_s = run(grid.p_on, grid.p_off, grid.off_level, sw)
    cpc_t = tuned.cpc if tuned is not None else float("inf")
    cpc_s = swept.cpc if swept is not None else float("inf")
    chosen = None if tuned is None and swept is None else \
        ("tuned" if cpc_t <= cpc_s else "swept")
    return {"cpc_tuned": cpc_t, "cpc_swept": cpc_s, "chosen": chosen,
            "tuned": tuned, "swept": swept,
            "infeasible_tuned": why_t, "infeasible_swept": why_s}


def optimize(grid, cfg: TuneConfig = TuneConfig()) -> TuneResult:
    """Gradient-tune every scenario row of ``grid``; hard-re-evaluate.

    Each row is seeded at its own swept `PolicySpec` (so the grid's K
    policies double as K random restarts per (market, system) cell) and
    tuned for ``cfg.steps`` Adam steps under the annealed soft
    objective. The final selection keeps, per row, the best hard-CPC
    policy among the tuned parameters and the swept baselines — when
    hardware parameters (idle draw, restart costs) are uniform within a
    cell, the reported ``cpc`` therefore matches or beats the best swept
    policy on every row. With fleet-coupling penalties configured the
    swept fallback is disabled (swept policies ignore the constraints),
    so ``cpc`` reports the tuned params unconditionally.
    """
    problem = problem_from_grid(grid)
    raw0 = init_from_grid(grid)
    raw_f, hist = _tune_loop(raw0, problem, cfg=cfg)

    # hard re-evaluation at tau -> 0
    swept = backtest(grid, use_pallas=False)
    cpc_swept = np.asarray(swept.cpc, np.float64)
    best_row = cell_best_rows(grid, cpc_swept)
    cpc_swept_best = cpc_swept[best_row]

    tuned = transform(raw_f)
    cpc_tuned = np.asarray(hard_cpc(tuned.p_on, tuned.p_off,
                                     tuned.off_level, problem), np.float64)
    # cell-best swept params evaluated under *this* row's hardware
    cb = PhysicalPolicy(p_on=grid.p_on[best_row], p_off=grid.p_off[best_row],
                        off_level=grid.off_level[best_row])
    cpc_cb = np.asarray(hard_cpc(cb.p_on, cb.p_off, cb.off_level, problem),
                        np.float64)

    cand = np.stack([cpc_tuned, cpc_swept, cpc_cb])        # [3, B]
    if cfg.power_cap_mw is not None or cfg.min_up_hours is not None:
        # fleet-coupling constraints: the swept baselines ignore them, so
        # falling back to a lower-CPC swept policy would silently violate
        # the constraint the user asked for — keep the tuned params.
        source = np.zeros(cand.shape[1], np.int64)
    else:
        source = np.argmin(cand, axis=0)
    cpc = cand[source, np.arange(cand.shape[1])]

    def pick(tuned_v, own_v, cb_v):
        stacked = jnp.stack([jnp.asarray(tuned_v), jnp.asarray(own_v),
                             jnp.asarray(cb_v)])
        return stacked[source, jnp.arange(stacked.shape[1])]

    params = PhysicalPolicy(
        p_on=pick(tuned.p_on, grid.p_on, cb.p_on),
        p_off=pick(tuned.p_off, grid.p_off, cb.p_off),
        off_level=pick(tuned.off_level, grid.off_level, cb.off_level))

    dispatch_out = None
    if cfg.dispatch is not None:
        dispatch_out = _dispatch_reeval(grid, params, cpc, best_row,
                                        cfg.dispatch)

    return TuneResult(
        params=params, raw=raw_f, cpc=cpc, cpc_tuned=cpc_tuned,
        cpc_swept=cpc_swept, cpc_swept_best=cpc_swept_best,
        improvement_vs_best=1.0 - cpc / cpc_swept_best,
        improvement_vs_own=1.0 - cpc / cpc_swept,
        source=source,
        history={k: np.asarray(v) for k, v in hist.items()},
        dispatch=dispatch_out)
