"""Batched gradient tuning of shutdown policies over a whole fleet grid.

`optimize` turns every row of a `ScenarioGrid` into an independent
optimization problem: the row's three policy variables (threshold,
hysteresis gap, off-capacity level — reparameterized unconstrained, see
`repro.tune.objective`) descend the temperature-relaxed CPC objective
simultaneously, one jitted `lax.scan` over optimization steps with the
whole [B]-row gradient computed in a single backward pass through the
soft scan — by default the fused, checkpointed custom VJP of
`repro.kernels.soft_scan_vjp` (``TuneConfig.fused``), which replaces
native autodiff through the associative scan with a block-local
recompute and cuts both the backward's arithmetic and its residual
memory.

The update rule *is* `repro.optim.adamw.adamw_update` — the same code
path that trains the models — vmapped over rows so each row carries its
own Adam moments and (optionally) its own per-row gradient clip.

The hot loop (`tune_loop`) is one compiled program: the τ-annealing
schedule, every Adam step, and the final hard (τ → 0) re-evaluation all
run inside a single jit with the raw-parameter carry donated, so a
tuning run is one dispatch and the optimizer state never round-trips.
Because the per-row gradients are batch-independent (sum-reduction, see
`soft_objective`), the loop also scales out without changing results:

  * row chunking — ``TuneConfig.chunk_rows`` tunes the grid in fixed
    row slices (padded to one compile shape), bounding peak memory so
    B ~ 10^5 grids tune on one host — *bit-identical* to the one-shot
    program (every chunk compiles to the same shape);
  * ``shard_map`` over B — with more than one device (including CPU
    virtual devices via ``--xla_force_host_platform_device_count``),
    rows are split across a 1-D `repro.parallel.row_mesh` and tuned in
    parallel. Same math, but XLA codegen depends on the shard width, so
    agreement with the single-device program is ULP-level rather than
    bitwise (shards narrower than 2 rows are never created).

Temperature annealing: the sigmoid temperature follows a geometric
schedule from ``tau_start`` (smooth, wide basins — gradients see far
across the price distribution) down to ``tau_end`` (nearly hard — the
soft objective tracks the real discrete-switching CPC). After the last
step the result is re-evaluated under the *hard* scan (tau -> 0
exactly), and each row keeps the best of {tuned params, its own swept
policy, the best swept policy of its (market, system) cell} — so the
reported CPC can never be worse than the swept grid it started from.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.dispatch import (DispatchConfig, DispatchInfeasible,
                            build_problem)
from repro.dispatch import dispatch as dispatch_solve
from repro.execution import (Coupling, ExecutionPlan, take_rows,
                             validate_plan_coupling)
from repro.fleet.engine import backtest, fleet_costs
from repro.fleet.grid import concat_rows, row_chunks
from repro.kernels.ref import fleet_scan_ref
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update
from repro.parallel.axes import SHARD_MAP_NOCHECK, row_mesh, shard_map
from repro.tune.objective import (DispatchCoupling, PhysicalPolicy,
                                  PolicyParams, TuneProblem, cell_index,
                                  dispatch_coupling_from_grid,
                                  init_from_grid, inverse_transform,
                                  problem_from_grid, soft_objective,
                                  transform)

from jax.sharding import PartitionSpec as P


_PLAN_FIELD_DEFAULTS = {"chunk_rows": 0, "shard": True}
_COUPLING_FIELD_DEFAULTS = {
    "power_cap_mw": None, "min_up_hours": None, "penalty_weight": 10.0,
    "dispatch": None, "dispatch_soft": None, "dispatch_blend": 0.5,
    "dispatch_mw_scale": 0.05}


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """Hyperparameters of a fleet tuning run (hashable — used as a jit
    static argument).

    Execution layout and fleet coupling are configured by the
    `repro.execution` pair: ``plan`` (`ExecutionPlan`: single / chunked
    / sharded, device cap, reproducibility contract) and ``coupling``
    (`Coupling`: power cap, aggregate-compute floor, dispatch-aware
    term, hard-dispatch re-scoring). The pre-redesign spellings
    (``chunk_rows`` / ``shard`` / ``power_cap_mw`` / ``min_up_hours`` /
    ``penalty_weight`` / ``dispatch`` / ``dispatch_soft`` /
    ``dispatch_blend`` / ``dispatch_mw_scale``) still work for one
    release: they emit a `DeprecationWarning` at construction and
    forward into ``resolved_plan`` / ``resolved_coupling``, which is
    all the tuner reads. Mixing an explicit ``plan=``/``coupling=``
    with the old spellings it replaces raises. The chunk-under-coupling
    legality rule is a constructor invariant
    (`repro.execution.validate_plan_coupling`), raised here instead of
    deep inside the hot-loop dispatcher.
    """

    steps: int = 300
    lr: float = 0.5              # raw-space Adam step (price units for
                                 # raw_off; Adam normalizes per-coordinate)
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    clip_norm: float = 0.0       # per-row grad clip; 0 disables. The
                                 # clipped quantity is the row's
                                 # gradient of its *own* CPC ratio
                                 # (sum-reduction, B-independent);
                                 # values calibrated against the PR-2/3
                                 # mean-reduction loop scale by 1/B
    tau_start: float = 30.0      # EUR/MWh-scale smoothing at the start
    tau_end: float = 0.3         # nearly hard by the end
    # hot-loop implementation knobs
    fused: bool = True           # checkpointed custom-VJP soft scan +
                                 # fused soft-dispatch VJP (False:
                                 # native autodiff — the PR-3 baseline,
                                 # kept for A/B benchmarks)
    block_t: int = 256           # checkpoint block length (hours)
    # deprecated execution spellings (forward into resolved_plan)
    chunk_rows: int = 0
    shard: bool = True
    eval_stages: int = 4         # hard (tau -> 0) re-evaluations spread
                                 # over the anneal: the scan splits into
                                 # this many segments (same per-step
                                 # ops; trajectories agree to float
                                 # round-off across stage counts) with
                                 # a hard CPC re-eval at each boundary
                                 # -> TuneResult.stage_cpc; clamped to
                                 # [1, steps]
    # deprecated coupling spellings (forward into resolved_coupling)
    power_cap_mw: Optional[float] = None
    min_up_hours: Optional[float] = None
    penalty_weight: float = 10.0
    dispatch: Optional[DispatchConfig] = None
    dispatch_soft: Optional[DispatchConfig] = None
    dispatch_blend: float = 0.5
    dispatch_mw_scale: float = 0.05
    # optional `repro.workload.Workload` (duck-typed, hashable frozen
    # dataclass — safe as part of this jit-static config): the soft
    # objective adds its SLO-aware work-ledger term and `optimize`
    # selects candidates by *realized* workload cost (energy + deferral
    # + drop) instead of bare CPC. None falls back to ``grid.workload``;
    # neither set keeps today's programs untouched
    workload: Optional[object] = None
    # the redesigned config surface (None: derive from the fields above)
    plan: Optional[ExecutionPlan] = None
    coupling: Optional[Coupling] = None

    def __post_init__(self):
        plan_old = [k for k, d in _PLAN_FIELD_DEFAULTS.items()
                    if getattr(self, k) != d]
        coup_old = [k for k, d in _COUPLING_FIELD_DEFAULTS.items()
                    if getattr(self, k) != d]
        if self.plan is not None and plan_old:
            raise ValueError(
                f"TuneConfig: pass plan= or the deprecated "
                f"{'/'.join(plan_old)}, not both")
        if self.coupling is not None and coup_old:
            raise ValueError(
                f"TuneConfig: pass coupling= or the deprecated "
                f"{'/'.join(coup_old)}, not both")
        for k in plan_old:
            warnings.warn(
                f"TuneConfig.{k} is deprecated — pass "
                f"plan=repro.execution.ExecutionPlan(...) instead",
                DeprecationWarning, stacklevel=3)
        for k in coup_old:
            warnings.warn(
                f"TuneConfig.{k} is deprecated — pass "
                f"coupling=repro.execution.Coupling(...) instead",
                DeprecationWarning, stacklevel=3)
        # constructor invariants: ExecutionPlan validates chunk_rows
        # (width-1 chunks etc.), validate_plan_coupling the
        # chunk-under-coupling contradiction — both raised here, at
        # assembly time, not deep inside the hot-loop dispatcher
        validate_plan_coupling(self.resolved_plan,
                               self.resolved_coupling,
                               context="TuneConfig")

    @property
    def resolved_plan(self) -> ExecutionPlan:
        """The `ExecutionPlan` the tuner executes: ``plan`` when given,
        else the deprecated fields' equivalent (``chunk_rows`` ->
        chunked/bitwise, ``shard=False`` -> single, else auto)."""
        if self.plan is not None:
            return self.plan
        if self.chunk_rows:
            return ExecutionPlan(mode="chunked",
                                 chunk_rows=self.chunk_rows,
                                 contract="bitwise")
        if not self.shard:
            return ExecutionPlan(mode="single")
        return ExecutionPlan()

    @property
    def resolved_coupling(self) -> Coupling:
        """The `Coupling` in force (never None — an unbound `Coupling()`
        when nothing couples): ``coupling`` when given, else the
        deprecated fields' equivalent."""
        if self.coupling is not None:
            return self.coupling
        return Coupling(power_cap_mw=self.power_cap_mw,
                        min_up_hours=self.min_up_hours,
                        penalty_weight=self.penalty_weight,
                        dispatch=self.dispatch_soft,
                        dispatch_blend=self.dispatch_blend,
                        dispatch_mw_scale=self.dispatch_mw_scale,
                        reeval=self.dispatch)

    def _replace(self, **kw) -> "TuneConfig":
        """NamedTuple-style replace (the pre-redesign TuneConfig was a
        NamedTuple; callers keep working)."""
        return dataclasses.replace(self, **kw)


class TuneResult(NamedTuple):
    """Output of `optimize` (per-row arrays of shape [B])."""

    params: PhysicalPolicy       # selected per-row policy (hard-eval best)
    raw: PolicyParams            # final raw params of the gradient run
    cpc: np.ndarray              # hard CPC of the selected policy
    cpc_tuned: np.ndarray        # hard CPC of the gradient-tuned params
    cpc_swept: np.ndarray        # engine CPC of the row's own swept policy
    cpc_swept_best: np.ndarray   # best engine CPC in the row's cell
    improvement_vs_best: np.ndarray   # 1 - cpc / cpc_swept_best
    improvement_vs_own: np.ndarray    # 1 - cpc / cpc_swept
    source: np.ndarray           # 0 = tuned, 1 = own swept, 2 = cell best
    history: dict                # per-step arrays: loss, tau, penalty
    # mean hard CPC at each anneal-stage boundary ([cfg.eval_stages],
    # last entry == mean(cpc_tuned)) — the convergence curve the soft
    # loss cannot show (chunked runs report the mean over row chunks)
    stage_cpc: Optional[np.ndarray] = None
    # feasible-dispatch re-evaluation (None unless cfg.dispatch or
    # cfg.dispatch_soft given): {"cpc_tuned", "cpc_swept", "chosen",
    # "tuned", "swept", "rows", "site_names", "infeasible_*"} where
    # "tuned"/"swept" are repro.dispatch.DispatchResult and "rows" the
    # grid rows operated as sites
    dispatch: Optional[dict] = None
    # total row-steps the finite-step guard rejected (0 on any healthy
    # run; per-step counts in history["guard_rejects"])
    guard_count: int = 0
    # [B] realized workload cost (energy + deferral + drop, EUR, mean
    # over the shared demand draws) of the *selected* policy — None
    # unless a Workload was configured; when set, ``source`` was chosen
    # by this yardstick instead of bare CPC
    workload_cost: Optional[np.ndarray] = None


def _tau_schedule(cfg: TuneConfig) -> jnp.ndarray:
    """Geometric anneal tau_start -> tau_end over ``cfg.steps``."""
    if cfg.steps == 1:
        return jnp.asarray([cfg.tau_start], jnp.float32)
    i = jnp.arange(cfg.steps, dtype=jnp.float32) / (cfg.steps - 1)
    return cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** i


def _hard_cpc_rows(p_on, p_off, off_level, problem: TuneProblem
                   ) -> jnp.ndarray:
    """Hard (tau -> 0) CPC of arbitrary per-row policy variables under
    each row's own hardware parameters — the engine's exact scan + cost
    path. Traced into `tune_loop` (and into the jitted `hard_cpc`)."""
    p_rows = problem.row_prices()
    scan = fleet_scan_ref(p_rows, p_on, p_off, off_level,
                          problem.idle_frac)
    return fleet_costs(
        scan, price_sum=problem.price_sum, fixed=problem.fixed,
        power=problem.power, period=problem.period,
        restart_energy_mwh=problem.restart_energy_mwh,
        restart_time_h=problem.restart_time_h,
        n_samples=p_rows.shape[1]).cpc


hard_cpc = jax.jit(_hard_cpc_rows)


def _stage_bounds(cfg: TuneConfig) -> list:
    """Step indices of the anneal-stage boundaries: ``eval_stages``
    near-equal segments of [0, steps] (strictly increasing — clamped to
    at most one stage per step)."""
    stages = max(1, min(int(cfg.eval_stages), cfg.steps))
    return [(i * cfg.steps) // stages for i in range(stages + 1)]


def _loop_body(raw0: PolicyParams, problem: TuneProblem, cfg: TuneConfig,
               coupling: Optional[DispatchCoupling] = None,
               telemetry: bool = False,
               axis_name: Optional[str] = None,
               scale_rows: Optional[int] = None):
    """The tuner hot loop: annealed Adam scan + hard re-evaluations.

    Traced under plain jit (single program), under `shard_map` (one
    shard of rows), and per chunk — identical per-row math in all
    three, which is what makes the scaled-out paths bit-consistent
    (``coupling`` is non-None in the single program and, since the
    psum rework, in the sharded path — never in a chunk).

    The step scan runs as ``cfg.eval_stages`` back-to-back `lax.scan`
    segments over the one tau schedule — the per-step ops are the same,
    so trajectories agree across stage counts to float round-off
    (segment boundaries change XLA fusion, hence ULP-level rather than
    bitwise) — with the *hard* (tau -> 0)
    CPC re-evaluated at each boundary (``history["stage_cpc"]``,
    [stages]; its last entry is the final hard re-eval, so the stage
    curve is free). ``telemetry`` adds per-step grad-norm / clip-
    fraction side-outputs to the history — observers of values the
    update already computes, never inputs to it, keeping the tuned
    parameters bit-identical (asserted in tests/test_obs.py).

    ``axis_name`` (set when traced inside the sharded path's
    `shard_map`) flows into `soft_objective`, whose fleet aggregates
    then psum-reduce across shards — coupled objectives shard;
    ``scale_rows`` pins the coupled terms' B-scale at the real global
    row count. The per-shard history loss removes the coupled term's
    cross-shard duplication (every shard carries the full global term)
    so the shard-averaged history matches the single program's.
    Returns ``(raw_f, history, cpc_tuned)``.
    """
    b = raw0.raw_off.shape[0]
    step = _make_step(problem, cfg, coupling, b, telemetry=telemetry,
                      axis_name=axis_name, scale_rows=scale_rows)
    taus = _tau_schedule(cfg)
    bounds = _stage_bounds(cfg)
    carry = _init_carry(raw0)
    hists, stage_cpc = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        carry, h = jax.lax.scan(step, carry, taus[lo:hi])
        hists.append(h)
        ph = transform(carry[0])
        cpc_rows = _hard_cpc_rows(ph.p_on, ph.p_off, ph.off_level,
                                  problem)
        stage_cpc.append(jnp.mean(cpc_rows))
    raw_f = carry[0]
    hist = hists[0] if len(hists) == 1 else \
        jax.tree.map(lambda *xs: jnp.concatenate(xs), *hists)
    hist["stage_cpc"] = jnp.stack(stage_cpc)
    # cpc_rows from the last stage IS the final hard re-evaluation
    return raw_f, hist, cpc_rows


_LR_BACKOFF_FLOOR = 2.0 ** -10   # per-row lr multiplier never decays
                                 # below this — a row that recovers
                                 # still moves


def _init_carry(raw0: PolicyParams):
    """The hot loop's scan carry: raw params, per-row Adam moments, and
    the per-row guard lr multiplier (1.0 until a step is rejected)."""
    b = raw0.raw_off.shape[0]
    state0 = AdamWState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(jnp.zeros_like, raw0),
                        nu=jax.tree.map(jnp.zeros_like, raw0))
    return (raw0, state0, jnp.ones((b,), jnp.float32))


def _make_step(problem: TuneProblem, cfg: TuneConfig,
               coupling: Optional[DispatchCoupling], b: int, *,
               telemetry: bool = False,
               axis_name: Optional[str] = None,
               scale_rows: Optional[int] = None):
    """Build the per-step closure of the Adam scan (shared by
    `_loop_body` and the stage-wise `tune_loop_checkpointed` segments,
    so both trace the *same* per-step program).

    Every step carries a branchless finite-step guard: a row whose soft
    CPC ratio or gradient leaves a non-finite value (a NaN price gap
    reaching the objective, an overflowing coupled term mid-storm) has
    its gradient zeroed, its parameters and Adam moments held, and its
    per-row lr multiplier halved (floor ``_LR_BACKOFF_FLOOR``) — the
    row re-enters at reduced step size instead of poisoning the carry.
    On an all-finite run every guard op is an exact arithmetic identity
    (``where(True, x, _)``, ``where(lr == 1.0, new, _)``), so healthy
    trajectories are bit-identical to the unguarded loop (asserted in
    tests/test_faults.py). The per-step reject count streams out as
    ``history["guard_rejects"]``.
    """
    rc = cfg.resolved_coupling
    opt = AdamWConfig(lr=cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                      weight_decay=0.0, clip_norm=cfg.clip_norm)

    def row_update(g, st, p):
        new_p, new_st, _ = adamw_update(g, st, p, opt)
        return new_p, new_st

    state_axes = AdamWState(step=None, mu=0, nu=0)
    vupdate = jax.vmap(row_update, in_axes=(0, state_axes, 0),
                       out_axes=(0, state_axes))

    grad_fn = jax.value_and_grad(soft_objective, has_aux=True)
    min_dwell = rc.dispatch.min_dwell_h \
        if rc.dispatch is not None else 0
    wl = getattr(cfg, "workload", None)
    # the [T] mean demand profile is host-side numpy (constant-folded
    # into the traced program); each row serves it independently, so
    # the workload term stays per-row separable on every plan path
    wl_demand = None if wl is None else jnp.asarray(
        wl.mean_demand_mw(int(problem.prices.shape[1])), jnp.float32)

    def step(carry, tau):
        raw, st, lr_scale = carry
        (loss, aux), grads = grad_fn(
            raw, problem, tau, power_cap_mw=rc.power_cap_mw,
            min_up_hours=rc.min_up_hours,
            penalty_weight=rc.penalty_weight,
            dispatch=coupling, dispatch_blend=rc.dispatch_blend,
            dispatch_min_dwell=min_dwell,
            dispatch_mw_scale=rc.dispatch_mw_scale,
            dispatch_fused=cfg.fused, relief=rc.relief_config,
            workload=wl, workload_demand=wl_demand,
            fused=cfg.fused, block_t=cfg.block_t, reduction="sum",
            axis_name=axis_name, scale_rows=scale_rows)
        if axis_name is None:
            hist_loss = loss / b
        else:
            # every shard's loss carries the full global coupled term;
            # keep 1/n_sh of it so the caller's shard average (which
            # divides the separable part by B through the b-per-shard
            # denominators) reproduces the single program's loss/B
            n_sh = jax.lax.psum(1, axis_name)
            hist_loss = (loss - aux["coupled"] * (1.0 - 1.0 / n_sh)) / b
        # finite-step guard: per-row accept mask over the row's own CPC
        # ratio and its three gradient components
        ok = (jnp.isfinite(aux["ratio"]) & jnp.isfinite(grads.raw_off)
              & jnp.isfinite(grads.raw_gap)
              & jnp.isfinite(grads.raw_lvl))                  # [B]
        out = {"loss": hist_loss, "tau": tau,
               "penalty": aux["penalty"],
               "dispatch_ratio": aux["dispatch_ratio"],
               "guard_rejects": jnp.sum((~ok).astype(jnp.float32))}
        if telemetry:
            # observers only: read the gradients the update consumes,
            # feed nothing back
            norm = jnp.sqrt(grads.raw_off ** 2 + grads.raw_gap ** 2
                            + grads.raw_lvl ** 2)            # [B]
            out["grad_norm"] = jnp.mean(norm)
            out["clip_frac"] = (
                jnp.mean((norm > cfg.clip_norm).astype(norm.dtype))
                if cfg.clip_norm else jnp.zeros((), norm.dtype))
        g_safe = jax.tree.map(lambda g: jnp.where(ok, g, 0.0), grads)
        new_p, new_st = vupdate(g_safe, st, raw)
        # backed-off rows blend toward the Adam target; where(lr == 1)
        # selects new_p itself because raw + 1.0 * (new_p - raw) is NOT
        # a bitwise identity
        applied = jax.tree.map(
            lambda n, r: jnp.where(lr_scale == 1.0, n,
                                   r + lr_scale * (n - r)), new_p, raw)
        raw_new = jax.tree.map(lambda a, r: jnp.where(ok, a, r),
                               applied, raw)
        st_new = AdamWState(
            step=new_st.step,       # global step counts every attempt
            mu=jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                            new_st.mu, st.mu),
            nu=jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                            new_st.nu, st.nu))
        lr_new = jnp.where(ok, lr_scale,
                           jnp.maximum(lr_scale * 0.5,
                                       _LR_BACKOFF_FLOOR))
        return (raw_new, st_new, lr_new), out

    return step


@functools.partial(jax.jit, static_argnames=("cfg", "telemetry"),
                   donate_argnums=(0,))
def tune_loop(raw0: PolicyParams, problem: TuneProblem,
              coupling: Optional[DispatchCoupling] = None, *,
              cfg: TuneConfig, telemetry: bool = False):
    """One compiled tuning program: τ-annealed Adam over all rows plus
    the staged hard re-evaluations, with the raw-parameter carry donated
    (the Adam scan reuses its buffers instead of allocating fresh ones
    each call). ``coupling`` (from `dispatch_coupling_from_grid`)
    switches on the dispatch-aware fleet term. ``telemetry`` is static:
    False (the default, and whenever `repro.obs` is disabled) compiles
    the exact pre-telemetry program with no extra side-outputs. This is
    the object `benchmarks/bench_tune.py` times."""
    return _loop_body(raw0, problem, cfg, coupling, telemetry)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "lo", "hi", "telemetry"))
def _stage_segment(carry, problem: TuneProblem,
                   coupling: Optional[DispatchCoupling] = None, *,
                   cfg: TuneConfig, lo: int, hi: int,
                   telemetry: bool = False):
    """One anneal stage of the checkpointed runner: the Adam scan over
    ``taus[lo:hi]`` plus the boundary's hard re-evaluation, jitted per
    (cfg, stage window). Same per-step program as `tune_loop` (built by
    `_make_step`); stage boundaries compile separately, so the
    checkpointed trajectory's bit-identity contract is against *itself*
    (resumed == uninterrupted), not against the single-jit loop —
    XLA fusion differs across program boundaries."""
    b = carry[0].raw_off.shape[0]
    step = _make_step(problem, cfg, coupling, b, telemetry=telemetry)
    taus = _tau_schedule(cfg)
    carry, hist = jax.lax.scan(step, carry, taus[lo:hi])
    ph = transform(carry[0])
    cpc_rows = _hard_cpc_rows(ph.p_on, ph.p_off, ph.off_level, problem)
    return carry, hist, cpc_rows


def _ckpt_template(carry, cfg: TuneConfig, n_steps_done: int,
                   n_stages_done: int, b: int, telemetry: bool) -> dict:
    """Zero-filled pytree matching a `tune_loop_checkpointed` save after
    ``n_stages_done`` stages — what `load_checkpoint` restores into."""
    keys = ["dispatch_ratio", "guard_rejects", "loss", "penalty", "tau"]
    if telemetry:
        keys += ["clip_frac", "grad_norm"]
    return {
        "carry": carry,
        "hist": {k: np.zeros((n_steps_done,), np.float32)
                 for k in keys},
        "stage_cpc": np.zeros((n_stages_done,), np.float32),
        "cpc_rows": np.zeros((b,), np.float32),
    }


def tune_loop_checkpointed(raw0: PolicyParams, problem: TuneProblem,
                           coupling: Optional[DispatchCoupling] = None,
                           *, cfg: TuneConfig, directory,
                           telemetry: bool = False, keep: int = 2):
    """`tune_loop` as resumable anneal stages with the full optimizer
    carry checkpointed at every stage boundary (`repro.checkpoint`).

    The scan runs stage by stage (`_stage_segment`, one jit per stage
    window); after each stage the carry — raw params, per-row Adam
    moments, the guard's lr multipliers — plus the accumulated history
    and stage CPCs land under ``directory`` via `CheckpointManager`
    (npz round-trips float bits exactly). A rerun over the same
    directory restores the newest stage and continues: a killed run
    resumed this way is *bit-identical* to one that never died
    (asserted in tests/test_faults.py), because the restored carry is
    the exact bytes the uninterrupted run would have carried and every
    remaining stage re-traces the same program. Returns
    ``(raw_f, history, cpc_tuned)`` like `tune_loop`."""
    from repro.checkpoint import CheckpointManager

    raw0 = PolicyParams(*(jnp.asarray(a) for a in raw0))
    b = raw0.raw_off.shape[0]
    bounds = _stage_bounds(cfg)
    n_stages = len(bounds) - 1
    mgr = CheckpointManager(directory, keep=keep)
    carry = _init_carry(raw0)
    hists: list = []
    stage_cpc: list = []
    cpc_rows = None
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        k = min(int(latest), n_stages)
        tree, _ = mgr.restore(
            _ckpt_template(carry, cfg, bounds[k], k, b, telemetry),
            step=latest)
        carry = tree["carry"]
        hists = [tree["hist"]]
        stage_cpc = [np.float32(v) for v in tree["stage_cpc"]]
        cpc_rows = tree["cpc_rows"]
        start = k
    for k in range(start, n_stages):
        carry, h, cpc_rows = _stage_segment(
            carry, problem, coupling, cfg=cfg, lo=bounds[k],
            hi=bounds[k + 1], telemetry=telemetry)
        hists.append({kk: np.asarray(v) for kk, v in h.items()})
        stage_cpc.append(np.float32(np.asarray(jnp.mean(cpc_rows))))
        hist_acc = {kk: np.concatenate([np.asarray(hh[kk])
                                        for hh in hists])
                    for kk in hists[0]}
        mgr.save(k + 1, {
            "carry": carry, "hist": hist_acc,
            "stage_cpc": np.asarray(stage_cpc, np.float32),
            "cpc_rows": np.asarray(cpc_rows, np.float32)},
            metadata={"stage": k + 1, "steps": cfg.steps},
            blocking=True)
    hist = {kk: np.concatenate([np.asarray(hh[kk]) for hh in hists])
            for kk in hists[0]}
    hist["stage_cpc"] = np.asarray(stage_cpc, np.float32)
    return carry[0], hist, cpc_rows


_PROBLEM_ROW_FIELDS = tuple(f for f in TuneProblem._fields
                            if f != "prices")

# history keys that count events over rows merge across chunks/shards
# by summing; everything else (losses, taus, fractions) averages
_HIST_MERGE = {"guard_rejects": np.sum}


def _take_problem(problem: TuneProblem, idx: np.ndarray) -> TuneProblem:
    """Row-slice every [B] field of a `TuneProblem` (prices stay shared,
    exactly like `ScenarioGrid.take_rows`) — the generic shape-driven
    `repro.execution.take_rows`."""
    return take_rows(problem, idx, shared=("prices",))


@functools.cache
def _sharded_loop(n_dev: int, cfg: TuneConfig, telemetry: bool = False):
    """jit(shard_map(loop)) over a 1-D row mesh, cached per
    (n_dev, cfg, telemetry).

    Per-shard histories come back stacked [n_dev, steps]; the caller
    averages them (equal shard sizes)."""
    mesh = row_mesh(n_dev)
    rows = P("rows")

    def body(raw0, problem):
        raw_f, hist, cpc = _loop_body(raw0, problem, cfg,
                                      telemetry=telemetry)
        return raw_f, {k: v[None] for k, v in hist.items()}, cpc

    in_specs = (rows, TuneProblem(
        prices=P(), **{f: rows for f in _PROBLEM_ROW_FIELDS}))
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(rows, rows, rows), **SHARD_MAP_NOCHECK)
    return jax.jit(fn, donate_argnums=(0,))


@functools.cache
def _sharded_plan_loop(n_dev: int, cfg: TuneConfig, scale_rows: int,
                       with_dispatch: bool, telemetry: bool = False):
    """jit(shard_map(loop)) for an explicit ``mode='sharded'`` plan:
    the loop traces with ``axis_name='rows'`` so every fleet aggregate
    psum-reduces across shards — coupled objectives included. Cached
    per (n_dev, cfg, real-row count, dispatch-coupling presence)."""
    mesh = row_mesh(n_dev)
    rows = P("rows")

    def body(raw0, problem, coupling=None):
        raw_f, hist, cpc = _loop_body(raw0, problem, cfg, coupling,
                                      telemetry=telemetry,
                                      axis_name="rows",
                                      scale_rows=scale_rows)
        return raw_f, {k: v[None] for k, v in hist.items()}, cpc

    prob_specs = TuneProblem(
        prices=P(), **{f: rows for f in _PROBLEM_ROW_FIELDS})
    if with_dispatch:
        coup_specs = DispatchCoupling(
            cell_id=rows, prices=P(), keys=P(), order=P(), demand=P(),
            fixed=rows, power=rows, migrate_cost=P(), cpc_ref=P())
        fn = shard_map(body, mesh=mesh,
                       in_specs=(rows, prob_specs, coup_specs),
                       out_specs=(rows, rows, rows),
                       **SHARD_MAP_NOCHECK)
    else:
        fn = shard_map(lambda r, p: body(r, p), mesh=mesh,
                       in_specs=(rows, prob_specs),
                       out_specs=(rows, rows, rows),
                       **SHARD_MAP_NOCHECK)
    return jax.jit(fn, donate_argnums=(0,))


def _pad_rows(raw0: PolicyParams, problem: TuneProblem,
              coupling: Optional[DispatchCoupling], n_rows: int,
              b_pad: int):
    """Pad the row axis to ``b_pad`` for equal shard widths by
    repeating row 0 — *including* ``raw0``, so a warm start survives
    the padding (the silent-ignore bug this replaces). Padded rows are
    neutralised out of every fleet aggregate: zero site weight (power
    cap / up-hours), zero coupling power and fixed cost, and the dummy
    dispatch cell ``C`` that `soft_dispatch_ratio`'s sharded branch
    discards — their own tuning trajectory is real but dropped on
    return, and sum-reduction keeps them out of real rows' gradients.
    """
    pad = b_pad - n_rows

    def rep0(x):
        x = jnp.asarray(x)
        return jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)])

    raw0 = jax.tree.map(rep0, raw0)
    problem = problem._replace(
        **{f: rep0(getattr(problem, f)) for f in _PROBLEM_ROW_FIELDS})
    problem = problem._replace(
        site_weight=problem.site_weight.at[n_rows:].set(0.0))
    if coupling is not None:
        c = coupling.prices.shape[0]
        coupling = coupling._replace(
            cell_id=jnp.concatenate([
                coupling.cell_id, jnp.full((pad,), c, jnp.int32)]),
            fixed=rep0(coupling.fixed).at[n_rows:].set(0.0),
            power=rep0(coupling.power).at[n_rows:].set(0.0))
    return raw0, problem, coupling


def _run_sharded(raw0: PolicyParams, problem: TuneProblem,
                 cfg: TuneConfig, n_rows: int, n_dev: int,
                 coupling: Optional[DispatchCoupling],
                 telemetry: bool):
    """The explicit ``mode='sharded'`` path: pad rows to equal shard
    widths when needed (warm start carried through — see `_pad_rows`),
    run the psum-reduced loop, trim the padding off every per-row
    output."""
    width = -(-n_rows // n_dev)
    b_pad = width * n_dev
    if b_pad != n_rows:
        raw0, problem, coupling = _pad_rows(raw0, problem, coupling,
                                            n_rows, b_pad)
    fn = _sharded_plan_loop(n_dev, cfg, n_rows, coupling is not None,
                            telemetry)
    if coupling is not None:
        raw_f, hist, cpc = fn(raw0, problem, coupling)
    else:
        raw_f, hist, cpc = fn(raw0, problem)
    raw_f = jax.tree.map(lambda x: x[:n_rows], raw_f)
    return raw_f, {k: _HIST_MERGE.get(k, np.mean)(np.asarray(v), axis=0)
                   for k, v in hist.items()}, cpc[:n_rows]


def _run_loop(raw0: PolicyParams, problem: TuneProblem, cfg: TuneConfig,
              n_rows: int,
              coupling: Optional[DispatchCoupling] = None,
              telemetry: bool = False):
    """Dispatch the hot loop over the `ExecutionPlan` paths.

    Per-row math is identical in all of them (sum-reduction makes each
    row's gradient independent of its batch); chunking is bitwise, the
    sharded paths are ULP-equivalent — and since the psum rework an
    explicit ``mode='sharded'`` plan carries coupled objectives too
    (``mode='auto'`` stays conservative: coupling runs the single
    program unless sharding is asked for). Returns
    ``(raw_f, history, cpc_tuned)`` with history arrays [steps].
    """
    plan = cfg.resolved_plan
    rc = cfg.resolved_coupling
    coupled = coupling is not None or rc.power_cap_mw is not None \
        or rc.min_up_hours is not None
    # re-validated here because callers may hand `optimize` a plan
    # constructed outside TuneConfig's constructor invariant
    validate_plan_coupling(plan, rc, context="TuneConfig")

    chunk = plan.chunk_rows
    if chunk and n_rows > chunk:
        # pad to one compile shape by repeating row 0: padded rows are
        # tuned like any other and dropped afterwards — per-row math is
        # batch-independent, so the real rows are unaffected (the loss
        # *history*, a diagnostic, does average over the padding)
        raws, cpcs, hists = [], [], []
        for sl in row_chunks(n_rows, chunk):
            raw_j = jax.tree.map(lambda x: jnp.asarray(x)[sl], raw0)
            r, h, cp = tune_loop(raw_j, _take_problem(problem, sl),
                                 cfg=cfg, telemetry=telemetry)
            raws.append(r)
            hists.append(h)
            cpcs.append(cp)
        hist = {k: _HIST_MERGE.get(k, np.mean)(
                    [np.asarray(h[k]) for h in hists], axis=0)
                for k in hists[0]}
        return (concat_rows(raws, n_rows), hist,
                concat_rows(cpcs, n_rows))

    if plan.mode == "sharded":
        n_avail = len(jax.devices())
        cap = plan.devices if plan.devices else n_avail
        # >= 2 rows per shard always: width-1 shards scalarize on
        # XLA:CPU (observed 1-ulp drift) and are degenerate parallelism
        n_dev = max(1, min(cap, n_avail, n_rows // 2))
        if n_dev > 1:
            return _run_sharded(raw0, problem, cfg, n_rows, n_dev,
                                coupling, telemetry)
    elif plan.mode == "auto" and not coupled and not chunk:
        # auto-sharding: an explicit chunk_rows is a memory bound the
        # user asked for — it wins over auto-sharding even when the
        # grid is small enough to skip the chunked branch above (the
        # user opted into the bitwise chunk contract; shards are only
        # ULP-equivalent)
        n_avail = len(jax.devices())
        # largest divisor of B that keeps >= 2 rows per shard
        n_dev = next((d for d in range(min(n_avail, n_rows // 2), 0, -1)
                      if n_rows % d == 0), 1)
        if n_dev > 1:
            raw_f, hist, cpc = _sharded_loop(n_dev, cfg,
                                             telemetry)(raw0, problem)
            return raw_f, {k: _HIST_MERGE.get(k, np.mean)(
                               np.asarray(v), axis=0)
                           for k, v in hist.items()}, cpc

    raw_f, hist, cpc = tune_loop(raw0, problem, coupling, cfg=cfg,
                                 telemetry=telemetry)
    return raw_f, {k: np.asarray(v) for k, v in hist.items()}, cpc


def sharded_soft_objective(raw: PolicyParams, problem: TuneProblem, tau,
                           *, n_dev: int,
                           coupling: Optional[DispatchCoupling] = None,
                           **kwargs):
    """The global coupled loss evaluated under `shard_map` over a row
    mesh — the acceptance probe for the psum rework (and what
    `benchmarks/bench_tune_coupled.py` times).

    Each shard evaluates `soft_objective` with ``axis_name='rows'``
    (fleet aggregates psum-reduced, coupled term identical on every
    shard) and the global value is reassembled as
    ``psum(aux['base']) + aux['coupled']`` — the separable part summed
    across shards, the fleet-coupled part counted once. The result is
    ULP-equal to the single program's ``reduction='sum'`` loss, and its
    gradient w.r.t. ``raw`` is *exactly* the single program's (the
    coupled aggregates reduce through `psum_id`, whose backward is the
    identity). ``kwargs`` forward into `soft_objective` (power_cap_mw,
    min_up_hours, dispatch_blend, fused, ...). B must divide evenly
    into ``n_dev`` shards.

    Differentiable in ``raw`` only: a `custom_vjp` takes the gradient
    *inside* each shard's program (the same move `_sharded_plan_loop`
    makes), because reverse mode *through* `shard_map` stages the fused
    kernels' scalar residuals across the mesh, which spec inference
    rejects under ``check_rep=False`` — and ``check_rep=True`` hits the
    known scan replication-type bug. The per-shard adjoint IS the
    global one: each shard's gradient of its *local* loss
    ``base + coupled`` w.r.t. its own rows equals the single program's
    per-row gradient (cross-shard base terms don't touch these rows;
    the coupled term reduces through `psum_id`).
    ``problem``/``coupling``/``tau`` are treated as constants.
    """
    b = raw.raw_off.shape[0]
    if b % n_dev:
        raise ValueError(
            f"sharded_soft_objective: {b} rows do not split evenly over "
            f"{n_dev} shards — pad the batch (see _pad_rows) or pick a "
            "divisor shard count")
    mesh = row_mesh(n_dev)
    rows = P("rows")
    prob_specs = TuneProblem(
        prices=P(), **{f: rows for f in _PROBLEM_ROW_FIELDS})

    def body(raw_s, problem_s, coupling_s=None):
        _, aux = soft_objective(
            raw_s, problem_s, tau, dispatch=coupling_s,
            reduction="sum", axis_name="rows", scale_rows=b, **kwargs)
        # base is shard-local (separable sum), coupled is the full
        # global term on every shard — psum the first, keep the second
        return jax.lax.psum(aux["base"], "rows") + aux["coupled"]

    def grad_body(raw_s, problem_s, coupling_s=None):
        # differentiate the *local* loss (base + coupled), not the
        # psum-reassembled global value: other shards' base terms do
        # not depend on these rows, and the coupled term's cross-shard
        # aggregates go through psum_id, so the per-shard gradient of
        # the local loss IS the single program's per-row gradient
        def local(rs):
            return soft_objective(
                rs, problem_s, tau, dispatch=coupling_s,
                reduction="sum", axis_name="rows", scale_rows=b,
                **kwargs)[0]
        return jax.grad(local)(raw_s)

    if coupling is not None:
        in_specs = (rows, prob_specs, DispatchCoupling(
            cell_id=rows, prices=P(), keys=P(), order=P(), demand=P(),
            fixed=rows, power=rows, migrate_cost=P(), cpc_ref=P()))
        extra = (problem, coupling)
    else:
        in_specs = (rows, prob_specs)
        extra = (problem,)
    val_fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=P(), **SHARD_MAP_NOCHECK)
    grad_fn = shard_map(grad_body, mesh=mesh, in_specs=in_specs,
                        out_specs=rows, **SHARD_MAP_NOCHECK)

    @jax.custom_vjp
    def loss(r):
        return val_fn(r, *extra)

    def loss_fwd(r):
        return val_fn(r, *extra), r

    def loss_bwd(r, ct):
        g = grad_fn(r, *extra)
        return (jax.tree.map(lambda x: x * ct, g),)

    loss.defvjp(loss_fwd, loss_bwd)
    return loss(raw)


def _hard_cpc_batched(p_on, p_off, off_level, problem: TuneProblem,
                      chunk_rows: int) -> np.ndarray:
    """`hard_cpc`, optionally evaluated in row chunks so the in-jit
    [B, T] price gather never exceeds the chunk footprint."""
    b = np.shape(p_on)[0]
    if not chunk_rows or b <= chunk_rows:
        return np.asarray(hard_cpc(p_on, p_off, off_level, problem),
                          np.float64)
    parts = [hard_cpc(jnp.asarray(p_on)[sl], jnp.asarray(p_off)[sl],
                      jnp.asarray(off_level)[sl],
                      _take_problem(problem, sl))
             for sl in row_chunks(b, chunk_rows)]
    return np.asarray(concat_rows(parts, b), np.float64)


def cell_best_rows(grid, cpc: np.ndarray) -> np.ndarray:
    """Index of the lowest-CPC row within each row's (market, system)
    cell, mapped back onto rows (robust to row permutations)."""
    key = cell_index(grid)
    best: dict[int, int] = {}
    for b in range(len(key)):
        c = int(key[b])
        if c not in best or cpc[b] < cpc[best[c]]:
            best[c] = b
    return np.asarray([best[int(c)] for c in key], np.int64)


def _dispatch_reeval(grid, params: PhysicalPolicy, cpc: np.ndarray,
                     best_row: np.ndarray, dcfg: DispatchConfig) -> dict:
    """Score the selected (tuned) and the best-swept policy sets under
    the *feasible* cross-site dispatcher — one site per (market, system)
    cell, hard constraints instead of the soft tuning penalties. A
    policy set that cannot meet the configured demand is not clipped to
    fit: it scores ``cpc = inf`` with the `DispatchInfeasible` reason
    recorded, and the feasible set (if any) is chosen."""
    key = cell_index(grid)
    sel: dict[int, int] = {}
    for b in range(len(key)):
        c = int(key[b])
        if c not in sel or cpc[b] < cpc[sel[c]]:
            sel[c] = b
    rows = np.asarray([sel[c] for c in sorted(sel)], np.int64)
    markets = np.asarray(grid.market_idx)[rows]
    prices = np.asarray(grid.prices)[markets]
    power = np.asarray(grid.power)[rows]
    fixed = np.asarray(grid.fixed)[rows]

    def run(p_on, p_off, lvl, take):
        try:
            return dispatch_solve(build_problem(
                prices, np.asarray(p_on)[take], np.asarray(p_off)[take],
                np.asarray(lvl)[take], power, dcfg, fixed=fixed),
                plan=getattr(dcfg, "plan", None)), None
        except DispatchInfeasible as e:
            return None, str(e)

    tuned, why_t = run(params.p_on, params.p_off, params.off_level, rows)
    sw = best_row[rows]
    swept, why_s = run(grid.p_on, grid.p_off, grid.off_level, sw)
    cpc_t = tuned.cpc if tuned is not None else float("inf")
    cpc_s = swept.cpc if swept is not None else float("inf")
    chosen = None if tuned is None and swept is None else \
        ("tuned" if cpc_t <= cpc_s else "swept")
    names = tuple(f"{grid.market_names[n]}/{grid.system_names[m]}"
                  for n, m in zip(np.asarray(grid.market_idx)[rows],
                                  np.asarray(grid.system_idx)[rows])) \
        if grid.market_names and grid.system_names else ()
    return {"cpc_tuned": cpc_t, "cpc_swept": cpc_s, "chosen": chosen,
            "tuned": tuned, "swept": swept, "rows": rows,
            "site_names": names,
            "infeasible_tuned": why_t, "infeasible_swept": why_s}


def optimize(grid, cfg: TuneConfig = TuneConfig(), *,
             warm_start=None) -> TuneResult:
    """Gradient-tune every scenario row of ``grid``; hard-re-evaluate.

    Each row is seeded at its own swept `PolicySpec` (so the grid's K
    policies double as K random restarts per (market, system) cell) and
    tuned for ``cfg.steps`` Adam steps under the annealed soft
    objective. ``warm_start`` overrides the seed: a `PolicyParams` (raw),
    a `PhysicalPolicy` (mapped through `inverse_transform`), or a prior
    `TuneResult` (its ``.raw``) — the entry point a receding-horizon
    caller (`repro.live`, `examples/live_operator.py`) uses to re-tune
    at each cadence tick from the previous tick's solution with a short
    ``cfg.steps`` budget instead of a cold anneal. The final selection keeps, per row, the best hard-CPC
    policy among the tuned parameters and the swept baselines — when
    hardware parameters (idle draw, restart costs) are uniform within a
    cell, the reported ``cpc`` therefore matches or beats the best swept
    policy on every row. With fleet-coupling penalties configured the
    swept fallback is disabled (swept policies ignore the constraints),
    so ``cpc`` reports the tuned params unconditionally — an explicit
    ``chunk_rows`` raises, since coupled terms see every row at once,
    and auto-mode sharding stays off; an explicit
    ``plan=ExecutionPlan(mode='sharded')`` *does* shard the coupled
    objective, psum-reducing its fleet aggregates across the row mesh.

    With ``cfg.dispatch_soft`` the annealed objective additionally
    differentiates through the relaxed water-fill dispatcher
    (`repro.tune.objective.soft_dispatch_ratio`), the per-row swept
    fallback is disabled for the same reason as above, and the final
    policy set is re-scored on *feasible* `repro.dispatch.dispatch`
    (under ``cfg.dispatch`` if also given, else under the same config)
    against the best-swept set — so the reported fleet CPC under hard
    dispatch is never worse than the swept baseline's.

    With a `repro.workload.Workload` (``cfg.workload``, defaulting to
    ``grid.workload``) the annealed objective adds the soft work-ledger
    term (`soft_objective`'s ``workload`` kwarg) and the final per-row
    selection is judged by *realized workload cost* — energy + SLO
    deferral + VoLL drops on one shared demand sample
    (`repro.workload.realized_cost`) — instead of bare CPC, landing in
    ``TuneResult.workload_cost``; the selected policy never costs more
    than the best swept policy of its cell under the same workload.
    """
    telemetry = obs.enabled()
    problem = problem_from_grid(grid)
    if warm_start is None:
        raw0 = init_from_grid(grid)
    elif isinstance(warm_start, TuneResult):
        raw0 = warm_start.raw
    elif isinstance(warm_start, PhysicalPolicy):
        raw0 = inverse_transform(warm_start)
    elif isinstance(warm_start, PolicyParams):
        raw0 = warm_start
    else:
        raise TypeError("warm_start must be PolicyParams, PhysicalPolicy "
                        f"or TuneResult, got {type(warm_start).__name__}")
    if np.asarray(raw0.raw_off).shape != (grid.n_rows,):
        raise ValueError(
            f"warm_start has {np.asarray(raw0.raw_off).shape} raw_off for "
            f"a {grid.n_rows}-row grid")
    if warm_start is not None:
        # the tuning loop donates its parameter carry; copy so the
        # caller's warm-start source (e.g. the previous tick's
        # TuneResult in a receding-horizon loop) stays alive
        raw0 = PolicyParams(*(jnp.array(a) for a in raw0))
    rc = cfg.resolved_coupling
    chunk = cfg.resolved_plan.chunk_rows
    wl = cfg.workload if cfg.workload is not None \
        else getattr(grid, "workload", None)
    if wl is not None and cfg.workload is None:
        # a grid-carried Workload flows into the loop too (cfg is the
        # jit-static carrier `_make_step` reads)
        cfg = cfg._replace(workload=wl)
    coupling = dispatch_coupling_from_grid(grid, rc.dispatch) \
        if rc.dispatch is not None else None
    raw_f, hist, cpc_tuned_dev = _run_loop(raw0, problem, cfg,
                                           grid.n_rows, coupling,
                                           telemetry)
    stage_cpc = np.asarray(hist.pop("stage_cpc"), np.float64)
    cpc_tuned = np.asarray(cpc_tuned_dev, np.float64)

    # hard re-evaluation of the swept baselines at tau -> 0 (chunked
    # under the same memory bound the tuning run declared)
    swept_plan = ExecutionPlan(mode="chunked", chunk_rows=chunk,
                               contract="bitwise") if chunk \
        else ExecutionPlan(mode="single")
    swept = backtest(grid, use_pallas=False, plan=swept_plan)
    cpc_swept = np.asarray(swept.cpc, np.float64)

    tuned = transform(raw_f)
    wl_demand = None
    wc_tuned = wc_swept = None
    if wl is not None:
        # the hard selection yardstick becomes the *realized* workload
        # cost — energy + SLO deferral + VoLL drops — on one shared
        # demand sample, so the tuned/swept comparison is paired and
        # the selected policy can never cost more than the best swept
        # one under the same workload
        from repro.workload import realized_cost
        wl_demand = wl.sample_demand_mw(grid.n_hours)
        wc_tuned = np.asarray(realized_cost(
            grid, tuned.p_on, tuned.p_off, tuned.off_level, wl,
            demand_mw=wl_demand), np.float64)
        wc_swept = np.asarray(realized_cost(
            grid, grid.p_on, grid.p_off, grid.off_level, wl,
            demand_mw=wl_demand), np.float64)
        best_row = cell_best_rows(grid, wc_swept)
    else:
        best_row = cell_best_rows(grid, cpc_swept)
    cpc_swept_best = cpc_swept[best_row]

    # cell-best swept params evaluated under *this* row's hardware
    cb = PhysicalPolicy(p_on=grid.p_on[best_row], p_off=grid.p_off[best_row],
                        off_level=grid.off_level[best_row])
    cpc_cb = _hard_cpc_batched(cb.p_on, cb.p_off, cb.off_level, problem,
                               chunk)

    cand = np.stack([cpc_tuned, cpc_swept, cpc_cb])        # [3, B]
    # the selection yardstick: realized workload cost when a Workload
    # is configured, bare hard CPC otherwise
    yard = np.stack([wc_tuned, wc_swept, wc_swept[best_row]]) \
        if wl is not None else cand
    if rc.binds:
        # fleet-coupling constraints: the swept baselines ignore them, so
        # falling back to a lower-CPC swept policy would silently violate
        # the constraint the user asked for — keep the tuned params.
        # (Dispatch-aware runs likewise: a per-row swept fallback judged
        # on *isolated* CPC would undo the fleet-role specialisation the
        # dispatch term just taught; the swept set still competes, as a
        # whole fleet, in the hard dispatch re-scoring below.)
        source = np.zeros(cand.shape[1], np.int64)
    else:
        source = np.argmin(yard, axis=0)
    cpc = cand[source, np.arange(cand.shape[1])]
    workload_cost = yard[source, np.arange(yard.shape[1])] \
        if wl is not None else None

    def pick(tuned_v, own_v, cb_v):
        stacked = jnp.stack([jnp.asarray(tuned_v), jnp.asarray(own_v),
                             jnp.asarray(cb_v)])
        return stacked[source, jnp.arange(stacked.shape[1])]

    params = PhysicalPolicy(
        p_on=pick(tuned.p_on, grid.p_on, cb.p_on),
        p_off=pick(tuned.p_off, grid.p_off, cb.p_off),
        off_level=pick(tuned.off_level, grid.off_level, cb.off_level))

    dispatch_out = None
    reeval_cfg = rc.reeval_config
    if reeval_cfg is not None:
        if rc.relief_config is not None and reeval_cfg.relief is None:
            # a Coupling-level relief covers the hard re-scoring too:
            # a storm-degraded policy set sheds at VoLL instead of
            # scoring a bare `inf`
            reeval_cfg = reeval_cfg._replace(relief=rc.relief_config)
        dispatch_out = _dispatch_reeval(grid, params, cpc, best_row,
                                        reeval_cfg)

    result = TuneResult(
        params=params, raw=raw_f, cpc=cpc, cpc_tuned=cpc_tuned,
        cpc_swept=cpc_swept, cpc_swept_best=cpc_swept_best,
        improvement_vs_best=1.0 - cpc / cpc_swept_best,
        improvement_vs_own=1.0 - cpc / cpc_swept,
        source=source, history=hist, stage_cpc=stage_cpc,
        dispatch=dispatch_out,
        guard_count=int(np.sum(hist.get("guard_rejects", 0.0))),
        workload_cost=workload_cost)
    if telemetry:
        _emit_tune_events(cfg, result)
    return result


def _emit_tune_events(cfg: TuneConfig, res: TuneResult) -> None:
    """Stream the finished run's history into the trace: one
    ``tune.step`` per optimization step (loss / tau / penalty, plus
    grad-norm and clip-fraction — present because the loop ran with its
    telemetry side-outputs), one ``tune.stage`` per hard re-eval
    boundary, one ``tune.result``."""
    hist = res.history
    step_keys = [k for k in ("loss", "tau", "penalty", "dispatch_ratio",
                             "grad_norm", "clip_frac") if k in hist]
    for i in range(len(hist["loss"])):
        obs.trace_event("tune.step",
                        {"step": i,
                         **{k: float(hist[k][i]) for k in step_keys}})
        if "grad_norm" in hist:
            obs.histogram("tune.grad_norm").observe(hist["grad_norm"][i])
    bounds = _stage_bounds(cfg)
    for k, v in enumerate(res.stage_cpc):
        obs.trace_event("tune.stage", {"stage": k,
                                       "through_step": bounds[k + 1],
                                       "cpc_hard_mean": float(v)})
    src_names = ("tuned", "own_swept", "cell_best")
    obs.trace_event("tune.result", {
        "rows": int(res.cpc.shape[0]), "steps": cfg.steps,
        "cpc_mean": float(np.mean(res.cpc)),
        "cpc_tuned_mean": float(np.mean(res.cpc_tuned)),
        "cpc_swept_best_mean": float(np.mean(res.cpc_swept_best)),
        "improvement_vs_best_mean": float(np.mean(res.improvement_vs_best)),
        "source_counts": {src_names[s]: int(n) for s, n in
                          zip(*np.unique(res.source, return_counts=True))}})
    if res.guard_count:
        rej = np.asarray(res.history["guard_rejects"])
        obs.trace_event("tune.guard", {
            "rejects_total": int(res.guard_count),
            "steps_affected": int((rej > 0).sum()),
            "first_step": int(np.argmax(rej > 0)),
            "rows": int(res.cpc.shape[0])})
        obs.counter("tune.guard_rejects").inc(int(res.guard_count))
    obs.gauge("tune.cpc_mean").set(float(np.mean(res.cpc)))
    obs.counter("tune.runs").inc()
