"""Differentiable per-row CPC/TCO objectives over a scenario grid.

The fleet engine *evaluates* policies; this module makes them
*parameters*. Each scenario row gets three unconstrained raw variables
(`PolicyParams`) that deterministic transforms map onto the feasible
policy set:

    p_off     = raw_off                           (price units, free)
    p_on      = p_off - softplus(raw_gap)         (p_on <= p_off always)
    off_level = (1 - 1e-6) sigmoid(raw_lvl)       (in [0, 1) always)

so gradient steps in raw space can never produce an inverted hysteresis
band or an infeasible capacity level — the constraint surface of
`repro.fleet.grid.PolicySpec`, enforced by construction instead of by
validation.

`soft_objective` prices every row with the temperature-``tau`` relaxed
scan (`repro.kernels.soft_scan`) and the *same* cost assembly the hard
backtest uses (`repro.fleet.engine.fleet_costs`), returning the mean
dimensionless CPC ratio (CPC/CPC_AO, Eq. 28's measured analogue) plus
optional fleet-coupling penalties:

  * ``power_cap_mw`` — soft cap on total instantaneous fleet draw
    (multi-site dispatch constraint, ROADMAP follow-on);
  * ``min_up_hours`` — minimum aggregate compute delivered by the fleet.

Both penalties are quadratic in the *relative* violation, so their scale
is comparable with the O(1) CPC ratio term; both weight each row by
1 / |its (market, system) cell| so a grid carrying K candidate policies
per site charges the site's mean dispatch once rather than summing K
copies (exact with one row per site).

Dispatch-aware tuning goes further than penalties: with a
`DispatchCoupling` (built by `dispatch_coupling_from_grid` from a
`repro.dispatch.DispatchConfig`), the soft objective blends in the
fleet CPC of the *dispatched* load — the relaxed schedules offer soft
availability, the softmin water-fill (`repro.kernels.soft_dispatch`)
places the demand over it at the same annealed temperature, and the
realized (fixed + energy-at-allocation + migration) cost per delivered
MWh flows gradients back into every site's thresholds. Sites then learn
their *fleet role*: a site whose prices are usually undercut elsewhere
is pushed toward aggressive shutdown (the designated swing site),
which isolated tuning cannot discover.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dispatch import (DispatchConfig, resolve_demand, segment_keys,
                            segment_rank)
from repro.fleet.engine import fleet_costs
from repro.kernels.queue_scan import QUEUE_MWH_SCALE, queue_scan
from repro.kernels.soft_dispatch import soft_dispatch, soft_shed
from repro.parallel.axes import psum_id
from repro.kernels.soft_scan import soft_scan_parts


class PolicyParams(NamedTuple):
    """Unconstrained per-row policy parameters (all [B])."""

    raw_off: jax.Array   # shutdown threshold, price units (identity)
    raw_gap: jax.Array   # softplus -> hysteresis band width p_off - p_on
    raw_lvl: jax.Array   # sigmoid -> off-capacity level


class PhysicalPolicy(NamedTuple):
    """Feasible policy variables (all [B]): p_on <= p_off, lvl in [0, 1)."""

    p_on: jax.Array
    p_off: jax.Array
    off_level: jax.Array


class TuneProblem(NamedTuple):
    """The static (non-tuned) data of a tuning run (from a ScenarioGrid).

    ``prices`` stays [N, T] shared across rows, exactly like
    `ScenarioGrid.prices` — the per-row [B, T] gather happens *inside*
    the jitted objective (as in `fleet.engine._backtest_jit`), so the
    persistent footprint is one year of prices per market, not per row.
    Everything else is [B]. ``idle_frac`` and the restart costs stay
    fixed — they are hardware properties, not policy choices.
    ``site_weight`` is 1 / (number of rows sharing the row's (market,
    system) cell): a grid with K policy columns holds K *candidate* rows
    per physical site, and coupling penalties must charge each site
    once, not K times — weighting by 1/K makes the fleet totals the
    per-site mean over candidates (exact when K = 1).
    """

    prices: jax.Array        # [N, T]
    market_idx: jax.Array    # [B] int32 row -> market
    price_sum: jax.Array     # [B] sum_t p_t of the row's market
    fixed: jax.Array
    power: jax.Array
    period: jax.Array
    idle_frac: jax.Array
    restart_energy_mwh: jax.Array
    restart_time_h: jax.Array
    site_weight: jax.Array

    def row_prices(self) -> jax.Array:
        """[B, T] per-row gather — call inside jit so the duplication is
        a compiler-managed temporary, not a live buffer."""
        return self.prices[self.market_idx]


_FEAS_MARGIN_SCALE = 1.05  # the soft feasibility term defends demand
                           # plus 5%: the annealed capacity slightly
                           # overstates the hard schedules near the
                           # thresholds, and the hard re-evaluation has
                           # no tolerance at all

_SHED_FLOOR_FRAC = 1e-3   # relief: the soft water-fill always
                          # dispatches at least this fraction of the
                          # demand — a ~zero effective demand parks the
                          # bisected water level off the sigmoid tails
                          # and the implicit-function backward divides
                          # by the vanished occupancy slope (NaN)

_SEL_SCALE = 0.01   # per-cell candidate-selection temperature per unit
                    # tau: the dispatched fleet runs ONE policy per
                    # (market, system) site, so candidate rows of a
                    # cell are blended by a softmax over their own soft
                    # CPC ratio at tau * this — uniform-ish while the
                    # scan is smooth, converging to the hard
                    # re-evaluation's per-cell argmin as tau anneals
                    # (ratio differences are O(1e-2), so the end-tau
                    # 0.3 * 0.01 = 3e-3 is decisively sharp). A
                    # single-candidate cell reduces to weight 1 exactly.


class DispatchCoupling(NamedTuple):
    """Static (non-tuned) data of the soft fleet-dispatch term.

    The dispatched fleet deploys one policy per (market, system) cell —
    the physical site. A grid carrying K candidate policies per site is
    aggregated by a *soft selection*: each candidate's availability is
    weighted by a per-cell softmax over the candidates' own soft CPC
    ratios (temperature ``tau * _SEL_SCALE``, co-annealed), which
    converges to the per-cell argmin the hard re-evaluation deploys —
    so the fleet the gradient sees is the fleet that will actually run,
    and the feasibility shortfall guards the *selected* set, not a
    candidate mean an always-on also-ran could prop up. Everything here
    is data, not parameters — gradients reach the rows through the soft
    availability they offer and through the selection itself
    (candidates compete). ``keys``/``order`` are the host-precomputed
    `repro.dispatch.segment_keys` sort reused by the softmin water-fill
    (`repro.kernels.soft_dispatch`); ``cpc_ref`` is a constant
    O(fleet-CPC) normalizer that makes the dispatch term dimensionless
    like the per-row CPC ratios.
    """

    cell_id: jax.Array       # [B] int32 row -> covered cell (site)
    prices: jax.Array        # [C, T] site prices
    keys: jax.Array          # [T, 3C] segment keys (f64 on the host)
    order: jax.Array         # [T, 3C] int32 ascending key sort
    demand: jax.Array        # [T] fleet demand profile (MW)
    fixed: jax.Array         # [B] per-row fixed cost (selection-blended)
    power: jax.Array         # [B] per-row site rating (MW)
    migrate_cost: jax.Array  # [] EUR per MW moved
    cpc_ref: jax.Array       # [] constant fleet-CPC normalizer


def dispatch_coupling_from_grid(grid, dcfg: DispatchConfig
                                ) -> DispatchCoupling:
    """Build the soft-dispatch coupling data for a `ScenarioGrid` under
    a `repro.dispatch.DispatchConfig` (same demand semantics as
    `build_problem`: scalar, [T] profile, or ``demand_frac`` of the
    summed per-site ratings)."""
    _, inverse, counts = np.unique(cell_index(grid), return_inverse=True,
                                   return_counts=True)
    c = len(counts)
    t = grid.n_hours
    cell_market = np.zeros(c, np.int64)
    cell_market[inverse] = np.asarray(grid.market_idx, np.int64)
    prices_c = np.asarray(grid.prices, np.float64)[cell_market]  # [C, T]

    # per-site rating for the demand_frac default: candidate rows of a
    # cell share the site, so average their (normally equal) ratings
    w = 1.0 / counts[inverse]                                    # [B]
    power_c = np.zeros(c)
    np.add.at(power_c, inverse, w * np.asarray(grid.power, np.float64))

    demand = np.asarray(resolve_demand(dcfg, power_c, t), np.float64)
    keys = segment_keys(prices_c, float(dcfg.migrate_cost))
    order, _ = segment_rank(prices_c, float(dcfg.migrate_cost),
                            keys=keys)
    fixed_c = np.zeros(c)
    np.add.at(fixed_c, inverse, w * np.asarray(grid.fixed, np.float64))
    cpc_ref = (fixed_c.sum()
               + float((demand * prices_c.mean(axis=0)).sum())) \
        / max(float(demand.sum()), 1e-9)
    return DispatchCoupling(
        cell_id=jnp.asarray(inverse, jnp.int32),
        prices=jnp.asarray(prices_c), keys=jnp.asarray(keys),
        order=jnp.asarray(order, jnp.int32), demand=jnp.asarray(demand),
        fixed=jnp.asarray(np.asarray(grid.fixed, np.float64)),
        power=jnp.asarray(np.asarray(grid.power, np.float64)),
        migrate_cost=jnp.asarray(float(dcfg.migrate_cost)),
        cpc_ref=jnp.asarray(cpc_ref))


_LVL_SCALE = 1.0 - 1e-6   # keeps off_level < 1 even when the f32
                          # sigmoid saturates to exactly 1.0


def transform(raw: PolicyParams) -> PhysicalPolicy:
    """Raw -> feasible policy variables (smooth, surjective onto the
    interior of the feasible set)."""
    p_off = raw.raw_off
    p_on = p_off - jax.nn.softplus(raw.raw_gap)
    return PhysicalPolicy(p_on=p_on, p_off=p_off,
                          off_level=_LVL_SCALE
                          * jax.nn.sigmoid(raw.raw_lvl))


def inverse_transform(phys: PhysicalPolicy, *, gap_min: float = 1e-3,
                      lvl_eps: float = 1e-4) -> PolicyParams:
    """Feasible -> raw, the right inverse of `transform` (used to seed
    tuning at a swept `PolicySpec`). Degenerate values are nudged inside
    the open feasible set: a zero hysteresis gap to ``gap_min``, an
    off_level of exactly 0 (or 1) to ``lvl_eps`` from the boundary."""
    p_off = np.asarray(phys.p_off, np.float64)
    gap = np.maximum(p_off - np.asarray(phys.p_on, np.float64), gap_min)
    # stable softplus^-1: log(e^y - 1) = y + log1p(-e^-y)
    raw_gap = np.where(gap > 20.0, gap, np.log(np.expm1(gap)))
    raw_gap = raw_gap + np.where(gap > 20.0, np.log1p(-np.exp(-gap)), 0.0)
    lvl = np.clip(np.asarray(phys.off_level, np.float64),
                  lvl_eps, 1.0 - lvl_eps)
    return PolicyParams(raw_off=jnp.asarray(p_off, jnp.float32),
                        raw_gap=jnp.asarray(raw_gap, jnp.float32),
                        raw_lvl=jnp.asarray(np.log(lvl / (1.0 - lvl)),
                                            jnp.float32))


def cell_index(grid) -> np.ndarray:
    """[B] int64 key of each row's (market, system) cell — the physical
    site a row's candidate policy would run at. Single source of the
    cell definition for site weighting and best-swept lookups."""
    mi = np.asarray(grid.market_idx, np.int64)
    si = np.asarray(grid.system_idx, np.int64)
    return mi * max(grid.n_systems, 1) + si


def problem_from_grid(grid) -> TuneProblem:
    """Extract the static tuning data from a `ScenarioGrid`."""
    _, inverse, counts = np.unique(cell_index(grid), return_inverse=True,
                                   return_counts=True)
    return TuneProblem(
        prices=grid.prices, market_idx=grid.market_idx,
        price_sum=jnp.sum(grid.prices, axis=1)[grid.market_idx],
        fixed=grid.fixed, power=grid.power, period=grid.period,
        idle_frac=grid.idle_frac,
        restart_energy_mwh=grid.restart_energy_mwh,
        restart_time_h=grid.restart_time_h,
        site_weight=jnp.asarray(1.0 / counts[inverse], jnp.float32))


def init_from_grid(grid) -> PolicyParams:
    """Seed raw parameters at the grid's own swept policies.

    Always-on rows (p_off = +inf) are seeded at their market's maximum
    price — operationally identical (no sample exceeds it, so the row
    never shuts down) but finite, so gradients can pull the threshold
    into the price range if shutdowns pay.
    """
    p_off = np.asarray(grid.p_off, np.float64)
    p_on = np.asarray(grid.p_on, np.float64)
    p_max = np.asarray(jnp.max(grid.prices, axis=1),
                       np.float64)[np.asarray(grid.market_idx)]
    inf = ~np.isfinite(p_off)
    p_off = np.where(inf, p_max, p_off)
    p_on = np.where(inf, p_max, p_on)
    return inverse_transform(PhysicalPolicy(
        p_on=p_on, p_off=p_off, off_level=np.asarray(grid.off_level)))


def soft_costs(raw: PolicyParams, problem: TuneProblem, tau, *,
               fused: bool = True, block_t: int = 256):
    """(FleetCosts, per-sample draw [B, T], capacity [B, T]) of the
    relaxed scan at ``tau`` — the engine's cost assembly over the soft
    sufficient statistics. ``fused`` selects the checkpointed
    custom-VJP soft-state evaluation (`repro.kernels.soft_scan_vjp`)
    instead of native autodiff through the associative scan — same
    gradients to tight tolerance, a fraction of the backward cost and
    residual memory."""
    phys = transform(raw)
    p = problem.row_prices()                      # [B, T] gather, in-jit
    scan, draw, cap = soft_scan_parts(p, phys.p_on, phys.p_off,
                                      phys.off_level, problem.idle_frac,
                                      tau=tau, fused=fused,
                                      block_t=block_t)
    costs = fleet_costs(
        scan, price_sum=problem.price_sum, fixed=problem.fixed,
        power=problem.power, period=problem.period,
        restart_energy_mwh=problem.restart_energy_mwh,
        restart_time_h=problem.restart_time_h, n_samples=p.shape[1])
    return costs, draw, cap


def soft_dispatch_ratio(cap: jax.Array, row_ratio: jax.Array,
                        coupling: DispatchCoupling, tau, *,
                        min_dwell: int = 0, mw_scale: float = 0.05,
                        fused: bool = False,
                        axis_name: Optional[str] = None,
                        relief=None) -> tuple[jax.Array, jax.Array]:
    """Fleet-level dispatched-CPC ratio of the relaxed schedules.

    ``cap`` is the [B, T] soft capacity trajectory and ``row_ratio``
    the per-row soft CPC ratio (both from `soft_costs`). Candidate
    rows are blended onto their sites by the per-cell soft selection
    (softmax over ``-row_ratio`` at temperature ``tau * _SEL_SCALE`` —
    see `DispatchCoupling`), the softmin water-fill
    (`repro.kernels.soft_dispatch`) places the demand profile over the
    resulting soft availability at the *same* temperature as the scan
    relaxation — co-annealed end to end — and the realized fleet cost
    (selected fixed + energy at the allocation + matched migration
    flow, the accounting of `repro.dispatch.summarize_alloc`) is
    normalised by ``coupling.cpc_ref`` to a dimensionless O(1) ratio.
    Returns ``(ratio, shortfall)`` where ``shortfall`` is the *sum*
    over hours of the squared relative availability deficit of the
    selected fleet against a 5%-margined demand — the smooth
    feasibility term that keeps gradient steps from shutting the fleet
    below the demand it must serve (the hard re-evaluation raises
    `DispatchInfeasible` there, so even one deficient hour must carry a
    loss-scale cost: a sum does, a per-hour mean would dilute it by T,
    and the margin covers the soft capacity slightly overstating the
    hard schedules near thresholds).

    ``relief`` (a duck-typed `repro.dispatch.Relief`) switches
    infeasibility handling from penalty to *pricing*: the smoothed
    shortfall (`repro.kernels.soft_dispatch.soft_shed`, co-annealed at
    the same MW temperature) is shed from the demand the water-fill
    places, its cost enters the fleet numerator at the value-of-lost-
    load price, and the squared-shortfall penalty is zeroed — gradients
    then weigh serving an expensive hour against shedding it, exactly
    the trade the hard dispatcher under `Relief` settles. ``None``
    traces the exact pre-relief program.

    With ``axis_name`` (inside a `shard_map` over a row mesh) each
    program holds only its shard of rows: the per-cell selection and
    the [C, T] availability / fixed-cost aggregates are reduced across
    shards with `repro.parallel.axes.psum_id` / `jax.lax.pmax` before
    the water-fill, so every shard dispatches the *whole* fleet — the
    coupled term is identical (to ULP) on all shards, and because
    `psum_id`'s backward is the identity (the aggregate's cotangent is
    already replicated), its per-row gradients match the single
    program exactly. Cells are widened by one dummy segment
    so padded rows (``cell_id == C``, zero power/fixed/weight) drop
    out of the fleet instead of polluting cell 0.
    """
    dtype = cap.dtype
    c = coupling.prices.shape[0]

    # per-cell soft selection over candidates (stabilised softmax)
    score = -row_ratio / jnp.maximum(tau * _SEL_SCALE, 1e-12)
    if axis_name is None:
        peak = jax.ops.segment_max(score, coupling.cell_id,
                                   num_segments=c)
        expw = jnp.exp(score - peak[coupling.cell_id])
        norm = jax.ops.segment_sum(expw, coupling.cell_id,
                                   num_segments=c)
        sel = expw / norm[coupling.cell_id]                     # [B]

        avail = (sel * coupling.power.astype(dtype))[:, None] * cap
        avail_c = jax.ops.segment_sum(avail, coupling.cell_id,
                                      num_segments=c)           # [C, T]
        fixed_fleet = jnp.sum(sel * coupling.fixed.astype(dtype))
    else:
        # local partials -> cross-shard reductions. The softmax shift
        # is the global per-cell max (stop-gradded: shift invariance
        # makes its gradient exactly zero); a cell with no local rows
        # maxes to -inf, and the dummy pad segment stays -inf on every
        # shard — clamp so exp(score - peak) cannot overflow there.
        cseg = c + 1
        # stop-grad BEFORE the pmax: shift invariance makes the peak's
        # gradient exactly zero anyway, and pmax has no JVP rule
        peak = jax.lax.pmax(
            jax.lax.stop_gradient(
                jax.ops.segment_max(score, coupling.cell_id,
                                    num_segments=cseg)), axis_name)
        peak = jnp.where(jnp.isfinite(peak), peak, 0.0)
        expw = jnp.exp(score - peak[coupling.cell_id])
        # norm reduces with a RAW psum: its cotangent is per-shard
        # (each shard's own rows' softmax cotangents), and the psum
        # backward — psum of those partials — is exactly the
        # cross-shard sum a straddled cell needs
        norm = jax.lax.psum(
            jax.ops.segment_sum(expw, coupling.cell_id,
                                num_segments=cseg), axis_name)
        sel = expw / norm[coupling.cell_id]                     # [B]

        # avail_c / fixed_fleet reduce with psum_id: they feed only
        # replicated expressions (the water-fill and the fleet CPC),
        # so their cotangent is already replicated and a raw psum's
        # backward would over-count it x n_sh — see parallel.axes
        avail = (sel * coupling.power.astype(dtype))[:, None] * cap
        avail_c = psum_id(
            jax.ops.segment_sum(avail, coupling.cell_id,
                                num_segments=cseg), axis_name)[:c]
        fixed_fleet = psum_id(
            jnp.sum(sel * coupling.fixed.astype(dtype)), axis_name)
    demand = coupling.demand.astype(dtype)
    if relief is None:
        d_eff = demand
    else:
        # shed >= the exact shortfall, so the dispatched d_eff never
        # exceeds total availability — the water-fill stays in its
        # feasible regime even through a storm-derated fleet. The
        # smoothing can push shed past a small demand at high tau
        # (w ~ tau * mw_scale in MW) and the water level falls off the
        # sigmoid tails at ~zero demand (1/occupancy' backward -> NaN)
        # — floor the *dispatched* demand only: the VoLL charge keeps
        # the unclamped shed so availability still feels gradient
        # pressure at fully-shed hours, and the floor is inactive as
        # tau -> 0 on any hour with availability (exact shed <= demand)
        shed = soft_shed(jnp.sum(avail_c, axis=0), demand, tau,
                         mw_scale=mw_scale)                     # [T]
        d_eff = jnp.maximum(demand - shed, _SHED_FLOOR_FRAC * demand)
    alloc = soft_dispatch(avail_c, coupling.keys.astype(dtype),
                          coupling.order, d_eff, tau=tau,
                          min_dwell=min_dwell, mw_scale=mw_scale,
                          use_pallas=False, fused=fused)        # [C, T]

    energy = jnp.sum(alloc * coupling.prices.astype(dtype))
    prev = jnp.concatenate([jnp.zeros_like(alloc[:, :1]),
                            alloc[:, :-1]], axis=1)
    delta = alloc - prev
    inflow = jnp.sum(jax.nn.relu(delta), axis=0)                # [T]
    outflow = jnp.sum(jax.nn.relu(-delta), axis=0)
    # matched cross-site flow min(in, out): demand ramps are not moves
    moved = 0.5 * (inflow + outflow - jnp.abs(inflow - outflow))
    migration = coupling.migrate_cost.astype(dtype) * jnp.sum(moved)
    delivered = jnp.maximum(jnp.sum(alloc), 1e-9)
    if relief is None:
        cpc_fleet = (fixed_fleet + energy + migration) / delivered
        ratio = cpc_fleet / coupling.cpc_ref.astype(dtype)
        short = jax.nn.relu(_FEAS_MARGIN_SCALE * demand
                            - jnp.sum(avail_c, axis=0)) \
            / jnp.maximum(demand, 1e-9)
        return ratio, jnp.sum(short ** 2)
    # relief: the VoLL charge replaces the squared-shortfall penalty —
    # shed is priced, not forbidden, matching the hard dispatcher
    shed_cost = dtype.type(float(relief.voll_eur_mwh)) * jnp.sum(shed)
    cpc_fleet = (fixed_fleet + energy + migration + shed_cost) \
        / delivered
    ratio = cpc_fleet / coupling.cpc_ref.astype(dtype)
    return ratio, jnp.zeros((), dtype)


def soft_objective(raw: PolicyParams, problem: TuneProblem, tau, *,
                   power_cap_mw: Optional[float] = None,
                   min_up_hours: Optional[float] = None,
                   penalty_weight: float = 10.0,
                   dispatch: Optional[DispatchCoupling] = None,
                   dispatch_blend: float = 0.5,
                   dispatch_min_dwell: int = 0,
                   dispatch_mw_scale: float = 0.05,
                   dispatch_fused: bool = False,
                   relief=None,
                   workload=None, workload_demand=None,
                   fused: bool = True, block_t: int = 256,
                   reduction: str = "mean",
                   axis_name: Optional[str] = None,
                   scale_rows: Optional[int] = None):
    """Scalar tuning loss at temperature ``tau`` (lower is better).

    loss = mean_b CPC_b / CPC_AO_b  (+ fleet-coupling penalties)

    The CPC ratio is dimensionless (Eq. 28), so rows with very different
    absolute costs contribute comparably and one learning rate serves
    the whole grid. Returns ``(loss, aux)`` with per-row diagnostics.

    With ``workload`` (a `repro.workload.Workload`) and
    ``workload_demand`` (its [T] mean demand profile, MW), each row
    additionally pays a soft work-ledger bill — SLO-rate-priced backlog
    plus VoLL-priced drops from `repro.kernels.queue_scan.queue_scan`
    at the co-annealed ledger temperature — normalized by its always-on
    bill so tuning learns SLO-aware shutdown thresholds. The term is
    per-row separable (each row serves the mean profile independently),
    so every chunk/shard trajectory contract is preserved;
    ``aux["workload"]`` carries the per-row term (zeros when off).

    With ``dispatch`` (a `DispatchCoupling`), the isolated-site term is
    *blended* with the fleet-level dispatched-CPC ratio of the relaxed
    schedules (`soft_dispatch_ratio`, co-annealed at the same ``tau``):

        loss = (1 - blend) mean_b ratio_b + blend ratio_fleet + ...

    plus an availability-shortfall penalty under ``penalty_weight``, so
    gradients cannot park the fleet below the demand it must serve
    (``relief`` — a duck-typed `repro.dispatch.Relief` — replaces that
    penalty with VoLL-priced soft shed, see `soft_dispatch_ratio`). The
    dispatch term couples every row through the shared water level —
    this objective is then *not* batch-separable: the chunked tuner
    path refuses it, and the sharded path reduces the fleet aggregates
    with in-loop psums (``axis_name``) instead.

    ``reduction="sum"`` (the tuner hot loop's setting) sums the per-row
    ratios instead of averaging and scales the coupling penalties (and
    the dispatch term) by B to compensate: without coupling terms,
    every per-row gradient is then *independent of which other rows
    share the batch* (Adam normalizes the common factor away), which is
    what lets the sharded / chunked `optimize` paths reproduce the
    single-program trajectory bit for bit.

    With ``axis_name`` (tracing inside a `shard_map` over a row mesh)
    the fleet aggregates — total instantaneous draw, aggregate
    up-hours, and everything inside `soft_dispatch_ratio` — are
    reduced across shards with `repro.parallel.axes.psum_id` before
    the penalties are formed, so each shard's loss carries the coupled
    terms of the *whole* fleet (identical on every shard to ULP); the
    separable ratio sum stays shard-local. `psum_id`'s backward is the
    identity (a raw psum would re-sum the replicated cotangent, an
    n-shard over-count), so the per-row gradients of this per-shard
    loss equal the single program's exactly — sharding a coupled
    objective is a legal `ExecutionPlan`, not a refused one.
    ``scale_rows`` then fixes the coupled terms' B-scale at the real
    global row count (shard widths and padding must not change the
    objective). ``aux["base"]`` / ``aux["coupled"]`` split the loss
    into its separable and fleet-coupled parts (psum the first, keep
    the second, to reassemble the global loss value on any shard).
    """
    costs, draw, cap = soft_costs(raw, problem, tau, fused=fused,
                                  block_t=block_t)
    ratio = costs.cpc / costs.cpc_ao
    loss = jnp.sum(ratio) if reduction == "sum" else jnp.mean(ratio)
    wl_ratio = jnp.zeros_like(ratio)
    if workload is not None and workload_demand is not None:
        # SLO-aware term (`workload` is a duck-typed
        # `repro.workload.Workload`, ``workload_demand`` its [T] mean
        # demand profile in MW): run the profile through the soft work
        # ledger against each row's relaxed capacity and price the
        # resulting backlog and drops. The ledger temperature co-anneals
        # with tau (`QUEUE_MWH_SCALE` MWh of smoothing per price unit),
        # so at the end of the schedule the term converges to the hard
        # ledger's deferral/drop bill. Normalizing by the row's
        # always-on bill (cpc_ao * period = F + E_AO) keeps it
        # dimensionless like ``ratio`` — and per-row separable, so the
        # chunked / sharded trajectory contracts are untouched.
        dtp = ratio.dtype
        dt = problem.period.astype(dtp) / cap.shape[1]              # [B]
        cap_mwh = (problem.power.astype(dtp) * dt)[:, None] * cap
        dem = dt[:, None] * jnp.asarray(workload_demand, dtp)[None, :]
        qs = queue_scan(dem, cap_mwh,
                        deadline=int(workload.deadline_h),
                        bound=float(workload.queue_bound_mwh),
                        tau=tau * QUEUE_MWH_SCALE)
        wl_cost = (dtp.type(float(workload.slo_penalty_eur_mwh))
                   * qs.backlog
                   + dtp.type(float(workload.relief.voll_eur_mwh))
                   * qs.dropped)                                    # [B]
        wl_ratio = wl_cost / (costs.cpc_ao * problem.period.astype(dtp))
        loss = loss + (jnp.sum(wl_ratio) if reduction == "sum"
                       else jnp.mean(wl_ratio))
    if scale_rows is not None:
        scale = scale_rows if reduction == "sum" else 1.0
    else:
        scale = ratio.shape[0] if reduction == "sum" else 1.0

    # coupling terms weight each row by 1/|cell| so a K-policy grid
    # charges each physical site once (per-site candidate mean), not K
    # times — see TuneProblem.site_weight
    penalty = jnp.zeros((), ratio.dtype)
    w = problem.site_weight.astype(ratio.dtype)
    if power_cap_mw is not None:
        fleet_mw = jnp.sum((problem.power * w)[:, None] * draw,
                           axis=0)                                  # [T]
        if axis_name is not None:
            fleet_mw = psum_id(fleet_mw, axis_name)
        excess = jax.nn.relu(fleet_mw - power_cap_mw) / power_cap_mw
        penalty = penalty + jnp.mean(excess ** 2)
    if min_up_hours is not None:
        total_up = jnp.sum(w * costs.up_hours)
        if axis_name is not None:
            total_up = psum_id(total_up, axis_name)
        deficit = jax.nn.relu(min_up_hours - total_up) / min_up_hours
        penalty = penalty + deficit ** 2

    dratio = jnp.zeros((), ratio.dtype)
    if dispatch is not None:
        dratio, shortfall = soft_dispatch_ratio(
            cap, ratio, dispatch, tau, min_dwell=dispatch_min_dwell,
            mw_scale=dispatch_mw_scale, fused=dispatch_fused,
            axis_name=axis_name, relief=relief)
        base = (1.0 - dispatch_blend) * loss
        loss = (1.0 - dispatch_blend) * loss \
            + dispatch_blend * scale * dratio
        penalty = penalty + shortfall
    else:
        base = loss
    coupled = dispatch_blend * scale * dratio if dispatch is not None \
        else jnp.zeros((), ratio.dtype)
    coupled = coupled + scale * penalty_weight * penalty
    loss = loss + scale * penalty_weight * penalty

    aux = {"ratio": ratio, "cpc": costs.cpc, "up_hours": costs.up_hours,
           "penalty": penalty, "dispatch_ratio": dratio,
           "base": base, "coupled": coupled, "workload": wl_ratio}
    return loss, aux
