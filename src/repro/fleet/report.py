"""Fleet aggregation: per-row results -> fleet-level decisions.

`FleetReport` is the raw per-row output of the engine. `summarize` folds
it back onto the (market, system, policy) cube — keyed by the report's own
index columns, so it is invariant to any row permutation — and answers the
operator questions: which policy wins at each site, how far each policy is
from the closed-form oracle (`repro.core.optimizer.optimal_shutdown`'s
reduction, Eqs. 21-29), and what the whole fleet dispatches in total.

With a `repro.dispatch.DispatchConfig`, `summarize` additionally runs the
*feasible* cross-site dispatcher over the fleet — one site per covered
(market, system) cell, operating its best swept policy's schedule — and
reports the realized fleet CPC, migration count/cost and constraint
slack as `FleetSummary.dispatch` (hard constraints at report time, not
penalty proxies).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.price_model import price_variability
from repro.core.tco import cpc_reduction
from repro.dispatch import (DispatchConfig, DispatchResult, build_problem,
                            dispatch)


class FleetReport(NamedTuple):
    """Per-scenario-row backtest results (all arrays of shape [B])."""

    cpc: jnp.ndarray            # realized cost-per-compute under the policy
    cpc_ao: jnp.ndarray         # always-on baseline CPC (Eq. 11)
    cpc_reduction: jnp.ndarray  # 1 - cpc / cpc_ao
    tco: jnp.ndarray            # F + energy + restart cost over the period
    energy_cost: jnp.ndarray    # running + idle draw energy cost
    restart_cost: jnp.ndarray   # energy cost of restarts
    up_hours: jnp.ndarray       # operational hours (restart time deducted)
    n_starts: jnp.ndarray       # off->on transitions
    x_realized: jnp.ndarray     # realized average shutdown fraction
    market_idx: jnp.ndarray    # [B] int32
    system_idx: jnp.ndarray    # [B] int32
    policy_idx: jnp.ndarray    # [B] int32


class FleetSummary(NamedTuple):
    """Fleet-level aggregates on the (N markets, M systems, K policies)
    cube. Cube cells never covered by a report row are NaN."""

    reduction: np.ndarray          # [N, M, K] CPC reduction per cell
    best_policy: np.ndarray        # [N, M] int argmax_k reduction
    best_reduction: np.ndarray     # [N, M]
    oracle_reduction: np.ndarray   # [N, M] closed-form optimum (Eqs. 21-29)
    regret: np.ndarray             # [N, M, K] oracle - realized
    energy_by_policy: np.ndarray   # [K] energy+restart cost across sites
    up_hours_by_policy: np.ndarray # [K] compute-hours across sites
    total_cost: float              # sum of TCO over the fleet
    total_up_hours: float
    # feasible cross-site dispatch over the best-policy sites (None
    # unless summarize() was given a DispatchConfig); dispatch_rows are
    # the report-row indices the dispatcher operated (cube-ordered, one
    # per covered (market, system) cell — indices follow the report's
    # row order, the dispatch stats themselves are order-invariant)
    dispatch: Optional[DispatchResult] = None
    dispatch_rows: Optional[np.ndarray] = None
    # workload-coupled ledger economics (a
    # `repro.workload.WorkloadResult`: CPC p10/p50/p90 over the demand
    # draws, served/deferred/dropped totals) — None unless the grid
    # carries a Workload spec or summarize() was given one
    workload: Optional[object] = None


def oracle_reduction_grid(prices: jnp.ndarray,
                          psi_nm: jnp.ndarray) -> jnp.ndarray:
    """Best theoretical CPC reduction per (market, system): the Eq. (26)
    maximum over each market's full PV set — `optimal_shutdown`'s
    ``cpc_reduction``, vectorized over the whole [N, M] grid."""

    def per_market(p, psi_m):
        pv = price_variability(p)

        def per_psi(s):
            return jnp.maximum(jnp.max(cpc_reduction(s, pv.k, pv.x)), 0.0)

        return jax.vmap(per_psi)(psi_m)

    return jax.vmap(per_market)(jnp.asarray(prices), jnp.asarray(psi_nm))


def dispatch_sites(grid, report: FleetReport,
                   best_policy: np.ndarray) -> np.ndarray:
    """Report-row index of each covered (market, system) cell's best
    policy, in canonical cube order — the site set the fleet dispatcher
    operates. Cube-ordered, so it is invariant to row permutations."""
    mi = np.asarray(report.market_idx)
    si = np.asarray(report.system_idx)
    pi = np.asarray(report.policy_idx)
    rows = []
    for n in range(grid.n_markets):
        for m in range(grid.n_systems):
            if best_policy[n, m] < 0:
                continue
            rows.append(int(np.flatnonzero(
                (mi == n) & (si == m) & (pi == best_policy[n, m]))[0]))
    return np.asarray(rows, np.int64)


def summarize(grid, report: FleetReport, *,
              dispatch_cfg: Optional[DispatchConfig] = None,
              workload=None) -> FleetSummary:
    """Aggregate a `FleetReport` over the scenario cube of ``grid``
    (a `repro.fleet.grid.ScenarioGrid`). Row order never matters: cells
    are addressed by the report's index columns.

    With ``dispatch_cfg``, the feasible cross-site dispatcher runs over
    one site per covered (market, system) cell — each operating its best
    swept policy — and the result lands in `FleetSummary.dispatch`
    with the operated rows in `FleetSummary.dispatch_rows` (raises
    `repro.dispatch.DispatchInfeasible` when the configured demand —
    scalar or a [T] profile such as `repro.dispatch.diurnal_demand` —
    cannot be met; hard constraints are never clipped).

    ``workload`` (a `repro.workload.Workload`, defaulting to
    ``grid.workload``) re-runs the rows through the workload-coupled
    backtest and lands the ledger economics — CPC p10/p50/p90 over the
    demand draws, served/deferred/dropped — in `FleetSummary.workload`;
    None (and no grid spec) leaves the summary exactly as before."""
    n, m, k = grid.n_markets, grid.n_systems, grid.n_policies
    mi = np.asarray(report.market_idx)
    si = np.asarray(report.system_idx)
    pi = np.asarray(report.policy_idx)

    def cube(values):
        # non-finite rows (a fully-outaged site delivers zero compute,
        # so its CPC is inf/NaN) enter the cube as NaN — degraded rows
        # drop out of the nan-aggregates instead of poisoning the
        # fleet totals; a no-op for healthy reports
        c = np.full((n, m, k), np.nan, np.float64)
        v = np.asarray(values, np.float64)
        c[mi, si, pi] = np.where(np.isfinite(v), v, np.nan)
        return c

    red = cube(report.cpc_reduction)
    cost = cube(report.energy_cost) + cube(report.restart_cost)
    hours = cube(report.up_hours)

    # (market, system) cells with no rows at all stay NaN / -1 instead of
    # tripping nanargmax's all-NaN error
    covered = ~np.all(np.isnan(red), axis=-1)
    best_policy = np.full((n, m), -1, np.int64)
    best_reduction = np.full((n, m), np.nan)
    if covered.any():
        best_policy[covered] = np.nanargmax(red[covered], axis=-1)
        best_reduction[covered] = np.nanmax(red[covered], axis=-1)

    # Psi per (market, system) from the per-row cost structure (Eq. 18)
    p_avg = np.asarray(grid.prices).mean(axis=1)
    psi_rows = (np.asarray(grid.fixed)
                / (np.asarray(grid.period) * np.asarray(grid.power)
                   * p_avg[np.asarray(grid.market_idx)]))
    psi_nm = np.full((n, m), np.nan)
    psi_nm[np.asarray(grid.market_idx), np.asarray(grid.system_idx)] = \
        psi_rows
    oracle = np.asarray(oracle_reduction_grid(grid.prices,
                                              jnp.asarray(psi_nm)))

    disp = None
    rows = None
    if dispatch_cfg is not None:
        rows = dispatch_sites(grid, report, best_policy)
        markets = np.asarray(grid.market_idx)[rows]
        systems = np.asarray(grid.system_idx)[rows]
        names = tuple(f"{grid.market_names[n]}/{grid.system_names[m]}"
                      for n, m in zip(markets, systems)) \
            if grid.market_names and grid.system_names else ()
        disp = dispatch(build_problem(
            np.asarray(grid.prices)[markets],
            np.asarray(grid.p_on)[rows], np.asarray(grid.p_off)[rows],
            np.asarray(grid.off_level)[rows], np.asarray(grid.power)[rows],
            dispatch_cfg, fixed=np.asarray(grid.fixed)[rows],
            site_names=names))

    wl = workload if workload is not None \
        else getattr(grid, "workload", None)
    wl_result = None
    if wl is not None:
        # lazy import: repro.workload imports the fleet engine
        from repro.workload import workload_backtest
        wl_result = workload_backtest(grid, wl).workload

    summary = FleetSummary(
        reduction=red,
        best_policy=best_policy,
        best_reduction=best_reduction,
        oracle_reduction=oracle,
        regret=oracle[:, :, None] - red,
        energy_by_policy=np.nansum(cost, axis=(0, 1)),
        up_hours_by_policy=np.nansum(hours, axis=(0, 1)),
        total_cost=float(np.nansum(cube(report.tco))),
        total_up_hours=float(np.nansum(hours)),
        dispatch=disp,
        dispatch_rows=rows,
        workload=wl_result,
    )
    if obs.enabled():
        obs.trace_event("fleet.summary", {
            "total_cost": summary.total_cost,
            "total_up_hours": summary.total_up_hours,
            "best_reduction": np.where(np.isfinite(best_reduction),
                                       best_reduction, None).tolist(),
            "top_regret": _top_regret(grid, summary, k=10)})
        obs.gauge("fleet.total_cost").set(summary.total_cost)
    return summary


def _top_regret(grid, summary: FleetSummary, k: int) -> list:
    """Worst-regret covered cube cells, largest first — the "where is
    this fleet leaving money on the table" rows of the operator digest
    (``fleet.summary`` event / `repro.obs.report`)."""
    regret = summary.regret
    flat = regret.ravel()
    idx = np.flatnonzero(np.isfinite(flat))
    idx = idx[np.argsort(-flat[idx], kind="stable")][:k]
    rows = []
    for i in idx:
        n, m, p = np.unravel_index(i, regret.shape)
        rows.append({
            "market": (grid.market_names[n] if grid.market_names
                       else int(n)),
            "system": (grid.system_names[m] if grid.system_names
                       else int(m)),
            "policy": (grid.policy_names[p] if grid.policy_names
                       else int(p)),
            "regret": float(regret[n, m, p]),
            "reduction": float(summary.reduction[n, m, p])})
    return rows
