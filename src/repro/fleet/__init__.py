"""Fleet-scale vectorized backtesting: thousands of (market x system x
policy) scenario simulations in one jitted pass.

  grid    — ScenarioGrid builder: N markets x M systems x K policies
            stacked into B = N*M*K scenario rows
  engine  — single-jit `backtest(grid) -> FleetReport` (vmap over rows,
            fused scan over hours; Pallas fleet_scan on TPU)
  report  — per-row CPC/TCO plus fleet summaries: best policy per market,
            regret vs the closed-form oracle, cross-site dispatch totals
"""

from repro.fleet.engine import backtest
from repro.fleet.grid import (PolicySpec, ScenarioGrid, build_grid,
                              elastic_policy)
from repro.fleet.report import FleetReport, FleetSummary, summarize

__all__ = ["PolicySpec", "ScenarioGrid", "build_grid", "elastic_policy",
           "backtest", "FleetReport", "FleetSummary", "summarize"]
