"""Single-jit fleet backtest: every scenario row, every hour, one call.

The engine replaces the per-trace Python loops of `examples/*.py` with one
jitted pass: per-row prices are gathered from the [N, T] market block, the
stateful hysteresis/partial-capacity scan runs batched over all B rows
(Pallas `fleet_scan` on TPU, the pure-JAX `fleet_scan_ref` recurrence
elsewhere), and cost accounting — restart overheads, idle draw, lost
restart time included — is a handful of fused [B] vector ops. A 1024-row x
8760-hour grid is a single dispatch.

Row semantics match `repro.core.policy.policy_cpc` (B=1 with
``off_level=0`` reproduces it to float round-off). Boundary convention:
the row state machine resumes on ``p <= p_on``, so a degenerate
``p_on == p_off`` row is *exactly* `threshold_policy` (whose thresholds
are price samples, making p == p_off common); a proper hysteresis row
differs from `hysteresis_policy` (strict ``p < p_on``) only at samples
exactly equal to p_on — a non-sample value in practice. Monte-Carlo
market ensembles give confidence bands on the Eq. (19) viability
question for free along the market axis.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.execution import ExecutionPlan
from repro.fleet.grid import ScenarioGrid, concat_rows, row_chunks
from repro.fleet.report import FleetReport
from repro.kernels.fleet_scan import fleet_scan
from repro.kernels.ref import FleetScanOut, fleet_hourly_ref, fleet_scan_ref


class FleetCosts(NamedTuple):
    """Per-row cost assembly over a `FleetScanOut` (all [B])."""

    cpc: jax.Array          # realized cost-per-compute
    cpc_ao: jax.Array       # always-on baseline (Eq. 11)
    tco: jax.Array          # fixed + energy + restart cost
    energy_cost: jax.Array  # running + idle draw energy cost
    restart_cost: jax.Array
    up_hours: jax.Array


def fleet_costs(scan: FleetScanOut, *, price_sum, fixed, power, period,
                restart_energy_mwh, restart_time_h, n_samples: int
                ) -> FleetCosts:
    """Cost accounting shared by the hard backtest and the differentiable
    tuner (`repro.tune.objective`): every quantity is affine in the four
    scan sums, so the same closed form prices a hard *and* a soft scan.
    ``price_sum`` is sum_t p_t per row; ``n_samples`` the series length.
    """
    dt = period / n_samples                           # [B] hours per sample
    e_ao = dt * power * price_sum                     # E_AO (Eq. 6)
    e_run = dt * power * scan.draw_price_sum
    e_restart = restart_energy_mwh * scan.restart_price_sum
    up_hours = dt * scan.up_units - restart_time_h * scan.n_starts
    tco = fixed + e_run + e_restart
    cpc = tco / jnp.maximum(up_hours, 1e-9)
    cpc_ao = (fixed + e_ao) / period                  # Eq. (11)
    return FleetCosts(cpc=cpc, cpc_ao=cpc_ao, tco=tco, energy_cost=e_run,
                      restart_cost=e_restart, up_hours=up_hours)


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_b",
                                             "block_t", "telemetry"))
def _backtest_jit(prices, market_idx, system_idx, policy_idx,
                  fixed, power, period, p_on, p_off, off_level, idle_frac,
                  restart_energy_mwh, restart_time_h, *,
                  use_pallas: bool, block_b: int, block_t: int,
                  telemetry: bool = False) -> FleetReport:
    t = prices.shape[1]
    p_rows = prices[market_idx]                       # [B, T] gather

    if use_pallas:
        scan = fleet_scan(p_rows, p_on, p_off, off_level, idle_frac,
                          block_b=block_b, block_t=block_t)
    else:
        scan = fleet_scan_ref(p_rows, p_on, p_off, off_level, idle_frac)

    if telemetry:
        # per-hour decision records: a *companion* scan over the same
        # state machine (`hard_hour_step`), aggregated on-device to [T]
        # and drained once per call — it reads the report's inputs and
        # feeds nothing back, so the FleetReport bits cannot change
        # (pinned in tests/test_obs.py)
        hourly = fleet_hourly_ref(p_rows, p_on, p_off, off_level,
                                  idle_frac, power)
        obs.drain("fleet.hourly", on_mw=hourly.on_mw,
                  draw_price=hourly.draw_price, starts=hourly.starts,
                  stops=hourly.stops)

    price_sum = jnp.sum(prices, axis=1)[market_idx]   # [B] sum_t p_t
    costs = fleet_costs(scan, price_sum=price_sum, fixed=fixed, power=power,
                        period=period, restart_energy_mwh=restart_energy_mwh,
                        restart_time_h=restart_time_h, n_samples=t)
    return FleetReport(
        cpc=costs.cpc, cpc_ao=costs.cpc_ao,
        cpc_reduction=1.0 - costs.cpc / costs.cpc_ao,
        tco=costs.tco, energy_cost=costs.energy_cost,
        restart_cost=costs.restart_cost,
        up_hours=costs.up_hours, n_starts=scan.n_starts,
        x_realized=1.0 - scan.up_units / t,
        market_idx=market_idx, system_idx=system_idx,
        policy_idx=policy_idx)


def backtest(grid: ScenarioGrid, *, use_pallas: Optional[bool] = None,
             block_b: int = 128, block_t: int = 512,
             chunk_rows: Optional[int] = None,
             plan: Optional[ExecutionPlan] = None) -> FleetReport:
    """Backtest every scenario row of ``grid`` in one jitted call.

    ``use_pallas=None`` auto-selects: the Pallas kernel on TPU, the
    vectorized pure-JAX recurrence elsewhere (the Pallas interpreter is a
    debugging tool, not a fast path). Both paths are checked against each
    other in `tests/test_fleet.py`.

    ``plan`` (`repro.execution.ExecutionPlan`) chooses the execution
    layout — the same object `repro.tune.TuneConfig` takes. A chunked
    plan evaluates the grid in fixed-size row slices (via
    `ScenarioGrid.take_rows`, padded to one compile shape) instead of
    one [B, T] pass — per-row results are identical, but the in-jit
    price gather never exceeds the chunk footprint, which is what lets
    `repro.tune.optimize` hard-re-evaluate B ~ 10^5 grids on one host.
    ``mode='sharded'`` raises: the backtest is a single [B, T] map with
    no coupled terms, so chunking already covers its memory story and a
    shard_map path would only add a second numerics contract.
    ``chunk_rows`` is the deprecated spelling of a chunked plan (one
    release of `DeprecationWarning`, then removal).
    """
    if chunk_rows is not None:
        if plan is not None:
            raise ValueError("backtest: pass plan= or the deprecated "
                             "chunk_rows, not both")
        warnings.warn(
            "backtest(chunk_rows=...) is deprecated — pass "
            "plan=repro.execution.ExecutionPlan(mode='chunked', "
            "chunk_rows=..., contract='bitwise') instead",
            DeprecationWarning, stacklevel=2)
        plan = ExecutionPlan(mode="chunked", chunk_rows=chunk_rows,
                             contract="bitwise") if chunk_rows \
            else ExecutionPlan(mode="single")
    if plan is not None and plan.mode == "sharded":
        raise ValueError(
            "backtest does not shard: the hard backtest is an uncoupled "
            "per-row map, so use ExecutionPlan(mode='chunked') for the "
            "memory bound (bitwise-identical results) instead")
    chunk = plan.chunk_rows if plan is not None else 0
    if chunk and grid.n_rows > chunk:
        parts = [backtest(grid.take_rows(sl), use_pallas=use_pallas,
                          block_b=block_b, block_t=block_t)
                 for sl in row_chunks(grid.n_rows, chunk)]
        return concat_rows(parts, grid.n_rows)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    telemetry = obs.enabled()
    report = _backtest_jit(
        grid.prices, grid.market_idx, grid.system_idx, grid.policy_idx,
        grid.fixed, grid.power, grid.period, grid.p_on, grid.p_off,
        grid.off_level, grid.idle_frac, grid.restart_energy_mwh,
        grid.restart_time_h, use_pallas=bool(use_pallas),
        block_b=block_b, block_t=block_t, telemetry=telemetry)
    if telemetry:
        obs.counter("fleet.backtests").inc()
        obs.trace_event("fleet.backtest", {
            "rows": int(grid.n_rows), "hours": int(grid.prices.shape[1]),
            "use_pallas": bool(use_pallas),
            "n_starts_total": float(jnp.sum(report.n_starts)),
            "cpc_mean": float(jnp.mean(report.cpc)),
            "reduction_mean": float(jnp.mean(report.cpc_reduction))})
    return report
