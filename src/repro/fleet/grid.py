"""ScenarioGrid — materialise a B = N x M x K backtesting fleet.

N markets (synthetic `MarketParams` ensembles or raw price matrices),
M `SystemCosts` variants and K policy configurations are stacked into a
flat pytree of B scenario rows that `repro.fleet.engine.backtest` consumes
in one jitted call. Prices stay [N, T] (shared across systems and
policies); every per-row quantity is a [B] vector, so the whole grid for
16 x 8 x 8 x 8760 h is ~a megabyte plus one year of prices per market.

Policies are *operational* (the machinery of `repro.core.policy`): a
two-threshold hysteresis state machine with restart overheads, residual
idle draw and a partial-shutdown capacity level (paper §V-C via
`repro.runtime.elastic`). A policy given as a shutdown fraction ``x`` is
resolved against each market's own empirical PV set (Eq. 1), so one spec
yields a different threshold price per market — exactly how an operator
would deploy the same plan across sites.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import execution
from repro.core.tco import SystemCosts
from repro.energy.markets import MarketParams, generate_market
from repro.runtime.elastic import capacity_plan


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """One operational shutdown policy, shared across markets/systems.

    Exactly one of ``x`` (shutdown fraction, resolved per market) or
    ``p_off`` (absolute threshold price) must be set; ``x <= 0`` or
    ``p_off=None`` with ``x=None`` means always-on. ``hysteresis`` < 1
    resumes only once the price falls below ``hysteresis * p_off``
    (sign-safe for negative thresholds). ``off_level`` is the capacity
    fraction kept online while "off" (partial shutdown, §V-C);
    ``idle_frac`` the residual draw of the shut-down remainder.
    """

    name: str
    x: Optional[float] = None
    p_off: Optional[float] = None
    hysteresis: float = 1.0
    off_level: float = 0.0
    idle_frac: float = 0.0
    restart_energy_mwh: float = 0.0
    restart_time_h: float = 0.0

    def __post_init__(self):
        if self.x is not None and self.p_off is not None:
            raise ValueError(f"policy {self.name!r}: give x or p_off, "
                             "not both")
        if not 0.0 <= self.off_level < 1.0:
            raise ValueError(f"policy {self.name!r}: off_level must be "
                             "in [0, 1)")
        if self.x is not None and not 0.0 <= self.x < 1.0:
            raise ValueError(f"policy {self.name!r}: x is a shutdown "
                             "fraction and must be in [0, 1)")
        if not 0.0 < self.hysteresis <= 1.0:
            raise ValueError(f"policy {self.name!r}: hysteresis must be "
                             "in (0, 1] (p_on may not exceed p_off)")


def elastic_policy(name: str, *, level: float, dp_total: int,
                   **spec_kwargs) -> PolicySpec:
    """A partial-shutdown policy whose off-capacity is snapped to a
    *realisable* data-parallel fraction via `repro.runtime.elastic`:
    keeping ``level`` of a ``dp_total``-replica job means keeping
    ``capacity_plan(level, dp_total).level`` of its power."""
    plan = capacity_plan(level, dp_total)
    return PolicySpec(name=name, off_level=plan.level, **spec_kwargs)


@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """Stacked scenario rows, ordered b = (n*M + m)*K + k."""

    prices: jnp.ndarray        # [N, T] hourly prices, shared across rows
    market_idx: jnp.ndarray    # [B] int32 row -> market n
    system_idx: jnp.ndarray    # [B] int32 row -> system m
    policy_idx: jnp.ndarray    # [B] int32 row -> policy k
    fixed: jnp.ndarray         # [B] F   (SystemCosts per row)
    power: jnp.ndarray         # [B] C
    period: jnp.ndarray        # [B] T hours
    p_on: jnp.ndarray          # [B] resume-below price
    p_off: jnp.ndarray         # [B] shutdown-above price
    off_level: jnp.ndarray     # [B] capacity retained while off
    idle_frac: jnp.ndarray     # [B] residual draw of the off part
    restart_energy_mwh: jnp.ndarray  # [B]
    restart_time_h: jnp.ndarray      # [B]
    market_names: tuple = ()
    system_names: tuple = ()
    policy_names: tuple = ()
    # optional `repro.workload.Workload` spec (duck-typed to avoid the
    # import cycle): None keeps every engine on the exogenous-demand
    # programs bit-identically; set, `workload_backtest`/`summarize`/
    # `optimize` couple the rows to sampled request traces
    workload: Optional[object] = None

    @property
    def n_rows(self) -> int:
        return int(self.market_idx.shape[0])

    @property
    def n_markets(self) -> int:
        return int(self.prices.shape[0])

    @property
    def n_systems(self) -> int:
        return len(self.system_names)

    @property
    def n_policies(self) -> int:
        return len(self.policy_names)

    @property
    def n_hours(self) -> int:
        return int(self.prices.shape[1])

    # fields shared across rows, NOT permuted by take_rows; everything
    # else must be a [B]-leading array or take_rows refuses to guess
    SHARED_FIELDS = ("prices", "market_names", "system_names",
                     "policy_names", "workload")

    def take_rows(self, order: np.ndarray) -> "ScenarioGrid":
        """Row-permuted view (shared fields stay); row order is an
        implementation detail the report layer must not depend on.

        Delegates to the one shape-driven `repro.execution.take_rows`
        (shared with `tune.optimizer`'s problem slicing and
        `LiveGrid.take_rows`): every field outside `SHARED_FIELDS` is
        carried through the permutation — a future per-row field is
        picked up automatically, and a field that is neither shared nor
        [B]-leading raises instead of being silently dropped
        (`tests/test_fleet.py` pins this against ``dataclasses.fields``).
        """
        return execution.take_rows(self, order, shared=self.SHARED_FIELDS,
                                   n_rows=self.n_rows)


def row_chunks(n_rows: int, chunk: int) -> list[np.ndarray]:
    """Equal-size row-index slices covering ``n_rows``, the last padded
    by repeating row 0.

    The single source of the chunked-evaluation idiom (fleet backtest,
    tuner loop, hard re-eval): equal slice sizes mean one compile shape,
    and because every per-row computation in those paths is independent
    of its batch, the padding rows cannot perturb the real ones — they
    are simply dropped again by `concat_rows`.
    """
    n_chunks = -(-n_rows // chunk)
    idx = np.concatenate([np.arange(n_rows),
                          np.zeros(n_chunks * chunk - n_rows, np.int64)])
    return [idx[j * chunk:(j + 1) * chunk] for j in range(n_chunks)]


def concat_rows(parts: list, n_rows: int):
    """Concatenate per-chunk pytrees along the row axis and trim the
    `row_chunks` padding. Works on bare arrays and on any pytree of
    [chunk]-leading leaves (FleetReport, PolicyParams, ...)."""
    return jax.tree.map(lambda *xs: jnp.concatenate(xs)[:n_rows], *parts)


def _resolve_threshold(prices_desc: np.ndarray, spec: PolicySpec) -> float:
    """Shutdown threshold of ``spec`` on one market (descending-sorted
    prices): Eq. (1)'s quantile for fraction specs, the given absolute
    price otherwise, +inf for always-on."""
    if spec.x is not None:
        n = prices_desc.shape[0]
        if spec.x <= 0.0:
            return np.inf
        m = int(np.clip(round(spec.x * n), 1, n - 1))
        return float(prices_desc[m - 1])
    if spec.p_off is None:
        return np.inf
    return float(spec.p_off)


def _resume_threshold(p_off: float, hysteresis: float) -> float:
    """p_on <= p_off even for negative prices: back off by
    (1 - hysteresis) of the threshold's magnitude."""
    if not np.isfinite(p_off):
        return p_off
    return p_off - (1.0 - hysteresis) * abs(p_off)


def build_grid(markets: Union[Sequence[MarketParams], np.ndarray],
               systems: Sequence[SystemCosts],
               policies: Sequence[PolicySpec],
               market_names: Optional[Sequence[str]] = None,
               system_names: Optional[Sequence[str]] = None,
               workload=None) -> ScenarioGrid:
    """Materialise the B = N*M*K scenario grid.

    ``markets``: either MarketParams (each generated via
    `repro.energy.markets.generate_market`) or an [N, T] price matrix
    (e.g. real SMARD traces). All markets must share T; all systems are
    backtested over the same period. ``workload`` (a
    `repro.workload.Workload`) couples the grid to sampled request
    traces wherever the grid flows; None keeps today's exogenous-demand
    programs untouched.
    """
    if len(systems) == 0 or len(policies) == 0:
        raise ValueError("need at least one system and one policy")
    if isinstance(markets, (np.ndarray, jnp.ndarray)):
        prices = np.asarray(markets, np.float32)
        if prices.ndim != 2:
            raise ValueError("price matrix must be [n_markets, n_hours]")
    else:
        if len(markets) == 0:
            raise ValueError("need at least one market")
        prices = np.stack([np.asarray(generate_market(mp).prices,
                                      np.float32) for mp in markets])
    n, t = prices.shape
    m_sys, k_pol = len(systems), len(policies)

    # per-(market, policy) thresholds from each market's own PV set
    sorted_desc = -np.sort(-prices, axis=1)
    p_off_nk = np.empty((n, k_pol), np.float32)
    p_on_nk = np.empty((n, k_pol), np.float32)
    for k, spec in enumerate(policies):
        for i in range(n):
            off = _resolve_threshold(sorted_desc[i], spec)
            p_off_nk[i, k] = off
            p_on_nk[i, k] = _resume_threshold(off, spec.hysteresis)

    mi, si, pi = np.meshgrid(np.arange(n), np.arange(m_sys),
                             np.arange(k_pol), indexing="ij")
    mi, si, pi = (a.reshape(-1).astype(np.int32) for a in (mi, si, pi))

    sys_field = lambda fn: np.asarray(  # noqa: E731
        [float(fn(s)) for s in systems], np.float32)[si]
    pol_field = lambda fn: np.asarray(  # noqa: E731
        [float(fn(p)) for p in policies], np.float32)[pi]

    if market_names is None:
        market_names = tuple(f"market{i}" for i in range(n))
    if system_names is None:
        system_names = tuple(f"system{i}" for i in range(m_sys))

    return ScenarioGrid(
        prices=jnp.asarray(prices),
        market_idx=jnp.asarray(mi), system_idx=jnp.asarray(si),
        policy_idx=jnp.asarray(pi),
        fixed=jnp.asarray(sys_field(lambda s: s.F)),
        power=jnp.asarray(sys_field(lambda s: s.C)),
        period=jnp.asarray(sys_field(lambda s: s.T)),
        p_on=jnp.asarray(p_on_nk[mi, pi]),
        p_off=jnp.asarray(p_off_nk[mi, pi]),
        off_level=jnp.asarray(pol_field(lambda p: p.off_level)),
        idle_frac=jnp.asarray(pol_field(lambda p: p.idle_frac)),
        restart_energy_mwh=jnp.asarray(
            pol_field(lambda p: p.restart_energy_mwh)),
        restart_time_h=jnp.asarray(pol_field(lambda p: p.restart_time_h)),
        market_names=tuple(market_names),
        system_names=tuple(system_names),
        policy_names=tuple(p.name for p in policies),
        workload=workload,
    )
