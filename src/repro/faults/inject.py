"""Inject compiled fault masks into the engines — in-scan, not in Python.

`faulted_backtest` is the fleet backtest under faults: one jitted
`lax.scan` whose per-hour body is the *same* `hard_hour_step` as
`repro.kernels.ref.fleet_scan_ref`, extended with three arithmetic
fault channels that are exact identities when healthy:

  * price-feed gaps — the state machine decides on the *observed* price
    (carry-forward of the last arrived sample, an in-scan ffill), while
    costs settle at the true market price (the exchange does not stop
    billing because a scraper died);
  * capacity outages — a zero multiplier forces the unit off (state
    carry included, so recovery into a cheap hour re-enters through the
    normal start accounting and bills the restart overhead), a partial
    multiplier derates capacity and draw proportionally;
  * demand surges — consumed by `faulted_problem` on the dispatch side.

With the identity masks every channel reduces to ``where(True, x, _)``
and ``* 1.0`` — bitwise no-ops — so a zero-fault run is bit-identical
to `repro.fleet.backtest` (asserted in tests/test_faults.py).

`faulted_problem` lowers the same masks onto a `DispatchProblem`
host-side: derated availability, surged demand, and gap-filled observed
prices (with the sort precompute invalidated so `dispatch` recomputes
it); pair it with `repro.dispatch.Relief` so storm-induced infeasible
hours shed gracefully instead of raising.
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.energy.stream import ffill_with_staleness
from repro.faults.trace import FaultMasks, FaultTrace
from repro.fleet.engine import backtest, fleet_costs
from repro.fleet.grid import ScenarioGrid
from repro.fleet.report import FleetReport
from repro.kernels.ref import FleetScanOut, hard_hour_step


def resolve_masks(faults: Union[FaultTrace, FaultMasks], n_sites: int,
                  n_markets: int, horizon: int) -> FaultMasks:
    """Compile a `FaultTrace` onto the scenario shape, or validate that
    pre-compiled `FaultMasks` already match it."""
    if isinstance(faults, FaultTrace):
        return faults.compile(n_sites, n_markets, horizon)
    m = faults
    if (m.cap_mult.shape != (n_sites, horizon)
            or m.price_ok.shape != (n_markets, horizon)):
        raise ValueError(
            f"FaultMasks compiled for cap{m.cap_mult.shape}/"
            f"price{m.price_ok.shape} do not fit a scenario with "
            f"{n_sites} sites x {n_markets} markets x {horizon} hours")
    return m


def emit_fault_events(faults: Union[FaultTrace, FaultMasks],
                      masks: FaultMasks, *, scope: str) -> None:
    """One ``fault.injected`` trace event per scheduled fault (or one
    aggregate event for hand-built masks), plus exposure counters —
    the raw material of the digest's Degradation section."""
    if not obs.enabled():
        return
    if isinstance(faults, FaultTrace) and len(faults):
        for ev in faults.events:
            obs.trace_event("fault.injected", {
                "fault": ev.kind, "target": int(ev.target),
                "start": int(ev.start), "duration": int(ev.duration),
                "magnitude": float(ev.magnitude), "scope": scope,
                "seed": faults.seed})
    elif not masks.is_trivial:
        counts = masks.counts()
        obs.trace_event("fault.injected", {
            "fault": "masks", "target": -1, "start": 0,
            "duration": int(masks.demand_mult.shape[0]),
            "magnitude": 1.0, "scope": scope, "seed": None, **counts})
    for k, v in masks.counts().items():
        if v:
            obs.counter(f"fault.{k}").inc(v)


def _faulted_scan(p_rows, ok_rows, mult_rows, p_on, p_off, off_level,
                  idle_frac) -> FleetScanOut:
    """Faulted fleet scan: `hard_hour_step` on observed prices, forced
    outage state, derated capacity/draw, true-price settlement."""
    b = p_rows.shape[0]
    p_on, p_off, off_level, idle_frac = (
        jnp.broadcast_to(jnp.asarray(v, jnp.float32), (b,))
        for v in (p_on, p_off, off_level, idle_frac))

    def step(carry, inp):
        on_prev, p_prev, acc = carry
        p_t, ok_t, m_t = inp
        p_obs = jnp.where(ok_t, p_t, p_prev)      # in-scan ffill
        on, _, _, _ = hard_hour_step(on_prev, p_obs, p_on, p_off,
                                     off_level, idle_frac)
        on_e = jnp.where(m_t > 0.0, on, 0.0)      # full outage forces off
        start = jnp.maximum(on_e - on_prev, 0.0)  # restart billed on
        cap = off_level + (1.0 - off_level) * on_e  # recovery
        draw = cap + idle_frac * (1.0 - cap)
        cap_f = cap * m_t                         # partial derate
        draw_f = draw * m_t
        acc = (acc[0] + draw_f * p_t, acc[1] + cap_f,
               acc[2] + start, acc[3] + start * p_t)
        return (on_e, p_obs, acc), None

    zeros = jnp.zeros((b,), jnp.float32)
    init = (jnp.ones((b,), jnp.float32), p_rows[:, 0],
            (zeros, zeros, zeros, zeros))
    (_, _, acc), _ = jax.lax.scan(
        step, init, (p_rows.T, ok_rows.T, mult_rows.T))
    return FleetScanOut(*acc)


@jax.jit
def _faulted_backtest_jit(prices, market_idx, system_idx, policy_idx,
                          fixed, power, period, p_on, p_off, off_level,
                          idle_frac, restart_energy_mwh, restart_time_h,
                          price_ok, cap_mult) -> FleetReport:
    """One jitted program mirroring `repro.fleet.engine._backtest_jit`
    (gather -> scan -> cost assembly all inside the same jit, so XLA's
    constant-division rewrite treats both identically — the bit-identity
    contract holds program-for-program, not just op-for-op)."""
    t = prices.shape[1]
    p_rows = prices[market_idx]                       # [B, T] gather
    ok_rows = price_ok[market_idx]
    scan = _faulted_scan(p_rows, ok_rows, cap_mult, p_on, p_off,
                         off_level, idle_frac)
    price_sum = jnp.sum(prices, axis=1)[market_idx]   # [B] sum_t p_t
    costs = fleet_costs(scan, price_sum=price_sum, fixed=fixed,
                        power=power, period=period,
                        restart_energy_mwh=restart_energy_mwh,
                        restart_time_h=restart_time_h, n_samples=t)
    return FleetReport(
        cpc=costs.cpc, cpc_ao=costs.cpc_ao,
        cpc_reduction=1.0 - costs.cpc / costs.cpc_ao,
        tco=costs.tco, energy_cost=costs.energy_cost,
        restart_cost=costs.restart_cost,
        up_hours=costs.up_hours, n_starts=scan.n_starts,
        x_realized=1.0 - scan.up_units / t,
        market_idx=market_idx, system_idx=system_idx,
        policy_idx=policy_idx)


def faulted_backtest(grid: ScenarioGrid,
                     faults: Union[FaultTrace, FaultMasks, None] = None,
                     *, _force_masked: bool = False) -> FleetReport:
    """`repro.fleet.backtest` under a fault schedule.

    ``faults`` is a `FaultTrace` (compiled here onto the grid's
    B rows x N markets x T hours; outage targets index backtest *rows*)
    or pre-compiled `FaultMasks`; None (or an empty trace) runs the
    healthy masks and returns bit-identical results to
    ``backtest(grid, use_pallas=False)``.

    Trivial masks short-circuit to the plain backtest program — the
    mask channels stream two extra [B, T] arrays through the
    sequential scan, a real cost a healthy run must not pay (gated in
    benchmarks/bench_faults.py). ``_force_masked`` keeps the masked
    program on trivial masks anyway; tests use it to pin the in-scan
    identity property (``where(True, x)`` / ``* 1.0`` are bitwise
    no-ops), and with it the result is still bit-identical.
    """
    t = int(grid.prices.shape[1])
    n_markets = int(grid.prices.shape[0])
    b = grid.n_rows
    if faults is None:
        faults = FaultTrace()
    if (isinstance(faults, FaultTrace) and not len(faults)
            and not _force_masked):
        # empty schedule: skip even the mask compilation ([B, T] arrays
        # allocated only to be discarded) and run the plain program
        return backtest(grid, use_pallas=False)
    masks = resolve_masks(faults, b, n_markets, t)
    emit_fault_events(faults, masks, scope="backtest")
    if masks.is_trivial and not _force_masked:
        return backtest(grid, use_pallas=False)
    return _faulted_backtest_jit(
        jnp.asarray(grid.prices, jnp.float32), grid.market_idx,
        grid.system_idx, grid.policy_idx, grid.fixed, grid.power,
        grid.period, grid.p_on, grid.p_off, grid.off_level,
        grid.idle_frac, grid.restart_energy_mwh, grid.restart_time_h,
        jnp.asarray(masks.price_ok),
        jnp.asarray(masks.cap_mult, jnp.float32))


def faulted_problem(problem, faults: Union[FaultTrace, FaultMasks], *,
                    site_market_idx: Optional[np.ndarray] = None):
    """Lower a fault schedule onto a `repro.dispatch.DispatchProblem`.

    Availability is derated by the capacity mask, demand scaled by the
    surge profile, and each site's price row forward-filled over its
    market's feed gaps (`ffill_with_staleness` — the operator allocates
    on the last published price). ``site_market_idx`` maps sites to
    mask markets; omitted, the mask must carry one row per site (or a
    single shared row). The segment sort is invalidated so `dispatch`
    recomputes it from the observed prices. Trivial masks return the
    problem object unchanged — bit-identical by construction.
    """
    s, t = np.asarray(problem.avail_mw).shape
    if isinstance(faults, FaultTrace):
        masks = faults.compile(s, s, t)
    else:
        masks = faults
        if masks.cap_mult.shape != (s, t):
            raise ValueError(
                f"FaultMasks.cap_mult{masks.cap_mult.shape} does not "
                f"fit a {s}-site x {t}-hour dispatch problem")
    if masks.is_trivial:
        return problem

    ok = np.asarray(masks.price_ok)
    if site_market_idx is not None:
        ok_rows = ok[np.asarray(site_market_idx)]
    elif ok.shape[0] == s:
        ok_rows = ok
    elif ok.shape[0] == 1:
        ok_rows = np.broadcast_to(ok, (s, t))
    else:
        raise ValueError(
            f"price_ok has {ok.shape[0]} markets for {s} sites — pass "
            "site_market_idx to map sites onto mask markets")

    prices = np.asarray(problem.prices, np.float64)
    if not ok_rows.all():
        filled = prices.copy()
        for i in range(s):
            if not ok_rows[i].all():
                filled[i], _ = ffill_with_staleness(
                    np.where(ok_rows[i], prices[i], np.nan))
        prices = filled
    avail = np.asarray(problem.avail_mw, np.float64) * masks.cap_mult
    demand = np.asarray(problem.demand_mw, np.float64) \
        * masks.demand_mult
    emit_fault_events(faults, masks, scope="dispatch")
    return problem._replace(
        prices=prices.astype(np.float32),
        avail_mw=avail.astype(np.float32),
        demand_mw=demand.astype(np.float32),
        order=None, rank=None)
