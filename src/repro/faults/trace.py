"""Declarative, seeded fault schedules and their compiled mask form.

A `FaultTrace` is a plain list of `FaultEvent` windows — *what* goes
wrong, *where*, *when*, and *how hard* — decoupled from how any engine
consumes it. `FaultTrace.compile` lowers the schedule onto a concrete
scenario shape once, as dense numpy masks (`FaultMasks`): a capacity
multiplier per site-hour, boolean feed/forecast availability per
market-hour, and a demand multiplier per hour. The masks are what flows
*in-scan* through the fleet backtest, the dispatch water-fill, and the
live controller (`repro.faults.inject`, `repro.live`): fault handling
is ordinary arithmetic on the device, never a Python-loop side path.

The all-healthy masks are exact identities — capacity ``* 1.0``, price
``where(True, p, _)``, demand ``* 1.0`` — so an empty trace is
*bit-identical* to running without the fault layer at all (asserted in
tests/test_faults.py). `random_storm` draws a reproducible storm from a
seed for chaos testing (`examples/chaos_fleet.py`).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import numpy as np

FAULT_KINDS = ("site_outage", "price_gap", "forecast_blackout",
               "demand_surge")


class FaultEvent(NamedTuple):
    """One fault window.

    kind : one of `FAULT_KINDS`.
    target : site index (``site_outage``), market index (``price_gap``,
        ``forecast_blackout``) or ignored (``demand_surge``); ``-1``
        hits every site/market.
    start, duration : hour window ``[start, start + duration)``,
        clipped to the horizon at compile time.
    magnitude : fraction of capacity *lost* for ``site_outage`` (1.0 =
        full outage, 0.3 = 30% derate); demand multiplier for
        ``demand_surge`` (1.5 = +50%); ignored for the feed faults.
    """

    kind: str
    target: int
    start: int
    duration: int
    magnitude: float = 1.0


class FaultMasks(NamedTuple):
    """Dense per-hour lowering of a `FaultTrace` onto one scenario.

    cap_mult : [S, T] float64 capacity multiplier (1.0 = healthy,
        0.0 = full outage). Rows are *sites* for dispatch and live use,
        or backtest rows when compiled with ``n_sites = B``.
    price_ok : [N, T] bool — the hour's price sample arrived.
    forecast_ok : [N, T] bool — the hour's forecast was published.
    demand_mult : [T] float64 fleet-demand multiplier.
    """

    cap_mult: np.ndarray
    price_ok: np.ndarray
    forecast_ok: np.ndarray
    demand_mult: np.ndarray

    @property
    def is_trivial(self) -> bool:
        """True when every mask is the identity (no fault ever fires)."""
        return bool((self.cap_mult == 1.0).all()
                    and self.price_ok.all() and self.forecast_ok.all()
                    and (self.demand_mult == 1.0).all())

    def counts(self) -> dict:
        """Per-kind fault exposure (hours), for telemetry and digests."""
        return {
            "outage_site_hours": int((self.cap_mult < 1.0).sum()),
            "price_gap_hours": int((~self.price_ok).sum()),
            "forecast_blackout_hours": int((~self.forecast_ok).sum()),
            "demand_surge_hours": int((self.demand_mult != 1.0).sum()),
        }


def identity_masks(n_sites: int, n_markets: int, horizon: int
                   ) -> FaultMasks:
    """The all-healthy masks: compiling an empty trace returns exactly
    these, and injecting them is bitwise a no-op."""
    return FaultMasks(
        cap_mult=np.ones((n_sites, horizon), np.float64),
        price_ok=np.ones((n_markets, horizon), bool),
        forecast_ok=np.ones((n_markets, horizon), bool),
        demand_mult=np.ones((horizon,), np.float64))


@dataclass(frozen=True)
class FaultTrace:
    """A declarative fault schedule: an ordered tuple of `FaultEvent`s
    plus the seed that generated them (``None`` for hand-written
    traces). Traces are shape-free; `compile` lowers onto a scenario."""

    events: Tuple[FaultEvent, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for ev in self.events:
            if ev.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {ev.kind!r} "
                                 f"(expected one of {FAULT_KINDS})")
            if ev.duration < 0 or ev.start < 0:
                raise ValueError(f"negative fault window: {ev}")

    def __len__(self) -> int:
        return len(self.events)

    def compile(self, n_sites: int, n_markets: int, horizon: int
                ) -> FaultMasks:
        """Lower the schedule to dense `[S,T]`/`[N,T]`/`[T]` masks.

        Overlapping outages on one site compose by taking the *worst*
        derate; overlapping surges multiply. Windows are clipped to
        ``[0, horizon)``; a target index out of range raises.
        """
        m = identity_masks(n_sites, n_markets, horizon)
        for ev in self.events:
            lo = min(ev.start, horizon)
            hi = min(ev.start + ev.duration, horizon)
            if hi <= lo:
                continue
            if ev.kind == "site_outage":
                rows = self._rows(ev, n_sites, "site")
                keep = 1.0 - float(ev.magnitude)
                if not 0.0 <= keep <= 1.0:
                    raise ValueError(f"outage magnitude not in [0,1]: {ev}")
                m.cap_mult[rows, lo:hi] = np.minimum(
                    m.cap_mult[rows, lo:hi], keep)
            elif ev.kind == "price_gap":
                m.price_ok[self._rows(ev, n_markets, "market"),
                           lo:hi] = False
            elif ev.kind == "forecast_blackout":
                m.forecast_ok[self._rows(ev, n_markets, "market"),
                              lo:hi] = False
            else:                                    # demand_surge
                if ev.magnitude < 0.0:
                    raise ValueError(f"negative surge multiplier: {ev}")
                m.demand_mult[lo:hi] *= float(ev.magnitude)
        return m

    @staticmethod
    def _rows(ev: FaultEvent, n: int, what: str):
        if ev.target == -1:
            return slice(None)
        if not 0 <= ev.target < n:
            raise ValueError(
                f"{ev.kind} target {ev.target} out of range for "
                f"{n} {what}s")
        return slice(ev.target, ev.target + 1)


def random_storm(seed: int, n_sites: int, n_markets: int, horizon: int,
                 *, n_outages: int = 3, n_price_gaps: int = 2,
                 n_blackouts: int = 2, n_surges: int = 1,
                 max_duration: int = 48,
                 surge_range: Tuple[float, float] = (1.2, 1.8)
                 ) -> FaultTrace:
    """Draw a reproducible fault storm: every window, target, and
    magnitude comes from one `np.random.default_rng(seed)` stream, so a
    storm is identified by ``(seed, shape, counts)`` alone."""
    rng = np.random.default_rng(seed)
    events = []

    def window():
        dur = int(rng.integers(1, max_duration + 1))
        start = int(rng.integers(0, max(horizon - dur, 1)))
        return start, dur

    for _ in range(n_outages):
        start, dur = window()
        # mostly full outages, occasionally a partial derate
        mag = 1.0 if rng.random() < 0.7 else float(rng.uniform(0.3, 0.9))
        events.append(FaultEvent("site_outage",
                                 int(rng.integers(0, n_sites)),
                                 start, dur, mag))
    for _ in range(n_price_gaps):
        start, dur = window()
        events.append(FaultEvent("price_gap",
                                 int(rng.integers(0, n_markets)),
                                 start, dur))
    for _ in range(n_blackouts):
        start, dur = window()
        events.append(FaultEvent("forecast_blackout",
                                 int(rng.integers(0, n_markets)),
                                 start, dur))
    for _ in range(n_surges):
        start, dur = window()
        events.append(FaultEvent("demand_surge", -1, start, dur,
                                 float(rng.uniform(*surge_range))))
    return FaultTrace(events=tuple(events), seed=seed)
