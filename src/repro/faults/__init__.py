"""Fault injection and graceful degradation for the fleet stack.

  trace  — declarative, seeded `FaultTrace` schedules (site outages,
           price-feed gaps, forecast blackouts, demand surges) compiled
           to dense per-hour `FaultMasks`
  inject — the masks flowing *in-scan* through the fleet backtest
           (`faulted_backtest`) and onto dispatch instances
           (`faulted_problem`)

The contract throughout: the healthy masks are exact arithmetic
identities, so a zero-fault run is bit-identical to the un-faulted
engines; storms are reproducible from a seed (`random_storm`); and
every injected fault leaves a ``fault.injected`` telemetry event behind
(`repro.obs`). Graceful handling of the injected faults lives with the
engines themselves: `repro.dispatch.Relief` prices shed,
`repro.live` degrades its forecasts down a fallback ladder, and
`repro.tune`'s guarded Adam rejects non-finite steps.
"""

from repro.faults.inject import (emit_fault_events, faulted_backtest,
                                 faulted_problem, resolve_masks)
from repro.faults.trace import (FAULT_KINDS, FaultEvent, FaultMasks,
                                FaultTrace, identity_masks, random_storm)

__all__ = ["FAULT_KINDS", "FaultEvent", "FaultMasks", "FaultTrace",
           "identity_masks", "random_storm", "emit_fault_events",
           "faulted_backtest", "faulted_problem", "resolve_masks"]
