"""Serving launcher: price-aware batched inference.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 32 --ticks 400 --region germany
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config
from repro.configs.inputs import reduced_config
from repro.energy.markets import generate_market
from repro.energy.presets import region_params
from repro.energy.stream import PriceStream
from repro.models.model import init_params
from repro.runtime.scheduler import EnergyAwareScheduler, SchedulerConfig
from repro.serving.engine import Request, ServeConfig, ServingEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--ticks", type=int, default=200)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--min-slots", type=int, default=0)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--region", default="germany")
    ap.add_argument("--psi", type=float, default=2.0)
    ap.add_argument("--no-price-gate", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    scheduler = None
    if not args.no_price_gate:
        md = generate_market(region_params(args.region, seed=args.seed))
        scheduler = EnergyAwareScheduler(
            PriceStream(np.asarray(md.prices)),
            SchedulerConfig(psi=args.psi, mode="oracle"))

    eng = ServingEngine(params, cfg,
                        ServeConfig(slots=args.slots,
                                    min_slots=args.min_slots,
                                    max_seq=args.max_seq),
                        scheduler=scheduler)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(
                               2, cfg.vocab - 1, size=8).astype(np.int32),
                           max_new=16))
    out = eng.run(ticks=args.ticks)
    print(json.dumps({k: (round(v, 6) if isinstance(v, float) else v)
                      for k, v in out.items()}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
