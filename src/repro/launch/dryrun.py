import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell and both production meshes
(16x16 single-pod, 2x16x16 multi-pod), this driver

  1. builds the cell's step function with production shardings
     (`repro.launch.steps`),
  2. ``jax.jit(...).lower(**ShapeDtypeStruct inputs)`` — no allocation,
  3. ``.compile()`` — GSPMD partitioning + backend compilation; sharding
     mismatches, non-divisible layouts and unsupported collectives fail
     HERE, which is exactly what the dry-run exists to catch,
  4. records ``compiled.memory_analysis()`` (the fits-in-HBM proof),
     raw ``cost_analysis()``, and the structural HLO analysis
     (`repro.launch.hlo_analysis` — loop-aware FLOPs / bytes / collective
     bytes) into a JSON artifact per cell.

Artifacts land in benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json
and feed §Roofline (benchmarks/roofline.py) and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b
  PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --mesh pod
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import (SHAPES, cell_skip_reason, get_config,
                                list_archs)
from repro.launch.hlo_analysis import analyze, raw_cost_analysis
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.steps import build_step
from repro.parallel.axes import use_sharding

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "artifacts" / "dryrun"

# TPU v5e
HBM_PER_CHIP = 16 * 2 ** 30


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             out_dir: Path, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mesh_shape": dict(mesh.shape), "kind": shape.kind}
    t0 = time.time()
    try:
        fn, args, rules = build_step(cfg, shape, mesh)
        with use_sharding(mesh, rules):
            lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            # donated args alias outputs; peak live set per device:
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes
                              - ma.alias_size_in_bytes),
            "hbm_per_chip": HBM_PER_CHIP,
        }
        # XLA CPU float-normalises bf16 compute to f32, so temp buffers for
        # bf16 models measure ~2x what a TPU run would allocate. Report
        # both the raw CPU peak and the TPU-adjusted estimate (temp halved
        # for bf16 models; arguments/outputs use real dtypes either way).
        temp_adj = (rec["memory"]["temp_bytes"] // 2
                    if cfg.dtype == "bfloat16"
                    else rec["memory"]["temp_bytes"])
        rec["memory"]["peak_bytes_tpu_est"] = int(
            rec["memory"]["argument_bytes"] + temp_adj
            + rec["memory"]["output_bytes"]
            - rec["memory"]["alias_bytes"])
        rec["fits"] = rec["memory"]["peak_bytes"] <= HBM_PER_CHIP
        rec["fits_tpu_est"] = \
            rec["memory"]["peak_bytes_tpu_est"] <= HBM_PER_CHIP

        try:
            ca = raw_cost_analysis(compiled)
            rec["cost_analysis_raw"] = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            }
        except Exception:                        # pragma: no cover
            rec["cost_analysis_raw"] = None

        f32_as = 2.0 if cfg.dtype == "bfloat16" else 4.0
        rep = analyze(compiled.as_text(), n_devices=mesh.size,
                      f32_as=f32_as)
        rec["hlo"] = rep.as_dict()
        rec["hlo"]["f32_counted_as_bytes"] = f32_as
        rec["ok"] = True
    except Exception as e:                       # the dry-run's job is to
        rec["ok"] = False                        # surface these
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape_name}.json").write_text(
        json.dumps(rec, indent=1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one architecture id")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--mesh", default="both",
                    choices=["both", "pod", "multipod"])
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (hillclimbs)")
    ap.add_argument("--tag", default="",
                    help="artifact subdirectory suffix (hillclimbs)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if args.mesh in ("both", "pod"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("both", "multipod"):
        meshes.append(("pod2x16x16", make_production_mesh(multi_pod=True)))

    n_fail = 0
    for mesh_name, mesh in meshes:
        out_dir = Path(args.out) / (mesh_name + args.tag)
        print(f"=== mesh {describe(mesh)} -> {out_dir}")
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                skip = cell_skip_reason(cfg, SHAPES[shape_name])
                if skip:
                    print(f"  SKIP {arch} x {shape_name}: {skip}")
                    continue
                rec = run_cell(arch, shape_name, mesh, mesh_name, out_dir,
                               overrides or None)
                if rec["ok"]:
                    mem = rec["memory"]
                    print(f"  OK   {arch} x {shape_name}: "
                          f"lower {rec['lower_s']}s compile "
                          f"{rec['compile_s']}s  peak/dev "
                          f"{mem['peak_bytes'] / 2**30:.2f} GiB "
                          f"(fits={rec['fits']})  flops/dev "
                          f"{rec['hlo']['flops']:.2e}")
                else:
                    n_fail += 1
                    print(f"  FAIL {arch} x {shape_name}: {rec['error']}")
    print(f"done; {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
