"""Jitted step functions with production shardings, for every cell kind.

`build_step(cfg, shape, mesh)` returns (jitted_fn, abstract_args, rules):

  train    -> train_step(params, opt_state, batch) -> (params', opt', loss)
              full step: loss + grad (remat inside) + AdamW update
  prefill  -> prefill_step(params, batch) -> (logits, caches)
  decode   -> serve_step(params, tokens, caches, positions)
              -> (logits, caches')

Rules are chosen per family and shape (DESIGN.md §5):

  * attention families train/prefill with Megatron SP (seq over `model`
    between blocks); SSM/hybrid keep seq unsharded (the SSD chunk scan is
    sequential in seq — sharding it would serialise GSPMD);
  * decode uses batch-only activation sharding with KV caches sharded over
    `model` (cache positions);
  * `long_500k` (global_batch=1) cannot shard batch: a dedicated rule set
    shards cache positions / heads instead.

Argument shardings are *sanitised*: a mesh axis that does not divide the
dim (e.g. vocab 50280 over model=16, or 40 query heads over 16) is dropped
for that input leaf — jit requires divisible argument shardings, while
internal `with_sharding_constraint`s may stay uneven (GSPMD pads).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.configs.inputs import input_specs
from repro.models.model import (cache_specs, decode_step, init_params,
                                loss_fn, param_specs, prefill)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel.axes import (DECODE_RULES, LogicalRules,
                                 SSM_PREFILL_RULES, TRAIN_RULES,
                                 logical_to_spec)

LONGCTX_RULES: LogicalRules = dict(DECODE_RULES, batch=None)
# optimizer moments: ZeRO-1 over the pod axis on top of fsdp — moments are
# touched once per step, so the cross-DCN gather/scatter happens once per
# step (vs per-layer for weights). Halves per-chip optimizer state on the
# multi-pod mesh (grok-1-314b's largest state tensor).
MOMENT_RULES: LogicalRules = dict(TRAIN_RULES, embed_p=("pod", "data"))
# aligned-cache decode: KV heads shard evenly over `model`, cache
# positions stay local -> the rolling-slot update is collective-free
DECODE_HEADS_RULES: LogicalRules = dict(DECODE_RULES, cache_seq=None)
LONGCTX_HEADS_RULES: LogicalRules = dict(DECODE_HEADS_RULES, batch=None)


def rules_for(cfg: ModelConfig, shape: ShapeSpec,
              tp: int = 16) -> LogicalRules:
    if shape.kind == "decode":
        heads_even = (cfg.n_heads and cfg.cache_heads % tp == 0
                      and cfg.n_heads % cfg.cache_heads == 0)
        # batch must divide the dp submesh; long_500k has batch 1
        if shape.global_batch < 32:
            return LONGCTX_HEADS_RULES if heads_even else LONGCTX_RULES
        return DECODE_HEADS_RULES if heads_even else DECODE_RULES
    if cfg.family in ("ssm", "hybrid"):
        if shape.kind == "train":
            # seq sharded at block boundaries: the SSD chunk scan gathers
            # the sequence *inside* the (rematted) block, so gathered
            # tensors are recomputed, never stored — the 48 layer-boundary
            # checkpoints stay seq-sharded (16x smaller live set)
            return TRAIN_RULES
        return SSM_PREFILL_RULES
    return TRAIN_RULES


def _sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim."""
    out = []
    for i, axes in enumerate(spec):
        if axes is None:
            out.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        keep = []
        size = shape[i] if i < len(shape) else 1
        for a in axes_t:
            if a not in mesh.shape:
                continue
            n = mesh.shape[a]
            if size % n == 0:
                keep.append(a)
                size //= n
        out.append(tuple(keep) if len(keep) > 1
                   else (keep[0] if keep else None))
    return P(*out)


def shardings_for(tree_specs: Any, tree_abstract: Any, mesh: Mesh,
                  rules: LogicalRules) -> Any:
    """Logical-axes pytree -> sanitized NamedSharding pytree."""
    def f(axes, leaf):
        spec = logical_to_spec(axes, rules, mesh)
        spec = _sanitize(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(f, tree_specs, tree_abstract,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None)))
                                for e in x))


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(cfg: ModelConfig, opt: AdamWConfig) -> Any:
    aparams = abstract_params(cfg)
    return jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aparams), opt))


def _batch_shardings(batch_abstract: dict, mesh: Mesh,
                     rules: LogicalRules) -> dict:
    def f(leaf):
        axes = ("batch",) + (None,) * (leaf.ndim - 1)
        spec = _sanitize(logical_to_spec(axes, rules, mesh), leaf.shape,
                         mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(f, batch_abstract)


def make_train_step(cfg: ModelConfig, opt: AdamWConfig):
    """Full production train step: loss -> grad (remat inside) -> AdamW.

    ``cfg.train_microbatches > 1`` accumulates gradients over microbatch
    slices of the global batch (f32 accumulator) — activation live-set
    scales 1/n while data order and loss are unchanged. This is what lets
    grok-1-314b train on 256 x 16 GiB chips at global batch 256 x 4k.
    """
    n_micro = cfg.train_microbatches

    def grad_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, mb, cfg), has_aux=True)(params)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            grads, metrics = grad_of(params, batch)
        else:
            def split(x):
                return x.reshape((n_micro, x.shape[0] // n_micro)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            acc_dt = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[cfg.grad_accum_dtype]

            def acc_fn(carry, mb):
                g_acc, m_acc = carry
                g, m = grad_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)).astype(acc_dt),
                    g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            zeros_m = jax.eval_shape(lambda: grad_of(params, jax.tree.map(
                lambda t: t[0], micro))[1])
            zeros_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                   zeros_m)
            (grads, msum), _ = jax.lax.scan(acc_fn, (zeros_g, zeros_m),
                                            micro)
            # stay in acc_dt: AdamW upcasts per-leaf (transient), so a
            # whole-tree f32 copy here would be the largest live tensor
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) / n_micro).astype(g.dtype),
                grads)
            metrics = jax.tree.map(lambda m: m / n_micro, msum)
        new_params, new_opt, stats = adamw_update(grads, opt_state, params,
                                                  opt)
        return new_params, new_opt, {**metrics, **stats}
    return train_step


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *,
               opt: Optional[AdamWConfig] = None, donate: bool = True):
    """Returns (jitted_fn, args_tuple, rules). ``args_tuple`` leaves are
    ShapeDtypeStructs with .sharding set — ready for .lower(*args)."""
    rules = rules_for(cfg, shape, tp=mesh.shape.get("model", 1))
    ins = input_specs(cfg, shape)
    aparams = abstract_params(cfg)
    pshard = shardings_for(param_specs(aparams), aparams, mesh, rules)

    def attach(tree, shards):
        return jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            tree, shards)

    if shape.kind == "train":
        opt = opt or AdamWConfig(moment_dtype=cfg.moment_dtype)
        aopt = abstract_opt_state(cfg, opt)
        # NOTE: pod-sharded moments (MOMENT_RULES, ZeRO-1 over DCN) were
        # measured and REFUTED as a pure-GSPMD change: the partitioner
        # replicates the f32 update instead of slicing (§Perf G4). A
        # hand-rolled shard_map optimizer step would be required.
        oshard = type(aopt)(
            step=NamedSharding(mesh, P()),
            mu=shardings_for(param_specs(aopt.mu), aopt.mu, mesh, rules),
            nu=shardings_for(param_specs(aopt.nu), aopt.nu, mesh, rules))
        bshard = _batch_shardings(ins["batch"], mesh, rules)
        fn = jax.jit(
            make_train_step(cfg, opt),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1) if donate else ())
        args = (attach(aparams, pshard), attach(aopt, oshard),
                attach(ins["batch"], bshard))
        return fn, args, rules

    if shape.kind == "prefill":
        bshard = _batch_shardings(ins["batch"], mesh, rules)
        cshard = shardings_for(cache_specs(cfg),
                               _abstract_caches(cfg, shape), mesh, rules)

        def prefill_step(params, batch):
            return prefill(params, batch, cfg, seq_sharded=
                           cfg.family not in ("ssm", "hybrid"))

        fn = jax.jit(prefill_step,
                     in_shardings=(pshard, bshard),
                     out_shardings=(None, cshard))
        args = (attach(aparams, pshard), attach(ins["batch"], bshard))
        return fn, args, rules

    if shape.kind == "decode":
        acaches = ins["caches"]
        cshard = shardings_for(cache_specs(cfg), acaches, mesh, rules)
        tshard = _batch_shardings(
            {"t": ins["tokens"], "p": ins["positions"]}, mesh, rules)

        def serve_step(params, tokens, caches, positions):
            return decode_step(params, tokens, caches, positions, cfg)

        fn = jax.jit(serve_step,
                     in_shardings=(pshard, tshard["t"], cshard,
                                   tshard["p"]),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,) if donate else ())
        args = (attach(aparams, pshard), attach(ins["tokens"], tshard["t"]),
                attach(acaches, cshard),
                attach(ins["positions"], tshard["p"]))
        return fn, args, rules

    raise ValueError(shape.kind)


def _abstract_caches(cfg: ModelConfig, shape: ShapeSpec):
    from repro.models.model import init_cache
    return init_cache(cfg, shape.global_batch, shape.seq_len, abstract=True)
