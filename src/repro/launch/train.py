"""Training launcher: energy-aware training of any assigned architecture.

On this CPU container it drives a *reduced* config end-to-end (real JAX
steps, simulated market clock); on a real cluster the same driver runs the
full config — the mesh comes from `make_production_mesh()` and the Trainer's
checkpoint/restore path is the shutdown/resume mechanism.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 200 --region germany --psi 2.0 --mode oracle
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import get_config
from repro.configs.inputs import reduced_config
from repro.energy.markets import generate_market
from repro.energy.presets import region_params
from repro.energy.stream import PriceStream
from repro.runtime.scheduler import EnergyAwareScheduler, SchedulerConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--region", default="germany")
    ap.add_argument("--psi", type=float, default=2.0)
    ap.add_argument("--mode", default="oracle",
                    choices=["oracle", "rolling", "always-on"])
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture config (cluster only)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fault-prob", type=float, default=0.0)
    ap.add_argument("--straggler-sigma", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced_config(cfg)

    md = generate_market(region_params(args.region, seed=args.seed))
    stream = PriceStream(np.asarray(md.prices))
    scheduler = None
    if args.mode != "always-on":
        scheduler = EnergyAwareScheduler(
            stream, SchedulerConfig(psi=args.psi, mode=args.mode))
        print("scheduler:", scheduler.stats_snapshot())

    trainer = Trainer(
        cfg,
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      microbatches=args.microbatches,
                      grad_compress=args.grad_compress,
                      fault_prob_per_step=args.fault_prob,
                      straggler_sigma=args.straggler_sigma,
                      seed=args.seed),
        scheduler=scheduler, batch_size=args.batch, seq_len=args.seq)
    out = trainer.run()
    print(json.dumps({k: (round(v, 6) if isinstance(v, float) else v)
                      for k, v in out.items()}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
