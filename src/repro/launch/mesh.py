"""Production meshes.

Everything is a *function* (never module-level device state): importing this
module must not initialise jax's backend, because the dry-run needs to set
XLA_FLAGS before first jax use while tests run on the single real CPU
device.

Production topology (TPU v5e): one pod = a 16x16 slice (256 chips);
multi-pod = 2 pods = 512 chips. Mesh axes:

  pod     crosses the inter-pod DCN boundary: *pure data parallelism* —
          the only cross-pod collective is the gradient all-reduce
          (optionally int8-compressed, `repro.optim.compress`)
  data    intra-pod data parallelism + fsdp (ZeRO-3 parameter sharding)
  model   tensor/sequence parallelism (Megatron-style)
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1,
                   pod: Optional[int] = None) -> Mesh:
    """A small mesh over whatever devices exist (tests / examples)."""
    n = data * model * (pod or 1)
    devs = np.asarray(jax.devices()[:n])
    if pod is None:
        return Mesh(devs.reshape(data, model), ("data", "model"))
    return Mesh(devs.reshape(pod, data, model), ("pod", "data", "model"))


def mesh_devices_required(multi_pod: bool) -> int:
    return 512 if multi_pod else 256


def describe(mesh: Mesh) -> str:
    return " x ".join(f"{k}={v}" for k, v in mesh.shape.items()) \
        + f" ({mesh.size} chips)"
