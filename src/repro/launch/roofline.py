"""Roofline terms from dry-run artifacts + analytic model FLOPs.

Hardware model (TPU v5e):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link

Terms per (arch x shape x mesh), all in seconds per step:

    compute    = HLO_FLOPs_per_device / peak
    memory     = HLO_bytes_per_device / HBM_bw
    collective = wire_bytes_per_device / link_bw

HLO quantities come from the structural analyzer (loop-aware; see
`repro.launch.hlo_analysis`). MODEL_FLOPS is the analytic 6*N*D (dense) /
6*N_active*D (MoE) + attention/SSD terms; the ratio MODEL_FLOPS/HLO_FLOPs
shows how much compiled compute is useful (remat and padding waste
included in the denominator by construction).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeSpec

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link


# ---------------------------------------------------------------------------
# analytic parameter counts and step FLOPs
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> dict:
    """Analytic parameter counts: total and active-per-token."""
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, g = cfg.n_heads, cfg.n_kv_heads
    attn = d * (h + 2 * g) * dh + h * dh * d
    if cfg.qkv_bias:
        attn += (h + 2 * g) * dh
    if cfg.n_experts:
        ffn_total = cfg.n_experts * 3 * d * cfg.d_ff + d * cfg.n_experts
        ffn_active = cfg.top_k * 3 * d * cfg.d_ff + d * cfg.n_experts
    elif cfg.d_ff:
        ffn_total = ffn_active = 3 * d * cfg.d_ff
    else:
        ffn_total = ffn_active = 0

    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.d_inner
        n = cfg.ssm_state
        conv_dim = d_in + 2 * n
        d_proj = 2 * d_in + 2 * n + cfg.ssm_heads
        ssm = d * d_proj + cfg.ssm_conv * conv_dim + d_in * d + d_in
        per_layer_total = per_layer_active = ssm
    else:
        per_layer_total = attn + ffn_total
        per_layer_active = attn + ffn_active

    total = cfg.n_layers * per_layer_total
    active = cfg.n_layers * per_layer_active
    if cfg.family == "hybrid":
        shared = attn + 3 * d * cfg.d_ff
        n_apps = cfg.n_layers // cfg.hybrid_attn_every
        total += shared                      # weight-shared: stored once
        active += shared * n_apps            # ...but applied n_apps times
    if cfg.is_encdec:
        enc = cfg.enc_layers * (attn + 2 * d * cfg.d_ff)
        total += enc
        active += enc
    embed = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return {"total": total, "active": active, "embed": embed,
            "unembed": cfg.vocab * d}


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Useful FLOPs of one step of this cell (fwd+bwd for train; fwd for
    prefill; one token for decode), standard 6ND/2ND conventions."""
    pc = param_counts(cfg)
    b, s = shape.global_batch, shape.seq_len
    d, dh, h = cfg.d_model, cfg.resolved_head_dim, cfg.n_heads

    def attn_core(tokens, kv_len, causal=True):
        # score + PV matmuls, causal halves the work
        full = 4.0 * tokens * kv_len * h * dh
        return full / 2 if causal else full

    def ssd_core(tokens):
        hh, p, n, l = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, \
            cfg.ssm_chunk
        # intra-chunk quadratic + state build/apply per token
        return tokens * hh * (2.0 * l * (n + p) + 4.0 * n * p)

    if shape.kind == "train":
        tokens = b * s
        f = 6.0 * pc["active"] * tokens + 6.0 * pc["unembed"] * tokens
        if cfg.family in ("ssm", "hybrid"):
            f += 3.0 * cfg.n_layers * ssd_core(tokens)
            if cfg.family == "hybrid":
                n_apps = cfg.n_layers // cfg.hybrid_attn_every
                f += 3.0 * n_apps * attn_core(tokens, s)
        else:
            win = cfg.swa_window or s
            f += 3.0 * cfg.n_layers * attn_core(tokens, min(s, win))
        if cfg.is_encdec:
            f += 3.0 * cfg.enc_layers * attn_core(b * cfg.enc_seq,
                                                  cfg.enc_seq, causal=False)
        return f

    if shape.kind == "prefill":
        tokens = b * s
        f = 2.0 * (pc["active"] + pc["unembed"] / s) * tokens
        if cfg.family in ("ssm", "hybrid"):
            f += cfg.n_layers * ssd_core(tokens)
            if cfg.family == "hybrid":
                f += (cfg.n_layers // cfg.hybrid_attn_every) \
                    * attn_core(tokens, s)
        else:
            win = cfg.swa_window or s
            f += cfg.n_layers * attn_core(tokens, min(s, win))
        return f

    # decode: one new token against a cache of length s
    f = 2.0 * (pc["active"] + pc["unembed"]) * b
    if cfg.family in ("ssm", "hybrid"):
        hh, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        f += cfg.n_layers * b * hh * 6.0 * n * p
        if cfg.family == "hybrid":
            f += (cfg.n_layers // cfg.hybrid_attn_every) \
                * attn_core(b, s, causal=False)
    else:
        win = cfg.swa_window or s
        f += cfg.n_layers * attn_core(b, min(s, win), causal=False)
    if cfg.is_encdec:
        f += cfg.n_layers * attn_core(b, cfg.enc_seq, causal=False)
    return f


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float
    hlo_flops_global: float
    useful_frac: float
    fits: bool
    peak_gib: float

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline-limited step time."""
        return self.compute_s * self.useful_frac / max(self.step_s, 1e-30)


def roofline_from_record(rec: dict, cfg: ModelConfig,
                         shape: ShapeSpec) -> RooflineRow:
    n_dev = 1
    for v in rec["mesh_shape"].values():
        n_dev *= v
    hlo = rec["hlo"]
    compute = hlo["flops"] / PEAK_FLOPS
    memory = hlo["bytes_accessed"] / HBM_BW
    coll = hlo["total_collective_wire"] / LINK_BW
    bound = max((compute, "compute"), (memory, "memory"),
                (coll, "collective"))[1]
    mf = model_flops(cfg, shape)
    hlo_global = hlo["flops"] * n_dev
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute, memory_s=memory, collective_s=coll,
        bound=bound, model_flops=mf, hlo_flops_global=hlo_global,
        useful_frac=mf / max(hlo_global, 1e-30),
        fits=rec.get("fits_tpu_est", rec.get("fits", False)),
        peak_gib=rec["memory"]["peak_bytes_tpu_est"] / 2 ** 30
        if "peak_bytes_tpu_est" in rec["memory"]
        else rec["memory"]["peak_bytes"] / 2 ** 30)
