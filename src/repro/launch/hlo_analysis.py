"""Structural analysis of compiled (post-SPMD) HLO text.

Why this exists: `compiled.cost_analysis()` visits each instruction once —
a `lax.scan` body (layers, attention KV chunks, SSD chunks, loss chunks)
is counted a single time regardless of trip count, which under-reports a
48-layer model's FLOPs by ~48x. This module re-derives the roofline terms
*structurally*: it parses the HLO module into computations, walks the call
graph (fusions, while bodies, conditionals) with multiplicities — a while
body's multiplicity is its trip count, recovered from the loop-condition
comparison constant — and accumulates:

  flops             2*M*N*K for dots (+ elementwise/reduce at 1 flop/elem)
  bytes             per-kernel HBM traffic: operands + results of every
                    top-level (non-fusion-internal) instruction; dynamic
                    slices (incl. inside fusions) charge the slice, not the
                    sliced operand — otherwise a scan over stacked layer
                    weights would count the whole stack every iteration
  collectives       per-kind operand bytes and estimated on-wire bytes
                    (ring terms: all-reduce 2(g-1)/g, all-gather /
                    reduce-scatter (g-1)/g of payload), with replica-group
                    sizes parsed per op

All quantities are per-device (the module is the SPMD program one device
runs). Validated against analytic FLOP counts in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m3": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"([\w\-]+)\((.*)$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls=|body=|condition=|to_apply=)%([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

# elementwise-ish ops counted at 1 flop per output element
_EW_OPS = frozenset("""
add subtract multiply divide maximum minimum power remainder and or xor not
negate abs sign exponential exponential-minus-one log log-plus-one sqrt
rsqrt cbrt tanh sine cosine tan atan2 erf logistic floor ceil round-nearest-afz
round-nearest-even compare select clamp convert is-finite shift-left
shift-right-arithmetic shift-right-logical popcnt clz
""".split())


def raw_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions (older
    releases return a per-device list, newer ones a plain dict)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _shape_bytes(type_str: str, f32_as: float = 4.0) -> float:
    """Total bytes of a (possibly tuple) HLO type string.

    ``f32_as``: bytes charged per f32 element. The XLA *CPU* backend
    float-normalises bf16 arithmetic to f32, so activation tensors that
    would be bf16 on TPU appear as f32 in the compiled module; passing
    f32_as=2.0 restores TPU-dtype accounting for bf16 models (params that
    stay bf16 in the module are counted at 2 B/elem either way; genuinely-
    f32 tensors — loss scalars, SSD states, norm internals — are small).
    """
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * (f32_as if dt == "f32" else _DTYPE_BYTES[dt])
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _result_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str                       # operand list + attributes (raw text)
    operands: list[str]             # %refs into the same computation


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    is_entry: bool = False

    def by_name(self) -> dict[str, Instr]:
        return {i.name: i for i in self.instrs}


_OPERAND_REF_RE = re.compile(r"%([\w\.\-]+)")


def _parse_instr_line(line: str) -> Optional[Instr]:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    # result type: balanced parens for tuple types (they may contain
    # /*index=N*/ comments); up to the first space otherwise
    if rest.startswith("("):
        depth, end = 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        type_str, tail = rest[:end], rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp + 1:].lstrip()
    m = _OPCODE_RE.match(tail)
    if not m:
        return None
    opcode, args = m.groups()
    op_part = args.split("), ")[0] if "), " in args else args
    operands = _OPERAND_REF_RE.findall(op_part)
    return Instr(name, type_str.strip(), opcode, args, operands)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{",
                         line)
            if m:
                cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr_line(line)
        if ins is not None:
            cur.instrs.append(ins)
    return comps


def _trip_count(cond: Computation, comps: dict[str, Computation]) -> int:
    """Trip count of a while loop: the largest integer constant reachable
    in its condition computation (jax scans compare the induction variable
    against the static length)."""
    best = 1
    stack, seen = [cond.name], set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        for ins in comps[cname].instrs:
            if ins.opcode == "constant":
                cm = re.match(r"(\d+)\)", ins.rest)
                if cm:
                    best = max(best, int(cm.group(1)))
            for c in _CONST_RE.findall(ins.rest):
                best = max(best, int(c))
            for ref in _CALL_ATTR_RE.findall(ins.rest):
                stack.append(ref)
    return best


def _call_multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution count of every computation, walking from ENTRY."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:                            # fallback: last computation
        entry = list(comps.values())[-1]
    mult: dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    # topological-ish: process repeatedly until stable (call graph is a DAG)
    for _ in range(64):
        changed = False
        new = {c: 0.0 for c in comps}
        new[entry.name] = 1.0
        for cname, comp in comps.items():
            m = mult[cname]
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.opcode == "while":
                    body = re.search(r"body=%([\w\.\-]+)", ins.rest)
                    cond = re.search(r"condition=%([\w\.\-]+)", ins.rest)
                    if body and cond and cond.group(1) in comps:
                        trips = _trip_count(comps[cond.group(1)], comps)
                        new[body.group(1)] = new.get(body.group(1), 0.0) \
                            + m * trips
                        new[cond.group(1)] = new.get(cond.group(1), 0.0) \
                            + m * (trips + 1)
                    continue
                bm = _BRANCH_RE.search(ins.rest)
                if bm:
                    for ref in _OPERAND_REF_RE.findall(bm.group(1)):
                        new[ref] = new.get(ref, 0.0) + m  # upper bound
                    continue
                for ref in _CALL_ATTR_RE.findall(ins.rest):
                    if ref in comps:
                        new[ref] = new.get(ref, 0.0) + m
        if new == mult:
            break
        mult = new
        changed = True
    return mult


def _dot_flops(ins: Instr, table: dict[str, Instr]) -> float:
    out = 1
    for d in _result_dims(ins.type_str):
        out *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not m:
        return 2.0 * out
    lhs = table.get(ins.operands[0]) if ins.operands else None
    if lhs is None:
        return 2.0 * out
    ldims = _result_dims(lhs.type_str)
    k = 1
    if m.group(1):
        for d in m.group(1).split(","):
            k *= ldims[int(d)] if int(d) < len(ldims) else 1
    return 2.0 * out * k


_SKIP_BYTES_OPS = frozenset(
    ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
     "after-all", "iota", "while", "conditional", "custom-call"))


def _fusion_bytes(ins: Instr, table: dict[str, Instr],
                  comps: dict[str, Computation],
                  f32_as: float = 4.0) -> float:
    """Fusion HBM traffic: result + operands; an operand consumed *only*
    through dynamic-slice inside the fusion charges the slice size."""
    total = _shape_bytes(ins.type_str, f32_as)
    callee = None
    m = re.search(r"calls=%([\w\.\-]+)", ins.rest)
    if m and m.group(1) in comps:
        callee = comps[m.group(1)]
    sliced_params: dict[int, float] = {}
    if callee is not None:
        params: dict[str, int] = {}
        uses: dict[str, list[Instr]] = {}
        for cin in callee.instrs:
            if cin.opcode == "parameter":
                pm = re.match(r"(\d+)", cin.rest)
                if pm:
                    params[cin.name] = int(pm.group(1))
            for op in cin.operands:
                uses.setdefault(op, []).append(cin)
        for pname, pidx in params.items():
            us = uses.get(pname, [])
            if us and all(u.opcode == "dynamic-slice" and
                          u.operands and u.operands[0] == pname
                          for u in us):
                sliced_params[pidx] = sum(_shape_bytes(u.type_str, f32_as)
                                          for u in us)
    for i, op in enumerate(ins.operands):
        src = table.get(op)
        if src is None:
            continue
        if i in sliced_params:
            total += sliced_params[i]
        else:
            total += _shape_bytes(src.type_str, f32_as)
    return total


@dataclasses.dataclass
class HLOReport:
    flops: float = 0.0                       # per device
    bytes_accessed: float = 0.0              # per device (HBM estimate)
    collective_payload: dict = dataclasses.field(default_factory=dict)
    collective_wire: dict = dataclasses.field(default_factory=dict)
    collective_count: dict = dataclasses.field(default_factory=dict)

    @property
    def total_collective_payload(self) -> float:
        return sum(self.collective_payload.values())

    @property
    def total_collective_wire(self) -> float:
        return sum(self.collective_wire.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_payload_bytes": dict(self.collective_payload),
            "collective_wire_bytes": dict(self.collective_wire),
            "collective_counts": dict(self.collective_count),
            "total_collective_payload": self.total_collective_payload,
            "total_collective_wire": self.total_collective_wire,
        }


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return default


_WIRE_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,      # applied to the *result*
    "reduce-scatter": lambda g: (g - 1) / g,  # applied to the operand
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def analyze(text: str, n_devices: int = 1,
            f32_as: float = 4.0) -> HLOReport:
    comps = parse_module(text)
    mult = _call_multiplicities(comps)
    rep = HLOReport()
    # computations reachable only as fusion callees contribute flops with
    # their own multiplicity; bytes are charged at the fusion *call site*.
    fusion_callees = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", ins.rest)
                if m:
                    fusion_callees.add(m.group(1))

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        table = comp.by_name()
        in_fusion = comp.name in fusion_callees
        for ins in comp.instrs:
            # ---- flops (everywhere, incl. fusion bodies) ----------------
            if ins.opcode == "dot":
                rep.flops += m * _dot_flops(ins, table)
            elif ins.opcode == "convolution":
                out = _shape_elems(ins.type_str)
                rep.flops += m * 2.0 * out      # stub frontends: negligible
            elif ins.opcode in _EW_OPS:
                rep.flops += m * _shape_elems(ins.type_str)
            elif ins.opcode in ("reduce", "reduce-window"):
                src = table.get(ins.operands[0]) if ins.operands else None
                rep.flops += m * (_shape_elems(src.type_str) if src else 0)
            # ---- bytes (top-level instructions only) --------------------
            if not in_fusion and ins.opcode not in _SKIP_BYTES_OPS:
                if ins.opcode == "fusion":
                    rep.bytes_accessed += m * _fusion_bytes(ins, table,
                                                            comps, f32_as)
                elif ins.opcode in ("dynamic-slice", "gather"):
                    rep.bytes_accessed += m * 2 * _shape_bytes(
                        ins.type_str, f32_as)
                elif ins.opcode == "dynamic-update-slice":
                    upd = (table.get(ins.operands[1])
                           if len(ins.operands) > 1 else None)
                    rep.bytes_accessed += m * 2 * (
                        _shape_bytes(upd.type_str, f32_as) if upd else 0.0)
                else:
                    total = _shape_bytes(ins.type_str, f32_as)
                    for op in ins.operands:
                        src = table.get(op)
                        if src is not None and src.opcode not in (
                                "constant",):
                            total += _shape_bytes(src.type_str, f32_as)
                    rep.bytes_accessed += m * total
            # ---- collectives --------------------------------------------
            if ins.opcode in COLLECTIVE_OPS or (
                    ins.opcode.endswith("-start")
                    and ins.opcode[:-6] in COLLECTIVE_OPS):
                kind = (ins.opcode[:-6] if ins.opcode.endswith("-start")
                        else ins.opcode)
                g = _group_size(ins.rest, n_devices)
                if kind == "all-gather":
                    payload = _shape_bytes(ins.type_str, f32_as)  # result
                else:
                    payload = 0.0
                    for op in ins.operands:
                        src = table.get(op)
                        if src is not None:
                            payload += _shape_bytes(src.type_str, f32_as)
                    if payload == 0.0:
                        payload = _shape_bytes(ins.type_str, f32_as)
                wire = payload * _WIRE_FACTOR[kind](max(g, 2))
                rep.collective_payload[kind] = \
                    rep.collective_payload.get(kind, 0.0) + m * payload
                rep.collective_wire[kind] = \
                    rep.collective_wire.get(kind, 0.0) + m * wire
                rep.collective_count[kind] = \
                    rep.collective_count.get(kind, 0) + m
    return rep
