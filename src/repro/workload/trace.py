"""Seeded request-arrival traces and their conversion to MW of demand.

The arrival process is doubly-stochastic Poisson: a deterministic
diurnal base rate (cosine day shape peaking at ``peak_hour``) scaled by
one Gamma-distributed mixing draw per demand scenario (mean 1, variance
``overdispersion`` — the burstiness knob), then Poisson-sampled per
hour. ``sample_requests`` returns ``[n_draws, T]`` hourly request
volumes; every draw is an equally-likely realisation of the same
million-user service, and the fleet engines score each scenario row
against *all* draws so CPC becomes a distribution, not a point.

Requests become MW through the serving stack's own throughput
accounting: one engine serves ``tokens_per_engine_hour`` tokens per
hour (``ServeConfig.slots / hours_per_tick`` — the tick accounting of
`repro.serving.engine` — via `Workload.from_serving`, or the roofline
decode rate of a real model config via `Workload.from_roofline`) and
draws ``engine_power_mw`` while doing it, so

    MW_t = requests_t * tokens_per_request
           / tokens_per_engine_hour * engine_power_mw.

A `Workload` is a frozen, hashable spec — valid as a jit-static
argument and inside `repro.tune.TuneConfig` — and the single object
`ScenarioGrid` / `DispatchConfig` / `TuneConfig` / `live_fleet_dispatch`
accept. Deferral and drop pricing (`deadline_h`, `queue_bound_mwh`,
``slo_penalty_eur_mwh``, `repro.dispatch.Relief` VoLL) parameterise the
work ledger in `repro.workload.queue` / `repro.kernels.queue_scan`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.dispatch.allocate import Relief

_HOURS_PER_DAY = 24.0


@dataclasses.dataclass(frozen=True)
class Workload:
    """Spec of a stochastic request workload and its SLO economics.

    Defaults describe a small interactive-inference service whose mean
    demand (~0.9 MW) is on the scale of one fleet row's rating: 2 req/s
    of 256-token requests against engines serving 1M tokens/hour at
    0.5 MW. All fields are scalars, so the spec is hashable (jit-static,
    `TuneConfig`-compatible).
    """

    # arrival process
    base_rps: float = 2.0          # mean arrival rate, requests/second
    diurnal_amp: float = 0.6       # relative amplitude of the day cycle
    peak_hour: float = 17.0        # local hour of peak demand
    overdispersion: float = 0.25   # variance of the per-draw Gamma mixer
    n_draws: int = 32              # demand scenarios sampled per run
    seed: int = 0
    # request -> MW conversion (serving-stack throughput accounting)
    tokens_per_request: float = 256.0
    tokens_per_engine_hour: float = 1.0e6
    engine_power_mw: float = 0.5
    # queue / SLO economics
    deadline_h: int = 4            # extra hours work may wait, then drops
    queue_bound_mwh: float = 4.0   # backlog cap; overflow drops youngest
    slo_penalty_eur_mwh: float = 40.0   # per MWh-hour of deferred backlog
    relief: Relief = Relief()      # VoLL pricing of dropped work

    def __post_init__(self):
        if self.base_rps < 0 or self.overdispersion < 0:
            raise ValueError("Workload: base_rps and overdispersion "
                             "must be non-negative")
        if self.n_draws < 1:
            raise ValueError("Workload: n_draws must be >= 1")
        if self.deadline_h < 0 or self.queue_bound_mwh < 0:
            raise ValueError("Workload: deadline_h and queue_bound_mwh "
                             "must be non-negative")
        if self.tokens_per_engine_hour <= 0 or self.tokens_per_request < 0:
            raise ValueError("Workload: token throughput/size must be "
                             "positive")

    # -- conversion ---------------------------------------------------

    @property
    def mw_per_request_hour(self) -> float:
        """MW of engines needed to serve one request per hour."""
        return (self.tokens_per_request / self.tokens_per_engine_hour
                * self.engine_power_mw)

    def requests_to_mw(self, requests_per_hour):
        """Hourly request volumes -> MW of compute demand."""
        return np.asarray(requests_per_hour, np.float64) \
            * self.mw_per_request_hour

    # -- arrival process ----------------------------------------------

    def arrival_rate(self, t: int, demand_mult=None) -> np.ndarray:
        """Expected requests per hour, [T] — the diurnal intensity.

        ``demand_mult`` ([T], e.g. `repro.faults.FaultMasks.demand_mult`
        from a ``demand_surge`` schedule) scales the intensity itself,
        so surges perturb the *arrival process*, not a finished profile.
        """
        h = np.arange(int(t), dtype=np.float64) % _HOURS_PER_DAY
        shape = 1.0 + self.diurnal_amp * np.cos(
            2.0 * np.pi * (h - self.peak_hour) / _HOURS_PER_DAY)
        lam = self.base_rps * 3600.0 * np.maximum(shape, 0.0)
        if demand_mult is not None:
            lam = lam * np.asarray(demand_mult, np.float64)
        return lam

    def sample_requests(self, t: int, demand_mult=None) -> np.ndarray:
        """``[n_draws, T]`` hourly request counts, seeded.

        Doubly-stochastic: one Gamma(1/od, od) mixing draw per scenario
        (mean 1, variance ``overdispersion``) multiplies the whole
        diurnal intensity, then each hour is Poisson — bursty days, not
        just bursty hours.
        """
        rng = np.random.default_rng(self.seed)
        lam = self.arrival_rate(t, demand_mult)
        if self.overdispersion > 0:
            k = 1.0 / self.overdispersion
            mix = rng.gamma(k, 1.0 / k, size=(self.n_draws, 1))
        else:
            mix = np.ones((self.n_draws, 1))
        return rng.poisson(mix * lam[None, :]).astype(np.float64)

    def mean_demand_mw(self, t: int, demand_mult=None) -> np.ndarray:
        """Deterministic expected demand profile, [T] MW.

        The duck-typed hook `repro.dispatch.resolve_demand`,
        `soft_objective` and `live_fleet_dispatch` consume: E[mix] = 1,
        so this is the arrival intensity through the MW conversion.
        """
        return self.requests_to_mw(self.arrival_rate(t, demand_mult))

    def sample_demand_mw(self, t: int, demand_mult=None) -> np.ndarray:
        """``[n_draws, T]`` MW demand draws (requests through the
        serving-throughput conversion)."""
        return self.requests_to_mw(self.sample_requests(t, demand_mult))

    # -- constructors from the serving/launch stacks ------------------

    @classmethod
    def from_serving(cls, serve_cfg, **overrides) -> "Workload":
        """Derive the MW conversion from a `repro.serving.ServeConfig`:
        one engine decodes ``slots`` tokens per ``hours_per_tick`` at
        ``power_mw`` — the exact tick accounting `ServingEngine.run`
        meters."""
        overrides.setdefault(
            "tokens_per_engine_hour",
            float(serve_cfg.slots) / float(serve_cfg.hours_per_tick))
        overrides.setdefault("engine_power_mw", float(serve_cfg.power_mw))
        return cls(**overrides)

    @classmethod
    def from_roofline(cls, model_cfg, *, batch: int = 128,
                      seq_len: int = 32_768, mfu: float = 0.4,
                      **overrides) -> "Workload":
        """Derive the MW conversion from a model's analytic decode rate:
        ``batch`` sequences decoding against a ``seq_len`` cache at
        ``mfu`` of `repro.launch.roofline.PEAK_FLOPS` on one chip."""
        from repro.configs.base import ShapeSpec
        from repro.launch.roofline import PEAK_FLOPS, model_flops

        shape = ShapeSpec("workload_decode", seq_len, batch, "decode")
        flops_per_step = model_flops(model_cfg, shape)  # batch tokens
        tokens_per_s = batch * PEAK_FLOPS * mfu / flops_per_step
        overrides.setdefault("tokens_per_engine_hour",
                             tokens_per_s * 3600.0)
        return cls(**overrides)
