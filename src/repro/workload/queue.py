"""Pure-numpy work-ledger oracle: the ground truth the scans replay.

One hour of the ledger, in plain sequential prose (no cumsum idiom, no
vectorised clip — deliberately a *third* implementation, independent of
both `repro.kernels.queue_scan.queue_scan` and the jnp oracle
`repro.kernels.ref.queue_scan_ref`):

  1. line up the waiting work oldest-first, arrivals last;
  2. serve greedily oldest-first until this hour's capacity is spent;
  3. work that has now waited past ``deadline`` hours drops (deadline
     expiry);
  4. survivors age one hour and re-queue oldest-first while the backlog
     bound has room — overflow drops youngest-first (the work most
     likely to still be retried upstream).

Every MWh is conserved by construction: arrivals + carried-in backlog
== served + dropped + carried-out backlog, hour by hour — the invariant
`tests/test_workload.py` pins exactly (integer-valued work in f64 makes
every sum exact) and property-tests under random specs.

Used directly by `live_fleet_dispatch` for the post-hoc workload replay
of a committed live allocation (hours x draws is tiny there), and by
tests as the replay oracle for the in-scan kernels.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class LedgerReplay(NamedTuple):
    """Per-hour ledger series of one arrival trace ([T] each)."""

    served: np.ndarray    # MWh served this hour
    dropped: np.ndarray   # MWh dropped (deadline expiry + overflow)
    backlog: np.ndarray   # MWh still queued at end of hour
    q_final: np.ndarray   # [deadline] end-of-run queue, youngest first


def replay_ledger(arrivals, cap, *, deadline: int,
                  bound: float) -> LedgerReplay:
    """Replay the hard hour-granularity work ledger for one trace.

    ``arrivals`` and ``cap`` are [T] MWh per hour (``cap`` broadcasts
    from a scalar). ``deadline`` is the number of *extra* hours work may
    wait after its arrival hour (0 = serve-or-drop the same hour);
    ``bound`` caps the carried backlog in MWh.
    """
    a = np.asarray(arrivals, np.float64)
    if a.ndim != 1:
        raise ValueError("replay_ledger replays ONE [T] trace (got "
                         f"shape {a.shape}); loop rows/draws, or use "
                         "queue_scan for batched traces")
    c = np.broadcast_to(np.asarray(cap, np.float64), a.shape)
    d = int(deadline)
    # q[i] has waited i+1 hours; q[d-1] is one hour from expiry
    q = [0.0] * d
    served = np.zeros(a.shape, np.float64)
    dropped = np.zeros(a.shape, np.float64)
    backlog = np.zeros(a.shape, np.float64)
    for t in range(a.shape[0]):
        work = [q[d - 1 - i] for i in range(d)] + [a[t]]  # oldest first
        rem = c[t]
        unserved = []
        for w in work:
            s = min(rem, w)
            rem -= s
            served[t] += s
            unserved.append(w - s)
        dropped[t] = unserved[0]          # waited past the deadline
        q = []
        kept = 0.0
        for w in unserved[1:]:            # oldest survivor first
            keep = min(w, max(bound - kept, 0.0))
            kept += keep
            dropped[t] += w - keep        # overflow drops youngest
            q.append(keep)
        q = q[::-1]                       # back to youngest-first
        backlog[t] = kept
    return LedgerReplay(served, dropped, backlog,
                        np.asarray(q, np.float64))


def ledger_cost(replay: LedgerReplay, *, slo_penalty_eur_mwh: float,
                voll_eur_mwh: float) -> dict:
    """SLO economics of a replay: deferral priced per MWh-hour of
    carried backlog (on top of the energy actually paid when the work is
    finally served — that part rides the fleet's own bill), drops at the
    VoLL rate of `repro.dispatch.Relief`."""
    deferred = float(np.sum(replay.backlog))
    dropped = float(np.sum(replay.dropped))
    return {
        "served_mwh": float(np.sum(replay.served)),
        "deferred_mwh_h": deferred,
        "dropped_mwh": dropped,
        "defer_cost": slo_penalty_eur_mwh * deferred,
        "drop_cost": voll_eur_mwh * dropped,
    }
