"""Workload-coupled fleet backtest: CPC as a distribution over demand.

`workload_backtest` is `repro.fleet.backtest` with the work ledger
riding the scan carry: every scenario row serves all ``n_draws`` demand
draws with its hour-by-hour *realised* capacity, so a shutdown decision
defers real work into the bounded queue (priced at the SLO penalty per
MWh-hour, plus the energy price eventually paid when it is served) or
drops it (priced at the `repro.dispatch.Relief` VoLL rate). The result
carries the plain `FleetReport` — bit-identical to the exogenous
program, the ledger feeds nothing back — plus a `WorkloadResult` with
served/deferred/dropped totals and CPC p10/p50/p90 over the draws, all
from one jitted program.

Zero-workload configs short-circuit to the plain ``backtest`` program
at zero overhead, exactly like `repro.faults.faulted_backtest`
(``_force_coupled`` keeps the coupled program anyway; tests use it to
pin that the fleet half of the fused scan is a bitwise no-op).

A ``demand_surge`` fault schedule perturbs the *arrival intensity*
before sampling (`Workload.arrival_rate`), so surges reshape the
request process itself rather than scaling a finished profile.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.fleet.engine import backtest, fleet_costs
from repro.fleet.grid import ScenarioGrid
from repro.fleet.report import FleetReport
from repro.kernels.queue_scan import workload_fleet_scan
from repro.workload.trace import Workload

_SERVED_FLOOR_MWH = 1e-9   # CPC denominator floor (a draw a row never
                           # serves is priced per this epsilon, like the
                           # up-hours floor in `fleet_costs`)


class WorkloadResult(NamedTuple):
    """Ledger economics of a workload-coupled backtest.

    Per-(row, draw) arrays are [B, G]; quantiles are per-row [B] over
    the G draws. ``cost`` is the full realized bill — fleet TCO (energy
    at true prices, restarts, fixed) + SLO deferral penalty + VoLL
    drops — and ``cpc`` prices it per *served* MWh.
    """

    served_mwh: jax.Array      # [B, G]
    dropped_mwh: jax.Array     # [B, G]
    deferred_mwh_h: jax.Array  # [B, G] MWh-hours of carried backlog
    served_cost: jax.Array     # [B, G] EUR at the hour each MWh served
    arrivals_mwh: jax.Array    # [B, G] total offered work
    cost: jax.Array            # [B, G] TCO + defer + drop EUR
    cpc: jax.Array             # [B, G] cost per served MWh
    cpc_p10: jax.Array         # [B]
    cpc_p50: jax.Array         # [B]
    cpc_p90: jax.Array         # [B]

    @property
    def n_draws(self) -> int:
        return int(self.served_mwh.shape[1])


class WorkloadBacktest(NamedTuple):
    """``report`` is the plain `FleetReport`; ``workload`` is None on
    the zero-workload short-circuit path."""

    report: FleetReport
    workload: Optional[WorkloadResult]


def _workload_stats(res, costs, demand_mw, dt, slo_rate, voll):
    """[B, G] ledger economics + per-row CPC quantiles from the fused
    scan output — shared by the backtest and the tuner's hard
    candidate-selection re-eval."""
    arrivals_mwh = dt[:, None] * jnp.sum(demand_mw, axis=1)[None, :]
    cost = costs.tco[:, None] + slo_rate * res.backlog \
        + voll * res.dropped
    cpc = cost / jnp.maximum(res.served, _SERVED_FLOOR_MWH)
    q = jnp.quantile(cpc, jnp.asarray([0.1, 0.5, 0.9], cpc.dtype),
                     axis=1)
    return WorkloadResult(
        served_mwh=res.served, dropped_mwh=res.dropped,
        deferred_mwh_h=res.backlog, served_cost=res.served_cost,
        arrivals_mwh=arrivals_mwh, cost=cost, cpc=cpc,
        cpc_p10=q[0], cpc_p50=q[1], cpc_p90=q[2])


@functools.partial(jax.jit, static_argnames=("deadline", "telemetry"))
def _workload_backtest_jit(prices, market_idx, system_idx, policy_idx,
                           fixed, power, period, p_on, p_off, off_level,
                           idle_frac, restart_energy_mwh, restart_time_h,
                           demand_mw, bound, slo_rate, voll, *,
                           deadline: int, telemetry: bool = False):
    """One jitted program mirroring `repro.fleet.engine._backtest_jit`
    (gather -> fused scan -> cost assembly in the same jit, so the
    bit-identity contract for the FleetReport holds program-for-program,
    exactly like `_faulted_backtest_jit`)."""
    t = prices.shape[1]
    p_rows = prices[market_idx]                       # [B, T] gather
    dt = period / t                                   # [B] hours/sample
    res = workload_fleet_scan(
        p_rows, p_on, p_off, off_level, idle_frac, power * dt,
        demand_mw, dt, deadline=deadline, bound=bound, hourly=telemetry)
    if telemetry:
        res, hourly = res
        obs.drain("workload.hourly", demand_mwh=hourly.demand_mwh,
                  served_mwh=hourly.served_mwh,
                  dropped_mwh=hourly.dropped_mwh,
                  backlog_mwh=hourly.backlog_mwh)
    price_sum = jnp.sum(prices, axis=1)[market_idx]   # [B] sum_t p_t
    costs = fleet_costs(res.fleet, price_sum=price_sum, fixed=fixed,
                        power=power, period=period,
                        restart_energy_mwh=restart_energy_mwh,
                        restart_time_h=restart_time_h, n_samples=t)
    report = FleetReport(
        cpc=costs.cpc, cpc_ao=costs.cpc_ao,
        cpc_reduction=1.0 - costs.cpc / costs.cpc_ao,
        tco=costs.tco, energy_cost=costs.energy_cost,
        restart_cost=costs.restart_cost,
        up_hours=costs.up_hours, n_starts=res.fleet.n_starts,
        x_realized=1.0 - res.fleet.up_units / t,
        market_idx=market_idx, system_idx=system_idx,
        policy_idx=policy_idx)
    return report, _workload_stats(res, costs, demand_mw, dt, slo_rate,
                                   voll)


def _demand_mult(grid: ScenarioGrid, faults) -> Optional[np.ndarray]:
    """Compile a fault schedule onto the grid shape and keep only the
    demand-surge channel (price/outage channels belong to
    `repro.faults.faulted_backtest` — pair the two for the supply
    side)."""
    if faults is None:
        return None
    from repro.faults.inject import emit_fault_events, resolve_masks
    masks = resolve_masks(faults, grid.n_rows,
                          int(grid.prices.shape[0]),
                          int(grid.prices.shape[1]))
    emit_fault_events(faults, masks, scope="workload")
    mult = np.asarray(masks.demand_mult, np.float64)
    return None if np.all(mult == 1.0) else mult


def workload_backtest(grid: ScenarioGrid,
                      workload: Optional[Workload] = None,
                      faults=None, *,
                      _force_coupled: bool = False) -> WorkloadBacktest:
    """Backtest ``grid`` against a stochastic request workload.

    ``workload`` defaults to ``grid.workload``; with neither set (and
    ``faults`` carrying no demand surge to apply), the call
    short-circuits to the plain ``backtest(grid, use_pallas=False)``
    program — bit-identical, zero overhead, no demand sampling
    (gated in benchmarks/bench_workload.py).
    """
    wl = workload if workload is not None \
        else getattr(grid, "workload", None)
    if wl is None and not _force_coupled:
        return WorkloadBacktest(backtest(grid, use_pallas=False), None)
    if wl is None:
        wl = Workload()
    t = int(grid.prices.shape[1])
    demand_mw = wl.sample_demand_mw(t, _demand_mult(grid, faults))
    telemetry = obs.enabled()
    report, result = _workload_backtest_jit(
        grid.prices, grid.market_idx, grid.system_idx, grid.policy_idx,
        grid.fixed, grid.power, grid.period, grid.p_on, grid.p_off,
        grid.off_level, grid.idle_frac, grid.restart_energy_mwh,
        grid.restart_time_h, jnp.asarray(demand_mw, jnp.float32),
        float(wl.queue_bound_mwh), float(wl.slo_penalty_eur_mwh),
        float(wl.relief.voll_eur_mwh), deadline=int(wl.deadline_h),
        telemetry=telemetry)
    if telemetry:
        obs.counter("workload.backtests").inc()
        served = float(jnp.mean(result.served_mwh))
        dropped = float(jnp.mean(result.dropped_mwh))
        obs.trace_event("workload.result", {
            "rows": int(grid.n_rows), "hours": t,
            "n_draws": result.n_draws,
            "served_mwh": served, "dropped_mwh": dropped,
            "deferred_mwh_h": float(jnp.mean(result.deferred_mwh_h)),
            "drop_frac": dropped / max(served + dropped, 1e-30),
            "cpc_p10_mean": float(jnp.mean(result.cpc_p10)),
            "cpc_p50_mean": float(jnp.mean(result.cpc_p50)),
            "cpc_p90_mean": float(jnp.mean(result.cpc_p90))})
    return WorkloadBacktest(report, result)


@functools.partial(jax.jit, static_argnames=("deadline",))
def _realized_cost_jit(prices, market_idx, fixed, power, period,
                       p_on, p_off, off_level, idle_frac,
                       restart_energy_mwh, restart_time_h, demand_mw,
                       bound, slo_rate, voll, *, deadline: int):
    t = prices.shape[1]
    p_rows = prices[market_idx]
    dt = period / t
    res = workload_fleet_scan(
        p_rows, p_on, p_off, off_level, idle_frac, power * dt,
        demand_mw, dt, deadline=deadline, bound=bound)
    price_sum = jnp.sum(prices, axis=1)[market_idx]
    costs = fleet_costs(res.fleet, price_sum=price_sum, fixed=fixed,
                        power=power, period=period,
                        restart_energy_mwh=restart_energy_mwh,
                        restart_time_h=restart_time_h, n_samples=t)
    cost = costs.tco[:, None] + slo_rate * res.backlog \
        + voll * res.dropped
    return jnp.mean(cost, axis=1)                     # [B] EUR


def realized_cost(grid: ScenarioGrid, p_on, p_off, off_level,
                  workload: Workload,
                  demand_mw: Optional[np.ndarray] = None) -> jax.Array:
    """Mean-over-draws realized workload cost (energy + deferral +
    drop), [B] EUR, of candidate policies ``(p_on, p_off, off_level)``
    on ``grid``'s markets/systems. The hard yardstick
    `repro.tune.optimize` selects candidates by when a workload is
    configured — sample ``demand_mw`` once and share it across
    candidates so the comparison is paired."""
    if demand_mw is None:
        demand_mw = workload.sample_demand_mw(int(grid.prices.shape[1]))
    return _realized_cost_jit(
        grid.prices, grid.market_idx, grid.fixed, grid.power,
        grid.period, p_on, p_off, off_level, grid.idle_frac,
        grid.restart_energy_mwh, grid.restart_time_h,
        jnp.asarray(demand_mw, jnp.float32),
        float(workload.queue_bound_mwh),
        float(workload.slo_penalty_eur_mwh),
        float(workload.relief.voll_eur_mwh),
        deadline=int(workload.deadline_h))
