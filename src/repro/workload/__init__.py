"""Workload-coupled demand: request traces, the work ledger, and the
coupled backtest. See `repro.workload.trace` for the arrival model and
`repro.workload.backtest` for the coupled program."""

from repro.workload.backtest import (WorkloadBacktest, WorkloadResult,
                                     realized_cost, workload_backtest)
from repro.workload.queue import (LedgerReplay, ledger_cost,
                                  replay_ledger)
from repro.workload.trace import Workload

__all__ = [
    "LedgerReplay",
    "Workload",
    "WorkloadBacktest",
    "WorkloadResult",
    "ledger_cost",
    "realized_cost",
    "replay_ledger",
    "workload_backtest",
]
