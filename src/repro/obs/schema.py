"""Trace event schema: one registry of every event kind the subsystem
emits, with the fields a consumer may rely on.

The schema is additive-versioned: bump `SCHEMA_VERSION` (re-exported
from `registry`) only when an existing field changes meaning; adding
kinds or optional fields is free. `repro.obs.report` treats unknown
kinds as opaque, so older readers survive newer traces.
"""

from __future__ import annotations

from .registry import SCHEMA_VERSION  # noqa: F401  (single source of truth)

# kind -> (emitter, required payload fields). Fields not listed here may
# appear but are not contractual. All array fields are JSON lists in
# hour order (index == hour within the instrumented call).
EVENT_KINDS: dict[str, tuple[str, tuple[str, ...]]] = {
    # lifecycle -----------------------------------------------------------
    "run.meta": ("registry.Run", (
        "run_id", "schema_version", "git_sha", "jax", "jaxlib", "backend",
        "device_kind", "timestamp")),
    "run.close": ("registry.Run", ("n_events", "metrics")),
    # tuning --------------------------------------------------------------
    "tune.step": ("tune.optimizer.optimize", (
        "step", "loss", "tau", "penalty")),            # + grad_norm/clip_frac
    "tune.stage": ("tune.optimizer.optimize", (
        "stage", "through_step", "cpc_hard_mean")),
    "tune.result": ("tune.optimizer.optimize", (
        "rows", "steps", "cpc_tuned_mean", "cpc_swept_best_mean",
        "improvement_vs_best_mean", "source_counts")),
    # fleet backtest ------------------------------------------------------
    "fleet.hourly": ("fleet.engine._backtest_jit (io_callback drain)", (
        "on_mw", "draw_price", "starts", "stops")),    # [T] each
    "fleet.backtest": ("fleet.engine.backtest", (
        "rows", "hours", "use_pallas", "n_starts_total")),
    "fleet.summary": ("fleet.report.summarize", (
        "total_cost", "best_reduction", "top_regret")),
    # dispatch ------------------------------------------------------------
    "dispatch.hourly": ("dispatch.allocate.summarize_alloc", (
        "delivered_mwh", "energy_cost", "moved_mw", "slack_capacity_mw",
        "demand_mw", "move_tol", "fixed_cost", "migrate_cost")),
    "dispatch.result": ("dispatch.allocate.summarize_alloc", (
        "cpc", "energy_cost", "migration_cost", "migration_mw",
        "n_migrations", "delivered_mwh", "slack_power_mw",
        "slack_capacity_mw", "slack_floor_mwh", "near_infeasible_hours")),
    "dispatch.infeasible": ("dispatch.allocate._check_feasible", (
        "reason",)),
    # live operator -------------------------------------------------------
    "live.step": ("live.controller._live_scan (io_callback drain)", (
        "on_mw", "cost_rate", "transitions", "abs_err1", "commits")),
    "live.result": ("live.report.summarize_live", (
        "rows", "hours", "cpc_mean", "regret_oracle_mean",
        "regret_offline_mean", "mae1_mean", "churn_total")),
    # workload ------------------------------------------------------------
    "workload.hourly": (
        "workload.backtest._workload_backtest_jit (io_callback drain)", (
            "demand_mwh", "served_mwh", "dropped_mwh", "backlog_mwh")),
    "workload.result": ("workload.backtest.workload_backtest", (
        "rows", "hours", "n_draws", "served_mwh", "dropped_mwh",
        "deferred_mwh_h", "drop_frac", "cpc_p10_mean", "cpc_p50_mean",
        "cpc_p90_mean")),
    # faults & degradation ------------------------------------------------
    "fault.injected": ("faults.inject.emit_fault_events", (
        "fault", "target", "start", "duration", "magnitude", "scope")),
    "dispatch.shed": ("dispatch.allocate.summarize_alloc", (
        "shed_mwh", "shed_cost", "n_shed_hours", "voll_eur_mwh")),
    "live.fallback": ("live.controller.live_backtest", (
        "fresh", "stale_shift", "seasonal_naive", "persistence",
        "forced_off_row_hours", "stale_price_row_hours")),
    "tune.guard": ("tune.optimizer.optimize", (
        "rejects_total", "steps_affected", "first_step", "rows")),
    # data loading --------------------------------------------------------
    "loader.skipped_rows": ("energy.smard._finalize", (
        "loader", "path", "n_rows", "n_parsed", "n_skipped", "n_nan",
        "skip_frac", "action")),
    # profiling -----------------------------------------------------------
    "profile.span": ("obs.profiling.profiled", ("label", "seconds")),
    "profile.trace": ("obs.profiling.xla_trace", ("label", "dir")),
    "profile.xla": ("obs.profiling.record_compiled", ("label",)),
    # benchmarks ----------------------------------------------------------
    "bench.artifact": ("benchmarks.common.write_artifact", (
        "name", "path")),
}


def validate(event: dict) -> list[str]:
    """Return a list of problems with one decoded trace line (empty ==
    valid). Unknown kinds are allowed; missing contractual fields are
    not."""
    problems = []
    kind = event.get("kind")
    if not kind:
        return ["event has no 'kind'"]
    if event.get("schema") != SCHEMA_VERSION:
        problems.append(
            f"schema {event.get('schema')!r} != {SCHEMA_VERSION}")
    spec = EVENT_KINDS.get(kind)
    if spec is not None:
        missing = [f for f in spec[1] if f not in event]
        if missing:
            problems.append(f"{kind}: missing fields {missing}")
    return problems
