"""Operator digest of a telemetry run: ``python -m repro.obs.report
<run-dir>`` renders ``trace.jsonl`` into text/markdown — CPC
trajectory, shutdown churn, slack minima, top-k regret rows — and
reconstructs the dispatch totals *from the trace alone* (bit-exact
against `repro.dispatch.DispatchResult`, because `summarize_alloc`
derives its totals from the same per-hour float64 aggregates the
``dispatch.hourly`` event carries; asserted in tests/test_obs.py).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Optional

import numpy as np

from .schema import SCHEMA_VERSION, validate


def load_events(run_dir) -> list:
    """Decode ``<run-dir>/trace.jsonl`` (list of dicts, file order)."""
    path = Path(run_dir) / "trace.jsonl"
    return [json.loads(line)
            for line in path.read_text(encoding="utf-8").splitlines()
            if line.strip()]


def load_metrics(run_dir) -> dict:
    path = Path(run_dir) / "metrics.json"
    if not path.exists():
        return {}
    return json.loads(path.read_text())


def reconstruct_dispatch(events: list) -> Optional[dict]:
    """Recompute the dispatch totals from the last ``dispatch.hourly``
    event — same float64 arrays, same summation order and the same
    closing expressions as `repro.dispatch.summarize_alloc`, so ``cpc``
    and ``n_migrations`` match the `DispatchResult` bit for bit."""
    hourly = [e for e in events if e.get("kind") == "dispatch.hourly"]
    if not hourly:
        return None
    e = hourly[-1]
    energy_t = np.asarray(e["energy_cost"], np.float64)
    delivered_t = np.asarray(e["delivered_mwh"], np.float64)
    moved = np.asarray(e["moved_mw"], np.float64)
    slack_t = np.asarray(e["slack_capacity_mw"], np.float64)
    energy_cost = float(energy_t.sum())
    migration_mw = float(moved.sum())
    migration_cost = e["migrate_cost"] * migration_mw
    delivered = float(delivered_t.sum())
    return {
        "cpc": (e["fixed_cost"] + energy_cost + migration_cost)
        / max(delivered, 1e-9),
        "energy_cost": energy_cost,
        "migration_cost": migration_cost,
        "migration_mw": migration_mw,
        "n_migrations": int((moved > e["move_tol"]).sum()),
        "delivered_mwh": delivered,
        "slack_capacity_mw": float(slack_t.min()),
        "hours": int(moved.shape[0]),
    }


def _fmt(v, sig: int = 4) -> str:
    """Significant-figure number rendering (stable across jax/platform
    ULP differences — what makes the golden-file test portable)."""
    if v is None:
        return "-"
    if isinstance(v, (int, np.integer)):
        return str(int(v))
    if not np.isfinite(v):
        return str(v)
    return f"{float(v):.{sig}g}"


def _section(out: list, title: str) -> None:
    out.append(f"\n## {title}\n")


def render_digest(run_dir, *, top_k: int = 5,
                  redact_meta: bool = False) -> str:
    """Markdown digest of one run directory. ``redact_meta`` replaces
    the volatile stamp fields (run id, sha, versions, timestamps) with
    ``<redacted>`` — used by the golden-file test, and handy for
    sharing traces."""
    events = load_events(run_dir)
    by_kind: dict = {}
    for e in events:
        by_kind.setdefault(e.get("kind"), []).append(e)

    out = ["# Telemetry run digest"]
    meta = by_kind.get("run.meta", [{}])[0]
    _section(out, "Run")
    volatile = ("run_id", "git_sha", "timestamp", "jax", "jaxlib",
                "python", "machine", "device_kind", "backend",
                "n_devices")
    for key in ("run_id", "schema_version", "git_sha", "jax", "jaxlib",
                "backend", "device_kind", "n_devices", "timestamp"):
        if key in meta:
            val = "<redacted>" if redact_meta and key in volatile \
                else meta[key]
            out.append(f"- {key}: {val}")
    out.append(f"- events: {len(events)} "
               f"({len(by_kind)} kinds)" if not redact_meta else
               "- events: <redacted>")

    # tuning ----------------------------------------------------------
    steps = by_kind.get("tune.step", [])
    stages = by_kind.get("tune.stage", [])
    results = by_kind.get("tune.result", [])
    if steps or results:
        _section(out, "Tuning")
        if results:
            r = results[-1]
            out.append(f"- rows: {r['rows']}  steps: {r['steps']}")
            out.append(f"- mean CPC: {_fmt(r.get('cpc_mean'))} "
                       f"(tuned {_fmt(r.get('cpc_tuned_mean'))}, best "
                       f"swept {_fmt(r.get('cpc_swept_best_mean'))})")
            out.append("- mean improvement vs best swept: "
                       f"{_fmt(r.get('improvement_vs_best_mean'), 3)}")
            src = r.get("source_counts", {})
            if src:
                out.append("- selection: " + ", ".join(
                    f"{k}={v}" for k, v in sorted(src.items())))
        if steps:
            first, last = steps[0], steps[-1]
            out.append(f"- soft loss: {_fmt(first['loss'])} -> "
                       f"{_fmt(last['loss'])} over {len(steps)} steps "
                       f"(tau {_fmt(first['tau'], 3)} -> "
                       f"{_fmt(last['tau'], 3)})")
            if "grad_norm" in first:
                out.append(f"- grad norm: {_fmt(first['grad_norm'], 3)} "
                           f"-> {_fmt(last['grad_norm'], 3)}; mean clip "
                           "fraction "
                           f"{_fmt(float(np.mean([s['clip_frac'] for s in steps])), 3)}")
        if stages:
            out.append("- hard-CPC anneal curve (per stage boundary):")
            for s in stages:
                out.append(f"  - stage {s['stage']} (through step "
                           f"{s['through_step']}): "
                           f"{_fmt(s['cpc_hard_mean'])}")

    # fleet backtests -------------------------------------------------
    backs = by_kind.get("fleet.backtest", [])
    hourly = by_kind.get("fleet.hourly", [])
    if backs or hourly:
        _section(out, "Fleet backtests")
        if backs:
            b = backs[-1]
            out.append(f"- calls: {len(backs)}; last: {b['rows']} rows x "
                       f"{b['hours']} h, mean CPC {_fmt(b['cpc_mean'])}, "
                       f"mean reduction {_fmt(b['reduction_mean'], 3)}")
        if hourly:
            h = hourly[-1]
            starts = np.asarray(h["starts"], np.float64)
            stops = np.asarray(h["stops"], np.float64)
            on = np.asarray(h["on_mw"], np.float64)
            churn = float(starts.sum() + stops.sum())
            out.append(f"- churn: {_fmt(churn)} transitions "
                       f"({_fmt(float(starts.sum()))} starts, peak hour "
                       f"{int((starts + stops).argmax())})")
            out.append(f"- fleet capacity online: min {_fmt(on.min())} "
                       f"MW, mean {_fmt(on.mean())} MW, max "
                       f"{_fmt(on.max())} MW")

    # workload --------------------------------------------------------
    wl_res = by_kind.get("workload.result", [])
    wl_hourly = by_kind.get("workload.hourly", [])
    if wl_res or wl_hourly:
        _section(out, "Workload")
        if wl_res:
            w = wl_res[-1]
            out.append(f"- coupled backtests: {len(wl_res)}; last: "
                       f"{w['rows']} rows x {w['hours']} h x "
                       f"{w['n_draws']} demand draws")
            out.append(f"- mean per (row, draw): served "
                       f"{_fmt(w['served_mwh'])} MWh, dropped "
                       f"{_fmt(w['dropped_mwh'])} MWh "
                       f"(drop fraction {_fmt(w['drop_frac'], 3)}), "
                       f"deferred {_fmt(w['deferred_mwh_h'])} MWh-h")
            out.append(f"- CPC over draws (row means): p10 "
                       f"{_fmt(w['cpc_p10_mean'])}, p50 "
                       f"{_fmt(w['cpc_p50_mean'])}, p90 "
                       f"{_fmt(w['cpc_p90_mean'])} EUR/MWh")
        if wl_hourly:
            h = wl_hourly[-1]
            dem = np.asarray(h["demand_mwh"], np.float64)
            srv = np.asarray(h["served_mwh"], np.float64)
            drp = np.asarray(h["dropped_mwh"], np.float64)
            bkl = np.asarray(h["backlog_mwh"], np.float64)
            out.append(f"- hourly (fleet means over {dem.shape[0]} h): "
                       f"offered {_fmt(dem.sum())} MWh, served "
                       f"{_fmt(srv.sum())} MWh, dropped "
                       f"{_fmt(drp.sum())} MWh")
            out.append(f"- backlog: peak {_fmt(bkl.max())} MWh (hour "
                       f"{int(bkl.argmax())}), mean {_fmt(bkl.mean())} "
                       "MWh")

    # dispatch --------------------------------------------------------
    recon = reconstruct_dispatch(events)
    disp = by_kind.get("dispatch.result", [])
    if recon or disp:
        _section(out, "Dispatch")
        if disp:
            d = disp[-1]
            out.append(f"- sites: {d.get('n_sites', '-')}; hours: "
                       f"{d.get('hours', '-')}")
            out.append(f"- CPC: {_fmt(d['cpc'])} (energy "
                       f"{_fmt(d['energy_cost'])}, migration "
                       f"{_fmt(d['migration_cost'])})")
            out.append(f"- moves: {d['n_migrations']} hours, "
                       f"{_fmt(d['migration_mw'])} MW total")
            out.append(f"- slack minima: capacity "
                       f"{_fmt(d['slack_capacity_mw'])} MW, power "
                       f"{_fmt(d['slack_power_mw'])} MW, floor "
                       f"{_fmt(d['slack_floor_mwh'])} MWh")
            out.append(f"- near-infeasible hours (< "
                       f"{_fmt(100 * d.get('near_frac', 0.05), 2)}% "
                       f"capacity slack): {d['near_infeasible_hours']}")
        if recon:
            out.append(f"- reconstructed from trace: CPC "
                       f"{_fmt(recon['cpc'])}, {recon['n_migrations']} "
                       "move hours"
                       + (" (matches emitted result exactly)"
                          if disp and recon["cpc"] == disp[-1]["cpc"]
                          and recon["n_migrations"]
                          == disp[-1]["n_migrations"] else ""))
    infeas = by_kind.get("dispatch.infeasible", [])
    if infeas:
        _section(out, "Dispatch infeasibilities")
        for e in infeas:
            out.append(f"- [{e.get('constraint', '?')}] {e['reason']}")

    # degradation (faults, shed, fallbacks, guard) --------------------
    faults = by_kind.get("fault.injected", [])
    sheds = by_kind.get("dispatch.shed", [])
    fallbacks = by_kind.get("live.fallback", [])
    guards = by_kind.get("tune.guard", [])
    if faults or sheds or fallbacks or guards:
        _section(out, "Degradation")
        if faults:
            per_kind: dict = {}
            for e in faults:
                per_kind.setdefault(e["fault"], []).append(e)
            scopes = sorted({e.get("scope", "?") for e in faults})
            out.append(f"- faults injected: {len(faults)} "
                       f"(scope: {', '.join(scopes)})")
            for kind, evs in sorted(per_kind.items()):
                hours = sum(int(e.get("duration", 0)) for e in evs)
                out.append(f"  - {kind}: {len(evs)} events, "
                           f"{hours} fault-hours")
        if sheds:
            s = sheds[-1]
            out.append(f"- load shed: {_fmt(s['shed_mwh'])} MWh over "
                       f"{s['n_shed_hours']} hours at VoLL "
                       f"{_fmt(s['voll_eur_mwh'])} EUR/MWh "
                       f"(cost {_fmt(s['shed_cost'])} EUR)")
        if fallbacks:
            f = fallbacks[-1]
            out.append(f"- forecast fallbacks: fresh {f['fresh']}, "
                       f"stale-shift {f['stale_shift']}, seasonal-naive "
                       f"{f['seasonal_naive']}, persistence "
                       f"{f['persistence']} row-hours")
            out.append(f"- forced-off row-hours: "
                       f"{f['forced_off_row_hours']}; stale-price "
                       f"row-hours: {f['stale_price_row_hours']}")
        if guards:
            g = guards[-1]
            out.append(f"- tuner guard: {g['rejects_total']} non-finite "
                       f"steps rejected across {g['steps_affected']} "
                       f"steps (first at step {g['first_step']}, "
                       f"{g['rows']} rows)")

    # live operation --------------------------------------------------
    live_res = by_kind.get("live.result", [])
    live_steps = by_kind.get("live.step", [])
    if live_res or live_steps:
        _section(out, "Live operation")
        if live_res:
            r = live_res[-1]
            out.append(f"- controllers: {r['rows']} rows x "
                       f"{r['hours']} h")
            out.append(f"- mean realized CPC: {_fmt(r['cpc_mean'])} "
                       f"(regret vs hindsight oracle "
                       f"{_fmt(r['regret_oracle_mean'], 3)}, vs offline "
                       f"{_fmt(r['regret_offline_mean'], 3)})")
            out.append(f"- one-step forecast MAE: {_fmt(r['mae1_mean'])} "
                       f"EUR/MWh; threshold churn: "
                       f"{_fmt(r['churn_total'])} commits")
            best = r.get("best")
            if best:
                out.append(f"- best design: {best['forecaster']} "
                           f"H={best['horizon']} cadence={best['cadence']} "
                           f"{best['family']} (CPC {_fmt(best['cpc'])})")
        if live_steps:
            h = live_steps[-1]
            on = np.asarray(h["on_mw"], np.float64)
            trans = np.asarray(h["transitions"], np.float64)
            err = np.asarray(h["abs_err1"], np.float64)
            out.append(f"- fleet capacity online: min {_fmt(on.min())} "
                       f"MW, mean {_fmt(on.mean())} MW over "
                       f"{on.shape[0]} hours")
            out.append(f"- transitions: {_fmt(float(trans.sum()))} "
                       f"(peak hour {int(trans.argmax())}); mean "
                       f"one-step |err|: {_fmt(float(err.mean()))}")

    # fleet summary / regret ------------------------------------------
    summaries = by_kind.get("fleet.summary", [])
    if summaries:
        s = summaries[-1]
        _section(out, f"Top-{top_k} regret rows")
        out.append(f"- fleet total cost: {_fmt(s['total_cost'])} EUR; "
                   f"up hours: {_fmt(s['total_up_hours'])}")
        rows = s.get("top_regret", [])[:top_k]
        if rows:
            out.append("")
            out.append("| market | system | policy | regret | reduction |")
            out.append("|---|---|---|---|---|")
            for r in rows:
                out.append(f"| {r['market']} | {r['system']} | "
                           f"{r['policy']} | {_fmt(r['regret'], 3)} | "
                           f"{_fmt(r['reduction'], 3)} |")

    # loaders ---------------------------------------------------------
    loads = by_kind.get("loader.skipped_rows", [])
    if loads:
        _section(out, "Data loading")
        for e in loads:
            path = Path(e["path"]).name if redact_meta else e["path"]
            filled = int(e.get("n_filled", 0) or 0)
            tail = f", {filled} gap-filled" if filled else ""
            out.append(f"- [{e['action']}] {e['loader']} {path}: "
                       f"{e['n_parsed']}/{e['n_rows']} rows parsed "
                       f"({e['n_skipped']} skipped, {e['n_nan']} empty"
                       f"{tail})")

    # profiling -------------------------------------------------------
    spans = by_kind.get("profile.span", [])
    xla = by_kind.get("profile.xla", [])
    if spans or xla:
        _section(out, "Profile")
        for e in spans:
            extra = {k: v for k, v in e.items()
                     if k not in ("schema", "kind", "ts", "seq", "label",
                                  "seconds")}
            tail = ("  (" + ", ".join(f"{k}={v}"
                                      for k, v in sorted(extra.items()))
                    + ")") if extra and not redact_meta else ""
            sec = "<redacted>" if redact_meta else _fmt(e["seconds"], 3)
            out.append(f"- span {e['label']}: {sec} s{tail}")
        for e in xla:
            parts = [f"{k}={_fmt(v)}" for k, v in sorted(e.items())
                     if k in ("flops", "bytes_accessed", "temp_bytes",
                              "output_bytes")]
            out.append(f"- xla {e['label']}: " + ", ".join(parts))

    return "\n".join(out) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a telemetry run directory into an operator "
        "digest (markdown).")
    ap.add_argument("run_dir", help="directory containing trace.jsonl")
    ap.add_argument("--top-k", type=int, default=5,
                    help="regret rows to show (default 5)")
    ap.add_argument("-o", "--output", default=None,
                    help="write the digest to this file instead of stdout")
    ap.add_argument("--redact-meta", action="store_true",
                    help="replace volatile stamp fields (ids, versions, "
                    "timings) — for diff-stable output")
    ap.add_argument("--validate", action="store_true",
                    help="also schema-check every trace line and report "
                    "problems")
    args = ap.parse_args(argv)

    digest = render_digest(args.run_dir, top_k=args.top_k,
                           redact_meta=args.redact_meta)
    rc = 0
    if args.validate:
        problems = [f"line {i}: {p}"
                    for i, e in enumerate(load_events(args.run_dir))
                    for p in validate(e)]
        if problems:
            digest += (f"\n## Schema problems (v{SCHEMA_VERSION})\n\n"
                       + "\n".join(f"- {p}" for p in problems) + "\n")
            rc = 1
    if args.output:
        Path(args.output).write_text(digest)
        print(f"wrote {args.output}")
    else:
        print(digest, end="")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
