"""Profiling capture for kernel callers: wall-clock spans, XLA
cost/memory analyses of compiled programs, and `jax.profiler.trace`
wrapping — all landing in the same run trace (and therefore the same
artifact schema `benchmarks/` writes).

Everything here degrades to a no-op when no run is active, so call
sites never need their own guards.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from pathlib import Path

from .registry import current, trace_event


@contextmanager
def profiled(label: str, **attrs):
    """Time a block and emit a ``profile.span`` event. Extra keyword
    attributes ride along in the payload (e.g. rows=..., steps=...)."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        trace_event("profile.span",
                    {"label": label,
                     "seconds": time.perf_counter() - t0, **attrs})


@contextmanager
def xla_trace(label: str):
    """Wrap a block in `jax.profiler.trace`, writing the device trace
    under ``<run-dir>/profile/<label>/`` (TensorBoard/Perfetto
    loadable) and emitting a ``profile.trace`` pointer event. Yields
    the trace directory, or None when no run is active (in which case
    no profiler is started — profiling is never free, so it only runs
    inside an explicit telemetry run)."""
    run = current()
    if run is None:
        yield None
        return
    import jax

    trace_dir = run.dir / "profile" / label.replace("/", "_")
    trace_dir.mkdir(parents=True, exist_ok=True)
    try:
        with jax.profiler.trace(str(trace_dir)):
            yield trace_dir
    finally:
        trace_event("profile.trace", {"label": label, "dir": trace_dir})


def record_compiled(label: str, compiled) -> dict:
    """Capture `cost_analysis` + `memory_analysis` of a lowered-and-
    compiled jax program into a ``profile.xla`` event; returns the
    payload so benchmark code can also fold it into its artifact JSON.
    Works whether or not a run is active."""
    from repro.launch.hlo_analysis import raw_cost_analysis

    payload: dict = {"label": label}
    try:
        ca = raw_cost_analysis(compiled)
    except Exception:
        ca = {}
    for key, out in (("flops", "flops"),
                     ("bytes accessed", "bytes_accessed"),
                     ("transcendentals", "transcendentals")):
        if key in ca:
            payload[out] = float(ca[key])
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            val = getattr(mem, attr, None)
            if val is not None:
                payload[attr.replace("_size_in_bytes", "_bytes")] = int(val)
    trace_event("profile.xla", payload)
    return payload
