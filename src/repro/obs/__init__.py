"""Fleet telemetry: zero-perturbation instrumentation of the
tune/dispatch/backtest hot loops, structured JSONL run traces, and a
report CLI (``python -m repro.obs.report <run-dir>``).

See `repro.obs.registry` for the off-means-off / bit-identity contract
and `repro.obs.schema` for the event catalogue.
"""

from .registry import (  # noqa: F401
    SCHEMA_VERSION,
    Counter,
    Gauge,
    Histogram,
    Run,
    capture,
    counter,
    current,
    disable,
    drain,
    enable,
    enabled,
    gauge,
    histogram,
    run_metadata,
    trace_event,
)

__all__ = [
    "SCHEMA_VERSION", "Counter", "Gauge", "Histogram", "Run",
    "capture", "counter", "current", "disable", "drain", "enable",
    "enabled", "gauge", "histogram", "run_metadata", "trace_event",
]
