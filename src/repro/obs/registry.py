"""Telemetry core: counters/gauges/histograms, a schema-versioned JSONL
trace emitter, and the jit-side `drain` that ships on-device summaries
to the host.

Design contract (asserted in `tests/test_obs.py`):

  * **Off means off.** With no run active (`enabled()` is False) the
    instrumented call sites stage *no host callbacks at all* — `drain`
    is a plain no-op at trace time and every hot path passes
    ``telemetry=False`` into its jit, so `repro.fleet.backtest`,
    `repro.tune.tune_loop` and `repro.dispatch.dispatch` compile to the
    exact programs they were before this module existed (inspectable:
    ``io_callback`` never appears in their jaxprs).
  * **On means bit-identical.** Telemetry only ever *reads* values the
    hot loops already compute: metrics ride side-outputs of the
    existing scans plus `io_callback`-drained buffers aggregated
    on-device into [T]-shaped summaries, never feeding back into the
    math. Enabling a run changes zero output bits of the instrumented
    programs (`tests/test_obs.py` compares them byte for byte).

A *run* is a directory: ``trace.jsonl`` (one JSON event per line, first
line is the ``run.meta`` stamp — run id, git sha, jax/jaxlib versions,
device kind, timestamp, schema version) plus ``metrics.json`` (final
counter/gauge/histogram snapshot, written on `disable`). Use the
`capture` context manager in tests and the ``--trace out/`` flags of
`examples/tune_policies.py` / `examples/fleet_dispatch.py` in demos;
render any run dir with ``python -m repro.obs.report <run-dir>``.
"""

from __future__ import annotations

import json
import subprocess
import threading
import time
from contextlib import contextmanager
from datetime import datetime, timezone
from pathlib import Path
from typing import Optional

import numpy as np

SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# run metadata
# ---------------------------------------------------------------------------

_GIT_SHA: Optional[str] = None


def _git_sha() -> Optional[str]:
    """Commit sha of the working tree (cached per process; None outside
    a git checkout — the stamp must never make telemetry fail)."""
    global _GIT_SHA
    if _GIT_SHA is None:
        try:
            _GIT_SHA = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                cwd=Path(__file__).resolve().parent, capture_output=True,
                text=True, timeout=5, check=True).stdout.strip()
        except Exception:
            _GIT_SHA = ""
    return _GIT_SHA or None


def run_metadata() -> dict:
    """Attribution stamp shared by trace runs and benchmark artifacts
    (`benchmarks.common.write_artifact`): enough to answer "what code,
    what jax, what machine produced this number?"."""
    import platform

    import jax
    import jaxlib

    dev = jax.devices()[0]
    return {
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": jax.device_count(),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "timestamp": datetime.now(timezone.utc).isoformat(),
    }


# ---------------------------------------------------------------------------
# metric instruments (host-side, summary statistics only)
# ---------------------------------------------------------------------------

class Counter:
    """Monotone event count (e.g. dispatch moves)."""

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value (e.g. minimum capacity slack of the last
    dispatch)."""

    def __init__(self) -> None:
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming summary (count/sum/min/max) — enough for an operator
    digest without storing every observation twice (the trace already
    has the series)."""

    def __init__(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self):
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": self.sum / self.count}


# ---------------------------------------------------------------------------
# the run (one trace file + live instruments)
# ---------------------------------------------------------------------------

def _json_default(o):
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, Path):
        return str(o)
    return str(o)


class Run:
    """One telemetry run: a directory with ``trace.jsonl`` and (after
    `close`) ``metrics.json``. Event writes are lock-serialized so
    io_callback drains from the runtime thread interleave cleanly with
    host-side emitters (the 8-virtual-device CI leg exercises this)."""

    def __init__(self, run_dir, run_id: Optional[str] = None) -> None:
        self.dir = Path(run_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.run_id = run_id or f"run-{int(time.time() * 1e3):x}"
        self.trace_path = self.dir / "trace.jsonl"
        self._lock = threading.Lock()
        self._seq = 0
        self._fh = self.trace_path.open("w", encoding="utf-8")
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.meta = {"run_id": self.run_id,
                     "schema_version": SCHEMA_VERSION, **run_metadata()}
        self.event("run.meta", self.meta)

    def event(self, kind: str, payload: dict) -> None:
        rec = {"schema": SCHEMA_VERSION, "kind": kind, "ts": time.time()}
        rec.update(payload)
        line = json.dumps(rec, default=_json_default)
        with self._lock:
            rec["seq"] = self._seq
            self._seq += 1
            # re-dump with the lock-assigned seq so lines stay ordered
            self._fh.write(json.dumps(rec, default=_json_default) + "\n")
            self._fh.flush()

    def metrics_snapshot(self) -> dict:
        return {
            "counters": {k: c.snapshot()
                         for k, c in sorted(self.counters.items())},
            "gauges": {k: g.snapshot()
                       for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self.histograms.items())},
        }

    def close(self) -> None:
        snap = self.metrics_snapshot()
        self.event("run.close", {"n_events": self._seq,
                                 "metrics": snap})
        (self.dir / "metrics.json").write_text(
            json.dumps(snap, indent=1, default=_json_default) + "\n")
        with self._lock:
            self._fh.close()


# ---------------------------------------------------------------------------
# module-level switch (the instrumented call sites only ever touch this)
# ---------------------------------------------------------------------------

_CURRENT: list = [None]          # 1-slot box; writes are rebinding-free


def current() -> Optional[Run]:
    return _CURRENT[0]


def enabled() -> bool:
    """The global telemetry switch. Every instrumented jit passes this
    as its static ``telemetry`` argument, so toggling selects a
    different compile-cache entry — the disabled entry stages no host
    callbacks and computes no side-outputs at all."""
    return _CURRENT[0] is not None


def enable(run_dir, run_id: Optional[str] = None) -> Run:
    """Start a telemetry run writing into ``run_dir`` (created if
    missing). Only one run is active at a time; enabling over an active
    run closes it first."""
    if _CURRENT[0] is not None:
        disable()
    _CURRENT[0] = Run(run_dir, run_id=run_id)
    return _CURRENT[0]


def disable() -> None:
    """Close the active run (flushes ``metrics.json``); no-op if none."""
    run, _CURRENT[0] = _CURRENT[0], None
    if run is not None:
        run.close()


@contextmanager
def capture(run_dir, run_id: Optional[str] = None):
    """``with capture(tmpdir) as run: ...`` — enable for a block, close
    on exit even on error."""
    run = enable(run_dir, run_id=run_id)
    try:
        yield run
    finally:
        if _CURRENT[0] is run:
            disable()
        else:                     # someone re-enabled inside the block
            run.close()


def trace_event(kind: str, payload: dict) -> None:
    """Emit one structured event; silent no-op when disabled."""
    run = _CURRENT[0]
    if run is not None:
        run.event(kind, payload)


def counter(name: str) -> Counter:
    run = _CURRENT[0]
    if run is None:
        return Counter()          # throwaway: off means off
    return run.counters.setdefault(name, Counter())


def gauge(name: str) -> Gauge:
    run = _CURRENT[0]
    if run is None:
        return Gauge()
    return run.gauges.setdefault(name, Gauge())


def histogram(name: str) -> Histogram:
    run = _CURRENT[0]
    if run is None:
        return Histogram()
    return run.histograms.setdefault(name, Histogram())


# ---------------------------------------------------------------------------
# jit-side drain
# ---------------------------------------------------------------------------

def drain(kind: str, **arrays) -> None:
    """Ship named on-device arrays to the trace as one event — callable
    *inside* a jitted function.

    When a run is active at trace time this stages one unordered
    `jax.experimental.io_callback` (kept by its IO effect, executed
    once per call of the compiled program); the callback looks up the
    run again at *call* time, so a program compiled while enabled goes
    quiet — without retracing — the moment the run closes. When no run
    is active this is a plain no-op: nothing is staged, the jaxpr is
    untouched. Call sites gate on a static ``telemetry`` argument fed
    from `enabled()`, which keeps the compile cache keyed consistently
    with the switch.
    """
    if not enabled():
        return
    from jax.experimental import io_callback

    names = tuple(arrays)

    def _sink(*vals):
        run = _CURRENT[0]
        if run is not None:
            run.event(kind, {n: np.asarray(v)
                             for n, v in zip(names, vals)})

    io_callback(_sink, None, *arrays.values(), ordered=False)
