"""Sharded checkpointing with async save and elastic restore.

The checkpoint is the mechanism behind everything the paper's technique
needs at runtime: temporary shutdowns (checkpoint -> power off -> restore),
fault tolerance (restore after node loss), and elastic capacity changes
(restore under a different mesh).

Layout: one directory per step:

    <dir>/step_000123/
        manifest.json          pytree structure, shapes, dtypes, metadata
        shard_<host>.npz       this host's param/opt leaves (unique shards)

Leaves are saved by flattened key path. On restore, arrays are placed
against *target* shardings (``jax.device_put`` with the restore mesh's
NamedShardings), so a checkpoint written on a 2x16x16 mesh restores onto a
16x16 mesh (or a shrunken elastic DP world) without a resharding pass —
GSPMD placement does the work. On this CPU container everything is a
single host shard; the format and the restore path are the real ones.

Async: ``save(..., blocking=False)`` snapshots leaves to host RAM
(device_get) and writes in a background thread, so the train loop resumes
after the copy, not after the fsync — checkpoint stalls are what make
frequent price-driven suspends affordable (measured in
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _to_savable(a: np.ndarray) -> np.ndarray:
    """np.savez can't serialise ml_dtypes (bf16/f8, numpy kind 'V');
    store them as same-width unsigned ints — the manifest records the true
    dtype and the loader views them back."""
    if a.dtype.kind == "V":
        return a.view(np.dtype(f"u{a.dtype.itemsize}"))
    return a


def _from_savable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    want = np.dtype(getattr(jax.numpy, dtype_name, dtype_name))
    if a.dtype != want and want.kind == "V":
        return a.view(want)
    return a


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    metadata: Optional[dict] = None, *,
                    blocking: bool = True,
                    host_index: int = 0) -> "SaveHandle":
    """Write ``tree`` under ``directory/step_<step>``; returns a handle
    (``.wait()`` joins the writer thread)."""
    directory = Path(directory)
    stepdir = directory / f"step_{step:08d}"
    tmpdir = directory / f".tmp_step_{step:08d}"
    flat = _flatten(tree)
    # snapshot to host memory first (device buffers may be donated next step)
    host_flat = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    treedef = jax.tree_util.tree_structure(tree)

    def write():
        tmpdir.mkdir(parents=True, exist_ok=True)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(host_flat.keys()),
            "shapes": {k: list(v.shape) for k, v in host_flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in host_flat.items()},
            "metadata": metadata or {},
            "written_at": time.time(),
        }
        (tmpdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
        np.savez(tmpdir / f"shard_{host_index}.npz",
                 **{k: _to_savable(v) for k, v in host_flat.items()})
        if stepdir.exists():
            shutil.rmtree(stepdir)
        tmpdir.rename(stepdir)           # atomic publish

    if blocking:
        write()
        return SaveHandle(None, stepdir)
    th = threading.Thread(target=write, daemon=True)
    th.start()
    return SaveHandle(th, stepdir)


class SaveHandle:
    def __init__(self, thread: Optional[threading.Thread], path: Path):
        self._thread = thread
        self.path = path

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()


def load_checkpoint(directory: str | Path, template: Any, *,
                    step: Optional[int] = None,
                    shardings: Any = None) -> tuple[Any, dict]:
    """Restore the latest (or a specific) step into ``template``'s
    structure. ``shardings``: optional matching pytree of NamedShardings —
    the elastic-restore path places every leaf straight onto the (possibly
    different) target mesh."""
    directory = Path(directory)
    if step is None:
        steps = sorted(directory.glob("step_*"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        stepdir = steps[-1]
    else:
        stepdir = directory / f"step_{step:08d}"
    manifest = json.loads((stepdir / "manifest.json").read_text())
    arrays: dict[str, np.ndarray] = {}
    for shard in sorted(stepdir.glob("shard_*.npz")):
        with np.load(shard) as z:
            arrays.update({k: _from_savable(z[k],
                                            manifest["dtypes"][k])
                           for k in z.files})

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    flat_s = (jax.tree_util.tree_flatten(shardings)[0]
              if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (path, leaf), shard in zip(flat_t, flat_s):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint {arr.shape} != {want}")
        if arr.dtype != np.dtype(leaf.dtype):
            arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), \
        manifest["metadata"] | {"step": manifest["step"]}


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; one in-flight async save."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._pending: Optional[SaveHandle] = None
        # measured save/restore latency feeds the runtime's shutdown-cost
        # correction (paper §V-A: shutdowns are not free)
        self.last_save_s: float = 0.0
        self.last_restore_s: float = 0.0

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             *, blocking: bool = False) -> SaveHandle:
        if self._pending is not None:
            self._pending.wait()
        t0 = time.perf_counter()
        handle = save_checkpoint(self.directory, step, tree, metadata,
                                 blocking=blocking)
        self.last_save_s = time.perf_counter() - t0
        self._pending = None if blocking else handle
        self._gc()
        return handle

    def restore(self, template: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        self.wait()
        t0 = time.perf_counter()
        out = load_checkpoint(self.directory, template, step=step,
                              shardings=shardings)
        self.last_restore_s = time.perf_counter() - t0
        return out

    def latest_step(self) -> Optional[int]:
        self.wait()
        steps = sorted(self.directory.glob("step_*"))
        return int(steps[-1].name.split("_")[1]) if steps else None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.wait()
            self._pending = None
            self._gc()       # prune only after every rename has landed

    def _gc(self) -> None:
        steps = sorted(self.directory.glob("step_*"))
        for old in steps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)
