"""EnergyAwareScheduler — the paper's WS policy as a control loop.

The paper derives the *planning* quantities offline: given a price series
and the system's cost-distribution coefficient Psi, `optimal_shutdown`
yields the CPC-minimising shutdown fraction x_opt and its threshold price.
This scheduler turns that into an *online* policy:

  oracle mode     the full series is known (paper's setting): the threshold
                  is fixed at p_thresh(x_opt) up front. Reproduces the
                  paper's WS policy exactly.
  rolling mode    the threshold is re-estimated every ``refit_hours`` from
                  the trailing window of observed prices (plus optional
                  day-ahead lookahead, which real spot markets publish).
                  This is what an operator could actually deploy.

Beyond the paper (§V-A closes the free-shutdown assumption):

  * viability gate uses the *overhead-adjusted* criterion
    k (1 - overhead) > Psi + 1, with the overhead measured by the trainer
    (checkpoint save + restore time and restart energy);
  * hysteresis + ``min_off_hours`` suppress shutdown churn: a suspend is
    only worth its restart cost if prices stay high long enough;
  * capacity levels for *partial* shutdown of heterogeneous partitions
    (paper §V-C: uniform clusters are all-or-nothing — the scheduler
    emits fractional capacity only when distinct partitions exist).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from repro.core.optimizer import optimal_shutdown
from repro.core.price_model import price_stats


class Action(enum.Enum):
    RUN = "run"
    SHUTDOWN = "shutdown"
    RESUME = "resume"
    STAY_DOWN = "stay_down"


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    psi: float = 2.0                 # cost-distribution coefficient
    mode: str = "oracle"             # oracle | rolling
    refit_hours: int = 24            # rolling: threshold refit period
    lookahead_hours: int = 0         # rolling: day-ahead peek
    hysteresis: float = 0.9          # resume at p < hysteresis * p_thresh
    min_off_hours: float = 1.0       # don't suspend for shorter spikes
    restart_overhead_frac: float = 0.0  # measured; adjusts viability
    x_cap: float = 0.5               # never plan more than 50% downtime


class EnergyAwareScheduler:
    """Maps a PriceStream to RUN / SHUTDOWN / RESUME / STAY_DOWN actions."""

    def __init__(self, stream, config: SchedulerConfig):
        self.stream = stream
        self.cfg = config
        self.running = True
        self.p_thresh = np.inf
        self.planned_x = 0.0
        self.viable = False
        self._hours_since_fit = np.inf
        self._off_hours = 0.0
        if config.mode == "oracle":
            self._fit(np.asarray(stream.prices))

    # ------------------------------------------------------------------
    def _fit(self, prices: np.ndarray) -> None:
        """(Re)derive threshold from a price window via the paper model."""
        plan = optimal_shutdown(prices, self.cfg.psi)
        k_opt = float(plan.k_opt) if np.isfinite(float(plan.k_opt)) else 0.0
        # overhead-adjusted viability (beyond-paper §V-A correction)
        adj_ok = (k_opt * (1.0 - self.cfg.restart_overhead_frac)
                  > self.cfg.psi + 1.0)
        self.viable = bool(plan.viable) and adj_ok
        if self.viable:
            self.planned_x = min(float(plan.x_opt), self.cfg.x_cap)
            self.p_thresh = float(plan.p_thresh)
        else:
            self.planned_x = 0.0
            self.p_thresh = np.inf
        self._hours_since_fit = 0.0

    def _maybe_refit(self) -> None:
        if self.cfg.mode != "rolling":
            return
        if self._hours_since_fit >= self.cfg.refit_hours:
            window = self.stream.trailing()
            if self.cfg.lookahead_hours:
                window = np.concatenate(
                    [window, self.stream.peek(self.cfg.lookahead_hours)])
            self._fit(window)

    # ------------------------------------------------------------------
    def step(self, hours: float = 1.0) -> Action:
        """Advance the simulated clock and decide the next action."""
        self._hours_since_fit += hours
        self._maybe_refit()
        price = self.stream.current()
        self.stream.advance(hours)

        if self.running:
            if price > self.p_thresh and self._spike_long_enough():
                self.running = False
                self._off_hours = 0.0
                return Action.SHUTDOWN
            return Action.RUN
        # suspended: resume below the hysteresis threshold
        self._off_hours += hours
        if price <= self.cfg.hysteresis * self.p_thresh:
            self.running = True
            return Action.RESUME
        return Action.STAY_DOWN

    def _spike_long_enough(self) -> bool:
        """Day-ahead check: will the price stay above threshold for at
        least ``min_off_hours``? (Without lookahead, assume yes — the
        single-threshold paper policy.)"""
        need = int(np.ceil(self.cfg.min_off_hours))
        if need <= 1 or self.cfg.lookahead_hours < need:
            return True
        ahead = self.stream.peek(need - 1)
        return bool(np.all(ahead > self.p_thresh))

    # ------------------------------------------------------------------
    def stats_snapshot(self) -> dict:
        window = (np.asarray(self.stream.prices)
                  if self.cfg.mode == "oracle" else self.stream.trailing())
        x = max(self.planned_x, 1e-4)
        st = price_stats(window, x)
        return {
            "p_thresh": self.p_thresh,
            "planned_x": self.planned_x,
            "viable": self.viable,
            "k_at_plan": float(st.k),
            "p_avg": float(st.p_avg),
        }


@dataclasses.dataclass(frozen=True)
class Partition:
    """A heterogeneous-cluster partition (paper §V-C): its own power draw
    and fixed-cost share, hence its own Psi and its own plan."""

    name: str
    power_mw: float
    fixed_cost_per_hour: float

    def psi(self, p_avg: float) -> float:
        return self.fixed_cost_per_hour / (self.power_mw * p_avg)


def partition_plans(partitions: list[Partition], prices: np.ndarray) -> dict:
    """Per-partition shutdown plans — the model applied partition-wise.
    Less energy-efficient partitions (higher C per fixed cost => lower Psi)
    become viable first."""
    p_avg = float(np.mean(prices))
    out = {}
    for part in partitions:
        plan = optimal_shutdown(prices, part.psi(p_avg))
        out[part.name] = {
            "psi": part.psi(p_avg),
            "viable": bool(plan.viable),
            "x_opt": float(plan.x_opt),
            "p_thresh": float(plan.p_thresh),
            "cpc_reduction": float(plan.cpc_reduction),
        }
    return out
