"""Online TCO / CPC accounting — the paper's model, measured instead of
assumed.

``CostMeter`` integrates, hour by simulated hour, exactly the quantities the
closed-form model predicts in aggregate:

    fixed cost  F/T per hour, accrued whether or not the system runs
    energy cost C * price while running (+ idle draw while suspended,
                + restart energy per resume — the §V-A costs the paper
                deliberately excludes, so predicted vs realised CPC
                quantifies that bias)
    uptime      compute-hours actually delivered

so realised CPC = (F_accrued + E_accrued) / uptime is directly comparable
with ``repro.core.tco.cpc_with_shutdowns`` and the predicted reduction of
``optimal_shutdown``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class CostMeter:
    """Integrates costs over simulated hours."""

    power_mw: float                 # C: full-operation draw [MW]
    fixed_cost_per_hour: float      # F / T [EUR/h]
    idle_power_frac: float = 0.0    # residual draw while suspended

    hours: float = 0.0
    uptime_hours: float = 0.0
    fixed_cost: float = 0.0
    energy_cost: float = 0.0
    energy_mwh: float = 0.0
    restart_energy_cost: float = 0.0
    restarts: int = 0
    shutdowns: int = 0
    # the always-on counterfactual, integrated on the same prices
    ao_energy_cost: float = 0.0

    def tick(self, hours: float, price: float, *, running: bool,
             load: float = 1.0) -> None:
        """Account ``hours`` of operation (or suspension) at ``price``.
        ``load``: fraction of full power drawn while running (partial
        capacity, e.g. a serving engine with some slots gated off)."""
        self.hours += hours
        self.fixed_cost += self.fixed_cost_per_hour * hours
        draw = self.power_mw * (load if running else self.idle_power_frac)
        mwh = draw * hours
        self.energy_mwh += mwh
        self.energy_cost += mwh * price
        self.ao_energy_cost += self.power_mw * hours * price
        if running:
            self.uptime_hours += hours

    def restart_event(self, price: float, energy_mwh: float,
                      lost_hours: float) -> None:
        """A resume: restart energy billed at the current price; the restart
        time is wall-clock during which fixed costs accrue but no compute is
        delivered (uptime not credited)."""
        self.restarts += 1
        cost = energy_mwh * price
        self.restart_energy_cost += cost
        self.energy_cost += cost
        self.energy_mwh += energy_mwh
        self.hours += lost_hours
        self.fixed_cost += self.fixed_cost_per_hour * lost_hours
        self.ao_energy_cost += self.power_mw * lost_hours * price

    def shutdown_event(self) -> None:
        self.shutdowns += 1

    # ------------------------------------------------------------------
    @property
    def tco(self) -> float:
        return self.fixed_cost + self.energy_cost

    @property
    def cpc(self) -> float:
        return self.tco / max(self.uptime_hours, 1e-9)

    @property
    def cpc_always_on(self) -> float:
        """Counterfactual CPC had the system never shut down (same
        prices, full uptime)."""
        return (self.fixed_cost + self.ao_energy_cost) / max(self.hours,
                                                             1e-9)

    @property
    def cpc_reduction(self) -> float:
        """Realised 1 - CPC/CPC_AO (the paper's Eq. 26, measured)."""
        ao = self.cpc_always_on
        return 1.0 - self.cpc / ao if ao > 0 else 0.0

    @property
    def realized_x(self) -> float:
        return 1.0 - self.uptime_hours / max(self.hours, 1e-9)

    def summary(self) -> dict:
        return {
            "hours": self.hours,
            "uptime_hours": self.uptime_hours,
            "x_realized": self.realized_x,
            "fixed_cost": self.fixed_cost,
            "energy_cost": self.energy_cost,
            "energy_mwh": self.energy_mwh,
            "restarts": self.restarts,
            "tco": self.tco,
            "cpc": self.cpc,
            "cpc_always_on": self.cpc_always_on,
            "cpc_reduction": self.cpc_reduction,
        }
