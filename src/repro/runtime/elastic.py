"""Elastic capacity: resize the data-parallel world at runtime.

The paper discusses variable capacity as binary (all-on / all-off) and
notes partial shutdown as future refinement (§V-C). At framework level,
partial capacity = shrinking the DP axis of the mesh: a 2x16x16 job can
drop to 1x16x16 (half power) by checkpointing, releasing one pod, and
restoring onto the smaller mesh. This module provides the mesh arithmetic
and the restore-side placement:

  * capacity level L in (0, 1]: keep round(L * dp_total) DP slices; the
    model axis is never resized (TP re-sharding would change per-op
    layouts; DP resize only changes the *batch* sharding and gradient
    all-reduce span — checkpointed params are DP-replicated / fsdp-sharded
    and re-place cleanly);
  * the *global batch is preserved* by raising the per-replica microbatch
    count (gradient accumulation) — data order and loss curves are
    unchanged by a capacity change, only step wall-time;
  * `capacity_schedule` maps a price series + partition plans to per-hour
    levels (the heterogeneous-partitions route of §V-C).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.parallel.axes import LogicalRules, logical_to_spec


@dataclasses.dataclass(frozen=True)
class CapacityLevel:
    level: float            # fraction of DP capacity in use
    dp_size: int            # resulting data-parallel world size
    microbatches: int       # accumulation factor preserving global batch


def resize_mesh(devices: np.ndarray, level: float, *,
                model_parallel: int,
                axis_names: tuple = ("data", "model")) -> Optional[Mesh]:
    """Build a mesh over the first ``round(level * n_dp)`` DP slices.

    ``devices``: flat array of available devices (as from jax.devices()).
    Returns None if fewer than one DP slice survives.
    """
    n = len(devices)
    dp_total = n // model_parallel
    dp_keep = max(int(round(level * dp_total)), 1)
    kept = np.asarray(devices[:dp_keep * model_parallel]).reshape(
        dp_keep, model_parallel)
    return Mesh(kept, axis_names)


def capacity_plan(level: float, dp_total: int,
                  base_microbatches: int = 1) -> CapacityLevel:
    """Constant-global-batch accumulation plan for a capacity level."""
    dp_keep = max(int(round(level * dp_total)), 1)
    scale = dp_total / dp_keep
    return CapacityLevel(level=dp_keep / dp_total, dp_size=dp_keep,
                         microbatches=int(np.ceil(base_microbatches
                                                  * scale)))


def reshard_tree(tree, mesh: Mesh, logical_specs, rules: LogicalRules):
    """Place a (restored) pytree onto ``mesh`` under logical specs — the
    elastic-restore path. Works across mesh *sizes* because every leaf is
    host-materialised by the checkpoint loader first."""
    def place(leaf, axes):
        spec = logical_to_spec(axes, rules)
        return jax.device_put(leaf, jax.sharding.NamedSharding(mesh, spec))
    return jax.tree.map(place, tree, logical_specs)


def capacity_schedule(prices: np.ndarray, partition_plans: dict,
                      power_by_partition: dict) -> np.ndarray:
    """Fractional capacity per hour from per-partition shutdown plans
    (paper §V-C realised): at each hour, a partition is off iff the price
    exceeds *its* threshold; capacity = online power / total power."""
    prices = np.asarray(prices)
    total = sum(power_by_partition.values())
    cap = np.zeros_like(prices, dtype=np.float64)
    if total <= 0.0:
        # no partitions (or zero installed power): nothing can be online
        return cap
    for name, plan in partition_plans.items():
        thr = plan["p_thresh"] if plan["viable"] else np.inf
        on = (prices <= thr).astype(np.float64)
        cap += on * power_by_partition[name]
    return cap / total
