from repro.runtime.accounting import CostMeter  # noqa: F401
from repro.runtime.scheduler import (EnergyAwareScheduler,
                                     SchedulerConfig)  # noqa: F401
from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
