"""Energy-aware trainer: the machinery the paper's decision model drives.

The trainer couples three clocks:

  * the *step* clock — real JAX `train_step` executions (jit, donated
    state, microbatch accumulation, optional int8 gradient compression);
  * the *simulated wall* clock — each step (or idle tick) advances
    ``hours_per_step`` of market time against the price stream;
  * the *cost* clock — `CostMeter` integrates fixed + energy spend.

Each tick, the `EnergyAwareScheduler` decides RUN / SHUTDOWN / RESUME.
A SHUTDOWN checkpoints (measured, not assumed — the save latency plus
restore latency and restart energy feed the scheduler's overhead-adjusted
viability gate) and suspends compute; a RESUME restores parameters from
the checkpoint, bit-identically, and training continues at the step where
it stopped (the data pipeline is stateless-by-step, so the token stream is
unaffected by the detour).

Fault tolerance uses the *same* path: an injected (or real) failure
discards live state and restores the last checkpoint — lost steps are
re-run and separately accounted. Straggler mitigation is a per-step
deadline: simulated host step-times are sampled per tick, and hosts
slower than ``straggler_deadline`` x median have their microbatch dropped
(gradient renormalised) instead of stalling the step — the accounting
reports both the time saved and the tokens lost.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import init_params, loss_fn
from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_init,
                               adamw_update)
from repro.optim.schedule import warmup_cosine
from repro.runtime.accounting import CostMeter
from repro.runtime.scheduler import Action, EnergyAwareScheduler


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    hours_per_step: float = 1.0        # simulated market-hours per step
    microbatches: int = 1              # gradient accumulation
    grad_compress: bool = False        # int8 error-feedback DP all-reduce
    # simulated cluster characteristics (cost model inputs)
    power_mw: float = 1.0
    fixed_cost_per_hour: float = 160.0
    idle_power_frac: float = 0.0
    restart_energy_mwh: float = 0.25   # energy to restart the fleet
    restart_time_h: float = 0.1        # wall time lost per resume
    # fault injection & stragglers (both off by default)
    fault_prob_per_step: float = 0.0
    straggler_sigma: float = 0.0       # lognormal sigma of host step time
    straggler_deadline: float = 1.5    # x median; slower microbatch dropped
    n_hosts: int = 8
    seed: int = 0


class Trainer:
    """Drives (model, optimizer, data) under an energy-aware schedule."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 scheduler: Optional[EnergyAwareScheduler] = None,
                 opt: Optional[AdamWConfig] = None,
                 data: Optional[SyntheticLM] = None,
                 batch_size: int = 8, seq_len: int = 128):
        self.cfg = cfg
        self.tcfg = tcfg
        self.scheduler = scheduler
        self.opt = opt or AdamWConfig(moment_dtype=cfg.moment_dtype)
        self.data = data or SyntheticLM(vocab=cfg.vocab, seq_len=seq_len,
                                        global_batch=batch_size,
                                        seed=tcfg.seed)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.meter = CostMeter(power_mw=tcfg.power_mw,
                               fixed_cost_per_hour=tcfg.fixed_cost_per_hour,
                               idle_power_frac=tcfg.idle_power_frac)
        self.rng = np.random.default_rng(tcfg.seed)

        key = jax.random.PRNGKey(tcfg.seed)
        self.params = init_params(key, cfg)
        self.opt_state = adamw_init(self.params, self.opt)
        from repro.optim.compress import init_error_feedback
        self.err = (init_error_feedback(self.params)
                    if tcfg.grad_compress else
                    jax.tree.map(lambda p: jnp.zeros((), jnp.float32),
                                 self.params))
        self.step = 0
        self.running = True
        self.history: list[dict] = []
        self.lost_steps = 0
        self.dropped_microbatches = 0
        warm = max(tcfg.steps // 20, 1)
        self._lr = lambda step: warmup_cosine(step, self.opt.lr, warm,
                                              tcfg.steps)
        self._train_step = self._build_train_step()

    # ------------------------------------------------------------------
    def _build_train_step(self) -> Callable:
        cfg, opt, n_micro = self.cfg, self.opt, self.tcfg.microbatches
        compress = self.tcfg.grad_compress

        def one_grad(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
            return grads, metrics

        def train_step(params, opt_state: AdamWState, err, batch, lr,
                       micro_keep):
            """micro_keep: [n_micro] 0/1 — straggler-dropped microbatches
            contribute zero gradient; the mean renormalises over kept.
            ``err``: int8-compression error-feedback state (pytree like
            params; unused when compression is off)."""
            if n_micro == 1:
                grads, metrics = one_grad(params, batch)
            else:
                def split(x):
                    return x.reshape((n_micro, x.shape[0] // n_micro)
                                     + x.shape[1:])
                micro = jax.tree.map(split, batch)

                def acc_fn(acc, inp):
                    mb, keep = inp
                    g, m = one_grad(params, mb)
                    g = jax.tree.map(lambda a, b: a + keep * b, acc[0], g)
                    return (g, jax.tree.map(lambda a, b: a + keep * b,
                                            acc[1], m)), None

                zeros_g = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                zeros_m = {"loss": 0., "ce": 0., "moe_aux": 0., "tokens": 0.}
                (grads, msum), _ = jax.lax.scan(
                    acc_fn, (zeros_g, zeros_m), (micro, micro_keep))
                denom = jnp.maximum(jnp.sum(micro_keep), 1.0)
                grads = jax.tree.map(lambda g: g / denom, grads)
                metrics = jax.tree.map(lambda m: m / denom, msum)
            if compress:
                # single-host path: the quantisation (and its error
                # feedback) is real; the pod all-gather is the identity.
                # Multi-host uses compress.compressed_pmean under shard_map.
                from repro.optim.compress import dequantize, quantize_int8

                def qdq(g, e):
                    q, scale, new_e = quantize_int8(g, e)
                    return dequantize(q, scale).astype(g.dtype), new_e

                pairs = jax.tree.map(qdq, grads, err)
                grads = jax.tree.map(lambda t: t[0], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
                err = jax.tree.map(lambda t: t[1], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
            new_params, new_opt, stats = adamw_update(
                grads, opt_state, params, opt, lr=lr)
            return new_params, new_opt, err, {**metrics, **stats}

        return jax.jit(train_step, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------------
    def _simulate_step_hosts(self) -> tuple[float, np.ndarray]:
        """Sample per-host step-time multipliers; return (step-time factor,
        keep mask over microbatches) under the straggler policy."""
        t = self.tcfg
        if t.straggler_sigma <= 0 or t.microbatches == 1:
            return 1.0, np.ones((t.microbatches,), np.float32)
        mult = self.rng.lognormal(0.0, t.straggler_sigma, t.n_hosts)
        med = float(np.median(mult))
        deadline = t.straggler_deadline * med
        # microbatches map round-robin onto hosts
        host_of = np.arange(t.microbatches) % t.n_hosts
        keep = (mult[host_of] <= deadline).astype(np.float32)
        if keep.sum() == 0:
            keep[:] = 1.0
        eff = min(float(np.max(np.where(mult <= deadline, mult, 0.0))),
                  deadline)
        self.dropped_microbatches += int((1 - keep).sum())
        return max(eff, med), keep

    def _checkpoint(self, blocking: bool = False):
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state,
                        "err": self.err},
                       metadata={"step": self.step}, blocking=blocking)

    def _restore(self):
        (tree, meta) = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state,
             "err": self.err})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.err = tree["err"]
        restored = int(meta["step"])
        self.lost_steps += max(self.step - restored, 0)
        self.step = restored

    # ------------------------------------------------------------------
    def run(self, log_every: int = 50,
            on_step: Optional[Callable[[dict], None]] = None) -> dict:
        t = self.tcfg
        self._checkpoint(blocking=True)          # step-0 baseline
        wall0 = time.perf_counter()
        while self.step < t.steps:
            price = (self.scheduler.stream.current()
                     if self.scheduler else 0.0)
            action = (self.scheduler.step(t.hours_per_step)
                      if self.scheduler else Action.RUN)

            if action in (Action.SHUTDOWN,):
                self._checkpoint(blocking=True)
                self.meter.shutdown_event()
                self.meter.tick(t.hours_per_step, price, running=False)
                self.running = False
                continue
            if action is Action.STAY_DOWN:
                self.meter.tick(t.hours_per_step, price, running=False)
                continue
            if action is Action.RESUME:
                self._restore()
                self.meter.restart_event(price, t.restart_energy_mwh,
                                         t.restart_time_h)
                self.running = True
                # the resume tick itself delivers compute below

            # fault injection (independent of the schedule)
            if (t.fault_prob_per_step > 0
                    and self.rng.random() < t.fault_prob_per_step):
                self._restore()
                self.meter.restart_event(price, t.restart_energy_mwh,
                                         t.restart_time_h)

            slowdown, keep = self._simulate_step_hosts()
            batch = self.data.batch_at(self.step)
            lr = self._lr(self.step)
            self.params, self.opt_state, self.err, metrics = \
                self._train_step(self.params, self.opt_state, self.err,
                                 batch, lr, jnp.asarray(keep))
            self.meter.tick(t.hours_per_step * slowdown, price,
                            running=True)
            self.step += 1

            if self.step % t.ckpt_every == 0:
                self._checkpoint()
            rec = {"step": self.step, "loss": float(metrics["loss"]),
                   "price": price, "cpc": self.meter.cpc,
                   "running": True}
            self.history.append(rec)
            if on_step is not None:
                on_step(rec)
            if log_every and self.step % log_every == 0:
                print(f"step {self.step:5d} loss {rec['loss']:.4f} "
                      f"price {price:7.2f} cpc {self.meter.cpc:9.2f} "
                      f"x={self.meter.realized_x:.3%}")

        self.ckpt.wait()
        out = self.meter.summary()
        out.update({
            "final_loss": self.history[-1]["loss"] if self.history else None,
            "lost_steps": self.lost_steps,
            "dropped_microbatches": self.dropped_microbatches,
            "wall_s": time.perf_counter() - wall0,
            "ckpt_save_s": self.ckpt.last_save_s,
            "ckpt_restore_s": self.ckpt.last_restore_s,
        })
        return out

    # ------------------------------------------------------------------
    def measured_restart_overhead_frac(self) -> float:
        """Measured shutdown overhead as a fraction of one suspend-hour's
        energy saving — feeds SchedulerConfig.restart_overhead_frac."""
        t = self.tcfg
        save_h = self.ckpt.last_save_s / 3600.0
        restore_h = self.ckpt.last_restore_s / 3600.0
        overhead_mwh = (t.restart_energy_mwh
                        + t.power_mw * (save_h + restore_h + t.restart_time_h))
        return overhead_mwh / max(t.power_mw * 1.0, 1e-9)
