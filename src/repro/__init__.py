"""repro — production JAX framework reproducing and extending
"Navigating the Energy Doldrums" (Arzt & Wolf, 2025).

Layers:
  core      — the paper's TCO / price-variability model (Eqs. 1-29, Eq. 30)
  energy    — price-market substrate (synthetic generators, streams, loaders)
  models    — LM workload substrate (dense/GQA/MoE/SSM/hybrid/enc-dec)
  kernels   — Pallas TPU kernels for compute hot spots
  parallel  — sharding rules for the (pod, data, model) production mesh
  optim     — optimizer + schedules + gradient machinery
  checkpoint— sharded checkpoints, async save, elastic re-shard
  runtime   — energy-aware variable-capacity trainer
  serving   — price-aware batched inference engine
  configs   — assigned architectures × input shapes
  launch    — mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
