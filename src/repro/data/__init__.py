from repro.data.pipeline import (SyntheticLM, batch_at, global_batch_sharding,
                                 host_shard)  # noqa: F401
