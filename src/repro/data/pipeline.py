"""Deterministic synthetic-token data pipeline.

The pipeline is *stateless by step index*: ``batch_at(step)`` is a pure
function of (seed, step), so a job restored from a step-``s`` checkpoint
resumes with exactly the batch it would have seen — the property the
energy-aware runtime relies on for bit-identical pause/resume and for
elastic re-sharding (a batch is defined globally and each host slices its
shard; changing the DP world size never changes the data order).

Documents are drawn from a power-law token distribution (so the loss has
realistic structure to descend), cut into power-law-length documents and
packed; ``loss_mask`` zeroes the first token after each boundary.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Deterministic synthetic LM corpus (packed documents)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    zipf_a: float = 1.2            # token power-law exponent

    def batch_at(self, step: int) -> dict:
        return batch_at(self, step)


def _zipf_tokens(key, shape, vocab: int, a: float):
    """Power-law token ids in [2, vocab): id = 2 + floor(z) with z ~ Zipf-ish
    via inverse-CDF on uniform (bounded; avoids scipy)."""
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
    hi = float(vocab - 2)
    z = (u ** (-1.0 / (a - 1.0)) - 1.0)            # Pareto tail, >= 0
    z = jnp.minimum(z, hi - 1.0)
    return (2.0 + z).astype(jnp.int32)


def batch_at(ds: SyntheticLM, step: int) -> dict:
    """The global batch for ``step``: {tokens, labels, loss_mask}.

    tokens/labels: [global_batch, seq_len] int32; labels are next-token
    shifted within the packed stream; token 1 is the document separator.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(ds.seed), step)
    k_tok, k_cut = jax.random.split(key)
    b, s = ds.global_batch, ds.seq_len
    toks = _zipf_tokens(k_tok, (b, s + 1), ds.vocab, ds.zipf_a)
    # document boundaries: geometric with mean mean_doc_len
    cut = jax.random.uniform(k_cut, (b, s + 1)) < (1.0 / ds.mean_doc_len)
    toks = jnp.where(cut, jnp.ones_like(toks), toks)   # sep token = 1
    tokens = toks[:, :-1]
    labels = toks[:, 1:]
    # don't train on predicting the token right after a separator boundary
    loss_mask = 1.0 - cut[:, 1:].astype(jnp.float32)
    return {"tokens": tokens, "labels": labels, "loss_mask": loss_mask}


def host_shard(batch: dict, host_index: int, n_hosts: int) -> dict:
    """The slice of the global batch this host feeds (per-host data
    loading: each host materialises only its rows)."""
    def f(x):
        per = x.shape[0] // n_hosts
        return x[host_index * per:(host_index + 1) * per]
    return jax.tree.map(f, batch)


def global_batch_sharding(mesh, rules) -> jax.sharding.NamedSharding:
    """NamedSharding for batch pytrees under the active logical rules."""
    from repro.parallel.axes import logical_to_spec
    return jax.sharding.NamedSharding(
        mesh, logical_to_spec(("batch", None), rules))


def to_numpy(batch: dict) -> dict:
    return jax.tree.map(np.asarray, batch)
