"""qwen2.5-14b — dense GQA transformer [hf:Qwen/Qwen2.5 family].

48L d_model=5120 40H (GQA kv=8, head_dim 128) d_ff=13824 vocab=152064,
QKV bias, RoPE theta 1e6.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    norm_eps=1e-6,
    train_microbatches=2,
))
