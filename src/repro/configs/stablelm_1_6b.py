"""stablelm-1.6b — dense transformer [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (MHA: kv=32) d_ff=5632 vocab=100352.
(StableLM-2's partial-rotary detail is simplified to full RoPE; noted in
DESIGN.md hardware-adaptation notes.)
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    rope_theta=1e4,
    norm_eps=1e-5,
))
