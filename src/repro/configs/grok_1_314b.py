"""grok-1-314b — MoE transformer [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8, head_dim 128) d_ff=32768, 8 experts top-2,
vocab=131072. Optimizer moments in bf16 so the 314B configuration fits the
16 GiB/chip production mesh (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    rope_theta=1e4,
    norm_eps=1e-5,
    moment_dtype="bfloat16",
    train_microbatches=8,
    grad_accum_dtype="bfloat16",
))
