"""whisper-large-v3 — encoder-decoder audio transformer [arXiv:2212.04356].

32L encoder + 32L decoder, d_model=1280, 20H (MHA), d_ff=5120 (plain GELU
MLP), vocab=51866. The conv audio frontend is a STUB: ``input_specs``
provides precomputed frame embeddings [B, 1500, 1280]. Positional encoding
is RoPE in this implementation (Whisper's learned/sinusoidal embeddings are
an equivalent-capacity substitution; DESIGN.md §4).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    enc_layers=32,
    enc_seq=1500,
    frontend="audio",
    rope_theta=1e4,
    norm_eps=1e-5,
))
