"""internvl2-76b — VLM: InternViT frontend (stub) + 76B LM backbone
[arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8, head_dim 128) d_ff=28672 vocab=128256.
The InternViT vision tower is a STUB: ``input_specs`` provides 256
precomputed patch embeddings per sample which replace the first 256 token
positions (labels masked there).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    frontend="vision",
    vis_tokens=256,
    rope_theta=5e5,
    norm_eps=1e-5,
    moment_dtype="bfloat16",
    train_microbatches=4,
))
