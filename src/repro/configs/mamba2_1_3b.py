"""mamba2-1.3b — SSD state-space model [arXiv:2405.21060].

48L d_model=2048 attention-free; ssm_state=128, expand 2 (d_inner=4096),
head_dim 64 (64 SSD heads), conv width 4, vocab 50280 (GPT-NeoX tokenizer),
tied embeddings.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    norm_eps=1e-5,
))
