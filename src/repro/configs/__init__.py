from repro.configs.base import (
    ModelConfig,
    ShapeSpec,
    SHAPES,
    ARCH_REGISTRY,
    get_config,
    list_archs,
    runnable_cells,
    cell_skip_reason,
)

__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "ARCH_REGISTRY",
    "get_config",
    "list_archs",
    "runnable_cells",
    "cell_skip_reason",
]
