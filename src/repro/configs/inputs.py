"""Input stand-ins for every (architecture × shape) cell.

``input_specs`` returns weak-type-correct ``ShapeDtypeStruct`` pytrees for
the entry point the shape's kind lowers:

  train    -> ``train_step``   {"batch": tokens/labels (+frames/patches)}
  prefill  -> ``prefill``      {"batch": tokens (+frames/patches)}
  decode   -> ``serve_step``   {"tokens", "caches", "positions"} — one new
              token against a KV/SSM cache of ``seq_len``

``concrete=True`` materialises small-seed arrays instead (smoke tests /
examples). ``reduced_config`` shrinks any architecture to a CPU-runnable
member of the same family for the per-arch smoke tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.layers import _dtype
from repro.models.model import init_cache


def _mk(concrete: bool):
    if concrete:
        def f(shape, dtype):
            if jnp.issubdtype(dtype, jnp.integer):
                return jnp.ones(shape, dtype)
            return jnp.zeros(shape, dtype)
        return f
    return jax.ShapeDtypeStruct


def _frontend_inputs(cfg: ModelConfig, b: int, mk) -> dict:
    adt = _dtype(cfg.dtype)
    out = {}
    if cfg.family == "audio":
        out["frames"] = mk((b, cfg.enc_seq, cfg.d_model), adt)
    if cfg.frontend == "vision":
        out["patches"] = mk((b, cfg.vis_tokens, cfg.d_model), adt)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                concrete: bool = False) -> dict:
    """Stand-ins for every model input of this (arch, shape) cell."""
    mk = _mk(concrete)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": mk((b, s), jnp.int32),
                 "labels": mk((b, s), jnp.int32)}
        batch.update(_frontend_inputs(cfg, b, mk))
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": mk((b, s), jnp.int32)}
        batch.update(_frontend_inputs(cfg, b, mk))
        return {"batch": batch}
    if shape.kind == "decode":
        caches = init_cache(cfg, b, s, abstract=not concrete)
        return {"tokens": mk((b, 1), jnp.int32),
                "caches": caches,
                "positions": mk((b,), jnp.int32)}
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family stand-in: few layers, narrow width, small vocab.

    Preserves the family-defining structure (GQA ratio, QKV bias, MoE
    top-k, SSD dims, shared-attention period, enc-dec, frontend kind).
    """
    heads = 4 if cfg.n_heads else 0
    if cfg.n_heads:
        ratio = cfg.n_kv_heads / cfg.n_heads
        kv = max(1, round(heads * ratio))
    else:
        kv = 0
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=4 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        dtype="float32",
        param_dtype="float32",
        moment_dtype="float32",
        remat="none",
        attn_q_chunk=8,
        attn_kv_chunk=16,
    )
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.swa_window:
        kw.update(swa_window=8)
    if cfg.hybrid_attn_every:
        kw.update(hybrid_attn_every=2)
    if cfg.is_encdec:
        kw.update(enc_layers=2, enc_seq=24)
    if cfg.frontend == "vision":
        kw.update(vis_tokens=8)
    return cfg.replace(**kw)


SMOKE_SHAPE = ShapeSpec("smoke", seq_len=16, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeSpec("smoke_prefill", seq_len=16, global_batch=2,
                          kind="prefill")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=16, global_batch=2,
                         kind="decode")
