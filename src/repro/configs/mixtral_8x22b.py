"""mixtral-8x22b — MoE transformer with sliding-window attention
[arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8, head_dim 128) d_ff=16384, 8 experts top-2,
vocab=32768, SWA window 4096 (per the assignment; the rolling cache makes
long_500k decode run at constant memory). bf16 optimizer moments (141B
total parameters).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    n_experts=8,
    top_k=2,
    swa_window=4096,
    rope_theta=1e6,
    norm_eps=1e-5,
    moment_dtype="bfloat16",
    train_microbatches=4,
))
