"""Architecture + shape configuration system.

Every assigned architecture registers a ``ModelConfig`` (exact
public-literature dimensions) via its module in ``repro/configs/<id>.py``.
Shapes are the assigned LM shape set; `runnable_cells` encodes the
skip rules (long_500k only for sub-quadratic archs; see DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_aux_coef: float = 0.01
    # sliding-window attention (0 = full)
    swa_window: int = 0
    # hybrid (zamba2): shared attention block applied every k SSM blocks
    hybrid_attn_every: int = 0
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # modality frontend stub: None | 'audio' | 'vision'
    frontend: Optional[str] = None
    vis_tokens: int = 256          # patch embeddings for 'vision' frontend
    # numerics / performance knobs
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"  # optimizer moments (bf16 for >=100B)
    logit_dtype: str = "float32"
    remat: str = "full"            # none | full | dots
    attn_impl: str = "xla"         # xla (blockwise online-softmax) | pallas
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    kv_cache_dtype: str = "bfloat16"  # int8 enables quantised KV (opt)
    kv_cache_align: int = 0        # store caches with KV heads replicated
                                   # to this count (Megatron GQA layout for
                                   # decode: even head sharding, no cache
                                   # reshard collectives; 0 = n_kv_heads)
    loss_chunk: int = 512          # seq-chunked cross-entropy (0 = off):
                                   # never materialises [B,S,V] logits
    train_microbatches: int = 1    # gradient accumulation (per train step)
    grad_accum_dtype: str = "float32"  # bf16 halves the accumulator (the
                                   # largest single state tensor of a 314B
                                   # train step) at ~1-2 mantissa bits of
                                   # accumulation error over <=16 terms

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:       # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def cache_heads(self) -> int:
        """KV-head count as stored in decode caches (>= n_kv_heads)."""
        if self.kv_cache_align and self.n_kv_heads \
                and self.kv_cache_align > self.n_kv_heads \
                and self.n_heads % self.kv_cache_align == 0 \
                and self.kv_cache_align % self.n_kv_heads == 0:
            return self.kv_cache_align
        return self.n_kv_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM state, hybrid with
        TP-sharded shared-attn KV, or sliding-window attention.)"""
        return self.family in ("ssm", "hybrid") or self.swa_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    def replace(self, **kw) -> "ShapeSpec":
        return dataclasses.replace(self, **kw)


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "mamba2-1.3b",
    "qwen2.5-14b",
    "stablelm-1.6b",
    "qwen1.5-0.5b",
    "qwen2.5-3b",
    "zamba2-1.2b",
    "whisper-large-v3",
    "grok-1-314b",
    "mixtral-8x22b",
    "internvl2-76b",
]

ARCH_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_REGISTRY:
        mod = arch.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return ARCH_REGISTRY[arch]


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def cell_skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell must run; else the documented reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is pure full-attention (see DESIGN.md §6)")
    return None


def runnable_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if cell_skip_reason(cfg, shape) is None:
                cells.append((arch, shape.name))
    return cells
