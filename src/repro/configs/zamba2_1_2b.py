"""zamba2-1.2b — hybrid Mamba2 + shared attention [arXiv:2411.15242].

38 Mamba2 layers, d_model=2048, ssm_state=64; one weight-shared attention
block (32H MHA, d_ff=8192 MLP) applied every 6 SSM layers (6 applications,
each with its own KV cache), vocab=32000. Zamba2's per-application LoRA
adapters and input-embedding concat are simplified away (DESIGN.md §6).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    hybrid_attn_every=6,
    rope_theta=1e4,
    norm_eps=1e-5,
    # the unrolled hybrid structure (6 shared-attn applications + 38 SSM
    # blocks, python-level groups) runs full-sequence per microbatch;
    # accumulation keeps its live set inside HBM
    train_microbatches=4,
))
