"""AdamW, self-implemented on parameter pytrees.

Moments are kept in ``cfg.moment_dtype`` (f32 default; bf16 for the >=100B
configs so grok-1-314b fits 16 GiB/chip — DESIGN.md §5) and sharded
identically to their parameters. Update math runs in f32 regardless.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0          # global-norm clip; 0 disables
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jax.Array                 # i32 scalar
    mu: Any                         # first moments  (pytree like params)
    nu: Any                         # second moments (pytree like params)


def _mdt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def adamw_init(params: Any, opt: AdamWConfig) -> AdamWState:
    mdt = _mdt(opt.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 opt: AdamWConfig, lr: Optional[jax.Array] = None
                 ) -> tuple[Any, AdamWState, dict]:
    """One AdamW step. ``lr`` overrides ``opt.lr`` (schedules).

    Large (layer-stacked) leaves are updated through a ``lax.map`` over
    the leading axis, so the f32 temporaries of the update math live for
    one layer slice at a time instead of the whole stack — without this,
    the optimizer's transient f32 copies (g32/m32/v32/delta per leaf) are
    the single largest memory term of a 314B-parameter train step.
    """
    lr = opt.lr if lr is None else lr
    if opt.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, opt.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    c1 = 1.0 - opt.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - opt.b2 ** step.astype(jnp.float32)
    mdt = _mdt(opt.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = opt.b1 * m.astype(jnp.float32) + (1 - opt.b1) * g32
        v32 = opt.b2 * v.astype(jnp.float32) + (1 - opt.b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps)
        if opt.weight_decay:
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    # plain tuples are the (p, mu, nu) triples produced by ``upd``;
    # NamedTuple containers (e.g. repro.tune's PolicyParams) are pytree
    # structure and must still be traversed
    _triple = lambda x: (isinstance(x, tuple)  # noqa: E731
                         and not hasattr(x, "_fields"))
    flat = jax.tree.map(upd, params, grads, state.mu, state.nu,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=_triple)
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=_triple)
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=_triple)
    stats = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, AdamWState(step, new_mu, new_nu), stats
