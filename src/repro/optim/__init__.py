"""Optimizer substrate: AdamW, schedules, clipping, gradient compression."""

from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_init,
                               adamw_update, clip_by_global_norm,
                               global_norm)
from repro.optim.schedule import constant, warmup_cosine
from repro.optim.compress import (compressed_pmean, init_error_feedback,
                                  quantize_int8, dequantize)

__all__ = [
    "AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
    "clip_by_global_norm", "global_norm", "constant", "warmup_cosine",
    "compressed_pmean", "init_error_feedback", "quantize_int8",
    "dequantize",
]
