"""Int8 gradient compression with error feedback.

Targets the *cross-pod* gradient reduction: the pod axis rides the slow
DCN/inter-pod links, so halving on-wire bytes (bf16 -> int8 + one f32
scale per tensor) directly shrinks the collective roofline term of the
multi-pod mesh. Error feedback (Seide et al., 2014; Karimireddy et al.,
2019) carries the quantisation residual into the next step, keeping
convergence unbiased in practice.

Wire scheme: each pod quantises its gradient to int8 with a per-tensor
scale, all-gathers the int8 payload + scales over the ``pod`` axis (small:
2..few pods) and de-quantise-sums locally. Intra-pod reductions stay in
bf16/f32 via GSPMD — only the slow link is compressed.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, err: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback int8 quantisation.

    Returns (q int8, scale f32 scalar, new_err f32 like x).
    """
    xf = x.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(xf)) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_pmean_leaf(g: jax.Array, err: jax.Array, axis_name: str
                          ) -> tuple[jax.Array, jax.Array]:
    """Mean-reduce one gradient leaf over ``axis_name`` on an int8 wire.

    Must run inside ``shard_map``/``pmap`` with ``axis_name`` bound.
    """
    q, scale, new_err = quantize_int8(g, err)
    qs = jax.lax.all_gather(q, axis_name)              # [P, ...] int8 wire
    ss = jax.lax.all_gather(scale, axis_name)          # [P] f32
    n = qs.shape[0]
    total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)
    return (total / n).astype(g.dtype), new_err


def compressed_pmean(grads: Any, err: Any, axis_name: str
                     ) -> tuple[Any, Any]:
    """Tree-wide int8 error-feedback mean over ``axis_name``."""
    out = jax.tree.map(
        lambda g, e: compressed_pmean_leaf(g, e, axis_name), grads, err)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_err
