"""CI benchmark gate: run the fleet/dispatch benchmarks in a fixed-seed
smoke configuration, write the results to ``BENCH_ci.json`` at the repo
root, and fail (exit 1) when a headline metric regresses more than the
tolerance against the previously *committed* baseline.

Gated metrics are the machine-relative **speedups** (fused/vectorized
path vs the per-row / per-hour Python loop on the same host), not
absolute rows/s: CI runners and dev laptops differ by integer factors in
absolute throughput, but the fused-vs-loop ratio is the property the
fleet and dispatch engines exist to provide, and a >30% drop in it means
someone de-fused a hot path. Absolute numbers are recorded alongside for
inspection.

  PYTHONPATH=src python -m benchmarks.check_regression          # gate
  PYTHONPATH=src python -m benchmarks.check_regression --reset  # reseed

The smoke shapes are fixed-seed and small enough for a CI runner; the
full-size headline numbers live in `bench_fleet` / `bench_dispatch` via
`python -m benchmarks.run`.
"""

from __future__ import annotations

import argparse
import json
import platform
import statistics
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_BASELINE = REPO_ROOT / "BENCH_ci.json"
TOLERANCE = 0.30          # fail when a gated metric drops >30%
SMOKE_RUNS = 3            # gate on the median of this many suite runs
LOW_WATER = 0.5           # --reset seeds baseline at median x this:
                          # the committed baseline is a low-water mark,
                          # so host jitter (shared CI runners swing
                          # single timings ~1.5x) doesn't flake the
                          # gate, while de-fusing a hot path (the 5-30x
                          # effects this gate exists for) still trips it

# name -> (runner, smoke kwargs, gated metric keys, recorded extras[,
# runs]) — `runs` overrides SMOKE_RUNS for suites whose gated metrics
# are fixed-seed deterministic (medians of identical values only burn
# CI time)
def _suites():
    from benchmarks import (bench_dispatch, bench_faults, bench_fleet,
                            bench_live, bench_tune, bench_tune_coupled,
                            bench_workload)
    return {
        # shapes sized so the fused calls take tens of ms: smaller smoke
        # runs time nothing but host jitter and the gate flakes
        "bench_fleet": (
            bench_fleet.bench_fleet,
            dict(n_markets=8, n_systems=4, hours=4096, baseline_rows=16),
            ("speedup",),
            ("rows_per_s_vectorized", "rows_per_s_python_loop", "rows")),
        # fault-support overhead on the same gated fleet shape:
        # fault_mask_speed_ratio (~1.0) gates that healthy runs pay
        # nothing for fault plumbing (trivial masks short-circuit to
        # the plain program — removing the short-circuit costs ~20-60%
        # and trips); fault_storm_speed_ratio (~0.4-0.7) is the masked
        # program's low-water mark — a structural regression (host
        # round-trip per hour) costs integer factors
        "bench_faults": (
            bench_faults.bench_faults,
            dict(n_markets=8, n_systems=4, hours=4096),
            ("fault_mask_speed_ratio", "fault_storm_speed_ratio"),
            ("rows_per_s_plain", "rows_per_s_zero_fault",
             "rows_per_s_forced_masked", "rows_per_s_storm", "rows",
             "storm_events", "bit_identical_masked_zero_fault")),
        # workload-coupling overhead on the same gated fleet shape:
        # workload_short_circuit_ratio (~1.0) gates that no-Workload
        # configs pay nothing for the ledger plumbing (they
        # short-circuit to the plain program), and
        # workload_coupled_speed_ratio is the fused fleet+ledger
        # program's low-water mark — sampling demand in-scan or a
        # de-fused per-draw loop costs integer factors and trips it
        "bench_workload": (
            bench_workload.bench_workload,
            dict(n_markets=8, n_systems=4, hours=4096, n_draws=8),
            ("workload_short_circuit_ratio",
             "workload_coupled_speed_ratio"),
            ("rows_per_s_plain", "rows_per_s_zero_workload",
             "rows_per_s_coupled", "rows", "n_draws",
             "bit_identical_coupled_fleet_report")),
        "bench_dispatch": (
            bench_dispatch.bench_dispatch,
            dict(n_sites=32, hours=4096, baseline_hours=256),
            ("speedup",),
            ("hours_per_s_fused", "hours_per_s_python_loop", "sites",
             "bit_identical_pallas_vs_ref")),
        # gates the tuner's fused-VJP advantage over the native-autodiff
        # backward it replaced (same machine-relative-speedup logic: a
        # drop means someone de-fused the tuner's backward pass), and —
        # since PR 6 — the telemetry-off/on speed ratio of the same
        # loop: `repro.obs` keeps overhead within host-timing noise
        # (ratio ~0.85-1.1 run to run), so the committed low-water gate
        # sits near 0.5x that; a real violation (telemetry staged
        # inside the hot loop instead of riding side-outputs) costs
        # integer factors and trips it
        "bench_tune": (
            bench_tune.bench_tune,
            dict(n_markets=4, n_systems=2, hours=1024, steps=40,
                 repeats=2, with_optimize=False),
            ("speedup_fused_vs_native", "telemetry_speed_ratio"),
            ("row_steps_per_s_fused", "row_steps_per_s_native", "rows",
             "steps", "temp_bytes_fused", "temp_bytes_native",
             "telemetry_overhead_frac")),
        # correctness gates, not speed: fd_grad_margin is 1e-3 over the
        # worst FD-vs-autodiff relative error of the dispatch-aware
        # objective in f64 (collapses by orders of magnitude if someone
        # breaks the soft water-fill's implicit gradient), and
        # dispatch_cpc_edge is the fixed-seed fleet-CPC advantage of
        # tuning *through* dispatch over re-scoring after the fact
        "bench_tune_dispatch": (
            bench_tune.bench_tune_dispatch,
            dict(n_markets=3, hours=512, steps=40),
            ("fd_grad_margin", "dispatch_cpc_edge"),
            ("cpc_rescore", "cpc_aware", "chosen_rescore",
             "chosen_aware", "rows", "steps"),
            1),   # fixed-seed deterministic: one run suffices
        # the coupled-fleet pair: speedup_dispatch_vjp gates the fused
        # soft-dispatch backward's edge over native autodiff (backward
        # time only — the forwards are the same bisection math), and
        # coupled_shard_ulp_ok (1.0/0.0) gates the psum-reduced sharded
        # objective's ULP agreement with the single program — a
        # correctness bit, so ANY drop trips the 30% tolerance
        "bench_tune_coupled": (
            bench_tune_coupled.bench_tune_coupled,
            dict(n_sites=64, hours=336, batch=16, rows_cfg=(8, 4, 8),
                 steps=12, repeats=3),
            ("speedup_dispatch_vjp", "coupled_shard_ulp_ok"),
            ("bwd_s_native", "bwd_s_fused", "err_ulp", "n_shards",
             "rows_per_s_sharded", "rows_per_s_single", "rows",
             "sites", "batch")),
        # gates the live controller's batched-scan edge over the
        # per-hour Python re-plan loop (both re-solve families in the
        # baseline, weighted by the sweep mix) — the number that makes
        # a controller-design sweep affordable; it collapses if the
        # outer scan is ever unrolled back to host steps
        "bench_live": (
            bench_live.bench_live,
            dict(n_markets=2, hours=1024, baseline_hours=128,
                 repeats=2),
            ("speedup_live",),
            ("controller_hours_per_s_jitted",
             "controller_hours_per_s_python", "rows",
             "frac_tuned_rows", "cpc_mean")),
    }


def run_smoke() -> dict:
    """Median of `SMOKE_RUNS` runs per gated metric: single timing runs
    of small smoke shapes are noisy (host scheduling, GC), and a flaky
    gate trains people to ignore it."""
    results = {}
    for name, (fn, kwargs, gated, extras, *rest) in _suites().items():
        runs = rest[0] if rest else SMOKE_RUNS
        outs = [fn(**kwargs) for _ in range(runs)]
        results[name] = {
            "measured": {k: statistics.median(o[k] for o in outs)
                         for k in gated},
            "info": {k: outs[-1][k] for k in extras},
            "smoke_config": kwargs,
        }
    return {"tolerance": TOLERANCE,
            "host": {"machine": platform.machine(),
                     "python": platform.python_version()},
            "results": results}


def compare(old: dict, new: dict) -> list[str]:
    failures = []
    for name, entry in old.get("results", {}).items():
        fresh = new["results"].get(name)
        if fresh is None:
            failures.append(f"{name}: benchmark missing from this run")
            continue
        for key, base in entry.get("gated", {}).items():
            got = fresh["measured"].get(key)
            if got is None:
                failures.append(f"{name}.{key}: metric missing")
            elif got < base * (1.0 - TOLERANCE):
                failures.append(
                    f"{name}.{key}: {got:.2f} vs baseline {base:.2f} "
                    f"(-{(1.0 - got / base):.0%} > {TOLERANCE:.0%} "
                    "tolerance)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="committed baseline to compare against (and "
                    "overwrite with this run's results)")
    ap.add_argument("--reset", action="store_true",
                    help="reseed the baseline without comparing")
    args = ap.parse_args()

    old = None
    if args.baseline.exists() and not args.reset:
        old = json.loads(args.baseline.read_text())

    new = run_smoke()
    # the low-water "gated" values are the baseline contract: a plain
    # run carries the committed ones forward (so accidentally committing
    # the overwritten file cannot tighten the gate onto raw jitter) and
    # only --reset reseeds them from this run's medians
    for name, entry in new["results"].items():
        if old is not None and name in old.get("results", {}):
            entry["gated"] = dict(old["results"][name].get("gated", {}))
        else:
            entry["gated"] = {k: v * LOW_WATER
                              for k, v in entry["measured"].items()}
    new["seeded_low_water"] = LOW_WATER
    args.baseline.write_text(json.dumps(new, indent=1) + "\n")
    print(f"wrote {args.baseline}")
    for name, entry in new["results"].items():
        print(f"  {name}: " + ", ".join(
            f"{k}={v:.2f} (gate {entry['gated'][k]:.2f})"
            for k, v in entry["measured"].items()))

    failures = [] if old is None else compare(old, new)
    _append_history(args.baseline, new, failures)
    if old is None:
        print("no baseline to compare against (seeded)")
        return 0
    if failures:
        print("benchmark regression gate FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"gate passed (tolerance {TOLERANCE:.0%})")
    return 0


def _append_history(baseline: Path, new: dict, failures: list) -> None:
    """Append this gated run to ``BENCH_history.jsonl`` next to the
    baseline: the baseline file is a low-water *contract* that plain
    runs overwrite in place, so without the history every trajectory
    point between resets is lost. One JSON line per run — measured
    medians, the gate verdict, and the `repro.obs` attribution stamp —
    gitignored locally, uploaded as a CI artifact."""
    try:
        from repro.obs import run_metadata
        meta = run_metadata()
    except Exception:
        meta = {"python": platform.python_version(),
                "machine": platform.machine()}
    entry = {
        "run_meta": meta,
        "measured": {name: dict(e["measured"])
                     for name, e in new["results"].items()},
        "gated": {name: dict(e.get("gated", {}))
                  for name, e in new["results"].items()},
        "gate_passed": not failures,
        "failures": failures,
    }
    path = baseline.parent / "BENCH_history.jsonl"
    with path.open("a", encoding="utf-8") as fh:
        fh.write(json.dumps(entry) + "\n")
    print(f"appended run to {path}")


if __name__ == "__main__":
    raise SystemExit(main())
