"""One coupled fleet at scale: the psum-reduced sharded objective vs
the single program, and the fused soft-dispatch VJP vs native autodiff.

Two questions, two headline numbers:

  * ``speedup_dispatch_vjp`` — backward-only throughput ratio of the
    fused custom VJP of `repro.kernels.soft_dispatch` over native
    autodiff through the per-hour scan, at S=64 sites (vmapped over a
    batch of fleets so the loop overhead amortizes the way the tuner's
    batched use does). The *forward* passes are the same math
    (bisection-dominated), so the honest A/B subtracts the forward's
    median wall time from the grad call's: what is gated is the cost of
    the backward alone — the part the custom VJP replaces.
  * ``coupled_shard_ulp_ok`` — 1.0 when the coupled objective evaluated
    under `shard_map` (`repro.tune.sharded_soft_objective`: fleet
    aggregates psum-reduced across the row mesh) matches the
    single-program ``reduction='sum'`` loss on the acceptance grid to a
    few ULP; 0.0 otherwise. This is the correctness gate of the
    sharded-but-coupled rework — a refactor that silently turns the
    psum reassembly into an approximation trips it.

Also recorded: coupled-tuning rows/s under the explicit sharded plan vs
the single program (``rows_per_s_sharded`` / ``rows_per_s_single``) —
informational on CI hosts (virtual CPU devices share the same cores, so
sharding there measures overhead, not speedup; the number exists to
show the path runs at scale, and its real value needs real devices).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timed, write_artifact
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig, segment_keys, segment_rank
from repro.energy.presets import region_params
from repro.execution import ExecutionPlan
from repro.fleet import PolicySpec, build_grid
from repro.kernels.soft_dispatch import soft_dispatch
from repro.tune import (TuneConfig, dispatch_coupling_from_grid,
                        init_from_grid, optimize, problem_from_grid,
                        sharded_soft_objective, soft_objective)

_DCFG = DispatchConfig(demand_frac=0.25, migrate_cost=4.0, min_dwell_h=2)


def _dispatch_instance(n_sites: int, hours: int, batch: int, seed: int = 0):
    """A batched synthetic dispatch instance: [B, S, T] availability
    over shared [S, T] prices (keys/order precomputed once, exactly as
    `dispatch_coupling_from_grid` hands them to the objective)."""
    rng = np.random.RandomState(seed)
    prices = 60.0 + 25.0 * rng.randn(n_sites, hours)
    avail = rng.uniform(0.2, 1.0, (batch, n_sites, hours))
    demand = np.full((batch, hours), 0.35 * n_sites)
    keys = segment_keys(prices, float(_DCFG.migrate_cost))
    order, _ = segment_rank(prices, float(_DCFG.migrate_cost), keys=keys)
    return (jnp.asarray(avail, jnp.float32), jnp.asarray(keys),
            jnp.asarray(order, jnp.int32),
            jnp.asarray(demand, jnp.float32))


def _grid(n_markets: int, n_systems: int, n_policies: int, hours: int):
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    psis = np.geomspace(0.5, 4.0, n_systems)
    systems = [make_system(float(psi) * hours * 1.0 * p_avg, 1.0,
                           float(hours)) for psi in psis]
    policies = [PolicySpec(f"x{i}", x=float(x), off_level=0.3)
                for i, x in enumerate(
                    np.linspace(0.02, 0.3, n_policies))]
    return build_grid(markets, systems, policies)


def bench_dispatch_vjp(n_sites: int = 64, hours: int = 336,
                       batch: int = 16, tau: float = 2.0,
                       repeats: int = 3) -> dict:
    """Fused-vs-native soft-dispatch backward at S=``n_sites``."""
    avail, keys, order, demand = _dispatch_instance(n_sites, hours,
                                                    batch)

    def loss_of(fused):
        def loss(a, d):
            al = jax.vmap(lambda ai, di: soft_dispatch(
                ai, keys, order, di, tau=tau,
                min_dwell=_DCFG.min_dwell_h, use_pallas=False,
                fused=fused))(a, d)
            return jnp.sum(al * jnp.asarray(0.5))
        return loss

    out = {"sites": n_sites, "hours": hours, "batch": batch}
    times = {}
    for name, fused in (("native", False), ("fused", True)):
        loss = loss_of(fused)
        fwd = jax.jit(loss)
        grad = jax.jit(jax.grad(loss))
        jax.block_until_ready(fwd(avail, demand))          # compile
        jax.block_until_ready(grad(avail, demand))
        _, fwd_us = timed(lambda: jax.block_until_ready(
            fwd(avail, demand)), repeats=repeats, stat="median")
        _, grad_us = timed(lambda: jax.block_until_ready(
            grad(avail, demand)), repeats=repeats, stat="median")
        times[name] = (fwd_us, grad_us)
        out[f"fwd_s_{name}"] = fwd_us / 1e6
        out[f"grad_s_{name}"] = grad_us / 1e6
    # backward-only: the grad call runs forward + backward; the fused
    # and native forwards are the same bisection-dominated math, so the
    # difference of medians isolates the backward the VJP replaces
    bwd_native = max(times["native"][1] - times["native"][0], 1e3)
    bwd_fused = max(times["fused"][1] - times["fused"][0], 1e3)
    out["bwd_s_native"] = bwd_native / 1e6
    out["bwd_s_fused"] = bwd_fused / 1e6
    out["speedup_dispatch_vjp"] = bwd_native / bwd_fused
    return out


def bench_coupled_shard(rows_cfg=(8, 4, 8), hours: int = 336,
                        steps: int = 12, tau: float = 5.0,
                        repeats: int = 2) -> dict:
    """Coupled-sharded vs single-program: ULP agreement of the loss on
    the acceptance grid, plus tuned rows/s under both plans."""
    grid = _grid(*rows_cfg, hours)
    problem = problem_from_grid(grid)
    raw = init_from_grid(grid)
    coupling = dispatch_coupling_from_grid(grid, _DCFG)
    b = grid.n_rows

    kw = dict(dispatch_blend=0.5, dispatch_min_dwell=_DCFG.min_dwell_h,
              penalty_weight=10.0, power_cap_mw=0.6 * float(
                  np.sum(np.asarray(grid.power)
                         * np.asarray(problem.site_weight))))
    single, _ = jax.jit(lambda r: soft_objective(
        r, problem, tau, dispatch=coupling, reduction="sum", **kw))(raw)
    n_dev = max(1, min(8, len(jax.devices()), b // 2))
    while b % n_dev:
        n_dev -= 1
    sharded = sharded_soft_objective(raw, problem, tau, n_dev=n_dev,
                                     coupling=coupling,
                                     dispatch_min_dwell=kw[
                                         "dispatch_min_dwell"],
                                     dispatch_blend=kw["dispatch_blend"],
                                     penalty_weight=kw["penalty_weight"],
                                     power_cap_mw=kw["power_cap_mw"])
    single_f, sharded_f = float(single), float(sharded)
    ulp = float(np.spacing(np.abs(np.float32(single_f))))
    err_ulp = abs(sharded_f - single_f) / ulp
    out = {
        "rows": b, "hours": hours, "n_shards": n_dev,
        "loss_single": single_f, "loss_sharded": sharded_f,
        "err_ulp": err_ulp,
        # 4 ULP headroom: reassembly is one psum + one add
        "coupled_shard_ulp_ok": 1.0 if err_ulp <= 4.0 else 0.0,
    }

    # rows/s of the full coupled tuning loop under both plans
    from repro.execution import Coupling
    coup = Coupling(dispatch=_DCFG)
    for label, plan in (("single", ExecutionPlan(mode="single")),
                        ("sharded", ExecutionPlan(mode="sharded"))):
        cfg = TuneConfig(steps=steps, plan=plan, coupling=coup)
        optimize(grid, cfg)                                # compile
        _, us = timed(lambda: optimize(grid, cfg), repeats=repeats,
                      stat="median")
        out[f"rows_per_s_{label}"] = b * steps / (us / 1e6)
    return out


def bench_tune_coupled(n_sites: int = 64, hours: int = 336,
                       batch: int = 16, rows_cfg=(8, 4, 8),
                       steps: int = 12, repeats: int = 3) -> dict:
    """The headline suite `benchmarks.check_regression` gates."""
    out = bench_dispatch_vjp(n_sites=n_sites, hours=hours, batch=batch,
                             repeats=repeats)
    out.update(bench_coupled_shard(rows_cfg=rows_cfg, hours=hours,
                                   steps=steps,
                                   repeats=max(1, repeats - 1)))
    write_artifact("bench_tune_coupled", out)
    return out


ALL = {"bench_tune_coupled": bench_tune_coupled}


if __name__ == "__main__":
    import json
    print(json.dumps(bench_tune_coupled(), indent=2, default=float))
