"""Workload-coupling overhead: what an exogenous-demand run pays for
workload support, and what the coupled ledger costs.

`repro.workload.workload_backtest` threads a [B, G, deadline] queue
carry plus a [G, T] demand stream through the fleet scan — a real cost.
The contract is that configs without a `Workload` never pay it:
zero-workload calls short-circuit to the plain backtest program, so
``workload_short_circuit_ratio`` (plain time / zero-workload time) sits
at ~1.0 and its committed baseline plus the 30% gate tolerance trips if
someone removes the short-circuit. ``workload_coupled_speed_ratio``
(plain time / coupled time at G demand draws) is the low-water mark for
the fused program itself: a structural regression — sampling demand
inside the scan, a host round-trip per hour, or a de-fused per-draw
loop — costs integer factors and trips it. The fleet half of the fused
scan must stay a bitwise no-op (the ledger rides the carry without
feeding back), checked field-for-field on the FleetReport."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_fleet import _fleet_grid
from benchmarks.common import timed, write_artifact
from repro.fleet import backtest
from repro.workload import Workload, workload_backtest


def bench_workload(n_markets: int = 8, n_systems: int = 4,
                   hours: int = 4096, n_draws: int = 8) -> dict:
    grid = _fleet_grid(n_markets, n_systems, hours)
    b = grid.n_rows
    wl = Workload(n_draws=n_draws, seed=7)

    def run_plain():
        rep = backtest(grid, use_pallas=False)
        jax.block_until_ready(rep.cpc)
        return rep

    def run_zero_workload():
        res = workload_backtest(grid)
        jax.block_until_ready(res.report.cpc)
        return res

    def run_coupled():
        res = workload_backtest(grid, wl)
        jax.block_until_ready(res.report.cpc)
        return res

    rep_plain, us_plain = timed(run_plain, repeats=3)
    res_zero, us_zero = timed(run_zero_workload, repeats=3)
    res_coupled, us_coupled = timed(run_coupled, repeats=3)

    identical = all(
        np.array_equal(np.asarray(getattr(rep_plain, f)),
                       np.asarray(getattr(res_coupled.report, f)))
        for f in rep_plain._fields)

    return {
        "rows": b,
        "hours": hours,
        "n_draws": n_draws,
        "workload_short_circuit_ratio": us_plain / us_zero,
        "workload_coupled_speed_ratio": us_plain / us_coupled,
        "rows_per_s_plain": b / (us_plain * 1e-6),
        "rows_per_s_zero_workload": b / (us_zero * 1e-6),
        "rows_per_s_coupled": b / (us_coupled * 1e-6),
        "bit_identical_coupled_fleet_report": identical,
        "cpc_p50_mean": float(np.mean(
            np.asarray(res_coupled.workload.cpc_p50))),
        "drop_frac": float(
            np.sum(np.asarray(res_coupled.workload.dropped_mwh))
            / max(np.sum(np.asarray(res_coupled.workload.arrivals_mwh)),
                  1e-9)),
    }


ALL = {"bench_workload": bench_workload}


def main() -> None:
    out = bench_workload()
    print(f"fleet: {out['rows']} rows x {out['hours']} h x "
          f"{out['n_draws']} demand draws")
    print(f"plain backtest      : {out['rows_per_s_plain']:>12.0f} rows/s")
    print(f"zero-workload       : "
          f"{out['rows_per_s_zero_workload']:>12.0f} rows/s  "
          f"(ratio {out['workload_short_circuit_ratio']:.3f} — "
          "no-Workload configs short-circuit)")
    print(f"coupled ledger      : {out['rows_per_s_coupled']:>12.0f} "
          f"rows/s  (ratio {out['workload_coupled_speed_ratio']:.3f}, "
          f"fleet half bit-identical: "
          f"{out['bit_identical_coupled_fleet_report']})")
    print(f"coupled CPC p50 mean {out['cpc_p50_mean']:.1f} EUR/MWh, "
          f"drop fraction {out['drop_frac']:.3f}")
    write_artifact("bench_workload", out)


if __name__ == "__main__":
    main()
