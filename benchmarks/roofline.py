"""§Roofline: assemble the per-(arch x shape x mesh) roofline table from
dry-run artifacts (benchmarks/artifacts/dryrun/...).

  PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import ARTIFACTS, write_artifact
from repro.configs.base import SHAPES, get_config
from repro.launch.roofline import (RooflineRow, roofline_from_record)

DRYRUN = ARTIFACTS / "dryrun"


def load_rows(mesh_dir: str) -> list[RooflineRow]:
    rows = []
    for f in sorted((DRYRUN / mesh_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            continue
        cfg = get_config(rec["arch"])
        rows.append(roofline_from_record(rec, cfg, SHAPES[rec["shape"]]))
    return rows


def to_markdown(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound "
           "| useful frac | MFU @roofline | fits |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r.arch} | {r.shape} | {r.compute_s:.3g} | {r.memory_s:.3g} "
        f"| {r.collective_s:.3g} | **{r.bound}** | {r.useful_frac:.2f} "
        f"| {r.mfu:.1%} | {'y' if r.fits else 'NO'} |\n"
        for r in rows)
    return hdr + body


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.mesh)
    if not rows:
        print(f"no artifacts under {DRYRUN / args.mesh}; "
              "run `python -m repro.launch.dryrun` first")
        return 1
    payload = {f"{r.arch}__{r.shape}": {
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "bound": r.bound,
        "model_flops": r.model_flops,
        "hlo_flops_global": r.hlo_flops_global,
        "useful_frac": r.useful_frac, "mfu_at_roofline": r.mfu,
        "fits": r.fits, "peak_gib": r.peak_gib,
    } for r in rows}
    write_artifact(f"roofline_{args.mesh}", payload)
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(f"{r.arch:18s} {r.shape:12s} c={r.compute_s:9.3g} "
                  f"m={r.memory_s:9.3g} x={r.collective_s:9.3g} "
                  f"{r.bound:10s} useful={r.useful_frac:5.2f} "
                  f"mfu={r.mfu:6.1%} fits={r.fits}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
