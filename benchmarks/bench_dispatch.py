"""Cross-site dispatch throughput: hours/sec of the fused dispatch scan
(Pallas kernel on TPU, jitted sequential reference elsewhere) vs the
per-hour Python loop it replaces (one host-side allocation step per
hour), plus the bit-identity check between the Pallas kernel (interpret
mode off-TPU) and `dispatch_ref`."""

from __future__ import annotations

import functools
import time

import jax
import numpy as np

from benchmarks.common import timed, write_artifact
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig, build_problem, dispatch
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, build_grid
from repro.kernels.dispatch_scan import dispatch_scan
from repro.kernels.ref import dispatch_alloc_hour, dispatch_ref


def _site_problem(n_sites: int, hours: int, cfg: DispatchConfig):
    """S sites = S seeds of the calibrated German market, each running a
    5%-shutdown hysteresis policy resolved against its own PV set (the
    `build_grid` machinery with one system and one policy)."""
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_sites)]
    p_avg = markets[0].p_avg
    system = make_system(2.0 * hours * 1.0 * p_avg, 1.0, float(hours))
    grid = build_grid(markets, [system],
                      [PolicySpec("x5h", x=0.05, hysteresis=0.9,
                                  off_level=0.25)])
    return build_problem(np.asarray(grid.prices), grid.p_on, grid.p_off,
                         grid.off_level, grid.power, cfg,
                         fixed=np.asarray(grid.fixed))


def bench_dispatch(n_sites: int = 64, hours: int = 8760,
                   baseline_hours: int = 96) -> dict:
    """S=64 sites x 8760 h feasible dispatch in one fused call."""
    cfg = DispatchConfig(demand_frac=0.4, migrate_cost=5.0, min_dwell_h=4)
    problem = _site_problem(n_sites, hours, cfg)

    def run_fused():
        res = dispatch(problem)          # auto path: pallas on TPU
        return res

    res, us_fused = timed(run_fused, repeats=3)

    # per-hour Python loop baseline: the same allocation, one host-side
    # jitted step per hour (as a non-fused implementation would run it).
    # Timed on the first `baseline_hours` hours and extrapolated.
    order, rank = problem.order, problem.rank
    step = jax.jit(functools.partial(dispatch_alloc_hour,
                                     min_dwell=problem.min_dwell_h))
    avail = np.asarray(problem.avail_mw, np.float32)
    demand = np.asarray(problem.demand_mw, np.float32)
    prev = np.zeros(n_sites, np.float32)
    dwell = np.zeros(n_sites, np.float32)
    jax.block_until_ready(step(prev, dwell, avail[:, 0], order[0],
                               rank[0], demand[0]))           # compile
    # per-call minimum: like `timed`, the floor is the stable estimator
    # of what a call costs (interrupt/GC outliers only ever add time)
    state = (prev, dwell)
    loop_s_per_hour = float("inf")
    for h in range(baseline_hours):
        t0 = time.perf_counter()
        alloc, dw = step(state[0], state[1], avail[:, h], order[h],
                         rank[h], demand[h])
        state = (jax.block_until_ready(alloc), dw)
        loop_s_per_hour = min(loop_s_per_hour,
                              time.perf_counter() - t0)

    # the loop is the same math: its prefix must match the fused result
    max_prefix_err = float(np.abs(
        np.asarray(state[0]) - res.alloc_mw[:, baseline_hours - 1]).max())

    # bit-identity: Pallas kernel (interpret mode off-TPU) vs dispatch_ref
    a_pal = np.asarray(dispatch_scan(problem.avail_mw, order, rank,
                                     problem.demand_mw,
                                     min_dwell=problem.min_dwell_h))
    a_ref = np.asarray(dispatch_ref(problem.avail_mw, order, rank,
                                    problem.demand_mw,
                                    min_dwell=problem.min_dwell_h))
    max_abs_err = float(np.abs(a_pal - a_ref).max())

    hours_per_s_fused = hours / (us_fused / 1e6)
    hours_per_s_loop = 1.0 / loop_s_per_hour
    out = {
        "sites": n_sites,
        "hours": hours,
        "hours_per_s_fused": hours_per_s_fused,
        "hours_per_s_python_loop": hours_per_s_loop,
        "speedup": hours_per_s_fused / hours_per_s_loop,
        "baseline_hours_sampled": baseline_hours,
        "max_abs_err_pallas_vs_ref": max_abs_err,
        "bit_identical_pallas_vs_ref": bool(np.array_equal(a_pal, a_ref)),
        "max_abs_err_loop_prefix": max_prefix_err,
        "cpc": res.cpc,
        "n_migrations": res.n_migrations,
        "migration_cost_frac": res.migration_cost
        / max(res.energy_cost + res.migration_cost, 1e-9),
    }
    write_artifact("bench_dispatch", out)
    return out


ALL = {"bench_dispatch": bench_dispatch}
