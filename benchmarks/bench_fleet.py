"""Fleet engine throughput: rows/sec of the single-jit vectorized
backtest vs the per-row Python loop it replaces (the pre-fleet
`policy_cpc` path, one scenario at a time)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import timed, write_artifact
from repro.core.policy import hysteresis_policy, policy_cpc
from repro.core.tco import SystemCosts, make_system
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, backtest, build_grid, elastic_policy


def _fleet_grid(n_markets: int, n_systems: int, hours: int):
    markets = [region_params("germany", seed=s) for s in range(n_markets)]
    for i, mp in enumerate(markets):
        markets[i] = mp.replace(n_hours=hours)
    p_avg = markets[0].p_avg           # generator rescales to this exactly
    psis = np.geomspace(0.5, 6.0, n_systems)
    systems = [make_system(float(psi) * hours * 1.0 * p_avg, 1.0,
                           float(hours)) for psi in psis]
    policies = [
        PolicySpec("always_on"),
        PolicySpec("x1", x=0.01),
        PolicySpec("x2", x=0.02),
        PolicySpec("x5", x=0.05),
        PolicySpec("x2_hyst", x=0.02, hysteresis=0.9,
                   restart_energy_mwh=0.3, restart_time_h=0.25),
        PolicySpec("x5_hyst", x=0.05, hysteresis=0.85,
                   restart_energy_mwh=0.3, restart_time_h=0.25),
        PolicySpec("x5_idle", x=0.05, idle_frac=0.05),
        elastic_policy("x5_half_dp", level=0.5, dp_total=16, x=0.05),
    ]
    return build_grid(markets, systems, policies)


def bench_fleet(n_markets: int = 16, n_systems: int = 8,
                hours: int = 8760, baseline_rows: int = 32) -> dict:
    """16 x 8 x 8 x 8760 h = 1024 scenario rows in one jitted call."""
    grid = _fleet_grid(n_markets, n_systems, hours)
    b = grid.n_rows

    def run_vectorized():
        rep = backtest(grid, use_pallas=False)
        jax.block_until_ready(rep.cpc)
        return rep

    rep, us_vec = timed(run_vectorized, repeats=3)

    # per-row Python loop baseline: the single-trace path, one row at a
    # time (jitted once; the loop itself is host-side, as it was before
    # the fleet engine existed). Timed on a sample and extrapolated.
    @jax.jit
    def _one_row(prices, p_on, p_off, idle, re_mwh, rt_h, f, c, t):
        mask = hysteresis_policy(prices, p_on, p_off)
        return policy_cpc(SystemCosts(f, c, t), prices, mask,
                          idle_power_frac=idle, restart_energy_mwh=re_mwh,
                          restart_time_h=rt_h)

    # partial-capacity rows are inexpressible in the single-trace path —
    # exactly the capability gap the fleet engine closes — so the sanity
    # comparison samples only full-shutdown rows.
    full_shutdown = np.flatnonzero(np.asarray(grid.off_level) == 0.0)
    sample = full_shutdown[np.linspace(0, len(full_shutdown) - 1,
                                       baseline_rows).astype(int)]
    args = [(grid.prices[int(grid.market_idx[r])], grid.p_on[r],
             grid.p_off[r], grid.idle_frac[r], grid.restart_energy_mwh[r],
             grid.restart_time_h[r], grid.fixed[r], grid.power[r],
             grid.period[r]) for r in sample]
    _one_row(*args[0]).block_until_ready()            # compile
    # per-call minimum: like `timed`, the floor is the stable estimator
    # of what a call costs (interrupt/GC outliers only ever add time)
    loop_cpc, per_call = [], []
    for a in args:
        t0 = time.perf_counter()
        loop_cpc.append(float(_one_row(*a)))
        per_call.append(time.perf_counter() - t0)
    loop_s_per_row = min(per_call)

    # sanity: the loop reproduces the engine on the sampled rows (small
    # residual expected: hysteresis_policy resumes on strict p < p_on,
    # the engine on p <= p_on, and threshold rows sit exactly on samples)
    max_rel = float(np.max(np.abs(
        np.asarray(loop_cpc) - np.asarray(rep.cpc)[sample])
        / np.asarray(rep.cpc)[sample]))

    rows_per_s_vec = b / (us_vec / 1e6)
    rows_per_s_loop = 1.0 / loop_s_per_row
    out = {
        "rows": b,
        "hours": hours,
        "rows_per_s_vectorized": rows_per_s_vec,
        "rows_per_s_python_loop": rows_per_s_loop,
        "speedup": rows_per_s_vec / rows_per_s_loop,
        "baseline_rows_sampled": int(len(sample)),
        "max_rel_err_vs_loop": max_rel,
    }
    write_artifact("bench_fleet", out)
    return out


ALL = {"bench_fleet": bench_fleet}
