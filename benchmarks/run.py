"""Benchmark aggregator: one entry per paper figure/table + runtime
benches + the roofline table (if dry-run artifacts exist).

  PYTHONPATH=src python -m benchmarks.run [--only fig3_pv_intervals]
"""

from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (bench_dispatch, bench_faults, bench_fleet,
                        bench_live,
                        bench_runtime, bench_tune, bench_tune_coupled,
                        bench_workload, paper_figures)
from benchmarks.common import ARTIFACTS


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-runtime", action="store_true",
                    help="paper figures only (fast)")
    args = ap.parse_args()

    suites = dict(paper_figures.ALL)
    if not args.skip_runtime:
        suites.update(bench_fleet.ALL)
        suites.update(bench_dispatch.ALL)
        suites.update(bench_tune.ALL)
        suites.update(bench_tune_coupled.ALL)
        suites.update(bench_live.ALL)
        suites.update(bench_faults.ALL)
        suites.update(bench_workload.ALL)
        suites.update(bench_runtime.ALL)
    if args.only:
        suites = {k: v for k, v in suites.items() if k == args.only}

    failures = 0
    print(f"{'benchmark':28s} {'seconds':>8s}  headline")
    for name, fn in suites.items():
        t0 = time.perf_counter()
        try:
            out = fn()
            dt = time.perf_counter() - t0
            headline = _headline(name, out)
            print(f"{name:28s} {dt:8.2f}  {headline}")
        except Exception as e:                      # pragma: no cover
            failures += 1
            print(f"{name:28s} {'FAIL':>8s}  {type(e).__name__}: {e}")
            traceback.print_exc()

    # roofline (only if the dry-run has produced artifacts)
    dryrun = ARTIFACTS / "dryrun" / "pod16x16"
    if dryrun.exists() and any(dryrun.glob("*.json")):
        from benchmarks.roofline import load_rows
        rows = load_rows("pod16x16")
        n_fit = sum(r.fits for r in rows)
        bounds = {b: sum(1 for r in rows if r.bound == b)
                  for b in ("compute", "memory", "collective")}
        print(f"{'roofline(pod16x16)':28s} {'-':>8s}  "
              f"{len(rows)} cells, {n_fit} fit, bounds: {bounds}")
    print(f"artifacts -> {ARTIFACTS}")
    return 1 if failures else 0


def _headline(name: str, out: dict) -> str:
    if name == "fig3_pv_intervals":
        h = out["intervals"]["1h"]
        return (f"x_BE(1h)={h['x_be_pct']:.2f}% "
                f"(paper {out['paper']['x_be_pct_1h']}%), "
                f"weekly viable={out['intervals']['1w']['viable']}")
    if name == "fig4_de_vs_sa":
        return (f"x_BE DE={out['germany']['x_be_pct']:.1f}% "
                f"SA={out['south_australia']['x_be_pct']:.1f}% "
                f"(paper 3.3/25.7)")
    if name == "fig5_psi_sweep":
        psi8 = out.get("psi_for_8pct")
        return (f"Psi for 8% reduction: "
                f"{psi8:.2f}" if psi8 else "8% never reached"
                ) + f" (paper ~{out['paper_psi_for_8pct']})"
    if name == "fig6_combined":
        c = out["amplified+cheap_hw"]
        return (f"combined x_BE={c['x_be_pct']:.1f}% "
                f"x_opt={c['x_opt_pct']:.2f}% (paper 10.15/2.77)")
    if name == "table2_regions":
        import numpy as np
        errs = [abs(v["ours"]["x_be_pct"] - v["paper"]["x_be_pct"])
                for v in out.values()
                if v["paper"]["x_be_pct"] and v["ours"]["x_be_pct"]]
        return f"{len(out)} regions, mean |x_BE err| = {np.mean(errs):.2f}pp"
    if name == "energy_aware_training":
        return (f"CPC red: predicted {out['predicted_cpc_red_pct']:.2f}% "
                f"realized {out['realized_cpc_red_pct']:.2f}%")
    if name == "fig1_diurnal":
        return (f"evening - midday = {out['evening_minus_midday']:.1f} "
                "EUR/MWh")
    if name == "fig2_price_regions":
        return f"p_thresh(x=1.15%) = {out['p_thresh']:.1f} EUR/MWh"
    if name == "bench_fleet":
        return (f"{out['rows']} rows: {out['rows_per_s_vectorized']:.0f} "
                f"rows/s vectorized vs {out['rows_per_s_python_loop']:.1f} "
                f"per-row loop (x{out['speedup']:.0f})")
    if name == "bench_dispatch":
        return (f"{out['sites']} sites x {out['hours']} h: "
                f"{out['hours_per_s_fused']:.0f} h/s fused vs "
                f"{out['hours_per_s_python_loop']:.1f} per-hour loop "
                f"(x{out['speedup']:.0f}), pallas|ref err "
                f"{out['max_abs_err_pallas_vs_ref']:.1e}")
    if name == "bench_faults":
        return (f"{out['rows']} rows: zero-fault ratio "
                f"{out['fault_mask_speed_ratio']:.2f}, storm ratio "
                f"{out['fault_storm_speed_ratio']:.2f}, masked "
                f"bit-identical: {out['bit_identical_masked_zero_fault']}")
    if name == "bench_workload":
        return (f"{out['rows']} rows x {out['n_draws']} draws: "
                f"short-circuit ratio "
                f"{out['workload_short_circuit_ratio']:.2f}, coupled "
                f"ratio {out['workload_coupled_speed_ratio']:.2f}, "
                f"fleet half bit-identical: "
                f"{out['bit_identical_coupled_fleet_report']}")
    if name == "bench_tune":
        line = (f"{out['rows']} rows x {out['steps']} steps: "
                f"{out['row_steps_per_s_fused']:.0f} row-steps/s fused "
                f"vs {out['row_steps_per_s_native']:.0f} native "
                f"(x{out['speedup_fused_vs_native']:.1f})")
        if out.get("temp_reduction"):
            line += f", x{out['temp_reduction']:.1f} less scratch"
        if "rows_strictly_better" in out:
            line += (f"; {out['rows_strictly_better']}/{out['rows']} "
                     f"rows beat best swept")
        return line
    if name == "bench_tune_dispatch":
        return (f"{out['rows']} sites x {out['hours']} h: fleet CPC "
                f"aware {out['cpc_aware']:.2f} vs rescore "
                f"{out['cpc_rescore']:.2f} "
                f"(edge x{out['dispatch_cpc_edge']:.4f}), FD-grad "
                f"margin {out['fd_grad_margin']:.0f}")
    if name == "bench_tune_coupled":
        return (f"dispatch VJP bwd x{out['speedup_dispatch_vjp']:.1f} "
                f"fused-vs-native (S={out['sites']}, B={out['batch']}); "
                f"{out['rows']} rows / {out['n_shards']} shards: "
                f"err {out['err_ulp']:.1f} ULP "
                f"({'OK' if out['coupled_shard_ulp_ok'] else 'FAIL'})")
    if name == "bench_live":
        return (f"{out['rows']} controllers x {out['hours']} h: "
                f"{out['controller_hours_per_s_jitted']:.0f} ctrl-h/s "
                f"jitted vs {out['controller_hours_per_s_python']:.0f} "
                f"python re-plan (x{out['speedup_live']:.0f})")
    if name == "step_time":
        return ", ".join(f"{k}: {v['s_per_step']:.2f}s"
                         for k, v in out.items())
    return ""


if __name__ == "__main__":
    raise SystemExit(main())
