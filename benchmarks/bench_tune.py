"""Policy-tuning throughput: row-steps/sec of the jitted fleet-wide
gradient loop, fused custom-VJP vs native autodiff, plus peak-memory.

One tuning step = forward + backward through the soft scan over all B
rows and T hours plus a vmapped Adam update — the figure of merit is
(rows x steps) / second, i.e. how many per-site gradient refinements
the tuner sustains. Both variants time the *same* compiled object the
tuner runs (`repro.tune.tune_loop`: annealing, Adam scan and hard
re-evaluation in one program), differing only in
``TuneConfig.fused`` — so the reported speedup is exactly what
switching the VJP buys. Warm timings are the median of ``repeats``
(`benchmarks.common.timed`), and the compiled programs' XLA
`memory_analysis` peak temp sizes quantify the HBM-resident
intermediates the checkpointed backward removes.
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import timed, write_artifact
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, build_grid
from repro.tune import (TuneConfig, init_from_grid, optimize,
                        problem_from_grid, tune_loop)


def _grid(n_markets: int, n_systems: int, hours: int):
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    psis = np.geomspace(0.5, 4.0, n_systems)
    systems = [make_system(float(psi) * hours * 1.0 * p_avg, 1.0,
                           float(hours)) for psi in psis]
    policies = [
        PolicySpec("always_on"),
        PolicySpec("x1", x=0.01), PolicySpec("x3", x=0.03),
        PolicySpec("x8", x=0.08), PolicySpec("x15", x=0.15),
        PolicySpec("x25", x=0.25),
        PolicySpec("x3_hyst", x=0.03, hysteresis=0.9),
        PolicySpec("x8_hyst", x=0.08, hysteresis=0.85),
    ]
    return build_grid(markets, systems, policies)


def _time_variant(problem, raw0_np, cfg: TuneConfig, repeats: int, *,
                  telemetry: bool = False, label: str | None = None):
    """Median warm wall time of the full jitted loop + compiled peak
    temp bytes. Compiles exactly once (the timed calls run the lowered
    executable directly — also the object `memory_analysis` reads);
    ``tune_loop`` donates its parameter carry, so every call rebuilds
    the (tiny) raw-parameter arrays from host copies. ``telemetry``
    compiles the variant with the `repro.obs` side-outputs; ``label``
    records the compiled program's XLA cost/memory analysis into the
    active trace (`repro.obs.profiling.record_compiled`)."""
    raw0 = jax.tree.map(jax.numpy.asarray, raw0_np)
    compiled = tune_loop.lower(raw0, problem, cfg=cfg,
                               telemetry=telemetry).compile()
    if label is not None:
        from repro.obs.profiling import record_compiled
        record_compiled(label, compiled)
    mem = compiled.memory_analysis()
    temp_bytes = None if mem is None else int(mem.temp_size_in_bytes)

    def call():
        out = compiled(jax.tree.map(jax.numpy.asarray, raw0_np), problem)
        jax.block_until_ready(out[0])
        return out

    _, warm_us = timed(call, repeats=repeats, stat="median")
    return warm_us / 1e6, temp_bytes


def bench_tune(n_markets: int = 8, n_systems: int = 4,
               hours: int = 2190, steps: int = 200, repeats: int = 3,
               with_optimize: bool = True) -> dict:
    """8 x 4 x 8 = 256 rows x 2190 h, 200 annealed Adam steps,
    fused custom-VJP vs native-autodiff backward at matched configs."""
    grid = _grid(n_markets, n_systems, hours)
    problem = problem_from_grid(grid)
    raw0_np = jax.tree.map(np.asarray, init_from_grid(grid))
    row_steps = grid.n_rows * steps

    fused_s, fused_tmp = _time_variant(
        problem, raw0_np, TuneConfig(steps=steps), repeats,
        label="tune_loop.fused")
    native_s, native_tmp = _time_variant(
        problem, raw0_np, TuneConfig(steps=steps, fused=False), repeats,
        label="tune_loop.native")
    # telemetry A/B: the same fused program with the `repro.obs`
    # side-outputs compiled in, timed under a live (throwaway) trace
    # run — this measures the <10% wall-clock overhead the telemetry
    # subsystem promises, and `check_regression` gates the ratio
    import tempfile

    from repro import obs
    with tempfile.TemporaryDirectory() as td:
        with obs.capture(td, run_id="bench_tune_telemetry"):
            tel_s, _ = _time_variant(
                problem, raw0_np, TuneConfig(steps=steps), repeats,
                telemetry=True, label="tune_loop.telemetry")

    out = {
        "rows": grid.n_rows,
        "hours": hours,
        "steps": steps,
        "repeats": repeats,
        "wall_s_fused": fused_s,
        "wall_s_native": native_s,
        "row_steps_per_s_fused": row_steps / fused_s,
        "row_steps_per_s_native": row_steps / native_s,
        "speedup_fused_vs_native": native_s / fused_s,
        "wall_s_telemetry": tel_s,
        "telemetry_overhead_frac": tel_s / fused_s - 1.0,
        "telemetry_speed_ratio": fused_s / tel_s,
        "temp_bytes_fused": fused_tmp,
        "temp_bytes_native": native_tmp,
        "temp_reduction": (native_tmp / fused_tmp
                           if fused_tmp and native_tmp else None),
    }

    if with_optimize:
        # end-to-end quality numbers (fused path, the default) — the
        # hard guarantee and how often the gradient beats the sweep
        res = optimize(grid, TuneConfig(steps=steps))
        out.update({
            "improvement_vs_best_mean": float(
                res.improvement_vs_best.mean()),
            "improvement_vs_own_mean": float(
                res.improvement_vs_own.mean()),
            "rows_strictly_better": int(
                (res.cpc < res.cpc_swept_best * (1 - 1e-6)).sum()),
            "loss_first": float(res.history["loss"][0]),
            "loss_last": float(res.history["loss"][-1]),
        })
    write_artifact("bench_tune", out)
    return out


def fd_grad_worst_rel_err(t: int = 48) -> float:
    """Fixed-seed central-FD-vs-autodiff sweep over every raw
    coordinate of the dispatch-aware soft objective in f64, returning
    the worst relative error. The single source of the FD harness:
    `tests/test_soft_dispatch.py` asserts it under the 1e-3 acceptance
    tolerance and `benchmarks.check_regression` gates its reciprocal
    margin, so the test and the CI gate cannot drift apart on what
    "FD-correct" means."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.energy.markets import MarketParams
    from repro.tune import (PolicyParams, dispatch_coupling_from_grid,
                            soft_objective)

    with enable_x64():
        grid = build_grid([MarketParams(n_hours=t, seed=s)
                           for s in range(2)],
                          [make_system(0.5 * t * 80.0, 1.0, float(t))],
                          [PolicySpec("x5", x=0.05, off_level=0.3),
                           PolicySpec("x10", x=0.10, off_level=0.3)])
        b = grid.n_rows
        problem = problem_from_grid(grid)
        problem = problem._replace(
            prices=jnp.asarray(problem.prices, jnp.float64),
            price_sum=jnp.asarray(problem.price_sum, jnp.float64))
        coupling = dispatch_coupling_from_grid(
            grid, DispatchConfig(demand_frac=0.4, migrate_cost=3.0,
                                 min_dwell_h=2))
        r = np.random.default_rng(11)
        raw = PolicyParams(raw_off=jnp.asarray(r.uniform(70, 110, b)),
                           raw_gap=jnp.asarray(r.uniform(0.5, 3.0, b)),
                           raw_lvl=jnp.asarray(r.uniform(-1.0, 1.0, b)))

        def loss(rw):
            return soft_objective(rw, problem, 4.0, dispatch=coupling,
                                  dispatch_min_dwell=2, fused=False)[0]

        got = jax.grad(loss)(raw)
        worst = 0.0
        for field in raw._fields:
            base = np.asarray(getattr(raw, field), np.float64)
            for i in range(b):
                h = 1e-5 * max(1.0, abs(base[i]))
                hi, lo = base.copy(), base.copy()
                hi[i] += h
                lo[i] -= h
                fd = (loss(raw._replace(**{field: jnp.asarray(hi)}))
                      - loss(raw._replace(**{field: jnp.asarray(lo)}))
                      ) / (2 * h)
                ad = float(np.asarray(getattr(got, field))[i])
                worst = max(worst, abs(ad - float(fd))
                            / max(abs(float(fd)), 1e-8))
    return worst


def bench_tune_dispatch(n_markets: int = 4, hours: int = 1024,
                        steps: int = 60, with_fd: bool = True) -> dict:
    """A/B dispatch-aware tuning vs the PR-3 re-score-only path on a
    one-policy-per-site fleet, both hard-scored on feasible
    `repro.dispatch.dispatch`; plus the FD-gradient correctness margin.

    Headline: ``dispatch_cpc_edge`` = re-score-only fleet CPC divided
    by the dispatch-aware fleet CPC (>= 1 means differentiating through
    dispatch paid for itself on this fixed-seed fleet)."""
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    systems = [make_system(0.5 * hours * 1.0 * p_avg, 1.0, float(hours))]
    grid = build_grid(markets, systems,
                      [PolicySpec("x8", x=0.08, off_level=0.3)])
    dcfg = DispatchConfig(demand_frac=0.25, migrate_cost=4.0,
                          min_dwell_h=3)

    import time
    t0 = time.perf_counter()
    rescore = optimize(grid, TuneConfig(steps=steps, dispatch=dcfg))
    t_rescore = time.perf_counter() - t0
    t0 = time.perf_counter()
    aware = optimize(grid, TuneConfig(steps=steps, dispatch_soft=dcfg))
    t_aware = time.perf_counter() - t0

    cpc_rescore = min(rescore.dispatch["cpc_tuned"],
                      rescore.dispatch["cpc_swept"])
    cpc_aware = min(aware.dispatch["cpc_tuned"],
                    aware.dispatch["cpc_swept"])
    out = {
        "rows": grid.n_rows,
        "hours": hours,
        "steps": steps,
        "cpc_rescore": cpc_rescore,
        "cpc_aware": cpc_aware,
        "dispatch_cpc_edge": cpc_rescore / cpc_aware,
        "wall_s_rescore": t_rescore,
        "wall_s_aware": t_aware,
        "chosen_rescore": rescore.dispatch["chosen"],
        "chosen_aware": aware.dispatch["chosen"],
    }
    if with_fd:
        worst = fd_grad_worst_rel_err()
        out["fd_grad_worst_rel_err"] = worst
        # margin vs the 1e-3 contract, capped at 10: the raw worst
        # error is FD-cancellation noise (~1e-6), so an uncapped ratio
        # would gate on that noise ~500x inside the contract — capped,
        # every healthy run reports exactly 10 (worst <= 1e-4) and the
        # low-water gate trips only when the error nears the contract,
        # while a real implicit-gradient bug (errors of 1e-2+) still
        # collapses the margin by orders of magnitude
        out["fd_grad_margin"] = min(10.0, 1e-3 / max(worst, 1e-12))
    write_artifact("bench_tune_dispatch", out)
    return out


ALL = {"bench_tune": bench_tune,
       "bench_tune_dispatch": bench_tune_dispatch}
