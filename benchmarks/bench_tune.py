"""Policy-tuning throughput: row-steps/sec of the jitted fleet-wide
gradient loop, fused custom-VJP vs native autodiff, plus peak-memory.

One tuning step = forward + backward through the soft scan over all B
rows and T hours plus a vmapped Adam update — the figure of merit is
(rows x steps) / second, i.e. how many per-site gradient refinements
the tuner sustains. Both variants time the *same* compiled object the
tuner runs (`repro.tune.tune_loop`: annealing, Adam scan and hard
re-evaluation in one program), differing only in
``TuneConfig.fused`` — so the reported speedup is exactly what
switching the VJP buys. Warm timings are the median of ``repeats``
(`benchmarks.common.timed`), and the compiled programs' XLA
`memory_analysis` peak temp sizes quantify the HBM-resident
intermediates the checkpointed backward removes.
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import timed, write_artifact
from repro.core.tco import make_system
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, build_grid
from repro.tune import (TuneConfig, init_from_grid, optimize,
                        problem_from_grid, tune_loop)


def _grid(n_markets: int, n_systems: int, hours: int):
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    psis = np.geomspace(0.5, 4.0, n_systems)
    systems = [make_system(float(psi) * hours * 1.0 * p_avg, 1.0,
                           float(hours)) for psi in psis]
    policies = [
        PolicySpec("always_on"),
        PolicySpec("x1", x=0.01), PolicySpec("x3", x=0.03),
        PolicySpec("x8", x=0.08), PolicySpec("x15", x=0.15),
        PolicySpec("x25", x=0.25),
        PolicySpec("x3_hyst", x=0.03, hysteresis=0.9),
        PolicySpec("x8_hyst", x=0.08, hysteresis=0.85),
    ]
    return build_grid(markets, systems, policies)


def _time_variant(problem, raw0_np, cfg: TuneConfig, repeats: int):
    """Median warm wall time of the full jitted loop + compiled peak
    temp bytes. Compiles exactly once (the timed calls run the lowered
    executable directly — also the object `memory_analysis` reads);
    ``tune_loop`` donates its parameter carry, so every call rebuilds
    the (tiny) raw-parameter arrays from host copies."""
    raw0 = jax.tree.map(jax.numpy.asarray, raw0_np)
    compiled = tune_loop.lower(raw0, problem, cfg=cfg).compile()
    mem = compiled.memory_analysis()
    temp_bytes = None if mem is None else int(mem.temp_size_in_bytes)

    def call():
        out = compiled(jax.tree.map(jax.numpy.asarray, raw0_np), problem)
        jax.block_until_ready(out[0])
        return out

    _, warm_us = timed(call, repeats=repeats, stat="median")
    return warm_us / 1e6, temp_bytes


def bench_tune(n_markets: int = 8, n_systems: int = 4,
               hours: int = 2190, steps: int = 200, repeats: int = 3,
               with_optimize: bool = True) -> dict:
    """8 x 4 x 8 = 256 rows x 2190 h, 200 annealed Adam steps,
    fused custom-VJP vs native-autodiff backward at matched configs."""
    grid = _grid(n_markets, n_systems, hours)
    problem = problem_from_grid(grid)
    raw0_np = jax.tree.map(np.asarray, init_from_grid(grid))
    row_steps = grid.n_rows * steps

    fused_s, fused_tmp = _time_variant(
        problem, raw0_np, TuneConfig(steps=steps), repeats)
    native_s, native_tmp = _time_variant(
        problem, raw0_np, TuneConfig(steps=steps, fused=False), repeats)

    out = {
        "rows": grid.n_rows,
        "hours": hours,
        "steps": steps,
        "repeats": repeats,
        "wall_s_fused": fused_s,
        "wall_s_native": native_s,
        "row_steps_per_s_fused": row_steps / fused_s,
        "row_steps_per_s_native": row_steps / native_s,
        "speedup_fused_vs_native": native_s / fused_s,
        "temp_bytes_fused": fused_tmp,
        "temp_bytes_native": native_tmp,
        "temp_reduction": (native_tmp / fused_tmp
                           if fused_tmp and native_tmp else None),
    }

    if with_optimize:
        # end-to-end quality numbers (fused path, the default) — the
        # hard guarantee and how often the gradient beats the sweep
        res = optimize(grid, TuneConfig(steps=steps))
        out.update({
            "improvement_vs_best_mean": float(
                res.improvement_vs_best.mean()),
            "improvement_vs_own_mean": float(
                res.improvement_vs_own.mean()),
            "rows_strictly_better": int(
                (res.cpc < res.cpc_swept_best * (1 - 1e-6)).sum()),
            "loss_first": float(res.history["loss"][0]),
            "loss_last": float(res.history["loss"][-1]),
        })
    write_artifact("bench_tune", out)
    return out


ALL = {"bench_tune": bench_tune}
