"""Policy-tuning throughput: row-steps/sec of the jitted fleet-wide
gradient loop, plus the realized improvement over the swept grid.

One tuning step = forward + backward through the associative soft scan
over all B rows and T hours plus a vmapped Adam update — the figure of
merit is (rows x steps) / second, i.e. how many per-site gradient
refinements the tuner sustains."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_artifact
from repro.core.tco import make_system
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, build_grid
from repro.tune import TuneConfig, optimize


def _grid(n_markets: int, n_systems: int, hours: int):
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    psis = np.geomspace(0.5, 4.0, n_systems)
    systems = [make_system(float(psi) * hours * 1.0 * p_avg, 1.0,
                           float(hours)) for psi in psis]
    policies = [
        PolicySpec("always_on"),
        PolicySpec("x1", x=0.01), PolicySpec("x3", x=0.03),
        PolicySpec("x8", x=0.08), PolicySpec("x15", x=0.15),
        PolicySpec("x25", x=0.25),
        PolicySpec("x3_hyst", x=0.03, hysteresis=0.9),
        PolicySpec("x8_hyst", x=0.08, hysteresis=0.85),
    ]
    return build_grid(markets, systems, policies)


def bench_tune(n_markets: int = 8, n_systems: int = 4,
               hours: int = 2190, steps: int = 200) -> dict:
    """8 x 4 x 8 = 256 rows x 2190 h, 200 annealed Adam steps."""
    grid = _grid(n_markets, n_systems, hours)
    cfg = TuneConfig(steps=steps)

    # the scan length is baked into the jitted loop, so a short warmup
    # would not compile the real thing: time a cold and a warm run
    t0 = time.perf_counter()
    optimize(grid, cfg)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = optimize(grid, cfg)
    wall_s = time.perf_counter() - t0

    out = {
        "rows": grid.n_rows,
        "hours": hours,
        "steps": steps,
        "wall_s": wall_s,
        "cold_wall_s": cold_s,
        "row_steps_per_s": grid.n_rows * steps / wall_s,
        "improvement_vs_best_mean": float(res.improvement_vs_best.mean()),
        "improvement_vs_own_mean": float(res.improvement_vs_own.mean()),
        "rows_strictly_better": int(
            (res.cpc < res.cpc_swept_best * (1 - 1e-6)).sum()),
        "loss_first": float(res.history["loss"][0]),
        "loss_last": float(res.history["loss"][-1]),
    }
    write_artifact("bench_tune", out)
    return out


ALL = {"bench_tune": bench_tune}
