"""Runtime benchmarks (ours, beyond the paper's figures): energy-aware
training simulation (predicted vs realised CPC reduction) and serving
cost-per-token under price gating — the paper's §V-A shutdown-cost gap,
measured."""

from __future__ import annotations

import numpy as np

from benchmarks.common import timed, write_artifact
from repro.configs.base import get_config
from repro.configs.inputs import reduced_config
from repro.core.optimizer import optimal_shutdown
from repro.energy.markets import generate_market
from repro.energy.presets import region_params
from repro.energy.stream import PriceStream
from repro.runtime.scheduler import EnergyAwareScheduler, SchedulerConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def bench_energy_aware_training(steps: int = 120,
                                region: str = "south_australia") -> dict:
    """Train a reduced model under the WS policy and compare the realised
    CPC reduction against the model's prediction (upper bound per §V-A)."""
    prices = np.asarray(generate_market(region_params(region)).prices)
    psi = 0.8          # energy-heavy system: shutdowns clearly viable
    plan = optimal_shutdown(prices, psi)

    def run(mode):
        sched = None
        if mode == "ws":
            sched = EnergyAwareScheduler(
                PriceStream(prices), SchedulerConfig(psi=psi,
                                                     mode="oracle"))
        t = Trainer(reduced_config(get_config("qwen1.5-0.5b")),
                    TrainerConfig(steps=steps,
                                  ckpt_dir=f"/tmp/bench_ckpt_{mode}",
                                  ckpt_every=25,
                                  fixed_cost_per_hour=psi * 80.0,
                                  power_mw=1.0),
                    scheduler=sched, batch_size=2, seq_len=32)
        return t.run(log_every=0)

    ws = run("ws")
    out = {
        "predicted_cpc_red_pct": float(plan.cpc_reduction) * 100,
        "realized_cpc_red_pct": ws["cpc_reduction"] * 100,
        "realized_x_pct": ws["x_realized"] * 100,
        "planned_x_pct": float(plan.x_opt) * 100,
        "restarts": ws["restarts"],
        "final_loss": ws["final_loss"],
        "ckpt_save_s": ws["ckpt_save_s"],
        "wall_s": ws["wall_s"],
    }
    write_artifact("bench_energy_training", out)
    return out


def bench_step_time(steps: int = 20) -> dict:
    """Wall-clock per train step for the reduced configs (CPU; framework
    overhead check, not a TPU number)."""
    out = {}
    for arch in ("qwen1.5-0.5b", "mamba2-1.3b", "mixtral-8x22b"):
        t = Trainer(reduced_config(get_config(arch)),
                    TrainerConfig(steps=steps,
                                  ckpt_dir=f"/tmp/bench_step_{arch}",
                                  ckpt_every=1000),
                    batch_size=4, seq_len=64)
        res = t.run(log_every=0)
        out[arch] = {"s_per_step": res["wall_s"] / steps,
                     "final_loss": res["final_loss"]}
    write_artifact("bench_step_time", out)
    return out


ALL = {
    "energy_aware_training": bench_energy_aware_training,
    "step_time": bench_step_time,
}
