"""Shared benchmark scaffolding: timing, artifact output, market access."""

from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

import numpy as np

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def timed(fn, *args, repeats: int = 3, stat: str = "min", **kw):
    """(result, microseconds-per-call) with one warmup.

    ``stat="min"`` (default) reports the *fastest* repeat: the minimum
    is the standard robust estimator for "what does this code cost" —
    interference from other processes only ever adds time, so the mean
    drifts with machine load (which matters for the CI regression gate,
    `check_regression`). ``stat="median"`` reports the median repeat
    instead — the right call when the timed quantity is itself a whole
    pipeline (e.g. `bench_tune`'s warm `tune_loop` runs) and a single
    lucky repeat should not define the gated number.
    """
    if stat not in ("min", "median"):
        raise ValueError(f"timed: unknown stat {stat!r}")
    fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    agg = min(times) if stat == "min" else statistics.median(times)
    return out, agg * 1e6


def write_artifact(name: str, payload: dict) -> Path:
    """Write one artifact JSON, stamped with run metadata (git sha,
    jax/jaxlib versions, device kind, timestamp — `repro.obs
    .run_metadata`) under ``run_meta`` so every BENCH_*.json number is
    attributable to the code and machine that produced it."""
    from repro.obs import run_metadata, trace_event

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / f"{name}.json"
    if isinstance(payload, dict) and "run_meta" not in payload:
        payload = {**payload, "run_meta": run_metadata()}
    path.write_text(json.dumps(payload, indent=1, default=_np_default))
    trace_event("bench.artifact", {"name": name, "path": str(path)})
    return path


def _np_default(o):
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def region_prices(region: str, seed: int | None = None) -> np.ndarray:
    from repro.energy.markets import generate_market
    from repro.energy.presets import region_params
    return np.asarray(
        generate_market(region_params(region, seed=seed)).prices)
