"""Fault-channel overhead: what a healthy run pays for fault support,
and what a storm run pays for the mask channels.

`repro.faults.faulted_backtest` threads extra mask channels
(observed-price ffill, outage forcing, capacity derate) through the
sequential scan — streaming two extra [B, T] arrays, a real cost.
The contract is that *healthy* runs never pay it: trivial masks
short-circuit to the plain backtest program, so
``fault_mask_speed_ratio`` (healthy time / zero-fault time) sits at
~1.0 and its committed baseline plus the 30% gate tolerance trips if
someone removes the short-circuit. ``fault_storm_speed_ratio``
(healthy time / storm time, ~0.4-0.7 on this shape) is the low-water
mark for the masked program itself: a structural regression — a host
round-trip or a de-fused gather per hour — costs integer factors and
trips it."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_fleet import _fleet_grid
from benchmarks.common import timed, write_artifact
from repro.faults import faulted_backtest, random_storm
from repro.fleet import backtest


def bench_faults(n_markets: int = 8, n_systems: int = 4,
                 hours: int = 4096) -> dict:
    grid = _fleet_grid(n_markets, n_systems, hours)
    b = grid.n_rows

    def run_plain():
        rep = backtest(grid, use_pallas=False)
        jax.block_until_ready(rep.cpc)
        return rep

    def run_zero_fault():
        rep = faulted_backtest(grid)
        jax.block_until_ready(rep.cpc)
        return rep

    def run_zero_fault_masked():
        rep = faulted_backtest(grid, _force_masked=True)
        jax.block_until_ready(rep.cpc)
        return rep

    storm = random_storm(7, b, n_markets, hours)

    def run_storm():
        rep = faulted_backtest(grid, storm)
        jax.block_until_ready(rep.cpc)
        return rep

    rep_plain, us_plain = timed(run_plain, repeats=3)
    rep_zero, us_zero = timed(run_zero_fault, repeats=3)
    rep_masked, us_masked = timed(run_zero_fault_masked, repeats=3)
    rep_storm, us_storm = timed(run_storm, repeats=3)

    identical = all(
        np.array_equal(np.asarray(getattr(rep_plain, f)),
                       np.asarray(getattr(rep_masked, f)))
        for f in rep_plain._fields)

    return {
        "rows": b,
        "hours": hours,
        "fault_mask_speed_ratio": us_plain / us_zero,
        "fault_storm_speed_ratio": us_plain / us_storm,
        "rows_per_s_plain": b / (us_plain * 1e-6),
        "rows_per_s_zero_fault": b / (us_zero * 1e-6),
        "rows_per_s_forced_masked": b / (us_masked * 1e-6),
        "rows_per_s_storm": b / (us_storm * 1e-6),
        "storm_events": len(storm),
        "bit_identical_masked_zero_fault": identical,
        "cpc_mean_storm": float(np.mean(np.asarray(rep_storm.cpc))),
    }


ALL = {"bench_faults": bench_faults}


def main() -> None:
    out = bench_faults()
    print(f"fleet: {out['rows']} rows x {out['hours']} h")
    print(f"plain backtest      : {out['rows_per_s_plain']:>12.0f} rows/s")
    print(f"zero-fault          : {out['rows_per_s_zero_fault']:>12.0f} "
          f"rows/s  (ratio {out['fault_mask_speed_ratio']:.3f} — "
          "trivial masks short-circuit)")
    print(f"forced masked       : "
          f"{out['rows_per_s_forced_masked']:>12.0f} rows/s  "
          f"(bit-identical: {out['bit_identical_masked_zero_fault']})")
    print(f"storm ({out['storm_events']} faults)    : "
          f"{out['rows_per_s_storm']:>12.0f} rows/s  "
          f"(ratio {out['fault_storm_speed_ratio']:.3f})")
    write_artifact("bench_faults", out)


if __name__ == "__main__":
    main()
