"""One benchmark per paper figure/table (Figs. 1-7, Table II).

Each function reproduces the figure's underlying data from our calibrated
synthetic markets + the jnp model, times the computation, and writes a
JSON artifact with the derived numbers next to the paper's published
values where the paper states them.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import region_prices, timed, write_artifact
from repro.core import price_model as pm
from repro.core import tco
from repro.core.optimizer import optimal_shutdown, psi_sweep
from repro.core.regions import (PAPER_LICHTENBERG, PAPER_TABLE2,
                                PSI_LICHTENBERG,
                                PAPER_SOUTH_AUSTRALIA_IV_B,
                                compute_region_row)
from repro.core.scenarios import (amplify_volatility, fossil_share,
                                  scale_fixed_costs)
from repro.energy.markets import diurnal_profile, generate_market
from repro.energy.presets import region_params


def fig1_diurnal() -> dict:
    """Fig. 1: average diurnal price/generation profile (Germany)."""
    md = generate_market(region_params("germany"))
    prof, us = timed(lambda: np.asarray(diurnal_profile(md)))
    n = (md.renewable.shape[0] // 24) * 24
    ren = np.asarray(md.renewable)[:n].reshape(-1, 24).mean(0)
    out = {"hourly_price": prof.tolist(),
           "hourly_renewable": ren.tolist(),
           # midday prices can be negative (solar surplus): report the
           # spread, not a ratio
           "evening_minus_midday": float(prof[19] - prof[13]),
           "us_per_call": us}
    write_artifact("fig1_diurnal", out)
    return out


def fig2_price_regions(x: float = 0.0115) -> dict:
    """Fig. 2: price-duration view, threshold + region means at x=1.15%."""
    prices = region_prices("germany")
    st, us = timed(pm.price_stats, prices, x)
    srt = np.sort(prices)[::-1]
    out = {"x": float(st.x), "p_thresh": float(st.p_thresh),
           "p_high": float(st.p_high), "p_low": float(st.p_low),
           "p_avg": float(st.p_avg),
           "duration_curve_sample": srt[:: max(len(srt) // 64, 1)].tolist(),
           "us_per_call": us}
    write_artifact("fig2_price_regions", out)
    return out


def fig3_pv_intervals() -> dict:
    """Fig. 3: PV k-x lines at 1 h / 1 day / 1 week sampling + x_BE for
    Psi_LB = 2 (paper: weekly never viable; 1 h viable below x=3.32%)."""
    prices = region_prices("germany")
    out = {"psi": PSI_LICHTENBERG, "intervals": {}}
    for name, factor in [("1h", 1), ("1d", 24), ("1w", 24 * 7)]:
        p = np.asarray(pm.resample(prices, factor))
        (plan), us = timed(optimal_shutdown, p, PSI_LICHTENBERG)
        pv = pm.price_variability(p)
        k_max = float(np.max(np.asarray(pv.k)))
        out["intervals"][name] = {
            "k_max": k_max,
            "viable": bool(plan.viable),
            "x_be_pct": float(plan.x_break_even) * 100,
            "x_opt_pct": float(plan.x_opt) * 100,
            "us_per_call": us,
        }
    out["paper"] = {"x_be_pct_1h": PAPER_LICHTENBERG["x_be_pct"],
                    "weekly_viable": False}
    write_artifact("fig3_pv_intervals", out)
    return out


def fig4_de_vs_sa() -> dict:
    """Fig. 4: Germany vs South Australia PV at Psi=2 (paper IV-B:
    x_BE 3.32% -> 25.66%)."""
    out = {}
    for region, paper_xbe in [("germany", PAPER_LICHTENBERG["x_be_pct"]),
                              ("south_australia",
                               PAPER_SOUTH_AUSTRALIA_IV_B["x_be_pct"])]:
        prices = region_prices(region)
        plan, us = timed(optimal_shutdown, prices, 2.0)
        out[region] = {"x_be_pct": float(plan.x_break_even) * 100,
                       "x_opt_pct": float(plan.x_opt) * 100,
                       "cpc_red_pct": float(plan.cpc_reduction) * 100,
                       "paper_x_be_pct": paper_xbe,
                       "us_per_call": us}
    write_artifact("fig4_de_vs_sa", out)
    return out


def fig5_psi_sweep() -> dict:
    """Fig. 5: max theoretical CPC reduction vs Psi (Germany 1 h). Paper:
    Psi must fall to ~0.38 to match South Australia's ~8%."""
    prices = region_prices("germany")
    psis = np.logspace(np.log10(0.05), np.log10(8.0), 40)
    red, us = timed(lambda: np.asarray(psi_sweep(prices, psis)))
    # Psi at which the reduction reaches 8% (paper: ~0.38)
    above = psis[red >= 0.08]
    out = {"psi": psis.tolist(), "cpc_reduction": red.tolist(),
           "psi_for_8pct": float(above.max()) if len(above) else None,
           "paper_psi_for_8pct": 0.38, "us_per_call": us}
    write_artifact("fig5_psi_sweep", out)
    return out


def fig6_combined() -> dict:
    """Fig. 6 / IV-D: combined scenario — Eq. (30) volatility amplification
    + 20% cheaper hardware (Psi 2.0 -> 1.6). Paper: x_BE 10.15%,
    x_opt 2.77%."""
    md = generate_market(region_params("germany"))
    prices = np.asarray(md.prices)
    beta = np.asarray(fossil_share(md.fossil, md.renewable))
    amplified = np.asarray(amplify_volatility(prices, beta))
    psi_new = float(scale_fixed_costs(PSI_LICHTENBERG, 0.8))

    scen = {}
    for name, p, psi_v in [("historic", prices, PSI_LICHTENBERG),
                           ("amplified", amplified, PSI_LICHTENBERG),
                           ("amplified+cheap_hw", amplified, psi_new)]:
        plan, us = timed(optimal_shutdown, p, psi_v)
        pv = pm.price_variability(p)
        red = np.asarray(tco.cpc_reduction(psi_v, pv.k, pv.x))
        scen[name] = {"psi": psi_v,
                      "x_be_pct": float(plan.x_break_even) * 100,
                      "x_opt_pct": float(plan.x_opt) * 100,
                      "cpc_red_pct": float(plan.cpc_reduction) * 100,
                      "reduction_curve_x": np.asarray(pv.x)[::200].tolist(),
                      "reduction_curve": red[::200].tolist(),
                      "us_per_call": us}
    scen["paper"] = {"x_be_pct": 10.15, "x_opt_pct": 2.77}
    write_artifact("fig6_combined", scen)
    return scen


def table2_regions() -> dict:
    """Table II / Fig. 7: the regional study on calibrated markets."""
    rows = {}
    for region, paper in PAPER_TABLE2.items():
        prices = region_prices(region)
        row, us = timed(compute_region_row, region, prices, paper.psi)
        rows[region] = {
            "ours": {"p_avg": row.p_avg, "x_be_pct": row.x_be_pct,
                     "x_opt_pct": row.x_opt_pct,
                     "cpc_red_pct": row.cpc_red_pct},
            "paper": {"p_avg": paper.p_avg, "x_be_pct": paper.x_be_pct,
                      "x_opt_pct": paper.x_opt_pct,
                      "cpc_red_pct": paper.cpc_red_pct},
            "us_per_call": us,
        }
    write_artifact("table2_regions", rows)
    return rows


ALL = {
    "fig1_diurnal": fig1_diurnal,
    "fig2_price_regions": fig2_price_regions,
    "fig3_pv_intervals": fig3_pv_intervals,
    "fig4_de_vs_sa": fig4_de_vs_sa,
    "fig5_psi_sweep": fig5_psi_sweep,
    "fig6_combined": fig6_combined,
    "table2_regions": table2_regions,
}
