"""Live-controller throughput: controller-hours/sec of the batched
jitted receding-horizon scan (`repro.live.live_backtest` — every
controller instance advanced one hour per scan step, all in one
program) vs the per-hour Python re-plan loop it replaces (numpy
forecast + threshold re-solve + hard state step per controller per
hour, the way a host-side operator daemon would run it). Both re-solve
families are represented in the baseline — quantile re-resolution and
the tuned family's per-tick Adam descent on the window CPC (same
analytic gradient the scan differentiates) — weighted by the sweep's
actual family mix. The fused number is what makes a controller-design
*sweep* affordable; the gate protects that edge."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import timed, write_artifact
from repro.core.tco import make_system
from repro.energy.forecast import seasonal_naive
from repro.energy.presets import region_params
from repro.fleet import PolicySpec, build_grid
from repro.live import LiveConfig, build_live_grid, live_backtest


def _live_case(n_markets: int, hours: int):
    markets = [region_params("germany", seed=s).replace(n_hours=hours)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    system = make_system(2.0 * hours * 1.0 * p_avg, 1.0, float(hours))
    policies = [PolicySpec("x8", x=0.08), PolicySpec("x15", x=0.15)]
    grid = build_grid(markets, [system], policies)
    lgrid = build_live_grid(grid, policies,
                            horizons=(24, 48), cadences=(1, 24),
                            families=("quantile", "tuned"))
    return grid, lgrid


def _window_cpc_grad_np(po, fc, lvl, idle, power, fixed_h, dt,
                        inv_tau):
    """Analytic d(relaxed window CPC)/d(p_off) for one controller —
    the numpy mirror of `repro.live.controller._window_cpc_grad`."""
    s = 1.0 / (1.0 + np.exp(-(po - fc) * inv_tau))
    cap = lvl + (1.0 - lvl) * s
    draw = cap + idle * (1.0 - cap)
    num = fixed_h + dt * power * float(np.sum(draw * fc))
    den = max(dt * float(np.sum(cap)), 1e-9)
    dcap = (1.0 - lvl) * s * (1.0 - s) * inv_tau
    dnum = dt * power * float(np.sum(dcap * (1.0 - idle) * fc))
    dden = dt * float(np.sum(dcap))
    return (dnum * den - num * dden) / (den * den)


def _python_controller_loop(prices_row: np.ndarray, hours: int,
                            horizon: int, season: int, x: float,
                            p_off0: float, family: str,
                            cfg: LiveConfig) -> float:
    """One controller, re-planned hour by hour in plain numpy — the
    honest host-side baseline (forecast, re-solve of the requested
    family, hard state step). Returns seconds per controller-hour
    (min over hours, matching `timed`'s floor convention)."""
    t_total = prices_row.shape[0]
    w = season + 1
    m = int(np.clip(round(x * horizon), 1, horizon - 1))
    lvl, idle, power = 0.0, 0.1, 1.0
    fixed_h, dt = 1.0, 1.0
    inv_tau = 1.0 / cfg.inner_tau
    on, p_off = 1.0, p_off0
    adam_m, adam_v, tc = 0.0, 0.0, 0.0
    best = float("inf")
    for t in range(hours):
        t0 = time.perf_counter()
        hist = prices_row[(t - w + 1 + np.arange(w)) % t_total]
        fc = seasonal_naive(hist, horizon, season)
        if family == "quantile":
            p_off = np.sort(fc)[::-1][m - 1]
        else:                        # tuned: warm-started Adam steps
            for k in range(cfg.inner_steps):
                g = _window_cpc_grad_np(p_off, fc, lvl, idle, power,
                                        fixed_h, dt, inv_tau)
                adam_m = cfg.adam_b1 * adam_m + (1 - cfg.adam_b1) * g
                adam_v = cfg.adam_b2 * adam_v + (1 - cfg.adam_b2) * g * g
                tc += 1.0
                mhat = adam_m / (1 - cfg.adam_b1 ** tc)
                vhat = adam_v / (1 - cfg.adam_b2 ** tc)
                p_off -= cfg.inner_lr * mhat \
                    / (np.sqrt(vhat) + cfg.adam_eps)
        p_t = prices_row[t % t_total]
        if p_t > p_off:
            on = 0.0
        elif p_t <= p_off:
            on = 1.0
        best = min(best, time.perf_counter() - t0)
    assert on in (0.0, 1.0)
    return best


def bench_live(n_markets: int = 4, hours: int = 2190,
               baseline_hours: int = 256, repeats: int = 3) -> dict:
    """B controllers x `hours` h in one jitted scan vs the Python
    re-plan loop, extrapolated from `baseline_hours` hours."""
    grid, lgrid = _live_case(n_markets, hours)
    cfg = LiveConfig(start=0, hours=hours, season=168)

    def run_fused():
        res = live_backtest(lgrid, cfg)
        res.cpc.block_until_ready()
        return res

    res, us_fused = timed(run_fused, repeats=repeats)
    ctrl_hours = lgrid.n_rows * hours
    per_s_fused = ctrl_hours / (us_fused / 1e6)

    # baseline: seconds/controller-hour per family, weighted by the
    # sweep's family mix (the daemon would run the same mix)
    prices = np.asarray(grid.prices, np.float64)
    fam = np.asarray(lgrid.family_id)
    frac_tuned = float((fam == 1).mean())
    s_q = _python_controller_loop(prices[0], baseline_hours, 24, 168,
                                  0.08, float(grid.p_off[0]),
                                  "quantile", cfg)
    s_t = _python_controller_loop(prices[0], baseline_hours, 24, 168,
                                  0.08, float(grid.p_off[0]),
                                  "tuned", cfg)
    s_mixed = (1.0 - frac_tuned) * s_q + frac_tuned * s_t
    per_s_loop = 1.0 / s_mixed

    out = {
        "rows": lgrid.n_rows,
        "hours": hours,
        "controller_hours_per_s_jitted": per_s_fused,
        "controller_hours_per_s_python": per_s_loop,
        "speedup_live": per_s_fused / per_s_loop,
        "baseline_hours_sampled": baseline_hours,
        "s_per_ctrl_hour_quantile": s_q,
        "s_per_ctrl_hour_tuned": s_t,
        "frac_tuned_rows": frac_tuned,
        "cpc_mean": float(np.asarray(res.cpc).mean()),
        "mae1_mean": float(np.asarray(res.mae1).mean()),
    }
    write_artifact("bench_live", out)
    return out


ALL = {"bench_live": bench_live}
