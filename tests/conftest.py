import gc

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess/multi-device tests (deselect with "
        "-m 'not slow')")


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Free jitted executables between test modules.

    Every compiled XLA executable holds mmap'd JIT code regions, and a
    full-suite run accumulates enough of them to exhaust the kernel's
    default ``vm.max_map_count`` (65530) — at which point the next
    compile segfaults inside XLA. Tests never share compiled programs
    across module boundaries, so clearing there bounds the map count at
    the single-module high-water mark for free."""
    yield
    import jax

    jax.clear_caches()
    gc.collect()
