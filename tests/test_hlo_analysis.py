"""HLO analyzer tests: the structural parser must recover loop-aware FLOPs
and collective bytes that plain cost_analysis undercounts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (_shape_bytes, analyze,
                                       parse_module, raw_cost_analysis)


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(s32[], f32[2,2]{1,0}, pred[8]{0})") == 4 + 16 + 8
    assert _shape_bytes("f32[4,8]{1,0}", f32_as=2.0) == 64
    assert _shape_bytes("f32[]") == 4


def _toy_module(L=6, D=64, B=4):
    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h)
    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()


def test_scan_flops_are_loop_aware():
    L, D, B = 6, 64, 4
    compiled = _toy_module(L, D, B)
    rep = analyze(compiled.as_text())
    analytic = 2 * L * B * D * D          # L matmuls
    # parser must be within 5% of analytic (elementwise ops add a little)
    assert analytic <= rep.flops <= analytic * 1.10
    # ...while raw cost_analysis counts the body once (the bug we fix)
    raw = raw_cost_analysis(compiled).get("flops", 0.0)
    assert raw < analytic / 2


def test_nested_scan_multiplicities():
    def f(w, x):
        def outer(h, wi):
            def inner(h2, _):
                return jnp.tanh(h2 @ wi), None
            h2, _ = jax.lax.scan(inner, h, jnp.arange(3))
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return jnp.sum(h)
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, 32, 32), jnp.float32),
        jax.ShapeDtypeStruct((2, 32), jnp.float32)).compile()
    rep = analyze(compiled.as_text())
    analytic = 2 * 4 * 3 * 2 * 32 * 32    # outer 4 x inner 3
    assert analytic <= rep.flops <= analytic * 1.15


def test_parse_module_finds_entry():
    compiled = _toy_module()
    comps = parse_module(compiled.as_text())
    entries = [c for c in comps.values() if c.is_entry]
    assert len(entries) == 1
    assert any(i.opcode == "while" for i in entries[0].instrs)


def test_bytes_charge_slices_not_stacks():
    """A scan over stacked weights must charge the per-iteration slice,
    not L x the whole stack."""
    L, D, B = 8, 128, 2
    compiled = _toy_module(L, D, B)
    rep = analyze(compiled.as_text())
    stack_bytes = L * D * D * 4
    # traffic should be a few passes over the stack (slice reads + entry
    # copies), far below the L x stack a naive operand count would give
    assert rep.bytes_accessed < stack_bytes * (L / 2)
    assert rep.bytes_accessed > stack_bytes * 0.8


def test_no_collectives_on_single_device():
    rep = analyze(_toy_module().as_text())
    assert rep.total_collective_payload == 0.0
