"""Cross-site dispatch subsystem tests: Pallas kernel vs sequential
oracle (bit-identical), site-permutation invariance, hard-constraint
feasibility at the extremes, schedule consistency with the fleet scan,
and the `summarize` round-trip with the new dispatch block."""

import numpy as np
import pytest

from repro.core.tco import make_system
from repro.dispatch import (DispatchConfig, DispatchInfeasible,
                            DispatchProblem, build_problem,
                            capacity_series, dispatch, segment_rank)
from repro.energy.markets import MarketParams
from repro.fleet import PolicySpec, backtest, build_grid, summarize
from repro.kernels.dispatch_scan import dispatch_scan
from repro.kernels.ref import dispatch_ref, fleet_scan_ref

rng = np.random.default_rng(17)


def _random_case(s, t, *, demand_frac=0.5, seed_shift=0):
    """Random prices/availability with a feasible constant demand."""
    r = np.random.default_rng(17 + seed_shift)
    prices = r.normal(80, 40, (s, t)).astype(np.float32)
    power = r.uniform(1.0, 3.0, s).astype(np.float32)
    on = (r.uniform(size=(s, t)) > 0.3).astype(np.float32)
    avail = power[:, None] * (0.2 + 0.8 * on)      # never fully dark
    demand = np.full(t, demand_frac * float(avail.sum(axis=0).min()),
                     np.float32)
    return prices, avail, demand


def _problem(prices, avail, demand, *, migrate_cost=0.0, min_dwell=0,
             power_cap=float("inf"), floor=0.0, fixed=0.0):
    order, rank = segment_rank(prices, migrate_cost)
    return DispatchProblem(
        prices=np.asarray(prices, np.float32),
        avail_mw=np.asarray(avail, np.float32),
        demand_mw=np.asarray(demand, np.float32),
        power_cap_mw=power_cap, migrate_cost=migrate_cost,
        min_dwell_h=min_dwell, compute_floor_mwh=floor, fixed_cost=fixed,
        order=order, rank=rank)


# ---------------------------------------------------------------------------
# (a) Pallas kernel vs sequential oracle: bit-identical
# ---------------------------------------------------------------------------

DISPATCH_CASES = [
    # S, T, migrate_cost, min_dwell  (T exercising block padding)
    (1, 64, 0.0, 0),
    (5, 333, 5.0, 0),
    (16, 1000, 5.0, 6),
    (64, 700, 0.0, 3),
]


@pytest.mark.parametrize("case", DISPATCH_CASES)
def test_dispatch_scan_bit_identical_to_ref(case):
    s, t, mc, dwell = case
    prices, avail, demand = _random_case(s, t)
    order, rank = segment_rank(prices, mc)
    got = np.asarray(dispatch_scan(avail, order, rank, demand,
                                   min_dwell=dwell, block_t=256))
    want = np.asarray(dispatch_ref(avail, order, rank, demand,
                                   min_dwell=dwell))
    np.testing.assert_array_equal(got, want,
                                  err_msg=f"S={s} T={t} mc={mc}")


def test_dispatch_engine_paths_identical():
    prices, avail, demand = _random_case(7, 500)
    prob = _problem(prices, avail, demand, migrate_cost=3.0, min_dwell=4)
    ref = dispatch(prob, use_pallas=False)
    pal = dispatch(prob, use_pallas=True)
    np.testing.assert_array_equal(ref.alloc_mw, pal.alloc_mw)
    assert ref.cpc == pal.cpc and ref.n_migrations == pal.n_migrations


# ---------------------------------------------------------------------------
# (b) allocation semantics
# ---------------------------------------------------------------------------

def test_demand_is_met_exactly_within_availability():
    prices, avail, demand = _random_case(9, 400)
    res = dispatch(_problem(prices, avail, demand, migrate_cost=4.0,
                            min_dwell=5), use_pallas=False)
    np.testing.assert_allclose(res.alloc_mw.sum(axis=0), demand,
                               rtol=1e-5, atol=1e-4)
    assert np.all(res.alloc_mw <= np.asarray(avail) + 1e-5)
    assert np.all(res.alloc_mw >= 0.0)


def test_zero_migration_cost_reduces_to_per_hour_argmin():
    """With no fee and no dwell the dispatcher fills the cheapest
    available sites each hour independently (greedy price argmin)."""
    s, t = 6, 200
    prices, avail, demand = _random_case(s, t)
    res = dispatch(_problem(prices, avail, demand), use_pallas=False)
    want = np.zeros((s, t))
    for h in range(t):
        left = float(demand[h])
        for i in np.argsort(prices[:, h], kind="stable"):
            take = min(left, float(avail[i, h]))
            want[i, h] = take
            left -= take
    np.testing.assert_allclose(res.alloc_mw, want, rtol=1e-5, atol=1e-4)


def test_site_permutation_invariance():
    """Permuting site order permutes the allocation rows and nothing
    else (prices are continuous draws, so sort keys are distinct)."""
    prices, avail, demand = _random_case(11, 300)
    perm = rng.permutation(11)
    base = dispatch(_problem(prices, avail, demand, migrate_cost=6.0,
                             min_dwell=3), use_pallas=False)
    shuf = dispatch(_problem(prices[perm], avail[perm], demand,
                             migrate_cost=6.0, min_dwell=3),
                    use_pallas=False)
    np.testing.assert_array_equal(base.alloc_mw[perm], shuf.alloc_mw)
    assert base.cpc == pytest.approx(shuf.cpc, rel=1e-12)
    assert base.n_migrations == shuf.n_migrations
    assert base.migration_mw == pytest.approx(shuf.migration_mw,
                                              rel=1e-9, abs=1e-9)


def test_migration_fee_and_dwell_suppress_thrash():
    """More friction, fewer moves — and hour 0's initial placement is
    never billed as migration."""
    prices, avail, demand = _random_case(8, 600)
    free = dispatch(_problem(prices, avail, demand), use_pallas=False)
    fee = dispatch(_problem(prices, avail, demand, migrate_cost=15.0),
                   use_pallas=False)
    dwell = dispatch(_problem(prices, avail, demand, migrate_cost=15.0,
                              min_dwell=12), use_pallas=False)
    assert free.n_migrations > fee.n_migrations >= dwell.n_migrations
    assert free.migration_cost == 0.0          # no fee, no bill
    assert fee.migration_cost > 0.0
    # the free allocation chases prices: it pays the least for energy
    assert free.energy_cost <= fee.energy_cost + 1e-6
    assert free.energy_cost <= dwell.energy_cost + 1e-6


def test_min_dwell_holds_load_in_place():
    """Two sites, prices flipping every hour: without dwell the load
    hops every hour; with min_dwell=4 it moves at most every 4th hour
    (capacity stays ample, so locks are never force-broken)."""
    t = 96
    flip = np.tile([1.0, 0.0], t // 2)
    prices = np.stack([40.0 + 30.0 * flip, 40.0 + 30.0 * (1 - flip)]) \
        .astype(np.float32)
    avail = np.full((2, t), 2.0, np.float32)
    demand = np.full(t, 1.5, np.float32)
    hop = dispatch(_problem(prices, avail, demand), use_pallas=False)
    held = dispatch(_problem(prices, avail, demand, migrate_cost=1e-3,
                             min_dwell=4), use_pallas=False)
    assert hop.n_migrations == t - 1
    assert held.n_migrations <= (t - 1) // 4 + 1
    moves = np.abs(np.diff(held.alloc_mw, axis=1)).sum(axis=0)
    move_hours = np.flatnonzero(moves > 1e-6)
    assert np.all(np.diff(move_hours) >= 4)


# ---------------------------------------------------------------------------
# (c) hard constraints: loud infeasibility + reported slack
# ---------------------------------------------------------------------------

def test_power_cap_below_demand_raises():
    prices, avail, demand = _random_case(4, 100)
    with pytest.raises(DispatchInfeasible, match="power cap"):
        dispatch(_problem(prices, avail, demand,
                          power_cap=float(demand.min()) * 0.5))


def test_availability_shortfall_raises():
    prices, avail, demand = _random_case(4, 100)
    short = avail.copy()
    short[:, 42] = 0.0                 # one dark hour sinks the fleet
    with pytest.raises(DispatchInfeasible, match="worst hour 42"):
        dispatch(_problem(prices, short, demand))


def test_compute_floor_above_demand_raises():
    prices, avail, demand = _random_case(4, 100)
    with pytest.raises(DispatchInfeasible, match="compute floor"):
        dispatch(_problem(prices, avail, demand,
                          floor=float(demand.sum()) * 1.5))


def test_feasible_slack_is_reported():
    prices, avail, demand = _random_case(5, 200)
    cap = float(demand.max()) + 7.0
    res = dispatch(_problem(prices, avail, demand, power_cap=cap,
                            floor=float(demand.sum()) * 0.5),
                   use_pallas=False)
    assert res.slack_power_mw == pytest.approx(7.0, abs=1e-4)
    want_cap_slack = float((avail.sum(axis=0) - demand).min())
    assert res.slack_capacity_mw == pytest.approx(want_cap_slack,
                                                  rel=1e-5)
    assert res.slack_floor_mwh == pytest.approx(
        res.delivered_mwh - float(demand.sum()) * 0.5, rel=1e-6)


# ---------------------------------------------------------------------------
# (d) schedules match the fleet scan's state machine
# ---------------------------------------------------------------------------

def test_capacity_series_consistent_with_fleet_scan():
    s, t = 6, 500
    prices = rng.normal(80, 40, (s, t)).astype(np.float32)
    p_off = rng.uniform(40, 160, s).astype(np.float32)
    p_on = p_off * rng.uniform(0.7, 1.0, s).astype(np.float32)
    lvl = rng.uniform(0.0, 0.6, s).astype(np.float32)
    cap = np.asarray(capacity_series(prices, p_on, p_off, lvl))
    scan = fleet_scan_ref(prices, p_on, p_off, lvl, np.zeros(s))
    np.testing.assert_allclose(cap.sum(axis=1), np.asarray(scan.up_units),
                               rtol=1e-5, atol=1e-2)
    assert np.all((cap >= lvl[:, None] - 1e-6) & (cap <= 1.0))


# ---------------------------------------------------------------------------
# (e) summarize round-trip with the dispatch block
# ---------------------------------------------------------------------------

T = 400
SYS = make_system(fixed=0.5 * T * 80.0, power=1.0, period=float(T))
CFG = DispatchConfig(demand_frac=0.3, migrate_cost=4.0, min_dwell_h=3)


def _fleet_grid(n_markets=3):
    markets = [MarketParams(n_hours=T, seed=s) for s in range(n_markets)]
    return build_grid(markets, [SYS],
                      [PolicySpec("ao"),
                       PolicySpec("x5", x=0.05, off_level=0.3),
                       PolicySpec("x10", x=0.10, off_level=0.3)])


def test_summarize_dispatch_block_round_trip():
    grid = _fleet_grid()
    rep = backtest(grid, use_pallas=False)
    summ = summarize(grid, rep, dispatch_cfg=CFG)
    d = summ.dispatch
    assert d is not None
    assert d.alloc_mw.shape == (grid.n_markets, T)
    demand = CFG.demand_frac * grid.n_markets * float(SYS.C)
    np.testing.assert_allclose(d.alloc_mw.sum(axis=0),
                               np.full(T, demand), rtol=1e-4)
    assert d.delivered_mwh == pytest.approx(demand * T, rel=1e-5)
    # CPC folds fixed + energy + migration over delivered compute
    assert d.cpc == pytest.approx(
        (grid.n_markets * float(SYS.F) + d.energy_cost
         + d.migration_cost) / d.delivered_mwh, rel=1e-9)
    # without a config the block is absent
    assert summarize(grid, rep).dispatch is None


def test_summarize_dispatch_block_permutation_invariant():
    grid = _fleet_grid()
    rep = backtest(grid, use_pallas=False)
    base = summarize(grid, rep, dispatch_cfg=CFG).dispatch
    order = rng.permutation(grid.n_rows)
    grid_p = grid.take_rows(order)
    perm = summarize(grid_p, backtest(grid_p, use_pallas=False),
                     dispatch_cfg=CFG).dispatch
    for field in base._fields:
        np.testing.assert_allclose(np.asarray(getattr(base, field)),
                                   np.asarray(getattr(perm, field)),
                                   rtol=1e-6, atol=1e-6, err_msg=field)


def test_summarize_dispatch_infeasible_raises():
    grid = _fleet_grid()
    rep = backtest(grid, use_pallas=False)
    bad = CFG._replace(power_cap_mw=0.1)
    with pytest.raises(DispatchInfeasible):
        summarize(grid, rep, dispatch_cfg=bad)


def test_tune_dispatch_reeval():
    """TuneConfig.dispatch re-scores tuned vs swept policy sets on
    feasible dispatch and reports both."""
    from repro.tune import TuneConfig, optimize
    grid = _fleet_grid()
    res = optimize(grid, TuneConfig(steps=20, dispatch=CFG))
    d = res.dispatch
    assert d is not None and d["chosen"] in ("tuned", "swept")
    chosen = d[d["chosen"]]
    assert chosen is not None
    assert min(d["cpc_tuned"], d["cpc_swept"]) == pytest.approx(
        chosen.cpc, rel=1e-12)
    # feasible by construction: per-hour demand met by the chosen set
    demand = CFG.demand_frac * grid.n_markets * float(SYS.C)
    np.testing.assert_allclose(chosen.alloc_mw.sum(axis=0),
                               np.full(T, demand), rtol=1e-4)
