"""Cross-site dispatch subsystem tests: Pallas kernel vs sequential
oracle (bit-identical), site-permutation invariance, hard-constraint
feasibility at the extremes, schedule consistency with the fleet scan,
the `summarize` round-trip with the dispatch block, [T] demand
profiles end to end, and property-based invariants of the hard
water-fill over random feasible problems."""

import numpy as np
import pytest

from repro.core.tco import make_system
from repro.dispatch import (DispatchConfig, DispatchInfeasible,
                            DispatchProblem, build_problem,
                            capacity_series, dispatch, diurnal_demand,
                            resolve_demand, segment_keys, segment_rank)
from repro.energy.markets import MarketParams
from repro.fleet import PolicySpec, backtest, build_grid, summarize
from repro.kernels.dispatch_scan import dispatch_scan
from repro.kernels.ref import dispatch_ref, fleet_scan_ref

from tests._hypothesis_compat import given, settings, st

rng = np.random.default_rng(17)


def _random_case(s, t, *, demand_frac=0.5, seed_shift=0):
    """Random prices/availability with a feasible constant demand."""
    r = np.random.default_rng(17 + seed_shift)
    prices = r.normal(80, 40, (s, t)).astype(np.float32)
    power = r.uniform(1.0, 3.0, s).astype(np.float32)
    on = (r.uniform(size=(s, t)) > 0.3).astype(np.float32)
    avail = power[:, None] * (0.2 + 0.8 * on)      # never fully dark
    demand = np.full(t, demand_frac * float(avail.sum(axis=0).min()),
                     np.float32)
    return prices, avail, demand


def _problem(prices, avail, demand, *, migrate_cost=0.0, min_dwell=0,
             power_cap=float("inf"), floor=0.0, fixed=0.0):
    order, rank = segment_rank(prices, migrate_cost)
    return DispatchProblem(
        prices=np.asarray(prices, np.float32),
        avail_mw=np.asarray(avail, np.float32),
        demand_mw=np.asarray(demand, np.float32),
        power_cap_mw=power_cap, migrate_cost=migrate_cost,
        min_dwell_h=min_dwell, compute_floor_mwh=floor, fixed_cost=fixed,
        order=order, rank=rank)


# ---------------------------------------------------------------------------
# (a) Pallas kernel vs sequential oracle: bit-identical
# ---------------------------------------------------------------------------

DISPATCH_CASES = [
    # S, T, migrate_cost, min_dwell  (T exercising block padding)
    (1, 64, 0.0, 0),
    (5, 333, 5.0, 0),
    (16, 1000, 5.0, 6),
    (64, 700, 0.0, 3),
]


@pytest.mark.parametrize("case", DISPATCH_CASES)
def test_dispatch_scan_bit_identical_to_ref(case):
    s, t, mc, dwell = case
    prices, avail, demand = _random_case(s, t)
    order, rank = segment_rank(prices, mc)
    got = np.asarray(dispatch_scan(avail, order, rank, demand,
                                   min_dwell=dwell, block_t=256))
    want = np.asarray(dispatch_ref(avail, order, rank, demand,
                                   min_dwell=dwell))
    np.testing.assert_array_equal(got, want,
                                  err_msg=f"S={s} T={t} mc={mc}")


def test_dispatch_engine_paths_identical():
    prices, avail, demand = _random_case(7, 500)
    prob = _problem(prices, avail, demand, migrate_cost=3.0, min_dwell=4)
    ref = dispatch(prob, use_pallas=False)
    pal = dispatch(prob, use_pallas=True)
    np.testing.assert_array_equal(ref.alloc_mw, pal.alloc_mw)
    assert ref.cpc == pal.cpc and ref.n_migrations == pal.n_migrations


# ---------------------------------------------------------------------------
# (b) allocation semantics
# ---------------------------------------------------------------------------

def test_demand_is_met_exactly_within_availability():
    prices, avail, demand = _random_case(9, 400)
    res = dispatch(_problem(prices, avail, demand, migrate_cost=4.0,
                            min_dwell=5), use_pallas=False)
    np.testing.assert_allclose(res.alloc_mw.sum(axis=0), demand,
                               rtol=1e-5, atol=1e-4)
    assert np.all(res.alloc_mw <= np.asarray(avail) + 1e-5)
    assert np.all(res.alloc_mw >= 0.0)


def test_zero_migration_cost_reduces_to_per_hour_argmin():
    """With no fee and no dwell the dispatcher fills the cheapest
    available sites each hour independently (greedy price argmin)."""
    s, t = 6, 200
    prices, avail, demand = _random_case(s, t)
    res = dispatch(_problem(prices, avail, demand), use_pallas=False)
    want = np.zeros((s, t))
    for h in range(t):
        left = float(demand[h])
        for i in np.argsort(prices[:, h], kind="stable"):
            take = min(left, float(avail[i, h]))
            want[i, h] = take
            left -= take
    np.testing.assert_allclose(res.alloc_mw, want, rtol=1e-5, atol=1e-4)


def test_site_permutation_invariance():
    """Permuting site order permutes the allocation rows and nothing
    else (prices are continuous draws, so sort keys are distinct)."""
    prices, avail, demand = _random_case(11, 300)
    perm = rng.permutation(11)
    base = dispatch(_problem(prices, avail, demand, migrate_cost=6.0,
                             min_dwell=3), use_pallas=False)
    shuf = dispatch(_problem(prices[perm], avail[perm], demand,
                             migrate_cost=6.0, min_dwell=3),
                    use_pallas=False)
    np.testing.assert_array_equal(base.alloc_mw[perm], shuf.alloc_mw)
    assert base.cpc == pytest.approx(shuf.cpc, rel=1e-12)
    assert base.n_migrations == shuf.n_migrations
    assert base.migration_mw == pytest.approx(shuf.migration_mw,
                                              rel=1e-9, abs=1e-9)


def test_migration_fee_and_dwell_suppress_thrash():
    """More friction, fewer moves — and hour 0's initial placement is
    never billed as migration."""
    prices, avail, demand = _random_case(8, 600)
    free = dispatch(_problem(prices, avail, demand), use_pallas=False)
    fee = dispatch(_problem(prices, avail, demand, migrate_cost=15.0),
                   use_pallas=False)
    dwell = dispatch(_problem(prices, avail, demand, migrate_cost=15.0,
                              min_dwell=12), use_pallas=False)
    assert free.n_migrations > fee.n_migrations >= dwell.n_migrations
    assert free.migration_cost == 0.0          # no fee, no bill
    assert fee.migration_cost > 0.0
    # the free allocation chases prices: it pays the least for energy
    assert free.energy_cost <= fee.energy_cost + 1e-6
    assert free.energy_cost <= dwell.energy_cost + 1e-6


def test_min_dwell_holds_load_in_place():
    """Two sites, prices flipping every hour: without dwell the load
    hops every hour; with min_dwell=4 it moves at most every 4th hour
    (capacity stays ample, so locks are never force-broken)."""
    t = 96
    flip = np.tile([1.0, 0.0], t // 2)
    prices = np.stack([40.0 + 30.0 * flip, 40.0 + 30.0 * (1 - flip)]) \
        .astype(np.float32)
    avail = np.full((2, t), 2.0, np.float32)
    demand = np.full(t, 1.5, np.float32)
    hop = dispatch(_problem(prices, avail, demand), use_pallas=False)
    held = dispatch(_problem(prices, avail, demand, migrate_cost=1e-3,
                             min_dwell=4), use_pallas=False)
    assert hop.n_migrations == t - 1
    assert held.n_migrations <= (t - 1) // 4 + 1
    moves = np.abs(np.diff(held.alloc_mw, axis=1)).sum(axis=0)
    move_hours = np.flatnonzero(moves > 1e-6)
    assert np.all(np.diff(move_hours) >= 4)


# ---------------------------------------------------------------------------
# (b2) [T] demand profiles end to end
# ---------------------------------------------------------------------------

def test_demand_profile_is_followed_hour_by_hour():
    s, t = 5, 240
    prices, avail, _ = _random_case(s, t)
    base = 0.4 * float(avail.sum(axis=0).min())
    profile = np.asarray(diurnal_demand(t, base_mw=base,
                                        swing_mw=0.5 * base),
                         np.float32)
    assert profile.min() > 0.0 and profile.max() <= avail.sum(axis=0).min()
    res = dispatch(_problem(prices, avail, profile, migrate_cost=2.0),
                   use_pallas=False)
    np.testing.assert_allclose(res.alloc_mw.sum(axis=0), profile,
                               rtol=1e-4, atol=1e-4)
    # ramps are demand changes, not migrations: the billed volume is
    # the matched in/out flow, strictly below the total |delta| the
    # hourly ramps produce
    delta = np.abs(np.diff(res.alloc_mw, axis=1)).sum()
    assert 0.0 < res.migration_mw < delta


def test_dispatch_config_profile_through_build_problem():
    t = 96
    grid_prices = rng.normal(80, 30, (3, t)).astype(np.float32)
    prof = diurnal_demand(t, base_mw=1.0, swing_mw=0.4)
    cfg = DispatchConfig(demand_mw=prof, migrate_cost=1.0)
    prob = build_problem(grid_prices, np.full(3, 60.0), np.full(3, 70.0),
                         np.full(3, 0.5), np.full(3, 1.0), cfg)
    np.testing.assert_allclose(prob.demand_mw, np.asarray(prof),
                               rtol=1e-6)
    assert isinstance(hash(cfg), int)   # tuple profile stays hashable


def test_demand_profile_wrong_length_raises():
    cfg = DispatchConfig(demand_mw=tuple(np.ones(50)))
    with pytest.raises(ValueError, match="50 entries"):
        resolve_demand(cfg, np.ones(3), 96)
    with pytest.raises(ValueError, match="swing_mw"):
        diurnal_demand(24, base_mw=1.0, swing_mw=2.0)


def test_summarize_dispatch_with_diurnal_profile():
    grid = _fleet_grid()
    rep = backtest(grid, use_pallas=False)
    # peak must clear the worst-case fleet hour (all three best-policy
    # sites at off_level 0.3 -> 0.9 MW): peak 0.84 MW stays feasible
    prof = diurnal_demand(T, base_mw=0.2 * grid.n_markets,
                          swing_mw=0.08 * grid.n_markets)
    summ = summarize(grid, rep, dispatch_cfg=DispatchConfig(
        demand_mw=prof, migrate_cost=4.0, min_dwell_h=3))
    d = summ.dispatch
    np.testing.assert_allclose(d.alloc_mw.sum(axis=0), np.asarray(prof),
                               rtol=1e-4)
    assert summ.dispatch_rows is not None
    assert len(summ.dispatch_rows) == grid.n_markets


# ---------------------------------------------------------------------------
# (b3) property-based invariants of the hard water-fill
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000),
       s=st.integers(1, 12),
       mc=st.floats(0.0, 30.0),
       dwell=st.integers(0, 10),
       frac=st.floats(0.05, 0.95))
def test_dispatch_invariants_on_random_feasible_problems(
        seed, s, mc, dwell, frac):
    """For any feasible problem: allocations meet demand exactly, never
    exceed availability, stay non-negative, and dwell-locked load is
    held (a site that just gained load does not shed it within the
    lock, capacity permitting)."""
    t = 150
    prices, avail, demand = _random_case(s, t, demand_frac=frac,
                                         seed_shift=seed)
    res = dispatch(_problem(prices, avail, demand, migrate_cost=mc,
                            min_dwell=dwell), use_pallas=False)
    alloc = res.alloc_mw
    np.testing.assert_allclose(alloc.sum(axis=0), demand, rtol=1e-4,
                               atol=1e-4)
    assert np.all(alloc <= np.asarray(avail, np.float64) + 1e-4)
    assert np.all(alloc >= 0.0)
    if dwell > 0:
        # replay the lock ledger: after an allocation *increase* a
        # site's load may not drop for `dwell` hours unless its own
        # availability drops below the held level (physics beats
        # contract) or the fleet demand sinks below the sum of locks
        ledger = np.zeros(s)
        prev = np.zeros(s)
        for h in range(t):
            locked = ledger > 0
            can_hold = np.minimum(prev, avail[:, h])
            if demand[h] >= can_hold[locked].sum() - 1e-4:
                assert np.all(alloc[:, h][locked]
                              >= can_hold[locked] - 1e-3), f"hour {h}"
            gained = alloc[:, h] > prev + 1e-3
            ledger = np.where(gained, dwell, np.maximum(ledger - 1, 0))
            prev = alloc[:, h]


@settings(max_examples=20, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), s=st.integers(2, 10),
       mc=st.floats(0.5, 25.0))
def test_dispatch_total_cost_monotone_in_migrate_cost(seed, s, mc):
    """The zero-fee dispatch is the per-hour cost optimum, so any
    positive fee can only cost more in total (energy + migration); and
    along an increasing fee ladder the *billed migration volume* never
    grows (more friction, fewer MW moved)."""
    t = 200
    prices, avail, demand = _random_case(s, t, seed_shift=seed)
    free = dispatch(_problem(prices, avail, demand), use_pallas=False)
    free_total = free.energy_cost       # no fee -> no migration bill
    moved_prev = free.migration_mw
    for fee in (0.5 * mc, mc, 2.0 * mc):
        res = dispatch(_problem(prices, avail, demand, migrate_cost=fee),
                       use_pallas=False)
        total = res.energy_cost + res.migration_cost
        assert total >= free_total - 1e-6 * max(1.0, abs(free_total))
        assert res.migration_mw <= moved_prev * (1.0 + 1e-6) + 1e-6
        moved_prev = res.migration_mw


# ---------------------------------------------------------------------------
# (c) hard constraints: loud infeasibility + reported slack
# ---------------------------------------------------------------------------

def test_power_cap_below_demand_raises():
    prices, avail, demand = _random_case(4, 100)
    with pytest.raises(DispatchInfeasible, match="power cap"):
        dispatch(_problem(prices, avail, demand,
                          power_cap=float(demand.min()) * 0.5))


def test_availability_shortfall_raises():
    prices, avail, demand = _random_case(4, 100)
    short = avail.copy()
    short[:, 42] = 0.0                 # one dark hour sinks the fleet
    with pytest.raises(DispatchInfeasible, match="worst hour 42"):
        dispatch(_problem(prices, short, demand))


def test_compute_floor_above_demand_raises():
    prices, avail, demand = _random_case(4, 100)
    with pytest.raises(DispatchInfeasible, match="compute floor"):
        dispatch(_problem(prices, avail, demand,
                          floor=float(demand.sum()) * 1.5))


def test_feasible_slack_is_reported():
    prices, avail, demand = _random_case(5, 200)
    cap = float(demand.max()) + 7.0
    res = dispatch(_problem(prices, avail, demand, power_cap=cap,
                            floor=float(demand.sum()) * 0.5),
                   use_pallas=False)
    assert res.slack_power_mw == pytest.approx(7.0, abs=1e-4)
    want_cap_slack = float((avail.sum(axis=0) - demand).min())
    assert res.slack_capacity_mw == pytest.approx(want_cap_slack,
                                                  rel=1e-5)
    assert res.slack_floor_mwh == pytest.approx(
        res.delivered_mwh - float(demand.sum()) * 0.5, rel=1e-6)


# ---------------------------------------------------------------------------
# (d) schedules match the fleet scan's state machine
# ---------------------------------------------------------------------------

def test_capacity_series_consistent_with_fleet_scan():
    s, t = 6, 500
    prices = rng.normal(80, 40, (s, t)).astype(np.float32)
    p_off = rng.uniform(40, 160, s).astype(np.float32)
    p_on = p_off * rng.uniform(0.7, 1.0, s).astype(np.float32)
    lvl = rng.uniform(0.0, 0.6, s).astype(np.float32)
    cap = np.asarray(capacity_series(prices, p_on, p_off, lvl))
    scan = fleet_scan_ref(prices, p_on, p_off, lvl, np.zeros(s))
    np.testing.assert_allclose(cap.sum(axis=1), np.asarray(scan.up_units),
                               rtol=1e-5, atol=1e-2)
    assert np.all((cap >= lvl[:, None] - 1e-6) & (cap <= 1.0))


# ---------------------------------------------------------------------------
# (e) summarize round-trip with the dispatch block
# ---------------------------------------------------------------------------

T = 400
SYS = make_system(fixed=0.5 * T * 80.0, power=1.0, period=float(T))
CFG = DispatchConfig(demand_frac=0.3, migrate_cost=4.0, min_dwell_h=3)


def _fleet_grid(n_markets=3):
    markets = [MarketParams(n_hours=T, seed=s) for s in range(n_markets)]
    return build_grid(markets, [SYS],
                      [PolicySpec("ao"),
                       PolicySpec("x5", x=0.05, off_level=0.3),
                       PolicySpec("x10", x=0.10, off_level=0.3)])


def test_summarize_dispatch_block_round_trip():
    grid = _fleet_grid()
    rep = backtest(grid, use_pallas=False)
    summ = summarize(grid, rep, dispatch_cfg=CFG)
    d = summ.dispatch
    assert d is not None
    assert d.alloc_mw.shape == (grid.n_markets, T)
    demand = CFG.demand_frac * grid.n_markets * float(SYS.C)
    np.testing.assert_allclose(d.alloc_mw.sum(axis=0),
                               np.full(T, demand), rtol=1e-4)
    assert d.delivered_mwh == pytest.approx(demand * T, rel=1e-5)
    # CPC folds fixed + energy + migration over delivered compute
    assert d.cpc == pytest.approx(
        (grid.n_markets * float(SYS.F) + d.energy_cost
         + d.migration_cost) / d.delivered_mwh, rel=1e-9)
    # without a config the block is absent
    assert summarize(grid, rep).dispatch is None


def test_summarize_dispatch_block_permutation_invariant():
    grid = _fleet_grid()
    rep = backtest(grid, use_pallas=False)
    base = summarize(grid, rep, dispatch_cfg=CFG).dispatch
    order = rng.permutation(grid.n_rows)
    grid_p = grid.take_rows(order)
    perm = summarize(grid_p, backtest(grid_p, use_pallas=False),
                     dispatch_cfg=CFG).dispatch
    for field in base._fields:
        np.testing.assert_allclose(np.asarray(getattr(base, field)),
                                   np.asarray(getattr(perm, field)),
                                   rtol=1e-6, atol=1e-6, err_msg=field)


def test_summarize_dispatch_infeasible_raises():
    grid = _fleet_grid()
    rep = backtest(grid, use_pallas=False)
    bad = CFG._replace(power_cap_mw=0.1)
    with pytest.raises(DispatchInfeasible):
        summarize(grid, rep, dispatch_cfg=bad)


def test_tune_dispatch_reeval():
    """TuneConfig.dispatch re-scores tuned vs swept policy sets on
    feasible dispatch and reports both."""
    from repro.tune import TuneConfig, optimize
    grid = _fleet_grid()
    res = optimize(grid, TuneConfig(steps=20, dispatch=CFG))
    d = res.dispatch
    assert d is not None and d["chosen"] in ("tuned", "swept")
    chosen = d[d["chosen"]]
    assert chosen is not None
    assert min(d["cpc_tuned"], d["cpc_swept"]) == pytest.approx(
        chosen.cpc, rel=1e-12)
    # feasible by construction: per-hour demand met by the chosen set
    demand = CFG.demand_frac * grid.n_markets * float(SYS.C)
    np.testing.assert_allclose(chosen.alloc_mw.sum(axis=0),
                               np.full(T, demand), rtol=1e-4)
