"""Differentiable policy-tuning subsystem tests: soft-scan relaxation
consistency (associative vs sequential, tau -> 0 limit vs the hard
scan), autodiff gradients vs central finite differences, reparam
feasibility, and the acceptance guarantee — tuned-then-hardened CPC
matches or beats the best swept `PolicySpec` on every row of a
fixed-seed 256-row grid."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core.tco import make_system
from repro.energy.markets import MarketParams
from repro.fleet import PolicySpec, build_grid
from repro.kernels.ref import fleet_scan_ref, soft_scan_ref
from repro.kernels.soft_scan import soft_fleet_scan
from repro.tune import (PhysicalPolicy, PolicyParams, TuneConfig,
                        init_from_grid, inverse_transform, optimize,
                        problem_from_grid, soft_objective, transform)

rng = np.random.default_rng(11)


def _random_case(b, t, gap_max=30.0):
    p = jnp.asarray(rng.normal(80, 40, (b, t)), jnp.float32)
    p_off = jnp.asarray(rng.uniform(40, 160, b), jnp.float32)
    p_on = p_off - jnp.asarray(rng.uniform(0.5, gap_max, b), jnp.float32)
    lvl = jnp.asarray(rng.uniform(0.0, 0.6, b), jnp.float32)
    idle = jnp.asarray(rng.uniform(0.0, 0.3, b), jnp.float32)
    return p, p_on, p_off, lvl, idle


# ---------------------------------------------------------------------------
# (a) soft scan: fused associative form vs sequential oracle, and tau -> 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", [20.0, 2.0, 0.1])
def test_soft_scan_matches_sequential_oracle(tau):
    p, p_on, p_off, lvl, idle = _random_case(7, 333)
    got = soft_fleet_scan(p, p_on, p_off, lvl, idle, tau=tau)
    want = soft_scan_ref(p, p_on, p_off, lvl, idle, tau=tau)
    for name in want._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            rtol=2e-4, atol=2e-3, err_msg=f"tau={tau} {name}")


def test_soft_scan_converges_to_hard_scan():
    """tau -> 0: the relaxation equals the hard two-threshold state
    machine at every sample away from the thresholds (random normal
    prices never sit exactly on a threshold)."""
    p, p_on, p_off, lvl, idle = _random_case(9, 500)
    hard = fleet_scan_ref(p, p_on, p_off, lvl, idle)
    soft = soft_fleet_scan(p, p_on, p_off, lvl, idle, tau=1e-3)
    for name in hard._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(soft, name)), np.asarray(getattr(hard, name)),
            rtol=1e-4, atol=5e-2, err_msg=name)


def test_soft_scan_temperature_ordering():
    """Smoother temperatures blur the state, but every temperature keeps
    the soft up_units within the trivial [0, T] bounds and the soft
    start count non-negative."""
    p, p_on, p_off, lvl, idle = _random_case(5, 200)
    for tau in (50.0, 5.0, 0.5):
        out = soft_fleet_scan(p, p_on, p_off, lvl, idle, tau=tau)
        assert np.all(np.asarray(out.up_units) >= 0.0)
        assert np.all(np.asarray(out.up_units) <= p.shape[1] + 1e-3)
        assert np.all(np.asarray(out.n_starts) >= -1e-6)


# ---------------------------------------------------------------------------
# (b) gradients vs central finite differences (float64)
# ---------------------------------------------------------------------------

def _tiny_problem_f64(b=3, t=48):
    from repro.tune.objective import TuneProblem
    p = rng.normal(80, 40, (b, t))
    return TuneProblem(
        prices=jnp.asarray(p, jnp.float64),
        market_idx=jnp.arange(b, dtype=jnp.int32),
        price_sum=jnp.asarray(p.sum(axis=1), jnp.float64),
        fixed=jnp.asarray(rng.uniform(5e4, 2e5, b), jnp.float64),
        power=jnp.asarray(np.full(b, 1.0), jnp.float64),
        period=jnp.asarray(np.full(b, float(t)), jnp.float64),
        idle_frac=jnp.asarray(np.full(b, 0.05), jnp.float64),
        restart_energy_mwh=jnp.asarray(np.full(b, 0.2), jnp.float64),
        restart_time_h=jnp.asarray(np.full(b, 0.1), jnp.float64),
        site_weight=jnp.asarray(np.full(b, 1.0), jnp.float64))


def test_gradients_match_finite_differences():
    """jax.grad through the associative soft scan vs central differences
    on every raw coordinate, rtol <= 1e-3 (float64)."""
    with enable_x64():
        problem = _tiny_problem_f64()
        b = problem.market_idx.shape[0]
        raw = PolicyParams(
            raw_off=jnp.asarray(rng.uniform(60, 120, b), jnp.float64),
            raw_gap=jnp.asarray(rng.uniform(0.5, 3.0, b), jnp.float64),
            raw_lvl=jnp.asarray(rng.uniform(-2.0, 1.0, b), jnp.float64))

        def loss(r):
            return soft_objective(r, problem, 5.0)[0]

        got = jax.grad(loss)(raw)
        for field in raw._fields:
            base = np.asarray(getattr(raw, field), np.float64)
            for i in range(b):
                h = 1e-4 * max(1.0, abs(base[i]))
                hi, lo = base.copy(), base.copy()
                hi[i] += h
                lo[i] -= h
                fd = (loss(raw._replace(**{field: jnp.asarray(hi)}))
                      - loss(raw._replace(**{field: jnp.asarray(lo)}))
                      ) / (2 * h)
                ad = float(np.asarray(getattr(got, field))[i])
                np.testing.assert_allclose(
                    ad, float(fd), rtol=1e-3, atol=1e-10,
                    err_msg=f"{field}[{i}]")


def test_penalty_gradients_flow():
    """Fleet-coupling penalties are active and differentiable: a binding
    power cap / compute floor yields a positive penalty and finite,
    non-zero gradients."""
    with enable_x64():
        problem = _tiny_problem_f64()

        def loss(r):
            return soft_objective(r, problem, 5.0, power_cap_mw=1.0,
                                  min_up_hours=1e4)[0]

        b = problem.market_idx.shape[0]
        raw = PolicyParams(raw_off=jnp.full((b,), 90.0),
                           raw_gap=jnp.full((b,), 1.0),
                           raw_lvl=jnp.full((b,), -1.0))
        _, aux = soft_objective(raw, problem, 5.0, power_cap_mw=1.0,
                                min_up_hours=1e4)
        assert float(aux["penalty"]) > 0.0
        g = jax.grad(loss)(raw)
        for field in raw._fields:
            arr = np.asarray(getattr(g, field))
            assert np.isfinite(arr).all()
        assert float(np.abs(np.asarray(g.raw_off)).max()) > 0.0


# ---------------------------------------------------------------------------
# (c) reparameterization: feasible by construction, invertible
# ---------------------------------------------------------------------------

def test_reparam_feasible_for_arbitrary_raw():
    """Any raw values — including extreme magnitudes — map to a feasible
    policy: p_on <= p_off and off_level in [0, 1)."""
    n = 64
    extremes = np.asarray([-1e6, -100.0, -1.0, 0.0, 1.0, 100.0, 1e6])
    raw = PolicyParams(
        raw_off=jnp.asarray(np.concatenate(
            [extremes, rng.normal(80, 200, n - len(extremes))]),
            jnp.float32),
        raw_gap=jnp.asarray(np.concatenate(
            [extremes, rng.normal(0, 50, n - len(extremes))]), jnp.float32),
        raw_lvl=jnp.asarray(np.concatenate(
            [extremes, rng.normal(0, 20, n - len(extremes))]), jnp.float32))
    phys = transform(raw)
    assert np.all(np.asarray(phys.p_on) <= np.asarray(phys.p_off) + 1e-6)
    assert np.all(np.asarray(phys.off_level) >= 0.0)
    assert np.all(np.asarray(phys.off_level) < 1.0)


def test_reparam_round_trip():
    b = 32
    phys = PhysicalPolicy(
        p_off=jnp.asarray(rng.uniform(40, 160, b), jnp.float32),
        p_on=None, off_level=jnp.asarray(rng.uniform(0.0, 0.9, b),
                                         jnp.float32))
    phys = phys._replace(
        p_on=phys.p_off - jnp.asarray(rng.uniform(0.01, 40, b), jnp.float32))
    back = transform(inverse_transform(phys))
    np.testing.assert_allclose(np.asarray(back.p_off),
                               np.asarray(phys.p_off), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(back.p_on),
                               np.asarray(phys.p_on), rtol=1e-4, atol=5e-3)
    np.testing.assert_allclose(np.asarray(back.off_level),
                               np.asarray(phys.off_level), atol=2e-4)


def test_init_from_grid_handles_always_on_rows():
    grid = build_grid([MarketParams(n_hours=400, seed=3)],
                      [make_system(60_000.0, 1.0, 400.0)],
                      [PolicySpec("ao"), PolicySpec("x5", x=0.05)])
    raw = init_from_grid(grid)
    phys = transform(raw)
    assert np.isfinite(np.asarray(phys.p_off)).all()
    # the AO row's finite stand-in threshold keeps it always-on: no
    # sample of its market exceeds the seeded p_off
    p_max = float(np.asarray(grid.prices).max())
    assert float(np.asarray(phys.p_off)[0]) >= p_max - 1e-3


# ---------------------------------------------------------------------------
# (d) acceptance: tuned (hard re-evaluated) matches or beats best swept
# ---------------------------------------------------------------------------

def _acceptance_grid():
    """Fixed-seed 4 markets x 4 systems x 16 policies = 256 rows.

    Hardware parameters (idle draw, restart costs) are uniform across
    policies, so the best-swept CPC per cell is directly comparable with
    tuned rows under any row's hardware."""
    t = 600
    markets = [MarketParams(n_hours=t, seed=s) for s in range(4)]
    systems = [make_system(float(psi) * t * 1.0 * 80.0, 1.0, float(t))
               for psi in (0.5, 1.0, 2.0, 4.0)]
    xs = (0.01, 0.02, 0.03, 0.05, 0.08, 0.10, 0.12, 0.15,
          0.20, 0.25, 0.30, 0.40)
    policies = [PolicySpec("ao")] + \
        [PolicySpec(f"x{int(x * 100)}", x=x) for x in xs] + \
        [PolicySpec("x3h", x=0.03, hysteresis=0.9),
         PolicySpec("x8h", x=0.08, hysteresis=0.85),
         PolicySpec("x15h", x=0.15, hysteresis=0.9)]
    return build_grid(markets, systems, policies)


def test_tuned_matches_or_beats_best_swept_on_every_row():
    grid = _acceptance_grid()
    assert grid.n_rows == 256
    res = optimize(grid, TuneConfig(steps=150))
    # hard guarantee: never worse than the best swept policy of the cell
    assert np.all(res.cpc <= res.cpc_swept_best * (1.0 + 1e-6))
    assert np.all(res.improvement_vs_best >= -1e-6)
    # and the gradient run genuinely searches: a meaningful share of
    # rows strictly improves on the *best* swept policy...
    strict = res.cpc < res.cpc_swept_best * (1.0 - 1e-5)
    assert strict.sum() >= grid.n_rows // 16
    # ...and on average every row improves a lot over its own policy
    assert res.improvement_vs_own.mean() > 0.01
    # the annealed soft loss went down
    assert res.history["loss"][-1] < res.history["loss"][0]
    # selected params are feasible
    assert np.all(np.asarray(res.params.p_on)
                  <= np.asarray(res.params.p_off) + 1e-6)
    lvl = np.asarray(res.params.off_level)
    assert np.all((lvl >= 0.0) & (lvl < 1.0))
    # staged hard re-evaluations ride along whether or not telemetry is
    # on: [eval_stages] finite means, the last being the final hard
    # re-eval itself
    assert res.stage_cpc.shape == (TuneConfig().eval_stages,)
    assert np.isfinite(res.stage_cpc).all()
    np.testing.assert_allclose(res.stage_cpc[-1],
                               np.asarray(res.cpc_tuned).mean(),
                               rtol=1e-5)


def test_stage_cpc_staging_leaves_trajectory_unchanged():
    """Splitting the Adam scan into eval_stages segments runs the same
    per-step ops in the same order — trajectories agree to float32
    round-off for any stage count (segment boundaries change XLA fusion,
    so agreement is ULP-level rather than bitwise) and the stage curve's
    last entry is the final hard re-eval."""
    grid = build_grid([MarketParams(n_hours=300, seed=5)],
                      [make_system(0.8 * 300 * 1.0 * 80.0, 1.0, 300.0)],
                      [PolicySpec("x5", x=0.05), PolicySpec("x20", x=0.2)])
    res1 = optimize(grid, TuneConfig(steps=24, eval_stages=1, shard=False))
    res3 = optimize(grid, TuneConfig(steps=24, eval_stages=3, shard=False))
    assert res1.stage_cpc.shape == (1,)
    assert res3.stage_cpc.shape == (3,)
    for field in res1.raw._fields:
        np.testing.assert_allclose(np.asarray(getattr(res1.raw, field)),
                                   np.asarray(getattr(res3.raw, field)),
                                   rtol=1e-6, atol=1e-6, err_msg=field)
    np.testing.assert_allclose(res1.cpc_tuned, res3.cpc_tuned, rtol=1e-6)
    np.testing.assert_allclose(res1.stage_cpc[-1], res3.stage_cpc[-1],
                               rtol=1e-6)


def test_min_up_hours_penalty_shifts_optimum():
    """A binding aggregate-compute floor must keep the tuned fleet's
    hard up-hours above the unconstrained optimum's."""
    t = 400
    grid = build_grid([MarketParams(n_hours=t, seed=9)],
                      [make_system(0.25 * t * 1.0 * 80.0, 1.0, float(t))],
                      [PolicySpec(f"x{int(x * 100)}", x=x)
                       for x in (0.1, 0.3, 0.5)])
    free = optimize(grid, TuneConfig(steps=80))
    # min_up_hours is in per-site units (candidate rows of a cell are
    # averaged, not summed): 1.02 * t is above the single site's
    # maximum deliverable, so the floor always binds
    floor = 1.02 * t
    constrained = optimize(grid, TuneConfig(
        steps=80, min_up_hours=floor, penalty_weight=100.0))
    prob = problem_from_grid(grid)
    from repro.fleet.engine import fleet_costs
    from repro.kernels.ref import fleet_scan_ref as hard

    def total_up(params):
        scan = hard(prob.row_prices(), params.p_on, params.p_off,
                    params.off_level, prob.idle_frac)
        c = fleet_costs(scan, price_sum=prob.price_sum, fixed=prob.fixed,
                        power=prob.power, period=prob.period,
                        restart_energy_mwh=prob.restart_energy_mwh,
                        restart_time_h=prob.restart_time_h,
                        n_samples=t)
        return float(np.sum(np.asarray(c.up_hours)))

    assert total_up(constrained.params) >= total_up(free.params) - 1e-6
