"""Elastic capacity + operational policy tests (paper §V-C machinery)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import (hysteresis_policy, policy_cpc,
                               shutdown_cost_adjusted_viability,
                               threshold_policy)
from repro.core.tco import cpc_with_shutdowns, make_system
from repro.core.price_model import price_stats
from repro.runtime.elastic import (capacity_plan, capacity_schedule,
                                   reshard_tree, resize_mesh)


# ---------------------------------------------------------------------------
# elastic capacity
# ---------------------------------------------------------------------------

def test_capacity_plan_preserves_global_batch():
    plan = capacity_plan(level=0.5, dp_total=16, base_microbatches=2)
    assert plan.dp_size == 8
    # half the replicas -> twice the accumulation
    assert plan.microbatches == 4
    assert plan.level == pytest.approx(0.5)


def test_capacity_plan_floors_at_one_replica():
    plan = capacity_plan(level=0.01, dp_total=8)
    assert plan.dp_size == 1
    assert plan.microbatches == 8


def test_resize_mesh_single_device():
    devices = np.asarray(jax.devices())
    mesh = resize_mesh(devices, level=1.0, model_parallel=1)
    assert mesh.size == 1
    assert tuple(mesh.shape.keys()) == ("data", "model")


def test_reshard_tree_places_on_mesh():
    from jax.sharding import Mesh
    from repro.parallel.axes import SINGLE_DEVICE_RULES
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    tree = {"w": jnp.arange(8.0).reshape(2, 4)}
    out = reshard_tree(tree, mesh, {"w": ("batch", None)},
                       SINGLE_DEVICE_RULES)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_capacity_schedule_blends_partitions():
    prices = np.asarray([10.0, 10.0, 100.0, 1000.0])
    plans = {
        "a": {"viable": True, "p_thresh": 50.0},    # off at 100, 1000
        "b": {"viable": True, "p_thresh": 500.0},   # off at 1000
        "c": {"viable": False, "p_thresh": np.inf}, # never off
    }
    power = {"a": 1.0, "b": 1.0, "c": 2.0}
    cap = capacity_schedule(prices, plans, power)
    np.testing.assert_allclose(cap, [1.0, 1.0, 0.75, 0.5])


# ---------------------------------------------------------------------------
# operational policies (beyond-paper §V-A/V-C refinements)
# ---------------------------------------------------------------------------

def test_capacity_schedule_empty_partitions_is_all_zero():
    """No partitions (or zero installed power) => zero capacity, not a
    ZeroDivisionError."""
    prices = np.asarray([10.0, 100.0, 1000.0])
    np.testing.assert_array_equal(capacity_schedule(prices, {}, {}),
                                  np.zeros(3))
    np.testing.assert_array_equal(
        capacity_schedule(prices, {"a": {"viable": False,
                                         "p_thresh": np.inf}},
                          {"a": 0.0}),
        np.zeros(3))


def test_policy_cpc_counts_boot_restart_when_starting_off():
    """A series that begins in the off state bills its boot (index 0) as a
    restart once initial_uptime says the machine was down before t=0."""
    prices = np.asarray([100.0, 50.0, 50.0, 50.0], np.float32)
    sysd = make_system(fixed=1000.0, power=1.0, period=4.0)
    mask = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    base = float(policy_cpc(sysd, prices, mask, restart_energy_mwh=2.0,
                            restart_time_h=0.5))
    booted = float(policy_cpc(sysd, prices, mask, restart_energy_mwh=2.0,
                              restart_time_h=0.5, initial_uptime=0.0))
    # boot restart: +2 MWh at p[0]=100 in cost, -0.5 h of uptime
    e_run = float(np.sum(prices))
    assert base == pytest.approx((1000.0 + e_run) / 4.0)
    assert booted == pytest.approx((1000.0 + e_run + 200.0) / 3.5)


def test_hysteresis_reduces_churn():
    prices = np.asarray([50, 120, 90, 120, 90, 120, 50], np.float32)
    single = np.asarray(threshold_policy(prices, 100.0))
    hyst = np.asarray(hysteresis_policy(prices, p_on=80.0, p_off=100.0))
    churn = lambda m: int(np.abs(np.diff(m)).sum())  # noqa: E731
    assert churn(hyst) < churn(single)
    # hysteresis never runs while a single threshold would shut down
    assert np.all(hyst <= single + 1e-9)


def test_policy_cpc_reduces_to_eq13_without_overheads():
    rng = np.random.default_rng(0)
    prices = np.abs(rng.normal(80, 40, 1000)).astype(np.float32)
    sysd = make_system(fixed=50_000.0, power=1.0, period=1000.0)
    st = price_stats(prices, 0.05)
    mask = threshold_policy(prices, float(st.p_thresh))
    got = float(policy_cpc(sysd, prices, mask))
    want = float(cpc_with_shutdowns(sysd, st.p_avg, st.k, st.x))
    assert got == pytest.approx(want, rel=2e-3)


def test_restart_overheads_increase_cpc():
    rng = np.random.default_rng(1)
    prices = np.abs(rng.normal(80, 40, 500)).astype(np.float32)
    sysd = make_system(fixed=10_000.0, power=1.0, period=500.0)
    mask = threshold_policy(prices, 150.0)
    free = float(policy_cpc(sysd, prices, mask))
    costly = float(policy_cpc(sysd, prices, mask,
                              restart_energy_mwh=0.5, restart_time_h=0.5))
    assert costly > free


def test_overhead_adjusted_viability_shrinks_region():
    # viable at zero overhead, not viable once overhead eats the spike
    assert bool(shutdown_cost_adjusted_viability(2.0, 4.0, 0.0))
    assert not bool(shutdown_cost_adjusted_viability(2.0, 4.0, 0.5))
