"""Per-architecture smoke tests: every assigned arch instantiates a reduced
same-family config and runs one forward/train step on CPU — shapes right,
no NaNs — plus prefill/decode consistency for the serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.configs.inputs import reduced_config
from repro.models.model import (decode_step, init_cache, init_params,
                                loss_fn, prefill)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(2, cfg.vocab - 1, (b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.vis_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = reduced_config(get_config(request.param))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


def test_forward_loss_finite(arch_setup):
    arch, cfg, params = arch_setup
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(
        params, _batch(cfg))
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    assert jnp.isfinite(metrics["ce"])


def test_train_step_updates_params(arch_setup):
    arch, cfg, params = arch_setup
    opt = AdamWConfig(lr=1e-3)
    state = adamw_init(params, opt)
    batch = _batch(cfg)

    @jax.jit
    def step(p, s):
        (loss, _), grads = jax.value_and_grad(
            lambda q: loss_fn(q, batch, cfg), has_aux=True)(p)
        newp, news, stats = adamw_update(grads, s, p, opt)
        return newp, news, loss, stats

    new_params, new_state, loss, stats = step(params, state)
    assert jnp.isfinite(loss)
    assert float(stats["grad_norm"]) > 0
    # at least the embedding moved
    delta = jnp.max(jnp.abs(new_params["embed"]["tok"].astype(jnp.float32)
                            - params["embed"]["tok"].astype(jnp.float32)))
    assert float(delta) > 0
    assert int(new_state.step) == 1


def test_loss_decreases_over_steps(arch_setup):
    arch, cfg, params = arch_setup
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = adamw_init(params, opt)
    batch = _batch(cfg)          # overfit one fixed batch

    @jax.jit
    def step(p, s):
        (loss, _), grads = jax.value_and_grad(
            lambda q: loss_fn(q, batch, cfg), has_aux=True)(p)
        newp, news, _ = adamw_update(grads, s, p, opt)
        return newp, news, loss

    losses = []
    for _ in range(8):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0], (arch, losses)


def test_prefill_then_decode_matches_joint_prefill(arch_setup):
    """Greedy consistency: prefill(s) + decode_step(token s) must agree
    with prefill(s+1) on the next-token logits."""
    arch, cfg, params = arch_setup
    b, s = 2, 24
    batch = _batch(cfg, b=b, s=s + 1, seed=1)
    full = {k: (v[:, :s + 1] if k in ("tokens", "labels") else v)
            for k, v in batch.items()}
    head = {k: (v[:, :s] if k in ("tokens", "labels") else v)
            for k, v in batch.items()}

    logits_full, _ = prefill(params, full, cfg, max_seq=s + 4)
    _, caches = prefill(params, head, cfg, max_seq=s + 4)
    logits_step, _ = decode_step(
        params, full["tokens"][:, s:s + 1], caches,
        jnp.full((b,), s, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


def test_multi_token_decode_matches_prefill(arch_setup):
    """Decode 4 tokens autoregressively from a prefilled cache; each step's
    logits must match a fresh prefill of the extended prompt."""
    arch, cfg, params = arch_setup
    b, s0, n_new = 1, 16, 4
    batch = _batch(cfg, b=b, s=s0 + n_new, seed=2)
    toks = batch["tokens"]
    head = dict(batch, tokens=toks[:, :s0], labels=toks[:, :s0])
    _, caches = prefill(params, head, cfg, max_seq=s0 + n_new + 1)
    for i in range(n_new):
        pos = s0 + i
        logits, caches = decode_step(params, toks[:, pos:pos + 1], caches,
                                     jnp.full((b,), pos, jnp.int32), cfg)
        ref_batch = dict(batch, tokens=toks[:, :pos + 1],
                         labels=toks[:, :pos + 1])
        ref_logits, _ = prefill(params, ref_batch, cfg,
                                max_seq=s0 + n_new + 1)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits),
                                   atol=3e-3, rtol=3e-3)


def test_init_cache_abstract_matches_concrete(arch_setup):
    arch, cfg, params = arch_setup
    conc = init_cache(cfg, 2, 16)
    abst = init_cache(cfg, 2, 16, abstract=True)
    c_leaves = jax.tree.leaves(conc)
    a_leaves = jax.tree.leaves(abst)
    assert len(c_leaves) == len(a_leaves)
    for c, a in zip(c_leaves, a_leaves):
        assert c.shape == a.shape and c.dtype == a.dtype


def test_pallas_impl_matches_xla(arch_setup):
    arch, cfg, params = arch_setup
    if cfg.family == "audio":
        pytest.skip("enc-dec covered via dense path")
    batch = _batch(cfg, b=1, s=32, seed=3)
    l_x, _ = loss_fn(params, batch, cfg)
    l_p, _ = loss_fn(params, batch, cfg.replace(attn_impl="pallas"))
    assert abs(float(l_x) - float(l_p)) < 1e-4, arch


def test_int8_kv_cache_close_to_bf16(arch_setup):
    """Scaled int8 KV (beyond-paper): multi-step decode must stay within
    quantization tolerance of the bf16 cache."""
    arch, cfg, params = arch_setup
    if cfg.family in ("ssm",):
        pytest.skip("no attention KV cache")
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    batch = _batch(cfg, b=2, s=21, seed=4)
    toks = batch["tokens"]
    outs = {}
    for name, c in [("base", cfg), ("int8", cfg8)]:
        head = dict(batch, tokens=toks[:, :16], labels=toks[:, :16])
        _, caches = prefill(params, head, c, max_seq=24)
        lg = None
        for i in range(5):
            lg, caches = decode_step(params, toks[:, 16 + i:17 + i],
                                     caches,
                                     jnp.full((2,), 16 + i, jnp.int32), c)
        outs[name] = lg
    denom = float(jnp.max(jnp.abs(outs["base"]))) + 1e-9
    rel = float(jnp.max(jnp.abs(outs["base"] - outs["int8"]))) / denom
    assert rel < 0.02, (arch, rel)
