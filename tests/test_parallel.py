"""Distribution tests on a host-device mesh (these spawn subprocesses with
XLA_FLAGS so the main test process keeps its single CPU device)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.parallel.axes import (SINGLE_DEVICE_RULES, TRAIN_RULES,
                                 logical_to_spec)


def test_logical_to_spec_basic():
    spec = logical_to_spec(("batch", "seq", None), TRAIN_RULES, mesh=None)
    assert spec == P(("pod", "data"), "model", None)


def test_logical_to_spec_dedupes_used_axes():
    spec = logical_to_spec(("seq", "heads", None), TRAIN_RULES, mesh=None)
    # both map to "model"; second use must drop it
    assert spec == P("model", None, None)


def test_single_device_rules_all_none():
    spec = logical_to_spec(("batch", "seq", "heads"), SINGLE_DEVICE_RULES)
    assert spec == P(None, None, None)


def _run_subprocess(body: str, devices: int = 8) -> str:
    """Run a snippet under forced host device count; return stdout."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        import sys
        sys.path.insert(0, {os.path.abspath('src')!r})
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_loss_matches_single_device():
    """The same model + batch must produce the same loss on a 2x4 mesh
    (with SP/TP/fsdp shardings active) as on one device."""
    out = _run_subprocess("""
        from repro.configs.base import get_config
        from repro.configs.inputs import reduced_config
        from repro.models.model import init_params, loss_fn
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.axes import use_sharding, TRAIN_RULES

        cfg = reduced_config(get_config("qwen2.5-3b"))
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(2, 250, (4, 32)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        l0, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
        mesh = make_host_mesh(data=2, model=4)
        with use_sharding(mesh, TRAIN_RULES):
            l1, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
        print("DIFF", abs(float(l0) - float(l1)))
    """)
    diff = float(out.strip().split("DIFF")[1])
    assert diff < 5e-3


@pytest.mark.slow
def test_moe_ep_matches_local_all_mesh_shapes():
    """EP all-to-all MoE == token-local oracle for dup>1, e_loc>1, tp=1."""
    out = _run_subprocess("""
        from repro.configs.base import get_config
        from repro.configs.inputs import reduced_config
        from repro.models import moe as moe_lib
        from repro.models.transformer import moe_ffn
        from repro.parallel.axes import use_sharding, TRAIN_RULES
        from repro.launch.mesh import make_host_mesh

        cfg = reduced_config(get_config("mixtral-8x22b")).replace(
            d_model=64, d_ff=128, n_experts=4, capacity_factor=8.0)
        p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64),
                              jnp.float32)
        ref, _ = moe_lib.moe_ffn_local(x.reshape(-1, 64), p, cfg)
        worst = 0.0
        for dn, mn in [(2, 4), (4, 2), (1, 8), (8, 1)]:
            mesh = make_host_mesh(data=dn, model=mn)
            with use_sharding(mesh, TRAIN_RULES):
                out, _ = jax.jit(
                    lambda x, p: moe_ffn(x, p, cfg, True))(x, p)
            worst = max(worst, float(jnp.max(jnp.abs(
                out.reshape(-1, 64) - ref))))
        print("WORST", worst)
    """)
    worst = float(out.strip().split("WORST")[1])
    assert worst < 1e-5


@pytest.mark.slow
def test_gqa_alignment_exact_under_tp():
    """MHA-ize+pad path (H=5 heads, G=1, TP=4): sharded attention must
    equal the unsharded result exactly."""
    out = _run_subprocess("""
        from repro.configs.base import get_config
        from repro.configs.inputs import reduced_config
        from repro.models.attention import blockwise_attention
        from repro.parallel.axes import use_sharding, TRAIN_RULES
        from repro.launch.mesh import make_host_mesh

        cfg = reduced_config(get_config("qwen1.5-0.5b")).replace(
            n_heads=5, n_kv_heads=1, attn_q_chunk=8, attn_kv_chunk=16)
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, 32, 5, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 32, 1, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 32, 1, 16)), jnp.float32)
        base = blockwise_attention(q, k, v, cfg, causal=True)
        mesh = make_host_mesh(data=2, model=4)
        with use_sharding(mesh, TRAIN_RULES):
            sh = jax.jit(lambda q, k, v: blockwise_attention(
                q, k, v, cfg, causal=True))(q, k, v)
        print("DIFF", float(jnp.max(jnp.abs(base - sh))))
    """)
    diff = float(out.strip().split("DIFF")[1])
    assert diff < 1e-5


@pytest.mark.slow
def test_compressed_pmean_under_shard_map():
    """int8 error-feedback mean over a 4-way axis: quantisation error is
    bounded and error feedback carries the residual."""
    out = _run_subprocess("""
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim.compress import (compressed_pmean_leaf,
                                          init_error_feedback)
        mesh = Mesh(np.asarray(jax.devices()[:4]), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        err = jnp.zeros((4, 64))

        def f(gs, es):
            m, e2 = compressed_pmean_leaf(gs[0], es[0], "pod")
            return m[None], e2[None]

        from repro.parallel.axes import SHARD_MAP_NOCHECK, shard_map
        m, e2 = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
            out_specs=(P("pod", None), P("pod", None)),
            **SHARD_MAP_NOCHECK))(g, err)
        true_mean = jnp.mean(g, axis=0)
        got = m[0]
        rel = float(jnp.max(jnp.abs(got - true_mean))
                    / (jnp.max(jnp.abs(true_mean)) + 1e-9))
        # residual is exactly the pre-quantisation value minus the wire value
        print("REL", rel)
    """, devices=4)
    rel = float(out.strip().split("REL")[1])
    assert rel < 0.05            # int8 wire error bound


@pytest.mark.slow
def test_dryrun_smoke_cell_both_meshes():
    """One full dry-run cell on the 16x16 AND 2x16x16 production meshes
    (the multi-pod proof, in miniature run time)."""
    out = _run_subprocess("""
        from repro.configs.base import get_config, SHAPES
        from repro.launch.steps import build_step
        from repro.launch.mesh import make_production_mesh
        from repro.parallel.axes import use_sharding

        for multi in (False, True):
            mesh = make_production_mesh(multi_pod=multi)
            cfg = get_config("qwen1.5-0.5b")
            fn, args, rules = build_step(cfg, SHAPES["train_4k"], mesh)
            with use_sharding(mesh, rules):
                compiled = fn.lower(*args).compile()
            ma = compiled.memory_analysis()
            assert ma.temp_size_in_bytes > 0
            print("MESHOK", mesh.size)
    """, devices=512)
    assert "MESHOK 256" in out and "MESHOK 512" in out
