"""Telemetry subsystem tests (`repro.obs`): the zero-perturbation
contract (every numeric result bit-identical with telemetry on vs off,
and the disabled program stages no host callbacks at all), exact
counter-vs-oracle agreement (the trace reproduces
`FleetSummary.dispatch`'s move count and CPC bit for bit), the
loader-event payload contract, the profiling capture, and a golden-file
test of the ``python -m repro.obs.report`` digest.

Regenerate the golden digest after an intentional format change with

  REGEN_OBS_GOLDEN=1 PYTHONPATH=src python -m pytest \\
      tests/test_obs.py::test_report_digest_matches_golden -q
"""

import json
import os
from pathlib import Path

import jax
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro import obs
from repro.core.tco import make_system
from repro.dispatch import DispatchConfig, build_problem, dispatch
from repro.energy.presets import region_params
from repro.energy.smard import load_price_csv
from repro.fleet import PolicySpec, backtest, build_grid, summarize
from repro.fleet.engine import _backtest_jit
from repro.obs.profiling import profiled, record_compiled, xla_trace
from repro.obs.report import (load_events, load_metrics,
                              reconstruct_dispatch, render_digest)
from repro.tune import TuneConfig, optimize

GOLDEN = Path(__file__).resolve().parent / "golden" / "obs_digest.md"


def _grid(t: int = 240, n_markets: int = 2):
    """Fixed-seed grid whose policies keep partial capacity online
    (off_level > 0), so the 35%-of-ratings dispatch demand below stays
    feasible in every hour."""
    markets = [region_params("germany", seed=s).replace(n_hours=t)
               for s in range(n_markets)]
    p_avg = markets[0].p_avg
    systems = [make_system(2.0 * t * 1.0 * p_avg, 1.0, float(t))]
    policies = [PolicySpec("always_on"),
                PolicySpec("x5", x=0.05, off_level=0.4),
                PolicySpec("x10", x=0.10, off_level=0.4),
                PolicySpec("x20", x=0.20, off_level=0.4)]
    return build_grid(markets, systems, policies,
                      market_names=[f"de-seed{s}" for s in range(n_markets)],
                      system_names=["psi2.0"])


_DCFG = DispatchConfig(demand_frac=0.35, migrate_cost=2.0, min_dwell_h=2)


def _assert_tree_equal(got, want, what: str) -> None:
    for field in want._fields:
        g, w = getattr(got, field), getattr(want, field)
        if g is None or w is None:
            assert g is w, f"{what}.{field}"
            continue
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{what}.{field}")


# ---------------------------------------------------------------------------
# (a) bit-identity: telemetry on vs off changes no numeric result
# ---------------------------------------------------------------------------

def test_backtest_bit_identical_on_off(tmp_path):
    grid = _grid()
    off = backtest(grid, use_pallas=False)
    with obs.capture(tmp_path / "run"):
        on = backtest(grid, use_pallas=False)
    _assert_tree_equal(on, off, "FleetReport")
    # and the run actually observed something
    kinds = {e["kind"] for e in load_events(tmp_path / "run")}
    assert {"fleet.backtest", "fleet.hourly"} <= kinds


def test_backtest_bit_identical_on_off_x64(tmp_path):
    with enable_x64():
        grid = _grid(t=120)
        off = backtest(grid, use_pallas=False)
        with obs.capture(tmp_path / "run"):
            on = backtest(grid, use_pallas=False)
        _assert_tree_equal(on, off, "FleetReport[x64]")


def test_dispatch_bit_identical_on_off(tmp_path):
    grid = _grid()
    rep = backtest(grid, use_pallas=False)
    off = summarize(grid, rep, dispatch_cfg=_DCFG).dispatch
    with obs.capture(tmp_path / "run"):
        on = summarize(grid, rep, dispatch_cfg=_DCFG).dispatch
    _assert_tree_equal(on, off, "DispatchResult")


def test_optimize_bit_identical_on_off(tmp_path):
    grid = _grid(t=160)
    cfg = TuneConfig(steps=10, shard=False)
    off = optimize(grid, cfg)
    with obs.capture(tmp_path / "run"):
        on = optimize(grid, cfg)
    for field in ("cpc", "cpc_tuned", "cpc_swept", "cpc_swept_best",
                  "source", "stage_cpc"):
        np.testing.assert_array_equal(np.asarray(getattr(on, field)),
                                      np.asarray(getattr(off, field)),
                                      err_msg=field)
    _assert_tree_equal(on.raw, off.raw, "raw")
    _assert_tree_equal(on.params, off.params, "params")
    for k in off.history:
        np.testing.assert_array_equal(np.asarray(on.history[k]),
                                      np.asarray(off.history[k]),
                                      err_msg=f"history[{k}]")


def test_optimize_bit_identical_on_off_x64(tmp_path):
    with enable_x64():
        grid = _grid(t=120)
        cfg = TuneConfig(steps=6, shard=False)
        off = optimize(grid, cfg)
        with obs.capture(tmp_path / "run"):
            on = optimize(grid, cfg)
        np.testing.assert_array_equal(on.cpc, off.cpc)
        np.testing.assert_array_equal(on.cpc_tuned, off.cpc_tuned)
        _assert_tree_equal(on.raw, off.raw, "raw[x64]")


def test_optimize_bit_identical_on_off_acceptance_grid(tmp_path):
    """The PR's acceptance grid (the same fixed-seed 256-row grid
    test_tune.py's guarantee runs on): enabling telemetry must leave the
    entire tuned result bit-identical."""
    from repro.energy.markets import MarketParams
    t = 600
    markets = [MarketParams(n_hours=t, seed=s) for s in range(4)]
    systems = [make_system(float(psi) * t * 1.0 * 80.0, 1.0, float(t))
               for psi in (0.5, 1.0, 2.0, 4.0)]
    xs = (0.01, 0.02, 0.03, 0.05, 0.08, 0.10, 0.12, 0.15,
          0.20, 0.25, 0.30, 0.40)
    policies = [PolicySpec("ao")] + \
        [PolicySpec(f"x{int(x * 100)}", x=x) for x in xs] + \
        [PolicySpec("x3h", x=0.03, hysteresis=0.9),
         PolicySpec("x8h", x=0.08, hysteresis=0.85),
         PolicySpec("x15h", x=0.15, hysteresis=0.9)]
    grid = build_grid(markets, systems, policies)
    assert grid.n_rows == 256
    cfg = TuneConfig(steps=25)
    off = optimize(grid, cfg)
    with obs.capture(tmp_path / "run"):
        on = optimize(grid, cfg)
    np.testing.assert_array_equal(on.cpc, off.cpc)
    np.testing.assert_array_equal(on.cpc_tuned, off.cpc_tuned)
    np.testing.assert_array_equal(on.stage_cpc, off.stage_cpc)
    _assert_tree_equal(on.raw, off.raw, "raw")
    _assert_tree_equal(on.params, off.params, "params")


# ---------------------------------------------------------------------------
# (b) the disabled program stages no host callbacks at all
# ---------------------------------------------------------------------------

def test_disabled_program_has_no_callbacks(tmp_path):
    grid = _grid(t=64)
    args = (grid.prices, grid.market_idx, grid.system_idx,
            grid.policy_idx, grid.fixed, grid.power, grid.period,
            grid.p_on, grid.p_off, grid.off_level, grid.idle_frac,
            grid.restart_energy_mwh, grid.restart_time_h)

    def trace(telemetry):
        return str(jax.make_jaxpr(
            lambda *a: _backtest_jit(*a, use_pallas=False, block_b=128,
                                     block_t=512, telemetry=telemetry)
        )(*args))

    assert not obs.enabled()
    assert "io_callback" not in trace(False)
    with obs.capture(tmp_path / "run"):
        assert "io_callback" in trace(True)
        # ... and telemetry=False stages nothing even while a run is on
        assert "io_callback" not in trace(False)


def test_drained_program_goes_quiet_after_disable(tmp_path):
    """A program compiled with its telemetry callback staged stops
    writing the moment the run closes — the io_callback sink looks the
    run up at call time, no retrace needed."""
    grid = _grid(t=64)
    args = (grid.prices, grid.market_idx, grid.system_idx,
            grid.policy_idx, grid.fixed, grid.power, grid.period,
            grid.p_on, grid.p_off, grid.off_level, grid.idle_frac,
            grid.restart_energy_mwh, grid.restart_time_h)
    with obs.capture(tmp_path / "run"):
        jax.block_until_ready(_backtest_jit(
            *args, use_pallas=False, block_b=128, block_t=512,
            telemetry=True))
        n_live = len(load_events(tmp_path / "run"))
    assert n_live >= 2                       # run.meta + fleet.hourly
    # same compiled entry, run closed: must not raise, must not write
    jax.block_until_ready(_backtest_jit(
        *args, use_pallas=False, block_b=128, block_t=512,
        telemetry=True))
    events = load_events(tmp_path / "run")
    assert sum(e["kind"] == "fleet.hourly" for e in events) == 1


# ---------------------------------------------------------------------------
# (c) counter vs oracle: the trace reproduces the dispatch result exactly
# ---------------------------------------------------------------------------

def test_trace_reproduces_dispatch_result_exactly(tmp_path):
    grid = _grid()
    rep = backtest(grid, use_pallas=False)
    with obs.capture(tmp_path / "run"):
        summ = summarize(grid, rep, dispatch_cfg=_DCFG)
    oracle = summ.dispatch
    events = load_events(tmp_path / "run")

    result = [e for e in events if e["kind"] == "dispatch.result"][-1]
    assert result["cpc"] == oracle.cpc
    assert result["n_migrations"] == oracle.n_migrations
    assert result["energy_cost"] == oracle.energy_cost
    assert result["migration_cost"] == oracle.migration_cost
    assert result["slack_capacity_mw"] == oracle.slack_capacity_mw
    assert result["slack_power_mw"] == oracle.slack_power_mw
    assert result["slack_floor_mwh"] == oracle.slack_floor_mwh

    # reconstruction from the per-hour event alone — not the scalars
    recon = reconstruct_dispatch(events)
    assert recon["cpc"] == oracle.cpc
    assert recon["n_migrations"] == oracle.n_migrations
    assert recon["energy_cost"] == oracle.energy_cost
    assert recon["migration_cost"] == oracle.migration_cost
    assert recon["delivered_mwh"] == oracle.delivered_mwh
    assert recon["slack_capacity_mw"] == oracle.slack_capacity_mw

    # and the metric instruments agree with both
    metrics = load_metrics(tmp_path / "run")
    assert metrics["counters"]["dispatch.calls"] == 1
    assert metrics["counters"]["dispatch.moves"] == oracle.n_migrations
    assert metrics["gauges"]["dispatch.cpc"] == oracle.cpc


def test_infeasible_dispatch_emits_reasoned_event(tmp_path):
    grid = _grid(t=96)
    rep = backtest(grid, use_pallas=False)
    bad = DispatchConfig(demand_frac=0.35, power_cap_mw=1e-3)
    with obs.capture(tmp_path / "run"):
        from repro.dispatch import DispatchInfeasible
        with pytest.raises(DispatchInfeasible):
            summarize(grid, rep, dispatch_cfg=bad)
        events = [e for e in load_events(tmp_path / "run")
                  if e["kind"] == "dispatch.infeasible"]
    assert len(events) == 1
    assert events[0]["constraint"] == "power_cap"


def test_tune_trace_matches_result(tmp_path):
    grid = _grid(t=160)
    cfg = TuneConfig(steps=10, shard=False)
    with obs.capture(tmp_path / "run"):
        res = optimize(grid, cfg)
    events = load_events(tmp_path / "run")
    steps = [e for e in events if e["kind"] == "tune.step"]
    stages = [e for e in events if e["kind"] == "tune.stage"]
    result = [e for e in events if e["kind"] == "tune.result"][-1]
    assert len(steps) == cfg.steps
    assert [e["step"] for e in steps] == list(range(cfg.steps))
    assert all("grad_norm" in e and "clip_frac" in e for e in steps)
    np.testing.assert_array_equal(
        np.asarray([e["loss"] for e in steps]),
        np.asarray(res.history["loss"], np.float64))
    assert len(stages) == TuneConfig().eval_stages
    np.testing.assert_array_equal(
        np.asarray([e["cpc_hard_mean"] for e in stages]), res.stage_cpc)
    assert stages[-1]["through_step"] == cfg.steps
    assert result["rows"] == grid.n_rows
    assert result["cpc_mean"] == float(np.mean(res.cpc))
    assert sum(result["source_counts"].values()) == grid.n_rows


# ---------------------------------------------------------------------------
# (d) loader events mirror LoadStats exactly
# ---------------------------------------------------------------------------

def test_loader_event_payload_matches_loadstats(tmp_path):
    csv = tmp_path / "prices.csv"
    csv.write_text("price\n80.0\n81.5\nnot-a-number\n79.0\nbad\n82.0\n")
    with obs.capture(tmp_path / "run"):
        with pytest.warns(UserWarning):
            _, stats = load_price_csv(csv, return_stats=True,
                                      max_skip_frac=0.05)
    events = [e for e in load_events(tmp_path / "run")
              if e["kind"] == "loader.skipped_rows"]
    assert len(events) == 1
    e = events[0]
    assert e["action"] == "warn"
    assert e["loader"] == "load_price_csv"
    assert e["path"] == str(csv)
    for field in ("n_rows", "n_parsed", "n_skipped", "n_nan"):
        assert e[field] == getattr(stats, field), field
    assert e["skip_frac"] == stats.skip_frac
    metrics = load_metrics(tmp_path / "run")
    assert metrics["counters"]["loader.skipped_rows"] == \
        stats.n_skipped + stats.n_nan


def test_loader_silent_when_disabled(tmp_path):
    csv = tmp_path / "prices.csv"
    csv.write_text("80.0\nbad\n82.0\n" * 10)
    assert not obs.enabled()
    with pytest.warns(UserWarning):
        arr = load_price_csv(csv, max_skip_frac=0.05)
    assert arr.shape == (20,)


# ---------------------------------------------------------------------------
# (e) profiling capture
# ---------------------------------------------------------------------------

def test_profiling_span_and_compiled_analysis(tmp_path):
    with obs.capture(tmp_path / "run"):
        with profiled("unit.block", rows=3):
            pass
        compiled = jax.jit(lambda x: (x * 2.0).sum()).lower(
            np.ones((8, 8), np.float32)).compile()
        payload = record_compiled("unit.program", compiled)
    assert payload["label"] == "unit.program"
    events = load_events(tmp_path / "run")
    spans = [e for e in events if e["kind"] == "profile.span"]
    xla = [e for e in events if e["kind"] == "profile.xla"]
    assert spans[0]["label"] == "unit.block"
    assert spans[0]["rows"] == 3
    assert spans[0]["seconds"] >= 0.0
    assert xla[0]["label"] == "unit.program"


def test_profiling_noops_when_disabled():
    assert not obs.enabled()
    with profiled("nope"):
        pass
    with xla_trace("nope") as d:
        assert d is None
    compiled = jax.jit(lambda x: x + 1).lower(np.ones(4, np.float32)
                                              ).compile()
    payload = record_compiled("nope", compiled)
    assert payload["label"] == "nope"        # returns data, writes nowhere


# ---------------------------------------------------------------------------
# (f) the operator digest (golden file, seeded 8-row run)
# ---------------------------------------------------------------------------

def _golden_run(run_dir) -> None:
    """One seeded end-to-end run exercising every digest section."""
    csv = run_dir.parent / "prices_golden.csv"
    csv.write_text("price\n80.0\n81.5\nbad-row\n79.0\n82.0\n77.5\n"
                   "76.0\n84.0\n")
    with obs.capture(run_dir, run_id="golden"):
        load_price_csv(csv, max_skip_frac=0.5)
        grid = _grid()
        with profiled("tune.optimize", rows=grid.n_rows, steps=12):
            optimize(grid, TuneConfig(steps=12, shard=False))
        rep = backtest(grid, use_pallas=False)
        summarize(grid, rep, dispatch_cfg=_DCFG)


def test_report_digest_matches_golden(tmp_path):
    run_dir = tmp_path / "run"
    _golden_run(run_dir)
    digest = render_digest(run_dir, redact_meta=True)
    if os.environ.get("REGEN_OBS_GOLDEN"):
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(digest)
        pytest.skip(f"regenerated {GOLDEN}")
    assert GOLDEN.exists(), \
        "golden digest missing — run with REGEN_OBS_GOLDEN=1 to create"
    assert digest == GOLDEN.read_text(), (
        "digest drifted from tests/golden/obs_digest.md — if the change "
        "is intentional, regenerate with REGEN_OBS_GOLDEN=1")


def test_report_cli_validates_clean(tmp_path, capsys):
    from repro.obs.report import main
    run_dir = tmp_path / "run"
    _golden_run(run_dir)
    out = tmp_path / "digest.md"
    rc = main([str(run_dir), "--validate", "-o", str(out)])
    assert rc == 0
    text = out.read_text()
    assert text.startswith("# Telemetry run digest")
    assert "(matches emitted result exactly)" in text


# ---------------------------------------------------------------------------
# (g) registry plumbing
# ---------------------------------------------------------------------------

def test_capture_restores_disabled_state_on_error(tmp_path):
    with pytest.raises(RuntimeError):
        with obs.capture(tmp_path / "run"):
            assert obs.enabled()
            raise RuntimeError("boom")
    assert not obs.enabled()
    # the run still closed cleanly: metrics.json exists, run.close logged
    events = load_events(tmp_path / "run")
    assert events[-1]["kind"] == "run.close"
    assert (tmp_path / "run" / "metrics.json").exists()


def test_trace_lines_are_schema_stamped_and_ordered(tmp_path):
    with obs.capture(tmp_path / "run"):
        obs.trace_event("tune.step", {"step": 0, "loss": 1.0})
        obs.trace_event("tune.step", {"step": 1, "loss": 0.5})
    events = load_events(tmp_path / "run")
    assert events[0]["kind"] == "run.meta"
    assert all(e["schema"] == 1 for e in events)
    assert [e["seq"] for e in events] == list(range(len(events)))
    meta = events[0]
    for key in ("run_id", "git_sha", "jax", "jaxlib", "backend",
                "timestamp"):
        assert key in meta
    # disabled instruments are throwaways, not errors
    obs.counter("x").inc()
    obs.gauge("x").set(1.0)
    obs.histogram("x").observe(2.0)
    assert json.loads((tmp_path / "run" / "metrics.json").read_text())
