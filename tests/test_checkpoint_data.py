"""Checkpoint manager + data pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import (CheckpointManager, load_checkpoint,
                                      save_checkpoint)
from repro.data.pipeline import SyntheticLM, batch_at, host_shard


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jax.random.normal(k, (3,), jnp.float32)
                       .astype(jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 3, tree, {"note": "x"})
    out, meta = load_checkpoint(tmp_path, _tree(seed=1))
    assert meta["step"] == 3 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        mgr.save(s, _tree(s), blocking=False)
    mgr.wait()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    for s in range(3):
        mgr.save(s, {"v": jnp.full((2,), float(s))}, blocking=True)
    out, meta = mgr.restore({"v": jnp.zeros((2,))}, step=1)
    assert float(out["v"][0]) == 1.0 and meta["step"] == 1


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 0, {"v": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, {"v": jnp.zeros((5,))})


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(tmp_path, 0, {"v": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        load_checkpoint(tmp_path, {"w": jnp.zeros((4,))})


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore places leaves against target NamedShardings (single-device
    degenerate mesh here; the same code path re-shards across mesh sizes)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(tmp_path, 0, tree)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = load_checkpoint(tmp_path, tree, shardings=shardings)
    assert out["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_batch_deterministic_by_step(step):
    ds = SyntheticLM(vocab=128, seq_len=32, global_batch=4, seed=1)
    b1, b2 = ds.batch_at(step), ds.batch_at(step)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_batches_differ_across_steps():
    ds = SyntheticLM(vocab=128, seq_len=32, global_batch=4)
    assert not np.array_equal(np.asarray(ds.batch_at(0)["tokens"]),
                              np.asarray(ds.batch_at(1)["tokens"]))


def test_labels_are_next_tokens():
    ds = SyntheticLM(vocab=128, seq_len=32, global_batch=2)
    b = ds.batch_at(0)
    # tokens[t+1] == labels[t] for all t < S-1 (same underlying stream)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_tokens_in_range():
    ds = SyntheticLM(vocab=99, seq_len=64, global_batch=4)
    t = np.asarray(ds.batch_at(7)["tokens"])
    assert t.min() >= 1 and t.max() < 99


def test_host_shard_partitions():
    ds = SyntheticLM(vocab=128, seq_len=16, global_batch=8)
    b = ds.batch_at(0)
    parts = [host_shard(b, i, 4) for i in range(4)]
    glued = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(glued, np.asarray(b["tokens"]))


def test_loss_mask_zeroes_post_boundary():
    ds = SyntheticLM(vocab=128, seq_len=512, global_batch=2,
                     mean_doc_len=32)
    b = ds.batch_at(0)
    sep = np.asarray(b["labels"]) == 1
    mask = np.asarray(b["loss_mask"])
    assert mask[sep].sum() == 0          # never train to predict into sep
    assert mask.mean() > 0.8             # most positions train
