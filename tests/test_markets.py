"""Synthetic market generator + calibration tests."""

import numpy as np
import pytest

from repro.core.optimizer import optimal_shutdown
from repro.core.regions import PAPER_TABLE2, compute_region_row
from repro.energy.forecast import mae, seasonal_naive
from repro.energy.markets import MarketParams, diurnal_profile, \
    generate_market
from repro.energy.presets import REGION_PRESETS, region_params
from repro.energy.smard import load_price_csv
from repro.energy.stream import PriceStream


def test_generator_hits_target_mean():
    md = generate_market(MarketParams(p_avg=77.84, n_hours=8760, seed=1))
    assert float(np.mean(md.prices)) == pytest.approx(77.84, rel=1e-3)


def test_generator_reproducible_by_seed():
    a = generate_market(MarketParams(seed=5))
    b = generate_market(MarketParams(seed=5))
    np.testing.assert_array_equal(np.asarray(a.prices),
                                  np.asarray(b.prices))
    c = generate_market(MarketParams(seed=6))
    assert not np.array_equal(np.asarray(a.prices), np.asarray(c.prices))


def test_generator_has_negative_and_spike_hours():
    md = generate_market(MarketParams(n_hours=8760, seed=2))
    p = np.asarray(md.prices)
    assert (p < 0).sum() > 10             # negative-price hours exist
    assert p.max() > 4 * p.mean()         # spikes exist


def test_generation_volumes_positive():
    md = generate_market(MarketParams(n_hours=1000, seed=3))
    assert np.all(np.asarray(md.fossil) > 0)
    assert np.all(np.asarray(md.renewable) > 0)


def test_diurnal_profile_midday_dip():
    """Fig. 1: solar depresses midday prices vs the evening peak."""
    md = generate_market(MarketParams(n_hours=8760, seed=4))
    prof = np.asarray(diurnal_profile(md))
    assert prof[19] > prof[13]            # evening peak > solar midday


def test_calibrated_regions_reproduce_paper_break_even():
    """Calibrated presets must land near Table II's break-even fractions
    (the quantity the viability decision depends on)."""
    for region in ("germany", "south_australia", "france"):
        row_paper = PAPER_TABLE2[region]
        md = generate_market(region_params(region))
        row = compute_region_row(region, np.asarray(md.prices),
                                 psi=row_paper.psi)
        assert row.x_be_pct == pytest.approx(row_paper.x_be_pct,
                                             rel=0.35), region


def test_all_regions_have_presets():
    for region in REGION_PRESETS:
        md = generate_market(region_params(region))
        assert np.isfinite(np.asarray(md.prices)).all()


def test_price_stream_trailing_and_peek():
    prices = np.arange(100.0)
    s = PriceStream(prices, window=10, start=20)
    assert s.current() == 20.0
    np.testing.assert_array_equal(s.trailing(), np.arange(11.0, 21.0))
    np.testing.assert_array_equal(s.peek(3), np.asarray([21.0, 22., 23.]))
    s.advance(5)
    assert s.current() == 25.0


def test_smard_csv_roundtrip(tmp_path):
    from repro.energy.smard import load_smard_csv
    csv = tmp_path / "p.csv"
    csv.write_text("Datum;Preis [EUR/MWh]\n01.01.2024 00:00;50,5\n"
                   "01.01.2024 01:00;-3,2\n01.01.2024 02:00;1.200,0\n")
    p = load_smard_csv(str(csv))
    np.testing.assert_allclose(p, [50.5, -3.2, 1200.0])


def test_generic_price_csv(tmp_path):
    csv = tmp_path / "p.csv"
    csv.write_text("price\n50.5\n-3.2\n120.0\n")
    np.testing.assert_allclose(load_price_csv(str(csv)),
                               [50.5, -3.2, 120.0])


def test_smard_csv_bad_column_fails_loudly(tmp_path):
    """A mis-pointed column index must raise, not return a short series."""
    from repro.energy.smard import load_smard_csv
    csv = tmp_path / "p.csv"
    csv.write_text("Datum;Preis\n01.01.2024 00:00;50,5\n"
                   "01.01.2024 01:00;-3,2\n")
    with pytest.raises(ValueError, match="no .* row parsed"):
        load_smard_csv(str(csv), column=0)   # datetime column: never a float


def test_smard_csv_skip_accounting_and_warning(tmp_path):
    from repro.energy.smard import load_smard_csv
    csv = tmp_path / "p.csv"
    csv.write_text("Datum;Preis\na;50,5\nb;bogus\nc;-\nd;70,0\nshort\n")
    with pytest.warns(UserWarning, match="skipped"):
        p, stats = load_smard_csv(str(csv), return_stats=True)
    np.testing.assert_allclose(p, [50.5, 70.0])
    assert stats.n_rows == 5
    assert stats.n_parsed == 2
    assert stats.n_skipped == 2        # "bogus" + the too-short row
    assert stats.n_nan == 1            # the "-" placeholder
    assert stats.skip_frac == pytest.approx(3 / 5)


def test_load_stats_str_in_loud_failure(tmp_path):
    """LoadStats renders its accounting, and the loud-failure message
    carries it (a mis-pointed column reports *what* was seen)."""
    from repro.energy.smard import LoadStats, load_smard_csv
    s = LoadStats(n_rows=5, n_parsed=2, n_skipped=2, n_nan=1)
    assert str(s) == ("5 data rows: 2 parsed, 2 unparseable, 1 empty "
                      "(60.0% bad)")
    csv = tmp_path / "p.csv"
    csv.write_text("Datum;Preis\n01.01.2024 00:00;50,5\n"
                   "01.01.2024 01:00;-3,2\n")
    with pytest.raises(ValueError) as ei:
        load_smard_csv(str(csv), column=0)
    assert str(LoadStats(n_rows=2, n_parsed=0, n_skipped=2,
                         n_nan=0)) in str(ei.value)


def test_generic_price_csv_multiline_header_and_all_header(tmp_path):
    import warnings
    csv = tmp_path / "p.csv"
    # a two-line header (plus a leading blank) must not trip the skip
    # warning — leading unparseable lines are header, not data
    csv.write_text("\nprice\nEUR/MWh\n" + "\n".join(str(float(i))
                                                    for i in range(20)))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        p = load_price_csv(str(csv))
    np.testing.assert_allclose(p, np.arange(20.0))
    # a file with no parseable value at all fails loudly
    bad = tmp_path / "bad.csv"
    bad.write_text("alpha\nbeta\ngamma\n")
    with pytest.raises(ValueError, match="no .* line parsed"):
        load_price_csv(str(bad))


def test_block_bootstrap_shapes_and_reproducibility():
    from repro.energy.ensemble import block_bootstrap
    src = np.arange(500.0)
    out = block_bootstrap(src, 4, series_hours=300, block_hours=48, seed=9)
    assert out.shape == (4, 300) and out.dtype == np.float32
    np.testing.assert_array_equal(
        out, block_bootstrap(src, 4, series_hours=300, block_hours=48,
                             seed=9))
    assert not np.array_equal(
        out, block_bootstrap(src, 4, series_hours=300, block_hours=48,
                             seed=10))
    # every sample comes from the source trace
    assert np.isin(out, src.astype(np.float32)).all()
    # blocks are contiguous (circular) runs of the source: within each
    # 48-sample block the integer series increments by 1 mod 500 (the
    # 300-sample series is 6 blocks with the last one trimmed; check the
    # 6 full blocks of the first 288 samples)
    blocks = out[:, :288].reshape(4, 6, 48)
    d = np.diff(blocks, axis=-1) % 500
    assert (d == 1).all()


def test_block_bootstrap_feeds_build_grid():
    from repro.core.tco import make_system
    from repro.energy.ensemble import block_bootstrap
    from repro.fleet import PolicySpec, backtest, build_grid
    md = generate_market(MarketParams(n_hours=600, seed=12))
    ens = block_bootstrap(np.asarray(md.prices), 5, block_hours=24 * 7,
                          seed=1)
    grid = build_grid(ens, [make_system(40_000.0, 1.0, 600.0)],
                      [PolicySpec("x5", x=0.05)])
    rep = backtest(grid, use_pallas=False)
    assert grid.n_rows == 5
    assert np.isfinite(np.asarray(rep.cpc)).all()
    # resampling preserves the source's gross price level
    assert np.mean(ens) == pytest.approx(float(np.mean(md.prices)),
                                         rel=0.15)


def test_forecast_seasonal_naive():
    prices = np.tile(np.arange(24.0), 30)      # perfectly periodic
    pred = seasonal_naive(prices[:-24], horizon=24)
    assert mae(pred, prices[-24:]) == pytest.approx(0.0, abs=1e-9)
