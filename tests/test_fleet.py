"""Fleet backtesting subsystem tests: B=1 equivalence with the
single-trace paths, Pallas kernel vs reference scan, and
permutation-invariant aggregation."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizer import optimal_shutdown
from repro.core.policy import hysteresis_policy, policy_cpc, threshold_policy
from repro.core.tco import make_system
from repro.energy.markets import MarketParams
from repro.fleet import (PolicySpec, backtest, build_grid, elastic_policy,
                         summarize)
from repro.kernels.fleet_scan import fleet_scan
from repro.kernels.ref import fleet_scan_ref

rng = np.random.default_rng(7)

T = 1200
SYS = make_system(fixed=60_000.0, power=1.0, period=float(T))


def _grid(policies, n_markets=1, systems=(SYS,)):
    markets = [MarketParams(n_hours=T, seed=s) for s in range(n_markets)]
    return build_grid(markets, list(systems), policies)


# ---------------------------------------------------------------------------
# (a) B=1 rows match the existing single-trace paths
# ---------------------------------------------------------------------------

def test_b1_threshold_matches_policy_cpc():
    grid = _grid([PolicySpec("x3", x=0.03)])
    rep = backtest(grid, use_pallas=False)
    prices = np.asarray(grid.prices[0])
    mask = threshold_policy(prices, float(grid.p_off[0]))
    want = float(policy_cpc(SYS, prices, mask))
    assert float(rep.cpc[0]) == pytest.approx(want, rel=1e-5)
    # realized shutdown fraction equals the mask's off fraction
    assert float(rep.x_realized[0]) == pytest.approx(
        1.0 - float(np.mean(np.asarray(mask))), abs=1e-6)


def test_b1_hysteresis_with_overheads_matches_policy_cpc():
    spec = PolicySpec("h", x=0.05, hysteresis=0.9, idle_frac=0.07,
                      restart_energy_mwh=0.4, restart_time_h=0.5)
    grid = _grid([spec])
    rep = backtest(grid, use_pallas=False)
    prices = np.asarray(grid.prices[0])
    mask = hysteresis_policy(prices, p_on=float(grid.p_on[0]),
                             p_off=float(grid.p_off[0]))
    want = float(policy_cpc(SYS, prices, mask, idle_power_frac=0.07,
                            restart_energy_mwh=0.4, restart_time_h=0.5))
    assert float(rep.cpc[0]) == pytest.approx(want, rel=1e-5)


def test_b1_always_on_matches_cpc_ao_and_oracle():
    grid = _grid([PolicySpec("ao")])
    rep = backtest(grid, use_pallas=False)
    # an always-on row realizes the AO baseline: zero reduction
    assert float(rep.cpc[0]) == pytest.approx(float(rep.cpc_ao[0]),
                                              rel=1e-6)
    assert float(rep.cpc_reduction[0]) == pytest.approx(0.0, abs=1e-6)
    # the summary's oracle column is optimal_shutdown's reduction
    summ = summarize(grid, rep)
    prices = np.asarray(grid.prices[0])
    psi = float(SYS.F) / (float(SYS.T) * float(SYS.C) * prices.mean())
    plan = optimal_shutdown(prices, psi)
    assert summ.oracle_reduction[0, 0] == pytest.approx(
        float(plan.cpc_reduction), rel=1e-5)


def test_oracle_threshold_row_attains_oracle_reduction():
    """A threshold policy at the oracle's own x_opt realizes (to within
    restart-free accounting noise) the closed-form optimum — regret ~ 0."""
    probe = _grid([PolicySpec("ao")])
    prices = np.asarray(probe.prices[0])
    psi = float(SYS.F) / (float(SYS.T) * float(SYS.C) * prices.mean())
    plan = optimal_shutdown(prices, psi)
    grid = _grid([PolicySpec("opt", x=float(plan.x_opt))])
    summ = summarize(grid, backtest(grid, use_pallas=False))
    assert abs(summ.regret[0, 0, 0]) < 1e-4


# ---------------------------------------------------------------------------
# (b) Pallas kernel vs reference scan (interpret mode on CPU)
# ---------------------------------------------------------------------------

FLEET_SCAN_CASES = [
    # B, T  (exercising block padding in both axes)
    (1, 64),
    (5, 333),
    (128, 512),
    (130, 1000),
]


@pytest.mark.parametrize("case", FLEET_SCAN_CASES)
def test_fleet_scan_matches_ref(case):
    b, t = case
    p = jnp.asarray(rng.normal(80, 40, (b, t)), jnp.float32)
    p_off = jnp.asarray(rng.uniform(40, 160, b), jnp.float32)
    p_on = p_off * jnp.asarray(rng.uniform(0.7, 1.0, b), jnp.float32)
    lvl = jnp.asarray(rng.uniform(0.0, 0.6, b), jnp.float32)
    idle = jnp.asarray(rng.uniform(0.0, 0.3, b), jnp.float32)
    got = fleet_scan(p, p_on, p_off, lvl, idle)
    want = fleet_scan_ref(p, p_on, p_off, lvl, idle)
    for name in want._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            rtol=2e-5, atol=1e-3, err_msg=f"{b}x{t} {name}")


def test_fleet_scan_exact_start_count():
    """Start counting is integral — the kernel and reference must agree
    exactly, including the initial-on convention (no start at t=0)."""
    p = jnp.asarray([[50.0, 200.0, 200.0, 50.0, 200.0, 50.0, 50.0]])
    out = fleet_scan(p, jnp.asarray([100.0]), jnp.asarray([100.0]),
                     jnp.asarray([0.0]), jnp.asarray([0.0]))
    assert float(out.n_starts[0]) == 2.0
    assert float(out.up_units[0]) == 4.0


def test_backtest_pallas_path_matches_ref_path():
    grid = _grid([PolicySpec("ao"), PolicySpec("x3", x=0.03),
                  elastic_policy("half", level=0.5, dp_total=8, x=0.05)],
                 n_markets=2,
                 systems=(SYS, make_system(150_000.0, 1.0, float(T))))
    ref = backtest(grid, use_pallas=False)
    pal = backtest(grid, use_pallas=True)
    for f in ("cpc", "cpc_ao", "cpc_reduction", "tco", "up_hours",
              "n_starts"):
        np.testing.assert_allclose(np.asarray(getattr(ref, f)),
                                   np.asarray(getattr(pal, f)),
                                   rtol=1e-5, atol=1e-5, err_msg=f)


# ---------------------------------------------------------------------------
# (c) report aggregation is permutation-invariant over rows
# ---------------------------------------------------------------------------

def test_summary_is_row_permutation_invariant():
    grid = _grid([PolicySpec("ao"), PolicySpec("x2", x=0.02),
                  PolicySpec("x5", x=0.05, hysteresis=0.9)],
                 n_markets=2,
                 systems=(SYS, make_system(150_000.0, 1.0, float(T))))
    rep = backtest(grid, use_pallas=False)
    base = summarize(grid, rep)

    order = rng.permutation(grid.n_rows)
    grid_p = grid.take_rows(order)
    rep_p = backtest(grid_p, use_pallas=False)
    perm = summarize(grid_p, rep_p)

    for field in base._fields:
        bv, pv = getattr(base, field), getattr(perm, field)
        if bv is None:        # dispatch block: absent unless configured
            assert pv is None, field
            continue
        np.testing.assert_allclose(np.asarray(bv), np.asarray(pv),
                                   rtol=1e-6, atol=1e-6, err_msg=field)


def test_grid_shapes_and_indexing():
    grid = _grid([PolicySpec("ao"), PolicySpec("x2", x=0.02)],
                 n_markets=3, systems=(SYS, SYS))
    assert grid.n_rows == 3 * 2 * 2
    assert grid.n_markets == 3 and grid.n_systems == 2
    assert grid.n_policies == 2
    # x-policies resolve per market: thresholds must differ across markets
    offs = np.asarray(grid.p_off).reshape(3, 2, 2)[:, 0, 1]
    assert len(np.unique(offs)) == 3
    # always-on rows have an infinite threshold
    assert np.all(np.isinf(np.asarray(grid.p_off).reshape(3, 2, 2)[:, :, 0]))


def test_take_rows_carries_every_per_row_field():
    """take_rows must permute every dataclass field that is not shared —
    compared against `dataclasses.fields()` so a future per-row field
    cannot be silently dropped."""
    import dataclasses

    from repro.fleet import ScenarioGrid

    grid = _grid([PolicySpec("ao"), PolicySpec("x2", x=0.02)],
                 n_markets=2, systems=(SYS, SYS))
    order = rng.permutation(grid.n_rows)
    perm = grid.take_rows(order)
    shared = set(ScenarioGrid.SHARED_FIELDS)
    names = {f.name for f in dataclasses.fields(ScenarioGrid)}
    assert shared < names
    for f in dataclasses.fields(ScenarioGrid):
        v, pv = getattr(grid, f.name), getattr(perm, f.name)
        if f.name in shared:
            assert pv is v or np.array_equal(np.asarray(pv),
                                             np.asarray(v)), f.name
        else:
            assert v.shape[0] == grid.n_rows, \
                f"{f.name}: per-row fields must be [B]-leading"
            np.testing.assert_array_equal(
                np.asarray(v)[order], np.asarray(pv), err_msg=f.name)


def test_take_rows_refuses_non_per_row_field():
    """A field that is neither shared nor [B]-leading must raise, not be
    silently dropped."""
    import dataclasses

    grid = _grid([PolicySpec("ao")])
    bad = dataclasses.replace(grid, restart_time_h=jnp.zeros(()))
    with pytest.raises(TypeError, match="neither a shared field"):
        bad.take_rows(np.arange(grid.n_rows))


def test_policy_spec_validation():
    with pytest.raises(ValueError):
        PolicySpec("bad", x=0.1, p_off=100.0)
    with pytest.raises(ValueError):
        PolicySpec("bad", x=0.1, off_level=1.0)
    with pytest.raises(ValueError):
        # inverted band (p_on > p_off) would make kernel and reference
        # scan disagree — must be rejected at spec time
        PolicySpec("bad", x=0.1, hysteresis=1.2)
    with pytest.raises(ValueError):
        build_grid(np.zeros((2, 10), np.float32), [], [PolicySpec("ao")])


def test_summary_tolerates_partial_cube():
    """Uncovered (market, system) cells stay NaN / -1 instead of crashing
    nanargmax."""
    grid = _grid([PolicySpec("ao"), PolicySpec("x2", x=0.02)],
                 n_markets=2, systems=(SYS, SYS))
    sub = grid.take_rows(np.arange(grid.n_policies))   # market 0, sys 0 only
    summ = summarize(sub, backtest(sub, use_pallas=False))
    assert summ.best_policy[0, 0] >= 0
    assert summ.best_policy[1, 1] == -1
    assert np.isnan(summ.best_reduction[1, 1])
