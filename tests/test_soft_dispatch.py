"""Soft (differentiable) dispatch tests: the relaxation pyramid.

Layer 1 — kernel consistency: the Pallas soft-dispatch path is
bit-identical to the sequential `soft_dispatch_ref` oracle (interpret
mode), exactly like the hard `dispatch_scan`.
Layer 2 — relaxation semantics: the softmin water-fill converges to the
hard greedy fill (allocation *and* CPC) as tau -> 0, reduces to the
per-hour entropic fill with zero fee / zero dwell, and is invariant to
site permutation.
Layer 3 — gradients: reverse-mode through the water level (implicit
Newton correction) matches central finite differences in float64.
Layer 4 — the dispatch-aware tuner: fleet CPC under *hard* feasible
dispatch matches or beats the PR-3 re-score-only path on the 256-row
acceptance grid, a swing site emerges, the full pipeline is seeded-
deterministic, and chunking the coupled objective raises loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core.tco import make_system
from repro.dispatch import (DispatchConfig, DispatchProblem, segment_keys,
                            segment_rank, summarize_alloc)
from repro.energy.markets import MarketParams
from repro.fleet import PolicySpec, build_grid
from repro.kernels.ref import (dispatch_ref, soft_dispatch_hour,
                               soft_dispatch_ref, soft_water_level)
from repro.kernels.soft_dispatch import soft_dispatch, soft_dispatch_pallas
from repro.tune import TuneConfig, optimize

rng = np.random.default_rng(29)


def _random_case(s, t, *, demand_frac=0.5, seed_shift=0):
    r = np.random.default_rng(29 + seed_shift)
    prices = r.normal(80, 40, (s, t)).astype(np.float32)
    power = r.uniform(1.0, 3.0, s).astype(np.float32)
    on = (r.uniform(size=(s, t)) > 0.3).astype(np.float32)
    avail = power[:, None] * (0.2 + 0.8 * on)      # never fully dark
    demand = np.full(t, demand_frac * float(avail.sum(axis=0).min()),
                     np.float32)
    return prices, avail, demand


def _hard_problem(prices, avail, demand, mc, dwell):
    order, rank = segment_rank(prices, mc)
    return DispatchProblem(
        prices=np.asarray(prices, np.float32),
        avail_mw=np.asarray(avail, np.float32),
        demand_mw=np.asarray(demand, np.float32),
        power_cap_mw=float("inf"), migrate_cost=mc, min_dwell_h=dwell,
        compute_floor_mwh=0.0, fixed_cost=0.0, order=order, rank=rank)


# ---------------------------------------------------------------------------
# (a) Pallas kernel vs sequential oracle: bit-identical (interpret mode)
# ---------------------------------------------------------------------------

SOFT_CASES = [
    # S, T, migrate_cost, min_dwell, tau  (T exercising block padding)
    (1, 64, 0.0, 0, 5.0),
    (5, 333, 5.0, 0, 2.0),
    (8, 500, 5.0, 6, 0.5),
    (16, 700, 3.0, 3, 20.0),
]


@pytest.mark.parametrize("case", SOFT_CASES)
def test_soft_dispatch_pallas_bit_identical_to_ref(case):
    s, t, mc, dwell, tau = case
    prices, avail, demand = _random_case(s, t)
    keys = segment_keys(prices, mc).astype(np.float32)
    order, _ = segment_rank(prices, mc)
    got = np.asarray(soft_dispatch_pallas(avail, keys, order, demand,
                                          tau=tau, min_dwell=dwell,
                                          block_t=256))
    want = np.asarray(soft_dispatch_ref(
        jnp.asarray(avail, jnp.float32), jnp.asarray(keys), order, demand,
        tau=tau, min_dwell=dwell))
    np.testing.assert_array_equal(got, want,
                                  err_msg=f"S={s} T={t} tau={tau}")


# ---------------------------------------------------------------------------
# (b) soft -> hard convergence as tau -> 0 (allocation and CPC)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mc,dwell", [(0.0, 0), (5.0, 0), (5.0, 4)])
def test_soft_converges_to_hard_allocation(mc, dwell):
    """At tau = 1e-3 (the f32 sweet spot: smaller tau runs into f32 key
    cancellation) the relaxed allocation matches the greedy fill to
    ~1e-3 MW on O(1) MW sites."""
    prices, avail, demand = _random_case(6, 400)
    keys = segment_keys(prices, mc)
    order, rank = segment_rank(prices, mc)
    hard = np.asarray(dispatch_ref(avail, order, rank, demand,
                                   min_dwell=dwell))
    soft = np.asarray(soft_dispatch(avail, keys, order, demand,
                                    tau=1e-3, min_dwell=dwell))
    np.testing.assert_allclose(soft, hard, atol=5e-3,
                               err_msg=f"mc={mc} dwell={dwell}")


@pytest.mark.parametrize("mc,dwell", [(5.0, 4), (3.0, 8)])
def test_soft_converges_to_hard_cpc(mc, dwell):
    """CPC of the soft allocation converges to the hard CPC even in
    dwell-heavy configs where isolated lock flips can keep a few hours'
    allocations apart (the locks are hair-trigger; the cost is not)."""
    prices, avail, demand = _random_case(6, 400)
    keys = segment_keys(prices, mc)
    prob = _hard_problem(prices, avail, demand, mc, dwell)
    hard = summarize_alloc(prob, np.asarray(dispatch_ref(
        avail, prob.order, prob.rank, demand, min_dwell=dwell)))
    soft = summarize_alloc(prob, np.asarray(soft_dispatch(
        avail, keys, prob.order, demand, tau=1e-3, min_dwell=dwell)))
    assert soft.cpc == pytest.approx(hard.cpc, rel=1e-3)
    assert soft.delivered_mwh == pytest.approx(hard.delivered_mwh,
                                               rel=1e-5)


def test_temperature_monotone_smoothing():
    """Warmer temperatures spread the allocation: the max per-site
    share of a single hour's demand decreases (weakly) with tau, while
    every temperature still sums to the demand."""
    prices, avail, demand = _random_case(6, 200)
    keys = segment_keys(prices, 0.0)
    order, _ = segment_rank(prices, 0.0)
    peak = []
    for tau in (1e-2, 5.0, 50.0):
        alloc = np.asarray(soft_dispatch(avail, keys, order, demand,
                                         tau=tau))
        np.testing.assert_allclose(alloc.sum(axis=0), demand, rtol=1e-4)
        peak.append((alloc / demand).max())
    assert peak[0] >= peak[1] >= peak[2]


# ---------------------------------------------------------------------------
# (c) zero fee / zero dwell: per-hour entropic softmin fill, no recurrence
# ---------------------------------------------------------------------------

def test_zero_fee_zero_dwell_reduces_to_per_hour_softmin_fill():
    """With no migration premium and no dwell the hours decouple: the
    allocation equals the per-hour entropic water-fill over widths =
    avail at keys = prices, computed independently per hour."""
    s, t, tau = 5, 120, 3.0
    prices, avail, demand = _random_case(s, t)
    keys = segment_keys(prices, 0.0)
    order, _ = segment_rank(prices, 0.0)
    got = np.asarray(soft_dispatch(avail, keys, order, demand, tau=tau))

    inv_tau = 1.0 / tau
    for h in range(0, t, 17):
        k = prices[:, h].astype(np.float64)
        w = avail[:, h].astype(np.float64)
        o = np.argsort(k, kind="stable")
        cums = np.cumsum(w[o])
        lam0 = k[o][min(int((cums < demand[h]).sum()), s - 1)]
        lam = soft_water_level(jnp.asarray(k), jnp.asarray(w),
                               demand[h], lam0, inv_tau)
        fill = w * jax.nn.sigmoid((lam - k) * inv_tau)
        fill = fill * demand[h] / fill.sum()
        # `got` ran in f32, the recomputation here in f64: the water
        # level agrees to f32 resolution, not better
        np.testing.assert_allclose(got[:, h], np.asarray(fill),
                                   rtol=5e-4, atol=1e-4,
                                   err_msg=f"hour {h}")


def test_site_permutation_invariance():
    """Shuffling site order permutes the allocation and nothing else.

    Run without dwell locks: the fee-retention recurrence is continuous
    in the running state, so reordered f32 summation inside the water
    level stays a rounding-level effect. (The dwell counter is a
    *discrete* ledger — hair-trigger by design — so bitwise-different
    summation orders can legitimately flip a lock; its soft dynamics
    are covered by the convergence and FD tests instead.)"""
    prices, avail, demand = _random_case(9, 300)
    perm = rng.permutation(9)
    mc, tau = 6.0, 1.5

    def run(p, a):
        keys = segment_keys(p, mc)
        order, _ = segment_rank(p, mc)
        return np.asarray(soft_dispatch(a, keys, order, demand, tau=tau))

    base = run(prices, avail)
    shuf = run(prices[perm], avail[perm])
    np.testing.assert_allclose(base[perm], shuf, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# (d) gradients vs central finite differences (float64)
# ---------------------------------------------------------------------------

def test_soft_dispatch_gradients_match_fd():
    """Reverse-mode through the water level (bisection under
    stop_gradient + one differentiable Newton step) against central
    differences on availability and demand, rtol <= 1e-3 in f64."""
    with enable_x64():
        r = np.random.default_rng(3)
        s, t = 4, 24
        prices = r.normal(80, 40, (s, t))
        avail0 = r.uniform(0.5, 2.0, (s, t))
        demand = np.full(t, 0.45 * avail0.sum(axis=0).min())
        mc = 4.0
        keys = segment_keys(prices, mc)
        order, _ = segment_rank(prices, mc)

        def cost(avail, dem):
            alloc = soft_dispatch_ref(avail, keys, order, dem, tau=3.0,
                                      min_dwell=3)
            return jnp.sum(alloc * jnp.asarray(prices))

        g_a = jax.grad(cost, argnums=0)(jnp.asarray(avail0),
                                        jnp.asarray(demand))
        g_d = jax.grad(cost, argnums=1)(jnp.asarray(avail0),
                                        jnp.asarray(demand))
        for i, j in zip(r.integers(0, s, 8), r.integers(0, t, 8)):
            h = 1e-6
            hi, lo = avail0.copy(), avail0.copy()
            hi[i, j] += h
            lo[i, j] -= h
            fd = (float(cost(jnp.asarray(hi), jnp.asarray(demand)))
                  - float(cost(jnp.asarray(lo), jnp.asarray(demand)))
                  ) / (2 * h)
            np.testing.assert_allclose(
                float(g_a[i, j]), fd, rtol=1e-3, atol=1e-4,
                err_msg=f"d/d avail[{i},{j}]")
        for j in (0, 7, 23):
            h = 1e-6
            hi, lo = demand.copy(), demand.copy()
            hi[j] += h
            lo[j] -= h
            fd = (float(cost(jnp.asarray(avail0), jnp.asarray(hi)))
                  - float(cost(jnp.asarray(avail0), jnp.asarray(lo)))
                  ) / (2 * h)
            np.testing.assert_allclose(float(g_d[j]), fd, rtol=1e-3,
                                       atol=1e-4,
                                       err_msg=f"d/d demand[{j}]")


def test_dispatch_aware_objective_gradients_match_fd():
    """Central FD through the *whole* dispatch-aware soft objective —
    scan relaxation, soft selection, water-fill, migration accounting —
    on every raw coordinate, rtol <= 1e-3 in f64. Uses the same FD
    harness the CI benchmark gate runs (`benchmarks.bench_tune.
    fd_grad_worst_rel_err`), at a different horizon so the two probe
    different fixed-seed problems."""
    from benchmarks.bench_tune import fd_grad_worst_rel_err
    worst = fd_grad_worst_rel_err(t=72)
    assert worst <= 1e-3, f"worst FD-vs-autodiff rel err {worst:.2e}"


# ---------------------------------------------------------------------------
# (e) dispatch-aware tuning: acceptance, swing site, determinism, chunking
# ---------------------------------------------------------------------------

_T = 400
_DCFG = DispatchConfig(demand_frac=0.25, migrate_cost=4.0, min_dwell_h=3)


def _fleet_grid(n_markets=3, n_policies=3, t=_T):
    markets = [MarketParams(n_hours=t, seed=s) for s in range(n_markets)]
    sys = make_system(0.5 * t * 80.0, 1.0, float(t))
    pols = [PolicySpec("ao"), PolicySpec("x5", x=0.05, off_level=0.3),
            PolicySpec("x10", x=0.10, off_level=0.3)][:n_policies]
    return build_grid(markets, [sys], pols)


def _acceptance_grid():
    """The fixed-seed 256-row grid of tests/test_tune.py, with a partial
    off-level so shut sites still offer dispatchable capacity."""
    t = 600
    markets = [MarketParams(n_hours=t, seed=s) for s in range(4)]
    systems = [make_system(float(psi) * t * 1.0 * 80.0, 1.0, float(t))
               for psi in (0.5, 1.0, 2.0, 4.0)]
    xs = (0.01, 0.02, 0.03, 0.05, 0.08, 0.10, 0.12, 0.15,
          0.20, 0.25, 0.30, 0.40)
    policies = [PolicySpec("ao")] + \
        [PolicySpec(f"x{int(x * 100)}", x=x, off_level=0.25)
         for x in xs] + \
        [PolicySpec("x3h", x=0.03, hysteresis=0.9, off_level=0.25),
         PolicySpec("x8h", x=0.08, hysteresis=0.85, off_level=0.25),
         PolicySpec("x15h", x=0.15, hysteresis=0.9, off_level=0.25)]
    return build_grid(markets, systems, policies)


def test_dispatch_aware_beats_rescore_only_on_acceptance_grid():
    """The tentpole acceptance: on the 256-row grid, dispatch-aware
    tuned policies hard-re-scored on feasible `dispatch()` achieve
    fleet CPC <= the PR-3 re-score-only path, and never worse than the
    best-swept set (min(tuned, swept) is reported either way)."""
    grid = _acceptance_grid()
    assert grid.n_rows == 256
    dcfg = DispatchConfig(demand_frac=0.3, migrate_cost=4.0,
                          min_dwell_h=3)
    rescore = optimize(grid, TuneConfig(steps=150, dispatch=dcfg))
    aware = optimize(grid, TuneConfig(steps=150, dispatch_soft=dcfg))
    cpc_rescore = min(rescore.dispatch["cpc_tuned"],
                      rescore.dispatch["cpc_swept"])
    cpc_aware = min(aware.dispatch["cpc_tuned"],
                    aware.dispatch["cpc_swept"])
    assert np.isfinite(cpc_aware)
    assert cpc_aware <= cpc_rescore * (1.0 + 1e-9)
    # the guarantee survives the coupling: never worse than best swept
    assert cpc_aware <= aware.dispatch["cpc_swept"] * (1.0 + 1e-9)


def test_swing_site_effect():
    """Under the fleet objective at least one site learns a materially
    different threshold than isolated tuning: with spare fleet capacity
    some candidate is pushed toward an always-on backup role (threshold
    far above the isolated optimum) so cheaper sites can chase prices."""
    grid = _fleet_grid()
    iso = optimize(grid, TuneConfig(steps=60, dispatch=_DCFG))
    aware = optimize(grid, TuneConfig(steps=60, dispatch_soft=_DCFG))
    p_iso = np.asarray(iso.params.p_off)
    p_aware = np.asarray(aware.params.p_off)
    # materially different: at least one site moved its shutdown
    # threshold by more than 20% of the isolated value
    rel = np.abs(p_aware - p_iso) / np.abs(p_iso)
    assert rel.max() > 0.2, (p_iso, p_aware)
    # and the role-shaped fleet is at least as good under *hard*
    # feasible dispatch (the dispatch_ratio history itself is measured
    # at the annealing τ of its step, so its endpoints are not
    # comparable — the hard re-score is)
    cpc_iso = min(iso.dispatch["cpc_tuned"], iso.dispatch["cpc_swept"])
    cpc_aware = min(aware.dispatch["cpc_tuned"],
                    aware.dispatch["cpc_swept"])
    assert np.isfinite(cpc_aware)
    assert cpc_aware <= cpc_iso * (1.0 + 1e-9)


def test_dispatch_aware_pipeline_seeded_determinism():
    """Full pipeline (build_grid -> tune_loop(dispatch_soft) -> hard
    dispatch re-score) twice from the same seed is bit-identical."""
    def run():
        grid = _fleet_grid()
        res = optimize(grid, TuneConfig(steps=25, dispatch_soft=_DCFG))
        return res

    a, b = run(), run()
    for field in ("p_on", "p_off", "off_level"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.params, field)),
            np.asarray(getattr(b.params, field)), err_msg=field)
    np.testing.assert_array_equal(a.cpc, b.cpc)
    assert a.dispatch["cpc_tuned"] == b.dispatch["cpc_tuned"]
    assert a.dispatch["cpc_swept"] == b.dispatch["cpc_swept"]
    if a.dispatch["tuned"] is not None:
        np.testing.assert_array_equal(a.dispatch["tuned"].alloc_mw,
                                      b.dispatch["tuned"].alloc_mw)


def test_chunked_dispatch_aware_objective_raises():
    """Coupled rows cannot chunk: the water level spans the whole
    fleet, so `chunk_rows` with `dispatch_soft` must raise instead of
    silently optimizing a different objective."""
    grid = _fleet_grid()
    with pytest.raises(ValueError, match="dispatch_soft"):
        optimize(grid, TuneConfig(steps=5, chunk_rows=4,
                                  dispatch_soft=_DCFG))


def test_dispatch_reeval_runs_under_dispatch_soft_alone():
    """dispatch_soft alone (no TuneConfig.dispatch) still hard-scores
    the final sets on feasible dispatch()."""
    grid = _fleet_grid()
    res = optimize(grid, TuneConfig(steps=20, dispatch_soft=_DCFG))
    d = res.dispatch
    assert d is not None and d["chosen"] in ("tuned", "swept")
    chosen = d[d["chosen"]]
    demand = _DCFG.demand_frac * grid.n_markets * 1.0
    np.testing.assert_allclose(chosen.alloc_mw.sum(axis=0),
                               np.full(_T, demand), rtol=1e-4)
