"""Paper-model tests: Eqs. (1)-(29) identities, the viability criterion,
and the published case-study numbers. Hypothesis drives the identity tests
over arbitrary price series."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import assume, given, settings, st

from repro.core import optimizer as copt
from repro.core import price_model as pm
from repro.core import scenarios, tco
from repro.core.regions import (PAPER_LICHTENBERG, PAPER_TABLE2,
                                psi_for_region)

prices_arrays = st.lists(
    st.floats(min_value=-50.0, max_value=3000.0, allow_nan=False,
              width=32),
    min_size=16, max_size=400).map(lambda xs: np.asarray(xs, np.float32))


def _positive_mean(p):
    return float(np.mean(p)) > 1.0


# ---------------------------------------------------------------------------
# price model (Eqs. 1-5, 20)
# ---------------------------------------------------------------------------

@given(prices_arrays)
@settings(max_examples=60, deadline=None)
def test_pv_weighted_mean_identity(prices):
    assume(_positive_mean(prices))
    """Eq. (2): p_avg == x*p_high + (1-x)*p_low at every PV point."""
    pv = pm.price_variability(prices)
    lhs = pv.x * pv.p_high + (1 - pv.x) * pv.p_low
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(pv.p_avg),
                               rtol=2e-3, atol=2e-3)


@given(prices_arrays)
@settings(max_examples=60, deadline=None)
def test_region_means_closed_form(prices):
    assume(_positive_mean(prices))
    """Eqs. (4)-(5) reconstruct p_high/p_low from (p_avg, k, x)."""
    pv = pm.price_variability(prices)
    p_high, p_low = pm.region_means(pv.p_avg, pv.k, pv.x)
    atol = 1e-4 * max(float(np.abs(prices).max()), 1.0)  # f32 cancellation
    np.testing.assert_allclose(np.asarray(p_high), np.asarray(pv.p_high),
                               rtol=2e-3, atol=atol)
    np.testing.assert_allclose(np.asarray(p_low), np.asarray(pv.p_low),
                               rtol=2e-3, atol=atol)


@given(prices_arrays)
@settings(max_examples=60, deadline=None)
def test_k_non_increasing_in_x(prices):
    assume(_positive_mean(prices))
    """k(x) is non-increasing: adding lower samples to the high region can
    only lower its mean. (The monotonicity Fig. 3 relies on.)"""
    pv = pm.price_variability(prices)
    k = np.asarray(pv.k)
    assert np.all(k[1:] <= k[:-1] + 1e-4)


def test_threshold_is_quantile():
    prices = np.arange(1.0, 101.0, dtype=np.float32)   # 1..100
    # x = 0.1 -> top-10 region -> threshold = 10th highest = 91
    assert float(pm.threshold_price(prices, 0.10)) == pytest.approx(91.0)


def test_resample_means_preserved():
    rng = np.random.default_rng(0)
    p = rng.normal(80, 30, size=24 * 7).astype(np.float32)
    day = pm.resample(jnp.asarray(p), 24)
    assert day.shape[0] == 7
    np.testing.assert_allclose(float(jnp.mean(day)), float(np.mean(p)),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# TCO / CPC (Eqs. 6-19)
# ---------------------------------------------------------------------------

@given(prices_arrays)
@settings(max_examples=60, deadline=None)
def test_ews_equals_low_region_cost(prices):
    assume(_positive_mean(prices))
    """Eq. (7) == Eq. (9): T*C*(1-x)*p_low == T*C*p_avg*(1-kx)."""
    pv = pm.price_variability(prices)
    sys = tco.make_system(fixed=1000.0, power=2.0, period=100.0)
    e1 = sys.T * sys.C * (1 - pv.x) * pv.p_low
    e2 = tco.energy_cost_with_shutdowns(sys, pv.p_avg, pv.k, pv.x)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=2e-3, atol=0.5)


@given(st.floats(0.1, 10.0), st.floats(1.01, 40.0),
       st.floats(0.001, 0.9))
@settings(max_examples=100, deadline=None)
def test_viability_iff_k_exceeds_psi_plus_one(psi_val, k, x):
    """Eq. (19): CPC_WS < CPC_AO  <=>  k > Psi + 1, for every x.

    The x-independence is the paper's central observation."""
    ratio = float(tco.cpc_ratio(psi_val, k, x))
    improves = ratio < 1.0
    criterion = k > psi_val + 1.0
    assert improves == criterion


def test_cpc_ratio_dimensionless_matches_dimensional():
    sys = tco.make_system(fixed=5000.0, power=1.5, period=200.0)
    p_avg, k, x = 80.0, 5.0, 0.02
    psi_val = float(tco.psi(sys, p_avg))
    full = float(tco.cpc_with_shutdowns(sys, p_avg, k, x)
                 / tco.cpc_always_on(sys, p_avg))
    reduced = float(tco.cpc_ratio(psi_val, k, x))
    assert full == pytest.approx(reduced, rel=1e-5)


# ---------------------------------------------------------------------------
# the paper's published numbers
# ---------------------------------------------------------------------------

def test_lichtenberg_closed_form_cpc_reduction():
    """Section IV-A: with Psi=2, x_opt=0.8189%, k_opt=4.9726 the paper
    reports a 0.5429% CPC reduction — Eq. (28) must reproduce it."""
    red = float(tco.cpc_reduction(2.0,
                                  PAPER_LICHTENBERG["k_opt"],
                                  PAPER_LICHTENBERG["x_opt_pct"] / 100))
    assert red * 100 == pytest.approx(PAPER_LICHTENBERG["cpc_red_pct"],
                                      abs=5e-3)


def test_table2_psi_rule():
    """Table II's Psi column follows Psi_region = Psi_LB * p_DE / p_region."""
    for row in PAPER_TABLE2.values():
        assert psi_for_region(row.p_avg) == pytest.approx(row.psi, abs=0.01)


def test_break_even_on_synthetic_two_level_series():
    """A two-level price series has an analytic break-even point.

    10% of hours at 1000, rest at 50 (p_avg = 145). With Psi = 3, k(x)
    must stay above Psi+1 = 4: mean(top m) = (10000 + (m-10)*50)/m for
    m >= 10, which crosses 4*145 = 580 at m = 9500/530 ~ 17.9 -> x_BE =
    0.17 (the break-even extends *past* the spike fraction — the high
    region may profitably absorb some cheap hours)."""
    prices = np.asarray([1000.0] * 10 + [50.0] * 90, np.float32)
    psi_val = 3.0
    plan = copt.optimal_shutdown(prices, psi_val)
    assert bool(plan.viable)
    assert float(plan.x_break_even) == pytest.approx(0.17, abs=0.011)
    # and at the spike fraction itself k is comfortably viable
    assert 1000.0 / 145.0 > psi_val + 1.0


def test_psi_sweep_monotone_nonincreasing():
    """Fig. 5: the max CPC reduction is non-increasing in Psi."""
    rng = np.random.default_rng(1)
    prices = np.abs(rng.normal(80, 40, 2000)).astype(np.float32) \
        + rng.pareto(3.0, 2000).astype(np.float32) * 50
    psis = np.linspace(0.05, 6.0, 30).astype(np.float32)
    red = np.asarray(copt.psi_sweep(prices, psis))
    assert np.all(red[1:] <= red[:-1] + 1e-6)
    assert np.all(red >= 0)


def test_optimal_shutdown_never_worse_than_ao():
    rng = np.random.default_rng(2)
    for seed in range(5):
        prices = np.abs(rng.normal(70, 30, 500)).astype(np.float32)
        plan = copt.optimal_shutdown(prices, 2.0)
        assert float(plan.cpc_reduction) >= 0.0


# ---------------------------------------------------------------------------
# scenarios (Eq. 30, Psi scaling)
# ---------------------------------------------------------------------------

@given(prices_arrays, st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_amplify_volatility_eq30(prices, beta):
    assume(_positive_mean(prices))
    out = np.asarray(scenarios.amplify_volatility(prices, beta))
    neg = prices <= 0
    np.testing.assert_allclose(out[neg], prices[neg], rtol=1e-6)
    expected = prices * (1 - beta) / 2 + prices * beta * 2
    np.testing.assert_allclose(out[~neg], expected[~neg], rtol=1e-5,
                               atol=1e-30)  # subnormal rounding


def test_amplify_increases_variability_when_beta_tracks_price():
    """When expensive hours are fossil-heavy (the realistic coupling),
    Eq. (30) increases k at small x."""
    rng = np.random.default_rng(3)
    prices = np.abs(rng.normal(80, 30, 1000)).astype(np.float32)
    beta = np.clip((prices - prices.min())
                   / (prices.max() - prices.min()), 0, 1)
    amp = np.asarray(scenarios.amplify_volatility(prices, beta))
    k0 = float(pm.price_stats(prices, 0.01).k)
    k1 = float(pm.price_stats(amp, 0.01).k)
    assert k1 > k0


def test_scale_fixed_costs():
    assert float(scenarios.scale_fixed_costs(2.0, 0.8)) \
        == pytest.approx(1.6)
