"""Optional-``hypothesis`` shim for the property-based tests.

The tier-1 environment does not ship ``hypothesis``; hard-importing it made
three whole modules fail *collection*, taking their plain pytest cases down
with them. Importing ``given``/``settings``/``assume``/``st`` from here keeps
every non-property test runnable everywhere: with hypothesis installed the
real objects are re-exported, without it the ``@given`` decorator turns the
test into a skip and the strategy namespace accepts (and ignores) any
strategy-building expression evaluated at module import time.
"""

import pytest

try:
    from hypothesis import assume, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any strategy construction chain (st.lists(...).map(...))."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda fn: fn

    def assume(condition):
        return True


__all__ = ["HAVE_HYPOTHESIS", "assume", "given", "settings", "st"]
